# Build-time artifact production (Python never runs on the request path).
#
# `make artifacts` trains the tiny-LM zoo (python/compile/pretrain.py) and
# lowers the AOT solver kernels to HLO text (python/compile/aot.py) into
# rust/artifacts/ — the directory the Rust tests and benches read
# (override with OJBKQ_ARTIFACTS). CI caches this directory keyed on the
# Python sources so `pjrt_roundtrip` and the trained-model smoke tests
# run without retraining on every push.

PYTHON    ?= python3
ARTIFACTS ?= rust/artifacts

.PHONY: artifacts artifacts-quick golden-fixture test bench trajectory clean-artifacts

# Regenerate the committed OJBQ1 golden fixture + logits snapshot
# (rust/tests/fixtures/) — only needed on a deliberate format bump; the
# fixture test compares bytes, so commit the result.
golden-fixture:
	$(PYTHON) python/tools/make_golden_ojbq1.py

artifacts:
	cd python && $(PYTHON) -m compile.pretrain --out ../$(ARTIFACTS)
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

# Reduced flavor for CI / smoke runs: one model, fewer steps, quick AOT
# variant subset. Produces the same file formats in the same place, then
# emits the perf-trajectory record against the freshly trained model.
artifacts-quick:
	cd python && $(PYTHON) -m compile.pretrain --out ../$(ARTIFACTS) \
		--models tiny-0.2M --steps 200
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS) --quick
	$(MAKE) trajectory

# Perf-trajectory artifacts: quick-scale packed-GEMM + solver +
# token-serving + robustness benches (BENCH_qgemm.json /
# BENCH_solver.json / BENCH_serve.json / BENCH_robust.json, written to
# rust/) plus a traced tiny-model quantize whose trace.json must pass
# the schema checker — the files the CI artifact job uploads on every
# push so perf and quant quality are comparable across commits.
trajectory:
	cd rust && OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_qgemm
	cd rust && OJBKQ_BENCH_QUICK=1 cargo bench --bench perf_solver
	cd rust && OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_serve
	cd rust && OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_robust
	cd rust && cargo run --release -- quantize --model tiny-0.2M \
		--calib 4 --seq 64 --trace-out trace.json --trace
	cd rust && cargo run --release -- check-trace trace.json

test:
	cd rust && cargo test --release -q

bench:
	cd rust && cargo bench

clean-artifacts:
	rm -rf $(ARTIFACTS)
