# Build-time artifact production (Python never runs on the request path).
#
# `make artifacts` trains the tiny-LM zoo (python/compile/pretrain.py) and
# lowers the AOT solver kernels to HLO text (python/compile/aot.py) into
# rust/artifacts/ — the directory the Rust tests and benches read
# (override with OJBKQ_ARTIFACTS). CI caches this directory keyed on the
# Python sources so `pjrt_roundtrip` and the trained-model smoke tests
# run without retraining on every push.

PYTHON    ?= python3
ARTIFACTS ?= rust/artifacts

.PHONY: artifacts artifacts-quick golden-fixture test bench clean-artifacts

# Regenerate the committed OJBQ1 golden fixture + logits snapshot
# (rust/tests/fixtures/) — only needed on a deliberate format bump; the
# fixture test compares bytes, so commit the result.
golden-fixture:
	$(PYTHON) python/tools/make_golden_ojbq1.py

artifacts:
	cd python && $(PYTHON) -m compile.pretrain --out ../$(ARTIFACTS)
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

# Reduced flavor for CI / smoke runs: one model, fewer steps, quick AOT
# variant subset. Produces the same file formats in the same place.
artifacts-quick:
	cd python && $(PYTHON) -m compile.pretrain --out ../$(ARTIFACTS) \
		--models tiny-0.2M --steps 200
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS) --quick

test:
	cd rust && cargo test --release -q

bench:
	cd rust && cargo bench

clean-artifacts:
	rm -rf $(ARTIFACTS)
