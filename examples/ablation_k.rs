//! Candidate-set exploration demo: how the best Babai-Klein residual
//! improves as K grows, on real layers of a trained model — the
//! per-column view behind Figure 2's perplexity curve.
//!
//! ```sh
//! cargo run --release --example ablation_k -- [--model small-0.8M] [--layer 0]
//! ```

use ojbkq::cli::Args;
use ojbkq::coordinator::Workbench;
use ojbkq::linalg::{cholesky_upper_jittered, syrk_upper};
use ojbkq::model::{LinearId, LinearKind, TapPoint, TapSet};
use ojbkq::quant::klein::{alpha_for, decode_kbest};
use ojbkq::quant::scales;
use ojbkq::quant::QuantConfig;
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let name = args.get_str("model", "small-0.8M");
    let block = args.get_usize("layer", 0);
    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let wb = Workbench::load(&dir, &name);

    // Capture real activations for the chosen block's QKV input.
    let mut rng = Rng::new(7);
    let calib = wb.corpus.calibration(8, wb.model.cfg.max_seq, &mut rng);
    let mut taps = TapSet::request(block, &[TapPoint::AttnIn]);
    for seq in &calib {
        wb.model.forward_prefix_taps(seq, &mut taps, block);
    }
    let x = taps.take(block, TapPoint::AttnIn).expect("tap");
    let id = LinearId { block, kind: LinearKind::Q };
    let w = wb.model.linear(id);
    println!(
        "layer {id} of {name}: X is {}x{}, W is {}x{}\n",
        x.rows(),
        x.cols(),
        w.rows(),
        w.cols()
    );

    // Build the BILS geometry for a handful of columns.
    let cfg = QuantConfig::paper_defaults(3, 128);
    let sc = scales::compute(w, &cfg);
    let gram = syrk_upper(&x, 0.0);
    let (r, _) = cholesky_upper_jittered(&gram, 1e-6)?;
    let qmax = cfg.box_max() as f32;
    let m = w.rows();

    let ks = [1usize, 2, 5, 10, 25, 50];
    let mut table = Table::new(
        &format!("Best Babai-Klein residual vs K — {id} (3-bit)"),
        &["column", "K=1", "K=2", "K=5", "K=10", "K=25", "K=50"],
    );
    let mut totals = vec![0.0f64; ks.len()];
    for j in (0..w.cols()).step_by(w.cols() / 6).take(6) {
        let s = sc.col_scale_vec(j);
        let z = sc.col_zero_vec(j);
        // q̄ for the runtime-consistent objective is W itself in q-space.
        let qbar: Vec<f32> =
            (0..m).map(|i| w.get(i, j) / s[i] + z[i]).collect();
        let min_rbar_sq = (0..m)
            .map(|i| {
                let v = r.get(i, i) as f64 * s[i] as f64;
                v * v
            })
            .fold(f64::INFINITY, f64::min);
        let mut row = vec![format!("col {j}")];
        for (ki, &k) in ks.iter().enumerate() {
            let mut krng = Rng::new(1000 + j as u64);
            let (_, res) = decode_kbest(&r, &s, &qbar, qmax, k, &mut krng);
            row.push(format!("{res:.4}"));
            totals[ki] += res;
            let _ = alpha_for(k, m, min_rbar_sq); // shown for doc purposes
        }
        table.push_row(&row);
    }
    table.emit(None, "ablation_k");
    println!("column-sum residuals by K: {totals:?}");
    println!("(monotone non-increasing; the K=1→5 drop dominates — Figure 2's knee)");
    Ok(())
}
