//! PJRT backend demo: run the same layer quantization through (a) the
//! native Rust PPI decoder and (b) the AOT-compiled Pallas kernel loaded
//! via the PJRT CPU client, and verify the codes agree — the L3↔L2↔L1
//! composition proof in example form.
//!
//! ```sh
//! cargo run --release --example pjrt_backend
//! ```
//! Requires `make artifacts` (decoder HLO variants).

use ojbkq::quant::{ojbkq as ojbkq_solver, Backend, QuantConfig};
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use ojbkq::tensor::Matrix;
use ojbkq::util::timed;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::var("OJBKQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let rt = SolverRuntime::new(&dir)?;
    println!("PJRT registry: {} decoder variants", rt.registry().len());
    anyhow::ensure!(
        !rt.registry().is_empty(),
        "no decoder artifacts in {dir:?}; run `make artifacts`"
    );

    let mut rng = Rng::new(3);
    let (m, n, p) = (96usize, 80usize, 192usize);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let x = Matrix::randn(p, m, 1.0, &mut rng);

    let base = QuantConfig { k: 5, ..QuantConfig::paper_defaults(4, 32) };
    let native_cfg = QuantConfig { backend: Backend::Native, ..base.clone() };
    let pjrt_cfg = QuantConfig { backend: Backend::Pjrt, ..base };

    let mut rng_a = Rng::new(11);
    let mut rng_b = Rng::new(11);
    let (q_native, t_native) =
        timed(|| ojbkq_solver::quantize(&w, &x, &x,&native_cfg, &mut rng_a, None).unwrap());
    let (q_pjrt, t_pjrt) =
        timed(|| ojbkq_solver::quantize(&w, &x, &x,&pjrt_cfg, &mut rng_b, Some(&rt)).unwrap());

    let total = q_native.codes.len();
    let mismatches = q_native
        .codes
        .iter()
        .zip(&q_pjrt.codes)
        .filter(|(a, b)| a != b)
        .count();
    println!(
        "native: {t_native:.3}s   pjrt (incl. first-compile): {t_pjrt:.3}s\n\
         code agreement: {}/{total} ({} mismatches, {:.4}%)",
        total - mismatches,
        mismatches,
        100.0 * mismatches as f64 / total as f64
    );
    anyhow::ensure!(
        (mismatches as f64) / (total as f64) < 0.01,
        "backends disagree beyond float-boundary noise"
    );
    // Output-space agreement.
    let rel = q_pjrt.dequantize().rel_err(&q_native.dequantize());
    println!("dequantized weight relative difference: {rel:.2e}");
    println!("OK: the AOT Pallas artifact reproduces the native hot path.");
    Ok(())
}
