//! **End-to-end driver** (DESIGN.md's E1 workload): load a tiny LM
//! trained at build time (`make artifacts`), run the full layer-wise
//! quantization pipeline for every method, and report the paper's
//! headline metric — held-out perplexity on the in-domain and shifted
//! corpora — plus compression ratio and wall time. The run recorded in
//! EXPERIMENTS.md §End-to-end comes from this binary.
//!
//! ```sh
//! cargo run --release --example quantize_pipeline -- \
//!     [--model small-0.8M] [--wbit 4] [--group 128] [--methods rtn,gptq,ours]
//! ```

use ojbkq::cli::Args;
use ojbkq::coordinator::{quantize_model, Workbench};
use ojbkq::eval::perplexity_pair;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::util::fmt_secs;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let name = args.get_str("model", "small-0.8M");
    let wbit = args.get_usize("wbit", 4) as u8;
    let group = args.get_usize("group", 128);
    let n_calib = args.get_usize("calib", 8);
    let seq = args.get_usize("seq", 128);
    let ppl_tokens = args.get_usize("ppl-tokens", 4096);
    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));

    let methods: Vec<Method> = match args.get("methods") {
        Some(list) => list
            .split(',')
            .map(|s| Method::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown method {s}")))
            .collect::<Result<_, _>>()?,
        None => vec![
            Method::Rtn,
            Method::Gptq,
            Method::Awq,
            Method::Quip,
            Method::BabaiNaive,
            Method::KleinRandomK,
            Method::Ojbkq,
        ],
    };

    let wb = Workbench::load(&dir, &name);
    println!(
        "model={name} ({} params, trained={}), calib {n_calib}x{seq}, W{wbit} g{group}\n",
        wb.model.cfg.param_count(),
        wb.trained
    );
    let (fp_in, fp_sh) =
        perplexity_pair(&wb.model, &wb.corpus, &wb.shifted, wb.model.cfg.max_seq, ppl_tokens);

    let mut table = Table::new(
        &format!("End-to-end: {name} W{wbit}A16 g{group}"),
        &[
            "method",
            "ppl in-domain",
            "ppl shifted",
            "Δppl",
            "compress",
            "resident",
            "quant time",
            "capture",
        ],
    );
    table.push_row(&[
        "BF16".into(),
        format!("{fp_in:.3}"),
        format!("{fp_sh:.3}"),
        "-".into(),
        "1.00x".into(),
        "1.00x".into(),
        "-".into(),
        "-".into(),
    ]);
    for method in methods {
        let cfg = QuantConfig::paper_defaults(wbit, group);
        let (qm, report) =
            quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, None)?;
        let (pin, psh) =
            perplexity_pair(&qm, &wb.corpus, &wb.shifted, wb.model.cfg.max_seq, ppl_tokens);
        table.push_row(&[
            method.label().into(),
            format!("{pin:.3}"),
            format!("{psh:.3}"),
            format!("{:+.3}", pin - fp_in),
            format!("{:.2}x", report.compression_ratio()),
            format!("{:.2}x", report.resident_compression()),
            fmt_secs(report.total_secs),
            fmt_secs(report.capture_secs),
        ]);
        eprintln!("[pipeline] {} done ({})", method.label(), fmt_secs(report.total_secs));
    }
    table.emit(Some(&PathBuf::from("results")), &format!("e2e_{name}_w{wbit}_g{group}"));
    Ok(())
}
