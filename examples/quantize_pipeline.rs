//! **End-to-end driver** (DESIGN.md's E1 workload): load a tiny LM
//! trained at build time (`make artifacts`), run the full layer-wise
//! quantization pipeline for every method, and report the paper's
//! headline metric — held-out perplexity on the in-domain and shifted
//! corpora — plus compression ratio and wall time. The run recorded in
//! EXPERIMENTS.md §End-to-end comes from this binary.
//!
//! ```sh
//! cargo run --release --example quantize_pipeline -- \
//!     [--model small-0.8M] [--wbit 4] [--group 128] [--methods rtn,gptq,ours] \
//!     [--save DIR]
//! ```
//!
//! With `--save DIR`, every method's quantized model is also written as a
//! native packed OJBQ1 checkpoint (`ojbkq::infer::save_quantized`),
//! reloaded, and checked bit-identical against the in-memory engine — the
//! deployment handoff in one example; the table gains an `artifact`
//! column with each checkpoint's size relative to the dense f32 export.

use ojbkq::cli::Args;
use ojbkq::coordinator::{quantize_model, Workbench};
use ojbkq::eval::perplexity_pair;
use ojbkq::infer::{load_quantized, save_quantized};
use ojbkq::model::LanguageModel;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{fmt_bytes, Table};
use ojbkq::util::fmt_secs;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let name = args.get_str("model", "small-0.8M");
    let wbit = args.get_usize("wbit", 4) as u8;
    let group = args.get_usize("group", 128);
    let n_calib = args.get_usize("calib", 8);
    let seq = args.get_usize("seq", 128);
    let ppl_tokens = args.get_usize("ppl-tokens", 4096);
    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));

    let methods: Vec<Method> = match args.get("methods") {
        Some(list) => list
            .split(',')
            .map(|s| Method::parse(s.trim()).ok_or_else(|| anyhow::anyhow!("unknown method {s}")))
            .collect::<Result<_, _>>()?,
        None => vec![
            Method::Rtn,
            Method::Gptq,
            Method::Awq,
            Method::Quip,
            Method::BabaiNaive,
            Method::KleinRandomK,
            Method::Ojbkq,
        ],
    };

    let wb = Workbench::load(&dir, &name);
    println!(
        "model={name} ({} params, trained={}), calib {n_calib}x{seq}, W{wbit} g{group}\n",
        wb.model.cfg.param_count(),
        wb.trained
    );
    let (fp_in, fp_sh) =
        perplexity_pair(&wb.model, &wb.corpus, &wb.shifted, wb.model.cfg.max_seq, ppl_tokens);

    let save_dir = args.get("save").map(PathBuf::from);
    if let Some(d) = &save_dir {
        std::fs::create_dir_all(d)?;
    }
    let mut table = Table::new(
        &format!("End-to-end: {name} W{wbit}A16 g{group}"),
        &[
            "method",
            "ppl in-domain",
            "ppl shifted",
            "Δppl",
            "compress",
            "resident",
            "artifact",
            "quant time",
            "capture",
        ],
    );
    table.push_row(&[
        "BF16".into(),
        format!("{fp_in:.3}"),
        format!("{fp_sh:.3}"),
        "-".into(),
        "1.00x".into(),
        "1.00x".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    let probe: Vec<u16> = wb.corpus.train()[..8.min(wb.corpus.train().len())].to_vec();
    for method in methods {
        let cfg = QuantConfig::paper_defaults(wbit, group);
        let (qm, mut report) =
            quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, None)?;
        if let Some(d) = &save_dir {
            // Ship the packed codes, reload them, and insist the loaded
            // engine is bit-identical to the in-memory one.
            let path = d.join(format!(
                "ckpt_{name}_w{wbit}_g{group}_{}.ojbq1",
                method.label().to_ascii_lowercase()
            ));
            let info = save_quantized(&qm, &path)?;
            report.artifact_bytes = Some(info.file_bytes);
            let back = load_quantized(&path, &name)?;
            anyhow::ensure!(
                back.forward(&probe) == qm.forward(&probe),
                "reloaded OJBQ1 checkpoint diverged from the in-memory engine"
            );
        }
        // The column reads the report field the save recorded.
        let artifact = match report.artifact_bytes {
            None => "-".to_string(),
            Some(b) => format!(
                "{} ({:.0}%)",
                fmt_bytes(b),
                100.0 * b as f64 / qm.dense_export_bytes() as f64
            ),
        };
        let (pin, psh) =
            perplexity_pair(&qm, &wb.corpus, &wb.shifted, wb.model.cfg.max_seq, ppl_tokens);
        table.push_row(&[
            method.label().into(),
            format!("{pin:.3}"),
            format!("{psh:.3}"),
            format!("{:+.3}", pin - fp_in),
            format!("{:.2}x", report.compression_ratio()),
            format!("{:.2}x", report.resident_compression()),
            artifact,
            fmt_secs(report.total_secs),
            fmt_secs(report.capture_secs),
        ]);
        eprintln!("[pipeline] {} done ({})", method.label(), fmt_secs(report.total_secs));
    }
    table.emit(Some(&PathBuf::from("results")), &format!("e2e_{name}_w{wbit}_g{group}"));
    Ok(())
}
