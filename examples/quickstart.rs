//! Quickstart: quantize one linear layer with every solver and compare
//! runtime-consistent output error — the 60-second tour of the library.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ojbkq::linalg::matmul;
use ojbkq::quant::{quantize_layer, Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use ojbkq::tensor::Matrix;
use ojbkq::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    // A synthetic layer: 128 input features, 96 output channels, with
    // correlated calibration activations (the regime where compensation-
    // and lattice-based solvers beat naive rounding).
    let mut rng = Rng::new(42);
    let (m, n, p) = (128usize, 96usize, 256usize);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let base = Matrix::randn(p, m, 1.0, &mut rng);
    let mix = Matrix::randn(m, m, 0.3, &mut rng);
    let x_fp = matmul(&base, &Matrix::eye(m).add(&mix));
    // Runtime activations drift slightly (as if upstream layers were
    // already quantized).
    let drift = Matrix::randn(p, m, 0.05, &mut rng);
    let x_rt = x_fp.add(&drift);

    let cfg = QuantConfig::paper_defaults(3, 64); // 3-bit, group size 64
    let y_ref = matmul(&x_rt, &w);

    let mut table = Table::new(
        "Quickstart — 3-bit g64 layer quantization",
        &["method", "runtime rel. error", "JTA score", "solve time"],
    );
    for &method in Method::all() {
        let (q, stats) = quantize_layer(method, &w, &x_fp, &x_rt, &cfg, 0, None)?;
        let w_hat = q.dequantize();
        let y_hat = matmul(&x_rt, &w_hat);
        let rel = y_hat.sub(&y_ref).frob() / y_ref.frob();
        let jta = ojbkq::quant::jta::score(&w_hat, &w, &x_fp, &x_rt, &cfg);
        table.push_row(&[
            method.label().to_string(),
            format!("{rel:.5}"),
            format!("{jta:.1}"),
            fmt_secs(stats.solve_secs),
        ]);
    }
    table.emit(None, "quickstart");
    println!(
        "Expected shape: lattice solvers (GPTQ/Ours*) ≪ RTN on runtime error;\n\
         Ours(R) ≤ Ours(N); and `Ours` wins on its own selection metric, the\n\
         JTA score (its end-to-end payoff is measured by the pipeline example).\n\
         Next: `cargo run --release --example quantize_pipeline`."
    );
    Ok(())
}
