"""AOT bridge: lower the Layer-2 tile solve to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Emits one artifact per static shape variant::

    artifacts/ojbkq_m{M}_t{T}_k{K}.hlo.txt

where M = row dimension, T = column-tile width, K = sampled paths
(uniforms carry K+1 paths; path 0 is the reserved greedy path). ``qmax``
is a runtime input, so bit-width is NOT part of the variant key.

Usage: python -m compile.aot [--out DIR] [--quick]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import layer_solve

#: Default variant registry: every (M, K) x T=64. M covers the tiny-LM
#: zoo's layer widths (96..768 padded up); K covers greedy (0) and the
#: paper default (5).
FULL_VARIANTS = [
    (m, 64, k) for m in (64, 128, 192, 256, 384, 512, 768) for k in (0, 5)
]
#: --quick subset used by CI-style runs.
QUICK_VARIANTS = [(64, 64, 0), (64, 64, 5), (128, 64, 5)]

#: PPI look-ahead block size compiled into the kernels (Appendix A's B).
BLOCK = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(m, t, k, block=BLOCK):
    """Lower one (M, T, K) decoder variant to HLO text."""
    p = k + 1
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((m, m), f32),  # R
        jax.ShapeDtypeStruct((m, t), f32),  # S
        jax.ShapeDtypeStruct((m, t), f32),  # QBAR
        jax.ShapeDtypeStruct((t,), f32),  # ALPHA
        jax.ShapeDtypeStruct((p, m, t), f32),  # UNIFORMS
        jax.ShapeDtypeStruct((), f32),  # QMAX
    )

    def fn(r, s, qbar, alpha, uniforms, qmax):
        return layer_solve(r, s, qbar, alpha, uniforms, qmax, block=block)

    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="output dir (default ../artifacts)")
    ap.add_argument("--quick", action="store_true", help="emit the quick subset only")
    args = ap.parse_args()
    out_dir = args.out or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    variants = QUICK_VARIANTS if args.quick else FULL_VARIANTS
    for m, t, k in variants:
        path = os.path.join(out_dir, f"ojbkq_m{m}_t{t}_k{k}.hlo.txt")
        text = lower_variant(m, t, k)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB)", file=sys.stderr)


if __name__ == "__main__":
    main()
