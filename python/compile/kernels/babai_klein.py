"""Layer-1 Pallas kernel: Parallel Path-Isolated K-best Babai decoding
(PPI-KBabai, paper Appendix A, Algorithm 2).

TPU re-think of the paper's CUDA design (DESIGN.md §7 Hardware-Adaptation):

* **Path isolation** = the leading tensor axis P (= K+1): every path's
  center/error buffers live in one VMEM-resident ``(P, B, T)`` block, so
  no cross-path state can be shared or corrupted -- correctness by
  construction rather than by synchronization.
* **Blocked look-ahead** (Algorithm 2 line 10) = a batched ``dot_general``
  over the path axis: ``ADJ = R[J, :] @ E`` hits the MXU instead of
  per-thread MACs. Because errors of unprocessed rows are zero, the full-
  width product equals the paper's ``R[J, F] @ E[F]`` with static shapes.
* **HBM<->VMEM schedule**: the whole tile (R: M*M, U: P*M*T, E/Q: P*M*T)
  is staged into VMEM by ``pallas_call``'s default BlockSpec; see
  ``vmem_bytes`` for the per-variant budget (<= ~6 MiB for M=768, T=64,
  P=6 -- within a TPU core's 16 MiB VMEM).
* **Sampling** (Eq. 13): a vectorized masked softmax over the 16 candidate
  code values + inverse-CDF against pre-supplied uniforms -- no divergent
  branches, no on-chip RNG primitive, bit-compatible with the Rust native
  decoder given identical uniforms.

``interpret=True`` is mandatory on the CPU PJRT plugin (real-TPU lowering
emits a Mosaic custom-call the CPU client cannot execute); XLA-CPU then
compiles the lowered HLO to native code, so the *runtime* path is
compiled, not interpreted.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Candidate code values enumerated by the sampler (supports wbit <= 4).
VMAX_CAND = 16


def round_code(c, qmax):
    """Round-half-away-from-zero, clamped to [0, qmax] (matches Rust
    f32::round on the non-negative box)."""
    return jnp.clip(jnp.floor(c + 0.5), 0.0, qmax)


def sample_codes(c, rbar, alpha, qmax, u):
    """Vectorized Eq. 13 sampling.

    c, u: (..., T) centers and uniforms; rbar: scalar or broadcastable
    (R_ii * s_i); alpha: (T,) temperatures. Returns codes with the same
    shape as ``c``. Max-subtracted at the clamped nearest integer and
    inverse-CDF'd with the strict ``cumsum > u * total`` rule -- the exact
    contract of rust klein::sample_code.
    """
    nearest = round_code(c, qmax)
    scale = alpha * rbar * rbar  # (..., T)
    v = jnp.arange(VMAX_CAND, dtype=c.dtype)  # (V,)
    dv = c[..., None] - v  # (..., T, V)
    dn = (c - nearest)[..., None]
    ex = -scale[..., None] * (dv * dv - dn * dn)
    weights = jnp.exp(ex)
    # Mask code values outside the box (v > qmax) -- one artifact serves
    # every bit-width -- and zero sub-significance weights (relative
    # exponent < -30 ~ 1e-13 of the max term). The cutoff makes all three
    # implementations agree exactly where XLA's FTZ / libm subnormal
    # behavior would otherwise diverge on ~1e-40 tail masses (see
    # rust klein::sample_code and ref.sample_code, same constant).
    weights = jnp.where((v <= qmax) & (ex >= -30.0), weights, 0.0)
    total = weights.sum(axis=-1)
    target = u * total
    cdf = jnp.cumsum(weights, axis=-1)
    idx = (cdf <= target[..., None]).sum(axis=-1)
    sampled = jnp.minimum(idx.astype(c.dtype), qmax)
    ok = jnp.isfinite(total) & (total > 0)
    return jnp.where(ok, sampled, nearest)


def _decode_body(r, s, qbar, alpha, u, qmax, block):
    """The blocked K-path back-substitution (pure jnp/lax; called from the
    Pallas kernel body on VMEM-resident values).

    Returns q_all: (P, M, T) integer codes as f32.
    """
    p, m, t = u.shape
    # Snap the look-ahead block to a divisor of M (artifact variants use
    # multiples of 16; odd tile heights fall back to smaller blocks).
    while m % block != 0:
        block -= 1
    nb = m // block

    def block_step(bi, state):
        e, q = state  # (P, M, T) each
        j_lo = (nb - 1 - bi) * block
        # --- 1. Global vectorized look-ahead (Algorithm 2 line 10).
        # Unprocessed rows of E are zero, so the full-width batched GEMM
        # equals R[J, F] @ E[F] with static shapes. (P, B, T)
        r_panel = jax.lax.dynamic_slice(r, (j_lo, 0), (block, m))  # (B, M)
        adj = jax.lax.dot_general(
            r_panel, e, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (B, P, T)
        adj = jnp.transpose(adj, (1, 0, 2))  # (P, B, T)
        # --- 2. Local sequential sweep within the block.
        r_blk = jax.lax.dynamic_slice(r, (j_lo, j_lo), (block, block))  # (B, B)
        s_blk = jax.lax.dynamic_slice(s, (j_lo, 0), (block, t))
        qbar_blk = jax.lax.dynamic_slice(qbar, (j_lo, 0), (block, t))
        u_blk = jax.lax.dynamic_slice(u, (0, j_lo, 0), (p, block, t))
        e_blk = jnp.zeros((p, block, t), dtype=jnp.float32)
        q_blk = jnp.zeros((p, block, t), dtype=jnp.float32)
        for rr in range(block - 1, -1, -1):  # static unroll (B steps)
            rloc = r_blk[rr]  # (B,)
            local = jnp.einsum("b,pbt->pt", rloc, e_blk)  # in-block errors
            rii = r_blk[rr, rr]
            s_i = s_blk[rr]  # (T,)
            c = qbar_blk[rr] + (adj[:, rr, :] + local) / (rii * s_i)  # (P, T)
            greedy = round_code(c[0], qmax)  # reserved greedy path
            sampled = sample_codes(c[1:], rii * s_i, alpha, qmax, u_blk[1:, rr, :])
            q_row = jnp.concatenate([greedy[None], sampled], axis=0)  # (P, T)
            e_row = s_i * (qbar_blk[rr] - q_row)
            e_blk = e_blk.at[:, rr, :].set(e_row)
            q_blk = q_blk.at[:, rr, :].set(q_row)
        e = jax.lax.dynamic_update_slice(e, e_blk, (0, j_lo, 0))
        q = jax.lax.dynamic_update_slice(q, q_blk, (0, j_lo, 0))
        return e, q

    e0 = jnp.zeros((p, m, t), dtype=jnp.float32)
    q0 = jnp.zeros((p, m, t), dtype=jnp.float32)
    _, q_all = jax.lax.fori_loop(0, nb, block_step, (e0, q0))
    return q_all


def _kernel(r_ref, s_ref, qbar_ref, alpha_ref, u_ref, qmax_ref, q_ref, *, block):
    """Pallas kernel body: stage the tile into VMEM values and decode."""
    r = r_ref[...]
    s = s_ref[...]
    qbar = qbar_ref[...]
    alpha = alpha_ref[...]
    u = u_ref[...]
    qmax = qmax_ref[0]
    q_ref[...] = _decode_body(r, s, qbar, alpha, u, qmax, block)


def ppi_decode(r, s, qbar, alpha, uniforms, qmax, *, block=16, interpret=True):
    """Decode one column tile with the Pallas PPI-KBabai kernel.

    Args mirror ``ref.decode_tile_ref``; ``qmax`` may be a traced scalar.
    Returns q_all: (P, M, T).
    """
    p, m, t = uniforms.shape
    qmax_arr = jnp.asarray(qmax, dtype=jnp.float32).reshape((1,))
    kernel = functools.partial(_kernel, block=block)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((p, m, t), jnp.float32),
        interpret=interpret,
    )(
        jnp.asarray(r, jnp.float32),
        jnp.asarray(s, jnp.float32),
        jnp.asarray(qbar, jnp.float32),
        jnp.asarray(alpha, jnp.float32),
        jnp.asarray(uniforms, jnp.float32),
        qmax_arr,
    )


def vmem_bytes(m, t, p, block=16):
    """Estimated VMEM working set of one kernel invocation (bytes):
    R + S + QBAR + U + E + Q + block scratch, all f32. Used by DESIGN.md's
    real-TPU feasibility analysis."""
    f = 4
    return f * (
        m * m  # R
        + 2 * m * t  # S, QBAR
        + t  # alpha
        + p * m * t  # U
        + 2 * p * m * t  # E, Q carries
        + 3 * p * block * t  # adj/e_blk/q_blk scratch
    )


def mxu_flops(m, t, p):
    """FLOPs of the batched look-ahead GEMMs (the MXU-eligible fraction):
    nb blocks x (B x M x P*T) MACs x 2."""
    return 2.0 * m * m * p * t  # sum over blocks of 2*B*M*(P*T) = 2*M^2*P*T
