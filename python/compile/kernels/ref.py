"""Pure-numpy oracle for the PPI-KBabai decoder.

Independent, deliberately-slow reference implementation of the paper's
Algorithms 1/3 (box-constrained Babai + Klein-randomized rounding) used to
validate both the Pallas kernel (`babai_klein.py`) and, transitively, the
Rust native decoder (which is tested against the same contract on the
Rust side).

Contract (shared with `rust/src/quant/ppi.rs` and the AOT artifact):

inputs
    r:        (M, M) f32 upper-triangular Cholesky factor (shared).
    s:        (M, T) f32 per-(row, column) scales.
    qbar:     (M, T) f32 real-valued LS solution in code space.
    alpha:    (T,)   f32 per-column Klein temperature.
    uniforms: (P, M, T) f32 in [0,1), P = K+1; path 0 is the reserved
              greedy path and ignores its uniforms.
    qmax:     scalar -- box upper bound (2^wbit - 1).

outputs
    q_all:  (P, M, T) integer codes as f32.
    resid:  (P, T) residuals ||R (s*(q - qbar))||^2 per path/column.

Sampling is Eq. 13 with the Liu-Ling-Stehle squared diagonal (see the doc
comment in rust/src/quant/klein.rs):

    Pr(q_i = v) ~ exp(-alpha (R_ii s_i)^2 ((c_i-v)^2 - (c_i-v*)^2))

max-subtracted at the clamped nearest integer v*, inverse-CDF sampled
against the supplied uniform with the strict `cumsum > u * total` rule.
"""

import numpy as np

#: Candidate code values enumerated by the samplers (supports wbit <= 4).
VMAX_CAND = 16


def round_code(c, qmax):
    """Round-half-away-from-zero then clamp to [0, qmax].

    numpy's np.round is banker's rounding; the Rust side uses f32::round
    (half away from zero). For the non-negative box this is floor(c+0.5).
    """
    return float(np.clip(np.floor(np.float32(c) + np.float32(0.5)), 0.0, qmax))


def sample_code(c, rbar_sq, alpha, qmax, u):
    """One Klein-randomized draw -- mirrors rust klein::sample_code."""
    n = int(qmax) + 1
    nearest = round_code(c, qmax)
    scale = np.float32(alpha) * np.float32(rbar_sq)
    dn = np.float32(c) - np.float32(nearest)
    weights = np.empty(n, dtype=np.float32)
    for v in range(n):
        dv = np.float32(c) - np.float32(v)
        ex = np.float32(-scale * (dv * dv - dn * dn))
        # Sub-significance cutoff shared with the Pallas kernel and the
        # Rust windowed sampler (same constant 30).
        weights[v] = np.exp(ex) if ex >= -30.0 else np.float32(0.0)
    total = np.float32(weights.sum(dtype=np.float32))
    if not np.isfinite(total) or not total > 0:
        return nearest
    target = np.float32(u) * total
    acc = np.float32(0.0)
    for v in range(n):
        acc = np.float32(acc + weights[v])
        if target < acc:
            return float(v)
    return float(qmax)


def decode_column(r, s_col, qbar_col, qmax, alpha_col, uniforms_col, greedy):
    """Decode one column via per-row back-substitution (Algorithm 1/3)."""
    m = r.shape[0]
    q = np.zeros(m, dtype=np.float32)
    e = np.zeros(m, dtype=np.float32)  # weight-space error s*(qbar - q)
    for i in range(m - 1, -1, -1):
        acc = float(
            np.dot(r[i, i + 1 :].astype(np.float64), e[i + 1 :].astype(np.float64))
        )
        c = np.float32(qbar_col[i]) + np.float32(
            acc / (float(r[i, i]) * float(s_col[i]))
        )
        if greedy:
            qi = round_code(c, qmax)
        else:
            rbar = float(r[i, i]) * float(s_col[i])
            qi = sample_code(
                float(c), rbar * rbar, float(alpha_col), qmax, float(uniforms_col[i])
            )
        q[i] = qi
        e[i] = np.float32(s_col[i]) * (np.float32(qbar_col[i]) - np.float32(qi))
    return q, e


def decode_tile_ref(r, s, qbar, alpha, uniforms, qmax):
    """Reference decode of a full tile. Returns (q_all, resid)."""
    r = np.asarray(r, dtype=np.float32)
    s = np.asarray(s, dtype=np.float32)
    qbar = np.asarray(qbar, dtype=np.float32)
    alpha = np.asarray(alpha, dtype=np.float32)
    uniforms = np.asarray(uniforms, dtype=np.float32)
    p, m, t = uniforms.shape
    assert r.shape == (m, m) and s.shape == (m, t) and qbar.shape == (m, t)
    q_all = np.zeros((p, m, t), dtype=np.float32)
    resid = np.zeros((p, t), dtype=np.float32)
    for path in range(p):
        for j in range(t):
            q, e = decode_column(
                r,
                s[:, j],
                qbar[:, j],
                qmax,
                alpha[j],
                uniforms[path, :, j],
                greedy=(path == 0),
            )
            q_all[path, :, j] = q
            re = r.astype(np.float64) @ e.astype(np.float64)
            resid[path, j] = np.float32((re * re).sum())
    return q_all, resid


def select_best(q_all, resid):
    """Argmin-residual candidate per column (Algorithm 4)."""
    winner = np.argmin(resid, axis=0)  # (T,)
    p, m, t = q_all.shape
    q_best = np.zeros((m, t), dtype=np.float32)
    for j in range(t):
        q_best[:, j] = q_all[winner[j], :, j]
    return q_best, winner


def make_case(m, t, k, seed, qmax=15.0, oversample=2):
    """Random well-posed decoder case (shared by tests and benches)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m * oversample + 2, m)).astype(np.float32)
    g = a.T @ a + 0.05 * np.eye(m, dtype=np.float32)
    r = np.linalg.cholesky(g).T.astype(np.float32)  # upper: g = r.T @ r
    s = (0.05 + 0.2 * rng.random((m, t))).astype(np.float32)
    qbar = (qmax * rng.random((m, t))).astype(np.float32)
    rbar_diag = np.diag(r)[:, None] * s  # (M, T)
    min_rbar_sq = (rbar_diag**2).min(axis=0)  # (T,)
    alpha = (np.log(solve_rho(max(k, 2), m)) / np.maximum(min_rbar_sq, 1e-30)).astype(
        np.float32
    )
    uniforms = rng.random((k + 1, m, t)).astype(np.float32)
    return r, s, qbar, alpha, uniforms


def solve_rho(k, m):
    """Solve K = (e*rho)^(2m/rho) on the rho >= 1 branch (bisection),
    mirroring rust klein::solve_rho."""
    rho_max = 1e9
    if k <= 1:
        return rho_max
    ln_k = np.log(float(k))

    def g(rho):
        return (2.0 * m / rho) * (1.0 + np.log(rho)) - ln_k

    if g(1.0) <= 0.0:
        return 1.0
    lo, hi = 1.0, 2.0
    while g(hi) > 0.0 and hi < rho_max:
        hi *= 2.0
    if hi >= rho_max:
        return rho_max
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if g(mid) > 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
