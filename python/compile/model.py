"""Layer-2 JAX graph: the per-tile OJBKQ solve that gets AOT-lowered.

Wraps the Layer-1 Pallas kernel (`kernels.babai_klein.ppi_decode`) with
the residual computation and Algorithm-4 argmin selection, producing the
winning codes for a column tile:

    q_all  = PPI-KBabai(R, S, QBAR, ALPHA, U, qmax)      # L1 kernel
    E      = S * (QBAR - q_all)                          # weight-space err
    RE     = R @ E  (batched over paths)                 # MXU
    resid  = sum(RE^2, rows)                             # (P, T)
    winner = argmin_p resid                              # JTA score argmin
    Q      = q_all[winner]                               # (M, T)

The Rust coordinator (rust/src/runtime) feeds padded tiles and reads Q
back; selection thus happens *inside* the artifact, keeping the request
path a single PJRT execute.
"""

import jax
import jax.numpy as jnp

from .kernels.babai_klein import ppi_decode


def layer_solve(r, s, qbar, alpha, uniforms, qmax, *, block=16, interpret=True):
    """Full tile solve: decode + residual + argmin selection.

    Returns a 1-tuple ``(q_best,)`` with q_best: (M, T) f32 codes —
    tuple-shaped because the AOT bridge lowers with return_tuple=True.
    """
    q_all = ppi_decode(r, s, qbar, alpha, uniforms, qmax, block=block, interpret=interpret)
    e = s[None, :, :] * (qbar[None, :, :] - q_all)  # (P, M, T)
    re = jax.lax.dot_general(
        r,
        e,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (M, P, T)
    resid = jnp.sum(re * re, axis=0)  # (P, T)
    winner = jnp.argmin(resid, axis=0)  # (T,)
    q_best = jnp.take_along_axis(q_all, winner[None, None, :], axis=0)[0]  # (M, T)
    return (q_best,)


def layer_solve_with_resid(r, s, qbar, alpha, uniforms, qmax, *, block=16, interpret=True):
    """Diagnostic variant also returning the winning residuals (T,)."""
    q_all = ppi_decode(r, s, qbar, alpha, uniforms, qmax, block=block, interpret=interpret)
    e = s[None, :, :] * (qbar[None, :, :] - q_all)
    re = jax.lax.dot_general(
        r, e, dimension_numbers=(((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    resid = jnp.sum(re * re, axis=0)
    winner = jnp.argmin(resid, axis=0)
    q_best = jnp.take_along_axis(q_all, winner[None, None, :], axis=0)[0]
    best = jnp.take_along_axis(resid, winner[None, :], axis=0)[0]
    return (q_best, best)
