"""Build-time trainer: trains the tiny-LM zoo standing in for the paper's
LLM families (DESIGN.md §2) and exports everything the Rust runtime needs.

Build-time Python only — never on the request path. Per model preset this
script writes to ``artifacts/``:

* ``model_{name}.bin``    — OJBW1 weights (rust/src/model/io.rs format)
* ``corpus_{name}.bin``   — OJBC1 token corpus the model was trained on
* ``fixture_{name}.bin``  — OJBF1 (tokens, logits) pair for the
  cross-implementation numerics test (rust/tests/model_parity.rs)

The architecture mirrors rust/src/model EXACTLY (see that module's doc):
token embedding + sinusoidal positions, N x [RMSNorm -> causal MHA ->
residual -> RMSNorm -> SwiGLU -> residual], final RMSNorm, tied head.

The corpus is the order-2 Markov + Zipf grammar of rust/src/data (own
numpy implementation; the canonical stream is THIS one — Rust loads it).

Usage: python -m compile.pretrain [--out DIR] [--models a,b] [--steps N]
"""

import argparse
import os
import sys
import zlib

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- presets

#: name -> (vocab, d_model, n_layers, n_heads, d_ff, max_seq, train_steps)
PRESETS = {
    "tiny-0.2M": (256, 96, 2, 4, 256, 128, 1000),
    "small-0.8M": (512, 128, 4, 4, 352, 128, 800),
    "base-2M": (512, 192, 6, 6, 512, 128, 500),
    "med-5M": (512, 256, 8, 8, 704, 128, 300),
}

# ------------------------------------------------------------------ corpus


def splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x, z ^ (z >> 31)


def gen_corpus(vocab, n, seed, noise=0.2, stream_seed=None):
    """Order-2 Markov + Zipf grammar (numpy twin of rust/src/data).

    ``seed`` fixes the *grammar* (successor tables); ``stream_seed`` (or
    ``seed`` when None) fixes the sampled stream — so a shifted-domain
    corpus can share the language while differing in style/noise.
    """
    rng = np.random.default_rng(seed if stream_seed is None else stream_seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    zipf = 1.0 / ranks**1.1
    zipf /= zipf.sum()
    # Precompute successor tables lazily via hashing.
    succ_cache = {}

    def successors(prev, cur):
        # Context = (cur, prev mod 8): dense enough to be learnable, rich
        # enough to need attention (twin of rust/src/data successors()).
        key = (prev & 7, cur)
        if key not in succ_cache:
            h = (seed * 0x9E3779B97F4A7C15 + (((prev & 7) << 32) | cur)) & 0xFFFFFFFFFFFFFFFF
            out = []
            for _ in range(4):
                h, v = splitmix64(h)
                out.append(v % vocab)
            succ_cache[key] = out
        return succ_cache[key]

    cum = [0.55, 0.80, 0.92, 1.0]
    toks = np.empty(n, dtype=np.uint16)
    prev = int(rng.choice(vocab, p=zipf))
    cur = int(rng.choice(vocab, p=zipf))
    toks[0] = prev
    if n > 1:
        toks[1] = cur
    for i in range(2, n):
        if rng.random() < noise:
            nxt = int(rng.choice(vocab, p=zipf))
        else:
            u = rng.random()
            succ = successors(prev, cur)
            nxt = succ[3]
            for j, c in enumerate(cum):
                if u < c:
                    nxt = succ[j]
                    break
        toks[i] = nxt
        prev, cur = cur, nxt
    return toks


def save_corpus(path, toks, vocab):
    eval_start = len(toks) * 9 // 10
    with open(path, "wb") as f:
        f.write(b"OJBC1\n")
        f.write(f"{vocab} {len(toks)} {eval_start}\n".encode())
        f.write(toks.astype("<u2").tobytes())


# ------------------------------------------------------------------- model


def init_params(key, vocab, d, n_layers, ff):
    ks = jax.random.split(key, 1 + 7 * n_layers)
    p = {"embedding": 0.02 * jax.random.normal(ks[0], (vocab, d), jnp.float32)}
    sd = 1.0 / np.sqrt(d)
    sf = 1.0 / np.sqrt(ff)
    for i in range(n_layers):
        k = ks[1 + 7 * i : 8 + 7 * i]
        p[f"b{i}.attn_norm"] = jnp.ones((d,), jnp.float32)
        p[f"b{i}.wq"] = sd * jax.random.normal(k[0], (d, d), jnp.float32)
        p[f"b{i}.wk"] = sd * jax.random.normal(k[1], (d, d), jnp.float32)
        p[f"b{i}.wv"] = sd * jax.random.normal(k[2], (d, d), jnp.float32)
        p[f"b{i}.wo"] = sd * jax.random.normal(k[3], (d, d), jnp.float32)
        p[f"b{i}.mlp_norm"] = jnp.ones((d,), jnp.float32)
        p[f"b{i}.wgate"] = sd * jax.random.normal(k[4], (d, ff), jnp.float32)
        p[f"b{i}.wup"] = sd * jax.random.normal(k[5], (d, ff), jnp.float32)
        p[f"b{i}.wdown"] = sf * jax.random.normal(k[6], (ff, d), jnp.float32)
    p["final_norm"] = jnp.ones((d,), jnp.float32)
    return p


def rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + 1e-5) * gain


def pos_encoding(seq, d):
    t = np.arange(seq)[:, None].astype(np.float64)
    i = np.arange(d // 2)[None, :].astype(np.float64)
    freq = np.exp(-(2.0 * i / d) * np.log(10_000.0))
    ang = t * freq
    pe = np.zeros((seq, d), dtype=np.float32)
    pe[:, 0::2] = np.sin(ang)
    pe[:, 1::2] = np.cos(ang)
    # Scaled to the token-embedding init std (0.02) so position does not
    # swamp token identity early in training (twin of rust model/mod.rs).
    return jnp.asarray(0.02 * pe)


def forward(p, tokens, n_layers, n_heads):
    """tokens: (B, S) int32 -> logits (B, S, V). Mirrors rust model/mod.rs."""
    emb = p["embedding"]
    b, s = tokens.shape
    d = emb.shape[1]
    x = emb[tokens] + pos_encoding(s, d)[None]
    hd = d // n_heads
    mask = jnp.tril(jnp.ones((s, s), bool))
    for i in range(n_layers):
        h = rmsnorm(x, p[f"b{i}.attn_norm"])
        q = h @ p[f"b{i}.wq"]
        k = h @ p[f"b{i}.wk"]
        v = h @ p[f"b{i}.wv"]
        qh = q.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        scores = qh @ kh.transpose(0, 1, 3, 2) / np.sqrt(hd)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1) @ vh  # (B, H, S, hd)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + attn @ p[f"b{i}.wo"]
        h2 = rmsnorm(x, p[f"b{i}.mlp_norm"])
        act = jax.nn.silu(h2 @ p[f"b{i}.wgate"]) * (h2 @ p[f"b{i}.wup"])
        x = x + act @ p[f"b{i}.wdown"]
    x = rmsnorm(x, p["final_norm"])
    return x @ emb.T


def loss_fn(p, tokens, n_layers, n_heads):
    logits = forward(p, tokens, n_layers, n_heads)
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)
    return nll.mean()


def adam_update(p, g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
    v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
    mh = jax.tree.map(lambda a: a / (1 - b1**step), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**step), v)
    p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + eps), p, mh, vh)
    return p, m, v


def train(name, out_dir, steps_override=None, seed=0xC0FFEE):
    vocab, d, n_layers, n_heads, ff, max_seq, steps = PRESETS[name]
    if steps_override:
        steps = steps_override
    print(f"[pretrain] {name}: vocab={vocab} d={d} L={n_layers} steps={steps}", file=sys.stderr)
    # Stable per-model grammar seed (NOT python hash(), which is salted
    # per process and would make corpora irreproducible).
    name_tag = zlib.crc32(name.encode()) & 0xFFFF
    grammar_seed = seed ^ name_tag
    corpus = gen_corpus(vocab, 300_000, seed=grammar_seed)
    save_corpus(os.path.join(out_dir, f"corpus_{name}.bin"), corpus, vocab)
    # Shifted-domain twin ("WikiText-2" role): same grammar, noisier
    # style, independent stream.
    shifted = gen_corpus(
        vocab, 60_000, seed=grammar_seed, noise=0.35, stream_seed=grammar_seed ^ 0x51F7ED
    )
    save_corpus(os.path.join(out_dir, f"corpus_shifted_{name}.bin"), shifted, vocab)
    train_split = corpus[: len(corpus) * 9 // 10].astype(np.int32)

    key = jax.random.PRNGKey(seed & 0xFFFFFFFF)
    params = init_params(key, vocab, d, n_layers, ff)
    grad_fn = jax.jit(
        jax.value_and_grad(lambda p, t: loss_fn(p, t, n_layers, n_heads))
    )
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    batch, seq = 8, max_seq
    lr = 3e-3
    update = jax.jit(lambda p, g, m, v, s: adam_update(p, g, m, v, s, lr))
    first = last = None
    for step in range(1, steps + 1):
        starts = rng.integers(0, len(train_split) - seq - 1, size=batch)
        toks = np.stack([train_split[st : st + seq] for st in starts])
        loss, grads = grad_fn(params, jnp.asarray(toks))
        params, m, v = update(params, grads, m, v, step)
        if first is None:
            first = float(loss)
        last = float(loss)
        if step % 50 == 0 or step == 1:
            print(f"[pretrain] {name} step {step}/{steps} loss={float(loss):.4f}", file=sys.stderr)
    print(f"[pretrain] {name} done: loss {first:.3f} -> {last:.3f}", file=sys.stderr)

    save_weights(params, os.path.join(out_dir, f"model_{name}.bin"), vocab, d, n_layers, n_heads, ff, max_seq)
    save_fixture(params, os.path.join(out_dir, f"fixture_{name}.bin"), corpus, n_layers, n_heads, vocab)
    return first, last


def save_weights(p, path, vocab, d, n_layers, n_heads, ff, max_seq):
    """OJBW1 writer (twin of rust/src/model/io.rs save_model)."""
    def tensor_bytes(name, rows, cols, arr):
        data = np.asarray(arr, dtype="<f4").reshape(rows * cols)
        return f"{name}\n{rows} {cols}\n".encode() + data.tobytes()

    with open(path, "wb") as f:
        f.write(b"OJBW1\n")
        f.write(f"{vocab} {d} {n_layers} {n_heads} {ff} {max_seq}\n".encode())
        f.write(tensor_bytes("embedding", vocab, d, p["embedding"]))
        for i in range(n_layers):
            f.write(tensor_bytes(f"b{i}.attn_norm", 1, d, p[f"b{i}.attn_norm"]))
            f.write(tensor_bytes(f"b{i}.wq", d, d, p[f"b{i}.wq"]))
            f.write(tensor_bytes(f"b{i}.wk", d, d, p[f"b{i}.wk"]))
            f.write(tensor_bytes(f"b{i}.wv", d, d, p[f"b{i}.wv"]))
            f.write(tensor_bytes(f"b{i}.wo", d, d, p[f"b{i}.wo"]))
            f.write(tensor_bytes(f"b{i}.mlp_norm", 1, d, p[f"b{i}.mlp_norm"]))
            f.write(tensor_bytes(f"b{i}.wgate", d, ff, p[f"b{i}.wgate"]))
            f.write(tensor_bytes(f"b{i}.wup", d, ff, p[f"b{i}.wup"]))
            f.write(tensor_bytes(f"b{i}.wdown", ff, d, p[f"b{i}.wdown"]))
        f.write(tensor_bytes("final_norm", 1, d, p["final_norm"]))


def save_fixture(p, path, corpus, n_layers, n_heads, vocab):
    """OJBF1: a (tokens, logits) pair for Rust/JAX forward parity tests."""
    seq = 24
    toks = corpus[1_000 : 1_000 + seq].astype(np.int32)[None]
    logits = np.asarray(forward(p, jnp.asarray(toks), n_layers, n_heads))[0]
    with open(path, "wb") as f:
        f.write(b"OJBF1\n")
        f.write(f"{seq} {vocab}\n".encode())
        f.write(toks[0].astype("<u2").tobytes())
        f.write(logits.astype("<f4").tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--models", default=",".join(PRESETS))
    ap.add_argument("--steps", type=int, default=None, help="override step count")
    args = ap.parse_args()
    out_dir = args.out or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in PRESETS:
            print(f"unknown preset {name!r}; have {list(PRESETS)}", file=sys.stderr)
            sys.exit(2)
        first, last = train(name, out_dir, steps_override=args.steps)
        if not last < first:
            print(f"WARNING: {name} loss did not improve ({first} -> {last})", file=sys.stderr)


if __name__ == "__main__":
    main()
