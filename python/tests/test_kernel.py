"""Layer-1 correctness: the Pallas PPI-KBabai kernel against the pure
numpy oracle — the CORE cross-layer correctness signal (the same oracle
contract is enforced against the Rust native decoder in
rust/src/quant/ppi.rs and against the AOT artifact in
rust/tests/pjrt_roundtrip.rs).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.babai_klein import ppi_decode, sample_codes, vmem_bytes
from compile.model import layer_solve, layer_solve_with_resid


def assert_tile_matches(m, t, k, seed, qmax=15.0, block=16):
    r, s, qbar, alpha, u = ref.make_case(m, t, k, seed, qmax=qmax)
    q_ref, resid_ref = ref.decode_tile_ref(r, s, qbar, alpha, u, qmax)
    q_ker = np.asarray(ppi_decode(r, s, qbar, alpha, u, qmax, block=block))
    mismatch = (q_ker != q_ref).mean()
    assert mismatch < 5e-3, f"mismatch fraction {mismatch} (m={m} t={t} k={k})"
    return q_ref, resid_ref, q_ker


class TestKernelVsOracle:
    def test_small_greedy(self):
        assert_tile_matches(16, 4, 0, seed=1)

    def test_small_sampled(self):
        assert_tile_matches(16, 4, 3, seed=2)

    def test_medium(self):
        assert_tile_matches(64, 8, 5, seed=3)

    def test_3bit_box(self):
        q_ref, _, q_ker = assert_tile_matches(32, 4, 2, seed=4, qmax=7.0)
        assert q_ker.max() <= 7.0 and q_ker.min() >= 0.0

    def test_block_sizes_equivalent(self):
        r, s, qbar, alpha, u = ref.make_case(32, 4, 2, seed=5)
        outs = [
            np.asarray(ppi_decode(r, s, qbar, alpha, u, 15.0, block=b))
            for b in (1, 4, 8, 16, 32)
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)

    def test_selection_matches_oracle(self):
        r, s, qbar, alpha, u = ref.make_case(48, 6, 4, seed=6)
        q_ref, resid_ref = ref.decode_tile_ref(r, s, qbar, alpha, u, 15.0)
        best_ref, _ = ref.select_best(q_ref, resid_ref)
        (best,) = layer_solve(r, s, qbar, alpha, u, 15.0)
        mismatch = (np.asarray(best) != best_ref).mean()
        assert mismatch < 5e-3, f"selection mismatch {mismatch}"

    def test_resid_variant_consistent(self):
        r, s, qbar, alpha, u = ref.make_case(32, 4, 3, seed=7)
        (q1,) = layer_solve(r, s, qbar, alpha, u, 15.0)
        q2, resid = layer_solve_with_resid(r, s, qbar, alpha, u, 15.0)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        assert np.all(np.asarray(resid) >= 0)


class TestKernelProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([16, 32, 48, 64]),
        t=st.integers(1, 8),
        k=st.integers(0, 4),
        qmax=st.sampled_from([3.0, 7.0, 15.0]),
        seed=st.integers(0, 10_000),
    )
    def test_matches_oracle_across_shapes(self, m, t, k, qmax, seed):
        assert_tile_matches(m, t, k, seed=seed, qmax=qmax)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([16, 32]),
        t=st.integers(1, 6),
        k=st.integers(1, 4),
        seed=st.integers(0, 10_000),
    )
    def test_codes_integral_and_boxed(self, m, t, k, seed):
        r, s, qbar, alpha, u = ref.make_case(m, t, k, seed)
        q = np.asarray(ppi_decode(r, s, qbar, alpha, u, 15.0))
        assert np.all(q == np.round(q))
        assert q.min() >= 0 and q.max() <= 15

    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([16, 32]), t=st.integers(1, 4), seed=st.integers(0, 10_000))
    def test_greedy_path_never_loses_selection(self, m, t, seed):
        """The winner's residual is <= the greedy path's (Algorithm 4)."""
        k = 4
        r, s, qbar, alpha, u = ref.make_case(m, t, k, seed)
        q_all, resid = ref.decode_tile_ref(r, s, qbar, alpha, u, 15.0)
        _, winner = ref.select_best(q_all, resid)
        for j in range(t):
            assert resid[winner[j], j] <= resid[0, j] + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_exact_center_zero_residual(self, seed):
        """Integer centers decode to themselves with zero residual."""
        m, t = 24, 3
        rng = np.random.default_rng(seed)
        r, s, _, alpha, u = ref.make_case(m, t, 2, seed)
        qbar = rng.integers(0, 16, size=(m, t)).astype(np.float32)
        q = np.asarray(ppi_decode(r, s, qbar, alpha, u, 15.0))
        np.testing.assert_array_equal(q[0], qbar)  # greedy path exact


class TestSampling:
    def test_greedy_limit(self):
        """alpha -> inf reduces sampling to rounding (paper §3.4)."""
        rng = np.random.default_rng(0)
        c = (15 * rng.random((5, 7))).astype(np.float32)
        u = rng.random((5, 7)).astype(np.float32)
        alpha = np.full((7,), 1e9, dtype=np.float32)
        out = np.asarray(sample_codes(c, np.float32(1.0), alpha, 15.0, u))
        expected = np.clip(np.floor(c + 0.5), 0, 15)
        np.testing.assert_array_equal(out, expected)

    def test_distribution_matches_eq13(self):
        """Empirical sampling frequencies track the analytic Eq. 13."""
        c_val, alpha_val, qmax = 6.3, 0.8, 15.0
        n = int(qmax) + 1
        w = np.exp(-alpha_val * (c_val - np.arange(n)) ** 2)
        probs = w / w.sum()
        rng = np.random.default_rng(1)
        trials = 40_000
        c = np.full((trials, 1), c_val, dtype=np.float32)
        u = rng.random((trials, 1)).astype(np.float32)
        alpha = np.array([alpha_val], dtype=np.float32)
        out = np.asarray(sample_codes(c, np.float32(1.0), alpha, qmax, u)).ravel()
        for v in range(n):
            emp = (out == v).mean()
            assert abs(emp - probs[v]) < 0.01, f"v={v} emp={emp} analytic={probs[v]}"

    def test_mask_respects_qmax(self):
        """Values above qmax must have zero probability (3-bit mask)."""
        rng = np.random.default_rng(2)
        c = np.full((2_000, 1), 6.9, dtype=np.float32)  # near the 3-bit edge
        u = rng.random((2_000, 1)).astype(np.float32)
        alpha = np.array([0.2], dtype=np.float32)  # hot: wide distribution
        out = np.asarray(sample_codes(c, np.float32(1.0), alpha, 7.0, u))
        assert out.max() <= 7.0

    @settings(max_examples=30, deadline=None)
    @given(
        c=st.floats(-2.0, 17.0),
        alpha=st.floats(0.01, 100.0),
        # u bounded away from the measure-zero 0/1 boundaries where the
        # shared e^-30 significance cutoff intentionally drops tail mass.
        u=st.floats(1e-6, 0.999),
    )
    def test_scalar_contract_matches_ref(self, c, alpha, u):
        """Vectorized sampler == scalar oracle sampler on random scalars."""
        got = float(
            np.asarray(
                sample_codes(
                    np.array([[c]], dtype=np.float32),
                    np.float32(1.0),
                    np.array([alpha], dtype=np.float32),
                    15.0,
                    np.array([[u]], dtype=np.float32),
                )
            )[0, 0]
        )
        want = ref.sample_code(c, 1.0, alpha, 15.0, u)
        assert got == want, f"c={c} alpha={alpha} u={u}: {got} vs {want}"


class TestVmemBudget:
    def test_all_variants_fit_tpu_vmem(self):
        """DESIGN.md §7: every emitted variant must fit a 16 MiB VMEM."""
        from compile.aot import FULL_VARIANTS

        for m, t, k in FULL_VARIANTS:
            b = vmem_bytes(m, t, k + 1)
            assert b < 16 * 2**20, f"variant ({m},{t},{k}) needs {b / 2**20:.1f} MiB"
