"""Layer-2 build-path tests: corpus generator statistics, model forward
shapes, training-step sanity, and serialization format invariants."""

import io
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import pretrain


class TestCorpus:
    def test_tokens_in_vocab_and_deterministic(self):
        a = pretrain.gen_corpus(64, 5_000, seed=1)
        b = pretrain.gen_corpus(64, 5_000, seed=1)
        np.testing.assert_array_equal(a, b)
        assert a.max() < 64
        c = pretrain.gen_corpus(64, 5_000, seed=2)
        assert not np.array_equal(a, c)

    def test_learnable_structure(self):
        """Conditional (order-2) entropy well below unigram entropy."""
        toks = pretrain.gen_corpus(64, 120_000, seed=3)
        uni = np.bincount(toks, minlength=64).astype(np.float64)
        p = uni / uni.sum()
        h_uni = -(p[p > 0] * np.log(p[p > 0])).sum()
        from collections import defaultdict

        ctx = defaultdict(lambda: np.zeros(64))
        for i in range(2, len(toks)):
            ctx[(toks[i - 2], toks[i - 1])][toks[i]] += 1
        h_cond, mass = 0.0, 0.0
        for counts in ctx.values():
            t = counts.sum()
            q = counts[counts > 0] / t
            h_cond += t * -(q * np.log(q)).sum()
            mass += t
        h_cond /= mass
        assert h_cond < 0.7 * h_uni, (h_cond, h_uni)

    def test_corpus_file_format(self):
        toks = pretrain.gen_corpus(32, 1_000, seed=4)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "c.bin")
            pretrain.save_corpus(path, toks, 32)
            with open(path, "rb") as f:
                assert f.readline() == b"OJBC1\n"
                vocab, n, eval_start = map(int, f.readline().split())
                assert (vocab, n, eval_start) == (32, 1_000, 900)
                data = np.frombuffer(f.read(), dtype="<u2")
            np.testing.assert_array_equal(data, toks)


class TestModelForward:
    def _params(self, vocab=32, d=16, layers=2, ff=24, seed=0):
        return pretrain.init_params(jax.random.PRNGKey(seed), vocab, d, layers, ff)

    def test_shapes_and_finite(self):
        p = self._params()
        toks = jnp.arange(10, dtype=jnp.int32).reshape(1, 10) % 32
        logits = pretrain.forward(p, toks, 2, 2)
        assert logits.shape == (1, 10, 32)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self):
        p = self._params()
        a = jnp.array([[1, 2, 3, 4, 5, 6]], dtype=jnp.int32)
        b = a.at[0, 5].set(31)
        la = pretrain.forward(p, a, 2, 2)
        lb = pretrain.forward(p, b, 2, 2)
        np.testing.assert_allclose(la[0, :5], lb[0, :5], rtol=1e-5, atol=1e-5)

    def test_loss_decreases_over_steps(self):
        vocab, d, layers, ff = 64, 32, 1, 48
        p = self._params(vocab, d, layers, ff)
        corpus = pretrain.gen_corpus(vocab, 30_000, seed=5).astype(np.int32)
        grad_fn = jax.jit(
            jax.value_and_grad(lambda pp, t: pretrain.loss_fn(pp, t, layers, 2))
        )
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        rng = np.random.default_rng(0)
        losses = []
        for step in range(1, 81):
            starts = rng.integers(0, len(corpus) - 33, size=8)
            toks = np.stack([corpus[s : s + 32] for s in starts])
            loss, g = grad_fn(p, jnp.asarray(toks))
            p, m, v = pretrain.adam_update(p, g, m, v, step, 5e-3)
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[::16]


class TestSerialization:
    def test_weight_file_layout(self):
        vocab, d, layers, heads, ff, seq = 16, 8, 1, 2, 12, 16
        p = pretrain.init_params(jax.random.PRNGKey(1), vocab, d, layers, ff)
        with tempfile.TemporaryDirectory() as dd:
            path = os.path.join(dd, "m.bin")
            pretrain.save_weights(p, path, vocab, d, layers, heads, ff, seq)
            with open(path, "rb") as f:
                assert f.readline() == b"OJBW1\n"
                dims = list(map(int, f.readline().split()))
                assert dims == [vocab, d, layers, heads, ff, seq]
                # First tensor header.
                assert f.readline().strip() == b"embedding"
                rows, cols = map(int, f.readline().split())
                assert (rows, cols) == (vocab, d)
                emb = np.frombuffer(f.read(rows * cols * 4), dtype="<f4")
                np.testing.assert_allclose(
                    emb.reshape(vocab, d), np.asarray(p["embedding"]), rtol=1e-6
                )

    def test_fixture_roundtrip(self):
        vocab, d, layers, heads, ff = 16, 8, 1, 2, 12
        p = pretrain.init_params(jax.random.PRNGKey(2), vocab, d, layers, ff)
        corpus = pretrain.gen_corpus(vocab, 3_000, seed=6)
        with tempfile.TemporaryDirectory() as dd:
            path = os.path.join(dd, "f.bin")
            pretrain.save_fixture(p, path, corpus, layers, heads, vocab)
            with open(path, "rb") as f:
                assert f.readline() == b"OJBF1\n"
                seq, v = map(int, f.readline().split())
                toks = np.frombuffer(f.read(seq * 2), dtype="<u2")
                logits = np.frombuffer(f.read(seq * v * 4), dtype="<f4").reshape(seq, v)
            recomputed = np.asarray(
                pretrain.forward(p, jnp.asarray(toks.astype(np.int32))[None], layers, heads)
            )[0]
            np.testing.assert_allclose(logits, recomputed, rtol=1e-5, atol=1e-5)


class TestRho:
    """The alpha schedule helper shared with the Rust solver."""

    def test_rho_monotone_in_k(self):
        from compile.kernels.ref import solve_rho

        m = 128
        assert solve_rho(5, m) > solve_rho(10, m) > solve_rho(50, m) >= 1.0

    def test_rho_satisfies_equation(self):
        from compile.kernels.ref import solve_rho

        k, m = 8, 64
        rho = solve_rho(k, m)
        assert abs((2 * m / rho) * (1 + np.log(rho)) - np.log(k)) < 1e-6
