"""Generate the committed OJBQ1 golden fixture + logits snapshot.

Writes ``rust/tests/fixtures/golden_tiny.ojbq1`` — a tiny hand-specified
packed checkpoint exercising every record form (dense embedding/norms, a
dense-fallback linear, packed linears at wbit 2/3/4, ragged scale groups,
and a decode-order perm) — plus ``golden_tiny_logits.bin``, the f32
logits of the pinned token sequence computed by this *independent*
float64 reimplementation of the forward pass.

``rust/tests/packed_checkpoint.rs::golden_fixture_pins_byte_layout_and_decode``
loads the fixture, re-saves it (must be byte-identical: pins field order,
framing, endianness), and compares forward logits against the snapshot
(pins the decode path). Regenerate only on a deliberate format bump:

    python3 python/tools/make_golden_ojbq1.py

The byte layout mirrors rust/src/infer/io.rs; every numeric value is an
exact binary fraction so the f32 file content is bit-deterministic.
"""

from __future__ import annotations

import os
import struct

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "..", "rust", "tests", "fixtures")

# Config: vocab=8 d_model=4 n_layers=1 n_heads=2 d_ff=6 max_seq=8.
VOCAB, D, LAYERS, HEADS, FF, MAX_SEQ = 8, 4, 1, 2, 6, 8
TOKENS = [1, 3, 0, 2, 5, 4]  # pinned snapshot sequence


def f32s(a) -> bytes:
    return np.asarray(a, dtype="<f4").tobytes()


def pack_bits(codes, wbit: int) -> bytes:
    """Little-endian bitstream, mirroring quant::qtensor::pack_bits."""
    out = bytearray((len(codes) * wbit + 7) // 8)
    bit = 0
    for c in codes:
        assert 0 <= c < (1 << wbit)
        byte, off = bit // 8, bit % 8
        out[byte] |= (c << off) & 0xFF
        if off + wbit > 8:
            out[byte + 1] |= c >> (8 - off)
        bit += wbit
    return bytes(out)


class PackedLayer:
    """One packed linear: codes in decode order + tables + optional perm."""

    def __init__(self, m, n, wbit, gs, codes, scales, zeros, perm=None):
        self.m, self.n, self.wbit, self.gs = m, n, wbit, gs
        self.n_groups = -(-m // gs)
        self.codes = np.asarray(codes, dtype=np.int64).reshape(m, n)
        self.scales = np.asarray(scales, dtype=np.float64).reshape(self.n_groups, n)
        zeros = np.asarray(zeros, dtype=np.float64).reshape(self.n_groups, n)
        self.corr = self.scales * zeros  # s·z — exact for our binary fractions
        self.perm = None if perm is None else list(perm)
        assert self.codes.max() < (1 << wbit)

    def dense(self) -> np.ndarray:
        """Runtime weight in original feature order (PackedTiles::to_dense)."""
        w = np.zeros((self.m, self.n))
        for i in range(self.m):
            g = i // self.gs
            row = self.scales[g] * self.codes[i] - self.corr[g]
            w[self.perm[i] if self.perm else i] = row
        return w

    def record(self, name: str) -> bytes:
        head = f"{name}\npacked\n{self.m} {self.n} {self.wbit} {self.gs} "
        head += f"{self.n_groups} {1 if self.perm else 0}\n"
        out = head.encode()
        out += f32s(self.scales) + f32s(self.corr)
        if self.perm:
            out += b"".join(struct.pack("<I", p) for p in self.perm)
        # Single column tile (n < COL_TILE=32): row-major m×n codes.
        out += pack_bits(list(self.codes.reshape(-1)), self.wbit)
        return out


def dense_record(name: str, rows: int, cols: int, data) -> bytes:
    return f"{name}\ndense\n{rows} {cols}\n".encode() + f32s(data)


# ----- the golden model (every value an exact binary fraction) ---------

EMB = np.array([[((t * 4 + j) % 7 - 3) * 0.125 for j in range(D)] for t in range(VOCAB)])
ATTN_NORM = np.array([1.0, 0.875, 1.125, 1.0])
MLP_NORM = np.array([0.75, 1.0, 1.25, 1.0])
FINAL_NORM = np.array([1.0, 1.0, 0.875, 1.125])
WO = np.array([[((i * 3 + j * 5) % 9 - 4) * 0.0625 for j in range(D)] for i in range(D)])


def qkv(c: int) -> PackedLayer:
    codes = [[(i * 5 + j * 3 + c) % 8 for j in range(D)] for i in range(D)]
    scales = [[0.0625 * (1 + (g + j + c) % 3) for j in range(D)] for g in range(2)]
    zeros = [[(g * 2 + j + c) % 8 for j in range(D)] for g in range(2)]
    perm = [2, 0, 3, 1] if c == 0 else None  # decode order on wq only
    return PackedLayer(D, D, 3, 3, codes, scales, zeros, perm)


def gate_up(c: int) -> PackedLayer:
    codes = [[(i + j * 2 + c) % 4 for j in range(FF)] for i in range(D)]
    scales = [[0.125 * (1 + (g + j + c) % 2) for j in range(FF)] for g in range(2)]
    zeros = [[(g + j + c) % 4 for j in range(FF)] for g in range(2)]
    return PackedLayer(D, FF, 2, 2, codes, scales, zeros)


def down() -> PackedLayer:
    codes = [[(i * 7 + j * 5) % 16 for j in range(D)] for i in range(FF)]
    scales = [[0.03125 * (1 + (g + j) % 4) for j in range(D)] for g in range(2)]
    zeros = [[(g * 3 + j) % 16 for j in range(D)] for g in range(2)]
    return PackedLayer(FF, D, 4, 4, codes, scales, zeros)  # ragged: 4+2 rows


WQ, WK, WV = qkv(0), qkv(1), qkv(2)
WGATE, WUP = gate_up(0), gate_up(1)
WDOWN = down()


# ----- float64 forward (mirrors rust/src/model + infer) ----------------

def rmsnorm(x, gain):
    ms = np.mean(x * x, axis=1, keepdims=True)
    return x / np.sqrt(ms + 1e-5) * gain


def log_softmax(v):
    m = np.max(v)
    return v - m - np.log(np.sum(np.exp(v - m)))


def silu(v):
    return v / (1.0 + np.exp(-v))


def embed(tokens):
    x = EMB[np.array(tokens)].copy()
    for t in range(len(tokens)):
        for i in range(D // 2):
            freq = np.exp(-(2.0 * i / D) * np.log(10_000.0))
            angle = t * freq
            x[t, 2 * i] += 0.02 * np.sin(angle)
            x[t, 2 * i + 1] += 0.02 * np.cos(angle)
    return x


def attention(q, k, v):
    seq, hd = q.shape[0], D // HEADS
    out = np.zeros((seq, D))
    scale = 1.0 / np.sqrt(hd)
    for h in range(HEADS):
        c0 = h * hd
        for t in range(seq):
            scores = np.array(
                [np.dot(q[t, c0 : c0 + hd], k[u, c0 : c0 + hd]) * scale for u in range(t + 1)]
            )
            w = np.exp(log_softmax(scores))
            out[t, c0 : c0 + hd] = w @ v[: t + 1, c0 : c0 + hd]
    return out


def forward(tokens):
    x = embed(tokens)
    h = rmsnorm(x, ATTN_NORM)
    ctx = attention(h @ WQ.dense(), h @ WK.dense(), h @ WV.dense())
    x_mid = x + ctx @ WO
    h2 = rmsnorm(x_mid, MLP_NORM)
    act = silu(h2 @ WGATE.dense()) * (h2 @ WUP.dense())
    x = x_mid + act @ WDOWN.dense()
    return rmsnorm(x, FINAL_NORM) @ EMB.T


# ----- emit ------------------------------------------------------------

def checkpoint_bytes() -> bytes:
    out = b"OJBQ1\n1\n"
    out += f"{VOCAB} {D} {LAYERS} {HEADS} {FF} {MAX_SEQ}\n".encode()
    out += dense_record("embedding", VOCAB, D, EMB)
    out += dense_record("b0.attn_norm", 1, D, ATTN_NORM)
    out += dense_record("b0.mlp_norm", 1, D, MLP_NORM)
    out += WQ.record("b0.wq") + WK.record("b0.wk") + WV.record("b0.wv")
    out += dense_record("b0.wo", D, D, WO)
    out += WGATE.record("b0.wgate") + WUP.record("b0.wup")
    out += WDOWN.record("b0.wdown")
    out += dense_record("final_norm", 1, D, FINAL_NORM)
    out += b"end\n"
    return out


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    ckpt = checkpoint_bytes()
    with open(os.path.join(OUT_DIR, "golden_tiny.ojbq1"), "wb") as f:
        f.write(ckpt)
    logits = forward(TOKENS)
    with open(os.path.join(OUT_DIR, "golden_tiny_logits.bin"), "wb") as f:
        f.write(f32s(logits))
    print(f"golden_tiny.ojbq1: {len(ckpt)} bytes; logits {logits.shape}")
    print(f"logit range [{logits.min():.4f}, {logits.max():.4f}]")


if __name__ == "__main__":
    main()
