//! **Design ablations** (DESIGN.md §5 extensions) — the choices the paper
//! motivates but does not table:
//!
//! 1. Babai → Random-K → *optimal* (sphere decoder) residual gap: how
//!    much of the optimality gap K candidates recover (§3.4's rationale).
//! 2. Decode ordering (act-order) on/off for the OJBKQ family (our
//!    documented deviation, DESIGN.md §6b).
//! 3. μ scheduling: fixed paper defaults vs depth-linear (the paper's
//!    future-work adaptive strategy).
//! 4. QEP corner vs full JTA.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::perplexity_pair;
use ojbkq::quant::sphere::decode_optimal;
use ojbkq::quant::{klein, Method, MuSchedule, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use ojbkq::testutil::gen_solver_case;

fn main() {
    // --- 1. Optimality-gap study on random BILS instances.
    let mut t_gap = Table::new(
        "Ablation — residual vs optimal (mean over 20 instances)",
        &["m", "Babai /opt", "K=5 /opt", "K=25 /opt", "sphere nodes"],
    );
    let mut rng = Rng::new(0xAB1);
    for &m in &[8usize, 12, 16] {
        let (mut b_tot, mut k5_tot, mut k25_tot, mut opt_tot, mut nodes) =
            (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0u64);
        for i in 0..20 {
            let case = gen_solver_case(&mut rng, m, 4);
            let opt = decode_optimal(&case.r, &case.s, &case.qbar, case.qmax, 2_000_000);
            let greedy =
                ojbkq::quant::babai::decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
            let gres =
                ojbkq::quant::babai::residual_sq(&case.r, &case.s, &case.qbar, &greedy);
            let mut r5 = Rng::new(100 + i);
            let (_, k5) = klein::decode_kbest(&case.r, &case.s, &case.qbar, case.qmax, 5, &mut r5);
            let mut r25 = Rng::new(100 + i);
            let (_, k25) =
                klein::decode_kbest(&case.r, &case.s, &case.qbar, case.qmax, 25, &mut r25);
            b_tot += gres;
            k5_tot += k5;
            k25_tot += k25;
            opt_tot += opt.resid;
            nodes += opt.nodes;
        }
        t_gap.push_row(&[
            m.to_string(),
            format!("{:.3}", b_tot / opt_tot.max(1e-12)),
            format!("{:.3}", k5_tot / opt_tot.max(1e-12)),
            format!("{:.3}", k25_tot / opt_tot.max(1e-12)),
            format!("{}", nodes / 20),
        ]);
        eprintln!("[ablation] m={m} gap study done");
    }
    t_gap.emit(Some(&exp::results_dir()), "ablation_optimality_gap");

    // --- 2–4. Pipeline-level ablations on the last bench model.
    let mc = &exp::bench_models()[exp::bench_models().len() - 1];
    let wb = exp::load_workbench(mc);
    let (n_calib, seq) = exp::calib_size();
    let ppl_tokens = exp::ppl_tokens();
    let base3 = QuantConfig::paper_defaults(3, 128);
    let runs: Vec<(&str, Method, QuantConfig)> = vec![
        ("Ours (paper μλ)", Method::Ojbkq, base3.clone()),
        (
            "Ours, no act-order",
            Method::Ojbkq,
            QuantConfig { act_order: false, ..base3.clone() },
        ),
        (
            "Ours, μ depth-linear 0→1",
            Method::Ojbkq,
            QuantConfig {
                mu_schedule: MuSchedule::DepthLinear { start: 0.0, end: 1.0 },
                ..base3.clone()
            },
        ),
        // In-pipeline, the QEP corner reuses runtime taps as the
        // reference and skips the FP tap cache (half the capture cost —
        // see `quant::skip_fp_reference`), so this row also measures that
        // substitution.
        ("QEP corner (μ=0,λ=0)", Method::Qep, base3.clone()),
        ("Ours(R) (μ=1,λ=0)", Method::KleinRandomK, base3.clone()),
        // Iterative solver families on the same shared-factor engine
        // (DESIGN.md §Solver families): how far post-decode refinement
        // moves perplexity relative to the one-shot lattice decode.
        ("QuantEase (CD refine)", Method::QuantEase, base3.clone()),
        ("ADMM-Q", Method::AdmmQ, base3.clone()),
    ];
    let mut t_pipe = Table::new(
        &format!("Ablation — pipeline variants on {} (3-bit g128)", mc.name),
        &["variant", "ppl in-domain", "ppl shifted"],
    );
    for (label, method, cfg) in runs {
        match quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, None) {
            Ok((qm, _)) => {
                let (pin, psh) =
                    perplexity_pair(&qm, &wb.corpus, &wb.shifted, mc.max_seq, ppl_tokens);
                t_pipe.push_row(&[label.to_string(), format!("{pin:.3}"), format!("{psh:.3}")]);
                eprintln!("[ablation] {label}: {pin:.3}/{psh:.3}");
            }
            Err(e) => {
                eprintln!("[ablation] {label} failed: {e}");
                t_pipe.push_row(&[label.to_string(), "err".into(), "err".into()]);
            }
        }
    }
    t_pipe.emit(Some(&exp::results_dir()), "ablation_pipeline_variants");
}
