//! **Figure 1** — layer-wise comparison of original output norms and JTA
//! reconstruction errors across early/mid/late blocks, for all linear
//! modules, under varying K. Shape target (DESIGN.md E4): reconstruction
//! error ≪ output norm everywhere, decreasing with K.

use ojbkq::bench::exp;
use ojbkq::coordinator::Pipeline;
use ojbkq::model::LinearId;
use ojbkq::quant::{LayerStats, Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;

fn main() {
    let mc = &exp::bench_models()[exp::bench_models().len() - 1];
    let wb = exp::load_workbench(mc);
    let (n_calib, seq) = exp::calib_size();
    let ks: Vec<usize> = if exp::quick() { vec![1, 5] } else { vec![1, 5, 15] };

    // One pipeline run per K, streaming per-layer stats.
    let mut records: Vec<(usize, Vec<(LinearId, LayerStats)>)> = Vec::new();
    for &k in &ks {
        let cfg = QuantConfig {
            k,
            ..QuantConfig::paper_defaults(4, 128)
        };
        let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
        let calib = wb.corpus.calibration(n_calib, seq.min(mc.max_seq), &mut rng);
        let mut layer_log: Vec<(LinearId, LayerStats)> = Vec::new();
        {
            let mut p = Pipeline::new(&wb.model, calib, Method::Ojbkq, cfg, None);
            p.on_layer = Some(Box::new(|id, stats| layer_log.push((id, stats.clone()))));
            let _ = p.run().expect("pipeline");
        }
        eprintln!("[fig1] K={k} pipeline done ({} layers)", layer_log.len());
        records.push((k, layer_log));
    }

    // Report blocks {first, middle, last} like the paper's layers 1/15/30.
    let n_blocks = wb.model.blocks.len();
    let picks = [0usize, n_blocks / 2, n_blocks - 1];
    let mut headers: Vec<String> = vec!["module".into(), "||XW||_F".into()];
    for &k in &ks {
        headers.push(format!("JTA err (K={k})"));
    }
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    for &blk in &picks {
        let mut table = Table::new(
            &format!("Figure 1 — {} block {blk} output norm vs JTA error", mc.name),
            &href,
        );
        let base = &records[0].1;
        for (idx, (id, stats)) in base.iter().enumerate() {
            if id.block != blk {
                continue;
            }
            let mut row: Vec<String> =
                vec![id.to_string(), format!("{:.3}", stats.out_norm)];
            for (_, log) in &records {
                row.push(format!("{:.3}", log[idx].1.jta_err));
            }
            table.push_row(&row);
        }
        table.emit(Some(&exp::results_dir()), &format!("fig1_block{blk}"));
    }

    // Shape check: total JTA error should not increase with K.
    let totals: Vec<f64> = records
        .iter()
        .map(|(_, log)| log.iter().map(|(_, s)| s.jta_err).sum())
        .collect();
    eprintln!("[fig1] total JTA error by K: {totals:?}");
}
