//! **Figure 2** — ablation on the candidate size K: perplexity on both
//! corpora vs K ∈ {1, 5, 10, 25, 50} at 4-bit g128. Shape target
//! (DESIGN.md E5): significant drop from K=1 to K=5, then diminishing
//! returns — the basis for the paper's K=5 default.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::perplexity_pair;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::Table;

fn main() {
    let mc = &exp::bench_models()[exp::bench_models().len() - 1];
    let wb = exp::load_workbench(mc);
    let (n_calib, seq) = exp::calib_size();
    let ppl_tokens = exp::ppl_tokens();
    let ks: Vec<usize> = if exp::quick() { vec![1, 5, 10] } else { vec![1, 5, 10, 25, 50] };

    let mut table = Table::new(
        &format!("Figure 2 — K ablation on {} (4-bit g128)", mc.name),
        &["K", "ppl in-domain", "ppl shifted", "quant secs", "klein samples", "impr %"],
    );
    let mut series = Vec::new();
    for &k in &ks {
        // K=1 means one sampled path; the greedy path is always reserved,
        // matching Algorithm 4 (K candidates + Babai point).
        let cfg = QuantConfig { k, ..QuantConfig::paper_defaults(4, 128) };
        let t0 = std::time::Instant::now();
        let (qm, report) =
            quantize_model(&wb.model, &wb.corpus, Method::KleinRandomK, &cfg, n_calib, seq, None)
                .expect("quantize");
        let secs = t0.elapsed().as_secs_f64();
        // Solver decode stats aggregated over every quantized linear:
        // how many Klein paths were sampled, and on what fraction of
        // columns a sampled path beat the greedy Babai point — the
        // mechanism behind the ppl-vs-K curve this figure plots.
        let samples: u64 = report.layers.iter().map(|l| l.klein_samples).sum();
        let improved: u64 = report.layers.iter().map(|l| l.klein_improved).sum();
        let cols: u64 = report.layers.iter().map(|l| l.cols).sum();
        let impr = 100.0 * improved as f64 / cols.max(1) as f64;
        let (pin, psh) = perplexity_pair(&qm, &wb.corpus, &wb.shifted, mc.max_seq, ppl_tokens);
        table.push_row(&[
            k.to_string(),
            format!("{pin:.3}"),
            format!("{psh:.3}"),
            format!("{secs:.2}"),
            samples.to_string(),
            format!("{impr:.1}"),
        ]);
        eprintln!(
            "[fig2] K={k}: ppl {pin:.3}/{psh:.3} ({secs:.1}s, {samples} samples, \
             {impr:.1}% cols improved)"
        );
        series.push(pin);
    }
    table.emit(Some(&exp::results_dir()), "fig2_k_ablation");
    exp::emit_bench_trace("fig2_k_ablation");
    // Shape note: K=5 should capture most of the K=50 improvement.
    if series.len() >= 2 {
        eprintln!(
            "[fig2] improvement K1->K5: {:.4}; K5->Kmax: {:.4}",
            series[0] - series[1],
            series[1] - series[series.len() - 1]
        );
    }
}
