//! **Figure 3 + Table 4** — sensitivity of the JTA knobs (μ, λ) on
//! shifted-corpus perplexity at 3-bit g128. Emits the full (μ, λ) grid
//! (Table 4) plus the two 1-D sweeps with the other knob fixed at 0.6
//! (Figure 3). Shape target (DESIGN.md E6): U-shaped μ curve with an
//! interior optimum; λ flatter with a robust interior operating point.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::perplexity;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::Table;

fn main() {
    let mc = &exp::bench_models()[exp::bench_models().len() - 1];
    let wb = exp::load_workbench(mc);
    let (n_calib, seq) = exp::calib_size();
    let ppl_tokens = exp::ppl_tokens();

    let grid: Vec<f64> = if exp::quick() {
        vec![0.1, 0.4, 0.6, 0.8]
    } else {
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]
    };

    let run = |mu: f64, lambda: f64| -> f64 {
        let cfg = QuantConfig { mu, lambda, ..QuantConfig::paper_defaults(3, 128) };
        match quantize_model(&wb.model, &wb.corpus, Method::Ojbkq, &cfg, n_calib, seq, None) {
            Ok((qm, _)) => perplexity(&qm, &wb.shifted, mc.max_seq, ppl_tokens),
            Err(e) => {
                eprintln!("[fig3] mu={mu} lambda={lambda} failed: {e}");
                f64::NAN
            }
        }
    };

    // Full grid (Table 4).
    let mut headers: Vec<String> = vec!["mu \\ lambda".into()];
    headers.extend(grid.iter().map(|l| format!("{l:.1}")));
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table4 = Table::new(
        &format!("Table 4 — shifted-corpus PPL on {} under (mu, lambda), 3-bit", mc.name),
        &href,
    );
    let mut best = (f64::INFINITY, 0.0, 0.0);
    for &mu in &grid {
        let mut row: Vec<String> = vec![format!("{mu:.1}")];
        for &lambda in &grid {
            let p = run(mu, lambda);
            if p < best.0 {
                best = (p, mu, lambda);
            }
            row.push(format!("{p:.4}"));
        }
        eprintln!("[fig3] grid row mu={mu} done");
        table4.push_row(&row);
    }
    table4.emit(Some(&exp::results_dir()), "table4_mu_lambda_grid");
    eprintln!("[fig3] grid optimum: ppl={:.4} at (mu={}, lambda={})", best.0, best.1, best.2);

    // 1-D sweeps with the other knob at 0.6 (Figure 3 panels). Reuses the
    // grid's sample points plus the boundary values the paper plots.
    let sweep: Vec<f64> =
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];
    let mut fig3 = Table::new(
        &format!("Figure 3 — 1-D sensitivity on {} (other knob = 0.6)", mc.name),
        &["value", "ppl (vary mu)", "ppl (vary lambda)"],
    );
    for &v in &sweep {
        let p_mu = run(v, 0.6);
        let p_la = run(0.6, v);
        fig3.push_row(&[format!("{v:.1}"), format!("{p_mu:.4}"), format!("{p_la:.4}")]);
        eprintln!("[fig3] sweep v={v}: mu-curve {p_mu:.4}, lambda-curve {p_la:.4}");
    }
    fig3.emit(Some(&exp::results_dir()), "fig3_mu_lambda_sweeps");
}
