//! **Figure 4** — quantization time ratios. Three views:
//!
//! * **4a (headline)**: end-to-end pipeline wall clock, streaming
//!   activation propagation vs the legacy O(L²) prefix re-forward
//!   captures, on the 8-block fallback model — asserts the streaming
//!   engine is ≥ 2× faster.
//! * **4 (paper metric)**: per-LAYER quantization time increase vs K (the
//!   K-independent stages — Gram, Cholesky, triangular solves, scale
//!   calibration — amortize the K-path decode, so layer time grows
//!   sub-linearly; the paper reports ~+80% at K=25).
//! * **4b**: the raw tile decode (which IS ~linear in K — the honest
//!   decomposition).

use ojbkq::bench::exp;
use ojbkq::bench::Bencher;
use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{CaptureMode, Pipeline};
use ojbkq::data::SyntheticGrammar;
use ojbkq::linalg::{cholesky_upper_jittered, syrk_upper};
use ojbkq::model::Model;
use ojbkq::quant::klein::alpha_for;
use ojbkq::quant::ppi::{decode_tile, PpiInput};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use ojbkq::tensor::Matrix;

fn main() {
    let (m, ntile) = if exp::quick() { (64usize, 64usize) } else { (128usize, 64usize) };
    let ks: Vec<usize> = if exp::quick() { vec![1, 5] } else { vec![1, 5, 15, 25] };
    let mut rng = Rng::new(0xF16);
    let a = Matrix::randn(2 * m, m, 1.0, &mut rng);
    let g = syrk_upper(&a, 0.05);
    let (r, _) = cholesky_upper_jittered(&g, 1e-6).unwrap();
    let s = Matrix::from_fn(m, ntile, |_, _| 0.05 + 0.2 * rng.uniform_f32());
    let qbar = Matrix::from_fn(m, ntile, |_, _| 15.0 * rng.uniform_f32());

    // --- Paper metric: FULL layer quantization time vs K (m=256 layer
    // with realistic calibration volume; the Gram/Cholesky/solve stages
    // are K-independent and amortize the decode).
    let (lm, ln, lp) = if exp::quick() { (128usize, 128usize, 512usize) } else { (256, 256, 1024) };
    let w = Matrix::randn(lm, ln, 0.5, &mut rng);
    let x = Matrix::randn(lp, lm, 1.0, &mut rng);
    let mut t_layer = Table::new(
        &format!("Figure 4 — per-LAYER quantization time vs K (m={lm}, n={ln}, p={lp})"),
        &["K", "layer ms", "ratio"],
    );
    let mut layer_base = None;
    for &k in &ks {
        let cfg = ojbkq::quant::QuantConfig {
            k,
            ..ojbkq::quant::QuantConfig::paper_defaults(4, 128)
        };
        let stats = Bencher::new(&format!("layer k={k}")).warmup(1).iters(5).run(|| {
            let mut lrng = Rng::new(42);
            ojbkq::quant::ojbkq::quantize(&w, &x, &x, &cfg, &mut lrng, None).unwrap()
        });
        let ms = stats.p50 * 1e3;
        if layer_base.is_none() {
            layer_base = Some(ms);
        }
        t_layer.push_row(&[
            k.to_string(),
            format!("{ms:.1}"),
            format!("{:.2}x", ms / layer_base.unwrap()),
        ]);
    }
    t_layer.emit(Some(&exp::results_dir()), "fig4_layer_time_ratio");

    // --- Decomposition: raw tile decode (linear in K by construction).
    let rt = SolverRuntime::new(&exp::artifacts_dir()).ok();
    let mut table = Table::new(
        &format!("Figure 4b — raw tile decode time vs K (m={m}, ntile={ntile})"),
        &["K", "native ms", "native ratio", "pjrt ms", "pjrt ratio"],
    );
    let mut native_base = None;
    let mut pjrt_base = None;
    for &k in &ks {
        let alpha: Vec<f32> = (0..ntile)
            .map(|j| {
                let mn = (0..m)
                    .map(|i| {
                        let v = r.get(i, i) as f64 * s.get(i, j) as f64;
                        v * v
                    })
                    .fold(f64::INFINITY, f64::min);
                alpha_for(k, m, mn) as f32
            })
            .collect();
        let uniforms = Rng::new(k as u64).uniform_vec_f32((k + 1) * m * ntile);
        let stats = Bencher::new(&format!("native k={k}")).warmup(1).iters(5).run(|| {
            decode_tile(&PpiInput {
                r: &r,
                s: &s,
                qbar: &qbar,
                qmax: 15.0,
                k,
                block: 16,
                alpha: &alpha,
                uniforms: &uniforms,
            })
        });
        let native_ms = stats.p50 * 1e3;
        if native_base.is_none() {
            native_base = Some(native_ms);
        }
        // PJRT path (only for K values with registered variants).
        let pjrt_ms = rt.as_ref().and_then(|rt| {
            rt.select_variant(m, ntile, k)?;
            let stats = Bencher::new(&format!("pjrt   k={k}")).warmup(1).iters(5).run(|| {
                rt.decode_tile(&r, &s, &qbar, 15.0, k, &alpha, &uniforms).expect("pjrt")
            });
            Some(stats.p50 * 1e3)
        });
        if let (Some(p), None) = (pjrt_ms, pjrt_base) {
            pjrt_base = Some(p);
        }
        table.push_row(&[
            k.to_string(),
            format!("{native_ms:.2}"),
            format!("{:.2}x", native_ms / native_base.unwrap()),
            pjrt_ms.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
            match (pjrt_ms, pjrt_base) {
                (Some(p), Some(b)) => format!("{:.2}x", p / b),
                _ => "-".into(),
            },
        ]);
    }
    table.emit(Some(&exp::results_dir()), "fig4_time_ratio");

    // Last (it ends in a hard assert): one flaky timing measurement must
    // not cost us the two tables above.
    pipeline_capture_speedup();
}

/// Figure 4a: end-to-end pipeline calibration cost — streaming activation
/// propagation vs the legacy prefix re-forwards, on the 8-block fallback
/// model (med-5M random init; capture cost does not depend on training
/// state). RTN keeps the solver share tiny so the capture regime
/// dominates, which is exactly the quantity the refactor targets.
fn pipeline_capture_speedup() {
    let mc = ModelConfig::named("med-5M");
    let mut mrng = Rng::new(0xF16A);
    let model = Model::random(mc.clone(), &mut mrng);
    let corpus = SyntheticGrammar::new(mc.vocab_size, 0.2, 42).corpus(40_000, &mut mrng);
    let (n_calib, seq) = if exp::quick() { (2usize, 48usize) } else { (4, 96) };
    let mut crng = Rng::new(0xCA11B);
    let calib = corpus.calibration(n_calib, seq, &mut crng);
    // Dense execution on both legs: the re-forward path always captures
    // from the dense spliced mirror, so packed execution on the streaming
    // leg would conflate capture strategy with kernel choice (the packed
    // engine is measured by `fig_qgemm`).
    let cfg = QuantConfig { group_size: 64, packed_exec: false, ..QuantConfig::default() };
    let run = |mode: CaptureMode| {
        Bencher::new(&format!("pipeline {mode:?}")).run_once(|| {
            Pipeline::new(&model, calib.clone(), Method::Rtn, cfg.clone(), None)
                .with_capture_mode(mode)
                .run()
                .unwrap()
        })
    };
    let ((_, rep_s), t_stream) = run(CaptureMode::Streaming);
    let ((_, rep_r), t_reforward) = run(CaptureMode::Reforward);
    let speedup = t_reforward / t_stream;
    let mut table = Table::new(
        &format!(
            "Figure 4a — pipeline capture: streaming vs re-forward ({} blocks, {n_calib}x{seq} calib, RTN)",
            mc.n_layers
        ),
        &["capture mode", "total s", "capture s", "block steps", "speedup"],
    );
    table.push_row(&[
        "streaming".to_string(),
        format!("{t_stream:.3}"),
        format!("{:.3}", rep_s.capture_secs),
        rep_s.capture_block_steps.to_string(),
        format!("{speedup:.2}x"),
    ]);
    table.push_row(&[
        "re-forward".to_string(),
        format!("{t_reforward:.3}"),
        format!("{:.3}", rep_r.capture_secs),
        rep_r.capture_block_steps.to_string(),
        "1.00x".to_string(),
    ]);
    table.emit(Some(&exp::results_dir()), "fig4_pipeline_capture");
    eprintln!("[fig4] streaming  {}", exp::timing_summary(&rep_s));
    eprintln!("[fig4] re-forward {}", exp::timing_summary(&rep_r));
    assert!(
        speedup >= 2.0,
        "streaming pipeline must be >=2x faster end-to-end than prefix re-forwards, got {speedup:.2}x"
    );
}
