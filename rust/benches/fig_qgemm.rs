//! Packed-engine throughput and memory: quantized-GEMM execution vs the
//! dense f32 splice it replaced.
//!
//! Two measurements on the fallback (random-init) model:
//!  * per-layer `Y = X·Ŵ` throughput — [`PackedLinear::matmul`] on
//!    bit-packed codes vs dense [`matmul`] on the dequantized weight, at
//!    calibration-sized and serving-sized batches;
//!  * whole-model forward latency + resident weight bytes —
//!    [`QuantizedModel`] vs its dense dequantized twin.
//!
//! ```sh
//! cargo bench --bench fig_qgemm             # full
//! OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_qgemm
//! ```

use ojbkq::bench::{exp, Bencher};
use ojbkq::coordinator::quantize_model;
use ojbkq::infer::PackedLinear;
use ojbkq::linalg::matmul;
use ojbkq::model::LanguageModel;
use ojbkq::quant::{rtn, Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use ojbkq::tensor::Matrix;

fn main() {
    layer_kernel_throughput();
    model_forward_and_memory();
}

/// Per-layer kernel comparison across batch sizes.
fn layer_kernel_throughput() {
    let (m, n) = if exp::quick() { (256usize, 256usize) } else { (512, 512) };
    let mut rng = Rng::new(0x46);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 64, ..Default::default() };
    let q = rtn::quantize(&w, &cfg);
    let packed = PackedLinear::from_quantized(&q, true);
    let dense = q.dequantize();
    let iters = if exp::quick() { 5 } else { 20 };
    let mut table = Table::new(
        &format!("fig_qgemm — packed vs dense GEMM, {m}×{n} W4 g64"),
        &["batch", "dense p50 (s)", "packed p50 (s)", "dense GFLOP/s", "packed GFLOP/s"],
    );
    for &batch in &[8usize, 64, 256] {
        let x = Matrix::randn(batch, m, 1.0, &mut rng);
        let flops = 2.0 * batch as f64 * m as f64 * n as f64;
        let sd = Bencher::new(&format!("dense  b={batch}")).iters(iters).run(|| matmul(&x, &dense));
        let sp =
            Bencher::new(&format!("packed b={batch}")).iters(iters).run(|| packed.matmul(&x));
        table.push_row(&[
            batch.to_string(),
            format!("{:.5}", sd.p50),
            format!("{:.5}", sp.p50),
            format!("{:.2}", ojbkq::bench::gflops(flops, &sd)),
            format!("{:.2}", ojbkq::bench::gflops(flops, &sp)),
        ]);
    }
    table.emit(Some(&exp::results_dir()), "fig_qgemm_layer");
}

/// Whole-model forward latency + resident weight memory.
fn model_forward_and_memory() {
    let mc = &exp::bench_models()[0];
    let wb = exp::load_workbench(mc);
    let cfg = QuantConfig { wbit: 4, group_size: 64, packed_exec: true, ..Default::default() };
    let (n_calib, seq) = if exp::quick() { (2usize, 32usize) } else { (4, 64) };
    let (qm, report) =
        quantize_model(&wb.model, &wb.corpus, Method::Rtn, &cfg, n_calib, seq, None)
            .expect("pipeline");
    let dense = qm.to_dense();
    let mut rng = Rng::new(0x51);
    let toks: Vec<u16> =
        (0..mc.max_seq.min(64)).map(|_| rng.below(mc.vocab_size as u64) as u16).collect();
    let iters = if exp::quick() { 3 } else { 10 };
    let sd = Bencher::new("model forward dense").iters(iters).run(|| dense.forward(&toks));
    let sp = Bencher::new("model forward packed").iters(iters).run(|| qm.forward(&toks));
    let fp_bytes = qm.fp_weight_bytes();
    let packed_bytes = qm.packed_weight_bytes();
    let mut table = Table::new(
        &format!("fig_qgemm — {} end-to-end, W4 g64 (RTN)", mc.name),
        &["engine", "forward p50 (s)", "resident weight bytes", "vs f32"],
    );
    table.push_row(&[
        "dense f32 splice".to_string(),
        format!("{:.5}", sd.p50),
        fp_bytes.to_string(),
        "1.00x".to_string(),
    ]);
    table.push_row(&[
        "packed integer codes".to_string(),
        format!("{:.5}", sp.p50),
        packed_bytes.to_string(),
        format!("{:.2}x", report.resident_compression()),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_qgemm_model");
    assert!(
        packed_bytes * 4 <= fp_bytes,
        "W4 resident memory must be ≤ 1/4 of f32: {packed_bytes} vs {fp_bytes}"
    );
}
