//! Packed-engine throughput and memory: quantized-GEMM execution vs the
//! dense f32 splice it replaced, plus the PR-3 batch-fused paths.
//!
//! Five measurements on the fallback (random-init) models:
//!  * per-layer `Y = X·Ŵ` throughput — [`PackedLinear::matmul`] on
//!    bit-packed codes vs dense [`matmul`] across a batch sweep
//!    `b ∈ {1, 8, 64, 512}` (serving-row to batched-capture-stack sizes);
//!  * the integer-core vs f32-reference kernel sweep —
//!    [`qgemm_packed_with`] under both [`PackedCore`]s across
//!    W2/W3/W4 × the same batch sweep, pinning the PR-6 speedup
//!    (headline scalar: `int_core_speedup_w4`);
//!  * the unpack kernel microbench — u64 bit-sliced
//!    [`unpack_bits_range`] vs the PR-3 table-driven
//!    [`unpack_bits_range_lut`] vs the per-code shift reference
//!    [`unpack_bits_range_shift`];
//!  * capture-stage throughput on the 8-block `med-5M` fallback model —
//!    one block advance of all calibration caches via the batched
//!    tall-GEMM stage API vs per-sequence stepping (serial loop and the
//!    PR-2-style `parallel_map` fan-out);
//!  * whole-model forward latency + resident weight bytes —
//!    [`QuantizedModel`] vs its dense dequantized twin.
//!
//! Machine-readable results land in `BENCH_qgemm.json` (cwd: `rust/`).
//!
//! ```sh
//! cargo bench --bench fig_qgemm             # full
//! OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_qgemm
//! ```

use ojbkq::bench::{exp, Bencher};
use ojbkq::config::ModelConfig;
use ojbkq::coordinator::quantize_model;
use ojbkq::infer::{qgemm_packed_with, PackedCore, PackedLinear, QuantizedModel};
use ojbkq::linalg::matmul;
use ojbkq::model::LanguageModel;
use ojbkq::parallel::parallel_map;
use ojbkq::quant::qtensor::{
    pack_bits, unpack_bits_range, unpack_bits_range_lut, unpack_bits_range_shift,
};
use ojbkq::quant::{rtn, Method, QuantConfig};
use ojbkq::report::{json_str, Table};
use ojbkq::rng::Rng;
use ojbkq::tensor::{Matrix, RowBatch};

fn main() {
    let mut json = Vec::new();
    let t = layer_kernel_throughput();
    json.push(("layer_sweep".to_string(), t.to_json()));
    let (t, extra) = core_sweep();
    json.push(("core_sweep".to_string(), t.to_json()));
    json.extend(extra);
    let t = unpack_microbench();
    json.push(("unpack".to_string(), t.to_json()));
    let (t, extra) = capture_batched_vs_per_sequence();
    json.push(("capture".to_string(), t.to_json()));
    json.extend(extra);
    let t = model_forward_and_memory();
    json.push(("model".to_string(), t.to_json()));
    let fields: Vec<String> =
        json.into_iter().map(|(k, v)| format!("{}:{}", json_str(&k), v)).collect();
    let payload = format!("{{{}}}\n", fields.join(","));
    std::fs::write("BENCH_qgemm.json", &payload).expect("write BENCH_qgemm.json");
    eprintln!("[bench] wrote BENCH_qgemm.json");
    exp::emit_bench_trace("fig_qgemm");
}

/// Per-layer kernel comparison across the batch sweep.
fn layer_kernel_throughput() -> Table {
    let (m, n) = if exp::quick() { (256usize, 256usize) } else { (512, 512) };
    let mut rng = Rng::new(0x46);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 64, ..Default::default() };
    let q = rtn::quantize(&w, &cfg);
    let packed = PackedLinear::from_quantized(&q, true);
    let dense = q.dequantize();
    let iters = if exp::quick() { 5 } else { 20 };
    let mut table = Table::new(
        &format!("fig_qgemm — packed vs dense GEMM, {m}×{n} W4 g64"),
        &["batch", "dense p50 (s)", "packed p50 (s)", "dense GFLOP/s", "packed GFLOP/s"],
    );
    for &batch in &[1usize, 8, 64, 512] {
        let x = Matrix::randn(batch, m, 1.0, &mut rng);
        let flops = 2.0 * batch as f64 * m as f64 * n as f64;
        let sd = Bencher::new(&format!("dense  b={batch}")).iters(iters).run(|| matmul(&x, &dense));
        let sp =
            Bencher::new(&format!("packed b={batch}")).iters(iters).run(|| packed.matmul(&x));
        table.push_row(&[
            batch.to_string(),
            format!("{:.5}", sd.p50),
            format!("{:.5}", sp.p50),
            format!("{:.2}", ojbkq::bench::gflops(flops, &sd)),
            format!("{:.2}", ojbkq::bench::gflops(flops, &sp)),
        ]);
    }
    table.emit(Some(&exp::results_dir()), "fig_qgemm_layer");
    table
}

/// Integer core vs f32 reference core on the same packed layers:
/// W2/W3/W4 × the serving-to-capture batch sweep. The headline scalar
/// `int_core_speedup_w4` (f32 p50 / int p50 at W4, worst batch) is what
/// the PR-6 acceptance pins at ≥ 1.5×.
fn core_sweep() -> (Table, Vec<(String, String)>) {
    let (m, n) = if exp::quick() { (256usize, 256usize) } else { (512, 512) };
    let iters = if exp::quick() { 5 } else { 20 };
    let mut rng = Rng::new(0x1C); // distinct stream from layer_kernel_throughput
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let mut table = Table::new(
        &format!("fig_qgemm — integer core vs f32 reference, {m}×{n} g64"),
        &["wbit", "batch", "f32 p50 (s)", "int p50 (s)", "int speedup", "int GFLOP/s"],
    );
    let mut extra = Vec::new();
    for &wbit in &[2u8, 3, 4] {
        let cfg = QuantConfig { wbit, group_size: 64, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let packed = PackedLinear::from_quantized(&q, true);
        let t = packed.as_packed().expect("packed layer");
        let mut worst = f64::INFINITY;
        for &batch in &[1usize, 8, 64, 512] {
            let x = Matrix::randn(batch, m, 1.0, &mut rng);
            let flops = 2.0 * batch as f64 * m as f64 * n as f64;
            let sf = Bencher::new(&format!("core f32 w{wbit} b={batch}"))
                .iters(iters)
                .run(|| qgemm_packed_with(t, &x, PackedCore::F32));
            let si = Bencher::new(&format!("core int w{wbit} b={batch}"))
                .iters(iters)
                .run(|| qgemm_packed_with(t, &x, PackedCore::Int));
            let speedup = sf.p50 / si.p50.max(1e-12);
            worst = worst.min(speedup);
            table.push_row(&[
                wbit.to_string(),
                batch.to_string(),
                format!("{:.5}", sf.p50),
                format!("{:.5}", si.p50),
                format!("{speedup:.2}x"),
                format!("{:.2}", ojbkq::bench::gflops(flops, &si)),
            ]);
        }
        extra.push((format!("int_core_speedup_w{wbit}"), format!("{worst:.3}")));
    }
    table.emit(Some(&exp::results_dir()), "fig_qgemm_core");
    (table, extra)
}

/// The u64 bit-sliced unpack vs the PR-3 LUT path vs the per-code shift
/// reference, per width.
fn unpack_microbench() -> Table {
    let n_codes = if exp::quick() { 1 << 16 } else { 1 << 18 };
    let iters = if exp::quick() { 10 } else { 30 };
    let mut rng = Rng::new(0x17);
    let mut table = Table::new(
        "fig_qgemm — unpack kernel, codes/s",
        &["wbit", "shift p50 (s)", "lut p50 (s)", "u64 p50 (s)", "u64 vs shift", "u64 vs lut"],
    );
    for &wbit in &[2u8, 3, 4] {
        let codes: Vec<u8> = (0..n_codes).map(|_| rng.below(1 << wbit) as u8).collect();
        // Word-aligned stream, as the packed engine holds it — the u64
        // path covers every code instead of falling back near the tail.
        let mut packed = pack_bits(&codes, wbit);
        packed.resize(packed.len().div_ceil(8) * 8, 0);
        let mut out = vec![0u8; n_codes];
        let ss = Bencher::new(&format!("unpack shift w{wbit}"))
            .iters(iters)
            .run(|| unpack_bits_range_shift(&packed, wbit, 0, &mut out));
        let sl = Bencher::new(&format!("unpack lut   w{wbit}"))
            .iters(iters)
            .run(|| unpack_bits_range_lut(&packed, wbit, 0, &mut out));
        let su = Bencher::new(&format!("unpack u64   w{wbit}"))
            .iters(iters)
            .run(|| unpack_bits_range(&packed, wbit, 0, &mut out));
        table.push_row(&[
            wbit.to_string(),
            format!("{:.6}", ss.p50),
            format!("{:.6}", sl.p50),
            format!("{:.6}", su.p50),
            format!("{:.2}x", ss.p50 / su.p50.max(1e-12)),
            format!("{:.2}x", sl.p50 / su.p50.max(1e-12)),
        ]);
    }
    table.emit(Some(&exp::results_dir()), "fig_qgemm_unpack");
    table
}

/// Capture-stage throughput on the 8-block fallback model: advancing all
/// calibration caches one block, batched tall-GEMM vs per-sequence
/// stepping (both the serial loop and the PR-2 `parallel_map` fan-out,
/// which nested kernel threads inside sequence threads).
fn capture_batched_vs_per_sequence() -> (Table, Vec<(String, String)>) {
    let mc = ModelConfig::named("med-5M");
    let wb = exp::load_workbench(&mc);
    let cfg = QuantConfig { wbit: 4, group_size: 64, ..Default::default() };
    let mut qm = QuantizedModel::from_model(&wb.model);
    // Only block 0 is advanced below — packing the other blocks would be
    // pure setup cost.
    for id in qm.linear_ids().into_iter().filter(|id| id.block == 0) {
        let q = rtn::quantize(wb.model.linear(id), &cfg);
        qm.set_layer(id, PackedLinear::from_quantized(&q, true));
    }
    let (n_calib, seq) = if exp::quick() { (8usize, 32usize) } else { (16, 64) };
    let mut rng = Rng::new(0xCA);
    let calib = wb.corpus.calibration(n_calib, seq, &mut rng);
    let parts: Vec<Matrix> = calib.iter().map(|s| qm.embed_sequence(s)).collect();
    let batch = RowBatch::stack(&parts);
    let iters = if exp::quick() { 5 } else { 10 };
    let block = 0usize;

    let advance_seq = |h: &Matrix| -> Matrix {
        let a = qm.attn_in(h, block);
        let c = qm.attn_ctx(&a, block);
        let m = qm.post_attn(h, &c, block);
        let mi = qm.mlp_in(&m, block);
        let act = qm.mlp_act(&mi, block);
        qm.post_mlp(&m, &act, block)
    };
    let s_serial = Bencher::new("capture per-seq serial").iters(iters).run(|| {
        parts.iter().map(|h| advance_seq(h)).collect::<Vec<_>>()
    });
    let s_fanout = Bencher::new("capture per-seq parallel_map").iters(iters).run(|| {
        parallel_map(parts.len(), |i| advance_seq(&parts[i]))
    });
    let s_batched = Bencher::new("capture batched tall-GEMM").iters(iters).run(|| {
        let a = qm.attn_in_batch(batch.data(), block);
        let c = qm.attn_ctx_batch(&a, batch.offsets(), block);
        let m = qm.post_attn_batch(batch.data(), &c, block);
        let mi = qm.mlp_in_batch(&m, block);
        let act = qm.mlp_act_batch(&mi, block);
        qm.post_mlp_batch(&m, &act, block)
    });
    let speedup_serial = s_serial.p50 / s_batched.p50.max(1e-12);
    let speedup_fanout = s_fanout.p50 / s_batched.p50.max(1e-12);
    let mut table = Table::new(
        &format!(
            "fig_qgemm — capture advance, {} ({} blocks), n_calib={n_calib} seq={seq} W4 g64",
            mc.name, mc.n_layers
        ),
        &["capture path", "block advance p50 (s)", "speedup vs batched"],
    );
    table.push_row(&[
        "per-sequence (serial)".to_string(),
        format!("{:.5}", s_serial.p50),
        format!("{speedup_serial:.2}x"),
    ]);
    table.push_row(&[
        "per-sequence (parallel_map)".to_string(),
        format!("{:.5}", s_fanout.p50),
        format!("{speedup_fanout:.2}x"),
    ]);
    table.push_row(&[
        "batched tall-GEMM".to_string(),
        format!("{:.5}", s_batched.p50),
        "1.00x".to_string(),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_qgemm_capture");
    let extra = vec![
        ("capture_speedup_vs_serial".to_string(), format!("{speedup_serial:.3}")),
        ("capture_speedup_vs_parallel_map".to_string(), format!("{speedup_fanout:.3}")),
    ];
    (table, extra)
}

/// Whole-model forward latency + resident weight memory.
fn model_forward_and_memory() -> Table {
    let mc = &exp::bench_models()[0];
    let wb = exp::load_workbench(mc);
    let cfg = QuantConfig { wbit: 4, group_size: 64, packed_exec: true, ..Default::default() };
    let (n_calib, seq) = if exp::quick() { (2usize, 32usize) } else { (4, 64) };
    let (qm, report) =
        quantize_model(&wb.model, &wb.corpus, Method::Rtn, &cfg, n_calib, seq, None)
            .expect("pipeline");
    let dense = qm.to_dense();
    let mut rng = Rng::new(0x51);
    let toks: Vec<u16> =
        (0..mc.max_seq.min(64)).map(|_| rng.below(mc.vocab_size as u64) as u16).collect();
    let iters = if exp::quick() { 3 } else { 10 };
    let sd = Bencher::new("model forward dense").iters(iters).run(|| dense.forward(&toks));
    let sp = Bencher::new("model forward packed").iters(iters).run(|| qm.forward(&toks));
    let fp_bytes = qm.fp_weight_bytes();
    let packed_bytes = qm.packed_weight_bytes();
    let mut table = Table::new(
        &format!("fig_qgemm — {} end-to-end, W4 g64 (RTN)", mc.name),
        &["engine", "forward p50 (s)", "resident weight bytes", "vs f32"],
    );
    table.push_row(&[
        "dense f32 splice".to_string(),
        format!("{:.5}", sd.p50),
        fp_bytes.to_string(),
        "1.00x".to_string(),
    ]);
    table.push_row(&[
        "packed integer codes".to_string(),
        format!("{:.5}", sp.p50),
        packed_bytes.to_string(),
        format!("{:.2}x", report.resident_compression()),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_qgemm_model");
    assert!(
        packed_bytes * 4 <= fp_bytes,
        "W4 resident memory must be ≤ 1/4 of f32: {packed_bytes} vs {fp_bytes}"
    );
    table
}
