//! Robustness-stack overhead: what the fault-injection harness and
//! crash-safe checkpointing cost when nothing goes wrong.
//!
//! Three measurements on random-init pipeline models:
//!
//!  * **disarmed fault sites** — ns per [`ojbkq::robust::fault_point`]
//!    call with no spec armed (the zero-cost discipline: one relaxed
//!    atomic load), plus full-pipeline wall-clock disarmed vs
//!    armed-but-never-firing, with a bit-identity assertion on the
//!    resulting forward logits;
//!  * **checkpoint overhead** — `quantize_model_checkpointed` (per-block
//!    OJBS1 segments + OJBM1 manifest, atomic writes) vs the plain
//!    pipeline, byte-identical output asserted;
//!  * **resume cost** — interrupt a checkpointed run with an injected
//!    torn segment write, resume it, and compare the kill+resume total
//!    against one uninterrupted checkpointed run — again byte-identical.
//!
//! Machine-readable results land in `BENCH_robust.json` (cwd: `rust/`).
//!
//! ```sh
//! cargo bench --bench fig_robust             # full
//! OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_robust
//! ```

use ojbkq::bench::{exp, Bencher};
use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{quantize_model, quantize_model_checkpointed};
use ojbkq::data::{Corpus, SyntheticGrammar};
use ojbkq::infer::{save_quantized, QuantizedModel};
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{json_str, Table};
use ojbkq::rng::Rng;
use ojbkq::robust;
use std::hint::black_box;
use std::path::Path;

fn main() {
    let mut json = Vec::new();
    let (t, extra) = disarmed_overhead();
    json.push(("disarmed_overhead".to_string(), t.to_json()));
    json.extend(extra);
    let (t, extra) = checkpoint_and_resume();
    json.push(("checkpoint_resume".to_string(), t.to_json()));
    json.extend(extra);
    let fields: Vec<String> =
        json.into_iter().map(|(k, v)| format!("{}:{}", json_str(&k), v)).collect();
    let payload = format!("{{{}}}\n", fields.join(","));
    std::fs::write("BENCH_robust.json", &payload).expect("write BENCH_robust.json");
    eprintln!("[bench] wrote BENCH_robust.json");
    exp::emit_bench_trace("fig_robust");
}

fn setup() -> (Model, Corpus) {
    let d = if exp::quick() { 48 } else { 96 };
    let cfg = ModelConfig {
        name: format!("robust-d{d}"),
        vocab_size: 64,
        d_model: d,
        n_layers: 2,
        n_heads: 4,
        d_ff: d * 2,
        max_seq: 64,
    };
    let mut rng = Rng::new(0x0B0);
    (Model::random(cfg, &mut rng), SyntheticGrammar::new(64, 0.2, 7).corpus(20_000, &mut rng))
}

fn qcfg() -> QuantConfig {
    QuantConfig { ntile: 16, ..QuantConfig::paper_defaults(4, 8) }
}

fn sizes() -> (usize, usize) {
    if exp::quick() {
        (2, 32) // (n_calib, seq_len)
    } else {
        (3, 48)
    }
}

fn ojbq1_bytes(qm: &QuantizedModel, path: &Path) -> Vec<u8> {
    save_quantized(qm, path).expect("writing OJBQ1");
    std::fs::read(path).expect("reading OJBQ1 back")
}

/// Disarmed fault-site cost: per-call ns and whole-pipeline ratio, with
/// the bit-identity gate on armed-but-never-firing.
fn disarmed_overhead() -> (Table, Vec<(String, String)>) {
    robust::reset_faults();
    let iters = if exp::quick() { 3 } else { 7 };
    const CALLS: usize = 1_000_000;
    let s_call = Bencher::new("fault_point disarmed").iters(iters).run(|| {
        for _ in 0..CALLS {
            black_box(robust::fault_point(black_box("serve.step")));
        }
    });
    assert_eq!(robust::fault_event_count(), 0, "disarmed fault sites must record nothing");
    let ns_per_call = s_call.p50 * 1e9 / CALLS as f64;

    let (model, corpus) = setup();
    let cfg = qcfg();
    let (n_calib, seq) = sizes();
    let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let mut logits = Vec::new();
    let mut p50 = Vec::new();
    for armed in [false, true] {
        robust::reset_faults();
        if armed {
            // Armed but never firing: the spec is live, the nth
            // threshold is unreachable.
            robust::set_faults(Some("coordinator.solve:err:1000000000")).unwrap();
        }
        let mut qm = None;
        let name = if armed { "pipeline armed-unfired" } else { "pipeline disarmed" };
        let s = Bencher::new(name).iters(iters).run(|| {
            let run = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, n_calib, seq, None);
            qm = Some(run.expect("pipeline").0);
        });
        assert_eq!(robust::fault_event_count(), 0, "unfired spec must record nothing");
        robust::reset_faults();
        logits.push(qm.expect("pipeline ran").forward(&toks));
        p50.push(s.p50);
    }
    assert!(logits[0] == logits[1], "armed-but-unfired harness must not move bits");
    let ratio = p50[1] / p50[0].max(1e-12);

    let mut table = Table::new(
        "fig_robust — disarmed fault-harness overhead",
        &["measurement", "p50 (s)", "derived"],
    );
    table.push_row(&[
        format!("fault_point × {CALLS} (disarmed)"),
        format!("{:.5}", s_call.p50),
        format!("{ns_per_call:.2} ns/call"),
    ]);
    table.push_row(&["pipeline disarmed".to_string(), format!("{:.5}", p50[0]), "1.00x".into()]);
    table.push_row(&[
        "pipeline armed-unfired".to_string(),
        format!("{:.5}", p50[1]),
        format!("{ratio:.3}x"),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_robust_disarmed");
    let extra = vec![
        ("fault_point_disarmed_ns".to_string(), format!("{ns_per_call:.3}")),
        ("armed_unfired_ratio".to_string(), format!("{ratio:.3}")),
    ];
    (table, extra)
}

/// Checkpointing and resume against the plain pipeline, byte-identity
/// asserted at every comparison point.
fn checkpoint_and_resume() -> (Table, Vec<(String, String)>) {
    robust::reset_faults();
    let (model, corpus) = setup();
    let cfg = qcfg();
    let (n_calib, seq) = sizes();
    let iters = if exp::quick() { 2 } else { 5 };
    let tmp = std::env::temp_dir().join("ojbkq_bench_robust");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).expect("temp dir");

    let mut qm = None;
    let s_plain = Bencher::new("quantize plain").iters(iters).run(|| {
        let run = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, n_calib, seq, None);
        qm = Some(run.expect("plain").0);
    });
    let gold = ojbq1_bytes(&qm.take().expect("plain ran"), &tmp.join("plain.ojbq1"));

    let parts = tmp.join("fresh.parts");
    let s_ck = Bencher::new("quantize checkpointed").iters(iters).run(|| {
        let run = quantize_model_checkpointed(
            &model, &corpus, Method::Ojbkq, &cfg, n_calib, seq, None, &parts, false,
        );
        qm = Some(run.expect("checkpointed").0);
    });
    let ck = ojbq1_bytes(&qm.take().expect("checkpointed ran"), &tmp.join("ck.ojbq1"));
    assert_eq!(ck, gold, "checkpointing moved bytes");
    let ckpt_overhead = s_ck.p50 / s_plain.p50.max(1e-12);

    // Interrupt after the first block's segment lands (torn write on the
    // second), then resume the durable prefix.
    let parts_kill = tmp.join("kill.parts");
    let s_resume = Bencher::new("interrupt + resume").iters(iters).run(|| {
        robust::set_faults(Some("io.segment_write:partial_write:2")).unwrap();
        let killed = quantize_model_checkpointed(
            &model, &corpus, Method::Ojbkq, &cfg, n_calib, seq, None, &parts_kill, false,
        );
        robust::reset_faults();
        assert!(killed.is_err(), "injected torn write must abort the run");
        let run = quantize_model_checkpointed(
            &model, &corpus, Method::Ojbkq, &cfg, n_calib, seq, None, &parts_kill, true,
        );
        qm = Some(run.expect("resume").0);
    });
    let resumed = ojbq1_bytes(&qm.take().expect("resume ran"), &tmp.join("resumed.ojbq1"));
    assert_eq!(resumed, gold, "resume diverged from the uninterrupted run");
    let resume_ratio = s_resume.p50 / s_ck.p50.max(1e-12);

    let mut table = Table::new(
        "fig_robust — crash-safe checkpointing and resume",
        &["measurement", "p50 (s)", "ratio"],
    );
    table.push_row(&["plain pipeline".to_string(), format!("{:.5}", s_plain.p50), "1.00x".into()]);
    table.push_row(&[
        "checkpointed (fresh)".to_string(),
        format!("{:.5}", s_ck.p50),
        format!("{ckpt_overhead:.3}x"),
    ]);
    table.push_row(&[
        "interrupted + resumed".to_string(),
        format!("{:.5}", s_resume.p50),
        format!("{resume_ratio:.3}x vs checkpointed"),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_robust_resume");
    let extra = vec![
        ("checkpoint_overhead_ratio".to_string(), format!("{ckpt_overhead:.3}")),
        ("resume_total_ratio".to_string(), format!("{resume_ratio:.3}")),
    ];
    (table, extra)
}
