//! Token-serving throughput on the packed integer core: KV-cached
//! autoregressive decode + continuous batching vs the O(T²) re-forward
//! generation loop it replaces.
//!
//! Three measurements on random-init serving models (the zoo presets cap
//! `max_seq` at 128; serving needs a ≥256-token prefix, so the bench
//! builds its own configs):
//!
//!  * the serving sweep — [`Scheduler`] tokens/sec at W2/W3/W4, one
//!    live sequence (scratch-arena [`ServeEngine::decode_step`] path) vs
//!    a full batch (`decode_step_batch`, one fused qgemm per linear per
//!    step), with the prefill/decode wall-clock split and resident
//!    KV-cache bytes per row;
//!  * KV-cached decode vs re-forward generation —
//!    [`LanguageModel::greedy_continue`] re-runs the whole prefix per
//!    token; the scheduler prefills once and appends. Headline scalar
//!    `kv_decode_speedup` (prefix ≥ 256), pinned ≥ 5× in-bench;
//!  * serving residency — packed weight bytes + peak KV-cache bytes =
//!    the one number a serving deployment holds resident.
//!
//! Machine-readable results land in `BENCH_serve.json` (cwd: `rust/`).
//!
//! ```sh
//! cargo bench --bench fig_serve             # full
//! OJBKQ_BENCH_QUICK=1 cargo bench --bench fig_serve
//! ```

use ojbkq::bench::{exp, Bencher};
use ojbkq::config::ModelConfig;
use ojbkq::infer::{PackedLinear, QuantizedModel};
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::{rtn, QuantConfig};
use ojbkq::report::{fmt_bytes, json_str, Table};
use ojbkq::rng::Rng;
use ojbkq::serve::{Request, Scheduler};

fn main() {
    let mut json = Vec::new();
    let (t, extra) = serving_sweep();
    json.push(("serving_sweep".to_string(), t.to_json()));
    json.extend(extra);
    let (t, extra) = kv_vs_reforward();
    json.push(("kv_vs_reforward".to_string(), t.to_json()));
    json.extend(extra);
    let (t, extra) = serving_residency();
    json.push(("residency".to_string(), t.to_json()));
    json.extend(extra);
    let fields: Vec<String> =
        json.into_iter().map(|(k, v)| format!("{}:{}", json_str(&k), v)).collect();
    let payload = format!("{{{}}}\n", fields.join(","));
    std::fs::write("BENCH_serve.json", &payload).expect("write BENCH_serve.json");
    eprintln!("[bench] wrote BENCH_serve.json");
    exp::emit_bench_trace("fig_serve");
}

/// Serving model with a ≥256-token context window (the zoo caps at 128).
fn serve_config() -> ModelConfig {
    if exp::quick() {
        ModelConfig {
            name: "serve-quick".to_string(),
            vocab_size: 256,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            max_seq: 320,
        }
    } else {
        ModelConfig {
            name: "serve-full".to_string(),
            vocab_size: 256,
            d_model: 256,
            n_layers: 4,
            n_heads: 8,
            d_ff: 512,
            max_seq: 320,
        }
    }
}

/// Random-init model packed at `wbit` (RTN g64 — the kernel under test
/// is serving, not the solver).
fn packed_model(cfg: &ModelConfig, wbit: u8, rng: &mut Rng) -> QuantizedModel {
    let m = Model::random(cfg.clone(), rng);
    let qc = QuantConfig { wbit, group_size: 64, ..Default::default() };
    let mut qm = QuantizedModel::from_model(&m);
    for id in qm.linear_ids() {
        let q = rtn::quantize(m.linear(id), &qc);
        qm.set_layer(id, PackedLinear::from_quantized(&q, true));
    }
    qm
}

/// Random prompts of length `len`.
fn prompts(n: usize, len: usize, vocab: usize, rng: &mut Rng) -> Vec<Vec<u16>> {
    (0..n).map(|_| (0..len).map(|_| rng.below(vocab as u64) as u16).collect()).collect()
}

/// One full scheduler run; returns (total secs, prefill secs, decode
/// secs, tokens, peak KV bytes).
fn serve_run(
    qm: &QuantizedModel,
    prompts: &[Vec<u16>],
    max_new: usize,
    max_concurrent: usize,
) -> (f64, f64, f64, u64, usize) {
    let t0 = std::time::Instant::now();
    let mut sched = Scheduler::new(qm, max_concurrent);
    for (i, p) in prompts.iter().enumerate() {
        sched
            .submit(Request {
                id: i as u64,
                prompt: p.clone(),
                max_new,
                temperature: 0.0,
                seed: 7 + i as u64,
            })
            .expect("admitted");
    }
    sched.run();
    let secs = t0.elapsed().as_secs_f64();
    let (pf, dec) = (sched.prefill_secs(), sched.decode_secs());
    (secs, pf, dec, sched.tokens_generated(), sched.peak_kv_bytes())
}

/// Tokens/sec at W2/W3/W4, single-stream vs continuously batched.
fn serving_sweep() -> (Table, Vec<(String, String)>) {
    let cfg = serve_config();
    let (n_req, prompt_len, max_new) =
        if exp::quick() { (4usize, 64usize, 16usize) } else { (4, 64, 48) };
    let iters = if exp::quick() { 2 } else { 5 };
    let mut rng = Rng::new(0x5E);
    let ps = prompts(n_req, prompt_len, cfg.vocab_size, &mut rng);
    let mut table = Table::new(
        &format!(
            "fig_serve — {} serving, {n_req} req × prompt {prompt_len} + {max_new} new",
            cfg.name
        ),
        &[
            "wbit",
            "mode",
            "tok/s",
            "prefill p50 (s)",
            "decode p50 (s)",
            "peak KV bytes",
        ],
    );
    let mut extra = Vec::new();
    for &wbit in &[2u8, 3, 4] {
        let qm = packed_model(&cfg, wbit, &mut rng);
        let total_tokens = (n_req * max_new) as f64;
        let mut stats = Vec::new();
        for &(mode, conc) in &[("single", 1usize), ("batched", n_req)] {
            let mut split = (0.0, 0.0, 0usize);
            let s = Bencher::new(&format!("serve w{wbit} {mode}")).iters(iters).run(|| {
                let (_, pf, dec, _, kv) = serve_run(&qm, &ps, max_new, conc);
                split = (pf, dec, kv);
            });
            let tps = total_tokens / s.p50.max(1e-12);
            table.push_row(&[
                wbit.to_string(),
                mode.to_string(),
                format!("{tps:.1}"),
                format!("{:.5}", split.0),
                format!("{:.5}", split.1),
                split.2.to_string(),
            ]);
            extra.push((format!("tokens_per_sec_{mode}_w{wbit}"), format!("{tps:.1}")));
            stats.push(s.p50);
        }
        extra.push((
            format!("batched_speedup_w{wbit}"),
            format!("{:.3}", stats[0] / stats[1].max(1e-12)),
        ));
    }
    table.emit(Some(&exp::results_dir()), "fig_serve_sweep");
    (table, extra)
}

/// KV-cached decode vs the O(T²) re-forward loop, prefix ≥ 256. The
/// acceptance scalar `kv_decode_speedup` is pinned ≥ 5× here.
fn kv_vs_reforward() -> (Table, Vec<(String, String)>) {
    let cfg = serve_config();
    let prompt_len = 256usize; // acceptance floor — not shrunk in quick mode
    let max_new = if exp::quick() { 16 } else { 48 };
    let iters = if exp::quick() { 2 } else { 5 };
    let mut rng = Rng::new(0x4B);
    let qm = packed_model(&cfg, 4, &mut rng);
    let prompt: Vec<u16> =
        (0..prompt_len).map(|_| rng.below(cfg.vocab_size as u64) as u16).collect();
    let s_reforward = Bencher::new("generate re-forward")
        .iters(iters)
        .run(|| qm.greedy_continue(&prompt, max_new));
    let mut split = (0.0f64, 0.0f64);
    let s_kv = Bencher::new("generate KV-cached").iters(iters).run(|| {
        let (_, pf, dec, _, _) = serve_run(&qm, std::slice::from_ref(&prompt), max_new, 1);
        split = (pf, dec);
    });
    let speedup = s_reforward.p50 / s_kv.p50.max(1e-12);
    let mut table = Table::new(
        &format!(
            "fig_serve — KV cache vs re-forward, {} W4, prefix {prompt_len} + {max_new} new",
            cfg.name
        ),
        &["generation path", "p50 (s)", "tok/s", "speedup"],
    );
    table.push_row(&[
        "re-forward (greedy_continue)".to_string(),
        format!("{:.5}", s_reforward.p50),
        format!("{:.1}", max_new as f64 / s_reforward.p50.max(1e-12)),
        "1.00x".to_string(),
    ]);
    table.push_row(&[
        "KV-cached (prefill + decode)".to_string(),
        format!("{:.5}", s_kv.p50),
        format!("{:.1}", max_new as f64 / s_kv.p50.max(1e-12)),
        format!("{speedup:.2}x"),
    ]);
    table.emit(Some(&exp::results_dir()), "fig_serve_kv");
    assert!(
        speedup >= 5.0,
        "KV-cached decode must beat re-forward generation by ≥5x at prefix ≥256: {speedup:.2}x"
    );
    let extra = vec![
        ("kv_decode_speedup".to_string(), format!("{speedup:.3}")),
        ("kv_prefill_secs".to_string(), format!("{:.5}", split.0)),
        ("kv_decode_secs".to_string(), format!("{:.5}", split.1)),
    ];
    (table, extra)
}

/// What a serving deployment holds resident: packed weights + KV cache.
fn serving_residency() -> (Table, Vec<(String, String)>) {
    let cfg = serve_config();
    let (n_req, prompt_len, max_new) = (4usize, 64usize, 8usize);
    let mut rng = Rng::new(0x4E5);
    let qm = packed_model(&cfg, 4, &mut rng);
    let ps = prompts(n_req, prompt_len, cfg.vocab_size, &mut rng);
    let (_, _, _, _, kv_peak) = serve_run(&qm, &ps, max_new, n_req);
    let weights = qm.packed_weight_bytes();
    let total = weights + kv_peak;
    let mut table = Table::new(
        &format!("fig_serve — {} W4 resident serving memory, {n_req} concurrent", cfg.name),
        &["component", "bytes", "human"],
    );
    table.push_row(&[
        "packed weights".to_string(),
        weights.to_string(),
        fmt_bytes(weights as u64),
    ]);
    table.push_row(&[
        "KV cache (peak)".to_string(),
        kv_peak.to_string(),
        fmt_bytes(kv_peak as u64),
    ]);
    table.push_row(&["total".to_string(), total.to_string(), fmt_bytes(total as u64)]);
    table.emit(Some(&exp::results_dir()), "fig_serve_residency");
    let extra = vec![
        ("packed_weight_bytes".to_string(), weights.to_string()),
        ("kv_peak_bytes".to_string(), kv_peak.to_string()),
        ("resident_bytes".to_string(), total.to_string()),
    ];
    (table, extra)
}
