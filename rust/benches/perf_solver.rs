//! **§Perf** — whole-stack solver profiling (DESIGN.md E8): GEMM
//! substrate throughput, per-stage layer-solve breakdown, PPI block-size
//! sweep, native-vs-PJRT decode throughput, and column scaling. Drives
//! the before/after iteration log in EXPERIMENTS.md §Perf.

use ojbkq::bench::exp;
use ojbkq::bench::{gflops, Bencher};
use ojbkq::linalg::{cholesky_upper_jittered, matmul, syrk_upper};
use ojbkq::quant::klein::alpha_for;
use ojbkq::quant::ppi::{decode_tile, PpiInput};
use ojbkq::quant::{jta, QuantConfig};
use ojbkq::report::Table;
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use ojbkq::tensor::Matrix;

fn main() {
    let mut rng = Rng::new(0x9E2F);

    // --- 1. GEMM substrate roofline.
    let mut t_gemm = Table::new("Perf — GEMM substrate", &["op", "shape", "GFLOP/s"]);
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512), (1024, 256, 512)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let stats =
            Bencher::new(&format!("gemm {m}x{k}x{n}")).warmup(2).iters(8).run(|| matmul(&a, &b));
        t_gemm.push_row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.2}", gflops(2.0 * (m * k * n) as f64, &stats)),
        ]);
    }
    for &(p, m) in &[(1024usize, 256usize), (2048, 384)] {
        let x = Matrix::randn(p, m, 1.0, &mut rng);
        let stats =
            Bencher::new(&format!("syrk {p}x{m}")).warmup(2).iters(8).run(|| syrk_upper(&x, 0.1));
        t_gemm.push_row(&[
            "syrk (X̃ᵀX̃)".into(),
            format!("{p}x{m}"),
            format!("{:.2}", gflops((p * m * m) as f64, &stats)),
        ]);
    }
    t_gemm.emit(Some(&exp::results_dir()), "perf_gemm");

    // --- 2. Layer-solve stage breakdown (m=256, n=256, p=1024, K=5).
    let (m, n, p, k) = if exp::quick() { (128, 128, 512, 5) } else { (256, 256, 1024, 5) };
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let x = Matrix::randn(p, m, 1.0, &mut rng);
    let cfg = QuantConfig { k, ..QuantConfig::paper_defaults(4, 128) };
    let mut t_stage = Table::new(
        &format!("Perf — layer solve stages (m={m} n={n} p={p} K={k})"),
        &["stage", "p50 ms"],
    );
    let sys_stats = Bencher::new("jta system (gram+rhs)")
        .warmup(1)
        .iters(5)
        .run(|| jta::build_system(&w, &x, &x, &cfg));
    let sys = jta::build_system(&w, &x, &x, &cfg);
    let chol_stats = Bencher::new("cholesky")
        .warmup(1)
        .iters(5)
        .run(|| cholesky_upper_jittered(&sys.gram, 1e-6).unwrap());
    let (r, _) = cholesky_upper_jittered(&sys.gram, 1e-6).unwrap();
    let solve_stats =
        Bencher::new("triangular solves").warmup(1).iters(5).run(|| jta::solve_real(&r, &sys.rhs));
    let s_tile = Matrix::from_fn(m, 64, |_, _| 0.1);
    let qbar = Matrix::from_fn(m, 64, |_, _| 7.5);
    let alpha: Vec<f32> = (0..64)
        .map(|j| {
            let mn = (0..m)
                .map(|i| {
                    let v = r.get(i, i) as f64 * s_tile.get(i, j) as f64;
                    v * v
                })
                .fold(f64::INFINITY, f64::min);
            alpha_for(k, m, mn) as f32
        })
        .collect();
    let uniforms = Rng::new(1).uniform_vec_f32((k + 1) * m * 64);
    let decode_stats = Bencher::new("ppi decode (1 tile)").warmup(1).iters(5).run(|| {
        decode_tile(&PpiInput {
            r: &r,
            s: &s_tile,
            qbar: &qbar,
            qmax: 15.0,
            k,
            block: 16,
            alpha: &alpha,
            uniforms: &uniforms,
        })
    });
    for (name, st) in [
        ("gram+rhs", &sys_stats),
        ("cholesky", &chol_stats),
        ("tri solves", &solve_stats),
        ("ppi decode/tile", &decode_stats),
    ] {
        t_stage.push_row(&[name.to_string(), format!("{:.2}", st.p50 * 1e3)]);
    }
    t_stage.emit(Some(&exp::results_dir()), "perf_stages");

    // --- 3. PPI block-size sweep (the Appendix-A B parameter).
    let mut t_block = Table::new("Perf — PPI block size sweep", &["B", "p50 ms"]);
    for &b in &[1usize, 4, 8, 16, 32, 64] {
        let st = Bencher::new(&format!("ppi B={b}")).warmup(1).iters(5).run(|| {
            decode_tile(&PpiInput {
                r: &r,
                s: &s_tile,
                qbar: &qbar,
                qmax: 15.0,
                k,
                block: b,
                alpha: &alpha,
                uniforms: &uniforms,
            })
        });
        t_block.push_row(&[b.to_string(), format!("{:.2}", st.p50 * 1e3)]);
    }
    t_block.emit(Some(&exp::results_dir()), "perf_block_sweep");

    // --- 4. Native vs PJRT decode.
    if let Ok(rt) = SolverRuntime::new(&exp::artifacts_dir()) {
        if rt.select_variant(m, 64, k).is_some() {
            let mut t_backend =
                Table::new("Perf — decode backend comparison", &["backend", "p50 ms"]);
            t_backend.push_row(&["native".to_string(), format!("{:.2}", decode_stats.p50 * 1e3)]);
            let st = Bencher::new("pjrt decode (1 tile)").warmup(1).iters(5).run(|| {
                rt.decode_tile(&r, &s_tile, &qbar, 15.0, k, &alpha, &uniforms).expect("pjrt")
            });
            t_backend.push_row(&["pjrt".to_string(), format!("{:.2}", st.p50 * 1e3)]);
            t_backend.emit(Some(&exp::results_dir()), "perf_backend");
        }
    }
}
