//! **§Perf** — whole-stack solver profiling (DESIGN.md E8): GEMM
//! substrate throughput, per-stage layer-solve breakdown, PPI block-size
//! sweep, native-vs-PJRT decode throughput, the end-to-end layer-solve
//! thread sweep, and the shared-factor group leverage. Drives the
//! before/after iteration log in EXPERIMENTS.md §Perf.
//!
//! Machine-readable results land in `BENCH_solver.json` (cwd: `rust/`) —
//! the solver-throughput trajectory the BENCH_* series tracks across
//! PRs, including the multi-threaded vs single-threaded end-to-end
//! OJBKQ layer solve.

use ojbkq::bench::exp;
use ojbkq::bench::{gflops, Bencher};
use ojbkq::coordinator::quantize_model;
use ojbkq::linalg::{cholesky_upper_jittered, matmul, syrk_upper};
use ojbkq::quant::klein::alpha_for;
use ojbkq::quant::ppi::{decode_tile, PpiInput};
use ojbkq::quant::{
    jta, quantize_layer, quantize_layer_shared, FactoredSystem, Method, QuantConfig,
};
use ojbkq::report::{json_str, Table};
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use ojbkq::tensor::Matrix;

fn main() {
    let mut json: Vec<(String, String)> = Vec::new();
    let mut rng = Rng::new(0x9E2F);

    // --- 1. GEMM substrate roofline.
    let mut t_gemm = Table::new("Perf — GEMM substrate", &["op", "shape", "GFLOP/s"]);
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512), (1024, 256, 512)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let stats =
            Bencher::new(&format!("gemm {m}x{k}x{n}")).warmup(2).iters(8).run(|| matmul(&a, &b));
        t_gemm.push_row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.2}", gflops(2.0 * (m * k * n) as f64, &stats)),
        ]);
    }
    for &(p, m) in &[(1024usize, 256usize), (2048, 384)] {
        let x = Matrix::randn(p, m, 1.0, &mut rng);
        let stats =
            Bencher::new(&format!("syrk {p}x{m}")).warmup(2).iters(8).run(|| syrk_upper(&x, 0.1));
        t_gemm.push_row(&[
            "syrk (X̃ᵀX̃)".into(),
            format!("{p}x{m}"),
            format!("{:.2}", gflops((p * m * m) as f64, &stats)),
        ]);
    }
    t_gemm.emit(Some(&exp::results_dir()), "perf_gemm");
    json.push(("gemm".to_string(), t_gemm.to_json()));

    // --- 2. Layer-solve stage breakdown (m=256, n=256, p=1024, K=5).
    let (m, n, p, k) = if exp::quick() { (128, 128, 512, 5) } else { (256, 256, 1024, 5) };
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let x = Matrix::randn(p, m, 1.0, &mut rng);
    let cfg = QuantConfig { k, ..QuantConfig::paper_defaults(4, 128) };
    let mut t_stage = Table::new(
        &format!("Perf — layer solve stages (m={m} n={n} p={p} K={k})"),
        &["stage", "p50 ms"],
    );
    let sys_stats = Bencher::new("jta system (gram+rhs)")
        .warmup(1)
        .iters(5)
        .run(|| jta::build_system(&w, &x, &x, &cfg));
    let sys = jta::build_system(&w, &x, &x, &cfg);
    let chol_stats = Bencher::new("cholesky")
        .warmup(1)
        .iters(5)
        .run(|| cholesky_upper_jittered(&sys.gram, 1e-6).unwrap());
    let (r, _) = cholesky_upper_jittered(&sys.gram, 1e-6).unwrap();
    let solve_stats =
        Bencher::new("triangular solves").warmup(1).iters(5).run(|| jta::solve_real(&r, &sys.rhs));
    let s_tile = Matrix::from_fn(m, 64, |_, _| 0.1);
    let qbar = Matrix::from_fn(m, 64, |_, _| 7.5);
    let alpha: Vec<f32> = (0..64)
        .map(|j| {
            let mn = (0..m)
                .map(|i| {
                    let v = r.get(i, i) as f64 * s_tile.get(i, j) as f64;
                    v * v
                })
                .fold(f64::INFINITY, f64::min);
            alpha_for(k, m, mn) as f32
        })
        .collect();
    let uniforms = Rng::new(1).uniform_vec_f32((k + 1) * m * 64);
    let decode_stats = Bencher::new("ppi decode (1 tile)").warmup(1).iters(5).run(|| {
        decode_tile(&PpiInput {
            r: &r,
            s: &s_tile,
            qbar: &qbar,
            qmax: 15.0,
            k,
            block: 16,
            alpha: &alpha,
            uniforms: &uniforms,
        })
    });
    for (name, st) in [
        ("gram+rhs", &sys_stats),
        ("cholesky", &chol_stats),
        ("tri solves", &solve_stats),
        ("ppi decode/tile", &decode_stats),
    ] {
        t_stage.push_row(&[name.to_string(), format!("{:.2}", st.p50 * 1e3)]);
    }
    t_stage.emit(Some(&exp::results_dir()), "perf_stages");
    json.push(("stages".to_string(), t_stage.to_json()));

    // --- 3. PPI block-size sweep (the Appendix-A B parameter).
    let mut t_block = Table::new("Perf — PPI block size sweep", &["B", "p50 ms"]);
    for &b in &[1usize, 4, 8, 16, 32, 64] {
        let st = Bencher::new(&format!("ppi B={b}")).warmup(1).iters(5).run(|| {
            decode_tile(&PpiInput {
                r: &r,
                s: &s_tile,
                qbar: &qbar,
                qmax: 15.0,
                k,
                block: b,
                alpha: &alpha,
                uniforms: &uniforms,
            })
        });
        t_block.push_row(&[b.to_string(), format!("{:.2}", st.p50 * 1e3)]);
    }
    t_block.emit(Some(&exp::results_dir()), "perf_block_sweep");
    json.push(("block_sweep".to_string(), t_block.to_json()));

    // --- 4. Native vs PJRT decode.
    if let Ok(rt) = SolverRuntime::new(&exp::artifacts_dir()) {
        if rt.select_variant(m, 64, k).is_some() {
            let mut t_backend =
                Table::new("Perf — decode backend comparison", &["backend", "p50 ms"]);
            t_backend.push_row(&["native".to_string(), format!("{:.2}", decode_stats.p50 * 1e3)]);
            let st = Bencher::new("pjrt decode (1 tile)").warmup(1).iters(5).run(|| {
                rt.decode_tile(&r, &s_tile, &qbar, 15.0, k, &alpha, &uniforms).expect("pjrt")
            });
            t_backend.push_row(&["pjrt".to_string(), format!("{:.2}", st.p50 * 1e3)]);
            t_backend.emit(Some(&exp::results_dir()), "perf_backend");
            json.push(("backend".to_string(), t_backend.to_json()));
        }
    }

    // --- 5. End-to-end layer solve: OJBKQ_THREADS sweep. The whole
    // Algorithm-1 path (gram, act-order, Cholesky, RHS, triangular
    // solves, tile-parallel Random-K decode) under pinned thread counts;
    // the multi-threaded row is the headline solver-throughput number of
    // BENCH_solver.json. Codes are bit-identical across rows by
    // construction (pinned by tests/solver_parallel.rs).
    let cfg_e2e = QuantConfig { k, ..QuantConfig::paper_defaults(4, 128) };
    let e2e_iters = if exp::quick() { 3 } else { 5 };
    let mut t_e2e = Table::new(
        &format!("Perf — end-to-end OJBKQ layer solve (m={m} n={n} p={p} K={k})"),
        &["threads", "p50 ms", "speedup vs 1"],
    );
    let solve_once = |cfg: &QuantConfig| {
        let mut lrng = Rng::new(0x50);
        ojbkq::quant::ojbkq::quantize(&w, &x, &x, cfg, &mut lrng, None).unwrap()
    };
    ojbkq::parallel::set_thread_override(1);
    let st_serial = Bencher::new("ojbkq solve T=1")
        .warmup(1)
        .iters(e2e_iters)
        .run(|| solve_once(&cfg_e2e));
    // Clear the pin: the parallel row (and everything after) runs at the
    // operator's OJBKQ_THREADS / available-parallelism default.
    ojbkq::parallel::set_thread_override(0);
    let nt = ojbkq::parallel::num_threads();
    let st_par = Bencher::new(&format!("ojbkq solve T={nt}"))
        .warmup(1)
        .iters(e2e_iters)
        .run(|| solve_once(&cfg_e2e));
    t_e2e.push_row(&["1".to_string(), format!("{:.2}", st_serial.p50 * 1e3), "1.00".into()]);
    t_e2e.push_row(&[
        nt.to_string(),
        format!("{:.2}", st_par.p50 * 1e3),
        format!("{:.2}", st_serial.p50 / st_par.p50.max(1e-9)),
    ]);
    t_e2e.emit(Some(&exp::results_dir()), "perf_solver_e2e");
    json.push(("solver_e2e".to_string(), t_e2e.to_json()));

    // --- 6. Shared-factor leverage: a synthetic Q/K/V group (three
    // layers on one tap) solved with per-layer factorization vs one
    // FactoredSystem built once — the coordinator's group path.
    let w_group: Vec<Matrix> =
        (0..3).map(|i| Matrix::randn(m, n, 0.5, &mut Rng::new(0x60 + i))).collect();
    let mut t_shared = Table::new(
        &format!("Perf — shared-factor QKV group (3 layers, m={m} n={n} p={p})"),
        &["mode", "p50 ms", "speedup"],
    );
    let st_solo = Bencher::new("per-layer factorization").warmup(1).iters(e2e_iters).run(|| {
        for (uid, wg) in w_group.iter().enumerate() {
            quantize_layer(Method::Ojbkq, wg, &x, &x, &cfg_e2e, uid as u64, None).unwrap();
        }
    });
    let st_shared = Bencher::new("shared FactoredSystem").warmup(1).iters(e2e_iters).run(|| {
        let sys = FactoredSystem::for_method(Method::Ojbkq, &x, &cfg_e2e).unwrap();
        for (uid, wg) in w_group.iter().enumerate() {
            quantize_layer_shared(
                Method::Ojbkq,
                wg,
                &x,
                &x,
                &cfg_e2e,
                uid as u64,
                None,
                sys.as_ref(),
            )
            .unwrap();
        }
    });
    t_shared.push_row(&[
        "per-layer".to_string(),
        format!("{:.2}", st_solo.p50 * 1e3),
        "1.00".into(),
    ]);
    t_shared.push_row(&[
        "shared".to_string(),
        format!("{:.2}", st_shared.p50 * 1e3),
        format!("{:.2}", st_solo.p50 / st_shared.p50.max(1e-9)),
    ]);
    t_shared.emit(Some(&exp::results_dir()), "perf_shared_factor");
    json.push(("shared_factor".to_string(), t_shared.to_json()));

    // --- 7. Solver-family sweep: every Table-1 method end-to-end through
    // `quantize_model` on the smallest zoo entry — per-family solve time,
    // mean runtime error, and the summed proxy decode residual
    // (`f(q) − f(w_real)` for the lattice/iterative families; see
    // DESIGN.md §Solver families). These rows are the BENCH_solver.json
    // trajectory for the widened bench: QuantEase and ADMM-Q land here
    // next to GPTQ/OJBKQ so refinement cost and quality track across PRs.
    let fam_mc = &exp::bench_models()[0];
    let fam_wb = exp::load_workbench(fam_mc);
    let (fam_calib, fam_seq) = exp::calib_size();
    let mut t_family = Table::new(
        &format!("Perf — solver families on {} (4-bit g128)", fam_mc.name),
        &["family", "solve s", "mean rt err", "proxy resid"],
    );
    let fam_cfg = QuantConfig::paper_defaults(4, 128);
    for method in exp::table_methods() {
        match quantize_model(&fam_wb.model, &fam_wb.corpus, method, &fam_cfg, fam_calib, fam_seq, None)
        {
            Ok((_, report)) => {
                let nl = report.layers.len().max(1) as f64;
                let rt_err: f64 = report.layers.iter().map(|l| l.stats.rt_err).sum::<f64>() / nl;
                let resid: f64 = report.layers.iter().map(|l| l.stats.decode_resid).sum();
                t_family.push_row(&[
                    method.label().to_string(),
                    format!("{:.3}", report.solver_secs()),
                    format!("{rt_err:.5}"),
                    format!("{resid:.4}"),
                ]);
                eprintln!(
                    "[bench] family {}: solve {:.3}s rt_err {rt_err:.5} resid {resid:.4}",
                    method.label(),
                    report.solver_secs()
                );
            }
            Err(e) => {
                eprintln!("[bench] family {} failed: {e}", method.label());
                t_family.push_row(&[method.label().to_string(), "err".into(), "err".into(), "err".into()]);
            }
        }
    }
    t_family.emit(Some(&exp::results_dir()), "perf_solver_family");
    json.push(("solver_family".to_string(), t_family.to_json()));

    let fields: Vec<String> =
        json.into_iter().map(|(key, v)| format!("{}:{}", json_str(&key), v)).collect();
    let payload = format!("{{{}}}\n", fields.join(","));
    std::fs::write("BENCH_solver.json", &payload).expect("write BENCH_solver.json");
    eprintln!("[bench] wrote BENCH_solver.json");
    exp::emit_bench_trace("perf_solver");
}
