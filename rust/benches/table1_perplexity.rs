//! **Table 1** — perplexity across models × methods at {W4, W3} × {g128,
//! g0}. Left value = in-domain corpus ("C4" role), right = shifted corpus
//! ("WikiText-2" role). Shape targets (DESIGN.md E1): Ours ≤ Ours(R) ≤
//! Ours(N) ≈ GPTQ/AWQ ≪ RTN, gaps widening at 3-bit and g0.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::perplexity_pair;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{mark_best_min, Table};
use ojbkq::util::fmt_secs;

fn main() {
    let models = exp::bench_models();
    let (n_calib, seq) = exp::calib_size();
    let ppl_tokens = exp::ppl_tokens();
    let settings: Vec<(u8, usize)> = if exp::quick() {
        vec![(4, 128), (3, 128)]
    } else {
        vec![(4, 128), (3, 128), (4, 0), (3, 0)]
    };

    for (wbit, group) in settings {
        let label = format!(
            "Table 1 — W{wbit}A16 g{} perplexity (in-domain / shifted)",
            if group == 0 { "0".into() } else { group.to_string() }
        );
        let mut headers: Vec<String> = vec!["Method".into()];
        for m in &models {
            headers.push(m.name.clone());
        }
        let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&label, &href);

        // Collect per-model columns: rows = BF16 + methods.
        let methods = exp::table_methods();
        let mut cells: Vec<Vec<String>> = vec![Vec::new(); methods.len() + 1];
        // For best-marking we need numeric columns per model over methods.
        for mc in &models {
            let wb = exp::load_workbench(mc);
            let t0 = std::time::Instant::now();
            let (fp_in, fp_sh) =
                perplexity_pair(&wb.model, &wb.corpus, &wb.shifted, mc.max_seq, ppl_tokens);
            cells[0].push(format!("{fp_in:.2}/{fp_sh:.2}"));
            let mut in_vals = Vec::new();
            let mut sh_vals = Vec::new();
            for &method in &methods {
                let cfg = QuantConfig::paper_defaults(wbit, group);
                let quantized =
                    quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, None);
                match quantized {
                    Ok((qm, rep)) => {
                        eprintln!(
                            "[table1] {} {}: {}",
                            mc.name,
                            method.label(),
                            exp::timing_summary(&rep)
                        );
                        let (pin, psh) = perplexity_pair(
                            &qm,
                            &wb.corpus,
                            &wb.shifted,
                            mc.max_seq,
                            ppl_tokens,
                        );
                        in_vals.push(pin);
                        sh_vals.push(psh);
                    }
                    Err(e) => {
                        eprintln!("[table1] {} {} failed: {e}", mc.name, method.label());
                        in_vals.push(f64::NAN);
                        sh_vals.push(f64::NAN);
                    }
                }
            }
            let mi = mark_best_min(&in_vals, 2);
            let ms = mark_best_min(&sh_vals, 2);
            for (i, (a, b)) in mi.into_iter().zip(ms).enumerate() {
                cells[i + 1].push(format!("{a}/{b}"));
            }
            eprintln!(
                "[table1] {} W{wbit} g{group} done in {}",
                mc.name,
                fmt_secs(t0.elapsed().as_secs_f64())
            );
        }
        let mut row: Vec<String> = vec!["BF16".into()];
        row.extend(cells[0].clone());
        table.push_row(&row);
        for (i, &method) in exp::table_methods().iter().enumerate() {
            let mut row: Vec<String> = vec![method.label().into()];
            row.extend(cells[i + 1].clone());
            table.push_row(&row);
        }
        table.emit(Some(&exp::results_dir()), &format!("table1_w{wbit}_g{group}"));
    }
    // Sanity print of the headline ordering on the first model.
    let _ = Method::all();
}
