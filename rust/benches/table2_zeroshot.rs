//! **Table 2** — zero-shot accuracy on the six multiple-choice suites
//! (ARC-C/ARC-E/BoolQ/Hella/PIQA/Wino analogues) under 4-bit and 3-bit
//! g128 quantization. Shape target (DESIGN.md E2): all methods ≈ BF16 at
//! 4-bit; at 3-bit the gaps widen and Ours degrades most gracefully.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::{zero_shot_accuracy, ZeroShotTask};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{mark_best_max, Table};

fn main() {
    let models = exp::bench_models();
    let (n_calib, seq) = exp::calib_size();
    let n_items = if exp::quick() { 40 } else { 120 };
    let tasks = ZeroShotTask::suite();
    let seed = 0xE0E0;

    for wbit in [4u8, 3u8] {
        for mc in &models {
            let wb = exp::load_workbench(mc);
            let mut headers: Vec<String> = vec!["Method".into()];
            headers.extend(tasks.iter().map(|t| t.name.to_string()));
            headers.push("Average".into());
            let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let mut table =
                Table::new(&format!("Table 2 — {} zero-shot, {wbit}-bit g128", mc.name), &href);

            // BF16 row.
            let fp_accs: Vec<f64> = tasks
                .iter()
                .map(|t| zero_shot_accuracy(&wb.model, &wb.corpus, t, n_items, seed))
                .collect();
            let fp_avg = fp_accs.iter().sum::<f64>() / fp_accs.len() as f64;
            let mut row: Vec<String> = vec!["BF16".into()];
            row.extend(fp_accs.iter().map(|a| format!("{a:.2}")));
            row.push(format!("{fp_avg:.2}"));
            table.push_row(&row);

            // Method rows (paper Table 2 set: GPTQ/AWQ/QUIP/O(N)/O(R)/O).
            let methods = [
                Method::Gptq,
                Method::Awq,
                Method::Quip,
                Method::BabaiNaive,
                Method::KleinRandomK,
                Method::Ojbkq,
            ];
            let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); tasks.len() + 1];
            for &method in &methods {
                let cfg = QuantConfig::paper_defaults(wbit, 128);
                let accs: Vec<f64> = match quantize_model(
                    &wb.model, &wb.corpus, method, &cfg, n_calib, seq, None,
                ) {
                    Ok((qm, _)) => tasks
                        .iter()
                        .map(|t| zero_shot_accuracy(&qm, &wb.corpus, t, n_items, seed))
                        .collect(),
                    Err(e) => {
                        eprintln!("[table2] {} {} failed: {e}", mc.name, method.label());
                        vec![f64::NAN; tasks.len()]
                    }
                };
                for (i, a) in accs.iter().enumerate() {
                    per_task[i].push(*a);
                }
                per_task[tasks.len()].push(accs.iter().sum::<f64>() / accs.len() as f64);
                eprintln!("[table2] {} {wbit}-bit {} done", mc.name, method.label());
            }
            // Mark best/second-best per column, then assemble rows.
            let marked: Vec<Vec<String>> =
                per_task.iter().map(|col| mark_best_max(col, 2)).collect();
            for (mi, &method) in methods.iter().enumerate() {
                let mut row: Vec<String> = vec![method.label().into()];
                for col in &marked {
                    row.push(col[mi].clone());
                }
                table.push_row(&row);
            }
            table.emit(
                Some(&exp::results_dir()),
                &format!("table2_{}_w{wbit}", mc.name.replace('.', "_")),
            );
        }
    }
}
