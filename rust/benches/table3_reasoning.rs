//! **Table 3** — generative "reasoning" accuracy (GSM8K/GPQA/MBPP
//! analogues) under 4-bit g128 quantization. Shape target (DESIGN.md E3):
//! Ours attains the highest average and tracks BF16 most closely; greedy
//! multi-token generation amplifies per-layer quantization error.

use ojbkq::bench::exp;
use ojbkq::coordinator::quantize_model;
use ojbkq::eval::{reasoning_accuracy, ReasoningTask};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{mark_best_max, Table};

fn main() {
    let models = exp::bench_models();
    let (n_calib, seq) = exp::calib_size();
    let n_items = if exp::quick() { 20 } else { 60 };
    let tasks = ReasoningTask::suite();
    let seed = 0x7A51;

    for mc in &models {
        let wb = exp::load_workbench(mc);
        let mut headers: Vec<String> = vec!["Method".into()];
        headers.extend(tasks.iter().map(|t| t.name.to_string()));
        headers.push("Avg".into());
        let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(
            &format!("Table 3 — {} reasoning accuracy, 4-bit g128", mc.name),
            &href,
        );

        let fp: Vec<f64> = tasks
            .iter()
            .map(|t| reasoning_accuracy(&wb.model, &wb.corpus, t, n_items, seed))
            .collect();
        let mut row: Vec<String> = vec!["BF16".into()];
        row.extend(fp.iter().map(|a| format!("{a:.2}")));
        row.push(format!("{:.2}", fp.iter().sum::<f64>() / fp.len() as f64));
        table.push_row(&row);

        let methods = [Method::Gptq, Method::Awq, Method::Quip, Method::Ojbkq];
        let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); tasks.len() + 1];
        for &method in &methods {
            let cfg = QuantConfig::paper_defaults(4, 128);
            let accs: Vec<f64> =
                match quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, None) {
                    Ok((qm, _)) => tasks
                        .iter()
                        .map(|t| reasoning_accuracy(&qm, &wb.corpus, t, n_items, seed))
                        .collect(),
                    Err(e) => {
                        eprintln!("[table3] {} {} failed: {e}", mc.name, method.label());
                        vec![f64::NAN; tasks.len()]
                    }
                };
            for (i, a) in accs.iter().enumerate() {
                per_task[i].push(*a);
            }
            per_task[tasks.len()].push(accs.iter().sum::<f64>() / accs.len() as f64);
            eprintln!("[table3] {} {} done", mc.name, method.label());
        }
        let marked: Vec<Vec<String>> = per_task.iter().map(|c| mark_best_max(c, 2)).collect();
        for (mi, &method) in methods.iter().enumerate() {
            let mut row: Vec<String> = vec![method.label().into()];
            for col in &marked {
                row.push(col[mi].clone());
            }
            table.push_row(&row);
        }
        table.emit(
            Some(&exp::results_dir()),
            &format!("table3_{}", mc.name.replace('.', "_")),
        );
    }
}
