//! Shared experiment plumbing for the `cargo bench` harnesses in
//! `rust/benches/` — zoo loading, method grids, and scale control.
//!
//! Every bench honors `OJBKQ_BENCH_QUICK=1` (reduced model set / token
//! budgets so the full suite stays CI-sized) and writes its tables to
//! `results/` as markdown + CSV via [`crate::report::Table::emit`].

use crate::config::ModelConfig;
use crate::coordinator::{PipelineReport, Workbench};
use crate::quant::Method;
use crate::util::fmt_secs;
use std::path::PathBuf;

/// Reduced-scale mode toggle.
pub fn quick() -> bool {
    std::env::var("OJBKQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Where bench tables land.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("OJBKQ_RESULTS").unwrap_or_else(|_| "results".into());
    PathBuf::from(dir)
}

/// Artifact directory (trained models + AOT kernels).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("OJBKQ_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

/// The model zoo a bench iterates, scaled by quick mode.
pub fn bench_models() -> Vec<ModelConfig> {
    let all = ModelConfig::zoo();
    if quick() {
        all.into_iter().take(1).collect()
    } else {
        // tiny + small by default (a full `cargo bench` stays ~1h on one
        // core); base-2M and med-5M join with OJBKQ_BENCH_FULL=1.
        let n = if std::env::var("OJBKQ_BENCH_FULL").is_ok() { 4 } else { 2 };
        all.into_iter().take(n).collect()
    }
}

/// Load a workbench for a zoo entry (trained artifacts or fallback).
pub fn load_workbench(cfg: &ModelConfig) -> Workbench {
    let wb = Workbench::load(&artifacts_dir(), &cfg.name);
    if !wb.trained {
        eprintln!(
            "[bench] WARNING: {} has no trained artifacts (run `make artifacts`); \
             using random-init fallback — absolute numbers will be meaningless",
            cfg.name
        );
    }
    wb
}

/// Methods in the paper's Table-1 row order — the full PTQ test bench,
/// including the iterative solver families on the shared-factor engine.
pub fn table_methods() -> Vec<Method> {
    vec![
        Method::Rtn,
        Method::Gptq,
        Method::Awq,
        Method::Quip,
        Method::BabaiNaive,
        Method::KleinRandomK,
        Method::Ojbkq,
        Method::QuantEase,
        Method::AdmmQ,
    ]
}

/// Calibration size (sequences, seq_len) per scale mode.
pub fn calib_size() -> (usize, usize) {
    if quick() {
        (4, 64)
    } else {
        (8, 128)
    }
}

/// Perplexity evaluation token budget.
pub fn ppl_tokens() -> usize {
    if quick() {
        1_024
    } else {
        4_096
    }
}

/// Drain the observability registry into a `trace.json` next to the
/// bench tables, when tracing is on (`OJBKQ_TRACE=1`). `label` names the
/// bench (becomes the `bench` config key); no-op when tracing is
/// disabled so benches pay nothing by default. Each bench calls this
/// once at the end of its run, giving the perf-trajectory artifacts a
/// span/counter manifest alongside the raw timing tables.
pub fn emit_bench_trace(label: &str) {
    if !crate::obs::enabled() {
        return;
    }
    let config = vec![
        ("bench".to_string(), label.to_string()),
        ("quick".to_string(), quick().to_string()),
    ];
    let trace = crate::report::RunTrace::capture(config);
    let dir = results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("TRACE_{label}.json"));
    match trace.write(&path) {
        Ok(()) => println!("[bench] wrote trace manifest to {}", path.display()),
        Err(e) => eprintln!("[bench] writing trace {}: {e}", path.display()),
    }
}

/// One-line timing decomposition of a pipeline run: total wall clock,
/// activation-capture share, solver share, and the number of
/// transformer-block advances the captures cost (linear in depth under
/// streaming capture) — plus the OJBQ1 artifact size when the run wrote
/// one (`PipelineReport::artifact_bytes`).
pub fn timing_summary(report: &PipelineReport) -> String {
    let mut out = format!(
        "total {} (capture {} / solve {}; {} block-steps)",
        fmt_secs(report.total_secs),
        fmt_secs(report.capture_secs),
        fmt_secs(report.solver_secs()),
        report.capture_block_steps
    );
    if let Some(b) = report.artifact_bytes {
        out.push_str(&format!("; artifact {}", crate::report::fmt_bytes(b)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_knobs_consistent() {
        // Not asserting env behavior (global), just that defaults are sane.
        let (n, s) = calib_size();
        assert!(n >= 4 && s >= 64);
        assert!(ppl_tokens() >= 1_024);
        assert!(!table_methods().is_empty());
    }
}
