//! Measurement harness (no `criterion` offline).
//!
//! Every `cargo bench` target in `rust/benches/` uses [`Bencher`] for
//! timing (warmup + fixed-iteration sampling + robust statistics) and
//! [`crate::report`] for emitting the paper-shaped tables. The harness is
//! deliberately simple and deterministic: wall-clock medians over a fixed
//! number of samples, no adaptive stopping, so runs are reproducible.

pub mod exp;

use crate::util::{fmt_secs, mean, percentile, stddev};
use std::time::Instant;

/// Timing statistics over repeated runs (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    /// Build from raw per-iteration seconds.
    pub fn from_samples(samples: Vec<f64>) -> Stats {
        let mean_v = mean(&samples);
        let std_v = stddev(&samples);
        let p50 = percentile(&samples, 50.0);
        let p95 = percentile(&samples, 95.0);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Stats { samples, mean: mean_v, std: std_v, p50, p95, min, max }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "p50={} mean={} ±{} p95={} (n={})",
            fmt_secs(self.p50),
            fmt_secs(self.mean),
            fmt_secs(self.std),
            fmt_secs(self.p95),
            self.samples.len()
        )
    }
}

/// Fixed-plan micro/macro benchmark runner.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Label printed with results.
    pub name: String,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher { warmup: 2, iters: 10, name: name.to_string() }
    }

    pub fn warmup(mut self, w: usize) -> Bencher {
        self.warmup = w;
        self
    }

    pub fn iters(mut self, i: usize) -> Bencher {
        self.iters = i.max(1);
        self
    }

    /// Time `f`, returning stats. The closure's return value is consumed
    /// via `std::hint::black_box` so the optimizer cannot elide work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(samples);
        eprintln!("[bench] {:<40} {}", self.name, stats.summary());
        stats
    }

    /// Time a single long-running invocation (macro benchmarks like full
    /// pipeline quantization where iteration is too expensive).
    pub fn run_once<T>(&self, f: impl FnOnce() -> T) -> (T, f64) {
        let t0 = Instant::now();
        let out = std::hint::black_box(f());
        let secs = t0.elapsed().as_secs_f64();
        eprintln!("[bench] {:<40} once={}", self.name, fmt_secs(secs));
        (out, secs)
    }
}

/// Throughput helper: FLOPs/sec from a flop count and stats (p50-based).
pub fn gflops(flops: f64, stats: &Stats) -> f64 {
    if stats.p50 <= 0.0 {
        return 0.0;
    }
    flops / stats.p50 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn bencher_collects_requested_iters() {
        let b = Bencher::new("noop").warmup(1).iters(5);
        let mut count = 0;
        let stats = b.run(|| {
            count += 1;
            count
        });
        assert_eq!(stats.samples.len(), 5);
        assert_eq!(count, 6); // 1 warmup + 5 recorded
    }

    #[test]
    fn gflops_sane() {
        let s = Stats::from_samples(vec![0.5]);
        assert!((gflops(1e9, &s) - 2.0).abs() < 1e-9);
    }
}
