//! Minimal command-line argument parser (no `clap` offline).
//!
//! Supports the subcommand + `--key value` / `--flag` grammar used by the
//! `ojbkq` binary, the examples, and the bench harnesses, with typed
//! getters, defaults, and an auto-generated usage string.

use std::collections::BTreeMap;

/// Parsed command line: optional subcommand, positional args, and options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (e.g. `quantize`).
    pub subcommand: Option<String>,
    /// Remaining positional (non-flag) tokens.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable core).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Raw option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; exits with a clear message on a value
    /// that does not parse (CLI misuse should not panic with a backtrace).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v.parse::<T>().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a {}, got {v:?}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// usize option.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_parse(key, default)
    }

    /// f64 option.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get_parse(key, default)
    }

    /// f32 option.
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get_parse(key, default)
    }

    /// u64 option.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get_parse(key, default)
    }

    /// Boolean flag (present = true) or `--key true/false`.
    pub fn get_flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of T.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse::<T>().unwrap_or_else(|_| {
                        eprintln!("error: --{key} list element {s:?} failed to parse");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = toks("quantize model.bin out.bin --wbit 4");
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.positional, vec!["model.bin", "out.bin"]);
        assert_eq!(a.get_usize("wbit", 3), 4);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = toks("run --k=5 --verbose --mu 0.6");
        assert_eq!(a.get_usize("k", 0), 5);
        assert!(a.get_flag("verbose"));
        assert!(!a.get_flag("quiet"));
        assert!((a.get_f64("mu", 0.0) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let a = toks("eval");
        assert_eq!(a.get_str("method", "ojbkq"), "ojbkq");
        assert_eq!(a.get_usize("k", 5), 5);
    }

    #[test]
    fn lists_parse() {
        let a = toks("sweep --ks 1,5,10,25");
        assert_eq!(a.get_list::<usize>("ks", &[]), vec![1, 5, 10, 25]);
        let b = toks("sweep");
        assert_eq!(b.get_list::<usize>("ks", &[7]), vec![7]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = toks("x --fast --wbit 3");
        assert!(a.get_flag("fast"));
        assert_eq!(a.get_usize("wbit", 0), 3);
    }
}
