//! Configuration system: typed configs for models, quantization methods,
//! and experiments, with a minimal INI/TOML-flavored text format
//! (`key = value` lines, `[section]` headers, `#` comments) so runs are
//! reproducible from checked-in files without a serde dependency.

use std::collections::BTreeMap;
use std::path::Path;

use crate::quant::QuantConfig;

/// Raw parsed config file: section -> key -> value.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse the text format. Unknown syntax errors carry line numbers.
    pub fn parse(text: &str) -> anyhow::Result<RawConfig> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                cfg.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
            } else {
                anyhow::bail!("config parse error on line {}: {raw:?}", lineno + 1);
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> anyhow::Result<RawConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path:?}: {e}"))?;
        RawConfig::parse(&text)
    }

    /// Raw lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section).and_then(|s| s.get(key)).map(|v| v.as_str())
    }

    /// Typed lookup with default.
    pub fn get_parse<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> T {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Section names present.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

/// Model architecture hyperparameters (mirrors `python/compile/pretrain.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    /// The zoo of tiny models standing in for the paper's LLM families
    /// (see DESIGN.md §2). Names echo the paper's abbreviations.
    pub fn zoo() -> Vec<ModelConfig> {
        vec![
            ModelConfig::named("tiny-0.2M"),
            ModelConfig::named("small-0.8M"),
            ModelConfig::named("base-2M"),
            ModelConfig::named("med-5M"),
        ]
    }

    /// Look up a zoo preset by name.
    pub fn named(name: &str) -> ModelConfig {
        let (vocab, d, l, h, ff, seq) = match name {
            "tiny-0.2M" => (256, 96, 2, 4, 256, 128),
            "small-0.8M" => (512, 128, 4, 4, 352, 128),
            "base-2M" => (512, 192, 6, 6, 512, 128),
            "med-5M" => (512, 256, 8, 8, 704, 128),
            other => panic!("unknown model preset {other:?}"),
        };
        ModelConfig {
            name: name.to_string(),
            vocab_size: vocab,
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: ff,
            max_seq: seq,
        }
    }

    /// Parameter count (embeddings + blocks; head is tied to embedding).
    pub fn param_count(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = 3 * self.d_model * self.d_ff;
        let norms = 2 * self.d_model;
        self.vocab_size * self.d_model + self.n_layers * (attn + mlp + norms) + self.d_model
    }

    pub fn head_dim(&self) -> usize {
        assert_eq!(self.d_model % self.n_heads, 0, "d_model must divide n_heads");
        self.d_model / self.n_heads
    }
}

/// Experiment-level config: which model, which method, which data sizes.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub model: ModelConfig,
    pub quant: QuantConfig,
    pub calib_sequences: usize,
    pub calib_seq_len: usize,
    pub eval_tokens: usize,
    pub seed: u64,
}

impl ExperimentConfig {
    pub fn default_for(model: ModelConfig) -> ExperimentConfig {
        ExperimentConfig {
            model,
            quant: QuantConfig::default(),
            calib_sequences: 32,
            calib_seq_len: 128,
            eval_tokens: 16_384,
            seed: 0xC0FFEE,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_comments() {
        let raw = RawConfig::parse(
            "# top comment\n[model]\nname = \"small-0.8M\"\nd_model = 128 # inline\n\n[quant]\nwbit=4\n",
        )
        .unwrap();
        assert_eq!(raw.get("model", "name"), Some("small-0.8M"));
        assert_eq!(raw.get_parse::<usize>("model", "d_model", 0), 128);
        assert_eq!(raw.get_parse::<usize>("quant", "wbit", 0), 4);
        assert_eq!(raw.get_parse::<usize>("quant", "missing", 7), 7);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(RawConfig::parse("not a kv line").is_err());
    }

    #[test]
    fn zoo_presets_consistent() {
        for cfg in ModelConfig::zoo() {
            assert_eq!(cfg.d_model % cfg.n_heads, 0, "{}", cfg.name);
            assert!(cfg.param_count() > 0);
        }
        // Names roughly reflect parameter counts.
        let small = ModelConfig::named("small-0.8M").param_count();
        assert!((500_000..1_500_000).contains(&small), "small={small}");
    }

    #[test]
    #[should_panic]
    fn unknown_preset_panics() {
        let _ = ModelConfig::named("nope");
    }
}
