//! The layer-wise PTQ pipeline coordinator — the L3 system that drives
//! everything (paper §3.1 "End-to-end layer-wise procedure").
//!
//! For each transformer block, in network order:
//!
//! 1. run the calibration set through the **full-precision** model once,
//!    capturing the inputs `X` of all four tap points;
//! 2. for each linear group (`[Q K V] → [O] → [Gate Up] → [Down]`):
//!    re-run the **partially quantized** model to capture the *runtime*
//!    inputs `X̃` (upstream layers — including earlier groups of the same
//!    block — already quantized), then quantize every linear in the
//!    group with the configured solver and splice the dequantized weight
//!    back into the running model.
//!
//! This is exactly the error-propagation regime the JTA objective is
//! designed for: `X̃` drifts from `X` as quantization progresses, and μ
//! controls which reference the layer aligns to.

use crate::config::ModelConfig;
use crate::data::Corpus;
use crate::model::{LinearId, LinearKind, Model, TapPoint, TapSet};
use crate::quant::{quantize_layer, LayerStats, Method, QuantConfig};
use crate::rng::Rng;
use crate::runtime::SolverRuntime;
use crate::tensor::Matrix;

/// Per-layer record in the pipeline report.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub id: LinearId,
    pub stats: LayerStats,
    /// Packed size of the quantized layer (bytes).
    pub packed_bytes: usize,
    /// FP32 size (bytes).
    pub fp_bytes: usize,
}

/// Result of a full pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub layers: Vec<LayerRecord>,
    pub total_secs: f64,
    pub method: String,
}

impl PipelineReport {
    /// Overall compression ratio (fp bytes / packed bytes).
    pub fn compression_ratio(&self) -> f64 {
        let fp: usize = self.layers.iter().map(|l| l.fp_bytes).sum();
        let packed: usize = self.layers.iter().map(|l| l.packed_bytes).sum();
        fp as f64 / packed.max(1) as f64
    }

    /// Total solver seconds (excluding calibration forwards).
    pub fn solver_secs(&self) -> f64 {
        self.layers.iter().map(|l| l.stats.solve_secs).sum()
    }
}

/// The pipeline: owns the reference model, the progressively-quantized
/// model, and the calibration set.
pub struct Pipeline<'a> {
    fp_model: Model,
    quant_model: Model,
    calib: Vec<Vec<u16>>,
    method: Method,
    cfg: QuantConfig,
    rt: Option<&'a SolverRuntime>,
    /// Progress callback (layer id, stats) for streaming metrics.
    pub on_layer: Option<Box<dyn FnMut(LinearId, &LayerStats) + 'a>>,
}

impl<'a> Pipeline<'a> {
    pub fn new(
        model: Model,
        calib: Vec<Vec<u16>>,
        method: Method,
        cfg: QuantConfig,
        rt: Option<&'a SolverRuntime>,
    ) -> Pipeline<'a> {
        assert!(!calib.is_empty(), "empty calibration set");
        Pipeline { quant_model: model.clone(), fp_model: model, calib, method, cfg, rt, on_layer: None }
    }

    /// Run the calibration set through `model`, capturing `points` of
    /// `block`. Only blocks `0..=block` are computed.
    fn capture(model: &Model, calib: &[Vec<u16>], block: usize, points: &[TapPoint]) -> TapSet {
        let mut taps = TapSet::request(block, points);
        for seq in calib {
            model.forward_prefix_taps(seq, &mut taps, block);
        }
        taps
    }

    /// Execute the pipeline; returns the quantized model and report.
    pub fn run(mut self) -> anyhow::Result<(Model, PipelineReport)> {
        let t0 = std::time::Instant::now();
        let mut report =
            PipelineReport { method: self.method.label().to_string(), ..Default::default() };
        if self.method == Method::Fp {
            report.total_secs = t0.elapsed().as_secs_f64();
            return Ok((self.quant_model, report));
        }
        let n_blocks = self.fp_model.blocks.len();
        // Linear groups sharing a tap point, in dataflow order.
        let groups: [(&[LinearKind], TapPoint); 4] = [
            (&[LinearKind::Q, LinearKind::K, LinearKind::V], TapPoint::AttnIn),
            (&[LinearKind::O], TapPoint::OIn),
            (&[LinearKind::Gate, LinearKind::Up], TapPoint::MlpIn),
            (&[LinearKind::Down], TapPoint::DownIn),
        ];
        for block in 0..n_blocks {
            // One FP capture of all tap points for this block.
            let mut fp_taps = Self::capture(
                &self.fp_model,
                &self.calib,
                block,
                &[TapPoint::AttnIn, TapPoint::OIn, TapPoint::MlpIn, TapPoint::DownIn],
            );
            let mut fp_x: std::collections::HashMap<TapPoint, Matrix> = Default::default();
            for p in [TapPoint::AttnIn, TapPoint::OIn, TapPoint::MlpIn, TapPoint::DownIn] {
                fp_x.insert(p, fp_taps.take(block, p).expect("fp tap missing"));
            }
            for (kinds, point) in groups.iter() {
                // Runtime capture reflects all quantization done so far.
                let mut rt_taps = Self::capture(&self.quant_model, &self.calib, block, &[*point]);
                let x_rt = rt_taps.take(block, *point).expect("rt tap missing");
                let x_fp = &fp_x[point];
                for &kind in kinds.iter() {
                    let id = LinearId { block, kind };
                    let w = self.fp_model.linear(id).clone();
                    let layer_uid = (block * 8 + layer_index(kind)) as u64;
                    // Per-layer μ schedule (paper Limitations / future
                    // work): resolve the depth-interpolated μ here so
                    // every solver sees a plain fixed-μ config.
                    let mut layer_cfg = self.cfg.clone();
                    if let crate::quant::MuSchedule::DepthLinear { start, end } =
                        self.cfg.mu_schedule
                    {
                        let frac = if n_blocks > 1 {
                            block as f64 / (n_blocks - 1) as f64
                        } else {
                            0.0
                        };
                        layer_cfg.mu = (start + (end - start) * frac).clamp(0.0, 1.0);
                    }
                    let (q, stats) =
                        quantize_layer(self.method, &w, x_fp, &x_rt, &layer_cfg, layer_uid, self.rt)?;
                    if let Some(cb) = self.on_layer.as_mut() {
                        cb(id, &stats);
                    }
                    report.layers.push(LayerRecord {
                        id,
                        packed_bytes: q.packed_bytes(),
                        fp_bytes: w.len() * 4,
                        stats,
                    });
                    self.quant_model.set_linear(id, q.dequantize());
                }
            }
        }
        report.total_secs = t0.elapsed().as_secs_f64();
        Ok((self.quant_model, report))
    }
}

fn layer_index(kind: LinearKind) -> usize {
    LinearKind::all().iter().position(|&k| k == kind).unwrap()
}

/// Convenience wrapper: quantize `model` with `method` using `n_calib`
/// sequences of `seq_len` drawn from the corpus train split.
pub fn quantize_model(
    model: &Model,
    corpus: &Corpus,
    method: Method,
    cfg: &QuantConfig,
    n_calib: usize,
    seq_len: usize,
    rt: Option<&SolverRuntime>,
) -> anyhow::Result<(Model, PipelineReport)> {
    let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
    let calib = corpus.calibration(n_calib, seq_len.min(model.cfg.max_seq), &mut rng);
    Pipeline::new(model.clone(), calib, method, cfg.clone(), rt).run()
}

/// Standard experiment setup: model + paired corpora (in-domain "C4" and
/// shifted "WikiText-2" analogue), built either from `artifacts/` or, if
/// unavailable, from a random-initialized fallback (clearly labeled).
pub struct Workbench {
    pub model: Model,
    pub corpus: Corpus,
    pub shifted: Corpus,
    pub trained: bool,
}

impl Workbench {
    /// Load the pretrained model + corpus for `name` from `dir`, falling
    /// back to a random model over a synthetic corpus when artifacts are
    /// absent (unit tests, solver-only benches).
    pub fn load(dir: &std::path::Path, name: &str) -> Workbench {
        let model_path = dir.join(format!("model_{name}.bin"));
        let corpus_path = dir.join(format!("corpus_{name}.bin"));
        if let (Ok(model), Ok(corpus)) =
            (crate::model::load_model(&model_path, name), crate::data::load_corpus(&corpus_path))
        {
            // Preferred shifted corpus: the pretrain-exported twin that
            // shares the grammar but differs in style/noise (the
            // "WikiText-2" role). Falls back to a synthetic one.
            let shifted_path = dir.join(format!("corpus_shifted_{name}.bin"));
            let shifted = crate::data::load_corpus(&shifted_path)
                .unwrap_or_else(|_| Self::shifted_corpus(corpus.vocab_size));
            return Workbench { model, corpus, shifted, trained: true };
        }
        let cfg = ModelConfig::named(name);
        let mut rng = Rng::new(0xFA11BACC);
        let model = Model::random(cfg.clone(), &mut rng);
        let corpus =
            crate::data::SyntheticGrammar::new(cfg.vocab_size, 0.2, 42).corpus(60_000, &mut rng);
        let shifted = Self::shifted_corpus(cfg.vocab_size);
        Workbench { model, corpus, shifted, trained: false }
    }

    /// The "WikiText-2" role: same grammar family, different seed and
    /// more noise (out-of-domain but same token space).
    fn shifted_corpus(vocab: usize) -> Corpus {
        let mut rng = Rng::new(0x51F7ED);
        crate::data::SyntheticGrammar::new(vocab, 0.35, 1337).corpus(20_000, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGrammar;

    fn setup() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
        };
        let mut rng = Rng::new(1);
        (
            Model::random(cfg, &mut rng),
            SyntheticGrammar::new(32, 0.2, 3).corpus(6_000, &mut rng),
        )
    }

    #[test]
    fn pipeline_quantizes_every_linear() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, ..Default::default() };
        let (qm, report) =
            quantize_model(&model, &corpus, Method::Rtn, &cfg, 4, 24, None).unwrap();
        assert_eq!(report.layers.len(), 2 * 7);
        // Quantized model differs from FP but is finite.
        for id in qm.linear_ids() {
            assert!(qm.linear(id).all_finite());
        }
        // d=16 with group_size=8 carries heavy scale tables relative to
        // codes; ratio ≈ 4 here (realistic layers reach 6-8x, tested in
        // qtensor.rs).
        assert!(report.compression_ratio() > 3.0, "ratio={}", report.compression_ratio());
    }

    #[test]
    fn fp_method_is_identity() {
        let (model, corpus) = setup();
        let cfg = QuantConfig::default();
        let (qm, report) =
            quantize_model(&model, &corpus, Method::Fp, &cfg, 2, 16, None).unwrap();
        assert!(report.layers.is_empty());
        let toks: Vec<u16> = vec![1, 5, 9];
        assert!(qm.forward(&toks).rel_err(&model.forward(&toks)) < 1e-12);
    }

    #[test]
    fn ojbkq_pipeline_beats_rtn_pipeline_on_layer_error() {
        let (model, corpus) = setup();
        let cfg = QuantConfig {
            wbit: 3,
            group_size: 8,
            k: 4,
            ntile: 16,
            mu: 0.5,
            lambda: 0.3,
            ..Default::default()
        };
        let (_, rep_ours) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 4, 24, None).unwrap();
        let (_, rep_rtn) =
            quantize_model(&model, &corpus, Method::Rtn, &cfg, 4, 24, None).unwrap();
        let sum_ours: f64 = rep_ours.layers.iter().map(|l| l.stats.rt_err).sum();
        let sum_rtn: f64 = rep_rtn.layers.iter().map(|l| l.stats.rt_err).sum();
        assert!(sum_ours < sum_rtn, "ours {sum_ours} vs rtn {sum_rtn}");
    }

    #[test]
    fn deterministic_pipeline() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 8, ..Default::default() };
        let (qa, _) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
        let (qb, _) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
        let toks: Vec<u16> = vec![2, 4, 6, 8];
        assert!(qa.forward(&toks).rel_err(&qb.forward(&toks)) < 1e-12);
    }

    #[test]
    fn on_layer_callback_streams() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let mut rng = Rng::new(5);
        let calib = corpus.calibration(2, 16, &mut rng);
        let mut seen = Vec::new();
        {
            let mut p = Pipeline::new(model, calib, Method::Rtn, cfg, None);
            p.on_layer = Some(Box::new(|id, _| seen.push(id)));
            let _ = p.run().unwrap();
        }
        assert_eq!(seen.len(), 14);
        assert_eq!(seen[0], LinearId { block: 0, kind: LinearKind::Q });
    }

    #[test]
    fn workbench_fallback_is_usable() {
        let wb = Workbench::load(std::path::Path::new("/nonexistent"), "tiny-0.2M");
        assert!(!wb.trained);
        assert!(wb.corpus.train().len() > 1_000);
        assert_eq!(wb.model.cfg.vocab_size, wb.corpus.vocab_size);
    }
}
