//! The layer-wise PTQ pipeline coordinator — the L3 system that drives
//! everything (paper §3.1 "End-to-end layer-wise procedure"), built on a
//! **streaming activation-propagation engine** that executes the
//! progressively-quantized model through the **packed integer kernels**
//! of [`crate::infer`].
//!
//! The paper's procedure needs two activation views per linear group: the
//! full-precision inputs `X` and the runtime inputs `X̃` from the
//! partially-quantized prefix. The naive realization (re-running every
//! calibration sequence from block 0 for each of the four groups of each
//! block) costs O(n_blocks²·calib) forwards and dominates wall-clock. The
//! streaming engine instead keeps **paired hidden-state caches** — one FP
//! and one runtime matrix per calibration sequence — and advances each
//! exactly once per block:
//!
//! 1. one FP [`Model::block_step`] per sequence captures all four
//!    reference taps (`X`) of the block and advances the FP cache;
//! 2. the runtime taps (`X̃`) are produced by recomputing only the
//!    *intra-block* stage invalidated by the previous group's weight
//!    splice — `AttnIn` is a norm of the resident state, `OIn` re-runs
//!    attention with the freshly spliced Q/K/V, `MlpIn` applies the
//!    attention residual + norm, `DownIn` the SwiGLU with the spliced
//!    Gate/Up — never re-touching blocks `< block`;
//! 3. after the `Down` splice the runtime cache advances via the MLP
//!    residual, completing that cache's single step for the block.
//!
//! The runtime cache lives in a [`QuantizedModel`]: each solved layer is
//! converted once into a [`PackedLinear`] and spliced as bit-packed
//! integer codes (4–8× less resident memory than the dense f32 splice it
//! replaces), so calibration exercises the same kernels as deployment —
//! `quantize → capture → eval` never calls `dequantize()` on the hot
//! path. `QuantConfig::packed_exec = false` restores the dense f32
//! splice, which is numerically bit-identical to the pre-packed engine
//! (used by the capture-equivalence tests and the dense CI leg).
//!
//! At the **QEP corner** (`μ=0, λ=0` — [`Method::Qep`], or OJBKQ
//! configured onto it) the FP tap cache is skipped entirely and the
//! runtime taps stand in for the reference
//! ([`crate::quant::skip_fp_reference`]), halving capture cost to
//! `n_blocks·n_calib` block advances.
//!
//! Summed over a block, the runtime refreshes cost exactly one block
//! forward, so calibration is **linear in depth**: `2·n_blocks·n_calib`
//! block advances total (`n_blocks·n_calib` under the QEP skip), tracked
//! in [`PipelineReport::capture_block_steps`].
//!
//! Both caches live **vstacked** in a [`RowBatch`] (one tall matrix +
//! per-sequence row offsets), and every capture site is **batch-fused**:
//! each linear stage runs as ONE tall GEMM over the stacked cache
//! (`attn_in_batch` → `attn_ctx_batch` → … → `post_mlp_batch`), so the
//! stage's weight matrix is streamed from memory once per *stage* rather
//! than once per *sequence*, and the captured `X` / `X̃` matrices fall
//! out of the stage outputs directly — no per-sequence stacking step.
//! Only the causal softmax core runs per sequence (dynamically scheduled
//! over the ragged row ranges). The batched stages are bit-identical to
//! per-sequence stepping (each output row is computed by the same kernel
//! over the same operands), so the pipeline stays bit-exactly
//! deterministic — pinned by `tests/batched_capture.rs`.
//!
//! [`CaptureMode::Reforward`] retains the legacy O(n_blocks²) prefix
//! re-forward path over a dense spliced [`Model`] mirror — used by
//! equivalence tests and the Figure-4 speedup bench, never by the
//! default pipeline.
//!
//! This is exactly the error-propagation regime the JTA objective is
//! designed for: `X̃` drifts from `X` as quantization progresses, and μ
//! controls which reference the layer aligns to.
//!
//! [`Model::block_step`]: crate::model::Model::block_step

use crate::config::ModelConfig;
use crate::data::Corpus;
use crate::infer::{PackedLinear, QuantizedModel};
use crate::model::{LinearId, LinearKind, Model, TapPoint, TapSet};
use crate::parallel::parallel_map;
use crate::quant::{
    quantize_layer_shared, skip_fp_reference, FactoredSystem, LayerStats, Method, QuantConfig,
};
use crate::rng::Rng;
use crate::robust::{self, FaultKind, RobustError, RunManifest};
use crate::runtime::SolverRuntime;
use crate::tensor::{Matrix, RowBatch};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Per-layer record in the pipeline report.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub id: LinearId,
    pub stats: LayerStats,
    /// Serialized (shipped) size of the quantized layer: codes at `wbit`
    /// bits + f16-equivalent tables (bytes).
    pub packed_bytes: usize,
    /// FP32 size (bytes).
    pub fp_bytes: usize,
    /// Resident size inside the packed execution engine
    /// ([`PackedLinear::bytes`]): bit-packed codes + f32 tables, or the
    /// dense fallback (bytes).
    pub resident_bytes: usize,
}

/// Result of a full pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub layers: Vec<LayerRecord>,
    pub total_secs: f64,
    /// Wall-clock seconds spent producing calibration activations
    /// (embedding, block advances and intra-block tap refreshes).
    pub capture_secs: f64,
    /// Number of transformer-block advances performed for calibration —
    /// `2·n_blocks·n_calib` under streaming capture (`n_blocks·n_calib`
    /// when the QEP corner skips the FP cache), quadratic in depth under
    /// [`CaptureMode::Reforward`].
    pub capture_block_steps: u64,
    pub method: String,
    /// On-disk size of the OJBQ1 checkpoint written for this run
    /// (`quantize --out`), filled in by the caller after
    /// [`crate::infer::save_quantized`]; `None` when nothing was written.
    /// The checkpoint's weight payload equals
    /// [`PipelineReport::packed_weight_bytes`] by construction
    /// (`bytes()`-consistent accounting, pinned by
    /// `rust/tests/packed_checkpoint.rs`).
    pub artifact_bytes: Option<u64>,
}

impl PipelineReport {
    /// Shipped compression ratio (fp bytes / serialized packed bytes).
    pub fn compression_ratio(&self) -> f64 {
        let fp: usize = self.layers.iter().map(|l| l.fp_bytes).sum();
        let packed: usize = self.layers.iter().map(|l| l.packed_bytes).sum();
        fp as f64 / packed.max(1) as f64
    }

    /// Resident weight bytes of the execution engine across all
    /// quantized layers (matches
    /// [`QuantizedModel::packed_weight_bytes`] for the returned model).
    pub fn packed_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.resident_bytes).sum()
    }

    /// f32 bytes of the same layers in dense form.
    pub fn fp_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.fp_bytes).sum()
    }

    /// Resident compression of the execution engine (f32 bytes / resident
    /// packed bytes) — the memory the serving process actually saves.
    pub fn resident_compression(&self) -> f64 {
        self.fp_weight_bytes() as f64 / self.packed_weight_bytes().max(1) as f64
    }

    /// Total solver seconds (excluding calibration captures).
    pub fn solver_secs(&self) -> f64 {
        self.layers.iter().map(|l| l.stats.solve_secs).sum()
    }

    /// Per-layer residual table — the Fig.-1-style quality breakdown
    /// that replaces the old single-scalar summary: runtime/JTA errors,
    /// relative error, decode residual, Klein improvement rate, clip
    /// rate, and code occupancy for every quantized linear.
    pub fn layer_table(&self) -> crate::report::Table {
        let mut t = crate::report::Table::new(
            "Per-layer quantization quality",
            &[
                "layer",
                "rt err",
                "jta err",
                "rel err",
                "decode resid",
                "klein impr %",
                "clip %",
                "occupancy",
                "solve s",
            ],
        );
        for l in &self.layers {
            let s = &l.stats;
            let rel = if s.out_norm > 0.0 { s.rt_err / s.out_norm } else { 0.0 };
            t.push_row(&[
                l.id.to_string(),
                format!("{:.4}", s.rt_err),
                format!("{:.4}", s.jta_err),
                format!("{:.5}", rel),
                format!("{:.4}", s.decode_resid),
                format!("{:.1}", 100.0 * s.klein_improvement_rate()),
                format!("{:.2}", 100.0 * s.clip_rate),
                format!("{:.3}", s.occupancy),
                format!("{:.3}", s.solve_secs),
            ]);
        }
        t
    }

    /// Per-layer metric records for `trace.json`
    /// ([`crate::report::RunTrace::layers`]); keys come from
    /// [`crate::obs::LAYER_METRIC_NAMES`].
    pub fn trace_layers(&self) -> Vec<crate::report::LayerTraceRow> {
        self.layers
            .iter()
            .map(|l| {
                let s = &l.stats;
                crate::report::LayerTraceRow {
                    id: l.id.to_string(),
                    metrics: vec![
                        ("rt_err".into(), s.rt_err),
                        ("jta_err".into(), s.jta_err),
                        ("out_norm".into(), s.out_norm),
                        ("decode_resid".into(), s.decode_resid),
                        ("greedy_resid".into(), s.greedy_resid),
                        ("cols".into(), s.cols as f64),
                        ("klein_samples".into(), s.klein_samples as f64),
                        ("klein_improved".into(), s.klein_improved as f64),
                        ("clip_rate".into(), s.clip_rate),
                        ("occupancy".into(), s.occupancy),
                        ("solve_secs".into(), s.solve_secs),
                        ("capture_secs".into(), s.capture_secs),
                        ("packed_bytes".into(), l.packed_bytes as f64),
                        ("fp_bytes".into(), l.fp_bytes as f64),
                        ("fallback".into(), if s.fallback { 1.0 } else { 0.0 }),
                    ],
                }
            })
            .collect()
    }
}

/// Span name for a tap-point group (member of
/// [`crate::obs::SPAN_NAMES`]).
fn tap_span(p: TapPoint) -> &'static str {
    match p {
        TapPoint::AttnIn => "attn_in",
        TapPoint::OIn => "o_in",
        TapPoint::MlpIn => "mlp_in",
        TapPoint::DownIn => "down_in",
    }
}

/// How the pipeline obtains calibration activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CaptureMode {
    /// Streaming activation propagation: paired resident hidden-state
    /// caches advanced once per block (default; linear in depth).
    Streaming,
    /// Legacy prefix re-forwards from block 0 for every capture
    /// (quadratic in depth), over a dense spliced model mirror. Kept for
    /// equivalence tests and benches.
    Reforward,
}

/// Linear groups sharing a tap point, in dataflow order.
const GROUPS: [(&[LinearKind], TapPoint); 4] = [
    (&[LinearKind::Q, LinearKind::K, LinearKind::V], TapPoint::AttnIn),
    (&[LinearKind::O], TapPoint::OIn),
    (&[LinearKind::Gate, LinearKind::Up], TapPoint::MlpIn),
    (&[LinearKind::Down], TapPoint::DownIn),
];

/// Human-readable locator for a tap group (used by [`RobustError`]
/// context): tap point + member layers.
fn group_desc(kinds: &[LinearKind]) -> String {
    let tap = GROUPS
        .iter()
        .find(|(k, _)| *k == kinds)
        .map(|(_, p)| format!("{p:?}"))
        .unwrap_or_else(|| "?".into());
    let names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
    format!("tap {tap}, layers {}", names.join("/"))
}

/// First non-finite entry of `m` as `(row, col)`, if any.
fn non_finite_pos(m: &Matrix) -> Option<(usize, usize)> {
    m.as_slice()
        .iter()
        .position(|v| !v.is_finite())
        .map(|i| (i / m.cols(), i % m.cols()))
}

/// The pipeline: borrows the reference model, owns the progressively
/// quantized packed-execution model, the calibration set, and the paired
/// FP / runtime hidden-state caches — each a [`RowBatch`] vstacking all
/// calibration sequences so stages run batch-fused.
pub struct Pipeline<'a> {
    fp_model: &'a Model,
    /// Packed execution engine holding every quantized layer so far
    /// (dense passthrough for not-yet-quantized layers). Advances the
    /// runtime hidden-state cache and is returned to the caller.
    runtime: QuantizedModel,
    /// Dense f32 mirror, spliced in lockstep — only materialized under
    /// [`CaptureMode::Reforward`], whose prefix re-forwards need a
    /// [`Model`].
    dense_runtime: Option<Model>,
    calib: Vec<Vec<u16>>,
    method: Method,
    cfg: QuantConfig,
    rt: Option<&'a SolverRuntime>,
    capture_mode: CaptureMode,
    /// The QEP-corner capture optimization (see
    /// [`crate::quant::skip_fp_reference`]).
    skip_fp: bool,
    /// FP hidden states at the entry of the current block, vstacked with
    /// per-sequence row offsets (`None` when `skip_fp` or before embed).
    fp_batch: Option<RowBatch>,
    /// Runtime (partially-quantized) hidden states at the same position,
    /// same stacked layout.
    rt_batch: Option<RowBatch>,
    /// Progress callback (layer id, stats) for streaming metrics.
    pub on_layer: Option<Box<dyn FnMut(LinearId, &LayerStats) + 'a>>,
}

impl<'a> Pipeline<'a> {
    /// Build a pipeline. Borrows `model` as the FP reference; the packed
    /// working copy starts as an all-dense passthrough engine.
    pub fn new(
        model: &'a Model,
        calib: Vec<Vec<u16>>,
        method: Method,
        cfg: QuantConfig,
        rt: Option<&'a SolverRuntime>,
    ) -> Pipeline<'a> {
        assert!(!calib.is_empty(), "empty calibration set");
        let skip_fp = skip_fp_reference(method, &cfg);
        Pipeline {
            fp_model: model,
            runtime: QuantizedModel::from_model(model),
            dense_runtime: None,
            calib,
            method,
            cfg,
            rt,
            capture_mode: CaptureMode::Streaming,
            skip_fp,
            fp_batch: None,
            rt_batch: None,
            on_layer: None,
        }
    }

    /// Select the capture strategy (default: [`CaptureMode::Streaming`]).
    pub fn with_capture_mode(mut self, mode: CaptureMode) -> Pipeline<'a> {
        self.capture_mode = mode;
        self
    }

    /// Legacy capture: run the calibration set through `model` from the
    /// embedding, capturing `points` of `block`. Only blocks `0..=block`
    /// are computed. `CaptureMode::Reforward` only.
    fn capture(model: &Model, calib: &[Vec<u16>], block: usize, points: &[TapPoint]) -> TapSet {
        let mut taps = TapSet::request(block, points);
        for seq in calib {
            model.forward_prefix_taps(seq, &mut taps, block);
        }
        taps
    }

    /// Execute the pipeline; returns the packed quantized model and the
    /// report.
    pub fn run(self) -> anyhow::Result<(QuantizedModel, PipelineReport)> {
        self.run_with(None)
    }

    /// [`Pipeline::run`] with optional crash-safe checkpointing: after
    /// each completed block the [`Checkpointer`] persists that block's
    /// packed layers + the run manifest, and blocks already recorded as
    /// completed are *replayed* (durable layers spliced in, caches
    /// advanced through the same batch-fused stages) instead of
    /// re-solved — so a resumed run is bit-identical to an
    /// uninterrupted one (pinned by `tests/fault_recovery.rs`).
    pub fn run_with(
        mut self,
        mut ckpt: Option<&mut Checkpointer>,
    ) -> anyhow::Result<(QuantizedModel, PipelineReport)> {
        let _pipeline_span = crate::obs::span("pipeline");
        let t0 = Instant::now();
        let mut report =
            PipelineReport { method: self.method.label().to_string(), ..Default::default() };
        if self.method == Method::Fp {
            report.total_secs = t0.elapsed().as_secs_f64();
            return Ok((self.runtime, report));
        }
        let n_blocks = self.fp_model.blocks.len();
        match self.capture_mode {
            CaptureMode::Streaming => {
                // Embed every calibration sequence once and vstack into
                // the resident batch caches, which then advance exactly
                // once per block — each linear stage as one tall GEMM.
                // Quantization never touches the embedding, so the
                // runtime cache starts as an exact copy of the FP cache
                // (which is skipped entirely at the QEP corner).
                let model = self.fp_model;
                let calib = &self.calib;
                let skip_fp = self.skip_fp;
                let ((bad, rt_batch, fp_batch), secs) = crate::obs::timed("embed", || {
                    let parts = parallel_map(calib.len(), |i| model.embed_sequence(&calib[i]));
                    // Ingest guard: locate any non-finite activation now,
                    // while the per-sequence (row = position) structure
                    // still exists, instead of letting NaN spread through
                    // every downstream Gram.
                    let bad = parts
                        .iter()
                        .enumerate()
                        .find_map(|(i, m)| non_finite_pos(m).map(|(r, c)| (i, r, c)));
                    let rt = RowBatch::stack(&parts);
                    let fp = if skip_fp { None } else { Some(rt.clone()) };
                    (bad, rt, fp)
                });
                report.capture_secs += secs;
                if let Some((seq, pos, dim)) = bad {
                    return Err(RobustError::new(
                        "coordinator.capture",
                        "non-finite calibration activation at ingest",
                    )
                    .with_context(format!(
                        "calib sequence {seq}, position {pos}, dim {dim} (token {})",
                        self.calib[seq][pos]
                    ))
                    .into());
                }
                self.rt_batch = Some(rt_batch);
                self.fp_batch = fp_batch;
            }
            CaptureMode::Reforward => {
                assert!(ckpt.is_none(), "checkpointed runs require streaming capture");
                self.dense_runtime = Some(self.fp_model.clone());
            }
        }
        for block in 0..n_blocks {
            if let Some(ck) = ckpt.as_deref_mut() {
                if block < ck.completed() {
                    self.replay_block_streaming(block, ck, &mut report)?;
                    continue;
                }
            }
            match self.capture_mode {
                CaptureMode::Streaming => self.run_block_streaming(block, n_blocks, &mut report)?,
                CaptureMode::Reforward => self.run_block_reforward(block, n_blocks, &mut report)?,
            }
            if let Some(ck) = ckpt.as_deref_mut() {
                ck.record_block(&self.runtime, block)?;
            }
        }
        report.total_secs = t0.elapsed().as_secs_f64();
        Ok((self.runtime, report))
    }

    /// Advance the FP cache one block as a single batch-fused
    /// [`Model::block_step_batch`] (one tall GEMM per linear stage),
    /// returning the four stacked reference tap matrices.
    fn step_fp(
        &mut self,
        block: usize,
        report: &mut PipelineReport,
    ) -> HashMap<TapPoint, Matrix> {
        let model = self.fp_model;
        let fp_batch = &mut self.fp_batch;
        let (out, secs) = crate::obs::timed("fp_step", || {
            let mut taps = TapSet::request(block, &TapPoint::all());
            let batch = fp_batch.as_mut().expect("fp cache initialized");
            model.block_step_batch(batch, block, &mut taps);
            let mut out = HashMap::new();
            for p in TapPoint::all() {
                out.insert(p, taps.take(block, p).expect("fp tap missing"));
            }
            out
        });
        report.capture_block_steps += self.calib.len() as u64;
        crate::obs::counter_add("capture.block_steps", self.calib.len() as u64);
        report.capture_secs += secs;
        out
    }

    /// Quantize one block under streaming capture: a single batch-fused
    /// FP cache advance (unless the QEP corner skips it), four
    /// intra-block runtime refreshes through the packed engine (one per
    /// group, each recomputing only the stage invalidated by the previous
    /// splice — each one tall kernel call over the stacked cache), and a
    /// single runtime cache advance. The stage outputs *are* the stacked
    /// `X̃` capture matrices.
    fn run_block_streaming(
        &mut self,
        block: usize,
        n_blocks: usize,
        report: &mut PipelineReport,
    ) -> anyhow::Result<()> {
        let fp_x: Option<HashMap<TapPoint, Matrix>> =
            if self.skip_fp { None } else { Some(self.step_fp(block, report)) };

        // Group [Q K V]: AttnIn is a norm of the resident runtime stack —
        // no upstream weights of this block are involved.
        let g = crate::obs::span(tap_span(TapPoint::AttnIn));
        let (attn_in, cap) = crate::obs::timed("capture", || {
            self.runtime.attn_in_batch(self.rt_batch.as_ref().expect("rt cache").data(), block)
        });
        report.capture_secs += cap;
        let x_fp = fp_x.as_ref().map_or(&attn_in, |m| &m[&TapPoint::AttnIn]);
        self.quantize_group(report, block, n_blocks, GROUPS[0].0, x_fp, &attn_in, cap)?;
        drop(g);

        // Group [O]: tall Q/K/V GEMMs with the freshly spliced weights +
        // per-sequence attention cores over the batch offsets.
        let g = crate::obs::span(tap_span(TapPoint::OIn));
        let (ctx, cap) = crate::obs::timed("capture", || {
            self.runtime.attn_ctx_batch(
                &attn_in,
                self.rt_batch.as_ref().expect("rt cache").offsets(),
                block,
            )
        });
        report.capture_secs += cap;
        let x_fp = fp_x.as_ref().map_or(&ctx, |m| &m[&TapPoint::OIn]);
        self.quantize_group(report, block, n_blocks, GROUPS[1].0, x_fp, &ctx, cap)?;
        drop(g);

        // Group [Gate Up]: attention residual + MLP norm after the O
        // splice.
        let g = crate::obs::span(tap_span(TapPoint::MlpIn));
        let ((x_mid, mlp_in), cap) = crate::obs::timed("capture", || {
            let x_mid = self.runtime.post_attn_batch(
                self.rt_batch.as_ref().expect("rt cache").data(),
                &ctx,
                block,
            );
            let mlp_in = self.runtime.mlp_in_batch(&x_mid, block);
            (x_mid, mlp_in)
        });
        report.capture_secs += cap;
        let x_fp = fp_x.as_ref().map_or(&mlp_in, |m| &m[&TapPoint::MlpIn]);
        self.quantize_group(report, block, n_blocks, GROUPS[2].0, x_fp, &mlp_in, cap)?;
        drop(g);

        // Group [Down]: SwiGLU with the spliced Gate/Up — one tall Gate
        // GEMM + one tall Up GEMM.
        let g = crate::obs::span(tap_span(TapPoint::DownIn));
        let (act, cap) = crate::obs::timed("capture", || self.runtime.mlp_act_batch(&mlp_in, block));
        report.capture_secs += cap;
        let x_fp = fp_x.as_ref().map_or(&act, |m| &m[&TapPoint::DownIn]);
        self.quantize_group(report, block, n_blocks, GROUPS[3].0, x_fp, &act, cap)?;
        drop(g);

        // Advance the runtime cache through the MLP residual with the
        // spliced Down — completing this cache's single step for the
        // block. Blocks `< block` are never touched again.
        if let Some(k) = robust::fault_point("coordinator.advance") {
            return Err(RobustError::new(
                "coordinator.advance",
                format!("injected {} fault", k.label()),
            )
            .with_block(block)
            .into());
        }
        let (new_data, secs) =
            crate::obs::timed("advance", || self.runtime.post_mlp_batch(&x_mid, &act, block));
        self.rt_batch.as_mut().expect("rt cache").set_data(new_data);
        report.capture_block_steps += self.calib.len() as u64;
        crate::obs::counter_add("capture.block_steps", self.calib.len() as u64);
        report.capture_secs += secs;
        Ok(())
    }

    /// Re-drive one already-completed block during a resume: splice the
    /// durable packed layers from its segment, then advance both caches
    /// through exactly the same batch-fused stage calls as the original
    /// run — the cache trajectory (and therefore every later block's
    /// capture) is bit-identical to the uninterrupted run. Nothing is
    /// solved, so replayed blocks add no [`LayerRecord`]s.
    fn replay_block_streaming(
        &mut self,
        block: usize,
        ckpt: &Checkpointer,
        report: &mut PipelineReport,
    ) -> anyhow::Result<()> {
        let linears = ckpt.load_block(&self.fp_model.cfg, block)?;
        for (&kind, lin) in LinearKind::all().iter().zip(linears) {
            self.runtime.set_layer(LinearId { block, kind }, lin);
        }
        if !self.skip_fp {
            let _ = self.step_fp(block, report);
        }
        let (new_data, secs) = crate::obs::timed("capture", || {
            let rt = self.rt_batch.as_ref().expect("rt cache");
            let attn_in = self.runtime.attn_in_batch(rt.data(), block);
            let ctx = self.runtime.attn_ctx_batch(&attn_in, rt.offsets(), block);
            let x_mid = self.runtime.post_attn_batch(rt.data(), &ctx, block);
            let mlp_in = self.runtime.mlp_in_batch(&x_mid, block);
            let act = self.runtime.mlp_act_batch(&mlp_in, block);
            self.runtime.post_mlp_batch(&x_mid, &act, block)
        });
        self.rt_batch.as_mut().expect("rt cache").set_data(new_data);
        report.capture_block_steps += self.calib.len() as u64;
        crate::obs::counter_add("capture.block_steps", self.calib.len() as u64);
        report.capture_secs += secs;
        Ok(())
    }

    /// Quantize one block under legacy prefix re-forward capture (dense
    /// spliced mirror).
    fn run_block_reforward(
        &mut self,
        block: usize,
        n_blocks: usize,
        report: &mut PipelineReport,
    ) -> anyhow::Result<()> {
        let n = self.calib.len() as u64;
        let fp_x: Option<HashMap<TapPoint, Matrix>> = if self.skip_fp {
            None
        } else {
            let fp_model = self.fp_model;
            let calib = &self.calib;
            let (mut fp_taps, secs) = crate::obs::timed("fp_step", || {
                Self::capture(fp_model, calib, block, &TapPoint::all())
            });
            report.capture_block_steps += n * (block as u64 + 1);
            crate::obs::counter_add("capture.block_steps", n * (block as u64 + 1));
            report.capture_secs += secs;
            let mut m: HashMap<TapPoint, Matrix> = HashMap::new();
            for p in TapPoint::all() {
                m.insert(p, fp_taps.take(block, p).expect("fp tap missing"));
            }
            Some(m)
        };
        for (kinds, point) in GROUPS.iter() {
            let _g = crate::obs::span(tap_span(*point));
            // Runtime capture reflects all quantization done so far.
            let (x_rt, cap) = crate::obs::timed("capture", || {
                let dense = self.dense_runtime.as_ref().expect("reforward dense mirror");
                let mut rt_taps = Self::capture(dense, &self.calib, block, &[*point]);
                rt_taps.take(block, *point).expect("rt tap missing")
            });
            report.capture_block_steps += n * (block as u64 + 1);
            crate::obs::counter_add("capture.block_steps", n * (block as u64 + 1));
            report.capture_secs += cap;
            let x_fp = fp_x.as_ref().map_or(&x_rt, |m| &m[point]);
            self.quantize_group(report, block, n_blocks, kinds, x_fp, &x_rt, cap)?;
        }
        Ok(())
    }

    /// Quantize every linear of one group against `(x_fp, x_rt)` and
    /// splice the packed execution form into the running engine (plus the
    /// dense mirror when re-forward capture needs one).
    ///
    /// The group is where factor sharing happens: every layer of the
    /// group consumes the same runtime taps, so the weight-independent
    /// factorization (Gram/Hessian, act-order permutation, Cholesky) is
    /// built ONCE here ([`FactoredSystem::for_method`]) and threaded
    /// through [`quantize_layer_shared`] — 3× less syrk+Cholesky work for
    /// Q/K/V, 2× for Gate/Up, bit-identical output either way.
    #[allow(clippy::too_many_arguments)]
    fn quantize_group(
        &mut self,
        report: &mut PipelineReport,
        block: usize,
        n_blocks: usize,
        kinds: &[LinearKind],
        x_fp: &Matrix,
        x_rt: &Matrix,
        capture_secs: f64,
    ) -> anyhow::Result<()> {
        let per_layer_capture = capture_secs / kinds.len() as f64;
        // Capture→factor boundary guard: an injected capture fault or
        // genuinely non-finite activations become a structured per-group
        // error here, before the Gram build can spread the poison into
        // every layer of the group.
        if let Some(k) = robust::fault_point("coordinator.capture") {
            return Err(RobustError::new(
                "coordinator.capture",
                format!("injected {} fault", k.label()),
            )
            .with_block(block)
            .with_context(group_desc(kinds))
            .into());
        }
        if !x_rt.all_finite() || !x_fp.all_finite() {
            return Err(RobustError::new(
                "coordinator.capture",
                "non-finite activations at capture→factor boundary",
            )
            .with_block(block)
            .with_context(group_desc(kinds))
            .into());
        }
        // Per-layer μ schedule (paper Limitations / future work): resolve
        // the depth-interpolated μ once per group (it varies only with
        // block depth) so every solver sees a plain fixed-μ config.
        let mut layer_cfg = self.cfg.clone();
        if let crate::quant::MuSchedule::DepthLinear { start, end } = self.cfg.mu_schedule {
            let frac = if n_blocks > 1 { block as f64 / (n_blocks - 1) as f64 } else { 0.0 };
            layer_cfg.mu = (start + (end - start) * frac).clamp(0.0, 1.0);
        }
        let method = self.method;
        let (shared, factor_secs) = crate::obs::timed("factor", || {
            if let Some(k) = robust::fault_point("coordinator.factor") {
                return Err(RobustError::new(
                    "coordinator.factor",
                    format!("injected {} fault", k.label()),
                )
                .with_block(block)
                .with_context(group_desc(kinds))
                .into());
            }
            FactoredSystem::for_method(method, x_rt, &layer_cfg)
        });
        // Degradation ladder, final rung: `cholesky_upper_jittered`
        // already escalates diagonal jitter deterministically inside the
        // factor build; if the factor still fails (ill-conditioned Gram,
        // or an injected factor fault), the group degrades per-layer to
        // RTN — which needs no factor — instead of aborting the run. The
        // event is recorded on every affected layer
        // ([`LayerStats::fallback`] → the `layer.fallback` trace metric).
        let (eff_method, shared, fallback) = match shared {
            Ok(s) => (method, s, false),
            Err(_) => (Method::Rtn, None, true),
        };
        // The shared factor build is solver work; attribute it evenly so
        // `PipelineReport::solver_secs` still accounts for all of it.
        let per_layer_factor = factor_secs / kinds.len() as f64;
        for &kind in kinds {
            let id = LinearId { block, kind };
            let mut w = self.fp_model.linear(id).clone();
            match robust::fault_point("coordinator.solve") {
                None => {}
                Some(FaultKind::Nan) => {
                    // Poison the working weight copy: the NaN flows
                    // through the real solver and must be caught by the
                    // solve→pack guard, exercising the genuine detection
                    // path end to end.
                    w.row_mut(0)[0] = f32::NAN;
                }
                Some(k) => {
                    return Err(RobustError::new(
                        "coordinator.solve",
                        format!("injected {} fault", k.label()),
                    )
                    .with_block(block)
                    .with_context(format!("layer {id}, {}", group_desc(kinds)))
                    .into());
                }
            }
            let layer_uid = (block * 8 + kind.index()) as u64;
            let (q, mut stats) = quantize_layer_shared(
                eff_method,
                &w,
                x_fp,
                x_rt,
                &layer_cfg,
                layer_uid,
                self.rt,
                shared.as_ref(),
            )
            .map_err(|e| e.context(format!("block {block}, layer {id}, {}", group_desc(kinds))))?;
            stats.capture_secs = per_layer_capture;
            stats.solve_secs += per_layer_factor;
            stats.fallback = fallback;
            if fallback && crate::obs::enabled() {
                crate::obs::hist_record("layer.fallback", 1.0);
            }
            if let Some(cb) = self.on_layer.as_mut() {
                cb(id, &stats);
            }
            let lin = crate::span!("pack", PackedLinear::from_quantized(&q, self.cfg.packed_exec));
            report.layers.push(LayerRecord {
                id,
                packed_bytes: q.packed_bytes(),
                fp_bytes: w.len() * 4,
                resident_bytes: lin.bytes(),
                stats,
            });
            if let Some(dense) = self.dense_runtime.as_mut() {
                dense.set_linear(id, q.dequantize());
            }
            self.runtime.set_layer(id, lin);
        }
        Ok(())
    }
}

/// Convenience wrapper: quantize `model` with `method` using `n_calib`
/// sequences of `seq_len` drawn from the corpus train split; returns the
/// packed execution model. The FP model is borrowed (never cloned).
pub fn quantize_model(
    model: &Model,
    corpus: &Corpus,
    method: Method,
    cfg: &QuantConfig,
    n_calib: usize,
    seq_len: usize,
    rt: Option<&SolverRuntime>,
) -> anyhow::Result<(QuantizedModel, PipelineReport)> {
    let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
    let calib = corpus.calibration(n_calib, seq_len.min(model.cfg.max_seq), &mut rng);
    Pipeline::new(model, calib, method, cfg.clone(), rt).run()
}

/// Fingerprint of everything besides the calibration tokens that
/// determines a checkpointed run's output: model shape, method, and the
/// full quantization config (all seeds included). Debug formatting is
/// the canonical serialization — any field change changes the hash, so
/// a stale parts directory can never be resumed under a different
/// configuration.
pub fn run_config_hash(
    mcfg: &ModelConfig,
    method: Method,
    cfg: &QuantConfig,
    n_calib: usize,
) -> u64 {
    let desc = format!("{mcfg:?}|{method:?}|{cfg:?}|n_calib={n_calib}");
    robust::fnv1a64(desc.as_bytes())
}

/// Crash-safe progress record of one quantization run: a parts
/// directory holding one packed segment per completed block plus the
/// `OJBM1` run manifest. Every write goes through
/// [`robust::atomic_write`] (temp file + rename) and the manifest's
/// completed count only advances *after* the block's segment is
/// durable, so a crash at any instant leaves a valid resumable prefix
/// — never a torn file.
pub struct Checkpointer {
    dir: PathBuf,
    manifest: RunManifest,
}

impl Checkpointer {
    /// Start a fresh checkpointed run in `dir` (manifest `completed=0`;
    /// stale segments from a previous run are simply overwritten).
    pub fn create(
        dir: &Path,
        config_hash: u64,
        calib_digest: u64,
        n_blocks: usize,
    ) -> anyhow::Result<Checkpointer> {
        let manifest = RunManifest { config_hash, calib_digest, n_blocks, completed: 0 };
        manifest.save(dir)?;
        Ok(Checkpointer { dir: dir.to_path_buf(), manifest })
    }

    /// Resume from `dir`, refusing a manifest whose identity (config
    /// hash, calibration digest, block count) doesn't match this run.
    pub fn resume(
        dir: &Path,
        config_hash: u64,
        calib_digest: u64,
        n_blocks: usize,
    ) -> anyhow::Result<Checkpointer> {
        let manifest = RunManifest::load(dir)?;
        manifest.verify(config_hash, calib_digest, n_blocks)?;
        Ok(Checkpointer { dir: dir.to_path_buf(), manifest })
    }

    /// Durable completed-block prefix: blocks `0..completed()` have
    /// committed segments on disk.
    pub fn completed(&self) -> usize {
        self.manifest.completed
    }

    fn segment_path(&self, block: usize) -> PathBuf {
        self.dir.join(format!("block_{block}.seg"))
    }

    /// Persist `block`'s seven packed layers, *then* advance the
    /// manifest — in that order, so `completed` never points past a
    /// durable segment.
    fn record_block(&mut self, qm: &QuantizedModel, block: usize) -> anyhow::Result<()> {
        crate::infer::save_block_segment(qm, block, &self.segment_path(block))?;
        self.manifest.completed = block + 1;
        self.manifest.save(&self.dir)
    }

    fn load_block(&self, cfg: &ModelConfig, block: usize) -> anyhow::Result<Vec<PackedLinear>> {
        crate::infer::load_block_segment(&self.segment_path(block), cfg, block)
    }
}

/// [`quantize_model`] with crash-safe checkpointing (`quantize --out` /
/// `--resume`): per-block packed segments and the run manifest land in
/// `parts_dir` as each block completes, and `resume = true` replays the
/// durable prefix of an interrupted run instead of recomputing it. The
/// resumed output is bit-identical to an uninterrupted run — the calib
/// sample and every solver RNG are keyed (not sequential), so skipping
/// completed blocks perturbs nothing downstream (pinned by
/// `tests/fault_recovery.rs`).
#[allow(clippy::too_many_arguments)]
pub fn quantize_model_checkpointed(
    model: &Model,
    corpus: &Corpus,
    method: Method,
    cfg: &QuantConfig,
    n_calib: usize,
    seq_len: usize,
    rt: Option<&SolverRuntime>,
    parts_dir: &Path,
    resume: bool,
) -> anyhow::Result<(QuantizedModel, PipelineReport)> {
    let mut rng = Rng::new(cfg.seed ^ 0xCA11B);
    let calib = corpus.calibration(n_calib, seq_len.min(model.cfg.max_seq), &mut rng);
    let config_hash = run_config_hash(&model.cfg, method, cfg, calib.len());
    let calib_digest = robust::digest_tokens(&calib);
    let n_blocks = model.blocks.len();
    let mut ck = if resume {
        Checkpointer::resume(parts_dir, config_hash, calib_digest, n_blocks)?
    } else {
        Checkpointer::create(parts_dir, config_hash, calib_digest, n_blocks)?
    };
    Pipeline::new(model, calib, method, cfg.clone(), rt).run_with(Some(&mut ck))
}

/// Standard experiment setup: model + paired corpora (in-domain "C4" and
/// shifted "WikiText-2" analogue), built either from `artifacts/` or, if
/// unavailable, from a random-initialized fallback (clearly labeled).
pub struct Workbench {
    pub model: Model,
    pub corpus: Corpus,
    pub shifted: Corpus,
    pub trained: bool,
}

impl Workbench {
    /// Load the pretrained model + corpus for `name` from `dir`, falling
    /// back to a random model over a synthetic corpus when artifacts are
    /// absent (unit tests, solver-only benches).
    pub fn load(dir: &std::path::Path, name: &str) -> Workbench {
        let model_path = dir.join(format!("model_{name}.bin"));
        let corpus_path = dir.join(format!("corpus_{name}.bin"));
        if let (Ok(model), Ok(corpus)) =
            (crate::model::load_model(&model_path, name), crate::data::load_corpus(&corpus_path))
        {
            // Preferred shifted corpus: the pretrain-exported twin that
            // shares the grammar but differs in style/noise (the
            // "WikiText-2" role). Falls back to a synthetic one.
            let shifted_path = dir.join(format!("corpus_shifted_{name}.bin"));
            let shifted = crate::data::load_corpus(&shifted_path)
                .unwrap_or_else(|_| Self::shifted_corpus(corpus.vocab_size));
            return Workbench { model, corpus, shifted, trained: true };
        }
        let cfg = ModelConfig::named(name);
        let mut rng = Rng::new(0xFA11BACC);
        let model = Model::random(cfg.clone(), &mut rng);
        let corpus =
            crate::data::SyntheticGrammar::new(cfg.vocab_size, 0.2, 42).corpus(60_000, &mut rng);
        let shifted = Self::shifted_corpus(cfg.vocab_size);
        Workbench { model, corpus, shifted, trained: false }
    }

    /// The "WikiText-2" role: same grammar family, different seed and
    /// more noise (out-of-domain but same token space).
    fn shifted_corpus(vocab: usize) -> Corpus {
        let mut rng = Rng::new(0x51F7ED);
        crate::data::SyntheticGrammar::new(vocab, 0.35, 1337).corpus(20_000, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGrammar;
    use crate::model::LanguageModel;

    fn setup() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
        };
        let mut rng = Rng::new(1);
        (
            Model::random(cfg, &mut rng),
            SyntheticGrammar::new(32, 0.2, 3).corpus(6_000, &mut rng),
        )
    }

    #[test]
    fn pipeline_quantizes_every_linear() {
        let (model, corpus) = setup();
        let cfg = QuantConfig {
            wbit: 4,
            group_size: 8,
            k: 2,
            ntile: 16,
            packed_exec: true,
            ..Default::default()
        };
        let (qm, report) =
            quantize_model(&model, &corpus, Method::Rtn, &cfg, 4, 24, None).unwrap();
        assert_eq!(report.layers.len(), 2 * 7);
        // Quantized model differs from FP but is finite.
        for id in qm.linear_ids() {
            assert!(qm.layer(id).is_packed());
            assert!(qm.layer(id).to_dense().all_finite());
        }
        // d=16 with group_size=8 carries heavy scale tables relative to
        // codes; ratio ≈ 4 here (realistic layers reach 6-8x, tested in
        // qtensor.rs).
        assert!(report.compression_ratio() > 3.0, "ratio={}", report.compression_ratio());
        // The execution engine itself runs below dense f32 memory, and
        // the report agrees with the engine's own accounting.
        assert!(report.resident_compression() > 1.5, "{}", report.resident_compression());
        assert_eq!(report.packed_weight_bytes(), qm.packed_weight_bytes());
    }

    #[test]
    fn fp_method_is_identity() {
        let (model, corpus) = setup();
        let cfg = QuantConfig::default();
        let (qm, report) =
            quantize_model(&model, &corpus, Method::Fp, &cfg, 2, 16, None).unwrap();
        assert!(report.layers.is_empty());
        let toks: Vec<u16> = vec![1, 5, 9];
        assert!(qm.forward(&toks).rel_err(&model.forward(&toks)) < 1e-12);
    }

    #[test]
    fn ojbkq_pipeline_beats_rtn_pipeline_on_layer_error() {
        let (model, corpus) = setup();
        let cfg = QuantConfig {
            wbit: 3,
            group_size: 8,
            k: 4,
            ntile: 16,
            mu: 0.5,
            lambda: 0.3,
            ..Default::default()
        };
        let (_, rep_ours) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 4, 24, None).unwrap();
        let (_, rep_rtn) =
            quantize_model(&model, &corpus, Method::Rtn, &cfg, 4, 24, None).unwrap();
        let sum_ours: f64 = rep_ours.layers.iter().map(|l| l.stats.rt_err).sum();
        let sum_rtn: f64 = rep_rtn.layers.iter().map(|l| l.stats.rt_err).sum();
        assert!(sum_ours < sum_rtn, "ours {sum_ours} vs rtn {sum_rtn}");
    }

    #[test]
    fn deterministic_pipeline() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 8, ..Default::default() };
        let (qa, _) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
        let (qb, _) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
        let toks: Vec<u16> = vec![2, 4, 6, 8];
        assert!(qa.forward(&toks).rel_err(&qb.forward(&toks)) < 1e-12);
    }

    #[test]
    fn on_layer_callback_streams() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let mut rng = Rng::new(5);
        let calib = corpus.calibration(2, 16, &mut rng);
        let mut seen = Vec::new();
        {
            let mut p = Pipeline::new(&model, calib, Method::Rtn, cfg, None);
            p.on_layer = Some(Box::new(|id, _| seen.push(id)));
            let _ = p.run().unwrap();
        }
        assert_eq!(seen.len(), 14);
        assert_eq!(seen[0], LinearId { block: 0, kind: LinearKind::Q });
    }

    #[test]
    fn streaming_capture_cost_is_linear_in_depth() {
        let (model, corpus) = setup();
        let mut rng = Rng::new(9);
        let n_calib = 3usize;
        let calib = corpus.calibration(n_calib, 16, &mut rng);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let (_, rep) =
            Pipeline::new(&model, calib.clone(), Method::Rtn, cfg.clone(), None).run().unwrap();
        let n_blocks = model.blocks.len() as u64;
        // One FP advance + one runtime advance per block per sequence.
        assert_eq!(rep.capture_block_steps, 2 * n_calib as u64 * n_blocks);
        // Legacy: 5 prefix forwards per block (1 FP + 4 runtime), each
        // (block+1) blocks deep — quadratic in depth.
        let (_, rep_legacy) = Pipeline::new(&model, calib, Method::Rtn, cfg, None)
            .with_capture_mode(CaptureMode::Reforward)
            .run()
            .unwrap();
        let quadratic: u64 = (0..n_blocks).map(|b| 5 * n_calib as u64 * (b + 1)).sum();
        assert_eq!(rep_legacy.capture_block_steps, quadratic);
        assert!(rep.capture_block_steps < rep_legacy.capture_block_steps);
    }

    #[test]
    fn qep_corner_skips_fp_tap_cache() {
        let (model, corpus) = setup();
        let mut rng = Rng::new(11);
        let n_calib = 3usize;
        let calib = corpus.calibration(n_calib, 16, &mut rng);
        let n_blocks = model.blocks.len() as u64;
        // μ=0, λ=0 through the config: only the runtime cache advances —
        // half the block steps of the paired-cache default.
        let cfg =
            QuantConfig { wbit: 4, group_size: 8, mu: 0.0, lambda: 0.0, ..Default::default() };
        let (qm, rep) =
            Pipeline::new(&model, calib.clone(), Method::Rtn, cfg, None).run().unwrap();
        assert_eq!(rep.capture_block_steps, n_calib as u64 * n_blocks);
        for id in qm.linear_ids() {
            assert!(qm.layer(id).to_dense().all_finite());
        }
        // Method::Qep pins the corner itself, whatever the config says.
        let cfg2 = QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, ..Default::default() };
        let (_, rep2) = Pipeline::new(&model, calib, Method::Qep, cfg2, None).run().unwrap();
        assert_eq!(rep2.capture_block_steps, n_calib as u64 * n_blocks);
        // The reforward path skips its FP prefix forwards too: 4 runtime
        // prefix forwards per block, no FP pass.
        let mut rng = Rng::new(12);
        let calib2 = corpus.calibration(n_calib, 16, &mut rng);
        let cfg3 =
            QuantConfig { wbit: 4, group_size: 8, mu: 0.0, lambda: 0.0, ..Default::default() };
        let (_, rep3) = Pipeline::new(&model, calib2, Method::Rtn, cfg3, None)
            .with_capture_mode(CaptureMode::Reforward)
            .run()
            .unwrap();
        let quadratic_rt_only: u64 =
            (0..n_blocks).map(|b| 4 * n_calib as u64 * (b + 1)).sum();
        assert_eq!(rep3.capture_block_steps, quadratic_rt_only);
    }

    #[test]
    fn layer_table_and_trace_layers_cover_every_linear() {
        let (model, corpus) = setup();
        let cfg = QuantConfig { wbit: 3, group_size: 8, k: 3, ntile: 16, ..Default::default() };
        let (_, report) =
            quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
        let table = report.layer_table();
        assert_eq!(table.rows.len(), report.layers.len());
        assert_eq!(table.rows.len(), 14);
        // Every layer record carries the decode diagnostics (native
        // OJBKQ decodes every column of every linear).
        for l in &report.layers {
            assert_eq!(l.stats.cols as usize, model.linear(l.id).cols());
            assert!(l.stats.occupancy > 0.0 && l.stats.occupancy <= 1.0);
            assert!((0.0..=1.0).contains(&l.stats.clip_rate));
        }
        // The per-layer records slot into a schema-valid trace.
        let mut tr = crate::report::RunTrace::capture(vec![("method".into(), "ours".into())]);
        tr.layers = report.trace_layers();
        crate::report::validate_trace(&tr.to_json()).unwrap();
    }

    #[test]
    fn workbench_fallback_is_usable() {
        let wb = Workbench::load(std::path::Path::new("/nonexistent"), "tiny-0.2M");
        assert!(!wb.trained);
        assert!(wb.corpus.train().len() > 1_000);
        assert_eq!(wb.model.cfg.vocab_size, wb.corpus.vocab_size);
    }
}
