//! Data substrate: the synthetic corpus standing in for C4/WikiText-2
//! (see DESIGN.md §2), calibration sampling, and corpus I/O.
//!
//! The canonical corpus is generated at build time by
//! `python/compile/pretrain.py` (the same token stream the tiny LMs are
//! trained on) and saved to `artifacts/corpus_{model}.bin`; Rust loads it
//! for calibration and evaluation. For solver-only benches and unit tests
//! this module also carries an independent Rust generator with the same
//! statistical design: an order-2 Markov grammar with Zipfian noise —
//! non-trivial bigram/trigram structure a small transformer can learn,
//! plus a heavy-tailed unigram marginal like natural text.

use crate::rng::Rng;
use std::io::{BufRead, Read, Write};
use std::path::Path;

/// A token corpus with a train/eval split.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub tokens: Vec<u16>,
    pub vocab_size: usize,
    /// Index where the held-out split starts.
    pub eval_start: usize,
}

impl Corpus {
    /// Training split.
    pub fn train(&self) -> &[u16] {
        &self.tokens[..self.eval_start]
    }

    /// Held-out split (perplexity + task evaluation).
    pub fn eval(&self) -> &[u16] {
        &self.tokens[self.eval_start..]
    }

    /// Sample `count` calibration sequences of `seq_len` tokens from the
    /// train split (paper: 128 C4 samples of 2048 tokens; scaled down).
    pub fn calibration(&self, count: usize, seq_len: usize, rng: &mut Rng) -> Vec<Vec<u16>> {
        let train = self.train();
        assert!(train.len() > seq_len + 1, "corpus too small for seq_len {seq_len}");
        (0..count)
            .map(|_| {
                let start = rng.below((train.len() - seq_len) as u64) as usize;
                train[start..start + seq_len].to_vec()
            })
            .collect()
    }

    /// Non-overlapping eval windows of `seq_len`, up to `max_tokens`.
    pub fn eval_windows(&self, seq_len: usize, max_tokens: usize) -> Vec<&[u16]> {
        let eval = self.eval();
        let mut out = Vec::new();
        let mut used = 0usize;
        let mut pos = 0usize;
        while pos + seq_len <= eval.len() && used < max_tokens {
            out.push(&eval[pos..pos + seq_len]);
            pos += seq_len;
            used += seq_len;
        }
        out
    }
}

/// The order-2 Markov + Zipf synthetic grammar.
///
/// Construction (deterministic in `seed`):
/// * each context hash `h(cur, prev mod 8)` selects 4 preferred
///   successors with weights (0.55, 0.25, 0.12, 0.08). Reducing `prev`
///   to 8 classes keeps the context table at `8·vocab` entries — dense
///   enough to be *learnable* from a few hundred thousand tokens, while
///   still requiring attention over more than the last token (a pure
///   bigram model cannot resolve the 8-way successor ambiguity);
/// * with probability `noise` the next token is drawn from a Zipf(1.1)
///   marginal instead (heavy-tailed unigram like natural text).
#[derive(Debug, Clone)]
pub struct SyntheticGrammar {
    vocab_size: usize,
    noise: f64,
    zipf_cdf: Vec<f64>,
    seed: u64,
}

impl SyntheticGrammar {
    pub fn new(vocab_size: usize, noise: f64, seed: u64) -> SyntheticGrammar {
        let mut weights: Vec<f64> =
            (1..=vocab_size).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        SyntheticGrammar { vocab_size, noise, zipf_cdf: weights, seed }
    }

    /// The 4 preferred successors of a context, with cumulative weights.
    fn successors(&self, prev: u16, cur: u16) -> [u16; 4] {
        let mut h = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(((prev & 7) as u64) << 32 | cur as u64);
        let mut out = [0u16; 4];
        for slot in out.iter_mut() {
            *slot = (crate::rng::splitmix64(&mut h) % self.vocab_size as u64) as u16;
        }
        out
    }

    fn zipf_sample(&self, u: f64) -> u16 {
        match self.zipf_cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.vocab_size - 1) as u16,
        }
    }

    /// Generate `n` tokens.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = Vec::with_capacity(n);
        let mut prev = self.zipf_sample(rng.uniform());
        let mut cur = self.zipf_sample(rng.uniform());
        out.push(prev);
        if n > 1 {
            out.push(cur);
        }
        const CUM: [f64; 4] = [0.55, 0.80, 0.92, 1.0];
        while out.len() < n {
            let next = if rng.uniform() < self.noise {
                self.zipf_sample(rng.uniform())
            } else {
                let succ = self.successors(prev, cur);
                let u = rng.uniform();
                let mut pick = succ[3];
                for (i, &c) in CUM.iter().enumerate() {
                    if u < c {
                        pick = succ[i];
                        break;
                    }
                }
                pick
            };
            out.push(next);
            prev = cur;
            cur = next;
        }
        out
    }

    /// Build a corpus with a 90/10 train/eval split.
    pub fn corpus(&self, n: usize, rng: &mut Rng) -> Corpus {
        let tokens = self.generate(n, rng);
        Corpus { tokens, vocab_size: self.vocab_size, eval_start: n * 9 / 10 }
    }
}

const CORPUS_MAGIC: &str = "OJBC1";

/// Save a corpus (`OJBC1` format: magic, `vocab n eval_start`, u16 LE).
pub fn save_corpus(c: &Corpus, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{CORPUS_MAGIC}")?;
    writeln!(w, "{} {} {}", c.vocab_size, c.tokens.len(), c.eval_start)?;
    let mut bytes = Vec::with_capacity(c.tokens.len() * 2);
    for &t in &c.tokens {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

/// Load an `OJBC1` corpus (as written by pretrain.py or [`save_corpus`]).
pub fn load_corpus(path: &Path) -> anyhow::Result<Corpus> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening corpus {path:?}: {e} (run `make artifacts`)"))?;
    let mut r = std::io::BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line)?;
    anyhow::ensure!(line.trim() == CORPUS_MAGIC, "bad corpus magic {line:?}");
    line.clear();
    r.read_line(&mut line)?;
    let dims: Vec<usize> =
        line.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
    anyhow::ensure!(dims.len() == 3, "bad corpus header {line:?}");
    let (vocab_size, n, eval_start) = (dims[0], dims[1], dims[2]);
    let mut buf = vec![0u8; n * 2];
    r.read_exact(&mut buf)?;
    let tokens: Vec<u16> =
        buf.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    anyhow::ensure!(tokens.iter().all(|&t| (t as usize) < vocab_size), "token out of vocab");
    Ok(Corpus { tokens, vocab_size, eval_start })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_tokens_in_vocab() {
        let g = SyntheticGrammar::new(128, 0.2, 7);
        let mut rng = Rng::new(1);
        let toks = g.generate(5_000, &mut rng);
        assert_eq!(toks.len(), 5_000);
        assert!(toks.iter().all(|&t| t < 128));
    }

    #[test]
    fn grammar_is_learnable_structure() {
        // The bigram conditional entropy must be far below the unigram
        // entropy — otherwise there is nothing for the LM to learn.
        let vocab = 64usize;
        let g = SyntheticGrammar::new(vocab, 0.15, 3);
        let mut rng = Rng::new(2);
        let toks = g.generate(200_000, &mut rng);
        let mut uni = vec![0f64; vocab];
        let mut big = std::collections::HashMap::<(u16, u16), Vec<f64>>::new();
        for w in toks.windows(3) {
            uni[w[2] as usize] += 1.0;
            big.entry((w[0], w[1])).or_insert_with(|| vec![0.0; vocab])[w[2] as usize] += 1.0;
        }
        let ent = |counts: &[f64]| {
            let total: f64 = counts.iter().sum();
            if total < 1.0 {
                return 0.0;
            }
            -counts
                .iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| (c / total) * (c / total).ln())
                .sum::<f64>()
        };
        let h_uni = ent(&uni);
        let mut h_cond = 0.0;
        let mut mass = 0.0;
        for counts in big.values() {
            let t: f64 = counts.iter().sum();
            h_cond += t * ent(counts);
            mass += t;
        }
        h_cond /= mass;
        assert!(
            h_cond < 0.7 * h_uni,
            "conditional entropy {h_cond:.3} not much below unigram {h_uni:.3}"
        );
    }

    #[test]
    fn corpus_split_and_calibration() {
        let g = SyntheticGrammar::new(64, 0.2, 5);
        let mut rng = Rng::new(3);
        let c = g.corpus(10_000, &mut rng);
        assert_eq!(c.train().len(), 9_000);
        assert_eq!(c.eval().len(), 1_000);
        let calib = c.calibration(8, 32, &mut rng);
        assert_eq!(calib.len(), 8);
        assert!(calib.iter().all(|s| s.len() == 32));
        let windows = c.eval_windows(100, 550);
        assert_eq!(windows.len(), 6); // ceil: windows until >= 550 tokens
    }

    #[test]
    fn corpus_io_roundtrip() {
        let g = SyntheticGrammar::new(32, 0.3, 9);
        let mut rng = Rng::new(4);
        let c = g.corpus(2_000, &mut rng);
        let dir = std::env::temp_dir().join("ojbkq_test_data");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.bin");
        save_corpus(&c, &path).unwrap();
        let c2 = load_corpus(&path).unwrap();
        assert_eq!(c.tokens, c2.tokens);
        assert_eq!(c.eval_start, c2.eval_start);
        assert_eq!(c.vocab_size, c2.vocab_size);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = SyntheticGrammar::new(64, 0.2, 11);
        let a = g.generate(500, &mut Rng::new(1));
        let b = g.generate(500, &mut Rng::new(1));
        assert_eq!(a, b);
        let c = g.generate(500, &mut Rng::new(2));
        assert_ne!(a, c);
    }
}
