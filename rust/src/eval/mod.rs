//! Evaluation harnesses for the paper's three metric families:
//! perplexity (Table 1), zero-shot multiple-choice accuracy (Table 2),
//! and generative "reasoning" accuracy (Table 3) — each on the synthetic
//! substrate documented in DESIGN.md §2.

mod reasoning;
mod zeroshot;

pub use reasoning::{reasoning_accuracy, ReasoningTask};
pub use zeroshot::{zero_shot_accuracy, ZeroShotTask};

use crate::data::Corpus;
use crate::model::{nll_from_logits, LanguageModel};
use crate::parallel::parallel_map;

/// Upper bound on stacked token rows per [`LanguageModel::forward_batch`]
/// chunk inside [`perplexity`]: bounds peak logits residency at roughly
/// `2 × PPL_BATCH_ROWS × vocab × 4` bytes — the tall LM-head matrix plus
/// its per-window split copies coexist briefly inside `forward_batch` —
/// (plus the tall MLP intermediates) however large the eval token budget
/// is, while keeping each chunk tall enough for the batch-fused GEMM win.
const PPL_BATCH_ROWS: usize = 16_384;

/// Perplexity of `model` on the corpus' held-out split, over up to
/// `max_tokens` tokens in windows of `seq_len`:
/// `exp(Σ NLL / Σ tokens)` — the paper's Table-1 metric.
///
/// Scoring is **batch-fused**: windows advance as stacked caches through
/// [`LanguageModel::forward_batch`] (in chunks of at most
/// [`PPL_BATCH_ROWS`] token rows), so every linear stage and the LM head
/// run as tall GEMMs — bit-identical to per-window forwards. The NLL
/// reduction runs in parallel over each chunk's per-window logits and
/// reduces in window order, so the result is deterministic.
pub fn perplexity<M: LanguageModel + Sync>(
    model: &M,
    corpus: &Corpus,
    seq_len: usize,
    max_tokens: usize,
) -> f64 {
    let _span = crate::obs::span("eval");
    let t0 = std::time::Instant::now();
    let windows = corpus.eval_windows(seq_len, max_tokens);
    assert!(!windows.is_empty(), "no eval windows (corpus too small?)");
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut start = 0usize;
    while start < windows.len() {
        let mut end = start;
        let mut rows = 0usize;
        while end < windows.len() && (end == start || rows + windows[end].len() <= PPL_BATCH_ROWS)
        {
            rows += windows[end].len();
            end += 1;
        }
        let chunk = &windows[start..end];
        let logits = model.forward_batch(chunk);
        let per_window = parallel_map(chunk.len(), |i| nll_from_logits(&logits[i], chunk[i]));
        for (n, c) in per_window {
            nll += n;
            count += c;
        }
        start = end;
    }
    if crate::obs::enabled() {
        crate::obs::counter_add("eval.windows", windows.len() as u64);
        crate::obs::counter_add("eval.tokens", count as u64);
        let secs = t0.elapsed().as_secs_f64();
        if secs > 0.0 {
            crate::obs::gauge_set("eval.windows_per_sec", windows.len() as f64 / secs);
        }
    }
    (nll / count.max(1) as f64).exp()
}

/// Two perplexities mirroring the paper's "C4 / WikiText-2" pair: the
/// held-out split of the training corpus, and a *shifted-distribution*
/// variant (same grammar family, noisier) playing the out-of-domain role.
pub fn perplexity_pair<M: LanguageModel + Sync>(
    model: &M,
    in_domain: &Corpus,
    shifted: &Corpus,
    seq_len: usize,
    max_tokens: usize,
) -> (f64, f64) {
    (
        perplexity(model, in_domain, seq_len, max_tokens),
        perplexity(model, shifted, seq_len, max_tokens),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::SyntheticGrammar;
    use crate::model::Model;
    use crate::rng::Rng;

    fn tiny() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
        };
        let mut rng = Rng::new(1);
        let model = Model::random(cfg, &mut rng);
        let corpus = SyntheticGrammar::new(32, 0.2, 3).corpus(4_000, &mut rng);
        (model, corpus)
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let (model, corpus) = tiny();
        let ppl = perplexity(&model, &corpus, 24, 480);
        // Uniform over 32 tokens => ppl 32; random model should be close
        // (it has no knowledge, but embeddings induce mild structure).
        assert!(ppl > 8.0 && ppl < 128.0, "ppl={ppl}");
    }

    #[test]
    fn corrupting_model_raises_ppl() {
        let (mut model, corpus) = tiny();
        let base = perplexity(&model, &corpus, 24, 480);
        // A random-init model is near-uniform (RMSNorm + small tied head
        // make linear-weight noise wash out), so to get a *confidently
        // wrong* model we sharpen the head: scaling the tied embedding
        // amplifies arbitrary preferences, which must raise NLL on
        // structured data.
        model.embedding = model.embedding.scale(50.0);
        let corrupted = perplexity(&model, &corpus, 24, 480);
        assert!(
            corrupted > base * 1.1,
            "confidently-wrong model should clearly raise ppl: {corrupted} vs {base}"
        );
    }

    #[test]
    fn batched_ppl_matches_per_window_scoring() {
        // The batch-fused scorer must agree bit-for-bit with independent
        // per-window `sequence_nll` calls in window order.
        let (model, corpus) = tiny();
        let ppl = perplexity(&model, &corpus, 24, 480);
        let windows = corpus.eval_windows(24, 480);
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for w in &windows {
            let (n, c) = model.sequence_nll(w);
            nll += n;
            count += c;
        }
        assert_eq!(ppl, (nll / count as f64).exp());
    }

    #[test]
    fn ppl_deterministic() {
        let (model, corpus) = tiny();
        let a = perplexity(&model, &corpus, 16, 320);
        let b = perplexity(&model, &corpus, 16, 320);
        assert_eq!(a, b);
    }
}
