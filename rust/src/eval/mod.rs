//! Evaluation harnesses for the paper's three metric families:
//! perplexity (Table 1), zero-shot multiple-choice accuracy (Table 2),
//! and generative "reasoning" accuracy (Table 3) — each on the synthetic
//! substrate documented in DESIGN.md §2.

mod reasoning;
mod zeroshot;

pub use reasoning::{reasoning_accuracy, ReasoningTask};
pub use zeroshot::{zero_shot_accuracy, ZeroShotTask};

use crate::data::Corpus;
use crate::model::LanguageModel;
use crate::parallel::parallel_map;

/// Perplexity of `model` on the corpus' held-out split, over up to
/// `max_tokens` tokens in windows of `seq_len`:
/// `exp(Σ NLL / Σ tokens)` — the paper's Table-1 metric. Windows are
/// scored in parallel (they are independent) and reduced in window order,
/// so the result is deterministic.
pub fn perplexity<M: LanguageModel + Sync>(
    model: &M,
    corpus: &Corpus,
    seq_len: usize,
    max_tokens: usize,
) -> f64 {
    let windows = corpus.eval_windows(seq_len, max_tokens);
    assert!(!windows.is_empty(), "no eval windows (corpus too small?)");
    let per_window = parallel_map(windows.len(), |i| model.sequence_nll(windows[i]));
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for (n, c) in per_window {
        nll += n;
        count += c;
    }
    (nll / count.max(1) as f64).exp()
}

/// Two perplexities mirroring the paper's "C4 / WikiText-2" pair: the
/// held-out split of the training corpus, and a *shifted-distribution*
/// variant (same grammar family, noisier) playing the out-of-domain role.
pub fn perplexity_pair<M: LanguageModel + Sync>(
    model: &M,
    in_domain: &Corpus,
    shifted: &Corpus,
    seq_len: usize,
    max_tokens: usize,
) -> (f64, f64) {
    (
        perplexity(model, in_domain, seq_len, max_tokens),
        perplexity(model, shifted, seq_len, max_tokens),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::SyntheticGrammar;
    use crate::model::Model;
    use crate::rng::Rng;

    fn tiny() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
        };
        let mut rng = Rng::new(1);
        let model = Model::random(cfg, &mut rng);
        let corpus = SyntheticGrammar::new(32, 0.2, 3).corpus(4_000, &mut rng);
        (model, corpus)
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let (model, corpus) = tiny();
        let ppl = perplexity(&model, &corpus, 24, 480);
        // Uniform over 32 tokens => ppl 32; random model should be close
        // (it has no knowledge, but embeddings induce mild structure).
        assert!(ppl > 8.0 && ppl < 128.0, "ppl={ppl}");
    }

    #[test]
    fn corrupting_model_raises_ppl() {
        let (mut model, corpus) = tiny();
        let base = perplexity(&model, &corpus, 24, 480);
        // A random-init model is near-uniform (RMSNorm + small tied head
        // make linear-weight noise wash out), so to get a *confidently
        // wrong* model we sharpen the head: scaling the tied embedding
        // amplifies arbitrary preferences, which must raise NLL on
        // structured data.
        model.embedding = model.embedding.scale(50.0);
        let corrupted = perplexity(&model, &corpus, 24, 480);
        assert!(
            corrupted > base * 1.1,
            "confidently-wrong model should clearly raise ppl: {corrupted} vs {base}"
        );
    }

    #[test]
    fn ppl_deterministic() {
        let (model, corpus) = tiny();
        let a = perplexity(&model, &corpus, 16, 320);
        let b = perplexity(&model, &corpus, 16, 320);
        assert_eq!(a, b);
    }
}
