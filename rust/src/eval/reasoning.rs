//! Generative "reasoning" evaluation — the Table-3 substitute
//! (GSM8K / GPQA / MBPP analogues).
//!
//! Each item takes a held-out context, greedily decodes `gen_len` tokens,
//! and scores the fraction of generated tokens matching the actual corpus
//! continuation. This exercises *multi-step autoregressive generation
//! under quantization error accumulation* — the failure mode that makes
//! reasoning benchmarks brittle in the paper (cf. QuIP's MBPP collapse):
//! one early wrong token derails every subsequent step.

use crate::data::Corpus;
use crate::model::LanguageModel;
use crate::rng::Rng;

/// A generative task configuration.
#[derive(Debug, Clone)]
pub struct ReasoningTask {
    pub name: &'static str,
    /// Context shown to the model.
    pub context_len: usize,
    /// Tokens to generate greedily.
    pub gen_len: usize,
}

impl ReasoningTask {
    /// The three suites standing in for GSM8K / GPQA / MBPP: increasing
    /// generation length = increasing error-compounding pressure.
    pub fn suite() -> Vec<ReasoningTask> {
        vec![
            ReasoningTask { name: "GSM8K", context_len: 32, gen_len: 4 },
            ReasoningTask { name: "GPQA", context_len: 16, gen_len: 8 },
            ReasoningTask { name: "MBPP", context_len: 48, gen_len: 12 },
        ]
    }
}

/// Mean per-token match rate (%) of greedy generations against the true
/// corpus continuations over `n_items` held-out items.
pub fn reasoning_accuracy<M: LanguageModel>(
    model: &M,
    corpus: &Corpus,
    task: &ReasoningTask,
    n_items: usize,
    seed: u64,
) -> f64 {
    let eval = corpus.eval();
    let span = task.context_len + task.gen_len;
    assert!(eval.len() > span * 2, "eval split too small");
    let mut rng = Rng::new(seed ^ 0xB00);
    let mut matched = 0usize;
    let mut total = 0usize;
    for _ in 0..n_items {
        let start = rng.below((eval.len() - span) as u64) as usize;
        let context = &eval[start..start + task.context_len];
        let truth = &eval[start + task.context_len..start + span];
        let gen = model.greedy_continue(context, task.gen_len);
        for (g, t) in gen.iter().zip(truth) {
            if g == t {
                matched += 1;
            }
            total += 1;
        }
    }
    100.0 * matched as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::SyntheticGrammar;
    use crate::model::Model;

    fn setup() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        };
        let mut rng = Rng::new(1);
        (Model::random(cfg, &mut rng), SyntheticGrammar::new(32, 0.2, 3).corpus(8_000, &mut rng))
    }

    #[test]
    fn accuracy_in_range_and_deterministic() {
        let (model, corpus) = setup();
        let task = &ReasoningTask::suite()[0];
        let a = reasoning_accuracy(&model, &corpus, task, 12, 5);
        let b = reasoning_accuracy(&model, &corpus, task, 12, 5);
        assert_eq!(a, b);
        assert!((0.0..=100.0).contains(&a));
    }

    #[test]
    fn suite_names() {
        let names: Vec<&str> = ReasoningTask::suite().iter().map(|t| t.name).collect();
        assert_eq!(names, vec!["GSM8K", "GPQA", "MBPP"]);
    }
}
