//! Zero-shot multiple-choice evaluation — the Table-2 substitute.
//!
//! Mechanism identical to LM-harness: each item is a context plus N
//! candidate continuations; the model scores each continuation by
//! length-normalized log-likelihood and picks the argmax. The true
//! continuation comes from the held-out corpus; distractors are windows
//! sampled elsewhere (or, for the Winogrande analogue, minimal
//! perturbations of the truth). Task parameters mirror the difficulty
//! spread of the paper's six suites.

use crate::data::Corpus;
use crate::model::LanguageModel;
use crate::rng::Rng;

/// A zero-shot task configuration.
#[derive(Debug, Clone)]
pub struct ZeroShotTask {
    pub name: &'static str,
    /// Context tokens shown before the choices.
    pub context_len: usize,
    /// Continuation length being scored.
    pub cont_len: usize,
    /// Number of choices (1 true + n−1 distractors).
    pub n_choices: usize,
    /// Winogrande-style minimal-pair distractors (perturb 1 token).
    pub minimal_pair: bool,
}

impl ZeroShotTask {
    /// The six suites standing in for ARC-C/ARC-E/BoolQ/Hella/PIQA/Wino.
    pub fn suite() -> Vec<ZeroShotTask> {
        let t = |name, context_len, cont_len, n_choices, minimal_pair| ZeroShotTask {
            name,
            context_len,
            cont_len,
            n_choices,
            minimal_pair,
        };
        vec![
            t("ARC-C", 12, 8, 4, false),
            t("ARC-E", 32, 4, 4, false),
            t("BoolQ", 24, 6, 2, false),
            t("Hella", 24, 12, 4, false),
            t("PIQA", 16, 8, 2, false),
            t("Wino", 32, 4, 2, true),
        ]
    }
}

/// One evaluation item.
struct Item {
    context: Vec<u16>,
    choices: Vec<Vec<u16>>,
    answer: usize,
}

/// Build `n_items` items deterministically from the corpus eval split.
fn build_items(task: &ZeroShotTask, corpus: &Corpus, n_items: usize, seed: u64) -> Vec<Item> {
    let eval = corpus.eval();
    let span = task.context_len + task.cont_len;
    assert!(eval.len() > span * 2, "eval split too small");
    let mut rng = Rng::new(seed ^ 0x5EED);
    (0..n_items)
        .map(|_| {
            let start = rng.below((eval.len() - span) as u64) as usize;
            let context = eval[start..start + task.context_len].to_vec();
            let truth = eval[start + task.context_len..start + span].to_vec();
            let mut choices = Vec::with_capacity(task.n_choices);
            let answer = rng.below(task.n_choices as u64) as usize;
            for c in 0..task.n_choices {
                if c == answer {
                    choices.push(truth.clone());
                } else if task.minimal_pair {
                    // Perturb one position of the truth with a random token.
                    let mut alt = truth.clone();
                    let pos = rng.below(alt.len() as u64) as usize;
                    let mut t = rng.below(corpus.vocab_size as u64) as u16;
                    if t == alt[pos] {
                        t = (t + 1) % corpus.vocab_size as u16;
                    }
                    alt[pos] = t;
                    choices.push(alt);
                } else {
                    // Distractor: continuation from an unrelated window.
                    let s2 = rng.below((eval.len() - task.cont_len) as u64) as usize;
                    choices.push(eval[s2..s2 + task.cont_len].to_vec());
                }
            }
            Item { context, choices, answer }
        })
        .collect()
}

/// Length-normalized continuation log-likelihood.
fn choice_score<M: LanguageModel>(model: &M, context: &[u16], cont: &[u16]) -> f64 {
    let mut seq = context.to_vec();
    seq.extend_from_slice(cont);
    let logits = model.forward(&seq);
    let mut ll = 0.0f64;
    for (off, &tok) in cont.iter().enumerate() {
        let pos = context.len() + off - 1; // logits at pos predict pos+1
        let ls = crate::util::log_softmax(logits.row(pos));
        ll += ls[tok as usize] as f64;
    }
    ll / cont.len() as f64
}

/// Accuracy (%) of `model` on `task` with `n_items` items.
pub fn zero_shot_accuracy<M: LanguageModel>(
    model: &M,
    corpus: &Corpus,
    task: &ZeroShotTask,
    n_items: usize,
    seed: u64,
) -> f64 {
    let items = build_items(task, corpus, n_items, seed);
    let mut correct = 0usize;
    for item in &items {
        let scores: Vec<f64> = item
            .choices
            .iter()
            .map(|c| choice_score(model, &item.context, c))
            .collect();
        let pick = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pick == item.answer {
            correct += 1;
        }
    }
    100.0 * correct as f64 / n_items.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::SyntheticGrammar;
    use crate::model::Model;

    fn setup() -> (Model, Corpus) {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        };
        let mut rng = Rng::new(1);
        (Model::random(cfg, &mut rng), SyntheticGrammar::new(32, 0.2, 3).corpus(8_000, &mut rng))
    }

    #[test]
    fn items_deterministic_and_well_formed() {
        let (_, corpus) = setup();
        let task = &ZeroShotTask::suite()[0];
        let a = build_items(task, &corpus, 10, 42);
        let b = build_items(task, &corpus, 10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
        for item in &a {
            assert_eq!(item.choices.len(), 4);
            assert_eq!(item.context.len(), task.context_len);
            assert!(item.answer < 4);
            assert_eq!(item.choices[item.answer].len(), task.cont_len);
        }
    }

    #[test]
    fn random_model_near_chance() {
        let (model, corpus) = setup();
        let task = ZeroShotTask {
            name: "x",
            context_len: 8,
            cont_len: 4,
            n_choices: 4,
            minimal_pair: false,
        };
        let acc = zero_shot_accuracy(&model, &corpus, &task, 60, 7);
        // Chance = 25%; random model should be within noise of chance.
        assert!(acc > 5.0 && acc < 60.0, "acc={acc}");
    }

    #[test]
    fn suite_has_six_named_tasks() {
        let suite = ZeroShotTask::suite();
        assert_eq!(suite.len(), 6);
        let names: Vec<&str> = suite.iter().map(|t| t.name).collect();
        assert!(names.contains(&"ARC-C") && names.contains(&"Wino"));
    }
}
