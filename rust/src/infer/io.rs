//! Packed checkpoint (de)serialization — the native **OJBQ1** format.
//!
//! OJBQ1 ships a [`QuantizedModel`] exactly as the execution engine
//! holds it: per-layer bit-packed code streams, group scale and `s·z`
//! correction tables, decode-order permutations, and dense fallbacks —
//! never densifying the packed layers (the pre-PR-4 path serialized
//! through `to_dense()` + OJBW1, throwing away the 4-8× resident
//! compression at the disk boundary). It mirrors the OJBW1 layout of
//! `rust/src/model/io.rs` (text header + named records) but adds a
//! format-version line and an explicit per-layer kind tag:
//!
//! ```text
//! OJBQ1\n
//! 1\n                                          (format version)
//! vocab d_model n_layers n_heads d_ff max_seq\n
//! <records, canonical order:>
//!   embedding                  dense  (vocab × d_model)
//!   per block i:
//!     b{i}.attn_norm           dense  (1 × d_model)
//!     b{i}.mlp_norm            dense  (1 × d_model)
//!     b{i}.{wq wk wv wo wgate wup wdown}   dense | packed
//!   final_norm                 dense  (1 × d_model)
//! end\n
//! ```
//!
//! A **dense** record (FP passthrough layers, AWQ/QuIP fallbacks, norms,
//! embedding) is `name\n`, `dense\n`, `rows cols\n`, then `rows·cols`
//! little-endian f32 bytes. A **packed** record serializes
//! [`PackedTiles`] field for field:
//!
//! ```text
//! name\n
//! packed\n
//! m n wbit group_size n_groups perm_flag\n
//! <scales: n_groups·n f32 LE>             group scale table s
//! <corr:   n_groups·n f32 LE>             correction table s·z
//! <perm:   m u32 LE>                      iff perm_flag == 1
//! <tiles:  ⌈n/COL_TILE⌉ streams>          tile t: ⌈m·width(t)·wbit/8⌉ B
//! ```
//!
//! Tile streams serialize at their **logical** length
//! ([`PackedTiles::tile_payload`], `⌈m·width·wbit/8⌉` bytes) — the
//! resident streams additionally carry ≤7 zero pad bytes each for
//! word-aligned u64 unpack, a kernel-layout detail that never hits
//! disk, so the on-disk format is byte-stable across kernel-layout
//! changes (the golden fixture pins this).
//! [`CheckpointInfo::weight_bytes`] reports the engine's resident
//! weight memory ([`PackedLinear::bytes`], which counts the pad).
//!
//! Reader hardening (see `rust/tests/packed_checkpoint.rs`): records are
//! read in canonical order with dimensions pinned by the config header,
//! so field-order or layout drift fails loudly instead of loading
//! garbage; every allocation is capped against the remaining file length
//! (a hostile header cannot provoke an OOM-sized allocation); all size
//! arithmetic is overflow-checked; packed metadata passes
//! [`PackedTiles::from_parts`] validation before any kernel sees it; and
//! the `end` terminator makes silent truncation detectable. Every
//! failure is an `Err`, never a panic.

use crate::infer::packed::PackedTiles;
use crate::infer::{PackedLinear, QuantizedBlock, QuantizedModel, COL_TILE};
use crate::model::io::{config_header_line, parse_config_header, parse_usize_fields};
use crate::model::LinearKind;
use crate::tensor::Matrix;
use crate::util::{bytes_to_f32s, f32s_to_bytes};
use std::io::{BufRead, Read, Write};
use std::path::Path;

const MAGIC: &str = "OJBQ1";
const VERSION: u32 = 1;
/// Magic line of a per-block partial segment (`save_block_segment`).
const SEG_MAGIC: &str = "OJBS1";

/// Size accounting returned by [`save_quantized`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointInfo {
    /// Total bytes of the written file (header + records + framing).
    pub file_bytes: u64,
    /// Resident weight bytes of the saved model's layers — by
    /// construction equal to [`QuantizedModel::packed_weight_bytes`].
    /// (The on-disk tensor section is marginally smaller: tile streams
    /// serialize without their word-alignment pad.)
    pub weight_bytes: usize,
}

/// Expected `(m, n)` of a block linear under `cfg` — what pins every
/// record's dimensions during both save (debug) and load (hard `Err`).
fn linear_dims(cfg: &crate::config::ModelConfig, kind: LinearKind) -> (usize, usize) {
    let (d, ff) = (cfg.d_model, cfg.d_ff);
    match kind {
        LinearKind::Q | LinearKind::K | LinearKind::V | LinearKind::O => (d, d),
        LinearKind::Gate | LinearKind::Up => (d, ff),
        LinearKind::Down => (ff, d),
    }
}

// ----- writer ---------------------------------------------------------

/// Save a packed model as an OJBQ1 checkpoint — streaming, straight from
/// the integer codes (no intermediate densify). Returns the written size
/// plus the `bytes()`-consistent weight-payload accounting.
pub fn save_quantized(qm: &QuantizedModel, path: &Path) -> anyhow::Result<CheckpointInfo> {
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("creating checkpoint {path:?}: {e}"))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{MAGIC}")?;
    writeln!(w, "{VERSION}")?;
    writeln!(w, "{}", config_header_line(&qm.cfg))?;
    let mut weight_bytes = 0usize;
    let emb = &qm.embedding;
    write_dense(&mut w, "embedding", emb.rows(), emb.cols(), emb.as_slice())?;
    for (i, b) in qm.blocks.iter().enumerate() {
        write_dense(&mut w, &format!("b{i}.attn_norm"), 1, b.attn_norm.len(), &b.attn_norm)?;
        write_dense(&mut w, &format!("b{i}.mlp_norm"), 1, b.mlp_norm.len(), &b.mlp_norm)?;
        for (&kind, lin) in LinearKind::all().iter().zip(b.linears()) {
            let name = format!("b{i}.{}", kind.name());
            debug_assert_eq!(lin.shape(), linear_dims(&qm.cfg, kind), "layer {name}");
            weight_bytes += lin.bytes();
            match lin {
                PackedLinear::Dense(mat) => {
                    write_dense(&mut w, &name, mat.rows(), mat.cols(), mat.as_slice())?;
                }
                PackedLinear::Packed(t) => write_packed(&mut w, &name, t)?,
            }
        }
    }
    write_dense(&mut w, "final_norm", 1, qm.final_norm.len(), &qm.final_norm)?;
    writeln!(w, "end")?;
    w.flush()?;
    drop(w);
    let file_bytes = std::fs::metadata(path)?.len();
    debug_assert_eq!(weight_bytes, qm.packed_weight_bytes(), "bytes() accounting drift");
    Ok(CheckpointInfo { file_bytes, weight_bytes })
}

fn write_dense(
    w: &mut impl Write,
    name: &str,
    rows: usize,
    cols: usize,
    data: &[f32],
) -> anyhow::Result<()> {
    writeln!(w, "{name}")?;
    writeln!(w, "dense")?;
    crate::model::io::write_f32_payload(w, rows, cols, data)
}

fn write_packed(w: &mut impl Write, name: &str, t: &PackedTiles) -> anyhow::Result<()> {
    let (m, n) = t.shape();
    writeln!(w, "{name}")?;
    writeln!(w, "packed")?;
    writeln!(
        w,
        "{m} {n} {} {} {} {}",
        t.wbit(),
        t.group_size(),
        t.scales().rows(),
        usize::from(t.perm().is_some())
    )?;
    w.write_all(&f32s_to_bytes(t.scales().as_slice()))?;
    w.write_all(&f32s_to_bytes(t.corr().as_slice()))?;
    if let Some(p) = t.perm() {
        let mut buf = Vec::with_capacity(p.len() * 4);
        for &v in p {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    // Serialize the logical bitstreams: the resident word-alignment pad
    // is a kernel-layout detail and never hits disk, so the OJBQ1 tensor
    // section is byte-identical to the pre-padding format.
    for ti in 0..t.tiles().len() {
        w.write_all(t.tile_payload(ti))?;
    }
    Ok(())
}

/// Save one block's seven linears as a crash-safe partial segment — the
/// unit of `quantize --out` checkpointing. Records use exactly the
/// OJBQ1 dense/packed layout under an `OJBS1` magic + block-index
/// header:
///
/// ```text
/// OJBS1\n
/// <block>\n
/// b{block}.{wq wk wv wo wgate wup wdown}   dense | packed
/// end\n
/// ```
///
/// The segment is serialized to memory and committed through
/// [`crate::robust::atomic_write`] (temp file + rename, the
/// `io.segment_write` fault site), so a crash mid-write never leaves a
/// torn segment — at worst an orphan `.tmp` that resume ignores.
pub fn save_block_segment(
    qm: &QuantizedModel,
    block: usize,
    path: &Path,
) -> anyhow::Result<()> {
    let b = qm
        .blocks
        .get(block)
        .ok_or_else(|| anyhow::anyhow!("segment block {block} out of range"))?;
    let mut buf: Vec<u8> = Vec::new();
    writeln!(buf, "{SEG_MAGIC}")?;
    writeln!(buf, "{block}")?;
    for (&kind, lin) in LinearKind::all().iter().zip(b.linears()) {
        let name = format!("b{block}.{}", kind.name());
        debug_assert_eq!(lin.shape(), linear_dims(&qm.cfg, kind), "layer {name}");
        match lin {
            PackedLinear::Dense(mat) => {
                write_dense(&mut buf, &name, mat.rows(), mat.cols(), mat.as_slice())?;
            }
            PackedLinear::Packed(t) => write_packed(&mut buf, &name, t)?,
        }
    }
    writeln!(buf, "end")?;
    crate::robust::atomic_write("io.segment_write", path, &buf)
}

// ----- reader ---------------------------------------------------------

/// A `BufRead` wrapper that refuses to allocate past the bytes actually
/// present in the file — the hostile-header OOM guard.
struct Reader<R: BufRead> {
    r: R,
    remaining: u64,
}

impl<R: BufRead> Reader<R> {
    /// Next text line, trimmed; `Err` at end of file (truncation).
    fn line(&mut self) -> anyhow::Result<String> {
        let mut s = String::new();
        let n = self.r.read_line(&mut s)?;
        anyhow::ensure!(n > 0, "unexpected end of file (truncated checkpoint)");
        self.remaining = self.remaining.saturating_sub(n as u64);
        Ok(s.trim_end_matches(['\n', '\r']).to_string())
    }

    /// Exactly `len` payload bytes, capped against the remaining file.
    fn bytes(&mut self, len: usize, what: &str) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(
            len as u64 <= self.remaining,
            "{what}: {len} bytes declared but at most {} remain in file",
            self.remaining
        );
        let mut buf = vec![0u8; len];
        self.r
            .read_exact(&mut buf)
            .map_err(|e| anyhow::anyhow!("{what}: truncated payload: {e}"))?;
        self.remaining -= len as u64;
        Ok(buf)
    }

    /// Exactly `count` little-endian f32 values.
    fn f32s(&mut self, count: usize, what: &str) -> anyhow::Result<Vec<f32>> {
        bytes_to_f32s(&self.bytes(mul(count, 4, what)?, what)?)
    }
}

/// Overflow-checked size arithmetic (hostile headers again).
fn mul(a: usize, b: usize, what: &str) -> anyhow::Result<usize> {
    a.checked_mul(b).ok_or_else(|| anyhow::anyhow!("{what}: size arithmetic overflow"))
}

/// Load an OJBQ1 checkpoint straight into the packed execution engine.
/// `name` labels the returned config (the header carries dimensions
/// only, matching OJBW1).
pub fn load_quantized(path: &Path, name: &str) -> anyhow::Result<QuantizedModel> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening checkpoint {path:?}: {e}"))?;
    let file_len = f.metadata()?.len();
    let mut r = Reader { r: std::io::BufReader::new(f), remaining: file_len };
    let magic = r.line()?;
    anyhow::ensure!(magic == MAGIC, "bad magic {magic:?} in {path:?} (expected {MAGIC})");
    let vline = r.line()?;
    let version: u32 = vline
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad version line {vline:?}: {e}"))?;
    anyhow::ensure!(
        version == VERSION,
        "unsupported {MAGIC} version {version} (this reader supports {VERSION})"
    );
    let cfg = parse_config_header(&r.line()?, name)?;
    // Each block's records need far more than one byte each; this guard
    // bounds the block loop (and its Vec growth) by the actual file.
    anyhow::ensure!(
        cfg.n_layers as u64 <= file_len,
        "declared {} blocks cannot fit in a {file_len}-byte file",
        cfg.n_layers
    );
    let embedding = read_dense(&mut r, "embedding", cfg.vocab_size, cfg.d_model)?;
    let mut blocks = Vec::new();
    for i in 0..cfg.n_layers {
        let attn_norm = read_dense(&mut r, &format!("b{i}.attn_norm"), 1, cfg.d_model)?;
        let mlp_norm = read_dense(&mut r, &format!("b{i}.mlp_norm"), 1, cfg.d_model)?;
        let mut linears = Vec::with_capacity(LinearKind::all().len());
        for &kind in LinearKind::all() {
            let (m, n) = linear_dims(&cfg, kind);
            linears.push(read_linear(&mut r, &format!("b{i}.{}", kind.name()), m, n)?);
        }
        blocks.push(QuantizedBlock::new(attn_norm.into_vec(), mlp_norm.into_vec(), linears));
    }
    let final_norm = read_dense(&mut r, "final_norm", 1, cfg.d_model)?.into_vec();
    let endline = r.line()?;
    anyhow::ensure!(endline == "end", "missing end marker (truncated checkpoint?)");
    anyhow::ensure!(
        r.remaining == 0,
        "{} trailing bytes after end marker (corrupt or concatenated checkpoint)",
        r.remaining
    );
    Ok(QuantizedModel { cfg, embedding, blocks, final_norm })
}

/// Record name line — canonical order is part of the format.
fn expect_name<R: BufRead>(r: &mut Reader<R>, name: &str) -> anyhow::Result<()> {
    let got = r.line()?;
    anyhow::ensure!(
        got == name,
        "expected tensor {name:?}, found {got:?} (layout drift or corruption)"
    );
    Ok(())
}

/// `rows cols\n` + payload, with the shape pinned by the caller.
fn read_f32_payload<R: BufRead>(
    r: &mut Reader<R>,
    what: &str,
    rows: usize,
    cols: usize,
) -> anyhow::Result<Matrix> {
    let shape = parse_usize_fields(&r.line()?, 2, "shape")?;
    anyhow::ensure!(
        shape[0] == rows && shape[1] == cols,
        "{what}: shape {}x{} does not match the config-implied {rows}x{cols}",
        shape[0],
        shape[1]
    );
    let count = mul(rows, cols, what)?;
    Ok(Matrix::from_vec(rows, cols, r.f32s(count, what)?))
}

/// A tensor that must be a dense record (norms, embedding).
fn read_dense<R: BufRead>(
    r: &mut Reader<R>,
    name: &str,
    rows: usize,
    cols: usize,
) -> anyhow::Result<Matrix> {
    expect_name(r, name)?;
    let kind = r.line()?;
    anyhow::ensure!(kind == "dense", "tensor {name}: expected a dense record, got tag {kind:?}");
    read_f32_payload(r, name, rows, cols)
}

/// A block linear: kind-tagged, dense fallback or packed tiles.
fn read_linear<R: BufRead>(
    r: &mut Reader<R>,
    name: &str,
    m: usize,
    n: usize,
) -> anyhow::Result<PackedLinear> {
    expect_name(r, name)?;
    let kind = r.line()?;
    match kind.as_str() {
        "dense" => Ok(PackedLinear::dense(read_f32_payload(r, name, m, n)?)),
        "packed" => read_packed_payload(r, name, m, n),
        other => anyhow::bail!("layer {name}: unknown kind tag {other:?}"),
    }
}

fn read_packed_payload<R: BufRead>(
    r: &mut Reader<R>,
    name: &str,
    em: usize,
    en: usize,
) -> anyhow::Result<PackedLinear> {
    let meta = parse_usize_fields(&r.line()?, 6, "packed meta")?;
    let (m, n, wbit, gs) = (meta[0], meta[1], meta[2], meta[3]);
    let (n_groups, perm_flag) = (meta[4], meta[5]);
    anyhow::ensure!(
        m == em && n == en,
        "{name}: packed dims {m}x{n} do not match the config-implied {em}x{en}"
    );
    anyhow::ensure!((1..=8).contains(&wbit), "{name}: unsupported wbit {wbit}");
    anyhow::ensure!((1..=m).contains(&gs), "{name}: group_size {gs} out of range for m={m}");
    anyhow::ensure!(
        n_groups == m.div_ceil(gs),
        "{name}: n_groups {n_groups} inconsistent with m={m} group_size={gs}"
    );
    anyhow::ensure!(perm_flag <= 1, "{name}: bad perm flag {perm_flag}");
    let table = mul(n_groups, n, name)?;
    let scales = Matrix::from_vec(n_groups, n, r.f32s(table, name)?);
    let corr = Matrix::from_vec(n_groups, n, r.f32s(table, name)?);
    let perm: Option<Vec<u32>> = if perm_flag == 1 {
        let raw = r.bytes(mul(m, 4, name)?, name)?;
        Some(raw.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    } else {
        None
    };
    let n_tiles = n.div_ceil(COL_TILE);
    let mut tiles = Vec::with_capacity(n_tiles);
    for t in 0..n_tiles {
        let wd = COL_TILE.min(n - t * COL_TILE);
        // ⌈m·wd·wbit/8⌉ — `quant::qtensor::packed_len`, overflow-checked.
        let bits = mul(mul(m, wd, name)?, wbit, name)?;
        tiles.push(r.bytes(bits.div_ceil(8), name)?);
    }
    let tiles = PackedTiles::from_parts(m, n, wbit as u8, gs, tiles, scales, corr, perm)
        .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
    Ok(PackedLinear::packed(tiles))
}

/// Load the seven packed linears of one block from a segment written by
/// [`save_block_segment`], with the same hardened reader discipline as
/// [`load_quantized`]: shapes pinned by `cfg`, allocations capped by
/// the actual file length, and `end` + no-trailing-bytes checks so
/// truncation or concatenation fails loudly.
pub fn load_block_segment(
    path: &Path,
    cfg: &crate::config::ModelConfig,
    block: usize,
) -> anyhow::Result<Vec<PackedLinear>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening segment {path:?}: {e}"))?;
    let file_len = f.metadata()?.len();
    let mut r = Reader { r: std::io::BufReader::new(f), remaining: file_len };
    let magic = r.line()?;
    anyhow::ensure!(magic == SEG_MAGIC, "bad magic {magic:?} in {path:?} (expected {SEG_MAGIC})");
    let bline = r.line()?;
    let got: usize = bline
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad segment block line {bline:?}: {e}"))?;
    anyhow::ensure!(got == block, "segment {path:?} holds block {got}, expected block {block}");
    let mut linears = Vec::with_capacity(LinearKind::all().len());
    for &kind in LinearKind::all() {
        let (m, n) = linear_dims(cfg, kind);
        linears.push(read_linear(&mut r, &format!("b{block}.{}", kind.name()), m, n)?);
    }
    let endline = r.line()?;
    anyhow::ensure!(endline == "end", "segment missing end marker (truncated?)");
    anyhow::ensure!(
        r.remaining == 0,
        "{} trailing bytes after segment end marker (corrupt segment)",
        r.remaining
    );
    Ok(linears)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Model;
    use crate::quant::{rtn, QuantConfig};
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ojbkq_test_infer_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn save_load_roundtrip_mixed_layers() {
        let cfg = ModelConfig {
            name: "rt".into(),
            vocab_size: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
        };
        let mut rng = Rng::new(0xB0);
        let model = Model::random(cfg, &mut rng);
        let mut qm = QuantizedModel::from_model(&model);
        let qcfg = QuantConfig { wbit: 4, group_size: 4, ..Default::default() };
        // Pack block 0 only: block 1 stays an FP-passthrough dense record.
        for &kind in LinearKind::all() {
            let id = crate::model::LinearId { block: 0, kind };
            let q = rtn::quantize(model.linear(id), &qcfg);
            qm.set_layer(id, PackedLinear::from_quantized(&q, true));
        }
        let path = tmp("mixed.ojbq1");
        let info = save_quantized(&qm, &path).unwrap();
        assert_eq!(info.weight_bytes, qm.packed_weight_bytes());
        assert!(info.file_bytes > info.weight_bytes as u64);
        let back = load_quantized(&path, "rt").unwrap();
        assert_eq!(back.packed_weight_bytes(), qm.packed_weight_bytes());
        for id in qm.linear_ids() {
            assert_eq!(back.layer(id).is_packed(), qm.layer(id).is_packed(), "{id}");
            assert_eq!(back.layer(id).to_dense(), qm.layer(id).to_dense(), "{id}");
        }
        let toks: Vec<u16> = vec![3, 7, 1, 0, 5];
        use crate::model::LanguageModel;
        assert_eq!(back.forward(&toks), qm.forward(&toks));
    }

    #[test]
    fn load_missing_file_is_err() {
        assert!(load_quantized(Path::new("/nonexistent/q.ojbq1"), "x").is_err());
    }

    #[test]
    fn block_segment_roundtrip_bit_exact() {
        // Crosses the io.segment_write fault site — serialize with the
        // tests that arm it.
        let _g = crate::robust::TEST_FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::robust::reset_faults();
        let cfg = ModelConfig {
            name: "seg".into(),
            vocab_size: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
        };
        let mut rng = Rng::new(0xB1);
        let model = Model::random(cfg, &mut rng);
        let mut qm = QuantizedModel::from_model(&model);
        let qcfg = QuantConfig { wbit: 3, group_size: 4, ..Default::default() };
        for &kind in LinearKind::all() {
            let id = crate::model::LinearId { block: 1, kind };
            let q = rtn::quantize(model.linear(id), &qcfg);
            qm.set_layer(id, PackedLinear::from_quantized(&q, true));
        }
        let path = tmp("block1.seg");
        save_block_segment(&qm, 1, &path).unwrap();
        let back = load_block_segment(&path, &qm.cfg, 1).unwrap();
        assert_eq!(back.len(), LinearKind::all().len());
        for ((kind, orig), loaded) in
            LinearKind::all().iter().zip(qm.blocks[1].linears()).zip(back.iter())
        {
            assert_eq!(loaded.is_packed(), orig.is_packed(), "{kind:?}");
            assert_eq!(loaded.to_dense(), orig.to_dense(), "{kind:?}");
        }
        // A segment only loads as the block it was written for.
        assert!(load_block_segment(&path, &qm.cfg, 0).is_err());
        assert!(save_block_segment(&qm, 9, &path).is_err());
    }
}
