//! Packed quantized inference engine — executing the transformer
//! directly from bit-packed integer codes.
//!
//! This is the deployment half of OJBKQ: the solver library
//! ([`crate::quant`]) produces per-layer codes + scale tables, and this
//! module runs `y = x·Ŵ` from them **without ever materializing the
//! dense f32 weight** (see DESIGN.md §Packed execution). Three layers:
//!
//! * [`PackedLinear`] — execution-ready layout converted once from a
//!   [`crate::quant::QuantizedLinear`]: codes bit-packed into column
//!   tiles, per-group scale and precomputed `s·z` correction tables, an
//!   optional decode-order row permutation (act-order solvers), and an
//!   explicit [`PackedLinear::bytes`] accounting hook. Transform methods
//!   (AWQ/QuIP) and FP passthrough keep a dense fallback.
//! * [`packed::qgemm_packed`] — blocked multi-row kernels that unpack
//!   each tile row once (table-driven LUT decode at the deployment
//!   widths) into a stack buffer and accumulate across the whole
//!   activation batch, parallelized over a row-block × column-tile grid
//!   so tall batched-capture stacks use every core.
//! * [`QuantizedModel`] — the packed twin of [`crate::model::Model`],
//!   mirroring the block-resident API (`embed_sequence` / `block_step` /
//!   `lm_head`, the six per-stage pieces, and the batched stage API
//!   `*_batch` + `block_step_batch` over a [`crate::tensor::RowBatch`])
//!   so the pipeline coordinator's runtime hidden-state cache advances
//!   through integer kernels one tall GEMM per stage, and the eval
//!   harnesses ([`crate::eval`]) score it through [`LanguageModel`] at
//!   4–8× lower weight memory.
//! * [`io`] — the native **OJBQ1** checkpoint format: [`save_quantized`]
//!   / [`load_quantized`] serialize the engine straight from the packed
//!   codes (no densify), so the on-disk artifact keeps the same 4–8×
//!   compression and `quantize --out` → `eval` round-trips bit for bit.
//!
//! Everything outside the seven per-block linears (embeddings, norms,
//! attention softmax, residuals) is shared arithmetic with the dense
//! model — [`QuantizedModel::from_model`] therefore reproduces
//! `Model::forward` bit for bit until layers are re-pointed at packed
//! codes via [`QuantizedModel::set_layer`].

pub mod io;
pub mod packed;

pub use io::{
    load_block_segment, load_quantized, save_block_segment, save_quantized, CheckpointInfo,
};
pub use packed::{
    packed_core, qgemm_packed, qgemm_packed_with, qgemv_packed, qgemv_packed_into,
    qgemv_packed_with, set_packed_core_override, GemvScratch, PackedCore, PackedLinear, COL_TILE,
};

use crate::config::ModelConfig;
use crate::linalg::matmul_par;
use crate::model::{
    causal_attention_batch, embed_tokens, rmsnorm, silu, LanguageModel, LinearId, LinearKind,
    Model,
};
use crate::tensor::{Matrix, RowBatch};

/// One transformer block of the packed engine: FP norms + seven
/// execution-ready linears (indexed in [`LinearKind::all`] order).
#[derive(Debug, Clone)]
pub struct QuantizedBlock {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    linears: Vec<PackedLinear>,
}

impl QuantizedBlock {
    /// Assemble a block from deserialized parts (the OJBQ1 checkpoint
    /// loader, [`crate::infer::io`]). `linears` must hold one layer per
    /// [`LinearKind::all`] slot, in that order.
    pub fn new(
        attn_norm: Vec<f32>,
        mlp_norm: Vec<f32>,
        linears: Vec<PackedLinear>,
    ) -> QuantizedBlock {
        assert_eq!(linears.len(), LinearKind::all().len(), "one linear per kind");
        QuantizedBlock { attn_norm, mlp_norm, linears }
    }

    /// All seven linears in [`LinearKind::all`] order.
    pub fn linears(&self) -> &[PackedLinear] {
        &self.linears
    }

    fn lin(&self, kind: LinearKind) -> &PackedLinear {
        &self.linears[kind.index()]
    }
}

/// The packed-execution model: embeddings and norms in f32, every linear
/// behind a [`PackedLinear`]. Mirrors the dense model's block-resident
/// forward API stage for stage.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    /// `vocab × d` token embedding (also the tied output head).
    pub embedding: Matrix,
    pub blocks: Vec<QuantizedBlock>,
    pub final_norm: Vec<f32>,
}

impl QuantizedModel {
    /// Start from a dense model: every linear is an FP passthrough, so
    /// the engine is numerically identical to `model` until layers are
    /// replaced with [`QuantizedModel::set_layer`].
    pub fn from_model(model: &Model) -> QuantizedModel {
        let blocks = (0..model.blocks.len())
            .map(|b| QuantizedBlock {
                attn_norm: model.blocks[b].attn_norm.clone(),
                mlp_norm: model.blocks[b].mlp_norm.clone(),
                linears: LinearKind::all()
                    .iter()
                    .map(|&kind| {
                        PackedLinear::dense(model.linear(LinearId { block: b, kind }).clone())
                    })
                    .collect(),
            })
            .collect();
        QuantizedModel {
            cfg: model.cfg.clone(),
            embedding: model.embedding.clone(),
            blocks,
            final_norm: model.final_norm.clone(),
        }
    }

    /// Borrow a layer.
    pub fn layer(&self, id: LinearId) -> &PackedLinear {
        self.blocks[id.block].lin(id.kind)
    }

    /// Replace a layer with its packed (or dense) execution form.
    pub fn set_layer(&mut self, id: LinearId, lin: PackedLinear) {
        let slot = &mut self.blocks[id.block].linears[id.kind.index()];
        assert_eq!(slot.shape(), lin.shape(), "layer {id} shape");
        *slot = lin;
    }

    /// All linear ids in quantization order.
    pub fn linear_ids(&self) -> Vec<LinearId> {
        let mut out = Vec::new();
        for block in 0..self.blocks.len() {
            for &kind in LinearKind::all() {
                out.push(LinearId { block, kind });
            }
        }
        out
    }

    /// Token embedding + positions (shared with the dense model).
    pub fn embed_sequence(&self, tokens: &[u16]) -> Matrix {
        embed_tokens(&self.embedding, &self.cfg, tokens)
    }

    /// Stage 1: post-attn-RMSNorm of the resident hidden state.
    pub fn attn_in(&self, hidden: &Matrix, block_idx: usize) -> Matrix {
        rmsnorm(hidden, &self.blocks[block_idx].attn_norm)
    }

    /// Stage 2: packed Q/K/V projections + causal attention.
    /// Single-sequence specialization of
    /// [`QuantizedModel::attn_ctx_batch`].
    pub fn attn_ctx(&self, attn_in: &Matrix, block_idx: usize) -> Matrix {
        self.attn_ctx_batch(attn_in, &[0, attn_in.rows()], block_idx)
    }

    /// Stage 3: packed output projection + attention residual.
    pub fn post_attn(&self, hidden: &Matrix, ctx: &Matrix, block_idx: usize) -> Matrix {
        hidden.add(&self.blocks[block_idx].lin(LinearKind::O).matmul(ctx))
    }

    /// Stage 4: post-mlp-RMSNorm.
    pub fn mlp_in(&self, x_mid: &Matrix, block_idx: usize) -> Matrix {
        rmsnorm(x_mid, &self.blocks[block_idx].mlp_norm)
    }

    /// Stage 5: SwiGLU over packed Gate/Up.
    pub fn mlp_act(&self, mlp_in: &Matrix, block_idx: usize) -> Matrix {
        let block = &self.blocks[block_idx];
        let g = block.lin(LinearKind::Gate).matmul(mlp_in);
        let u = block.lin(LinearKind::Up).matmul(mlp_in);
        Matrix::from_fn(mlp_in.rows(), self.cfg.d_ff, |i, j| silu(g.get(i, j)) * u.get(i, j))
    }

    /// Stage 6: packed down projection + MLP residual.
    pub fn post_mlp(&self, x_mid: &Matrix, act: &Matrix, block_idx: usize) -> Matrix {
        x_mid.add(&self.blocks[block_idx].lin(LinearKind::Down).matmul(act))
    }

    /// Advance a resident hidden state one block in place (composition of
    /// the six stages, same order as the dense model).
    pub fn block_step(&self, hidden: &mut Matrix, block_idx: usize) {
        let h = self.attn_in(hidden, block_idx);
        let ctx = self.attn_ctx(&h, block_idx);
        let x_mid = self.post_attn(hidden, &ctx, block_idx);
        let h2 = self.mlp_in(&x_mid, block_idx);
        let act = self.mlp_act(&h2, block_idx);
        *hidden = self.post_mlp(&x_mid, &act, block_idx);
    }

    /// Final RMSNorm + tied LM head.
    pub fn lm_head(&self, hidden: &Matrix) -> Matrix {
        let xf = rmsnorm(hidden, &self.final_norm);
        matmul_par(&xf, &self.embedding.transpose())
    }

    // ----- Batched stage API (mirrors `Model`'s) -----------------------
    //
    // One tall integer-kernel call per linear stage over a vstacked
    // hidden batch; only the causal softmax core runs per sequence. The
    // packed kernel's row-block × tile grid makes the tall call the
    // high-arithmetic-intensity path: each code row is unpacked once per
    // stage instead of once per sequence.

    /// Batched stage 1: RMSNorm of a stacked hidden batch (row-wise).
    pub fn attn_in_batch(&self, hidden: &Matrix, block_idx: usize) -> Matrix {
        self.attn_in(hidden, block_idx)
    }

    /// Batched stage 2: one tall packed Q/K/V GEMM triple + per-sequence
    /// causal cores over the `offsets` row ranges.
    pub fn attn_ctx_batch(&self, attn_in: &Matrix, offsets: &[usize], block_idx: usize) -> Matrix {
        let block = &self.blocks[block_idx];
        let q = block.lin(LinearKind::Q).matmul(attn_in);
        let k = block.lin(LinearKind::K).matmul(attn_in);
        let v = block.lin(LinearKind::V).matmul(attn_in);
        causal_attention_batch(&q, &k, &v, offsets, self.cfg.n_heads)
    }

    /// Batched stage 3: packed output projection + residual.
    pub fn post_attn_batch(&self, hidden: &Matrix, ctx: &Matrix, block_idx: usize) -> Matrix {
        self.post_attn(hidden, ctx, block_idx)
    }

    /// Batched stage 4: MLP RMSNorm over the stack.
    pub fn mlp_in_batch(&self, x_mid: &Matrix, block_idx: usize) -> Matrix {
        self.mlp_in(x_mid, block_idx)
    }

    /// Batched stage 5: SwiGLU with one tall packed Gate/Up GEMM pair.
    pub fn mlp_act_batch(&self, mlp_in: &Matrix, block_idx: usize) -> Matrix {
        self.mlp_act(mlp_in, block_idx)
    }

    /// Batched stage 6: packed down projection + residual.
    pub fn post_mlp_batch(&self, x_mid: &Matrix, act: &Matrix, block_idx: usize) -> Matrix {
        self.post_mlp(x_mid, act, block_idx)
    }

    /// Advance a whole stacked cache one block through the packed kernels
    /// — the batch-fused twin of [`QuantizedModel::block_step`],
    /// bit-identical to stepping each sequence separately.
    pub fn block_step_batch(&self, batch: &mut RowBatch, block_idx: usize) {
        let h = self.attn_in_batch(batch.data(), block_idx);
        let ctx = self.attn_ctx_batch(&h, batch.offsets(), block_idx);
        let x_mid = self.post_attn_batch(batch.data(), &ctx, block_idx);
        let h2 = self.mlp_in_batch(&x_mid, block_idx);
        let act = self.mlp_act_batch(&h2, block_idx);
        batch.set_data(self.post_mlp_batch(&x_mid, &act, block_idx));
    }

    /// Resident weight bytes of the engine (Σ [`PackedLinear::bytes`]
    /// over every linear) — the number behind the reported compression.
    pub fn packed_weight_bytes(&self) -> usize {
        self.blocks.iter().flat_map(|b| b.linears.iter().map(|l| l.bytes())).sum()
    }

    /// f32 payload bytes of the whole dense export (linears + embedding
    /// + norms) — what a dense OJBW1 save of [`QuantizedModel::to_dense`]
    /// writes, the denominator of the artifact-size comparison shown by
    /// the CLI and pinned by the ≤40%-of-dense checkpoint regression.
    pub fn dense_export_bytes(&self) -> usize {
        let norms: usize =
            self.blocks.iter().map(|b| b.attn_norm.len() + b.mlp_norm.len()).sum();
        self.fp_weight_bytes() + (self.embedding.len() + norms + self.final_norm.len()) * 4
    }

    /// f32 bytes of the same linears in dense form.
    pub fn fp_weight_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                b.linears.iter().map(|l| {
                    let (m, n) = l.shape();
                    m * n * 4
                })
            })
            .sum()
    }

    /// Export as a dense [`Model`] (dequantizes every packed layer) —
    /// cross-check (`--dense-out`) and parity-test support, not an
    /// execution path; native serialization is [`save_quantized`].
    pub fn to_dense(&self) -> Model {
        Model {
            cfg: self.cfg.clone(),
            embedding: self.embedding.clone(),
            final_norm: self.final_norm.clone(),
            blocks: self
                .blocks
                .iter()
                .map(|b| crate::model::Block {
                    attn_norm: b.attn_norm.clone(),
                    wq: b.lin(LinearKind::Q).to_dense(),
                    wk: b.lin(LinearKind::K).to_dense(),
                    wv: b.lin(LinearKind::V).to_dense(),
                    wo: b.lin(LinearKind::O).to_dense(),
                    mlp_norm: b.mlp_norm.clone(),
                    wgate: b.lin(LinearKind::Gate).to_dense(),
                    wup: b.lin(LinearKind::Up).to_dense(),
                    wdown: b.lin(LinearKind::Down).to_dense(),
                })
                .collect(),
        }
    }
}

impl LanguageModel for QuantizedModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn forward(&self, tokens: &[u16]) -> Matrix {
        let mut x = self.embed_sequence(tokens);
        for bi in 0..self.blocks.len() {
            self.block_step(&mut x, bi);
        }
        self.lm_head(&x)
    }

    fn forward_batch(&self, seqs: &[&[u16]]) -> Vec<Matrix> {
        crate::model::forward_batch_stacked(
            seqs,
            |s| self.embed_sequence(s),
            |batch, bi| self.block_step_batch(batch, bi),
            self.blocks.len(),
            |h| self.lm_head(h),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn, QuantConfig};
    use crate::rng::Rng;

    fn tiny() -> Model {
        let cfg = ModelConfig {
            name: "inf".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
        };
        let mut rng = Rng::new(0x1F);
        Model::random(cfg, &mut rng)
    }

    #[test]
    fn dense_passthrough_is_bit_exact() {
        let m = tiny();
        let qm = QuantizedModel::from_model(&m);
        let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
        assert!(qm.forward(&toks).rel_err(&m.forward(&toks)) < 1e-12);
        assert_eq!(qm.packed_weight_bytes(), qm.fp_weight_bytes());
        assert!(qm.to_dense().forward(&toks).rel_err(&m.forward(&toks)) < 1e-12);
    }

    #[test]
    fn packed_layers_shrink_memory_and_track_dense() {
        let m = tiny();
        let mut qm = QuantizedModel::from_model(&m);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        for id in qm.linear_ids() {
            let q = rtn::quantize(m.linear(id), &cfg);
            qm.set_layer(id, PackedLinear::from_quantized(&q, true));
            assert!(qm.layer(id).is_packed());
        }
        assert!(qm.packed_weight_bytes() < qm.fp_weight_bytes());
        // Packed forward tracks the dense dequantized model closely.
        let dense = qm.to_dense();
        let toks: Vec<u16> = vec![7, 2, 9, 11, 0, 5];
        let rel = qm.forward(&toks).rel_err(&dense.forward(&toks));
        assert!(rel < 1e-4, "rel={rel}");
    }

    #[test]
    fn stage_composition_matches_block_step() {
        let m = tiny();
        let qm = QuantizedModel::from_model(&m);
        let toks: Vec<u16> = vec![8, 6, 7, 5];
        let x0 = qm.embed_sequence(&toks);
        let h = qm.attn_in(&x0, 0);
        let ctx = qm.attn_ctx(&h, 0);
        let x_mid = qm.post_attn(&x0, &ctx, 0);
        let h2 = qm.mlp_in(&x_mid, 0);
        let act = qm.mlp_act(&h2, 0);
        let manual = qm.post_mlp(&x_mid, &act, 0);
        let mut x = x0.clone();
        qm.block_step(&mut x, 0);
        assert!(x.rel_err(&manual) < 1e-12);
    }

    #[test]
    fn forward_batch_matches_forward_on_packed_model() {
        let m = tiny();
        let mut qm = QuantizedModel::from_model(&m);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        // Mix of packed and dense-passthrough layers (only block 0 packed).
        for &kind in LinearKind::all() {
            let id = LinearId { block: 0, kind };
            let q = rtn::quantize(m.linear(id), &cfg);
            qm.set_layer(id, PackedLinear::from_quantized(&q, true));
        }
        let seqs: Vec<Vec<u16>> = vec![vec![3, 1, 4, 1, 5, 9], vec![2], vec![7, 2, 9, 11]];
        let refs: Vec<&[u16]> = seqs.iter().map(|s| s.as_slice()).collect();
        let batched = qm.forward_batch(&refs);
        for (s, got) in seqs.iter().zip(&batched) {
            assert_eq!(*got, LanguageModel::forward(&qm, s), "seq len {}", s.len());
        }
    }

    #[test]
    #[should_panic]
    fn set_layer_shape_mismatch_panics() {
        let m = tiny();
        let mut qm = QuantizedModel::from_model(&m);
        let id = LinearId { block: 0, kind: LinearKind::Down };
        qm.set_layer(id, PackedLinear::dense(Matrix::zeros(3, 3)));
    }
}
