//! Execution-ready packed weight layout + blocked integer kernels.
//!
//! [`PackedLinear`] is the deployment form of a solved
//! [`QuantizedLinear`]: integer codes bit-packed (via
//! [`crate::quant::qtensor::pack_bits`]) into **column tiles** of
//! [`COL_TILE`] outputs, alongside the per-group scale table and a
//! precomputed `s·z` correction table. The conversion happens once, after
//! the solver; from then on every matmul runs straight off the bitstream:
//!
//! `y_j = Σ_g s_{g,j} · (Σ_{i∈g} x_i·q_{ij}) − (s·z)_{g,j} · Σ_{i∈g} x_i`
//!
//! [`qgemm_packed`] is the blocked multi-row kernel behind
//! [`PackedLinear::matmul`]: per column tile, each packed code row is
//! unpacked **once** into a stack buffer and accumulated across the whole
//! activation batch (the row-at-a-time `qgemv` loop re-read every code
//! per activation row), with large batches parallelized over tiles via
//! [`crate::parallel`]. Act-order solvers (OJBKQ, GPTQ) keep their codes
//! in decode order; the kernel gathers activations through the recorded
//! row permutation instead of falling back to a dense weight. Genuine
//! dense transforms (AWQ's folded scaling, QuIP's rotations) and FP
//! passthrough layers use the [`PackedLinear::Dense`] fallback.

use crate::linalg::matmul;
use crate::parallel::parallel_map;
use crate::quant::qtensor::{pack_bits, unpack_bits_range};
use crate::quant::QuantizedLinear;
use crate::tensor::Matrix;

/// Output columns per packed tile — sized so one unpacked code row plus
/// the per-row accumulator live comfortably in registers / L1.
pub const COL_TILE: usize = 32;

/// Minimum `batch·m·n` product before [`qgemm_packed`] fans tiles out to
/// threads: the pipeline already parallelizes over calibration sequences
/// (whose per-step matrices are small), so the kernel only adds its own
/// parallelism for genuinely large single calls (eval batches, benches).
const PARALLEL_FLOPS_MIN: usize = 1 << 21;

/// Column-tiled bit-packed codes + scale/correction tables.
#[derive(Debug, Clone)]
pub struct PackedTiles {
    m: usize,
    n: usize,
    wbit: u8,
    group_size: usize,
    n_groups: usize,
    /// One little-endian bitstream per column tile; tile `t` holds the
    /// `m × width(t)` codes of columns `[t·COL_TILE, …)`, row-major.
    tiles: Vec<Vec<u8>>,
    /// Group scales `s`, `n_groups × n`.
    scales: Matrix,
    /// Precomputed correction table `s·z`, `n_groups × n`.
    corr: Matrix,
    /// Decode-order row permutation: code row `i` multiplies activation
    /// feature `perm[i]`.
    perm: Option<Vec<u32>>,
}

impl PackedTiles {
    fn from_quantized(q: &QuantizedLinear) -> PackedTiles {
        let (m, n) = (q.m, q.n);
        let n_tiles = n.div_ceil(COL_TILE);
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut tile_codes: Vec<u8> = Vec::with_capacity(m * COL_TILE);
        for t in 0..n_tiles {
            let c0 = t * COL_TILE;
            let w = COL_TILE.min(n - c0);
            tile_codes.clear();
            for i in 0..m {
                tile_codes.extend_from_slice(&q.codes[i * n + c0..i * n + c0 + w]);
            }
            tiles.push(pack_bits(&tile_codes, q.wbit));
        }
        PackedTiles {
            m,
            n,
            wbit: q.wbit,
            group_size: q.scales.group_size,
            n_groups: q.scales.n_groups(),
            tiles,
            scales: q.scales.scales.clone(),
            corr: q.scales.scales.hadamard(&q.scales.zeros),
            perm: q.perm.clone(),
        }
    }

    /// Resident bytes of the packed representation (codes + f32 tables +
    /// permutation) — what the execution engine actually holds in memory.
    fn bytes(&self) -> usize {
        let codes: usize = self.tiles.iter().map(|t| t.len()).sum();
        let tables = (self.scales.len() + self.corr.len()) * 4;
        let perm = self.perm.as_ref().map_or(0, |p| p.len() * 4);
        codes + tables + perm
    }

    /// Reconstruct the dense `m×n` runtime weight in original feature
    /// order: `ŵ = s·q − s·z` per cell, rows scattered through `perm`.
    fn to_dense(&self) -> Matrix {
        let mut deq = Matrix::zeros(self.m, self.n);
        let mut row_codes = [0u8; COL_TILE];
        for (ti, packed) in self.tiles.iter().enumerate() {
            let c0 = ti * COL_TILE;
            let w = COL_TILE.min(self.n - c0);
            for i in 0..self.m {
                let g = i / self.group_size;
                unpack_bits_range(packed, self.wbit, i * w, &mut row_codes[..w]);
                let drow = &mut deq.row_mut(i)[c0..c0 + w];
                for (jj, slot) in drow.iter_mut().enumerate() {
                    *slot = self.scales.get(g, c0 + jj) * row_codes[jj] as f32
                        - self.corr.get(g, c0 + jj);
                }
            }
        }
        match &self.perm {
            None => deq,
            Some(p) => {
                let mut out = Matrix::zeros(self.m, self.n);
                for i in 0..self.m {
                    out.row_mut(p[i] as usize).copy_from_slice(deq.row(i));
                }
                out
            }
        }
    }
}

/// An execution-ready linear layer: packed integer codes or a dense f32
/// fallback. Conversion from the solver output happens once
/// ([`PackedLinear::from_quantized`]); the capture/eval hot path never
/// materializes dense weights for packed layers.
#[derive(Debug, Clone)]
pub enum PackedLinear {
    /// Bit-packed integer execution (RTN, Babai/Klein/OJBKQ, GPTQ —
    /// including act-order layers, via the recorded row permutation).
    Packed(PackedTiles),
    /// Dense f32 execution: FP passthrough layers and transform methods
    /// whose runtime weight is not `S⊙(Q−Z)` in any feature order
    /// (AWQ, QuIP).
    Dense(Matrix),
}

impl PackedLinear {
    /// Convert a solved layer into execution form. With `packed_exec`
    /// false everything becomes a dense splice (the numerically exact
    /// legacy mode).
    pub fn from_quantized(q: &QuantizedLinear, packed_exec: bool) -> PackedLinear {
        if !packed_exec || q.wbit == 0 || (q.effective.is_some() && q.perm.is_none()) {
            return PackedLinear::Dense(q.dequantize());
        }
        PackedLinear::Packed(PackedTiles::from_quantized(q))
    }

    /// Wrap a dense weight (FP passthrough).
    pub fn dense(w: Matrix) -> PackedLinear {
        PackedLinear::Dense(w)
    }

    /// `(m, n)` = (input features, output features).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            PackedLinear::Packed(t) => (t.m, t.n),
            PackedLinear::Dense(w) => w.shape(),
        }
    }

    /// True when this layer executes through the integer kernel.
    pub fn is_packed(&self) -> bool {
        matches!(self, PackedLinear::Packed(_))
    }

    /// Resident memory of this layer inside the execution engine.
    pub fn bytes(&self) -> usize {
        match self {
            PackedLinear::Packed(t) => t.bytes(),
            PackedLinear::Dense(w) => w.len() * 4,
        }
    }

    /// Dense `m×n` runtime weight (original feature order) — export and
    /// test support, not the execution path.
    pub fn to_dense(&self) -> Matrix {
        match self {
            PackedLinear::Packed(t) => t.to_dense(),
            PackedLinear::Dense(w) => w.clone(),
        }
    }

    /// `Y = X · Ŵ` for a batch of activation rows.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            PackedLinear::Packed(t) => qgemm_packed(t, x),
            PackedLinear::Dense(w) => matmul(x, w),
        }
    }
}

/// Blocked multi-row quantized GEMM over the tiled bitstream.
pub fn qgemm_packed(t: &PackedTiles, x: &Matrix) -> Matrix {
    assert_eq!(x.cols(), t.m, "activation/layer shape mismatch");
    let b = x.rows();
    // Gather activations into decode order once per call; every tile then
    // reads the same permuted view.
    let gathered;
    let xp: &Matrix = match &t.perm {
        Some(p) => {
            gathered = Matrix::from_fn(b, t.m, |r, i| x.get(r, p[i] as usize));
            &gathered
        }
        None => x,
    };
    // Per-group activation sums (the z-correction operand), `b × groups`.
    let mut gsum = Matrix::zeros(b, t.n_groups);
    for r in 0..b {
        let row = xp.row(r);
        let grow = gsum.row_mut(r);
        for (i, &v) in row.iter().enumerate() {
            grow[i / t.group_size] += v;
        }
    }
    let n_tiles = t.tiles.len();
    let tile_out: Vec<Matrix> = if n_tiles > 1 && b * t.m * t.n >= PARALLEL_FLOPS_MIN {
        parallel_map(n_tiles, |ti| tile_matmul(t, xp, &gsum, ti))
    } else {
        (0..n_tiles).map(|ti| tile_matmul(t, xp, &gsum, ti)).collect()
    };
    let mut y = Matrix::zeros(b, t.n);
    for (ti, block) in tile_out.iter().enumerate() {
        y.set_block(0, ti * COL_TILE, block);
    }
    y
}

/// One output tile: unpack each code row once, accumulate across the
/// whole batch, then apply the per-group scale/correction.
fn tile_matmul(t: &PackedTiles, xp: &Matrix, gsum: &Matrix, ti: usize) -> Matrix {
    let c0 = ti * COL_TILE;
    let w = COL_TILE.min(t.n - c0);
    let b = xp.rows();
    let packed = &t.tiles[ti];
    let mut out = Matrix::zeros(b, w);
    let mut acc = vec![0.0f32; b * w];
    let mut row_codes = [0u8; COL_TILE];
    let mut codes_f = [0.0f32; COL_TILE];
    for g in 0..t.n_groups {
        acc.fill(0.0);
        let r0 = g * t.group_size;
        let r1 = (r0 + t.group_size).min(t.m);
        for i in r0..r1 {
            unpack_bits_range(packed, t.wbit, i * w, &mut row_codes[..w]);
            for (cf, &c) in codes_f[..w].iter_mut().zip(&row_codes[..w]) {
                *cf = c as f32;
            }
            for r in 0..b {
                let xv = xp.get(r, i);
                if xv == 0.0 {
                    continue;
                }
                let arow = &mut acc[r * w..r * w + w];
                for (a, &cf) in arow.iter_mut().zip(&codes_f[..w]) {
                    *a += xv * cf;
                }
            }
        }
        for r in 0..b {
            let gsv = gsum.get(r, g);
            let orow = out.row_mut(r);
            let arow = &acc[r * w..r * w + w];
            for (jj, o) in orow.iter_mut().enumerate() {
                *o += t.scales.get(g, c0 + jj) * arow[jj] - t.corr.get(g, c0 + jj) * gsv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{gptq, rtn, QuantConfig};
    use crate::rng::Rng;

    fn rand_layer(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x = Matrix::randn(7, m, 1.0, &mut rng);
        (w, x)
    }

    #[test]
    fn packed_matmul_matches_dequantized_gemm() {
        // Ragged groups (m % gs ≠ 0) and ragged tiles (n % COL_TILE ≠ 0)
        // across every supported low bit-width.
        for &wbit in &[2u8, 3, 4] {
            for &(m, n, gs) in &[(48usize, 40usize, 16usize), (33, 37, 12), (20, 5, 0)] {
                let (w, x) = rand_layer(m, n, wbit as u64 * 100 + m as u64);
                let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
                let q = rtn::quantize(&w, &cfg);
                let p = PackedLinear::from_quantized(&q, true);
                assert!(p.is_packed());
                let dense = matmul(&x, &q.dequantize());
                let packed = p.matmul(&x);
                assert!(
                    packed.rel_err(&dense) < 1e-4,
                    "wbit={wbit} m={m} n={n} gs={gs}: rel={}",
                    packed.rel_err(&dense)
                );
            }
        }
    }

    #[test]
    fn act_order_perm_runs_packed_and_matches_effective() {
        let (w, x) = rand_layer(40, 24, 9);
        let cfg = QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
        let q = gptq::quantize(&w, &x, &cfg).unwrap();
        assert!(q.perm.is_some() && q.effective.is_some());
        let p = PackedLinear::from_quantized(&q, true);
        assert!(p.is_packed(), "perm layers must run on the integer kernel");
        let dense = matmul(&x, &q.dequantize()); // effective, original order
        let packed = p.matmul(&x);
        assert!(packed.rel_err(&dense) < 1e-4, "rel={}", packed.rel_err(&dense));
        // And the dense reconstruction agrees with the solver's effective.
        assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-5);
    }

    #[test]
    fn effective_without_perm_falls_back_dense() {
        let (w, x) = rand_layer(24, 12, 3);
        let mut q = rtn::quantize(&w, &QuantConfig::default());
        q.effective = Some(w.clone()); // a transform folded here (AWQ/QuIP)
        let p = PackedLinear::from_quantized(&q, true);
        assert!(!p.is_packed());
        assert_eq!(p.matmul(&x), matmul(&x, &w));
        assert_eq!(p.bytes(), 24 * 12 * 4);
    }

    #[test]
    fn packed_exec_off_splices_dense() {
        let (w, _) = rand_layer(16, 8, 4);
        let q = rtn::quantize(&w, &QuantConfig { wbit: 4, group_size: 8, ..Default::default() });
        let p = PackedLinear::from_quantized(&q, false);
        assert!(!p.is_packed());
        assert_eq!(p.to_dense(), q.dequantize());
    }

    #[test]
    fn to_dense_matches_dequantize() {
        for &(gs, wbit) in &[(16usize, 4u8), (12, 3), (0, 2)] {
            let (w, _) = rand_layer(48, 37, gs as u64 + wbit as u64);
            let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
            let q = rtn::quantize(&w, &cfg);
            let p = PackedLinear::from_quantized(&q, true);
            // `s·q − s·z` vs `s·(q−z)`: identical up to one f32 rounding.
            assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-6);
        }
    }

    #[test]
    fn resident_bytes_beat_f32_by_4x_at_w4() {
        let (w, _) = rand_layer(256, 64, 7);
        let cfg = QuantConfig { wbit: 4, group_size: 128, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let fp = 256 * 64 * 4;
        assert!(
            p.bytes() * 4 <= fp,
            "resident {} vs fp {} (ratio {:.2})",
            p.bytes(),
            fp,
            fp as f64 / p.bytes() as f64
        );
    }

    #[test]
    fn zero_activation_batch_short_circuits() {
        let (w, _) = rand_layer(24, 6, 5);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let p = PackedLinear::from_quantized(&rtn::quantize(&w, &cfg), true);
        let y = p.matmul(&Matrix::zeros(3, 24));
        assert!(y.as_slice().iter().all(|&v| v == 0.0));
    }
}
