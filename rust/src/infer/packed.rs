//! Execution-ready packed weight layout + blocked integer kernels.
//!
//! [`PackedLinear`] is the deployment form of a solved
//! [`QuantizedLinear`]: integer codes bit-packed (via
//! [`crate::quant::qtensor::pack_bits`]) into **column tiles** of
//! [`COL_TILE`] outputs — each tile stream word-aligned (padded to a
//! multiple of 8 bytes) so the u64 bit-sliced unpack of
//! [`unpack_bits_range`] never straddles the buffer end — alongside the
//! per-group scale table and a precomputed `s·z` correction table. The
//! conversion happens once, after the solver; from then on every matmul
//! runs straight off the bitstream:
//!
//! `y_j = Σ_g s_{g,j} · (Σ_{i∈g} x_i·q_{ij}) − (s·z)_{g,j} · Σ_{i∈g} x_i`
//!
//! [`qgemm_packed`] evaluates this through one of two cores (see
//! DESIGN.md §Integer-core packed GEMM):
//!
//! * **Integer core** (default, [`PackedCore::Int`]): activations are
//!   quantized once per `(row, group)` onto a fixed-point grid
//!   (`x̂ᵢ = round(xᵢ/a)`, `a = max|x|/A` with amplitude `A ≤ 32767`
//!   budgeted so `code·group_size·A < 2³¹`), the inner loop is a pure
//!   `i32 += i16·i16` multiply-accumulate over raw codes, and the f32
//!   scale/correction is applied **once per group boundary**:
//!   `y_j += a·(s_{g,j}·acc_j − (s·z)_{g,j}·Σx̂)`. Integer accumulation
//!   is exact, so results are bit-identical under any blocking or
//!   thread count by construction.
//! * **f32 reference core** ([`PackedCore::F32`], `OJBKQ_F32_CORE=1` or
//!   [`set_packed_core_override`]): the PR-2/3 kernel — per-code
//!   `u8→f32` convert (hoisted into a per-panel pass) and f32 FMA —
//!   kept bit-identical to its historical output as the parity
//!   reference, mirroring the dense-exec escape hatch.
//!
//! Both cores run **cache-blocked microkernels** over a
//! [`ROW_BLOCK`] × [`COL_TILE`] grid: per grid cell, code rows are
//! unpacked once per [`PANEL_ROWS`]-row panel (u64 word loads, many
//! codes per shift) into a stack buffer sized for L1, and the integer
//! core walks a **contiguous** activation panel (the decode-order
//! permutation of act-order solvers is resolved once in the
//! quantization prologue — no column-strided `x.get` and no per-element
//! zero test inside the MAC loop). Tall (batched-capture) inputs
//! parallelize over grid cells via [`crate::parallel`]; the per-row
//! activation prologue (group sums / fixed-point quantization)
//! parallelizes over row chunks on the same threshold. Single-row calls
//! take the register-resident [`qgemv_packed`] path. Genuine dense
//! transforms (AWQ's folded scaling, QuIP's rotations) and FP
//! passthrough layers use the [`PackedLinear::Dense`] fallback.

use crate::linalg::matmul_par;
use crate::parallel::{parallel_for_chunks, parallel_map_dynamic};
use crate::quant::qtensor::{pack_bits, packed_len, unpack_bits_range};
use crate::quant::QuantizedLinear;
use crate::tensor::Matrix;
use std::sync::atomic::{AtomicU8, Ordering};

/// Output columns per packed tile — sized so one unpacked code row plus
/// the per-row accumulator live comfortably in registers / L1.
pub const COL_TILE: usize = 32;

/// Activation rows per parallel grid cell: tall (batched-capture) inputs
/// are split into row blocks so the kernel parallelizes over
/// **row blocks × column tiles**, not tiles alone — with a handful of
/// tiles and a tall stacked batch, tile-only fan-out left most cores
/// idle.
pub const ROW_BLOCK: usize = 64;

/// Code rows unpacked per microkernel panel: a `PANEL_ROWS × COL_TILE`
/// i16 code panel (4 KiB) plus one activation slice stay L1-resident
/// while every activation row of the grid cell streams across it, so
/// each code is unpacked once per cell regardless of group size.
pub const PANEL_ROWS: usize = 64;

/// Minimum `batch·m·n` product before [`qgemm_packed`] fans grid cells
/// (and the per-row activation prologue) out to threads. Re-tuned for
/// the batch-fused capture path: the coordinator issues one tall call
/// per stage instead of parallelizing over per-sequence calls, so the
/// kernel parallelizes earlier than the PR-2 tile-only threshold.
const PARALLEL_FLOPS_MIN: usize = 1 << 20;

/// Hard cap on the fixed-point activation amplitude: `i16` storage.
const ACT_AMP_MAX: u64 = i16::MAX as u64;

// ----- core selection -------------------------------------------------

/// Which arithmetic core the packed kernels run — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackedCore {
    /// Integer core (default): i32 group accumulation of raw codes
    /// against fixed-point activations, f32 touched once per group.
    Int,
    /// f32 reference core: the PR-2/3 per-code dequantize-and-FMA
    /// kernel, kept bit-identical as the parity baseline
    /// (`OJBKQ_F32_CORE=1` / `--f32-core`).
    F32,
}

/// Process-wide core override: 0 = unset (env decides), 1 = Int,
/// 2 = F32. Mirrors `parallel::set_thread_override` — a race-free
/// runtime toggle for tests and the CLI, taking precedence over the
/// `OJBKQ_F32_CORE` environment default.
static CORE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force (or un-force, with `None`) the packed-kernel core for this
/// process, overriding `OJBKQ_F32_CORE`.
pub fn set_packed_core_override(core: Option<PackedCore>) {
    let v = match core {
        None => 0,
        Some(PackedCore::Int) => 1,
        Some(PackedCore::F32) => 2,
    };
    CORE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The core [`qgemm_packed`] / [`qgemv_packed`] dispatch to: the
/// override if set, else the `OJBKQ_F32_CORE` environment default
/// (read once), else the integer core.
pub fn packed_core() -> PackedCore {
    match CORE_OVERRIDE.load(Ordering::Relaxed) {
        1 => PackedCore::Int,
        2 => PackedCore::F32,
        _ => {
            static ENV_F32: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
            let f32_core = *ENV_F32.get_or_init(|| {
                matches!(
                    std::env::var("OJBKQ_F32_CORE").as_deref(),
                    Ok("1") | Ok("true") | Ok("yes")
                )
            });
            if f32_core {
                PackedCore::F32
            } else {
                PackedCore::Int
            }
        }
    }
}

/// Pad a bitstream to a multiple of 8 bytes (zero fill) so u64 word
/// loads starting at any in-range byte stay inside the allocation.
fn pad_word_aligned(mut stream: Vec<u8>) -> Vec<u8> {
    stream.resize(stream.len().div_ceil(8) * 8, 0);
    stream
}

/// Column-tiled bit-packed codes + scale/correction tables.
#[derive(Debug, Clone)]
pub struct PackedTiles {
    m: usize,
    n: usize,
    wbit: u8,
    group_size: usize,
    n_groups: usize,
    /// One little-endian bitstream per column tile; tile `t` holds the
    /// `m × width(t)` codes of columns `[t·COL_TILE, …)`, row-major.
    /// Streams are word-aligned: padded with zero bytes to a multiple
    /// of 8 so the u64 bit-sliced unpack never reads past the end. The
    /// serialized form is the unpadded prefix ([`PackedTiles::tile_payload`]).
    tiles: Vec<Vec<u8>>,
    /// Group scales `s`, `n_groups × n`.
    scales: Matrix,
    /// Precomputed correction table `s·z`, `n_groups × n`.
    corr: Matrix,
    /// Decode-order row permutation: code row `i` multiplies activation
    /// feature `perm[i]`.
    perm: Option<Vec<u32>>,
}

impl PackedTiles {
    fn from_quantized(q: &QuantizedLinear) -> PackedTiles {
        let (m, n) = (q.m, q.n);
        let n_tiles = n.div_ceil(COL_TILE);
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut tile_codes: Vec<u8> = Vec::with_capacity(m * COL_TILE);
        for t in 0..n_tiles {
            let c0 = t * COL_TILE;
            let w = COL_TILE.min(n - c0);
            tile_codes.clear();
            for i in 0..m {
                tile_codes.extend_from_slice(&q.codes[i * n + c0..i * n + c0 + w]);
            }
            tiles.push(pad_word_aligned(pack_bits(&tile_codes, q.wbit)));
        }
        PackedTiles {
            m,
            n,
            wbit: q.wbit,
            group_size: q.scales.group_size,
            n_groups: q.scales.n_groups(),
            tiles,
            scales: q.scales.scales.clone(),
            corr: q.scales.scales.hadamard(&q.scales.zeros),
            perm: q.perm.clone(),
        }
    }

    /// Reassemble tiles from deserialized parts (the OJBQ1 checkpoint
    /// loader, `crate::infer::io`), validating every structural invariant
    /// the kernels rely on — group layout, tile count and per-tile
    /// bitstream length, table shapes, and (when present) that `perm` is
    /// a genuine permutation of `0..m`. A hostile or corrupted checkpoint
    /// therefore fails here with `Err`, never as an index panic inside
    /// [`qgemm_packed`]. Tiles are accepted at the serialized (logical)
    /// length or already word-aligned, and stored word-aligned.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        m: usize,
        n: usize,
        wbit: u8,
        group_size: usize,
        tiles: Vec<Vec<u8>>,
        scales: Matrix,
        corr: Matrix,
        perm: Option<Vec<u32>>,
    ) -> anyhow::Result<PackedTiles> {
        anyhow::ensure!(m >= 1 && n >= 1, "empty packed layer {m}x{n}");
        anyhow::ensure!((1..=8).contains(&wbit), "unsupported wbit {wbit}");
        anyhow::ensure!(
            (1..=m).contains(&group_size),
            "group_size {group_size} out of range for m={m}"
        );
        let n_groups = m.div_ceil(group_size);
        anyhow::ensure!(
            scales.shape() == (n_groups, n),
            "scale table shape {:?} != ({n_groups}, {n})",
            scales.shape()
        );
        anyhow::ensure!(
            corr.shape() == (n_groups, n),
            "correction table shape {:?} != ({n_groups}, {n})",
            corr.shape()
        );
        let n_tiles = n.div_ceil(COL_TILE);
        anyhow::ensure!(tiles.len() == n_tiles, "{} tiles, expected {n_tiles}", tiles.len());
        for (t, tile) in tiles.iter().enumerate() {
            let w = COL_TILE.min(n - t * COL_TILE);
            let want = packed_len(m * w, wbit);
            anyhow::ensure!(
                tile.len() == want || tile.len() == want.div_ceil(8) * 8,
                "tile {t} holds {} bytes, expected {want}",
                tile.len()
            );
        }
        let tiles = tiles.into_iter().map(pad_word_aligned).collect();
        if let Some(p) = &perm {
            anyhow::ensure!(p.len() == m, "perm length {} != m={m}", p.len());
            let mut seen = vec![false; m];
            for &pi in p {
                let i = pi as usize;
                anyhow::ensure!(i < m, "perm entry {pi} out of range for m={m}");
                anyhow::ensure!(!seen[i], "perm entry {pi} duplicated");
                seen[i] = true;
            }
        }
        Ok(PackedTiles { m, n, wbit, group_size, n_groups, tiles, scales, corr, perm })
    }

    /// `(m, n)` = (input features, output features).
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// Code bit width.
    pub fn wbit(&self) -> u8 {
        self.wbit
    }

    /// Rows per scale group (the last group may be short).
    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Per-tile bit-packed code streams, in column-tile order —
    /// word-aligned resident form (see [`PackedTiles::tile_payload`] for
    /// the serialized prefix).
    pub fn tiles(&self) -> &[Vec<u8>] {
        &self.tiles
    }

    /// The logical (unpadded) bitstream of tile `t` — exactly
    /// `packed_len` bytes, what the OJBQ1 checkpoint serializes. The
    /// word-alignment pad is a resident-layout detail and never hits
    /// disk, keeping the on-disk format byte-stable.
    pub fn tile_payload(&self, t: usize) -> &[u8] {
        let w = COL_TILE.min(self.n - t * COL_TILE);
        &self.tiles[t][..packed_len(self.m * w, self.wbit)]
    }

    /// Group scale table `s`, `n_groups × n`.
    pub fn scales(&self) -> &Matrix {
        &self.scales
    }

    /// Precomputed correction table `s·z`, `n_groups × n`.
    pub fn corr(&self) -> &Matrix {
        &self.corr
    }

    /// Decode-order row permutation, when the solver recorded one.
    pub fn perm(&self) -> Option<&[u32]> {
        self.perm.as_deref()
    }

    /// Resident bytes of the packed representation (word-aligned code
    /// streams + f32 tables + permutation) — what the execution engine
    /// actually holds in memory, including the ≤7 alignment pad bytes
    /// per tile stream.
    fn bytes(&self) -> usize {
        let codes: usize = self.tiles.iter().map(|t| t.len()).sum();
        let tables = (self.scales.len() + self.corr.len()) * 4;
        let perm = self.perm.as_ref().map_or(0, |p| p.len() * 4);
        codes + tables + perm
    }

    /// Reconstruct the dense `m×n` runtime weight in original feature
    /// order: `ŵ = s·q − s·z` per cell, rows scattered through `perm`.
    fn to_dense(&self) -> Matrix {
        let mut deq = Matrix::zeros(self.m, self.n);
        let mut row_codes = [0u8; COL_TILE];
        for (ti, packed) in self.tiles.iter().enumerate() {
            let c0 = ti * COL_TILE;
            let w = COL_TILE.min(self.n - c0);
            for i in 0..self.m {
                let g = i / self.group_size;
                unpack_bits_range(packed, self.wbit, i * w, &mut row_codes[..w]);
                let drow = &mut deq.row_mut(i)[c0..c0 + w];
                for (jj, slot) in drow.iter_mut().enumerate() {
                    *slot = self.scales.get(g, c0 + jj) * row_codes[jj] as f32
                        - self.corr.get(g, c0 + jj);
                }
            }
        }
        match &self.perm {
            None => deq,
            Some(p) => {
                let mut out = Matrix::zeros(self.m, self.n);
                for i in 0..self.m {
                    out.row_mut(p[i] as usize).copy_from_slice(deq.row(i));
                }
                out
            }
        }
    }
}

/// An execution-ready linear layer: packed integer codes or a dense f32
/// fallback. Conversion from the solver output happens once
/// ([`PackedLinear::from_quantized`]); the capture/eval hot path never
/// materializes dense weights for packed layers.
#[derive(Debug, Clone)]
pub enum PackedLinear {
    /// Bit-packed integer execution (RTN, Babai/Klein/OJBKQ, GPTQ —
    /// including act-order layers, via the recorded row permutation).
    Packed(PackedTiles),
    /// Dense f32 execution: FP passthrough layers and transform methods
    /// whose runtime weight is not `S⊙(Q−Z)` in any feature order
    /// (AWQ, QuIP).
    Dense(Matrix),
}

impl PackedLinear {
    /// Convert a solved layer into execution form. With `packed_exec`
    /// false everything becomes a dense splice (the numerically exact
    /// legacy mode).
    pub fn from_quantized(q: &QuantizedLinear, packed_exec: bool) -> PackedLinear {
        if !packed_exec || q.wbit == 0 || (q.effective.is_some() && q.perm.is_none()) {
            return PackedLinear::Dense(q.dequantize());
        }
        PackedLinear::Packed(PackedTiles::from_quantized(q))
    }

    /// Wrap a dense weight (FP passthrough).
    pub fn dense(w: Matrix) -> PackedLinear {
        PackedLinear::Dense(w)
    }

    /// Wrap already-validated tiles (checkpoint deserialization).
    pub fn packed(tiles: PackedTiles) -> PackedLinear {
        PackedLinear::Packed(tiles)
    }

    /// Borrow the tiled representation of a packed layer.
    pub fn as_packed(&self) -> Option<&PackedTiles> {
        match self {
            PackedLinear::Packed(t) => Some(t),
            PackedLinear::Dense(_) => None,
        }
    }

    /// `(m, n)` = (input features, output features).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            PackedLinear::Packed(t) => (t.m, t.n),
            PackedLinear::Dense(w) => w.shape(),
        }
    }

    /// True when this layer executes through the integer kernel.
    pub fn is_packed(&self) -> bool {
        matches!(self, PackedLinear::Packed(_))
    }

    /// Resident memory of this layer inside the execution engine.
    pub fn bytes(&self) -> usize {
        match self {
            PackedLinear::Packed(t) => t.bytes(),
            PackedLinear::Dense(w) => w.len() * 4,
        }
    }

    /// Dense `m×n` runtime weight (original feature order) — export and
    /// test support, not the execution path.
    pub fn to_dense(&self) -> Matrix {
        match self {
            PackedLinear::Packed(t) => t.to_dense(),
            PackedLinear::Dense(w) => w.clone(),
        }
    }

    /// `Y = X · Ŵ` for a batch of activation rows. Both legs parallelize
    /// internally on tall inputs (grid cells for packed codes, row blocks
    /// for the dense fallback), so batched-capture stacks run one big
    /// call instead of per-sequence fan-out. Single activation rows take
    /// the [`qgemv_packed`] register path.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            PackedLinear::Packed(t) => qgemm_packed(t, x),
            PackedLinear::Dense(w) => {
                crate::obs::counter_add("qgemm.dense_calls", 1);
                matmul_par(x, w)
            }
        }
    }

    /// `y = x · Ŵ` for a single activation row, written into `out` — the
    /// decode hot path of [`crate::serve`]. Bit-identical to
    /// `self.matmul(x_as_1row).row(0)` on every leg:
    ///
    /// * **Packed, integer core**: [`qgemv_packed_into`] — quantize the
    ///   row into the caller's [`GemvScratch`] and run the register
    ///   kernel; **zero allocations** after scratch warm-up.
    /// * **Packed, f32 reference core**: the shared [`qgemm_packed_with`]
    ///   grid kernel at `bl = 1` (the parity leg keeps its one set of
    ///   numerics; it allocates, but it is not the deployment core).
    /// * **Dense fallback**: [`crate::linalg::row_matmul_into`], the
    ///   `m = 1` specialization of the blocked GEMM.
    pub fn gemv_into(&self, x: &[f32], scratch: &mut GemvScratch, out: &mut [f32]) {
        match self {
            PackedLinear::Packed(t) => match packed_core() {
                PackedCore::Int => qgemv_packed_into(t, x, scratch, out),
                PackedCore::F32 => {
                    let xm = Matrix::from_vec(1, t.m, x.to_vec());
                    let y = qgemm_packed_with(t, &xm, PackedCore::F32);
                    out.copy_from_slice(y.row(0));
                }
            },
            PackedLinear::Dense(w) => {
                crate::obs::counter_add("qgemm.dense_calls", 1);
                crate::linalg::row_matmul_into(x, w, out);
            }
        }
    }
}

// ----- integer core ---------------------------------------------------

/// Fixed-point activation panel, built once per [`qgemm_packed`] call by
/// the quantization prologue and shared (read-only) by every grid cell.
/// Rows are stored in **decode order** — the act-order permutation is
/// resolved here, once, so the microkernel walks contiguous memory.
#[derive(Debug, Default)]
struct IntActPanel {
    /// `b × m` quantized activations `x̂ = round(x/a)`, row-major,
    /// decode order.
    xq: Vec<i16>,
    /// `b × n_groups` dequantization scales `a = max|x|/A` (0 for
    /// all-zero groups).
    ascale: Vec<f32>,
    /// `b × n_groups` integer group sums `Σ_{i∈g} x̂ᵢ` — the exact
    /// z-correction operand.
    gisum: Vec<i32>,
}

/// Fixed-point amplitude `A` for a layer: as large as i16 storage
/// allows, shrunk when huge groups × wide codes would overflow the i32
/// accumulator — `A·(2^wbit−1)·group_size < 2³¹` guarantees
/// `Σ_{i∈g} x̂ᵢ·q_{ij}` fits with the sign bit to spare.
fn act_amp(t: &PackedTiles) -> f32 {
    let maxcode = ((1u32 << t.wbit) - 1) as u64;
    let gs = t.group_size.clamp(1, t.m) as u64;
    ((i32::MAX as u64) / (maxcode * gs)).clamp(1, ACT_AMP_MAX) as f32
}

/// Quantize one activation row onto the fixed-point grid — the per-row
/// body of [`quantize_act_rows`], also the prologue of the scratch-arena
/// decode path ([`qgemv_packed_into`]), so batched and single-token
/// activation quantization share one code path by construction.
fn quantize_act_row(
    t: &PackedTiles,
    row: &[f32],
    amp: f32,
    qrow: &mut [i16],
    arow: &mut [f32],
    grow: &mut [i32],
) {
    let (m, gsz, n_groups) = (t.m, t.group_size, t.n_groups);
    let perm = t.perm.as_deref();
    debug_assert_eq!(row.len(), m);
    debug_assert_eq!(qrow.len(), m);
    debug_assert_eq!(arow.len(), n_groups);
    debug_assert_eq!(grow.len(), n_groups);
    for g in 0..n_groups {
        let i0 = g * gsz;
        let i1 = (i0 + gsz).min(m);
        let mut amax = 0.0f32;
        match perm {
            None => {
                for &v in &row[i0..i1] {
                    amax = amax.max(v.abs());
                }
            }
            Some(p) => {
                for &pi in &p[i0..i1] {
                    amax = amax.max(row[pi as usize].abs());
                }
            }
        }
        if amax == 0.0 || !amax.is_finite() {
            // All-zero (or degenerate) group: a = 0 makes the whole
            // contribution exactly 0, matching the f32 core.
            arow[g] = 0.0;
            grow[g] = 0;
            for slot in &mut qrow[i0..i1] {
                *slot = 0;
            }
            continue;
        }
        let inv = amp / amax;
        arow[g] = amax / amp;
        let mut sum = 0i32;
        match perm {
            None => {
                for (slot, &v) in qrow[i0..i1].iter_mut().zip(&row[i0..i1]) {
                    let q = (v * inv).round() as i32;
                    sum += q;
                    *slot = q as i16;
                }
            }
            Some(p) => {
                for (slot, &pi) in qrow[i0..i1].iter_mut().zip(&p[i0..i1]) {
                    let q = (row[pi as usize] * inv).round() as i32;
                    sum += q;
                    *slot = q as i16;
                }
            }
        }
        grow[g] = sum;
    }
}

/// Quantize activation rows `[r0, r1)` of `x` onto the fixed-point grid,
/// filling the panel slices for those rows.
#[allow(clippy::too_many_arguments)]
fn quantize_act_rows(
    t: &PackedTiles,
    x: &Matrix,
    amp: f32,
    r0: usize,
    r1: usize,
    xq: &mut [i16],
    ascale: &mut [f32],
    gisum: &mut [i32],
) {
    let (m, n_groups) = (t.m, t.n_groups);
    for r in r0..r1 {
        quantize_act_row(
            t,
            x.row(r),
            amp,
            &mut xq[(r - r0) * m..(r - r0 + 1) * m],
            &mut ascale[(r - r0) * n_groups..(r - r0 + 1) * n_groups],
            &mut gisum[(r - r0) * n_groups..(r - r0 + 1) * n_groups],
        );
    }
}

/// Build the fixed-point panel for all `b` activation rows — the
/// prologue of the integer core. Row chunks fan out to threads on the
/// same size threshold as the main grid (tall batched-capture inputs
/// used to pay this serially while only the grid was parallel).
fn build_int_panel(t: &PackedTiles, x: &Matrix, parallel: bool) -> IntActPanel {
    let b = x.rows();
    let (m, n_groups) = (t.m, t.n_groups);
    let amp = act_amp(t);
    if parallel && b > 1 {
        let chunks: Vec<(Vec<i16>, Vec<f32>, Vec<i32>)> = parallel_for_chunks(b, |range| {
            let rows = range.len();
            let mut xq = vec![0i16; rows * m];
            let mut ascale = vec![0f32; rows * n_groups];
            let mut gisum = vec![0i32; rows * n_groups];
            quantize_act_rows(t, x, amp, range.start, range.end, &mut xq, &mut ascale, &mut gisum);
            (xq, ascale, gisum)
        });
        let mut xq = Vec::with_capacity(b * m);
        let mut ascale = Vec::with_capacity(b * n_groups);
        let mut gisum = Vec::with_capacity(b * n_groups);
        for (cx, ca, cg) in chunks {
            xq.extend_from_slice(&cx);
            ascale.extend_from_slice(&ca);
            gisum.extend_from_slice(&cg);
        }
        IntActPanel { xq, ascale, gisum }
    } else {
        let mut xq = vec![0i16; b * m];
        let mut ascale = vec![0f32; b * n_groups];
        let mut gisum = vec![0i32; b * n_groups];
        quantize_act_rows(t, x, amp, 0, b, &mut xq, &mut ascale, &mut gisum);
        IntActPanel { xq, ascale, gisum }
    }
}

/// Register-tiled MAC for a full-width tile: `acc_j += Σ_k x̂_k·q_{k,j}`
/// with the 32-lane i32 accumulator living in registers across the whole
/// code panel, spilled into the cell accumulator once per panel.
#[inline]
fn mac_panel_full(arow: &mut [i32], xrow: &[i16], cpanel: &[i16]) {
    let mut acc = [0i32; COL_TILE];
    for (k, &q) in xrow.iter().enumerate() {
        if q == 0 {
            continue; // exact in integers: skipping adds nothing
        }
        let q = q as i32;
        let crow = &cpanel[k * COL_TILE..(k + 1) * COL_TILE];
        for (a, &c) in acc.iter_mut().zip(crow) {
            *a += q * c as i32;
        }
    }
    for (a, v) in arow.iter_mut().zip(acc) {
        *a += v;
    }
}

/// Ragged-width MAC (the last column tile when `n % COL_TILE ≠ 0`).
#[inline]
fn mac_panel(arow: &mut [i32], xrow: &[i16], cpanel: &[i16], w: usize) {
    for (k, &q) in xrow.iter().enumerate() {
        if q == 0 {
            continue;
        }
        let q = q as i32;
        let crow = &cpanel[k * w..k * w + w];
        for (a, &c) in arow.iter_mut().zip(crow) {
            *a += q * c as i32;
        }
    }
}

/// One integer-core grid cell: per group, unpack the tile's code rows
/// into [`PANEL_ROWS`]-row i16 panels (u64 bit-sliced, once per cell),
/// stream every activation row of the cell across the panel in pure i32
/// MAC, and touch f32 exactly once per (row, group) at the boundary:
/// `out_j += a·(s_j·acc_j − (s·z)_j·Σx̂)`.
fn tile_matmul_int(
    t: &PackedTiles,
    act: &IntActPanel,
    ti: usize,
    r0: usize,
    r1: usize,
) -> Matrix {
    let c0 = ti * COL_TILE;
    let w = COL_TILE.min(t.n - c0);
    let bl = r1 - r0;
    let m = t.m;
    let n_groups = t.n_groups;
    let packed = &t.tiles[ti];
    let mut out = Matrix::zeros(bl, w);
    let mut row_codes = [0u8; COL_TILE];
    let mut cpanel = [0i16; PANEL_ROWS * COL_TILE];
    let mut iacc = vec![0i32; bl * w];
    for g in 0..n_groups {
        let i0 = g * t.group_size;
        let i1 = (i0 + t.group_size).min(m);
        iacc.fill(0);
        let mut cs = i0;
        while cs < i1 {
            let cl = (i1 - cs).min(PANEL_ROWS);
            for k in 0..cl {
                unpack_bits_range(packed, t.wbit, (cs + k) * w, &mut row_codes[..w]);
                let prow = &mut cpanel[k * w..k * w + w];
                for (p, &c) in prow.iter_mut().zip(&row_codes[..w]) {
                    *p = c as i16;
                }
            }
            let panel = &cpanel[..cl * w];
            for r in 0..bl {
                let xrow = &act.xq[(r0 + r) * m + cs..][..cl];
                let arow = &mut iacc[r * w..r * w + w];
                if w == COL_TILE {
                    mac_panel_full(arow, xrow, panel);
                } else {
                    mac_panel(arow, xrow, panel, w);
                }
            }
            cs += cl;
        }
        let srow = &t.scales.row(g)[c0..c0 + w];
        let crow = &t.corr.row(g)[c0..c0 + w];
        for r in 0..bl {
            let a = act.ascale[(r0 + r) * n_groups + g];
            let gsv = act.gisum[(r0 + r) * n_groups + g] as f32;
            let arow = &iacc[r * w..r * w + w];
            let orow = &mut out.row_mut(r)[..w];
            for j in 0..w {
                orow[j] += a * (srow[j] * arow[j] as f32 - crow[j] * gsv);
            }
        }
    }
    out
}

/// Single-row integer tile kernel: the group accumulator never leaves
/// registers (no cell accumulator buffer, no panel staging) — unpack
/// cost dominates at `b = 1`, so each code row is decoded straight into
/// the MAC. Writes the tile's `w` outputs into the caller's buffer
/// (zero-filled here), so the decode hot loop allocates nothing.
/// Bit-identical to [`tile_matmul_int`] with `bl = 1`: i32 accumulation
/// is exact and the boundary arithmetic is the same expression in the
/// same order.
fn tile_gemv_int_into(t: &PackedTiles, act: &IntActPanel, ti: usize, out: &mut [f32]) {
    let c0 = ti * COL_TILE;
    let w = COL_TILE.min(t.n - c0);
    debug_assert_eq!(out.len(), w);
    let packed = &t.tiles[ti];
    out.fill(0.0);
    let mut row_codes = [0u8; COL_TILE];
    for g in 0..t.n_groups {
        let i0 = g * t.group_size;
        let i1 = (i0 + t.group_size).min(t.m);
        let mut acc = [0i32; COL_TILE];
        for i in i0..i1 {
            let q = act.xq[i] as i32;
            if q == 0 {
                continue; // skip the unpack too — exact in integers
            }
            unpack_bits_range(packed, t.wbit, i * w, &mut row_codes[..w]);
            for (a, &c) in acc[..w].iter_mut().zip(&row_codes[..w]) {
                *a += q * c as i32;
            }
        }
        let a = act.ascale[g];
        let gsv = act.gisum[g] as f32;
        let srow = &t.scales.row(g)[c0..c0 + w];
        let crow = &t.corr.row(g)[c0..c0 + w];
        for j in 0..w {
            out[j] += a * (srow[j] * acc[j] as f32 - crow[j] * gsv);
        }
    }
}

// ----- f32 reference core ---------------------------------------------

/// Per-group activation sums (the z-correction operand of the f32
/// core), `b × groups`, accumulated group-by-group, gathering through
/// the decode-order permutation when one is recorded. Row chunks fan
/// out to threads on the main-grid threshold.
fn build_gsum_f32(t: &PackedTiles, x: &Matrix, parallel: bool) -> Matrix {
    let b = x.rows();
    let fill = |r: usize, grow: &mut [f32]| {
        let row = x.row(r);
        match &t.perm {
            None => {
                for (gv, chunk) in grow.iter_mut().zip(row.chunks(t.group_size)) {
                    *gv = chunk.iter().sum::<f32>();
                }
            }
            Some(p) => {
                for (gv, pchunk) in grow.iter_mut().zip(p.chunks(t.group_size)) {
                    *gv = pchunk.iter().map(|&pi| row[pi as usize]).sum::<f32>();
                }
            }
        }
    };
    let ng = t.n_groups;
    if parallel && b > 1 {
        let chunks: Vec<Vec<f32>> = parallel_for_chunks(b, |range| {
            let mut buf = vec![0.0f32; range.len() * ng];
            for (k, r) in range.clone().enumerate() {
                fill(r, &mut buf[k * ng..(k + 1) * ng]);
            }
            buf
        });
        let mut flat = Vec::with_capacity(b * ng);
        for c in chunks {
            flat.extend_from_slice(&c);
        }
        return Matrix::from_vec(b, ng, flat);
    }
    let mut gsum = Matrix::zeros(b, ng);
    for r in 0..b {
        fill(r, gsum.row_mut(r));
    }
    gsum
}

/// One f32-reference grid cell: the historical kernel, bit-identical to
/// its PR-3 output — per (row, column) the accumulator sees the same
/// `xᵢ·qᵢⱼ` additions in the same `i` order. The only restructure is
/// that the per-code `u8→f32` convert is hoisted into a per-panel
/// unpack-and-widen pass instead of re-running inside the row loop
/// (associativity untouched: chunking an outer loop does not regroup
/// any accumulator's additions).
fn tile_matmul_f32(
    t: &PackedTiles,
    x: &Matrix,
    gsum: &Matrix,
    ti: usize,
    r0: usize,
    r1: usize,
) -> Matrix {
    let c0 = ti * COL_TILE;
    let w = COL_TILE.min(t.n - c0);
    let bl = r1 - r0;
    let packed = &t.tiles[ti];
    let perm = t.perm.as_deref();
    let mut out = Matrix::zeros(bl, w);
    let mut acc = vec![0.0f32; bl * w];
    let mut row_codes = [0u8; COL_TILE];
    let mut cpanel = [0.0f32; PANEL_ROWS * COL_TILE];
    for g in 0..t.n_groups {
        acc.fill(0.0);
        let i0 = g * t.group_size;
        let i1 = (i0 + t.group_size).min(t.m);
        let mut cs = i0;
        while cs < i1 {
            let cl = (i1 - cs).min(PANEL_ROWS);
            for k in 0..cl {
                unpack_bits_range(packed, t.wbit, (cs + k) * w, &mut row_codes[..w]);
                let prow = &mut cpanel[k * w..k * w + w];
                for (p, &c) in prow.iter_mut().zip(&row_codes[..w]) {
                    *p = c as f32;
                }
            }
            for k in 0..cl {
                let i = cs + k;
                // Decode-order gather fused into the loop: code row `i`
                // multiplies activation feature `perm[i]`.
                let xi = perm.map_or(i, |p| p[i] as usize);
                let crow = &cpanel[k * w..k * w + w];
                for r in 0..bl {
                    let xv = x.get(r0 + r, xi);
                    let arow = &mut acc[r * w..r * w + w];
                    for (a, &cf) in arow.iter_mut().zip(crow) {
                        *a += xv * cf;
                    }
                }
            }
            cs += cl;
        }
        for r in 0..bl {
            let gsv = gsum.get(r0 + r, g);
            let orow = out.row_mut(r);
            let arow = &acc[r * w..r * w + w];
            for (jj, o) in orow.iter_mut().enumerate() {
                *o += t.scales.get(g, c0 + jj) * arow[jj] - t.corr.get(g, c0 + jj) * gsv;
            }
        }
    }
    out
}

// ----- kernel entry points --------------------------------------------

/// Blocked multi-row quantized GEMM over the tiled bitstream,
/// dispatching to the active [`PackedCore`] (integer by default; the
/// f32 reference behind `OJBKQ_F32_CORE=1` / [`set_packed_core_override`]).
///
/// Tall (batched-capture) inputs parallelize over a grid of
/// [`ROW_BLOCK`]-row × [`COL_TILE`]-column cells; each cell's output
/// depends only on its own activation rows, so the split is bit-exact
/// with respect to any other blocking — exactly so on the integer core
/// (i32 accumulation), and by fixed per-accumulator addition order on
/// the f32 core. Act-order layers read activations through the recorded
/// decode-order permutation (resolved once in the integer prologue, or
/// fused into the tile loop on the f32 core) — no permuted copy of the
/// (possibly very tall) batch is ever materialized. Single-row inputs
/// take [`qgemv_packed`].
pub fn qgemm_packed(t: &PackedTiles, x: &Matrix) -> Matrix {
    qgemm_packed_with(t, x, packed_core())
}

/// [`qgemm_packed`] with an explicit core — the parity-test and bench
/// entry point.
pub fn qgemm_packed_with(t: &PackedTiles, x: &Matrix, core: PackedCore) -> Matrix {
    assert_eq!(x.cols(), t.m, "activation/layer shape mismatch");
    // Kernel counters are analytic — derived from shapes at entry, so the
    // microkernel loops below carry zero instrumentation. Each grid cell
    // unpacks its tile's codes once (`n_row_blocks·m·n` code words per
    // call; the single-row register path touches each code exactly once)
    // in `PANEL_ROWS×COL_TILE` panel refills.
    if crate::obs::enabled() {
        let b = x.rows();
        let gemv = b == 1 && core == PackedCore::Int;
        let n_row_blocks = if gemv { 1 } else { b.div_ceil(ROW_BLOCK).max(1) };
        crate::obs::counter_add(if gemv { "qgemm.gemv_calls" } else { "qgemm.calls" }, 1);
        crate::obs::counter_add("qgemm.rows", b as u64);
        crate::obs::counter_add("qgemm.macs", (b * t.m * t.n) as u64);
        crate::obs::counter_add("qgemm.unpacked_codes", (n_row_blocks * t.m * t.n) as u64);
        crate::obs::counter_add(
            "qgemm.panel_fills",
            (n_row_blocks * t.tiles.len() * t.m.div_ceil(PANEL_ROWS)) as u64,
        );
    }
    if x.rows() == 1 && core == PackedCore::Int {
        return qgemv_int(t, x);
    }
    let b = x.rows();
    let n_tiles = t.tiles.len();
    let n_row_blocks = b.div_ceil(ROW_BLOCK).max(1);
    let cells = n_tiles * n_row_blocks;
    let parallel = cells > 1 && b * t.m * t.n >= PARALLEL_FLOPS_MIN;
    let cell_out: Vec<(usize, usize, Matrix)> = match core {
        PackedCore::Int => {
            let act = build_int_panel(t, x, parallel);
            let cell = |c: usize| {
                let ti = c % n_tiles;
                let r0 = (c / n_tiles) * ROW_BLOCK;
                let r1 = (r0 + ROW_BLOCK).min(b);
                (ti, r0, tile_matmul_int(t, &act, ti, r0, r1))
            };
            if parallel {
                parallel_map_dynamic(cells, cell)
            } else {
                (0..cells).map(cell).collect()
            }
        }
        PackedCore::F32 => {
            let gsum = build_gsum_f32(t, x, parallel);
            let cell = |c: usize| {
                let ti = c % n_tiles;
                let r0 = (c / n_tiles) * ROW_BLOCK;
                let r1 = (r0 + ROW_BLOCK).min(b);
                (ti, r0, tile_matmul_f32(t, x, &gsum, ti, r0, r1))
            };
            if parallel {
                parallel_map_dynamic(cells, cell)
            } else {
                (0..cells).map(cell).collect()
            }
        }
    };
    let mut y = Matrix::zeros(b, t.n);
    for (ti, r0, block) in &cell_out {
        y.set_block(*r0, ti * COL_TILE, block);
    }
    y
}

/// Single-row packed GEMV — the `m = 1` decode path, where unpack cost
/// dominates and the group accumulator fits in registers. Dispatches to
/// the active core: the integer core runs the dedicated
/// [`tile_gemv_int`] register kernel (bit-identical to the blocked
/// grid); the f32 reference core shares the grid kernel at `bl = 1`, so
/// each core produces exactly one set of numerics regardless of entry
/// point.
pub fn qgemv_packed(t: &PackedTiles, x: &Matrix) -> Matrix {
    qgemv_packed_with(t, x, packed_core())
}

/// [`qgemv_packed`] with an explicit core — the parity-test and bench
/// entry point.
pub fn qgemv_packed_with(t: &PackedTiles, x: &Matrix, core: PackedCore) -> Matrix {
    assert_eq!(x.rows(), 1, "qgemv_packed is the single-row kernel");
    qgemm_packed_with(t, x, core)
}

/// Integer-core single-row path behind [`qgemv_packed`] /
/// [`qgemm_packed`] dispatch: a throwaway scratch arena + the shared
/// write-into kernel, so the legacy allocating signature is a thin
/// wrapper over [`qgemv_packed_into`]'s body.
fn qgemv_int(t: &PackedTiles, x: &Matrix) -> Matrix {
    let mut scratch = GemvScratch::new();
    let mut y = Matrix::zeros(1, t.n);
    qgemv_int_into(t, x.row(0), &mut scratch, y.row_mut(0));
    y
}

/// Reusable scratch arena for the single-row integer kernel: the
/// fixed-point activation panel buffers ([`IntActPanel`] for one row),
/// resized per layer (capacity is retained, so growth happens only
/// until the largest layer has been seen) and reused across every
/// [`qgemv_packed_into`] call threaded through it, so a KV-cached decode
/// step performs **zero heap allocations** in the GEMV hot loop after
/// warm-up. One scratch serves layers of any shape.
#[derive(Debug, Default)]
pub struct GemvScratch {
    panel: IntActPanel,
}

impl GemvScratch {
    /// Empty arena; buffers grow on first use.
    pub fn new() -> GemvScratch {
        GemvScratch::default()
    }

    /// Resize the panel buffers for a layer with `m` input features and
    /// `n_groups` scale groups (contents are overwritten by the caller).
    fn prepare(&mut self, m: usize, n_groups: usize) {
        self.panel.xq.resize(m, 0);
        self.panel.ascale.resize(n_groups, 0.0);
        self.panel.gisum.resize(n_groups, 0);
    }
}

/// Allocation-free single-row packed GEMV on the **integer core**: the
/// activation row is quantized into the caller's [`GemvScratch`] and the
/// tile outputs written straight into `out` (`len = n`). Bit-identical
/// to [`qgemv_packed`] / the corresponding [`qgemm_packed`] row on the
/// integer core — same prologue, same register kernel, i32 accumulation
/// exact under any tile split. This is the decode hot path of
/// [`crate::serve`]; the f32 reference core and dense fallback go
/// through [`PackedLinear::gemv_into`], which dispatches here only for
/// packed layers on the integer core.
pub fn qgemv_packed_into(t: &PackedTiles, x: &[f32], scratch: &mut GemvScratch, out: &mut [f32]) {
    assert_eq!(x.len(), t.m, "activation/layer shape mismatch");
    assert_eq!(out.len(), t.n, "output buffer shape mismatch");
    // Same analytic counters as the gemv leg of [`qgemm_packed_with`]
    // (b = 1, one unpack pass, register panels), so trace totals do not
    // depend on which single-row entry point ran.
    if crate::obs::enabled() {
        crate::obs::counter_add("qgemm.gemv_calls", 1);
        crate::obs::counter_add("qgemm.rows", 1);
        crate::obs::counter_add("qgemm.macs", (t.m * t.n) as u64);
        crate::obs::counter_add("qgemm.unpacked_codes", (t.m * t.n) as u64);
        crate::obs::counter_add(
            "qgemm.panel_fills",
            (t.tiles.len() * t.m.div_ceil(PANEL_ROWS)) as u64,
        );
    }
    qgemv_int_into(t, x, scratch, out);
}

/// Body shared by [`qgemv_packed_into`] (counters at entry) and
/// [`qgemv_int`] (counters already recorded by [`qgemm_packed_with`]).
fn qgemv_int_into(t: &PackedTiles, x: &[f32], scratch: &mut GemvScratch, out: &mut [f32]) {
    scratch.prepare(t.m, t.n_groups);
    quantize_act_row(
        t,
        x,
        act_amp(t),
        &mut scratch.panel.xq[..t.m],
        &mut scratch.panel.ascale[..t.n_groups],
        &mut scratch.panel.gisum[..t.n_groups],
    );
    let act = &scratch.panel;
    let n_tiles = t.tiles.len();
    let parallel = n_tiles > 1 && t.m * t.n >= PARALLEL_FLOPS_MIN;
    if parallel {
        // Huge layers only: per-tile temp buffers are the price of the
        // fan-out (each tile is independent and i32-exact, so the split
        // stays bit-identical). Decode-sized layers take the serial
        // zero-allocation leg below.
        let tiles_out: Vec<Vec<f32>> = parallel_map_dynamic(n_tiles, |ti| {
            let w = COL_TILE.min(t.n - ti * COL_TILE);
            let mut buf = vec![0.0f32; w];
            tile_gemv_int_into(t, act, ti, &mut buf);
            buf
        });
        for (ti, tv) in tiles_out.iter().enumerate() {
            out[ti * COL_TILE..ti * COL_TILE + tv.len()].copy_from_slice(tv);
        }
    } else {
        for ti in 0..n_tiles {
            let c0 = ti * COL_TILE;
            let w = COL_TILE.min(t.n - c0);
            tile_gemv_int_into(t, act, ti, &mut out[c0..c0 + w]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::{gptq, rtn, QuantConfig};
    use crate::rng::Rng;

    fn rand_layer(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x = Matrix::randn(7, m, 1.0, &mut rng);
        (w, x)
    }

    #[test]
    fn packed_matmul_matches_dequantized_gemm() {
        // Ragged groups (m % gs ≠ 0) and ragged tiles (n % COL_TILE ≠ 0)
        // across every supported low bit-width — on both cores.
        for &wbit in &[2u8, 3, 4] {
            for &(m, n, gs) in &[(48usize, 40usize, 16usize), (33, 37, 12), (20, 5, 0)] {
                let (w, x) = rand_layer(m, n, wbit as u64 * 100 + m as u64);
                let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
                let q = rtn::quantize(&w, &cfg);
                let p = PackedLinear::from_quantized(&q, true);
                assert!(p.is_packed());
                let dense = matmul(&x, &q.dequantize());
                for core in [PackedCore::Int, PackedCore::F32] {
                    let packed = qgemm_packed_with(p.as_packed().unwrap(), &x, core);
                    assert!(
                        packed.rel_err(&dense) < 1e-4,
                        "{core:?} wbit={wbit} m={m} n={n} gs={gs}: rel={}",
                        packed.rel_err(&dense)
                    );
                }
            }
        }
    }

    #[test]
    fn act_order_perm_runs_packed_and_matches_effective() {
        let (w, x) = rand_layer(40, 24, 9);
        let cfg = QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
        let q = gptq::quantize(&w, &x, &cfg).unwrap();
        assert!(q.perm.is_some() && q.effective.is_some());
        let p = PackedLinear::from_quantized(&q, true);
        assert!(p.is_packed(), "perm layers must run on the integer kernel");
        let dense = matmul(&x, &q.dequantize()); // effective, original order
        for core in [PackedCore::Int, PackedCore::F32] {
            let packed = qgemm_packed_with(p.as_packed().unwrap(), &x, core);
            assert!(packed.rel_err(&dense) < 1e-4, "{core:?} rel={}", packed.rel_err(&dense));
        }
        // And the dense reconstruction agrees with the solver's effective.
        assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-5);
    }

    #[test]
    fn effective_without_perm_falls_back_dense() {
        let (w, x) = rand_layer(24, 12, 3);
        let mut q = rtn::quantize(&w, &QuantConfig::default());
        q.effective = Some(w.clone()); // a transform folded here (AWQ/QuIP)
        let p = PackedLinear::from_quantized(&q, true);
        assert!(!p.is_packed());
        assert_eq!(p.matmul(&x), matmul(&x, &w));
        assert_eq!(p.bytes(), 24 * 12 * 4);
    }

    #[test]
    fn packed_exec_off_splices_dense() {
        let (w, _) = rand_layer(16, 8, 4);
        let q = rtn::quantize(&w, &QuantConfig { wbit: 4, group_size: 8, ..Default::default() });
        let p = PackedLinear::from_quantized(&q, false);
        assert!(!p.is_packed());
        assert_eq!(p.to_dense(), q.dequantize());
    }

    #[test]
    fn to_dense_matches_dequantize() {
        for &(gs, wbit) in &[(16usize, 4u8), (12, 3), (0, 2)] {
            let (w, _) = rand_layer(48, 37, gs as u64 + wbit as u64);
            let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
            let q = rtn::quantize(&w, &cfg);
            let p = PackedLinear::from_quantized(&q, true);
            // `s·q − s·z` vs `s·(q−z)`: identical up to one f32 rounding.
            assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-6);
        }
    }

    #[test]
    fn resident_bytes_beat_f32_by_4x_at_w4() {
        let (w, _) = rand_layer(256, 64, 7);
        let cfg = QuantConfig { wbit: 4, group_size: 128, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let fp = 256 * 64 * 4;
        assert!(
            p.bytes() * 4 <= fp,
            "resident {} vs fp {} (ratio {:.2})",
            p.bytes(),
            fp,
            fp as f64 / p.bytes() as f64
        );
    }

    #[test]
    fn tile_streams_are_word_aligned_and_payload_is_logical() {
        let (w, x) = rand_layer(33, 37, 21);
        let cfg = QuantConfig { wbit: 3, group_size: 12, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let t = p.as_packed().unwrap();
        for (ti, stream) in t.tiles().iter().enumerate() {
            assert_eq!(stream.len() % 8, 0, "tile {ti} not word-aligned");
            let wd = COL_TILE.min(37 - ti * COL_TILE);
            let logical = packed_len(33 * wd, 3);
            assert_eq!(t.tile_payload(ti).len(), logical, "tile {ti} payload");
            assert_eq!(&stream[..logical], t.tile_payload(ti));
            assert!(stream[logical..].iter().all(|&b| b == 0), "pad must be zero");
        }
        // Padding is invisible to the kernels.
        let y = p.matmul(&x);
        assert_eq!(y, qgemm_packed_with(t, &x, packed_core()));
    }

    #[test]
    fn tall_batch_grid_matches_per_sequence_chunks() {
        // The row-block × tile grid (and its parallel leg) must be
        // bit-exact against per-chunk calls: a tall stacked batch equals
        // the vstack of its parts — including act-order layers, whose
        // decode-order gather is resolved in the prologue, and including
        // the single-row qgemv path (the 1-row part below) — on both
        // cores.
        let mut rng = Rng::new(0x7A11);
        let w = Matrix::randn(48, 40, 0.5, &mut rng);
        let xcal = Matrix::randn(16, 48, 1.0, &mut rng);
        let cfg_rtn = QuantConfig { wbit: 3, group_size: 16, ..Default::default() };
        let cfg_act =
            QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
        let layers = [
            PackedLinear::from_quantized(&rtn::quantize(&w, &cfg_rtn), true),
            PackedLinear::from_quantized(&gptq::quantize(&w, &xcal, &cfg_act).unwrap(), true),
        ];
        // Ragged parts crossing ROW_BLOCK, tall enough in total to take
        // the parallel grid leg (b·m·n ≥ PARALLEL_FLOPS_MIN).
        let counts = [64usize, 1, 199, 83, 256];
        let parts: Vec<Matrix> =
            counts.iter().map(|&c| Matrix::randn(c, 48, 1.0, &mut rng)).collect();
        let tall = Matrix::vstack_all(&parts);
        assert!(tall.rows() * 48 * 40 >= PARALLEL_FLOPS_MIN);
        for p in &layers {
            assert!(p.is_packed());
            let t = p.as_packed().unwrap();
            for core in [PackedCore::Int, PackedCore::F32] {
                let batched = qgemm_packed_with(t, &tall, core);
                let stacked = Matrix::vstack_all(
                    &parts.iter().map(|x| qgemm_packed_with(t, x, core)).collect::<Vec<_>>(),
                );
                assert_eq!(batched, stacked, "{core:?} grid blocking must be bit-exact");
            }
        }
    }

    #[test]
    fn from_parts_roundtrips_and_rejects_corruption() {
        let (w, x) = rand_layer(20, 40, 77);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let t = p.as_packed().unwrap();
        let rebuild = |wbit: u8, gs: usize, tiles: Vec<Vec<u8>>, perm: Option<Vec<u32>>| {
            let (s, c) = (t.scales().clone(), t.corr().clone());
            PackedTiles::from_parts(20, 40, wbit, gs, tiles, s, c, perm)
        };
        // Faithful reassembly executes bit-identically — from the
        // resident (word-aligned) streams or the serialized (logical)
        // payloads alike.
        let back = rebuild(3, 8, t.tiles().to_vec(), None).unwrap();
        assert_eq!(qgemm_packed(&back, &x), p.matmul(&x));
        let logical: Vec<Vec<u8>> =
            (0..t.tiles().len()).map(|ti| t.tile_payload(ti).to_vec()).collect();
        let back = rebuild(3, 8, logical, None).unwrap();
        assert_eq!(qgemm_packed(&back, &x), p.matmul(&x));
        // Every broken invariant is an Err, not a panic.
        assert!(rebuild(0, 8, t.tiles().to_vec(), None).is_err(), "wbit 0");
        assert!(rebuild(9, 8, t.tiles().to_vec(), None).is_err(), "wbit 9");
        assert!(rebuild(3, 0, t.tiles().to_vec(), None).is_err(), "group_size 0");
        assert!(rebuild(3, 21, t.tiles().to_vec(), None).is_err(), "group_size > m");
        assert!(rebuild(3, 16, t.tiles().to_vec(), None).is_err(), "wrong n_groups");
        assert!(rebuild(3, 8, t.tiles()[..1].to_vec(), None).is_err(), "missing tile");
        let mut short = t.tiles().to_vec();
        short[1].truncate(t.tile_payload(1).len() - 1);
        assert!(rebuild(3, 8, short, None).is_err(), "short tile stream");
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(vec![0; 20])).is_err(), "dup perm");
        let mut oob: Vec<u32> = (0..20).collect();
        oob[3] = 99;
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(oob)).is_err(), "oob perm");
        let ok_perm: Vec<u32> = (0..20).rev().collect();
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(ok_perm)).is_ok());
    }

    #[test]
    fn zero_activation_batch_short_circuits() {
        let (w, _) = rand_layer(24, 6, 5);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let p = PackedLinear::from_quantized(&rtn::quantize(&w, &cfg), true);
        let t = p.as_packed().unwrap();
        for core in [PackedCore::Int, PackedCore::F32] {
            let y = qgemm_packed_with(t, &Matrix::zeros(3, 24), core);
            assert!(y.as_slice().iter().all(|&v| v == 0.0), "{core:?}");
        }
    }

    #[test]
    fn gemv_entry_matches_gemm_row() {
        // qgemv_packed ≡ qgemm_packed on a 1-row input, per core, and
        // both match the corresponding row of a taller batch.
        let (w, x) = rand_layer(48, 40, 0xE1);
        let cfg = QuantConfig { wbit: 4, group_size: 16, ..Default::default() };
        let p = PackedLinear::from_quantized(&rtn::quantize(&w, &cfg), true);
        let t = p.as_packed().unwrap();
        let row0 = x.block(0, 0, 1, 48);
        for core in [PackedCore::Int, PackedCore::F32] {
            let via_gemv = qgemv_packed_with(t, &row0, core);
            let via_gemm = qgemm_packed_with(t, &row0, core);
            assert_eq!(via_gemv, via_gemm, "{core:?}");
            let tall = qgemm_packed_with(t, &x, core);
            assert_eq!(via_gemv.row(0), &tall.row(0)[..], "{core:?} vs batch row");
        }
    }

    #[test]
    fn gemv_scratch_path_matches_allocating_entry() {
        // qgemv_packed_into (scratch arena, write-into) must be
        // bit-identical to qgemm_packed_with on the integer core, layer
        // after layer through ONE reused scratch — including act-order
        // layers and ragged tiles. The dense fallback's gemv_into must
        // equal its matmul row.
        let mut rng = Rng::new(0x5C4A);
        let mut scratch = GemvScratch::new();
        for &(m, n, gs, wbit, act_order) in &[
            (48usize, 40usize, 16usize, 4u8, false),
            (33, 37, 12, 3, false),
            (40, 24, 8, 4, true),
            (20, 5, 0, 2, false),
        ] {
            let w = Matrix::randn(m, n, 0.5, &mut rng);
            let x = Matrix::randn(1, m, 1.0, &mut rng);
            let cfg = QuantConfig { wbit, group_size: gs, act_order, ..Default::default() };
            let q = if act_order {
                let xcal = Matrix::randn(16, m, 1.0, &mut rng);
                gptq::quantize(&w, &xcal, &cfg).unwrap()
            } else {
                rtn::quantize(&w, &cfg)
            };
            let p = PackedLinear::from_quantized(&q, true);
            let t = p.as_packed().unwrap();
            let mut out = vec![0.0f32; n];
            qgemv_packed_into(t, x.row(0), &mut scratch, &mut out);
            let want = qgemm_packed_with(t, &x, PackedCore::Int);
            assert_eq!(&out[..], want.row(0), "m={m} n={n} gs={gs} wbit={wbit}");
            // Dense fallback leg of gemv_into.
            let d = PackedLinear::dense(w.clone());
            let mut dout = vec![0.0f32; n];
            d.gemv_into(x.row(0), &mut scratch, &mut dout);
            assert_eq!(&dout[..], d.matmul(&x).row(0), "dense m={m} n={n}");
        }
    }

    #[test]
    fn act_amp_respects_overflow_budget() {
        // i16-bounded for deployment shapes, shrunk for huge groups ×
        // wide codes so `amp·maxcode·group_size` stays below 2³¹.
        let (w, _) = rand_layer(256, 8, 1);
        let q = rtn::quantize(&w, &QuantConfig { wbit: 4, group_size: 128, ..Default::default() });
        let p = PackedLinear::from_quantized(&q, true);
        let amp = act_amp(p.as_packed().unwrap());
        assert_eq!(amp, i16::MAX as f32);
        // Synthetic worst case: whole-column group at W8.
        let q = rtn::quantize(&w, &QuantConfig { wbit: 8, group_size: 0, ..Default::default() });
        let p = PackedLinear::from_quantized(&q, true);
        let t = p.as_packed().unwrap();
        let amp = act_amp(t) as u64;
        let maxcode = (1u64 << t.wbit()) - 1;
        assert!(amp * maxcode * t.group_size() as u64 <= i32::MAX as u64);
        assert!(amp >= 1);
    }
}
