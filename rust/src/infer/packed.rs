//! Execution-ready packed weight layout + blocked integer kernels.
//!
//! [`PackedLinear`] is the deployment form of a solved
//! [`QuantizedLinear`]: integer codes bit-packed (via
//! [`crate::quant::qtensor::pack_bits`]) into **column tiles** of
//! [`COL_TILE`] outputs, alongside the per-group scale table and a
//! precomputed `s·z` correction table. The conversion happens once, after
//! the solver; from then on every matmul runs straight off the bitstream:
//!
//! `y_j = Σ_g s_{g,j} · (Σ_{i∈g} x_i·q_{ij}) − (s·z)_{g,j} · Σ_{i∈g} x_i`
//!
//! [`qgemm_packed`] is the blocked multi-row kernel behind
//! [`PackedLinear::matmul`]: per column tile, each packed code row is
//! unpacked **once per [`ROW_BLOCK`]-row grid cell** — through the
//! table-driven fast paths of [`unpack_bits_range`] — into a stack
//! buffer and accumulated across that cell's activation rows (the
//! row-at-a-time `qgemv` loop re-read every code per activation row;
//! the grid trades some unpack amortization on tall inputs for
//! cell-level parallelism). Large calls parallelize over a
//! [`ROW_BLOCK`] × [`COL_TILE`] grid via [`crate::parallel`], so the tall
//! stacked batches of the batch-fused capture path use every core, not
//! one thread per tile. Act-order solvers (OJBKQ, GPTQ) keep their codes
//! in decode order; the kernel gathers activations through the recorded
//! row permutation inside the tile loop (no permuted batch copy) instead
//! of falling back to a dense weight. Genuine dense transforms (AWQ's
//! folded scaling, QuIP's rotations) and FP passthrough layers use the
//! [`PackedLinear::Dense`] fallback.

use crate::linalg::matmul_par;
use crate::parallel::parallel_map_dynamic;
use crate::quant::qtensor::{pack_bits, unpack_bits_range};
use crate::quant::QuantizedLinear;
use crate::tensor::Matrix;

/// Output columns per packed tile — sized so one unpacked code row plus
/// the per-row accumulator live comfortably in registers / L1.
pub const COL_TILE: usize = 32;

/// Activation rows per parallel grid cell: tall (batched-capture) inputs
/// are split into row blocks so the kernel parallelizes over
/// **row blocks × column tiles**, not tiles alone — with a handful of
/// tiles and a tall stacked batch, tile-only fan-out left most cores
/// idle.
pub const ROW_BLOCK: usize = 64;

/// Minimum `batch·m·n` product before [`qgemm_packed`] fans grid cells
/// out to threads. Re-tuned for the batch-fused capture path: the
/// coordinator now issues one tall call per stage instead of
/// parallelizing over per-sequence calls, so the kernel parallelizes
/// earlier than the PR-2 tile-only threshold.
const PARALLEL_FLOPS_MIN: usize = 1 << 20;

/// Column-tiled bit-packed codes + scale/correction tables.
#[derive(Debug, Clone)]
pub struct PackedTiles {
    m: usize,
    n: usize,
    wbit: u8,
    group_size: usize,
    n_groups: usize,
    /// One little-endian bitstream per column tile; tile `t` holds the
    /// `m × width(t)` codes of columns `[t·COL_TILE, …)`, row-major.
    tiles: Vec<Vec<u8>>,
    /// Group scales `s`, `n_groups × n`.
    scales: Matrix,
    /// Precomputed correction table `s·z`, `n_groups × n`.
    corr: Matrix,
    /// Decode-order row permutation: code row `i` multiplies activation
    /// feature `perm[i]`.
    perm: Option<Vec<u32>>,
}

impl PackedTiles {
    fn from_quantized(q: &QuantizedLinear) -> PackedTiles {
        let (m, n) = (q.m, q.n);
        let n_tiles = n.div_ceil(COL_TILE);
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut tile_codes: Vec<u8> = Vec::with_capacity(m * COL_TILE);
        for t in 0..n_tiles {
            let c0 = t * COL_TILE;
            let w = COL_TILE.min(n - c0);
            tile_codes.clear();
            for i in 0..m {
                tile_codes.extend_from_slice(&q.codes[i * n + c0..i * n + c0 + w]);
            }
            tiles.push(pack_bits(&tile_codes, q.wbit));
        }
        PackedTiles {
            m,
            n,
            wbit: q.wbit,
            group_size: q.scales.group_size,
            n_groups: q.scales.n_groups(),
            tiles,
            scales: q.scales.scales.clone(),
            corr: q.scales.scales.hadamard(&q.scales.zeros),
            perm: q.perm.clone(),
        }
    }

    /// Reassemble tiles from deserialized parts (the OJBQ1 checkpoint
    /// loader, `crate::infer::io`), validating every structural invariant
    /// the kernels rely on — group layout, tile count and per-tile
    /// bitstream length, table shapes, and (when present) that `perm` is
    /// a genuine permutation of `0..m`. A hostile or corrupted checkpoint
    /// therefore fails here with `Err`, never as an index panic inside
    /// [`qgemm_packed`].
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        m: usize,
        n: usize,
        wbit: u8,
        group_size: usize,
        tiles: Vec<Vec<u8>>,
        scales: Matrix,
        corr: Matrix,
        perm: Option<Vec<u32>>,
    ) -> anyhow::Result<PackedTiles> {
        anyhow::ensure!(m >= 1 && n >= 1, "empty packed layer {m}x{n}");
        anyhow::ensure!((1..=8).contains(&wbit), "unsupported wbit {wbit}");
        anyhow::ensure!(
            (1..=m).contains(&group_size),
            "group_size {group_size} out of range for m={m}"
        );
        let n_groups = m.div_ceil(group_size);
        anyhow::ensure!(
            scales.shape() == (n_groups, n),
            "scale table shape {:?} != ({n_groups}, {n})",
            scales.shape()
        );
        anyhow::ensure!(
            corr.shape() == (n_groups, n),
            "correction table shape {:?} != ({n_groups}, {n})",
            corr.shape()
        );
        let n_tiles = n.div_ceil(COL_TILE);
        anyhow::ensure!(tiles.len() == n_tiles, "{} tiles, expected {n_tiles}", tiles.len());
        for (t, tile) in tiles.iter().enumerate() {
            let w = COL_TILE.min(n - t * COL_TILE);
            let want = crate::quant::qtensor::packed_len(m * w, wbit);
            anyhow::ensure!(
                tile.len() == want,
                "tile {t} holds {} bytes, expected {want}",
                tile.len()
            );
        }
        if let Some(p) = &perm {
            anyhow::ensure!(p.len() == m, "perm length {} != m={m}", p.len());
            let mut seen = vec![false; m];
            for &pi in p {
                let i = pi as usize;
                anyhow::ensure!(i < m, "perm entry {pi} out of range for m={m}");
                anyhow::ensure!(!seen[i], "perm entry {pi} duplicated");
                seen[i] = true;
            }
        }
        Ok(PackedTiles { m, n, wbit, group_size, n_groups, tiles, scales, corr, perm })
    }

    /// `(m, n)` = (input features, output features).
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// Code bit width.
    pub fn wbit(&self) -> u8 {
        self.wbit
    }

    /// Rows per scale group (the last group may be short).
    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Per-tile bit-packed code streams, in column-tile order.
    pub fn tiles(&self) -> &[Vec<u8>] {
        &self.tiles
    }

    /// Group scale table `s`, `n_groups × n`.
    pub fn scales(&self) -> &Matrix {
        &self.scales
    }

    /// Precomputed correction table `s·z`, `n_groups × n`.
    pub fn corr(&self) -> &Matrix {
        &self.corr
    }

    /// Decode-order row permutation, when the solver recorded one.
    pub fn perm(&self) -> Option<&[u32]> {
        self.perm.as_deref()
    }

    /// Resident bytes of the packed representation (codes + f32 tables +
    /// permutation) — what the execution engine actually holds in memory.
    fn bytes(&self) -> usize {
        let codes: usize = self.tiles.iter().map(|t| t.len()).sum();
        let tables = (self.scales.len() + self.corr.len()) * 4;
        let perm = self.perm.as_ref().map_or(0, |p| p.len() * 4);
        codes + tables + perm
    }

    /// Reconstruct the dense `m×n` runtime weight in original feature
    /// order: `ŵ = s·q − s·z` per cell, rows scattered through `perm`.
    fn to_dense(&self) -> Matrix {
        let mut deq = Matrix::zeros(self.m, self.n);
        let mut row_codes = [0u8; COL_TILE];
        for (ti, packed) in self.tiles.iter().enumerate() {
            let c0 = ti * COL_TILE;
            let w = COL_TILE.min(self.n - c0);
            for i in 0..self.m {
                let g = i / self.group_size;
                unpack_bits_range(packed, self.wbit, i * w, &mut row_codes[..w]);
                let drow = &mut deq.row_mut(i)[c0..c0 + w];
                for (jj, slot) in drow.iter_mut().enumerate() {
                    *slot = self.scales.get(g, c0 + jj) * row_codes[jj] as f32
                        - self.corr.get(g, c0 + jj);
                }
            }
        }
        match &self.perm {
            None => deq,
            Some(p) => {
                let mut out = Matrix::zeros(self.m, self.n);
                for i in 0..self.m {
                    out.row_mut(p[i] as usize).copy_from_slice(deq.row(i));
                }
                out
            }
        }
    }
}

/// An execution-ready linear layer: packed integer codes or a dense f32
/// fallback. Conversion from the solver output happens once
/// ([`PackedLinear::from_quantized`]); the capture/eval hot path never
/// materializes dense weights for packed layers.
#[derive(Debug, Clone)]
pub enum PackedLinear {
    /// Bit-packed integer execution (RTN, Babai/Klein/OJBKQ, GPTQ —
    /// including act-order layers, via the recorded row permutation).
    Packed(PackedTiles),
    /// Dense f32 execution: FP passthrough layers and transform methods
    /// whose runtime weight is not `S⊙(Q−Z)` in any feature order
    /// (AWQ, QuIP).
    Dense(Matrix),
}

impl PackedLinear {
    /// Convert a solved layer into execution form. With `packed_exec`
    /// false everything becomes a dense splice (the numerically exact
    /// legacy mode).
    pub fn from_quantized(q: &QuantizedLinear, packed_exec: bool) -> PackedLinear {
        if !packed_exec || q.wbit == 0 || (q.effective.is_some() && q.perm.is_none()) {
            return PackedLinear::Dense(q.dequantize());
        }
        PackedLinear::Packed(PackedTiles::from_quantized(q))
    }

    /// Wrap a dense weight (FP passthrough).
    pub fn dense(w: Matrix) -> PackedLinear {
        PackedLinear::Dense(w)
    }

    /// Wrap already-validated tiles (checkpoint deserialization).
    pub fn packed(tiles: PackedTiles) -> PackedLinear {
        PackedLinear::Packed(tiles)
    }

    /// Borrow the tiled representation of a packed layer.
    pub fn as_packed(&self) -> Option<&PackedTiles> {
        match self {
            PackedLinear::Packed(t) => Some(t),
            PackedLinear::Dense(_) => None,
        }
    }

    /// `(m, n)` = (input features, output features).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            PackedLinear::Packed(t) => (t.m, t.n),
            PackedLinear::Dense(w) => w.shape(),
        }
    }

    /// True when this layer executes through the integer kernel.
    pub fn is_packed(&self) -> bool {
        matches!(self, PackedLinear::Packed(_))
    }

    /// Resident memory of this layer inside the execution engine.
    pub fn bytes(&self) -> usize {
        match self {
            PackedLinear::Packed(t) => t.bytes(),
            PackedLinear::Dense(w) => w.len() * 4,
        }
    }

    /// Dense `m×n` runtime weight (original feature order) — export and
    /// test support, not the execution path.
    pub fn to_dense(&self) -> Matrix {
        match self {
            PackedLinear::Packed(t) => t.to_dense(),
            PackedLinear::Dense(w) => w.clone(),
        }
    }

    /// `Y = X · Ŵ` for a batch of activation rows. Both legs parallelize
    /// internally on tall inputs (grid cells for packed codes, row blocks
    /// for the dense fallback), so batched-capture stacks run one big
    /// call instead of per-sequence fan-out.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            PackedLinear::Packed(t) => qgemm_packed(t, x),
            PackedLinear::Dense(w) => matmul_par(x, w),
        }
    }
}

/// Blocked multi-row quantized GEMM over the tiled bitstream.
///
/// Tall (batched-capture) inputs parallelize over a grid of
/// [`ROW_BLOCK`]-row × [`COL_TILE`]-column cells; each cell's output
/// depends only on its own activation rows, so the split is bit-exact
/// with respect to any other blocking. Act-order layers read activations
/// through the recorded decode-order permutation **inside** the tile
/// loop — no permuted copy of the (possibly very tall) batch is ever
/// materialized.
pub fn qgemm_packed(t: &PackedTiles, x: &Matrix) -> Matrix {
    assert_eq!(x.cols(), t.m, "activation/layer shape mismatch");
    let b = x.rows();
    // Per-group activation sums (the z-correction operand), `b × groups`,
    // accumulated group-by-group (no per-element division), gathering
    // through the decode-order permutation when one is recorded.
    let mut gsum = Matrix::zeros(b, t.n_groups);
    for r in 0..b {
        let row = x.row(r);
        let grow = gsum.row_mut(r);
        match &t.perm {
            None => {
                for (gv, chunk) in grow.iter_mut().zip(row.chunks(t.group_size)) {
                    *gv = chunk.iter().sum::<f32>();
                }
            }
            Some(p) => {
                for (gv, pchunk) in grow.iter_mut().zip(p.chunks(t.group_size)) {
                    *gv = pchunk.iter().map(|&pi| row[pi as usize]).sum::<f32>();
                }
            }
        }
    }
    let n_tiles = t.tiles.len();
    let n_row_blocks = b.div_ceil(ROW_BLOCK).max(1);
    let cells = n_tiles * n_row_blocks;
    let cell = |c: usize| {
        let ti = c % n_tiles;
        let r0 = (c / n_tiles) * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(b);
        (ti, r0, tile_matmul(t, x, &gsum, ti, r0, r1))
    };
    let cell_out: Vec<(usize, usize, Matrix)> =
        if cells > 1 && b * t.m * t.n >= PARALLEL_FLOPS_MIN {
            parallel_map_dynamic(cells, cell)
        } else {
            (0..cells).map(cell).collect()
        };
    let mut y = Matrix::zeros(b, t.n);
    for (ti, r0, block) in &cell_out {
        y.set_block(*r0, ti * COL_TILE, block);
    }
    y
}

/// One grid cell: unpack each code row of the tile once, accumulate it
/// across the cell's activation rows, then apply the per-group
/// scale/correction.
fn tile_matmul(
    t: &PackedTiles,
    x: &Matrix,
    gsum: &Matrix,
    ti: usize,
    r0: usize,
    r1: usize,
) -> Matrix {
    let c0 = ti * COL_TILE;
    let w = COL_TILE.min(t.n - c0);
    let bl = r1 - r0;
    let packed = &t.tiles[ti];
    let perm = t.perm.as_deref();
    let mut out = Matrix::zeros(bl, w);
    let mut acc = vec![0.0f32; bl * w];
    let mut row_codes = [0u8; COL_TILE];
    let mut codes_f = [0.0f32; COL_TILE];
    for g in 0..t.n_groups {
        acc.fill(0.0);
        let i0 = g * t.group_size;
        let i1 = (i0 + t.group_size).min(t.m);
        for i in i0..i1 {
            unpack_bits_range(packed, t.wbit, i * w, &mut row_codes[..w]);
            for (cf, &c) in codes_f[..w].iter_mut().zip(&row_codes[..w]) {
                *cf = c as f32;
            }
            // Decode-order gather fused into the loop: code row `i`
            // multiplies activation feature `perm[i]`.
            let xi = perm.map_or(i, |p| p[i] as usize);
            for r in 0..bl {
                let xv = x.get(r0 + r, xi);
                if xv == 0.0 {
                    continue;
                }
                let arow = &mut acc[r * w..r * w + w];
                for (a, &cf) in arow.iter_mut().zip(&codes_f[..w]) {
                    *a += xv * cf;
                }
            }
        }
        for r in 0..bl {
            let gsv = gsum.get(r0 + r, g);
            let orow = out.row_mut(r);
            let arow = &acc[r * w..r * w + w];
            for (jj, o) in orow.iter_mut().enumerate() {
                *o += t.scales.get(g, c0 + jj) * arow[jj] - t.corr.get(g, c0 + jj) * gsv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::{gptq, rtn, QuantConfig};
    use crate::rng::Rng;

    fn rand_layer(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x = Matrix::randn(7, m, 1.0, &mut rng);
        (w, x)
    }

    #[test]
    fn packed_matmul_matches_dequantized_gemm() {
        // Ragged groups (m % gs ≠ 0) and ragged tiles (n % COL_TILE ≠ 0)
        // across every supported low bit-width.
        for &wbit in &[2u8, 3, 4] {
            for &(m, n, gs) in &[(48usize, 40usize, 16usize), (33, 37, 12), (20, 5, 0)] {
                let (w, x) = rand_layer(m, n, wbit as u64 * 100 + m as u64);
                let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
                let q = rtn::quantize(&w, &cfg);
                let p = PackedLinear::from_quantized(&q, true);
                assert!(p.is_packed());
                let dense = matmul(&x, &q.dequantize());
                let packed = p.matmul(&x);
                assert!(
                    packed.rel_err(&dense) < 1e-4,
                    "wbit={wbit} m={m} n={n} gs={gs}: rel={}",
                    packed.rel_err(&dense)
                );
            }
        }
    }

    #[test]
    fn act_order_perm_runs_packed_and_matches_effective() {
        let (w, x) = rand_layer(40, 24, 9);
        let cfg = QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
        let q = gptq::quantize(&w, &x, &cfg).unwrap();
        assert!(q.perm.is_some() && q.effective.is_some());
        let p = PackedLinear::from_quantized(&q, true);
        assert!(p.is_packed(), "perm layers must run on the integer kernel");
        let dense = matmul(&x, &q.dequantize()); // effective, original order
        let packed = p.matmul(&x);
        assert!(packed.rel_err(&dense) < 1e-4, "rel={}", packed.rel_err(&dense));
        // And the dense reconstruction agrees with the solver's effective.
        assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-5);
    }

    #[test]
    fn effective_without_perm_falls_back_dense() {
        let (w, x) = rand_layer(24, 12, 3);
        let mut q = rtn::quantize(&w, &QuantConfig::default());
        q.effective = Some(w.clone()); // a transform folded here (AWQ/QuIP)
        let p = PackedLinear::from_quantized(&q, true);
        assert!(!p.is_packed());
        assert_eq!(p.matmul(&x), matmul(&x, &w));
        assert_eq!(p.bytes(), 24 * 12 * 4);
    }

    #[test]
    fn packed_exec_off_splices_dense() {
        let (w, _) = rand_layer(16, 8, 4);
        let q = rtn::quantize(&w, &QuantConfig { wbit: 4, group_size: 8, ..Default::default() });
        let p = PackedLinear::from_quantized(&q, false);
        assert!(!p.is_packed());
        assert_eq!(p.to_dense(), q.dequantize());
    }

    #[test]
    fn to_dense_matches_dequantize() {
        for &(gs, wbit) in &[(16usize, 4u8), (12, 3), (0, 2)] {
            let (w, _) = rand_layer(48, 37, gs as u64 + wbit as u64);
            let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
            let q = rtn::quantize(&w, &cfg);
            let p = PackedLinear::from_quantized(&q, true);
            // `s·q − s·z` vs `s·(q−z)`: identical up to one f32 rounding.
            assert!(p.to_dense().rel_err(&q.dequantize()) < 1e-6);
        }
    }

    #[test]
    fn resident_bytes_beat_f32_by_4x_at_w4() {
        let (w, _) = rand_layer(256, 64, 7);
        let cfg = QuantConfig { wbit: 4, group_size: 128, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let fp = 256 * 64 * 4;
        assert!(
            p.bytes() * 4 <= fp,
            "resident {} vs fp {} (ratio {:.2})",
            p.bytes(),
            fp,
            fp as f64 / p.bytes() as f64
        );
    }

    #[test]
    fn tall_batch_grid_matches_per_sequence_chunks() {
        // The row-block × tile grid (and its parallel leg) must be
        // bit-exact against per-chunk calls: a tall stacked batch equals
        // the vstack of its parts — including act-order layers, whose
        // decode-order gather is fused into the tile loop.
        let mut rng = Rng::new(0x7A11);
        let w = Matrix::randn(48, 40, 0.5, &mut rng);
        let xcal = Matrix::randn(16, 48, 1.0, &mut rng);
        let cfg_rtn = QuantConfig { wbit: 3, group_size: 16, ..Default::default() };
        let cfg_act =
            QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
        let layers = [
            PackedLinear::from_quantized(&rtn::quantize(&w, &cfg_rtn), true),
            PackedLinear::from_quantized(&gptq::quantize(&w, &xcal, &cfg_act).unwrap(), true),
        ];
        // Ragged parts crossing ROW_BLOCK, tall enough in total to take
        // the parallel grid leg (b·m·n ≥ PARALLEL_FLOPS_MIN).
        let counts = [64usize, 1, 199, 83, 256];
        let parts: Vec<Matrix> =
            counts.iter().map(|&c| Matrix::randn(c, 48, 1.0, &mut rng)).collect();
        let tall = Matrix::vstack_all(&parts);
        assert!(tall.rows() * 48 * 40 >= PARALLEL_FLOPS_MIN);
        for p in &layers {
            assert!(p.is_packed());
            let batched = p.matmul(&tall);
            let stacked =
                Matrix::vstack_all(&parts.iter().map(|x| p.matmul(x)).collect::<Vec<_>>());
            assert_eq!(batched, stacked, "grid blocking must be bit-exact");
        }
    }

    #[test]
    fn from_parts_roundtrips_and_rejects_corruption() {
        let (w, x) = rand_layer(20, 40, 77);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let p = PackedLinear::from_quantized(&q, true);
        let t = p.as_packed().unwrap();
        let rebuild = |wbit: u8, gs: usize, tiles: Vec<Vec<u8>>, perm: Option<Vec<u32>>| {
            let (s, c) = (t.scales().clone(), t.corr().clone());
            PackedTiles::from_parts(20, 40, wbit, gs, tiles, s, c, perm)
        };
        // Faithful reassembly executes bit-identically.
        let back = rebuild(3, 8, t.tiles().to_vec(), None).unwrap();
        assert_eq!(qgemm_packed(&back, &x), p.matmul(&x));
        // Every broken invariant is an Err, not a panic.
        assert!(rebuild(0, 8, t.tiles().to_vec(), None).is_err(), "wbit 0");
        assert!(rebuild(9, 8, t.tiles().to_vec(), None).is_err(), "wbit 9");
        assert!(rebuild(3, 0, t.tiles().to_vec(), None).is_err(), "group_size 0");
        assert!(rebuild(3, 21, t.tiles().to_vec(), None).is_err(), "group_size > m");
        assert!(rebuild(3, 16, t.tiles().to_vec(), None).is_err(), "wrong n_groups");
        assert!(rebuild(3, 8, t.tiles()[..1].to_vec(), None).is_err(), "missing tile");
        let mut short = t.tiles().to_vec();
        short[1].pop();
        assert!(rebuild(3, 8, short, None).is_err(), "short tile stream");
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(vec![0; 20])).is_err(), "dup perm");
        let mut oob: Vec<u32> = (0..20).collect();
        oob[3] = 99;
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(oob)).is_err(), "oob perm");
        let ok_perm: Vec<u32> = (0..20).rev().collect();
        assert!(rebuild(3, 8, t.tiles().to_vec(), Some(ok_perm)).is_ok());
    }

    #[test]
    fn zero_activation_batch_short_circuits() {
        let (w, _) = rand_layer(24, 6, 5);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let p = PackedLinear::from_quantized(&rtn::quantize(&w, &cfg), true);
        let y = p.matmul(&Matrix::zeros(3, 24));
        assert!(y.as_slice().iter().all(|&v| v == 0.0));
    }
}
