//! # OJBKQ — Objective-Joint Babai-Klein Quantization
//!
//! A production reproduction of *"OJBKQ: Objective-Joint Babai-Klein
//! Quantization"* (Wang, Zhao, Lu, Gu, Chang — 2026): layer-wise
//! post-training quantization of transformer language models formulated as
//! box-constrained integer least-squares (BILS), solved per weight column
//! by the box-constrained Babai nearest-plane algorithm augmented with K
//! Klein-randomized decoding paths, with candidates selected under the
//! Joint Target Alignment (JTA) objective.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — pipeline coordinator, solver library, model /
//!   data / eval substrates, PJRT runtime for AOT artifacts.
//! * **L2 (`python/compile/model.py`)** — the JAX layer-solve graph,
//!   AOT-lowered once to HLO text artifacts by `python/compile/aot.py`.
//! * **L1 (`python/compile/kernels/babai_klein.py`)** — the Pallas
//!   PPI-KBabai kernel (path-isolated parallel K-path back-substitution).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` plus pretrained tiny-LM weights, and the Rust
//! binary is self-contained afterwards.
//!
//! Entry points: [`coordinator::Pipeline`] drives end-to-end quantization
//! and returns a packed-execution [`infer::QuantizedModel`]; [`quant`]
//! exposes every solver (RTN / GPTQ / AWQ / QuIP / Babai / Klein /
//! OJBKQ); [`infer`] executes the quantized model straight from
//! bit-packed integer codes; [`serve`] generates tokens from it with a
//! KV cache and continuous batching; [`robust`] is the failure model
//! (fault injection, graceful degradation, crash-safe resumable runs);
//! [`eval`] measures perplexity,
//! zero-shot and reasoning accuracy on any [`model::LanguageModel`];
//! [`bench`] is the measurement harness used by `cargo bench`.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod infer;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod quant;
pub mod report;
pub mod rng;
pub mod robust;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testutil;
pub mod util;
