//! Cholesky factorization `G = RᵀR` with `R` upper-triangular.
//!
//! This is the factorization at the heart of OJBKQ (Algorithm 1 line 2):
//! `X̃ᵀX̃ + λ²I = RᵀR`. Matching the paper's design note, no matrix inverse
//! is ever formed anywhere in the pipeline — downstream consumers use the
//! triangular solves in [`super::trsm`].
//!
//! Calibration Gram matrices are frequently near-singular (p < m, or
//! correlated activations), so [`cholesky_upper_jittered`] escalates a
//! diagonal jitter geometrically until the factorization succeeds — the
//! same dampening trick GPTQ uses, exposed explicitly.

use crate::tensor::Matrix;

/// Failure: the matrix is not numerically positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Pivot index where positive-definiteness failed.
    pub pivot: usize,
    /// The offending pivot value.
    pub value: f64,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cholesky failed at pivot {} (value {:.3e})", self.pivot, self.value)
    }
}

impl std::error::Error for CholeskyError {}

/// Factor a symmetric positive-definite `G` (only the upper triangle is
/// read) into upper-triangular `R` with `G = RᵀR`. Diagonal accumulations
/// run in f64 to keep large `m` stable in f32 storage.
pub fn cholesky_upper(g: &Matrix) -> Result<Matrix, CholeskyError> {
    let n = g.rows();
    assert_eq!(g.cols(), n, "cholesky needs square input");
    let mut r = Matrix::zeros(n, n);
    // Row-by-row (upper-looking): for each row i,
    //   R[i,i] = sqrt(G[i,i] - sum_{k<i} R[k,i]^2)
    //   R[i,j] = (G[i,j] - sum_{k<i} R[k,i]R[k,j]) / R[i,i]
    for i in 0..n {
        let mut diag = g.get(i, i) as f64;
        for k in 0..i {
            let v = r.get(k, i) as f64;
            diag -= v * v;
        }
        if !(diag > 0.0) || !diag.is_finite() {
            return Err(CholeskyError { pivot: i, value: diag });
        }
        let rii = diag.sqrt();
        r.set(i, i, rii as f32);
        let inv = (1.0 / rii) as f32;
        // Compute the remainder of row i. The k-loop walks rows of R
        // (contiguous), accumulating into a scratch row — unit stride.
        let mut scratch: Vec<f32> = (i + 1..n).map(|j| g.get(i, j)).collect();
        for k in 0..i {
            let rki = r.get(k, i);
            if rki == 0.0 {
                continue;
            }
            let rk = &r.row(k)[i + 1..n];
            for (s, &v) in scratch.iter_mut().zip(rk) {
                *s -= rki * v;
            }
        }
        for (off, s) in scratch.into_iter().enumerate() {
            r.set(i, i + 1 + off, s * inv);
        }
    }
    Ok(r)
}

/// Cholesky with geometric jitter escalation: tries `G`, then
/// `G + jitter·mean(diag)·I` with jitter ∈ {j0, 10·j0, 100·j0, …} up to
/// 10 attempts. Returns `(R, jitter_used)` where jitter is the *absolute*
/// value added to the diagonal (0.0 when no jitter was needed).
pub fn cholesky_upper_jittered(g: &Matrix, j0: f64) -> Result<(Matrix, f64), CholeskyError> {
    match cholesky_upper(g) {
        Ok(r) => return Ok((r, 0.0)),
        Err(_) => {}
    }
    let n = g.rows();
    let mean_diag: f64 =
        (0..n).map(|i| g.get(i, i) as f64).sum::<f64>().max(1e-30) / n.max(1) as f64;
    let mut jitter = j0 * mean_diag;
    let mut last_err = CholeskyError { pivot: 0, value: 0.0 };
    for _ in 0..10 {
        let mut gj = g.clone();
        for i in 0..n {
            gj.add_at(i, i, jitter as f32);
        }
        match cholesky_upper(&gj) {
            Ok(r) => return Ok((r, jitter)),
            Err(e) => last_err = e,
        }
        jitter *= 10.0;
    }
    Err(last_err)
}
