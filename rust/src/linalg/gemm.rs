//! Blocked f32 GEMM and friends.
//!
//! The pre-solve cost of every layer quantization is dominated by Gram
//! matrix formation `X̃ᵀX̃` and target GEMMs `XW`, so this file carries the
//! crate's FLOP throughput. The kernels are cache-blocked and written so
//! LLVM auto-vectorizes the inner loops (contiguous unit-stride FMAs over
//! the output row); no unsafe, no intrinsics.

use crate::tensor::Matrix;

/// Cache block sizes. `MC×KC` A-panel (~128 KiB) fits L2; `KC×NC` B-panel
/// rows stream through L1. Tuned on the CI CPU in the §Perf pass.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul inner dim: {:?} vs {:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm(1.0, a, b, 0.0, &mut c);
    c
}

/// General `C = alpha·A·B + beta·C` (row-major, no transposes).
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm inner dim");
    assert_eq!(c.shape(), (m, n), "gemm output shape");
    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.map_inplace(|v| v * beta);
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    // Loop order jc (N) -> pc (K) -> ic (M); inner micro-kernel accumulates
    // a row of C against a K-panel of B with the K dimension unrolled 4×:
    // each pass over the (contiguous, vectorizable) C row performs 4 FMAs
    // per load/store instead of 1, quadrupling arithmetic intensity
    // (§Perf iteration 3: 13.5 → see perf_gemm.md).
    let a_s = a.as_slice();
    let b_s = b.as_slice();
    let c_s = c.as_mut_slice();
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kbk = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                for i in ic..ic + mb {
                    let c_row = &mut c_s[i * n + jc..i * n + jc + nb];
                    let a_row = &a_s[i * k + pc..i * k + pc + kbk];
                    let mut p = 0usize;
                    while p + 4 <= kbk {
                        let a0 = alpha * a_row[p];
                        let a1 = alpha * a_row[p + 1];
                        let a2 = alpha * a_row[p + 2];
                        let a3 = alpha * a_row[p + 3];
                        if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                            let base = (pc + p) * n + jc;
                            let b0 = &b_s[base..base + nb];
                            let b1 = &b_s[base + n..base + n + nb];
                            let b2 = &b_s[base + 2 * n..base + 2 * n + nb];
                            let b3 = &b_s[base + 3 * n..base + 3 * n + nb];
                            for j in 0..nb {
                                c_row[j] +=
                                    a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                            }
                        }
                        p += 4;
                    }
                    for (off, &aip) in a_row[p..].iter().enumerate() {
                        let aip = alpha * aip;
                        if aip == 0.0 {
                            continue;
                        }
                        let b_row = &b_s[(pc + p + off) * n + jc..(pc + p + off) * n + jc + nb];
                        for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                            *cv += aip * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Minimum `2·m·k·n` FLOPs before [`matmul_par`] fans row blocks out to
/// threads; below this the spawn + assembly overhead beats the win.
const PAR_FLOPS_MIN: usize = 1 << 22;

/// `C = A · B`, parallelized over contiguous row blocks of `A` when the
/// product is large enough — the tall-GEMM entry of the batched capture
/// path, where `A` is a vstack of per-sequence hidden caches.
///
/// **Bit-identical** to [`matmul`]: every output row is produced by the
/// same blocked micro-kernel over the same operands in the same order;
/// the row split only changes which thread runs it. Batched captures
/// therefore agree exactly with per-sequence stepping.
pub fn matmul_par(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    let nt = crate::parallel::num_threads();
    if nt <= 1 || m < 2 || 2usize.saturating_mul(m * k).saturating_mul(n) < PAR_FLOPS_MIN {
        return matmul(a, b);
    }
    let blocks = crate::parallel::parallel_for_chunks(m, |r| {
        let sub = a.block(r.start, 0, r.len(), k);
        (r.start, matmul(&sub, b))
    });
    let mut c = Matrix::zeros(m, n);
    for (r0, blk) in blocks {
        c.set_block(r0, 0, &blk);
    }
    c
}

/// Rows `[i0, i1)` of `C = Aᵀ · B` (`A: p×m`, `B: p×n`), accumulated
/// into the caller's zero-initialized `(i1−i0)×n` row-major buffer. This
/// is the whole serial kernel restricted to an output-row range: each
/// output element is accumulated by the exact same sequence of rank-4
/// FMAs regardless of the range split, which is what makes
/// [`gemm_tn`]'s parallel fan-out bit-identical to its serial form.
fn gemm_tn_rows(
    a_s: &[f32],
    b_s: &[f32],
    p: usize,
    m: usize,
    n: usize,
    i0: usize,
    i1: usize,
    buf: &mut [f32],
) {
    debug_assert_eq!(buf.len(), (i1 - i0) * n);
    // Rank-4 accumulation: four sample rows per pass over C. C fits L2
    // for our m,n (≤ ~1k); the inner loop is contiguous over n.
    let mut r = 0usize;
    while r + 4 <= p {
        let a0r = &a_s[r * m..(r + 1) * m];
        let a1r = &a_s[(r + 1) * m..(r + 2) * m];
        let a2r = &a_s[(r + 2) * m..(r + 3) * m];
        let a3r = &a_s[(r + 3) * m..(r + 4) * m];
        let b0 = &b_s[r * n..(r + 1) * n];
        let b1 = &b_s[(r + 1) * n..(r + 2) * n];
        let b2 = &b_s[(r + 2) * n..(r + 3) * n];
        let b3 = &b_s[(r + 3) * n..(r + 4) * n];
        for i in i0..i1 {
            let (a0, a1, a2, a3) = (a0r[i], a1r[i], a2r[i], a3r[i]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let c_row = &mut buf[(i - i0) * n..(i - i0) * n + n];
            for j in 0..n {
                c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        r += 4;
    }
    for rr in r..p {
        let a_row = &a_s[rr * m..(rr + 1) * m];
        let b_row = &b_s[rr * n..(rr + 1) * n];
        for i in i0..i1 {
            let av = a_row[i];
            if av == 0.0 {
                continue;
            }
            let c_row = &mut buf[(i - i0) * n..(i - i0) * n + n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
}

/// `C = Aᵀ · B` for `A: p×m`, `B: p×n` → `C: m×n`, without materializing
/// the transpose. Both operands are walked row-by-row (unit stride).
/// Large products fan output-row ranges out to threads (the RHS GEMM
/// `X̃ᵀY*` of every layer solve); each output row is produced by the same
/// kernel over the same operands in the same order, so the result is
/// **bit-identical** at any thread count.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let _span = crate::obs::span("gemm_tn");
    let (p, m) = a.shape();
    let (pb, n) = b.shape();
    assert_eq!(p, pb, "gemm_tn leading dim");
    let mut c = Matrix::zeros(m, n);
    let a_s = a.as_slice();
    let c_s = c.as_mut_slice();
    let nt = crate::parallel::num_threads();
    if nt <= 1 || m < 2 || 2usize.saturating_mul(p * m).saturating_mul(n) < PAR_FLOPS_MIN {
        gemm_tn_rows(a_s, b.as_slice(), p, m, n, 0, m, c_s);
        return c;
    }
    let b_s = b.as_slice();
    let chunks = crate::parallel::parallel_for_chunks(m, |range| {
        let mut buf = vec![0.0f32; range.len() * n];
        gemm_tn_rows(a_s, b_s, p, m, n, range.start, range.end, &mut buf);
        (range.start, buf)
    });
    for (i0, buf) in chunks {
        c_s[i0 * n..i0 * n + buf.len()].copy_from_slice(&buf);
    }
    c
}

/// Rows `[i0, i1)` of the upper triangle of `AᵀA` (`A: p×m`),
/// accumulated into the caller's zero-initialized `(i1−i0)×m` row-major
/// buffer (entries left of the diagonal stay zero). Restricting the
/// serial kernel to an output-row range keeps every element's FMA
/// sequence unchanged, so [`syrk_upper`]'s row-parallel fan-out is
/// bit-identical to serial.
fn syrk_rows(a_s: &[f32], p: usize, m: usize, i0: usize, i1: usize, buf: &mut [f32]) {
    debug_assert_eq!(buf.len(), (i1 - i0) * m);
    // Rank-4 updates: four sample rows per pass over G's upper triangle,
    // so each G row is loaded/stored once per 4 FMAs (§Perf iteration 4).
    let mut r = 0usize;
    while r + 4 <= p {
        let row0 = &a_s[r * m..(r + 1) * m];
        let row1 = &a_s[(r + 1) * m..(r + 2) * m];
        let row2 = &a_s[(r + 2) * m..(r + 3) * m];
        let row3 = &a_s[(r + 3) * m..(r + 4) * m];
        for i in i0..i1 {
            let (a0, a1, a2, a3) = (row0[i], row1[i], row2[i], row3[i]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let g_row = &mut buf[(i - i0) * m + i..(i - i0) * m + m];
            let (b0, b1, b2, b3) = (&row0[i..], &row1[i..], &row2[i..], &row3[i..]);
            for j in 0..g_row.len() {
                g_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        r += 4;
    }
    for rr in r..p {
        let row = &a_s[rr * m..(rr + 1) * m];
        for i in i0..i1 {
            let av = row[i];
            if av == 0.0 {
                continue;
            }
            let g_row = &mut buf[(i - i0) * m + i..(i - i0) * m + m];
            for (gv, &bv) in g_row.iter_mut().zip(&row[i..]) {
                *gv += av * bv;
            }
        }
    }
}

/// Split `[0, m)` into up to `parts` contiguous row ranges of
/// near-equal *upper-triangle area* (row `i` of the triangle costs
/// `m − i`): boundary `k` sits at `m·(1 − √(1 − k/parts))`. An even row
/// split would hand the first chunk ~2× its fair share of FLOPs.
fn triangular_split(m: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    for k in 1..parts {
        let frac = k as f64 / parts as f64;
        let r = (m as f64 * (1.0 - (1.0 - frac).sqrt())).round() as usize;
        bounds.push(r.clamp(*bounds.last().unwrap(), m));
    }
    bounds.push(m);
    let mut out = Vec::with_capacity(parts);
    for w in bounds.windows(2) {
        if w[1] > w[0] {
            out.push(w[0]..w[1]);
        }
    }
    out
}

/// Symmetric Gram matrix `G = AᵀA + ridge·I` for `A: p×m` → `G: m×m`.
/// Computes the upper triangle then mirrors — half the FLOPs of
/// `gemm_tn`. Large Grams (every layer solve's `X̃ᵀX̃`) fan output-row
/// ranges out to threads ([`triangular_split`] balances the ragged
/// per-row costs); the split leaves each element's accumulation order
/// untouched, so the result is **bit-identical** at any thread count.
pub fn syrk_upper(a: &Matrix, ridge: f32) -> Matrix {
    let _span = crate::obs::span("syrk");
    let (p, m) = a.shape();
    let mut g = Matrix::zeros(m, m);
    let a_s = a.as_slice();
    let g_s = g.as_mut_slice();
    let nt = crate::parallel::num_threads();
    if nt <= 1 || m < 2 || p.saturating_mul(m).saturating_mul(m) < PAR_FLOPS_MIN {
        syrk_rows(a_s, p, m, 0, m, g_s);
    } else {
        let ranges = triangular_split(m, nt);
        let chunks = crate::parallel::parallel_for_ranges(ranges, |range| {
            let mut buf = vec![0.0f32; range.len() * m];
            syrk_rows(a_s, p, m, range.start, range.end, &mut buf);
            (range.start, buf)
        });
        for (i0, buf) in chunks {
            g_s[i0 * m..i0 * m + buf.len()].copy_from_slice(&buf);
        }
    }
    // Mirror the strictly-upper part and add the ridge.
    for i in 0..m {
        g_s[i * m + i] += ridge;
        for j in i + 1..m {
            g_s[j * m + i] = g_s[i * m + j];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangular_split_covers_contiguously_and_balances_area() {
        for &(m, parts) in &[(0usize, 4usize), (1, 4), (7, 3), (100, 7), (256, 8)] {
            let rs = triangular_split(m, parts);
            let mut expect = 0;
            for r in &rs {
                assert_eq!(r.start, expect, "m={m} parts={parts}");
                assert!(r.end > r.start);
                expect = r.end;
            }
            assert_eq!(expect, m, "m={m} parts={parts}");
            // Triangle areas within ~2x of each other for real splits
            // (an even row split would be ~parts× apart at the extremes).
            if m >= 100 && rs.len() == parts {
                let area =
                    |r: &std::ops::Range<usize>| (r.start..r.end).map(|i| m - i).sum::<usize>();
                let areas: Vec<usize> = rs.iter().map(area).collect();
                let min = *areas.iter().min().unwrap();
                let max = *areas.iter().max().unwrap();
                assert!(max / min.max(1) <= 2, "m={m} parts={parts} areas={areas:?}");
            }
        }
    }
}

/// `y = x · B` for a single row vector `x: 1×k` and `B: k×n`, written
/// into the caller's `out` buffer — the allocation-free dense matvec of
/// the KV-cached decode loop ([`crate::serve`]).
///
/// **Bit-identical** to `matmul(x_as_1row, b).row(0)`: the loop nest is
/// [`gemm`] specialized to `m = 1, alpha = 1, beta = 0` — same `NC`/`KC`
/// blocking, same 4-unrolled K kernel with the same zero-skip, same
/// accumulation order — so single-token decode matches the batched
/// teacher-forced path exactly.
pub fn row_matmul_into(x: &[f32], b: &Matrix, out: &mut [f32]) {
    let (k, n) = b.shape();
    assert_eq!(x.len(), k, "row_matmul inner dim");
    assert_eq!(out.len(), n, "row_matmul output dim");
    out.fill(0.0);
    if n == 0 || k == 0 {
        return;
    }
    let b_s = b.as_slice();
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kbk = KC.min(k - pc);
            let c_row = &mut out[jc..jc + nb];
            let a_row = &x[pc..pc + kbk];
            let mut p = 0usize;
            while p + 4 <= kbk {
                let a0 = a_row[p];
                let a1 = a_row[p + 1];
                let a2 = a_row[p + 2];
                let a3 = a_row[p + 3];
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let base = (pc + p) * n + jc;
                    let b0 = &b_s[base..base + nb];
                    let b1 = &b_s[base + n..base + n + nb];
                    let b2 = &b_s[base + 2 * n..base + 2 * n + nb];
                    let b3 = &b_s[base + 3 * n..base + 3 * n + nb];
                    for j in 0..nb {
                        c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                }
                p += 4;
            }
            for (off, &aip) in a_row[p..].iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let b_row = &b_s[(pc + p + off) * n + jc..(pc + p + off) * n + jc + nb];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += aip * bv;
                }
            }
        }
    }
}

/// `y = A · x`.
pub fn gemv(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, k) = a.shape();
    assert_eq!(k, x.len(), "gemv inner dim");
    let a_s = a.as_slice();
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = &a_s[i * k..(i + 1) * k];
        let mut acc = 0.0f32;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[i] = acc;
    }
    y
}
