//! Numerical linear algebra substrate.
//!
//! Everything the OJBKQ pipeline needs, written from scratch (the build is
//! offline — no BLAS/LAPACK): blocked GEMM with f32 micro-kernels,
//! symmetric rank-k update for Gram matrices, Cholesky factorization with
//! adaptive jitter, triangular solves (vector and multiple-RHS), and
//! random orthogonal matrix generation for the QuIP-style baseline.

mod cholesky;
mod gemm;
mod orthogonal;
mod trsm;

pub use cholesky::{cholesky_upper, cholesky_upper_jittered, CholeskyError};
pub use gemm::{gemm, gemm_tn, gemv, matmul, matmul_par, row_matmul_into, syrk_upper};
pub use orthogonal::{random_orthogonal, signed_permutation};
pub use trsm::{solve_lower_t, solve_upper_mat, trsv_lower_t, trsv_upper};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    /// Naive triple-loop reference used to validate the blocked GEMM.
    fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols(), b.rows());
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                let aik = a.get(i, k);
                for j in 0..b.cols() {
                    c.add_at(i, j, aik * b.get(k, j));
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 130, 31)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = matmul_naive(&a, &b);
            assert!(c.rel_err(&r) < 1e-5, "({m},{k},{n}) rel={}", c.rel_err(&r));
        }
    }

    #[test]
    fn matmul_par_bit_identical_to_serial() {
        let mut rng = Rng::new(7);
        // Small (below the parallel threshold) and tall (above it): both
        // must agree with the serial kernel exactly, not approximately.
        for &(m, k, n) in &[(5usize, 9usize, 4usize), (300, 64, 128)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_eq!(matmul_par(&a, &b), matmul(&a, &b), "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_tn_matches_transpose_matmul() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(40, 13, 1.0, &mut rng);
        let b = Matrix::randn(40, 21, 1.0, &mut rng);
        let c = gemm_tn(&a, &b);
        let r = matmul(&a.transpose(), &b);
        assert!(c.rel_err(&r) < 1e-5);
    }

    #[test]
    fn syrk_matches_ata() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(50, 17, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.0);
        let r = matmul(&a.transpose(), &a);
        // syrk fills the full symmetric matrix.
        assert!(g.rel_err(&r) < 1e-5);
        for i in 0..17 {
            for j in 0..17 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn syrk_adds_ridge() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let g0 = syrk_upper(&a, 0.0);
        let g1 = syrk_upper(&a, 2.5);
        for i in 0..6 {
            assert!((g1.get(i, i) - g0.get(i, i) - 2.5).abs() < 1e-5);
        }
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(23, 11, 1.0, &mut rng);
        let x: Vec<f32> = (0..11).map(|i| i as f32 * 0.3 - 1.0).collect();
        let y = gemv(&a, &x);
        let xm = Matrix::from_vec(11, 1, x);
        let r = matmul(&a, &xm);
        for i in 0..23 {
            assert!((y[i] - r.get(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(6);
        for &n in &[1usize, 2, 8, 33, 64] {
            let a = Matrix::randn(n + 5, n, 1.0, &mut rng);
            let g = syrk_upper(&a, 0.1);
            let r = cholesky_upper(&g).expect("spd");
            let rtr = gemm_tn(&r, &r);
            assert!(rtr.rel_err(&g) < 1e-4, "n={n} rel={}", rtr.rel_err(&g));
            // Upper-triangular with positive diagonal.
            for i in 0..n {
                assert!(r.get(i, i) > 0.0);
                for j in 0..i {
                    assert_eq!(r.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite_but_jitter_recovers() {
        let mut g = Matrix::eye(4);
        g.set(2, 2, -1.0);
        assert!(cholesky_upper(&g).is_err());
        let (r, jitter) = cholesky_upper_jittered(&g, 1e-8).expect("jitter should recover");
        assert!(jitter > 0.0);
        assert!(r.all_finite());
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let mut rng = Rng::new(7);
        let n = 24;
        let a = Matrix::randn(n + 3, n, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.5);
        let r = cholesky_upper(&g).unwrap();
        let x_true: Vec<f32> = (0..n).map(|i| (i as f32 * 0.17).sin()).collect();
        // b = R^T R x
        let rx = gemv(&r, &x_true);
        let b = {
            let rt = r.transpose();
            gemv(&rt, &rx)
        };
        let u = trsv_lower_t(&r, &b); // solves R^T u = b
        let x = trsv_upper(&r, &u); // solves R x = u
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "i={i} {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn multi_rhs_solve_matches_vector_solve() {
        let mut rng = Rng::new(8);
        let n = 16;
        let a = Matrix::randn(n + 2, n, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.3);
        let r = cholesky_upper(&g).unwrap();
        let b = Matrix::randn(n, 5, 1.0, &mut rng);
        let xm = solve_upper_mat(&r, &b);
        for j in 0..5 {
            let xv = trsv_upper(&r, &b.col(j));
            for i in 0..n {
                assert!((xm.get(i, j) - xv[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(9);
        for &n in &[4usize, 16, 48] {
            let q = random_orthogonal(n, &mut rng);
            let qtq = gemm_tn(&q, &q);
            assert!(qtq.rel_err(&Matrix::eye(n)) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn signed_permutation_is_orthogonal() {
        let mut rng = Rng::new(10);
        let q = signed_permutation(12, &mut rng);
        let qtq = gemm_tn(&q, &q);
        assert!(qtq.rel_err(&Matrix::eye(12)) < 1e-6);
    }
}
