//! Random orthogonal matrices — the incoherence-processing substrate for
//! the QuIP-style baseline ([`crate::quant::quip`]).
//!
//! QuIP multiplies weights/Hessians by random orthogonal matrices so that
//! the lattice basis becomes "incoherent" (no dominant axis). We generate
//! them by Gram–Schmidt (QR) on a Gaussian matrix — Haar-distributed up to
//! sign convention — plus a cheaper signed-permutation variant used in
//! ablations.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Haar-random `n×n` orthogonal matrix via modified Gram–Schmidt on a
/// Gaussian sample. Columns are re-orthogonalized once ("twice is enough")
/// for f32 robustness at n up to ~1k.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Matrix {
    let g = Matrix::randn(n, n, 1.0, rng);
    // Work column-major on a transposed copy so each vector is contiguous.
    let gt = g.transpose();
    let mut cols: Vec<Vec<f32>> = (0..n).map(|i| gt.row(i).to_vec()).collect();
    for i in 0..n {
        // Two MGS passes against previous columns.
        for _pass in 0..2 {
            for j in 0..i {
                let dot: f64 = cols[i]
                    .iter()
                    .zip(&cols[j])
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                let d = dot as f32;
                // Split borrow: clone-free via raw indexing.
                let (left, right) = cols.split_at_mut(i);
                let cj = &left[j];
                for (v, &u) in right[0].iter_mut().zip(cj) {
                    *v -= d * u;
                }
            }
        }
        let norm: f64 = cols[i].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        let inv = if norm > 1e-12 { (1.0 / norm) as f32 } else { 0.0 };
        for v in cols[i].iter_mut() {
            *v *= inv;
        }
        // Degenerate column (measure-zero): replace with a canonical basis
        // vector orthogonal by construction after re-orthogonalization.
        if inv == 0.0 {
            for (k, v) in cols[i].iter_mut().enumerate() {
                *v = if k == i { 1.0 } else { 0.0 };
            }
        }
    }
    let mut q = Matrix::zeros(n, n);
    for (j, c) in cols.iter().enumerate() {
        for (i, &v) in c.iter().enumerate() {
            q.set(i, j, v);
        }
    }
    q
}

/// Random signed permutation matrix — an O(n) orthogonal transform used as
/// a cheap incoherence ablation (rotates axes without mixing them).
pub fn signed_permutation(n: usize, rng: &mut Rng) -> Matrix {
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut q = Matrix::zeros(n, n);
    for (i, &p) in perm.iter().enumerate() {
        let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
        q.set(i, p, sign);
    }
    q
}
