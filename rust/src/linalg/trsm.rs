//! Triangular solves against an upper-triangular factor `R` (and its
//! transpose), vector and multiple-right-hand-side forms.
//!
//! Algorithm 1 lines 3–4 of the paper: solve `Rᵀu = c` (forward
//! substitution on the implicitly-lower `Rᵀ`) then `Rv = u` (backward
//! substitution). `R` is stored upper-triangular row-major; we never form
//! `Rᵀ` or any inverse.

use crate::tensor::Matrix;

/// Solve `Rᵀ u = b` where `R` is upper-triangular (so `Rᵀ` is lower).
/// Forward substitution: `u[i] = (b[i] - Σ_{k<i} R[k,i]·u[k]) / R[i,i]`.
pub fn trsv_lower_t(r: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.len(), n);
    let mut u = b.to_vec();
    for i in 0..n {
        let ui = u[i] / r.get(i, i);
        u[i] = ui;
        if ui != 0.0 {
            // Scatter the update along column i of R = row i of Rᵀ:
            // u[j] -= R[i,j] * u[i] for j > i — row i of R is contiguous.
            let ri = &r.row(i)[i + 1..n];
            for (uj, &rij) in u[i + 1..].iter_mut().zip(ri) {
                *uj -= rij * ui;
            }
        }
    }
    u
}

/// Solve `R v = b` for upper-triangular `R` (backward substitution).
pub fn trsv_upper(r: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.len(), n);
    let mut v = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut acc = b[i] as f64;
        let ri = &r.row(i)[i + 1..n];
        for (k, &rij) in ri.iter().enumerate() {
            acc -= rij as f64 * v[i + 1 + k] as f64;
        }
        v[i] = (acc / r.get(i, i) as f64) as f32;
    }
    v
}

/// Minimum `n²·nrhs` work before the multi-RHS solves fan RHS-column
/// blocks out to threads; below this the spawn + block copy overhead
/// beats the win.
const PAR_SOLVE_MIN: usize = 1 << 21;

/// True when a multi-RHS triangular solve should run column-parallel.
fn par_solve(n: usize, nrhs: usize) -> bool {
    nrhs >= 2
        && crate::parallel::num_threads() > 1
        && n.saturating_mul(n).saturating_mul(nrhs) >= PAR_SOLVE_MIN
}

/// Fan a multi-RHS solve out over contiguous RHS-column blocks. Each
/// column's substitution recurrence touches only that column, so the
/// block split is **bit-identical** to the serial sweep — the same
/// per-element operations run in the same order, only on another thread.
fn solve_cols_par(
    r: &Matrix,
    b: &Matrix,
    serial: impl Fn(&Matrix, &Matrix) -> Matrix + Sync,
) -> Matrix {
    let n = r.rows();
    let nrhs = b.cols();
    let blocks = crate::parallel::parallel_for_chunks(nrhs, |range| {
        let sub = b.block(0, range.start, n, range.len());
        (range.start, serial(r, &sub))
    });
    let mut out = Matrix::zeros(n, nrhs);
    for (c0, blk) in blocks {
        out.set_block(0, c0, &blk);
    }
    out
}

/// Multiple-RHS `Rᵀ U = B` (B: n×nrhs), column-blocked so the inner loop
/// runs contiguously across RHS columns. Large systems run RHS-column-
/// parallel ([`solve_cols_par`] — bit-identical to serial).
pub fn solve_lower_t(r: &Matrix, b: &Matrix) -> Matrix {
    let _span = crate::obs::span("trsm");
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.rows(), n);
    if par_solve(n, b.cols()) {
        return solve_cols_par(r, b, solve_lower_t_serial);
    }
    solve_lower_t_serial(r, b)
}

fn solve_lower_t_serial(r: &Matrix, b: &Matrix) -> Matrix {
    let n = r.rows();
    let nrhs = b.cols();
    let mut u = b.clone();
    for i in 0..n {
        let inv = 1.0 / r.get(i, i);
        for j in 0..nrhs {
            let v = u.get(i, j) * inv;
            u.set(i, j, v);
        }
        let ui_row: Vec<f32> = u.row(i).to_vec();
        let ri: Vec<f32> = r.row(i)[i + 1..n].to_vec();
        for (k, &rij) in ri.iter().enumerate() {
            if rij == 0.0 {
                continue;
            }
            let dst = u.row_mut(i + 1 + k);
            for (d, &s) in dst.iter_mut().zip(&ui_row) {
                *d -= rij * s;
            }
        }
    }
    u
}

/// Multiple-RHS `R V = B` (B: n×nrhs), backward substitution with
/// row-contiguous updates. Large systems run RHS-column-parallel
/// ([`solve_cols_par`] — bit-identical to serial).
pub fn solve_upper_mat(r: &Matrix, b: &Matrix) -> Matrix {
    let _span = crate::obs::span("trsm");
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.rows(), n);
    if par_solve(n, b.cols()) {
        return solve_cols_par(r, b, solve_upper_mat_serial);
    }
    solve_upper_mat_serial(r, b)
}

fn solve_upper_mat_serial(r: &Matrix, b: &Matrix) -> Matrix {
    let n = r.rows();
    let nrhs = b.cols();
    let mut v = b.clone();
    for i in (0..n).rev() {
        // v[i,:] -= Σ_{j>i} R[i,j] · v[j,:]
        let ri: Vec<f32> = r.row(i)[i + 1..n].to_vec();
        let mut acc: Vec<f64> = v.row(i).iter().map(|&x| x as f64).collect();
        for (k, &rij) in ri.iter().enumerate() {
            if rij == 0.0 {
                continue;
            }
            let src = v.row(i + 1 + k);
            for (a, &s) in acc.iter_mut().zip(src) {
                *a -= rij as f64 * s as f64;
            }
        }
        let inv = 1.0 / r.get(i, i) as f64;
        let dst = v.row_mut(i);
        for (d, a) in dst.iter_mut().zip(acc) {
            *d = (a * inv) as f32;
        }
        let _ = nrhs;
    }
    v
}
