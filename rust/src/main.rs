//! `ojbkq` — the OJBKQ quantization pipeline CLI (L3 leader entrypoint).
//!
//! ```text
//! ojbkq info      [--artifacts DIR]
//! ojbkq quantize  --model NAME [--method ours] [--wbit 4] [--group 128]
//!                 [--k 5] [--mu μ] [--lambda λ] [--backend native|pjrt]
//!                 [--calib 32] [--seq 128] [--out CKPT.ojbq1]
//!                 [--resume DIR] [--dense-out PATH] [--dense-exec]
//!                 [--f32-core] [--trace] [--trace-out trace.json]
//!                 [--inject-fault SITE:KIND[:NTH]]
//! ojbkq eval      --model NAME [--method ours] [--from CKPT.ojbq1]
//!                 [--ppl-tokens 8192] [--zeroshot] [--reasoning]
//!                 (quantize + evaluate, or evaluate a saved checkpoint)
//! ojbkq generate  --model NAME [--method ours] [--from CKPT.ojbq1]
//!                 [--new 32] [--requests 1] [--batch R] [--temp 0]
//!                 [--prompt 3,1,4] [--prompt-len 32] [--gen-seed 7]
//!                 (KV-cached autoregressive serving: quantize or load a
//!                 checkpoint, then generate tokens through the
//!                 continuous-batching scheduler — greedy at --temp 0,
//!                 softmax sampling otherwise; prompts come from --prompt
//!                 token ids or --prompt-len eval-corpus slices)
//! ojbkq check-trace FILE   (validate a trace.json against its schema)
//! ojbkq methods   (list available solvers)
//! ```
//!
//! `--method` names a solver family (see `ojbkq methods`): the OJBKQ
//! variants (`ours`, `babai`/`ours-n`, `klein`/`ours-r`, `qep`), the
//! baselines (`rtn`, `gptq`, `awq`, `quip`), and the iterative families
//! on the shared-factor engine — `quantease` (cyclic coordinate descent
//! with exact rank-1 updates from the shared Gram, Babai/Klein warm
//! start) and `admmq`/`admm-q` (ADMM splitting between the continuous
//! Hessian-weighted LS subproblem and the box-constrained integer
//! projection, with penalty adaptation). See DESIGN.md §Solver families.
//!
//! `--trace` (also: `OJBKQ_TRACE=1`) turns on the observability stack
//! (`ojbkq::obs`): hierarchical wall-clock spans over every pipeline
//! phase (capture/factor/solve/pack per tap group, eval), per-layer
//! quantization-quality metrics (runtime/JTA residuals, Klein
//! improvement rate, clip rate, code occupancy), and packed-kernel
//! counters (MACs, unpacked code words, panel fills, gemv/gemm path
//! hits). After the run the CLI prints the span tree + per-layer
//! residual table and writes the machine-readable manifest to
//! `--trace-out` (default `trace.json`; schema documented in DESIGN.md
//! §Observability, checkable offline with `ojbkq check-trace`).
//! Tracing is pure observation — output is bit-identical on or off.
//!
//! Quantized execution is on by default: the pipeline returns a packed
//! [`ojbkq::infer::QuantizedModel`] whose calibration captures and evals
//! run straight from bit-packed integer codes. `--dense-exec` restores
//! the legacy dense f32 splice (also: `OJBKQ_DENSE_EXEC=1`). Packed
//! layers execute on the **integer core** by default — i32 group
//! accumulation over fixed-point activations, f32 touched once per
//! group boundary; `--f32-core` (also: `OJBKQ_F32_CORE=1`) pins the
//! per-code dequantize-and-FMA f32 reference kernel instead, the parity
//! baseline for the integer core (see DESIGN.md §Integer-core packed
//! GEMM).
//!
//! `quantize --out` writes the **native packed OJBQ1 checkpoint**
//! (`ojbkq::infer::save_quantized`) — integer codes, scale/correction
//! tables and decode perms exactly as the engine holds them, 4-8× below
//! the dense f32 export. `eval --from` loads such a checkpoint straight
//! into the packed engine and scores it, bit-identically to the run that
//! wrote it. `--dense-out` keeps the legacy dequantized OJBW1 export for
//! cross-checks.
//!
//! `quantize --out` is also **crash-safe**: the run writes a per-block
//! OJBS1 segment plus an OJBM1 run manifest to `CKPT.ojbq1.parts/` as
//! each transformer block completes (atomic temp-file + rename), then
//! assembles the final OJBQ1 checkpoint. After a crash,
//! `--resume CKPT.ojbq1.parts` verifies the manifest against the run
//! configuration and calibration digest, replays the completed blocks
//! from their segments, and continues — the resumed output is
//! bit-identical to an uninterrupted run (see DESIGN.md §Failure model).
//!
//! `--inject-fault SITE:KIND[:NTH]` (also: `OJBKQ_FAULTS`, comma list)
//! arms the fault-injection harness (`ojbkq::robust`) for robustness
//! drills: KIND ∈ err|panic|nan|partial_write|stall fires the NTH time
//! execution crosses SITE. Disarmed, every fault site costs one relaxed
//! atomic load — output is bit-identical with the harness compiled in.
//!
//! Model NAME refers to the zoo presets (see `config::ModelConfig::zoo`)
//! whose trained weights live in `artifacts/` after `make artifacts`.

use ojbkq::cli::Args;
use ojbkq::coordinator::{quantize_model, quantize_model_checkpointed, PipelineReport, Workbench};
use ojbkq::eval;
use ojbkq::infer::{load_quantized, save_quantized, QuantizedModel};
use ojbkq::quant::{Backend, Method, QuantConfig};
use ojbkq::report::{artifact_summary, fmt_bytes, RunTrace, Table};
use ojbkq::runtime::SolverRuntime;
use ojbkq::serve::{Request, Scheduler};
use ojbkq::util::fmt_secs;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::parse();
    if args.get_flag("f32-core") {
        // Process-global kernel toggle: pin the f32 reference core for
        // every packed matmul this run (capture, eval, checkpoint serving).
        ojbkq::infer::set_packed_core_override(Some(ojbkq::infer::PackedCore::F32));
    }
    if args.get_flag("trace") {
        // Process-global observability toggle, same shape as --f32-core:
        // spans, per-layer quality metrics, and kernel counters record for
        // the whole run and drain into trace.json at the end.
        ojbkq::obs::set_trace_override(Some(true));
    }
    if let Some(spec) = args.get("inject-fault") {
        // Process-global fault-injection arming, same shape as --trace
        // (env form: OJBKQ_FAULTS=site:kind[:nth],...).
        if let Err(e) = ojbkq::robust::set_faults(Some(spec)) {
            eprintln!("--inject-fault: {e}");
            std::process::exit(2);
        }
    }
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("methods") => cmd_methods(),
        Some("quantize") => cmd_quantize(&args, false),
        Some("eval") => cmd_quantize(&args, true),
        Some("generate") => cmd_generate(&args),
        Some("check-trace") => cmd_check_trace(&args),
        _ => {
            eprintln!(
                "usage: ojbkq <info|methods|quantize|eval|generate|check-trace> [--options]\n\
                 quantize --model NAME [--out CKPT.ojbq1] writes the native packed\n\
                 OJBQ1 checkpoint (--dense-out PATH keeps the dequantized OJBW1\n\
                 export for cross-checks); eval [--from CKPT.ojbq1] scores a saved\n\
                 checkpoint directly; generate serves tokens from it with a KV\n\
                 cache and continuous batching (--new N --requests R --temp T).\n\
                 quantize --resume DIR continues an interrupted --out run from\n\
                 its .parts/ directory; --inject-fault SITE:KIND[:NTH] arms the\n\
                 fault-injection harness (see DESIGN.md section Failure model).\n\
                 --trace [--trace-out FILE] records spans,\n\
                 per-layer quality metrics and kernel counters to trace.json;\n\
                 check-trace FILE validates one against the schema.\n\
                 see `rust/src/main.rs` docs or README.md"
            );
            2
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

fn quant_config(args: &Args) -> QuantConfig {
    let wbit = args.get_usize("wbit", 4) as u8;
    let group = args.get_usize("group", 128);
    let mut cfg = QuantConfig::paper_defaults(wbit, group);
    cfg.k = args.get_usize("k", cfg.k);
    cfg.mu = args.get_f64("mu", cfg.mu);
    cfg.lambda = args.get_f64("lambda", cfg.lambda);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.ntile = args.get_usize("ntile", cfg.ntile);
    cfg.block = args.get_usize("block", cfg.block);
    cfg.backend = match args.get_str("backend", "native").as_str() {
        "pjrt" => Backend::Pjrt,
        _ => Backend::Native,
    };
    if args.get_flag("dense-exec") {
        cfg.packed_exec = false;
    }
    cfg
}

fn cmd_methods() -> i32 {
    println!("available methods (--method):");
    for m in Method::all() {
        println!("  {:<10} {}", m.label().to_ascii_lowercase(), description(*m));
    }
    0
}

fn description(m: Method) -> &'static str {
    match m {
        Method::Fp => "no quantization (reference)",
        Method::Rtn => "round-to-nearest",
        Method::Gptq => "sequential error compensation (act-order on)",
        Method::Awq => "activation-aware weight scaling",
        Method::Quip => "incoherence rotation + greedy decode",
        Method::BabaiNaive => "Ours(N): box-constrained Babai nearest-plane",
        Method::KleinRandomK => "Ours(R): Random-K Babai/Klein",
        Method::Ojbkq => "Ours: Random-K Babai/Klein + JTA objective",
        Method::Qep => "QEP corner of JTA (mu=0, lambda=0)",
        Method::QuantEase => "cyclic coordinate descent, Babai/Klein warm start",
        Method::AdmmQ => "ADMM splitting w/ box projection + penalty adaptation",
    }
}

fn cmd_info(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    println!("artifacts dir: {dir:?}");
    match SolverRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT CPU client: ok");
            println!("decoder artifact variants:");
            for key in rt.registry() {
                println!("  {}", key.file_name());
            }
            if rt.registry().is_empty() {
                println!("  (none — run `make artifacts`)");
            }
        }
        Err(e) => println!("PJRT runtime unavailable: {e}"),
    }
    for name in ["tiny-0.2M", "small-0.8M", "base-2M", "med-5M"] {
        let present = dir.join(format!("model_{name}.bin")).exists();
        println!("model {name:<12} trained-weights={}", if present { "yes" } else { "no" });
    }
    0
}

/// Load an OJBQ1 checkpoint for `eval --from`, sanity-checking it
/// against the reference model's architecture.
fn load_checkpoint(ckpt: &str, name: &str, wb: &Workbench) -> anyhow::Result<QuantizedModel> {
    let qm = load_quantized(Path::new(ckpt), name)?;
    let (qc, mc) = (&qm.cfg, &wb.model.cfg);
    anyhow::ensure!(
        qc.vocab_size == mc.vocab_size
            && qc.d_model == mc.d_model
            && qc.n_layers == mc.n_layers
            && qc.n_heads == mc.n_heads
            && qc.d_ff == mc.d_ff
            && qc.max_seq == mc.max_seq,
        "checkpoint architecture does not match model {name}"
    );
    println!(
        "loaded OJBQ1 checkpoint {ckpt}: {} resident weight bytes ({:.2}x below dense f32)",
        qm.packed_weight_bytes(),
        qm.fp_weight_bytes() as f64 / qm.packed_weight_bytes().max(1) as f64
    );
    Ok(qm)
}

/// Run the quantization pipeline and any requested artifact writes.
/// Returns the packed model plus its [`PipelineReport`] (the caller
/// threads the report into the trace manifest when `--trace` is on).
/// `Err` carries the process exit code.
fn run_quantize(
    args: &Args,
    name: &str,
    method: Method,
    cfg: &QuantConfig,
    dir: &Path,
    wb: &Workbench,
) -> Result<(QuantizedModel, PipelineReport), i32> {
    let rt_holder;
    let rt = if cfg.backend == Backend::Pjrt {
        match SolverRuntime::new(dir) {
            Ok(r) => {
                rt_holder = r;
                Some(&rt_holder)
            }
            Err(e) => {
                eprintln!("error: pjrt backend requested but runtime failed: {e}");
                return Err(1);
            }
        }
    } else {
        None
    };
    let n_calib = args.get_usize("calib", 16);
    let seq = args.get_usize("seq", 128);
    println!(
        "quantizing {name} with {} (wbit={} group={} K={} mu={} lambda={})",
        method.label(),
        cfg.wbit,
        cfg.group_size,
        cfg.k,
        cfg.mu,
        cfg.lambda
    );
    // Crash-safe checkpointing: any run that writes an OJBQ1 checkpoint
    // also records per-block segments + a manifest in `<out>.parts/`;
    // `--resume DIR` picks an interrupted parts directory back up.
    let resume_dir = args.get("resume").map(PathBuf::from);
    let parts_dir: Option<PathBuf> = match (&resume_dir, args.get("out")) {
        (Some(d), _) => Some(d.clone()),
        (None, Some(out)) => Some(PathBuf::from(format!("{out}.parts"))),
        (None, None) => None,
    };
    if let Some(pd) = &parts_dir {
        println!(
            "crash-safe run: per-block segments + manifest in {} ({})",
            pd.display(),
            if resume_dir.is_some() { "resuming" } else { "fresh" }
        );
    }
    let run = match &parts_dir {
        Some(pd) => quantize_model_checkpointed(
            &wb.model,
            &wb.corpus,
            method,
            cfg,
            n_calib,
            seq,
            rt,
            pd,
            resume_dir.is_some(),
        ),
        None => quantize_model(&wb.model, &wb.corpus, method, cfg, n_calib, seq, rt),
    };
    let (qmodel, mut report) = match run {
        Ok(x) => x,
        Err(e) => {
            eprintln!("quantization failed: {e}");
            return Err(1);
        }
    };
    println!(
        "done in {} (capture {} / solver {}, {} block-steps); compression {:.2}x over fp32",
        fmt_secs(report.total_secs),
        fmt_secs(report.capture_secs),
        fmt_secs(report.solver_secs()),
        report.capture_block_steps,
        report.compression_ratio()
    );
    if report.layers.is_empty() {
        println!("packed engine: FP passthrough (no layers quantized; full f32 resident)");
    } else {
        println!(
            "packed engine: {} resident weight bytes ({:.2}x below the {} f32 bytes; {} execution)",
            report.packed_weight_bytes(),
            report.resident_compression(),
            report.fp_weight_bytes(),
            if cfg.packed_exec { "integer-kernel" } else { "dense" }
        );
    }
    if let Some(out) = args.get("out") {
        // Native packed checkpoint — straight from the integer codes, no
        // densify (the pre-OJBQ1 path exported `to_dense()` here and gave
        // the compression back at the disk boundary).
        match save_quantized(&qmodel, Path::new(out)) {
            Ok(info) => {
                report.artifact_bytes = Some(info.file_bytes);
                println!(
                    "wrote packed OJBQ1 checkpoint {}",
                    artifact_summary(out, info.file_bytes, qmodel.dense_export_bytes() as u64)
                );
            }
            Err(e) => {
                eprintln!("saving {out}: {e}");
                return Err(1);
            }
        }
    }
    if let Some(out) = args.get("dense-out") {
        if let Err(e) = ojbkq::model::save_model(&qmodel.to_dense(), Path::new(out)) {
            eprintln!("saving {out}: {e}");
            return Err(1);
        }
        println!("wrote dequantized OJBW1 cross-check model to {out}");
    }
    // One-line recap through the shared report formatter — includes the
    // artifact size recorded above when `--out` wrote a checkpoint.
    println!("[report] {}", ojbkq::bench::exp::timing_summary(&report));
    Ok((qmodel, report))
}

/// Assemble and emit the `--trace` manifest after a traced run: span
/// tree + metrics from the global registry, per-layer residual rows from
/// the pipeline report (absent for `eval --from`, which re-quantizes
/// nothing), and the run configuration. Prints the human rendering and
/// writes the JSON to `--trace-out` (default `trace.json`).
fn emit_trace(
    args: &Args,
    name: &str,
    method: Method,
    cfg: &QuantConfig,
    report: Option<&PipelineReport>,
) {
    let config = vec![
        ("model".to_string(), name.to_string()),
        ("method".to_string(), method.label().to_string()),
        ("wbit".to_string(), cfg.wbit.to_string()),
        ("group".to_string(), cfg.group_size.to_string()),
        ("k".to_string(), cfg.k.to_string()),
        ("mu".to_string(), cfg.mu.to_string()),
        ("lambda".to_string(), cfg.lambda.to_string()),
        ("seed".to_string(), cfg.seed.to_string()),
        ("backend".to_string(), format!("{:?}", cfg.backend).to_ascii_lowercase()),
        ("packed_exec".to_string(), cfg.packed_exec.to_string()),
    ];
    let mut trace = RunTrace::capture(config);
    if let Some(report) = report {
        trace.layers = report.trace_layers();
        print!("{}", report.layer_table().to_markdown());
    }
    print!("{}", trace.to_markdown());
    let out = args.get_str("trace-out", "trace.json");
    match trace.write(Path::new(&out)) {
        Ok(()) => println!("wrote trace manifest to {out}"),
        Err(e) => eprintln!("[warn] writing trace {out}: {e}"),
    }
}

/// `ojbkq check-trace FILE` — parse and schema-validate a `trace.json`,
/// rejecting unknown span segments / metric names (the CI traced leg's
/// gate against silent taxonomy drift).
fn cmd_check_trace(args: &Args) -> i32 {
    let Some(path) = args.positional.first() else {
        eprintln!("usage: ojbkq check-trace FILE");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("check-trace: reading {path}: {e}");
            return 1;
        }
    };
    match ojbkq::report::validate_trace(&text) {
        Ok(()) => {
            println!("check-trace: {path} ok (schema version {})", ojbkq::report::TRACE_VERSION);
            0
        }
        Err(e) => {
            eprintln!("check-trace: {path} INVALID: {e}");
            1
        }
    }
}

/// `ojbkq generate` — KV-cached autoregressive token serving: quantize
/// the model (or load an OJBQ1 checkpoint via `--from`), submit
/// `--requests` generation requests to the continuous-batching
/// [`Scheduler`], and report per-request tokens plus the serving-rate /
/// resident-memory summary (weights + KV cache as one number).
fn cmd_generate(args: &Args) -> i32 {
    let name = args.get_str("model", "small-0.8M");
    let method = match Method::parse(&args.get_str("method", "ours")) {
        Some(m) => m,
        None => {
            eprintln!("unknown method; see `ojbkq methods`");
            return 2;
        }
    };
    let cfg = quant_config(args);
    let dir = artifacts_dir(args);
    let wb = Workbench::load(&dir, &name);
    if !wb.trained {
        eprintln!("[warn] no trained artifacts for {name}; using random-init fallback");
    }
    let from = args.get("from");
    let mut report = None;
    let qmodel = if let Some(ckpt) = from {
        match load_checkpoint(ckpt, &name, &wb) {
            Ok(qm) => qm,
            Err(e) => {
                eprintln!("loading checkpoint {ckpt}: {e}");
                return 1;
            }
        }
    } else {
        match run_quantize(args, &name, method, &cfg, &dir, &wb) {
            Ok((qm, rep)) => {
                report = Some(rep);
                qm
            }
            Err(code) => return code,
        }
    };
    let max_seq = qmodel.cfg.max_seq;
    let n_req = args.get_usize("requests", 1).max(1);
    let batch = args.get_usize("batch", n_req).max(1);
    let max_new = args.get_usize("new", 32).max(1);
    let temperature = args.get_f32("temp", 0.0);
    let gen_seed = args.get_u64("gen-seed", 7);
    let prompt_len = args.get_usize("prompt-len", (max_seq / 4).max(1)).clamp(1, max_seq);
    let explicit: Option<Vec<u16>> =
        args.get("prompt").map(|_| args.get_list::<u16>("prompt", &[]));
    if let Some(p) = &explicit {
        if p.is_empty() || p.len() > max_seq {
            eprintln!("--prompt needs 1..={max_seq} comma-separated token ids");
            return 2;
        }
        if let Some(&bad) = p.iter().find(|&&t| t as usize >= qmodel.cfg.vocab_size) {
            eprintln!("--prompt token {bad} outside vocab of {}", qmodel.cfg.vocab_size);
            return 2;
        }
    }
    let eval_toks = wb.corpus.eval();
    if explicit.is_none() && eval_toks.len() < prompt_len {
        eprintln!(
            "eval corpus has {} tokens < prompt-len {prompt_len}; pass --prompt ids instead",
            eval_toks.len()
        );
        return 2;
    }
    let mut sched = Scheduler::new(&qmodel, batch);
    for r in 0..n_req {
        let prompt = match &explicit {
            Some(p) => p.clone(),
            None => {
                // Deterministic staggered eval-corpus slices, one per
                // request, wrapping as needed.
                let start = (r * prompt_len) % eval_toks.len().saturating_sub(prompt_len).max(1);
                eval_toks[start..start + prompt_len].to_vec()
            }
        };
        if let Err(reason) = sched.submit(Request {
            id: r as u64,
            prompt,
            max_new,
            temperature,
            seed: gen_seed.wrapping_add(r as u64),
        }) {
            eprintln!("request {r} rejected: {reason}");
        }
    }
    sched.run();
    for f in sched.finished() {
        println!(
            "request {}: prompt {} tokens -> {} generated ({}): {:?}",
            f.id,
            f.prompt_len,
            f.generated.len(),
            f.status,
            f.generated
        );
    }
    let secs = sched.prefill_secs() + sched.decode_secs();
    println!(
        "served {} tokens across {} requests in {} (prefill {} / decode {}): {:.1} tok/s",
        sched.tokens_generated(),
        n_req,
        fmt_secs(secs),
        fmt_secs(sched.prefill_secs()),
        fmt_secs(sched.decode_secs()),
        sched.tokens_generated() as f64 / secs.max(1e-9),
    );
    // Resident serving memory is weights + KV cache as ONE number — the
    // cache is real deployment memory, not an accounting footnote.
    let weight_bytes = qmodel.packed_weight_bytes() as u64;
    let kv_peak = sched.peak_kv_bytes() as u64;
    println!(
        "resident serving memory: {} packed weights + {} peak KV cache = {}",
        fmt_bytes(weight_bytes),
        fmt_bytes(kv_peak),
        fmt_bytes(weight_bytes + kv_peak),
    );
    if ojbkq::obs::enabled() {
        emit_trace(args, &name, method, &cfg, report.as_ref());
    }
    0
}

fn cmd_quantize(args: &Args, and_eval: bool) -> i32 {
    let name = args.get_str("model", "small-0.8M");
    let method = match Method::parse(&args.get_str("method", "ours")) {
        Some(m) => m,
        None => {
            eprintln!("unknown method; see `ojbkq methods`");
            return 2;
        }
    };
    let cfg = quant_config(args);
    let dir = artifacts_dir(args);
    let wb = Workbench::load(&dir, &name);
    if !wb.trained {
        eprintln!("[warn] no trained artifacts for {name}; using random-init fallback");
    }
    let from = if and_eval { args.get("from") } else { None };
    let mut report = None;
    let qmodel = if let Some(ckpt) = from {
        // Score a previously written OJBQ1 checkpoint: no re-quantization,
        // the packed codes load straight into the execution engine —
        // bit-identical to the run that wrote them.
        match load_checkpoint(ckpt, &name, &wb) {
            Ok(qm) => qm,
            Err(e) => {
                eprintln!("loading checkpoint {ckpt}: {e}");
                return 1;
            }
        }
    } else {
        match run_quantize(args, &name, method, &cfg, &dir, &wb) {
            Ok((qm, rep)) => {
                report = Some(rep);
                qm
            }
            Err(code) => return code,
        }
    };
    if and_eval {
        // The OJBQ1 header carries no method field, so a loaded
        // checkpoint's column says what it is instead of misattributing
        // the numbers to whatever --method defaulted to.
        let label = if from.is_some() { "checkpoint" } else { method.label() };
        let ppl_tokens = args.get_usize("ppl-tokens", 8_192);
        let seq_len = wb.model.cfg.max_seq;
        let (c4, wt2) =
            eval::perplexity_pair(&qmodel, &wb.corpus, &wb.shifted, seq_len, ppl_tokens);
        let (fc4, fwt2) =
            eval::perplexity_pair(&wb.model, &wb.corpus, &wb.shifted, seq_len, ppl_tokens);
        let mut t = Table::new(&format!("{name} — {label}"), &["metric", "FP32", label]);
        t.push_row(&["ppl (in-domain)".to_string(), format!("{fc4:.3}"), format!("{c4:.3}")]);
        t.push_row(&["ppl (shifted)".to_string(), format!("{fwt2:.3}"), format!("{wt2:.3}")]);
        if args.get_flag("zeroshot") {
            for task in eval::ZeroShotTask::suite() {
                let a = eval::zero_shot_accuracy(&qmodel, &wb.corpus, &task, 100, cfg.seed);
                let f = eval::zero_shot_accuracy(&wb.model, &wb.corpus, &task, 100, cfg.seed);
                t.push_row(&[task.name.to_string(), format!("{f:.1}"), format!("{a:.1}")]);
            }
        }
        if args.get_flag("reasoning") {
            for task in eval::ReasoningTask::suite() {
                let a = eval::reasoning_accuracy(&qmodel, &wb.corpus, &task, 50, cfg.seed);
                let f = eval::reasoning_accuracy(&wb.model, &wb.corpus, &task, 50, cfg.seed);
                t.push_row(&[task.name.to_string(), format!("{f:.1}"), format!("{a:.1}")]);
            }
        }
        t.emit(None, "eval");
    }
    if ojbkq::obs::enabled() {
        emit_trace(args, &name, method, &cfg, report.as_ref());
    }
    0
}
