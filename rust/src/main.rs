//! `ojbkq` — the OJBKQ quantization pipeline CLI (L3 leader entrypoint).
//!
//! ```text
//! ojbkq info      [--artifacts DIR]
//! ojbkq quantize  --model NAME [--method ours] [--wbit 4] [--group 128]
//!                 [--k 5] [--mu μ] [--lambda λ] [--backend native|pjrt]
//!                 [--calib 32] [--seq 128] [--out PATH] [--dense-exec]
//! ojbkq eval      --model NAME [--method ours] [--ppl-tokens 8192]
//!                 [--zeroshot] [--reasoning] (quantize + evaluate)
//! ojbkq methods   (list available solvers)
//! ```
//!
//! Quantized execution is on by default: the pipeline returns a packed
//! [`ojbkq::infer::QuantizedModel`] whose calibration captures and evals
//! run straight from bit-packed integer codes. `--dense-exec` restores
//! the legacy dense f32 splice (also: `OJBKQ_DENSE_EXEC=1`).
//!
//! Model NAME refers to the zoo presets (see `config::ModelConfig::zoo`)
//! whose trained weights live in `artifacts/` after `make artifacts`.

use ojbkq::cli::Args;
use ojbkq::coordinator::{quantize_model, Workbench};
use ojbkq::eval;
use ojbkq::quant::{Backend, Method, QuantConfig};
use ojbkq::report::Table;
use ojbkq::runtime::SolverRuntime;
use ojbkq::util::fmt_secs;
use std::path::PathBuf;

fn main() {
    let args = Args::parse();
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("methods") => cmd_methods(),
        Some("quantize") => cmd_quantize(&args, false),
        Some("eval") => cmd_quantize(&args, true),
        _ => {
            eprintln!(
                "usage: ojbkq <info|methods|quantize|eval> [--options]\n\
                 see `rust/src/main.rs` docs or README.md"
            );
            2
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

fn quant_config(args: &Args) -> QuantConfig {
    let wbit = args.get_usize("wbit", 4) as u8;
    let group = args.get_usize("group", 128);
    let mut cfg = QuantConfig::paper_defaults(wbit, group);
    cfg.k = args.get_usize("k", cfg.k);
    cfg.mu = args.get_f64("mu", cfg.mu);
    cfg.lambda = args.get_f64("lambda", cfg.lambda);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.ntile = args.get_usize("ntile", cfg.ntile);
    cfg.block = args.get_usize("block", cfg.block);
    cfg.backend = match args.get_str("backend", "native").as_str() {
        "pjrt" => Backend::Pjrt,
        _ => Backend::Native,
    };
    if args.get_flag("dense-exec") {
        cfg.packed_exec = false;
    }
    cfg
}

fn cmd_methods() -> i32 {
    println!("available methods (--method):");
    for m in Method::all() {
        println!("  {:<10} {}", m.label().to_ascii_lowercase(), description(*m));
    }
    0
}

fn description(m: Method) -> &'static str {
    match m {
        Method::Fp => "no quantization (reference)",
        Method::Rtn => "round-to-nearest",
        Method::Gptq => "sequential error compensation (act-order on)",
        Method::Awq => "activation-aware weight scaling",
        Method::Quip => "incoherence rotation + greedy decode",
        Method::BabaiNaive => "Ours(N): box-constrained Babai nearest-plane",
        Method::KleinRandomK => "Ours(R): Random-K Babai/Klein",
        Method::Ojbkq => "Ours: Random-K Babai/Klein + JTA objective",
        Method::Qep => "QEP corner of JTA (mu=0, lambda=0)",
    }
}

fn cmd_info(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    println!("artifacts dir: {dir:?}");
    match SolverRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT CPU client: ok");
            println!("decoder artifact variants:");
            for key in rt.registry() {
                println!("  {}", key.file_name());
            }
            if rt.registry().is_empty() {
                println!("  (none — run `make artifacts`)");
            }
        }
        Err(e) => println!("PJRT runtime unavailable: {e}"),
    }
    for name in ["tiny-0.2M", "small-0.8M", "base-2M", "med-5M"] {
        let present = dir.join(format!("model_{name}.bin")).exists();
        println!("model {name:<12} trained-weights={}", if present { "yes" } else { "no" });
    }
    0
}

fn cmd_quantize(args: &Args, and_eval: bool) -> i32 {
    let name = args.get_str("model", "small-0.8M");
    let method = match Method::parse(&args.get_str("method", "ours")) {
        Some(m) => m,
        None => {
            eprintln!("unknown method; see `ojbkq methods`");
            return 2;
        }
    };
    let cfg = quant_config(args);
    let dir = artifacts_dir(args);
    let wb = Workbench::load(&dir, &name);
    if !wb.trained {
        eprintln!("[warn] no trained artifacts for {name}; using random-init fallback");
    }
    let rt_holder;
    let rt = if cfg.backend == Backend::Pjrt {
        match SolverRuntime::new(&dir) {
            Ok(r) => {
                rt_holder = r;
                Some(&rt_holder)
            }
            Err(e) => {
                eprintln!("error: pjrt backend requested but runtime failed: {e}");
                return 1;
            }
        }
    } else {
        None
    };
    let n_calib = args.get_usize("calib", 16);
    let seq = args.get_usize("seq", 128);
    println!(
        "quantizing {name} with {} (wbit={} group={} K={} mu={} lambda={})",
        method.label(),
        cfg.wbit,
        cfg.group_size,
        cfg.k,
        cfg.mu,
        cfg.lambda
    );
    let (qmodel, report) =
        match quantize_model(&wb.model, &wb.corpus, method, &cfg, n_calib, seq, rt) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("quantization failed: {e}");
                return 1;
            }
        };
    println!(
        "done in {} (capture {} / solver {}, {} block-steps); compression {:.2}x over fp32",
        fmt_secs(report.total_secs),
        fmt_secs(report.capture_secs),
        fmt_secs(report.solver_secs()),
        report.capture_block_steps,
        report.compression_ratio()
    );
    if report.layers.is_empty() {
        println!("packed engine: FP passthrough (no layers quantized; full f32 resident)");
    } else {
        println!(
            "packed engine: {} resident weight bytes ({:.2}x below the {} f32 bytes; {} execution)",
            report.packed_weight_bytes(),
            report.resident_compression(),
            report.fp_weight_bytes(),
            if cfg.packed_exec { "integer-kernel" } else { "dense" }
        );
    }
    if let Some(out) = args.get("out") {
        if let Err(e) = ojbkq::model::save_model(&qmodel.to_dense(), std::path::Path::new(out)) {
            eprintln!("saving {out}: {e}");
            return 1;
        }
        println!("wrote dequantized model to {out}");
    }
    if and_eval {
        let ppl_tokens = args.get_usize("ppl-tokens", 8_192);
        let seq_len = wb.model.cfg.max_seq;
        let (c4, wt2) =
            eval::perplexity_pair(&qmodel, &wb.corpus, &wb.shifted, seq_len, ppl_tokens);
        let (fc4, fwt2) =
            eval::perplexity_pair(&wb.model, &wb.corpus, &wb.shifted, seq_len, ppl_tokens);
        let mut t = Table::new(
            &format!("{name} — {}", method.label()),
            &["metric", "FP32", method.label()],
        );
        t.push_row(&["ppl (in-domain)".to_string(), format!("{fc4:.3}"), format!("{c4:.3}")]);
        t.push_row(&["ppl (shifted)".to_string(), format!("{fwt2:.3}"), format!("{wt2:.3}")]);
        if args.get_flag("zeroshot") {
            for task in eval::ZeroShotTask::suite() {
                let a = eval::zero_shot_accuracy(&qmodel, &wb.corpus, &task, 100, cfg.seed);
                let f = eval::zero_shot_accuracy(&wb.model, &wb.corpus, &task, 100, cfg.seed);
                t.push_row(&[task.name.to_string(), format!("{f:.1}"), format!("{a:.1}")]);
            }
        }
        if args.get_flag("reasoning") {
            for task in eval::ReasoningTask::suite() {
                let a = eval::reasoning_accuracy(&qmodel, &wb.corpus, &task, 50, cfg.seed);
                let f = eval::reasoning_accuracy(&wb.model, &wb.corpus, &task, 50, cfg.seed);
                t.push_row(&[task.name.to_string(), format!("{f:.1}"), format!("{a:.1}")]);
            }
        }
        t.emit(None, "eval");
    }
    0
}
