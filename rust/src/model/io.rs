//! Weight (de)serialization — the "OJBW1" format written by
//! `python/compile/pretrain.py` and read here:
//!
//! ```text
//! OJBW1\n
//! vocab d_model n_layers n_heads d_ff max_seq\n
//! { name\n rows cols\n <rows*cols f32 LE bytes> }*
//! ```
//!
//! Tensor names: `embedding`, `final_norm` (1×d), and per block `b{i}.`
//! + {`attn_norm` (1×d), `wq wk wv wo` (d×d), `mlp_norm` (1×d),
//! `wgate wup` (d×ff), `wdown` (ff×d)}.

use super::{Block, Model};
use crate::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::{bytes_to_f32s, f32s_to_bytes};
use std::collections::HashMap;
use std::io::{BufRead, Read, Write};
use std::path::Path;

const MAGIC: &str = "OJBW1";

/// Save a model in OJBW1 format.
pub fn save_model(model: &Model, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{MAGIC}")?;
    let c = &model.cfg;
    writeln!(
        w,
        "{} {} {} {} {} {}",
        c.vocab_size, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.max_seq
    )?;
    let mut write_tensor = |name: &str,
                            rows: usize,
                            cols: usize,
                            data: &[f32]|
     -> anyhow::Result<()> {
        writeln!(w, "{name}")?;
        writeln!(w, "{rows} {cols}")?;
        w.write_all(&f32s_to_bytes(data))?;
        Ok(())
    };
    write_tensor("embedding", c.vocab_size, c.d_model, model.embedding.as_slice())?;
    for (i, b) in model.blocks.iter().enumerate() {
        write_tensor(&format!("b{i}.attn_norm"), 1, c.d_model, &b.attn_norm)?;
        write_tensor(&format!("b{i}.wq"), c.d_model, c.d_model, b.wq.as_slice())?;
        write_tensor(&format!("b{i}.wk"), c.d_model, c.d_model, b.wk.as_slice())?;
        write_tensor(&format!("b{i}.wv"), c.d_model, c.d_model, b.wv.as_slice())?;
        write_tensor(&format!("b{i}.wo"), c.d_model, c.d_model, b.wo.as_slice())?;
        write_tensor(&format!("b{i}.mlp_norm"), 1, c.d_model, &b.mlp_norm)?;
        write_tensor(&format!("b{i}.wgate"), c.d_model, c.d_ff, b.wgate.as_slice())?;
        write_tensor(&format!("b{i}.wup"), c.d_model, c.d_ff, b.wup.as_slice())?;
        write_tensor(&format!("b{i}.wdown"), c.d_ff, c.d_model, b.wdown.as_slice())?;
    }
    write_tensor("final_norm", 1, c.d_model, &model.final_norm)?;
    Ok(())
}

/// Load a model in OJBW1 format. `name` labels the returned config.
pub fn load_model(path: &Path, name: &str) -> anyhow::Result<Model> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening model {path:?}: {e} (run `make artifacts`)"))?;
    let mut r = std::io::BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line)?;
    anyhow::ensure!(line.trim() == MAGIC, "bad magic {line:?} in {path:?}");
    line.clear();
    r.read_line(&mut line)?;
    let dims: Vec<usize> =
        line.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
    anyhow::ensure!(dims.len() == 6, "bad config line {line:?}");
    let cfg = ModelConfig {
        name: name.to_string(),
        vocab_size: dims[0],
        d_model: dims[1],
        n_layers: dims[2],
        n_heads: dims[3],
        d_ff: dims[4],
        max_seq: dims[5],
    };
    let mut tensors: HashMap<String, Matrix> = HashMap::new();
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let tname = line.trim().to_string();
        if tname.is_empty() {
            continue;
        }
        line.clear();
        r.read_line(&mut line)?;
        let shape: Vec<usize> =
            line.split_whitespace().map(|t| t.parse()).collect::<Result<_, _>>()?;
        anyhow::ensure!(shape.len() == 2, "bad shape line {line:?} for {tname}");
        let (rows, cols) = (shape[0], shape[1]);
        let mut buf = vec![0u8; rows * cols * 4];
        r.read_exact(&mut buf)?;
        tensors.insert(tname, Matrix::from_vec(rows, cols, bytes_to_f32s(&buf)?));
    }
    let take = |tensors: &mut HashMap<String, Matrix>, name: &str| -> anyhow::Result<Matrix> {
        tensors.remove(name).ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))
    };
    let take_vec = |tensors: &mut HashMap<String, Matrix>, name: &str| -> anyhow::Result<Vec<f32>> {
        Ok(take(tensors, name)?.into_vec())
    };
    let embedding = take(&mut tensors, "embedding")?;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        blocks.push(Block {
            attn_norm: take_vec(&mut tensors, &format!("b{i}.attn_norm"))?,
            wq: take(&mut tensors, &format!("b{i}.wq"))?,
            wk: take(&mut tensors, &format!("b{i}.wk"))?,
            wv: take(&mut tensors, &format!("b{i}.wv"))?,
            wo: take(&mut tensors, &format!("b{i}.wo"))?,
            mlp_norm: take_vec(&mut tensors, &format!("b{i}.mlp_norm"))?,
            wgate: take(&mut tensors, &format!("b{i}.wgate"))?,
            wup: take(&mut tensors, &format!("b{i}.wup"))?,
            wdown: take(&mut tensors, &format!("b{i}.wdown"))?,
        });
    }
    let final_norm = take_vec(&mut tensors, "final_norm")?;
    let model = Model { cfg, embedding, blocks, final_norm };
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig {
            name: "rt".into(),
            vocab_size: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
        };
        let mut rng = Rng::new(1);
        let m = Model::random(cfg, &mut rng);
        let dir = std::env::temp_dir().join("ojbkq_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path, "rt").unwrap();
        assert_eq!(m.embedding, m2.embedding);
        assert_eq!(m.blocks[1].wdown, m2.blocks[1].wdown);
        assert_eq!(m.final_norm, m2.final_norm);
        // Same forward outputs.
        let toks: Vec<u16> = vec![3, 7, 1, 0];
        assert!(m.forward(&toks).rel_err(&m2.forward(&toks)) < 1e-12);
    }

    #[test]
    fn load_missing_file_errors_with_hint() {
        let err = load_model(Path::new("/nonexistent/m.bin"), "x").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
