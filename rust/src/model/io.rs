//! Weight (de)serialization — the "OJBW1" format written by
//! `python/compile/pretrain.py` and read here:
//!
//! ```text
//! OJBW1\n
//! vocab d_model n_layers n_heads d_ff max_seq\n
//! { name\n rows cols\n <rows*cols f32 LE bytes> }*
//! ```
//!
//! Tensor names: `embedding`, `final_norm` (1×d), and per block `b{i}.`
//! + {`attn_norm` (1×d), `wq wk wv wo` (d×d), `mlp_norm` (1×d),
//! `wgate wup` (d×ff), `wdown` (ff×d)}.

use super::{Block, Model};
use crate::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::{bytes_to_f32s, f32s_to_bytes};
use std::collections::HashMap;
use std::io::{BufRead, Read, Write};
use std::path::Path;

const MAGIC: &str = "OJBW1";

/// Render the config header line shared by OJBW1 and the packed OJBQ1
/// checkpoint format (`crate::infer::io`):
/// `vocab d_model n_layers n_heads d_ff max_seq`.
pub(crate) fn config_header_line(c: &ModelConfig) -> String {
    format!(
        "{} {} {} {} {} {}",
        c.vocab_size, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.max_seq
    )
}

/// Parse `n` whitespace-separated `usize` fields from a header line,
/// rejecting malformed or overlong input with a labeled error.
pub(crate) fn parse_usize_fields(line: &str, n: usize, what: &str) -> anyhow::Result<Vec<usize>> {
    let fields: Vec<usize> = line
        .split_whitespace()
        .map(|t| t.parse())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad {what} line {line:?}: {e}"))?;
    anyhow::ensure!(
        fields.len() == n,
        "bad {what} line {line:?} ({} fields, expected {n})",
        fields.len()
    );
    Ok(fields)
}

/// Parse and structurally validate the shared config header line. The
/// checks reject dimensions no forward pass could execute (zero sizes, a
/// head count that does not divide `d_model`) so downstream readers can
/// size allocations off the config without asserting later.
pub(crate) fn parse_config_header(line: &str, name: &str) -> anyhow::Result<ModelConfig> {
    let dims = parse_usize_fields(line, 6, "config")?;
    let cfg = ModelConfig {
        name: name.to_string(),
        vocab_size: dims[0],
        d_model: dims[1],
        n_layers: dims[2],
        n_heads: dims[3],
        d_ff: dims[4],
        max_seq: dims[5],
    };
    anyhow::ensure!(
        cfg.vocab_size >= 1 && cfg.d_model >= 1 && cfg.d_ff >= 1 && cfg.max_seq >= 1,
        "degenerate config {line:?}"
    );
    anyhow::ensure!(
        cfg.n_heads >= 1 && cfg.d_model % cfg.n_heads == 0,
        "n_heads {} does not divide d_model {}",
        cfg.n_heads,
        cfg.d_model
    );
    Ok(cfg)
}

/// Write one f32 tensor payload — the framing shared by OJBW1 records
/// and the dense records of OJBQ1 (`crate::infer::io`): `rows cols\n`
/// followed by `rows·cols` little-endian f32 bytes. Callers write their
/// own name/tag lines first.
pub(crate) fn write_f32_payload(
    w: &mut impl Write,
    rows: usize,
    cols: usize,
    data: &[f32],
) -> anyhow::Result<()> {
    debug_assert_eq!(data.len(), rows * cols, "tensor payload shape");
    writeln!(w, "{rows} {cols}")?;
    w.write_all(&f32s_to_bytes(data))?;
    Ok(())
}

/// Save a model in OJBW1 format.
pub fn save_model(model: &Model, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "{MAGIC}")?;
    writeln!(w, "{}", config_header_line(&model.cfg))?;
    let c = &model.cfg;
    let mut write_tensor = |name: &str,
                            rows: usize,
                            cols: usize,
                            data: &[f32]|
     -> anyhow::Result<()> {
        writeln!(w, "{name}")?;
        write_f32_payload(&mut w, rows, cols, data)
    };
    write_tensor("embedding", c.vocab_size, c.d_model, model.embedding.as_slice())?;
    for (i, b) in model.blocks.iter().enumerate() {
        write_tensor(&format!("b{i}.attn_norm"), 1, c.d_model, &b.attn_norm)?;
        write_tensor(&format!("b{i}.wq"), c.d_model, c.d_model, b.wq.as_slice())?;
        write_tensor(&format!("b{i}.wk"), c.d_model, c.d_model, b.wk.as_slice())?;
        write_tensor(&format!("b{i}.wv"), c.d_model, c.d_model, b.wv.as_slice())?;
        write_tensor(&format!("b{i}.wo"), c.d_model, c.d_model, b.wo.as_slice())?;
        write_tensor(&format!("b{i}.mlp_norm"), 1, c.d_model, &b.mlp_norm)?;
        write_tensor(&format!("b{i}.wgate"), c.d_model, c.d_ff, b.wgate.as_slice())?;
        write_tensor(&format!("b{i}.wup"), c.d_model, c.d_ff, b.wup.as_slice())?;
        write_tensor(&format!("b{i}.wdown"), c.d_ff, c.d_model, b.wdown.as_slice())?;
    }
    write_tensor("final_norm", 1, c.d_model, &model.final_norm)?;
    Ok(())
}

/// Load a model in OJBW1 format. `name` labels the returned config.
pub fn load_model(path: &Path, name: &str) -> anyhow::Result<Model> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening model {path:?}: {e} (run `make artifacts`)"))?;
    let file_len = f.metadata()?.len();
    let mut r = std::io::BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line)?;
    anyhow::ensure!(line.trim() == MAGIC, "bad magic {line:?} in {path:?}");
    line.clear();
    r.read_line(&mut line)?;
    let cfg = parse_config_header(&line, name)?;
    let mut tensors: HashMap<String, Matrix> = HashMap::new();
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let tname = line.trim().to_string();
        if tname.is_empty() {
            continue;
        }
        line.clear();
        r.read_line(&mut line)?;
        let shape = parse_usize_fields(&line, 2, "shape")?;
        let (rows, cols) = (shape[0], shape[1]);
        // Same hostile-header hardening as the OJBQ1 loader: refuse to
        // allocate more than the file could possibly hold, with the size
        // arithmetic overflow-checked.
        let byte_len = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| anyhow::anyhow!("{tname}: tensor size overflow"))?;
        anyhow::ensure!(
            byte_len as u64 <= file_len,
            "{tname}: {byte_len} bytes declared in a {file_len}-byte file"
        );
        let mut buf = vec![0u8; byte_len];
        r.read_exact(&mut buf)?;
        tensors.insert(tname, Matrix::from_vec(rows, cols, bytes_to_f32s(&buf)?));
    }
    let take = |tensors: &mut HashMap<String, Matrix>, name: &str| -> anyhow::Result<Matrix> {
        tensors.remove(name).ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))
    };
    let take_vec = |tensors: &mut HashMap<String, Matrix>, name: &str| -> anyhow::Result<Vec<f32>> {
        Ok(take(tensors, name)?.into_vec())
    };
    let embedding = take(&mut tensors, "embedding")?;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        blocks.push(Block {
            attn_norm: take_vec(&mut tensors, &format!("b{i}.attn_norm"))?,
            wq: take(&mut tensors, &format!("b{i}.wq"))?,
            wk: take(&mut tensors, &format!("b{i}.wk"))?,
            wv: take(&mut tensors, &format!("b{i}.wv"))?,
            wo: take(&mut tensors, &format!("b{i}.wo"))?,
            mlp_norm: take_vec(&mut tensors, &format!("b{i}.mlp_norm"))?,
            wgate: take(&mut tensors, &format!("b{i}.wgate"))?,
            wup: take(&mut tensors, &format!("b{i}.wup"))?,
            wdown: take(&mut tensors, &format!("b{i}.wdown"))?,
        });
    }
    let final_norm = take_vec(&mut tensors, "final_norm")?;
    let model = Model { cfg, embedding, blocks, final_norm };
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig {
            name: "rt".into(),
            vocab_size: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
        };
        let mut rng = Rng::new(1);
        let m = Model::random(cfg, &mut rng);
        let dir = std::env::temp_dir().join("ojbkq_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        save_model(&m, &path).unwrap();
        let m2 = load_model(&path, "rt").unwrap();
        assert_eq!(m.embedding, m2.embedding);
        assert_eq!(m.blocks[1].wdown, m2.blocks[1].wdown);
        assert_eq!(m.final_norm, m2.final_norm);
        // Same forward outputs.
        let toks: Vec<u16> = vec![3, 7, 1, 0];
        assert!(m.forward(&toks).rel_err(&m2.forward(&toks)) < 1e-12);
    }

    #[test]
    fn load_missing_file_errors_with_hint() {
        let err = load_model(Path::new("/nonexistent/m.bin"), "x").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn hostile_tensor_shape_cannot_allocate_past_file() {
        // A shape line declaring terabytes in a tiny file must Err before
        // allocating (same hardening as the OJBQ1 loader).
        let dir = std::env::temp_dir().join("ojbkq_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hostile.bin");
        std::fs::write(&path, b"OJBW1\n16 8 1 2 12 8\nembedding\n4000000000 1024\n").unwrap();
        assert!(load_model(&path, "x").is_err());
    }
}
