//! Transformer model substrate: a GPT-style decoder-only LM implemented
//! forward-only in Rust, numerically mirroring the JAX training
//! definition in `python/compile/pretrain.py` (which trains the tiny-LM
//! zoo at build time and exports weights to `artifacts/`).
//!
//! Architecture (per [`crate::config::ModelConfig`]):
//! token embedding + sinusoidal positions → N × [RMSNorm → causal MHA →
//! residual → RMSNorm → SwiGLU MLP → residual] → RMSNorm → tied LM head.
//!
//! The seven quantizable linears per block (`Q K V O Gate Up Down`) are
//! addressed by [`LinearId`], and [`Model::forward_with_taps`] captures
//! the *inputs* of any requested linears — the `X` / `X̃` matrices of the
//! paper's layer-wise objectives — in one pass.
//!
//! The forward pass is factored into a **block-resident API** —
//! [`Model::embed_sequence`] produces a hidden-state matrix, and
//! [`Model::block_step`] advances it one transformer block (recording
//! taps), with [`Model::lm_head`] projecting to logits. The streaming
//! pipeline coordinator keeps one resident hidden state per calibration
//! sequence and advances each exactly once per block, instead of
//! re-forwarding the whole prefix; `block_step` itself is composed of the
//! six per-stage pieces (`attn_in` → `attn_ctx` → `post_attn` → `mlp_in`
//! → `mlp_act` → `post_mlp`) so a single stage can be recomputed after a
//! weight splice without touching anything upstream.

pub(crate) mod io;

pub use io::{load_model, save_model};

use crate::config::ModelConfig;
use crate::linalg::matmul_par;
use crate::parallel::parallel_map_dynamic;
use crate::rng::Rng;
use crate::tensor::{Matrix, RowBatch};
use std::collections::HashMap;

/// Which linear inside a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LinearKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

impl LinearKind {
    /// Quantization order within a block (paper: all linear modules).
    pub fn all() -> &'static [LinearKind] {
        &[
            LinearKind::Q,
            LinearKind::K,
            LinearKind::V,
            LinearKind::O,
            LinearKind::Gate,
            LinearKind::Up,
            LinearKind::Down,
        ]
    }

    /// Position of this kind in [`LinearKind::all`] order — the canonical
    /// per-block slot index shared by the coordinator's layer UIDs and the
    /// packed execution engine's layer table.
    pub fn index(&self) -> usize {
        Self::all().iter().position(|k| k == self).unwrap()
    }

    /// The tap point whose output feeds this linear.
    pub fn tap(&self) -> TapPoint {
        match self {
            LinearKind::Q | LinearKind::K | LinearKind::V => TapPoint::AttnIn,
            LinearKind::O => TapPoint::OIn,
            LinearKind::Gate | LinearKind::Up => TapPoint::MlpIn,
            LinearKind::Down => TapPoint::DownIn,
        }
    }

    /// Serialization name.
    pub fn name(&self) -> &'static str {
        match self {
            LinearKind::Q => "wq",
            LinearKind::K => "wk",
            LinearKind::V => "wv",
            LinearKind::O => "wo",
            LinearKind::Gate => "wgate",
            LinearKind::Up => "wup",
            LinearKind::Down => "wdown",
        }
    }
}

/// Fully-qualified linear layer address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinearId {
    pub block: usize,
    pub kind: LinearKind,
}

impl std::fmt::Display for LinearId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "b{}.{}", self.block, self.kind.name())
    }
}

/// Activation capture points inside a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TapPoint {
    /// Post-attn-RMSNorm (input of Q/K/V).
    AttnIn,
    /// Concatenated attention heads (input of O).
    OIn,
    /// Post-mlp-RMSNorm (input of Gate/Up).
    MlpIn,
    /// `silu(gate) ⊙ up` (input of Down).
    DownIn,
}

impl TapPoint {
    /// All four tap points in dataflow order.
    pub fn all() -> [TapPoint; 4] {
        [TapPoint::AttnIn, TapPoint::OIn, TapPoint::MlpIn, TapPoint::DownIn]
    }
}

/// A capture request + storage: rows accumulate across forward calls.
#[derive(Debug, Default)]
pub struct TapSet {
    want: Vec<(usize, TapPoint)>,
    data: HashMap<(usize, TapPoint), Vec<Matrix>>,
}

impl TapSet {
    pub fn request(block: usize, points: &[TapPoint]) -> TapSet {
        TapSet { want: points.iter().map(|&p| (block, p)).collect(), data: HashMap::new() }
    }

    fn record(&mut self, block: usize, point: TapPoint, x: &Matrix) {
        if self.want.contains(&(block, point)) {
            self.data.entry((block, point)).or_default().push(x.clone());
        }
    }

    /// Concatenated captured rows for a tap (in capture order).
    pub fn take(&mut self, block: usize, point: TapPoint) -> Option<Matrix> {
        let mats = self.data.remove(&(block, point))?;
        if mats.is_empty() {
            return None;
        }
        Some(Matrix::vstack_all(&mats))
    }
}

/// One transformer block's parameters.
#[derive(Debug, Clone)]
pub struct Block {
    pub attn_norm: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub mlp_norm: Vec<f32>,
    pub wgate: Matrix,
    pub wup: Matrix,
    pub wdown: Matrix,
}

/// The model: embeddings + blocks + final norm (LM head tied).
#[derive(Debug, Clone)]
pub struct Model {
    pub cfg: ModelConfig,
    /// `vocab × d` token embedding (also the tied output head).
    pub embedding: Matrix,
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
}

impl Model {
    /// Random init (unit tests / solver benches; trained weights come from
    /// `artifacts/` via [`load_model`]).
    pub fn random(cfg: ModelConfig, rng: &mut Rng) -> Model {
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        let std_d = 1.0 / (d as f32).sqrt();
        let std_ff = 1.0 / (ff as f32).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                attn_norm: vec![1.0; d],
                wq: Matrix::randn(d, d, std_d, rng),
                wk: Matrix::randn(d, d, std_d, rng),
                wv: Matrix::randn(d, d, std_d, rng),
                wo: Matrix::randn(d, d, std_d, rng),
                mlp_norm: vec![1.0; d],
                wgate: Matrix::randn(d, ff, std_d, rng),
                wup: Matrix::randn(d, ff, std_d, rng),
                wdown: Matrix::randn(ff, d, std_ff, rng),
            })
            .collect();
        Model {
            embedding: Matrix::randn(cfg.vocab_size, cfg.d_model, 0.02, rng),
            blocks,
            final_norm: vec![1.0; cfg.d_model],
            cfg,
        }
    }

    /// Borrow a linear's weight.
    pub fn linear(&self, id: LinearId) -> &Matrix {
        let b = &self.blocks[id.block];
        match id.kind {
            LinearKind::Q => &b.wq,
            LinearKind::K => &b.wk,
            LinearKind::V => &b.wv,
            LinearKind::O => &b.wo,
            LinearKind::Gate => &b.wgate,
            LinearKind::Up => &b.wup,
            LinearKind::Down => &b.wdown,
        }
    }

    /// Replace a linear's weight (with e.g. a dequantized matrix).
    pub fn set_linear(&mut self, id: LinearId, w: Matrix) {
        let b = &mut self.blocks[id.block];
        let slot = match id.kind {
            LinearKind::Q => &mut b.wq,
            LinearKind::K => &mut b.wk,
            LinearKind::V => &mut b.wv,
            LinearKind::O => &mut b.wo,
            LinearKind::Gate => &mut b.wgate,
            LinearKind::Up => &mut b.wup,
            LinearKind::Down => &mut b.wdown,
        };
        assert_eq!(slot.shape(), w.shape(), "linear {id} shape");
        *slot = w;
    }

    /// All linear ids in quantization order.
    pub fn linear_ids(&self) -> Vec<LinearId> {
        let mut out = Vec::new();
        for block in 0..self.blocks.len() {
            for &kind in LinearKind::all() {
                out.push(LinearId { block, kind });
            }
        }
        out
    }

    /// Logits for one token sequence (`seq × vocab`).
    pub fn forward(&self, tokens: &[u16]) -> Matrix {
        self.forward_with_taps(tokens, &mut TapSet::default())
    }

    /// Legacy tap-only forward that stops after `until_block` (inclusive).
    /// Retained for the coordinator's `CaptureMode::Reforward` equivalence
    /// path and ad-hoc inspection; the streaming pipeline uses
    /// [`Model::embed_sequence`] + [`Model::block_step`] instead, which
    /// advance a resident hidden state once per block.
    pub fn forward_prefix_taps(&self, tokens: &[u16], taps: &mut TapSet, until_block: usize) {
        self.forward_impl(tokens, taps, Some(until_block));
    }

    /// Forward pass recording requested activation taps.
    pub fn forward_with_taps(&self, tokens: &[u16], taps: &mut TapSet) -> Matrix {
        self.forward_impl(tokens, taps, None)
            .expect("full forward always yields logits")
    }

    fn forward_impl(
        &self,
        tokens: &[u16],
        taps: &mut TapSet,
        until_block: Option<usize>,
    ) -> Option<Matrix> {
        let mut x = self.embed_sequence(tokens);
        for bi in 0..self.blocks.len() {
            self.block_step(&mut x, bi, taps);
            if until_block == Some(bi) {
                return None;
            }
        }
        Some(self.lm_head(&x))
    }

    /// Token embedding + sinusoidal positions (matches pretrain.py): the
    /// initial `seq × d` hidden-state matrix of the block-resident
    /// forward API. Embed once, then advance with [`Model::block_step`].
    pub fn embed_sequence(&self, tokens: &[u16]) -> Matrix {
        embed_tokens(&self.embedding, &self.cfg, tokens)
    }

    /// Advance a resident hidden state through block `block_idx` in place,
    /// recording any requested taps. Composed of the per-stage pieces
    /// below so the streaming coordinator can recompute an individual
    /// stage (e.g. the attention context after a Q/K/V splice) without
    /// re-running anything upstream — `forward` and the pipeline captures
    /// therefore share the exact same arithmetic, bit for bit.
    pub fn block_step(&self, hidden: &mut Matrix, block_idx: usize, taps: &mut TapSet) {
        let h = self.attn_in(hidden, block_idx);
        taps.record(block_idx, TapPoint::AttnIn, &h);
        let ctx = self.attn_ctx(&h, block_idx);
        taps.record(block_idx, TapPoint::OIn, &ctx);
        let x_mid = self.post_attn(hidden, &ctx, block_idx);
        let h2 = self.mlp_in(&x_mid, block_idx);
        taps.record(block_idx, TapPoint::MlpIn, &h2);
        let act = self.mlp_act(&h2, block_idx);
        taps.record(block_idx, TapPoint::DownIn, &act);
        *hidden = self.post_mlp(&x_mid, &act, block_idx);
    }

    /// Stage 1: post-attn-RMSNorm of the resident hidden state — the
    /// `AttnIn` tap (input of Q/K/V).
    pub fn attn_in(&self, hidden: &Matrix, block_idx: usize) -> Matrix {
        rmsnorm(hidden, &self.blocks[block_idx].attn_norm)
    }

    /// Stage 2: Q/K/V projections + causal attention over `attn_in` — the
    /// `OIn` tap (concatenated head outputs, input of O). Single-sequence
    /// specialization of [`Model::attn_ctx_batch`].
    pub fn attn_ctx(&self, attn_in: &Matrix, block_idx: usize) -> Matrix {
        self.attn_ctx_batch(attn_in, &[0, attn_in.rows()], block_idx)
    }

    /// Stage 3: output projection + attention residual:
    /// `x_mid = hidden + ctx · Wo`.
    pub fn post_attn(&self, hidden: &Matrix, ctx: &Matrix, block_idx: usize) -> Matrix {
        hidden.add(&matmul_par(ctx, &self.blocks[block_idx].wo))
    }

    /// Stage 4: post-mlp-RMSNorm of `x_mid` — the `MlpIn` tap (input of
    /// Gate/Up).
    pub fn mlp_in(&self, x_mid: &Matrix, block_idx: usize) -> Matrix {
        rmsnorm(x_mid, &self.blocks[block_idx].mlp_norm)
    }

    /// Stage 5: SwiGLU activation `silu(mlp_in·Wgate) ⊙ (mlp_in·Wup)` —
    /// the `DownIn` tap (input of Down).
    pub fn mlp_act(&self, mlp_in: &Matrix, block_idx: usize) -> Matrix {
        let block = &self.blocks[block_idx];
        let g = matmul_par(mlp_in, &block.wgate);
        let u = matmul_par(mlp_in, &block.wup);
        Matrix::from_fn(mlp_in.rows(), self.cfg.d_ff, |i, j| silu(g.get(i, j)) * u.get(i, j))
    }

    /// Stage 6: down projection + MLP residual — the next block's resident
    /// hidden state: `x' = x_mid + act · Wdown`.
    pub fn post_mlp(&self, x_mid: &Matrix, act: &Matrix, block_idx: usize) -> Matrix {
        x_mid.add(&matmul_par(act, &self.blocks[block_idx].wdown))
    }

    /// Final RMSNorm + tied LM head: `logits = norm(hidden) · Eᵀ`.
    pub fn lm_head(&self, hidden: &Matrix) -> Matrix {
        let xf = rmsnorm(hidden, &self.final_norm);
        matmul_par(&xf, &self.embedding.transpose())
    }

    // ----- Batched stage API -------------------------------------------
    //
    // Every stage except the causal-attention core is row-wise or a GEMM,
    // so a vstack of per-sequence hidden caches can flow through the
    // block as ONE tall call per linear stage (`X·Wq|k|v`, `ctx·Wo`,
    // `X·Wgate|up`, `act·Wdown`) — the same weight matrix is streamed
    // from memory once per *stage*, not once per *sequence*. The
    // attention softmax core alone is causal per sequence; it consumes
    // the batched Q/K/V projections through the [`RowBatch`] offsets.
    // All batched stages are bit-identical to per-sequence stepping
    // (see `matmul_par`), which the batched-capture parity tests pin.

    /// Batched stage 1: RMSNorm of a stacked hidden batch (row-wise, so
    /// the stacked call *is* the per-sequence call).
    pub fn attn_in_batch(&self, hidden: &Matrix, block_idx: usize) -> Matrix {
        self.attn_in(hidden, block_idx)
    }

    /// Batched stage 2: ONE tall Q/K/V GEMM triple over the stacked
    /// `attn_in`, then the per-sequence causal softmax cores over the
    /// `offsets` row ranges.
    pub fn attn_ctx_batch(&self, attn_in: &Matrix, offsets: &[usize], block_idx: usize) -> Matrix {
        let block = &self.blocks[block_idx];
        let q = matmul_par(attn_in, &block.wq);
        let k = matmul_par(attn_in, &block.wk);
        let v = matmul_par(attn_in, &block.wv);
        causal_attention_batch(&q, &k, &v, offsets, self.cfg.n_heads)
    }

    /// Batched stage 3: output projection + residual over the stack.
    pub fn post_attn_batch(&self, hidden: &Matrix, ctx: &Matrix, block_idx: usize) -> Matrix {
        self.post_attn(hidden, ctx, block_idx)
    }

    /// Batched stage 4: MLP RMSNorm over the stack.
    pub fn mlp_in_batch(&self, x_mid: &Matrix, block_idx: usize) -> Matrix {
        self.mlp_in(x_mid, block_idx)
    }

    /// Batched stage 5: SwiGLU with one tall Gate GEMM + one tall Up GEMM.
    pub fn mlp_act_batch(&self, mlp_in: &Matrix, block_idx: usize) -> Matrix {
        self.mlp_act(mlp_in, block_idx)
    }

    /// Batched stage 6: down projection + residual over the stack.
    pub fn post_mlp_batch(&self, x_mid: &Matrix, act: &Matrix, block_idx: usize) -> Matrix {
        self.post_mlp(x_mid, act, block_idx)
    }

    /// Advance a whole stacked cache one block — the batch-fused twin of
    /// [`Model::block_step`]: one tall GEMM per linear stage, attention
    /// cores per sequence, taps recorded once as the stacked matrices
    /// (identical to vstacking per-sequence taps in sequence order).
    pub fn block_step_batch(&self, batch: &mut RowBatch, block_idx: usize, taps: &mut TapSet) {
        let h = self.attn_in_batch(batch.data(), block_idx);
        taps.record(block_idx, TapPoint::AttnIn, &h);
        let ctx = self.attn_ctx_batch(&h, batch.offsets(), block_idx);
        taps.record(block_idx, TapPoint::OIn, &ctx);
        let x_mid = self.post_attn_batch(batch.data(), &ctx, block_idx);
        let h2 = self.mlp_in_batch(&x_mid, block_idx);
        taps.record(block_idx, TapPoint::MlpIn, &h2);
        let act = self.mlp_act_batch(&h2, block_idx);
        taps.record(block_idx, TapPoint::DownIn, &act);
        batch.set_data(self.post_mlp_batch(&x_mid, &act, block_idx));
    }
}

/// Shared embedding stage: token rows + sinusoidal positions scaled to
/// the embedding init std so position does not swamp token identity
/// (twin of pretrain.pos_encoding). Used by both the dense [`Model`] and
/// the packed [`crate::infer::QuantizedModel`], which must agree bit for
/// bit on everything except the linear kernels.
pub fn embed_tokens(embedding: &Matrix, cfg: &ModelConfig, tokens: &[u16]) -> Matrix {
    let seq = tokens.len();
    assert!(seq <= cfg.max_seq, "sequence too long");
    let d = cfg.d_model;
    let mut x = Matrix::zeros(seq, d);
    for (t, &tok) in tokens.iter().enumerate() {
        embed_token_into(embedding, cfg, tok, t, x.row_mut(t));
    }
    x
}

/// Embed one token at absolute position `pos` into `row` — the per-row
/// body of [`embed_tokens`], exposed for the KV-cached decode path
/// ([`crate::serve`]), which embeds exactly one new token per step. The
/// sinusoidal position term depends on `pos`, so decode must pass the
/// token's absolute position, not 0.
pub fn embed_token_into(
    embedding: &Matrix,
    cfg: &ModelConfig,
    tok: u16,
    pos: usize,
    row: &mut [f32],
) {
    let d = cfg.d_model;
    assert!(pos < cfg.max_seq, "position beyond max_seq");
    row.copy_from_slice(embedding.row(tok as usize));
    for i in 0..d / 2 {
        let freq = (-(2.0 * i as f64 / d as f64) * 10_000f64.ln()).exp();
        let angle = pos as f64 * freq;
        row[2 * i] += 0.02 * angle.sin() as f32;
        row[2 * i + 1] += 0.02 * angle.cos() as f32;
    }
}

/// A causal language model the evaluation harnesses can score: the dense
/// FP [`Model`] and the packed-execution [`crate::infer::QuantizedModel`]
/// both implement it, so perplexity / zero-shot / reasoning evals run
/// identically on either (Table 1–3 of the paper compare exactly these
/// two execution forms).
pub trait LanguageModel {
    /// Architecture metadata (`max_seq` bounds scoring windows).
    fn config(&self) -> &ModelConfig;

    /// Logits for one token sequence (`seq × vocab`).
    fn forward(&self, tokens: &[u16]) -> Matrix;

    /// Logits for a batch of token sequences — semantically
    /// `seqs.iter().map(forward)`, which this default performs. The dense
    /// and packed models override it with the **batch-fused path**: all
    /// sequences advance as one stacked cache, so every non-attention
    /// linear stage (and the LM head) runs as a single tall GEMM,
    /// bit-identically to the per-sequence loop.
    fn forward_batch(&self, seqs: &[&[u16]]) -> Vec<Matrix> {
        seqs.iter().map(|s| self.forward(s)).collect()
    }

    /// Sum of token negative log-likelihoods for positions `1..seq`
    /// (predicting token t from prefix `..t`), plus the token count.
    fn sequence_nll(&self, tokens: &[u16]) -> (f64, usize) {
        if tokens.len() < 2 {
            return (0.0, 0);
        }
        nll_from_logits(&self.forward(tokens), tokens)
    }

    /// Greedy continuation of `prompt` by `n` tokens.
    fn greedy_continue(&self, prompt: &[u16], n: usize) -> Vec<u16> {
        let max_seq = self.config().max_seq;
        let mut ctx: Vec<u16> = prompt.to_vec();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let window =
                if ctx.len() > max_seq { &ctx[ctx.len() - max_seq..] } else { &ctx[..] };
            let logits = self.forward(window);
            let last = logits.row(logits.rows() - 1);
            let next = crate::util::argmax(last) as u16;
            out.push(next);
            ctx.push(next);
        }
        out
    }
}

impl LanguageModel for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn forward(&self, tokens: &[u16]) -> Matrix {
        Model::forward(self, tokens)
    }

    fn forward_batch(&self, seqs: &[&[u16]]) -> Vec<Matrix> {
        let mut taps = TapSet::default();
        forward_batch_stacked(
            seqs,
            |s| self.embed_sequence(s),
            |batch, bi| self.block_step_batch(batch, bi, &mut taps),
            self.blocks.len(),
            |h| self.lm_head(h),
        )
    }
}

/// Shared driver behind the batch-fused [`LanguageModel::forward_batch`]
/// overrides of the dense [`Model`] and the packed
/// [`crate::infer::QuantizedModel`]: embed every sequence, vstack into a
/// [`RowBatch`], advance the whole stack block by block (`step`), then
/// project the LM head as one tall GEMM and split per sequence. Keeping
/// the two engines on one driver keeps their batching contracts from
/// drifting apart.
pub fn forward_batch_stacked(
    seqs: &[&[u16]],
    embed: impl Fn(&[u16]) -> Matrix,
    mut step: impl FnMut(&mut RowBatch, usize),
    n_blocks: usize,
    lm_head: impl Fn(&Matrix) -> Matrix,
) -> Vec<Matrix> {
    if seqs.is_empty() {
        return Vec::new();
    }
    let parts: Vec<Matrix> = seqs.iter().map(|s| embed(s)).collect();
    let mut batch = RowBatch::stack(&parts);
    for bi in 0..n_blocks {
        step(&mut batch, bi);
    }
    let counts: Vec<usize> = (0..batch.n_seqs()).map(|i| batch.seq_rows(i)).collect();
    lm_head(batch.data()).split_rows(&counts)
}

/// Sum of token NLLs for positions `1..seq` given the sequence's logits —
/// shared by [`LanguageModel::sequence_nll`] and the batched perplexity
/// harness (which obtains logits via [`LanguageModel::forward_batch`]).
pub fn nll_from_logits(logits: &Matrix, tokens: &[u16]) -> (f64, usize) {
    if tokens.len() < 2 {
        return (0.0, 0);
    }
    let mut nll = 0.0f64;
    for t in 0..tokens.len() - 1 {
        let ls = crate::util::log_softmax(logits.row(t));
        nll -= ls[tokens[t + 1] as usize] as f64;
    }
    (nll, tokens.len() - 1)
}

/// RMSNorm with learned gain (eps = 1e-5, matching pretrain.py).
pub fn rmsnorm(x: &Matrix, gain: &[f32]) -> Matrix {
    let (rows, cols) = x.shape();
    assert_eq!(gain.len(), cols);
    let mut out = Matrix::zeros(rows, cols);
    for i in 0..rows {
        rmsnorm_row(x.row(i), gain, out.row_mut(i));
    }
    out
}

/// One row of [`rmsnorm`] — shared with the single-token decode path so
/// the per-row arithmetic (f64 mean-square, eps = 1e-5) is identical by
/// construction.
pub fn rmsnorm_row(row: &[f32], gain: &[f32], dst: &mut [f32]) {
    let cols = row.len();
    assert_eq!(gain.len(), cols);
    assert_eq!(dst.len(), cols);
    let ms: f64 = row.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / cols as f64;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for j in 0..cols {
        dst[j] = (row[j] as f64 * inv) as f32 * gain[j];
    }
}

/// SiLU activation.
#[inline]
pub fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Multi-head causal self-attention on a single sequence.
/// `q,k,v: seq×d`; returns the concatenated head outputs (`seq×d`).
pub fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    attention_core(q, k, v, 0, q.rows(), n_heads)
}

/// Causal attention over a **stack of sequences**: `q,k,v` are tall
/// batched projections (one GEMM over the vstacked caches) and
/// `offsets` are the cumulative row offsets of the per-sequence groups
/// ([`RowBatch::offsets`]). The softmax core runs per sequence (the
/// causal mask never crosses a sequence boundary), dynamically scheduled
/// across threads because calibration sequences can be ragged. Results
/// are stacked in sequence order — bit-identical to running
/// [`causal_attention`] per sequence.
pub fn causal_attention_batch(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    offsets: &[usize],
    n_heads: usize,
) -> Matrix {
    assert_eq!(q.shape(), k.shape());
    assert_eq!(q.shape(), v.shape());
    assert!(
        offsets.len() >= 2 && offsets[0] == 0 && *offsets.last().unwrap() == q.rows(),
        "offsets must cover the stacked rows"
    );
    let n_seqs = offsets.len() - 1;
    if n_seqs == 1 {
        return attention_core(q, k, v, 0, q.rows(), n_heads);
    }
    let parts = parallel_map_dynamic(n_seqs, |s| {
        attention_core(q, k, v, offsets[s], offsets[s + 1], n_heads)
    });
    Matrix::vstack_all(&parts)
}

/// Incremental single-query causal attention for KV-cached decode: the
/// query row for the newest position attends over the `len` cached
/// key/value rows (the new position's own K/V row must already be
/// appended, i.e. `len = t + 1`). Writes the concatenated head outputs
/// into `out` (accumulating into zeros). Per head this is exactly the
/// `t = len-1` iteration of [`attention_core`] — f64 dot products scaled
/// by `1/sqrt(hd)`, scores rounded to f32, [`crate::util::log_softmax`],
/// then f32 accumulation in position order — so a decode step is
/// bit-identical to the corresponding teacher-forced row.
pub fn attention_step(
    q_row: &[f32],
    k: &Matrix,
    v: &Matrix,
    len: usize,
    n_heads: usize,
    out: &mut [f32],
) {
    let d = q_row.len();
    assert_eq!(d % n_heads, 0);
    assert_eq!(out.len(), d);
    assert!(len >= 1 && len <= k.rows() && len <= v.rows());
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f64).sqrt();
    out.fill(0.0);
    let mut scores = Vec::with_capacity(len);
    for h in 0..n_heads {
        let c0 = h * hd;
        let qt = &q_row[c0..c0 + hd];
        scores.clear();
        for u in 0..len {
            let ku = &k.row(u)[c0..c0 + hd];
            let dot: f64 = qt.iter().zip(ku).map(|(&a, &b)| a as f64 * b as f64).sum();
            scores.push((dot * scale) as f32);
        }
        let ls = crate::util::log_softmax(&scores);
        for (u, &l) in ls.iter().enumerate() {
            let w = (l as f64).exp() as f32;
            let vu = &v.row(u)[c0..c0 + hd];
            for (x, &vv) in out[c0..c0 + hd].iter_mut().zip(vu) {
                *x += w * vv;
            }
        }
    }
}

/// The softmax core on rows `[r0, r1)` of (possibly stacked) `q,k,v`,
/// without copying the slice out.
fn attention_core(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    r0: usize,
    r1: usize,
    n_heads: usize,
) -> Matrix {
    let d = q.cols();
    let seq = r1 - r0;
    assert_eq!(d % n_heads, 0);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = Matrix::zeros(seq, d);
    for h in 0..n_heads {
        let c0 = h * hd;
        for t in 0..seq {
            // scores over positions 0..=t
            let qt = &q.row(r0 + t)[c0..c0 + hd];
            let mut scores = Vec::with_capacity(t + 1);
            for u in 0..=t {
                let ku = &k.row(r0 + u)[c0..c0 + hd];
                let dot: f64 =
                    qt.iter().zip(ku).map(|(&a, &b)| a as f64 * b as f64).sum();
                scores.push((dot * scale) as f32);
            }
            let ls = crate::util::log_softmax(&scores);
            let dst_full = out.row_mut(t);
            for (u, &l) in ls.iter().enumerate() {
                let w = (l as f64).exp() as f32;
                let vu = &v.row(r0 + u)[c0..c0 + hd];
                for (x, &vv) in dst_full[c0..c0 + hd].iter_mut().zip(vu) {
                    *x += w * vv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 16,
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let mut rng = Rng::new(1);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = (0..10).map(|i| (i * 3 % 32) as u16).collect();
        let logits = m.forward(&toks);
        assert_eq!(logits.shape(), (10, 32));
        assert!(logits.all_finite());
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position t must not depend on tokens after t.
        let mut rng = Rng::new(2);
        let m = Model::random(tiny_cfg(), &mut rng);
        let a: Vec<u16> = vec![1, 2, 3, 4, 5, 6];
        let mut b = a.clone();
        b[5] = 31; // change the last token only
        let la = m.forward(&a);
        let lb = m.forward(&b);
        for t in 0..5 {
            for j in 0..32 {
                assert!(
                    (la.get(t, j) - lb.get(t, j)).abs() < 1e-5,
                    "t={t} j={j}"
                );
            }
        }
    }

    #[test]
    fn taps_capture_linear_inputs() {
        let mut rng = Rng::new(3);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = vec![5, 9, 13, 2];
        let mut taps = TapSet::request(1, &[TapPoint::AttnIn, TapPoint::DownIn]);
        let _ = m.forward_with_taps(&toks, &mut taps);
        let attn_in = taps.take(1, TapPoint::AttnIn).unwrap();
        assert_eq!(attn_in.shape(), (4, 16));
        let down_in = taps.take(1, TapPoint::DownIn).unwrap();
        assert_eq!(down_in.shape(), (4, 24));
        // Untapped point absent.
        assert!(taps.take(0, TapPoint::AttnIn).is_none());
    }

    #[test]
    fn taps_accumulate_across_calls() {
        let mut rng = Rng::new(4);
        let m = Model::random(tiny_cfg(), &mut rng);
        let mut taps = TapSet::request(0, &[TapPoint::MlpIn]);
        let _ = m.forward_with_taps(&[1, 2, 3], &mut taps);
        let _ = m.forward_with_taps(&[4, 5], &mut taps);
        assert_eq!(taps.take(0, TapPoint::MlpIn).unwrap().rows(), 5);
    }

    #[test]
    fn block_step_chain_matches_forward() {
        // Embedding + per-block stepping + head must reproduce `forward`
        // exactly (they share the same code path by construction).
        let mut rng = Rng::new(21);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9];
        let mut taps = TapSet::default();
        let mut x = m.embed_sequence(&toks);
        for bi in 0..m.blocks.len() {
            m.block_step(&mut x, bi, &mut taps);
        }
        let logits = m.lm_head(&x);
        assert!(logits.rel_err(&m.forward(&toks)) < 1e-12);
    }

    #[test]
    fn block_step_taps_match_prefix_forward_taps() {
        let mut rng = Rng::new(22);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = vec![7, 2, 9, 11];
        for block in 0..m.blocks.len() {
            let mut legacy = TapSet::request(block, &TapPoint::all());
            m.forward_prefix_taps(&toks, &mut legacy, block);
            let mut streaming = TapSet::request(block, &TapPoint::all());
            let mut x = m.embed_sequence(&toks);
            for bi in 0..=block {
                let mut sink = TapSet::default();
                let taps =
                    if bi == block { &mut streaming } else { &mut sink };
                m.block_step(&mut x, bi, taps);
            }
            for p in TapPoint::all() {
                let a = legacy.take(block, p).unwrap();
                let b = streaming.take(block, p).unwrap();
                assert!(b.rel_err(&a) < 1e-12, "block {block} {p:?}");
            }
        }
    }

    #[test]
    fn block_stages_compose_into_block_step() {
        let mut rng = Rng::new(23);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = vec![8, 6, 7, 5, 3];
        let x0 = m.embed_sequence(&toks);
        // Manual stage composition.
        let h = m.attn_in(&x0, 0);
        let ctx = m.attn_ctx(&h, 0);
        let x_mid = m.post_attn(&x0, &ctx, 0);
        let h2 = m.mlp_in(&x_mid, 0);
        let act = m.mlp_act(&h2, 0);
        let manual = m.post_mlp(&x_mid, &act, 0);
        // block_step on the same input.
        let mut x = x0.clone();
        m.block_step(&mut x, 0, &mut TapSet::default());
        assert!(x.rel_err(&manual) < 1e-12);
    }

    #[test]
    fn block_step_batch_matches_per_sequence_steps() {
        // Ragged sequence lengths; the stacked advance (one tall GEMM per
        // stage) must equal per-sequence stepping exactly, taps included.
        let mut rng = Rng::new(31);
        let m = Model::random(tiny_cfg(), &mut rng);
        let seqs: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5], vec![9], vec![7, 8, 6, 2]];
        let parts: Vec<Matrix> = seqs.iter().map(|s| m.embed_sequence(s)).collect();
        let mut batch = RowBatch::stack(&parts);
        for bi in 0..m.blocks.len() {
            let mut batch_taps = TapSet::request(bi, &TapPoint::all());
            m.block_step_batch(&mut batch, bi, &mut batch_taps);
            // Per-sequence reference on independent caches.
            let mut seq_taps = TapSet::request(bi, &TapPoint::all());
            let mut stepped = Vec::new();
            for s in &seqs {
                let mut h = m.embed_sequence(s);
                for b in 0..bi {
                    m.block_step(&mut h, b, &mut TapSet::default());
                }
                m.block_step(&mut h, bi, &mut seq_taps);
                stepped.push(h);
            }
            assert_eq!(*batch.data(), Matrix::vstack_all(&stepped), "block {bi} hidden");
            for p in TapPoint::all() {
                let a = batch_taps.take(bi, p).unwrap();
                let b = seq_taps.take(bi, p).unwrap();
                assert_eq!(a, b, "block {bi} {p:?} tap");
            }
        }
    }

    #[test]
    fn forward_batch_matches_forward() {
        let mut rng = Rng::new(32);
        let m = Model::random(tiny_cfg(), &mut rng);
        let seqs: Vec<Vec<u16>> = vec![vec![3, 1, 4, 1, 5], vec![2, 7], vec![11; 8]];
        let refs: Vec<&[u16]> = seqs.iter().map(|s| s.as_slice()).collect();
        let batched = LanguageModel::forward_batch(&m, &refs);
        assert_eq!(batched.len(), 3);
        for (s, got) in seqs.iter().zip(&batched) {
            assert_eq!(*got, m.forward(s), "seq len {}", s.len());
        }
    }

    #[test]
    fn causal_attention_batch_matches_per_sequence() {
        let mut rng = Rng::new(33);
        let counts = [4usize, 1, 7, 3];
        let total: usize = counts.iter().sum();
        let q = Matrix::randn(total, 8, 1.0, &mut rng);
        let k = Matrix::randn(total, 8, 1.0, &mut rng);
        let v = Matrix::randn(total, 8, 1.0, &mut rng);
        let offsets = [0usize, 4, 5, 12, 15];
        let batched = causal_attention_batch(&q, &k, &v, &offsets, 2);
        let mut r0 = 0usize;
        for &c in &counts {
            let qs = q.block(r0, 0, c, 8);
            let ks = k.block(r0, 0, c, 8);
            let vs = v.block(r0, 0, c, 8);
            let single = causal_attention(&qs, &ks, &vs, 2);
            assert_eq!(batched.block(r0, 0, c, 8), single, "seq at row {r0}");
            r0 += c;
        }
    }

    #[test]
    fn set_linear_changes_output() {
        let mut rng = Rng::new(5);
        let mut m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = vec![7, 8, 9];
        let before = m.forward(&toks);
        let id = LinearId { block: 0, kind: LinearKind::Gate };
        let w = m.linear(id).map(|v| v * 1.5);
        m.set_linear(id, w);
        let after = m.forward(&toks);
        assert!(before.rel_err(&after) > 1e-6);
    }

    #[test]
    fn nll_reasonable_for_random_model() {
        let mut rng = Rng::new(6);
        let m = Model::random(tiny_cfg(), &mut rng);
        let toks: Vec<u16> = (0..12).map(|_| rng.below(32) as u16).collect();
        let (nll, count) = m.sequence_nll(&toks);
        assert_eq!(count, 11);
        let per_tok = nll / count as f64;
        // Random model ≈ uniform: per-token NLL near ln(32) ≈ 3.47.
        assert!((per_tok - (32f64).ln()).abs() < 1.0, "per_tok={per_tok}");
    }

    #[test]
    fn greedy_continue_deterministic() {
        let mut rng = Rng::new(7);
        let m = Model::random(tiny_cfg(), &mut rng);
        let a = m.greedy_continue(&[1, 2, 3], 5);
        let b = m.greedy_continue(&[1, 2, 3], 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Matrix::from_vec(1, 4, vec![2.0, -2.0, 2.0, -2.0]);
        let y = rmsnorm(&x, &[1.0; 4]);
        for j in 0..4 {
            assert!((y.get(0, j).abs() - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // With v rows one-hot per position, outputs are attention weights;
        // they must be non-negative and sum to 1 per (t, head).
        let seq = 4;
        let d = 8;
        let mut rng = Rng::new(8);
        let q = Matrix::randn(seq, d, 1.0, &mut rng);
        let k = Matrix::randn(seq, d, 1.0, &mut rng);
        let v = Matrix::full(seq, d, 1.0);
        let out = causal_attention(&q, &k, &v, 2);
        for t in 0..seq {
            for j in 0..d {
                assert!((out.get(t, j) - 1.0).abs() < 1e-4, "t={t} j={j} {}", out.get(t, j));
            }
        }
    }
}
