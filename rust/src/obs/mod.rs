//! Observability substrate: a hierarchical span tracer and a typed
//! metrics registry, both **process-global, thread-safe, and near-zero
//! cost when disabled** — the single timing/counting source behind
//! `trace.json` (see [`crate::report::RunTrace`] and DESIGN.md
//! §Observability).
//!
//! Tracing is off by default. It is enabled by the `OJBKQ_TRACE`
//! environment variable (read once, like `OJBKQ_F32_CORE`) or
//! programmatically via [`set_trace_override`] (the CLI `--trace` flag,
//! tests). When disabled, every entry point reduces to one relaxed
//! atomic load — no allocation, no lock, no `Instant::now` — pinned by
//! `rust/tests/obs_trace.rs` (mirroring the `no_dequant_hot_path.rs`
//! counter-test pattern via [`event_count`]). Instrumentation never
//! touches numerics, so pipeline output is bit-identical with tracing
//! on and off.
//!
//! **Spans** aggregate by *path*: each thread keeps a stack of active
//! span names, and a finished span records `(count, wall secs)` under
//! the `/`-joined path of its ancestors (e.g.
//! `pipeline/attn_in/solve`). Worker threads spawned by
//! [`crate::parallel`] start with an empty stack, so spans opened
//! inside a parallel fan-out aggregate under their own leaf path — by
//! design: cross-thread parent attribution would need message plumbing
//! the hot paths should not pay for.
//!
//! **Metrics** are typed monotonic counters ([`counter_add`]),
//! last-write-wins gauges ([`gauge_set`]) and summary histograms
//! ([`hist_record`]: count/sum/min/max). Names come from the curated
//! [`METRIC_NAMES`] taxonomy — `debug_assert`ed at record time and
//! enforced by the `trace.json` schema checker
//! ([`crate::report::validate_trace`], CI `check-trace` leg) so the
//! namespace cannot drift silently.
//!
//! Kernel counters are **analytic**: the packed-GEMM entry points
//! record work derived from shapes (`b·m·n` MACs, codes unpacked per
//! grid cell, panel fills) rather than incrementing per element, so the
//! microkernel inner loops carry no instrumentation at all.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ----- enablement -----------------------------------------------------

/// Process-wide trace override: 0 = unset (env decides), 1 = on,
/// 2 = off. Mirrors `infer::set_packed_core_override` — a race-free
/// runtime toggle that takes precedence over the `OJBKQ_TRACE`
/// environment default.
static TRACE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force tracing on/off for this process (`None` restores the
/// `OJBKQ_TRACE` environment default). Used by the CLI `--trace` flag
/// and by tests.
pub fn set_trace_override(on: Option<bool>) {
    let v = match on {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    TRACE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Is tracing enabled? One relaxed atomic load on the hot path; the
/// environment is consulted once per process.
#[inline]
pub fn enabled() -> bool {
    match TRACE_OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            static ENV: OnceLock<bool> = OnceLock::new();
            *ENV.get_or_init(|| {
                matches!(std::env::var("OJBKQ_TRACE").as_deref(), Ok("1") | Ok("true") | Ok("yes"))
            })
        }
    }
}

// ----- taxonomy -------------------------------------------------------

/// Every span name the stack may open — path segments in `trace.json`
/// are validated against this list (see DESIGN.md §Observability for
/// what each covers).
pub const SPAN_NAMES: &[&str] = &[
    // coordinator phases
    "pipeline",
    "embed",
    "fp_step",
    "capture",
    "factor",
    "solve",
    "pack",
    "advance",
    // tap-point groups (one per `coordinator::GROUPS` entry)
    "attn_in",
    "o_in",
    "mlp_in",
    "down_in",
    // linalg primitives under factor/solve
    "syrk",
    "gemm_tn",
    "trsm",
    // evaluation
    "eval",
    // token serving (scheduler tick → prefill / decode_step leaves)
    "serve",
    "prefill",
    "decode_step",
];

/// Every registry metric name, with units:
///
/// | name | type | unit |
/// |---|---|---|
/// | `quant.layers` | counter | layers solved |
/// | `quant.cols` | counter | decoded weight columns (OJBKQ family) |
/// | `quant.klein_samples` | counter | Klein paths sampled (K·cols) |
/// | `quant.klein_improved` | counter | columns where a sampled path beat greedy Babai |
/// | `quant.clipped_codes` | counter | codes at a box bound (0 or 2^wbit−1) |
/// | `quant.codes` | counter | total codes emitted |
/// | `layer.rt_err` | hist | per-layer `‖X̃Ŵ − X̃W‖_F` |
/// | `layer.jta_err` | hist | per-layer `‖X̃Ŵ − Y*(μ)‖_F` |
/// | `layer.decode_resid` | hist | per-layer Σ_cols winner `‖R(s⊙(q−q̄))‖²` |
/// | `layer.clip_rate` | hist | per-layer clipped-code fraction |
/// | `layer.occupancy` | hist | per-layer distinct codes / 2^wbit |
/// | `layer.solve_secs` | hist | per-layer solver seconds |
/// | `quant.sweeps` | counter | iterative-solver sweeps/iterations (QuantEase/ADMM-Q) |
/// | `layer.sweeps` | hist | per-layer sweeps/iterations to convergence |
/// | `layer.obj_delta` | hist | per-layer objective decrease over the warm start |
/// | `qgemm.calls` | counter | blocked packed-GEMM entries |
/// | `qgemm.gemv_calls` | counter | single-row register-path entries |
/// | `qgemm.dense_calls` | counter | dense-fallback matmuls |
/// | `qgemm.rows` | counter | activation rows through packed kernels |
/// | `qgemm.macs` | counter | `b·m·n` multiply-accumulates (analytic) |
/// | `qgemm.unpacked_codes` | counter | code words unpacked (analytic, per grid cell) |
/// | `qgemm.panel_fills` | counter | `PANEL_ROWS×COL_TILE` panel unpacks |
/// | `parallel.fanouts` | counter | parallel primitive invocations that spawned |
/// | `parallel.tasks` | counter | tasks spawned across all fan-outs |
/// | `eval.windows` | counter | perplexity windows scored |
/// | `eval.tokens` | counter | tokens scored |
/// | `eval.windows_per_sec` | gauge | eval throughput (last run) |
/// | `capture.block_steps` | counter | transformer-block advances for calibration |
/// | `serve.tokens_generated` | counter | tokens sampled by the serving engine |
/// | `serve.requests_admitted` | counter | requests admitted (prefilled) by the scheduler |
/// | `serve.requests_retired` | counter | requests retired at their token budget |
/// | `serve.kv_bytes` | gauge | resident KV-cache bytes across live sequences |
/// | `serve.tokens_per_sec` | gauge | serving throughput (last run) |
/// | `serve.requests_rejected` | counter | submissions refused at admission (empty/too-long/queue-full) |
/// | `serve.requests_expired` | counter | requests retired by deadline expiry |
/// | `layer.fallback` | hist | per-layer RTN-fallback events (1.0 per degraded layer) |
pub const METRIC_NAMES: &[&str] = &[
    "quant.layers",
    "quant.cols",
    "quant.klein_samples",
    "quant.klein_improved",
    "quant.clipped_codes",
    "quant.codes",
    "layer.rt_err",
    "layer.jta_err",
    "layer.decode_resid",
    "layer.clip_rate",
    "layer.occupancy",
    "layer.solve_secs",
    "quant.sweeps",
    "layer.sweeps",
    "layer.obj_delta",
    "qgemm.calls",
    "qgemm.gemv_calls",
    "qgemm.dense_calls",
    "qgemm.rows",
    "qgemm.macs",
    "qgemm.unpacked_codes",
    "qgemm.panel_fills",
    "parallel.fanouts",
    "parallel.tasks",
    "eval.windows",
    "eval.tokens",
    "eval.windows_per_sec",
    "capture.block_steps",
    "serve.tokens_generated",
    "serve.requests_admitted",
    "serve.requests_retired",
    "serve.kv_bytes",
    "serve.tokens_per_sec",
    "serve.requests_rejected",
    "serve.requests_expired",
    "layer.fallback",
];

/// Keys allowed in the per-layer metric records of `trace.json`
/// (`RunTrace::layers`) — the per-layer residual table.
pub const LAYER_METRIC_NAMES: &[&str] = &[
    "rt_err",
    "jta_err",
    "out_norm",
    "decode_resid",
    "greedy_resid",
    "cols",
    "klein_samples",
    "klein_improved",
    "clip_rate",
    "occupancy",
    "solve_secs",
    "capture_secs",
    "packed_bytes",
    "fp_bytes",
    "fallback",
];

// ----- global state ---------------------------------------------------

#[derive(Debug, Clone, Copy, Default)]
struct SpanStat {
    count: u64,
    secs: f64,
}

/// Histogram summary: enough for mean/min/max reporting without storing
/// samples (per-layer distributions are small; full samples live in the
/// per-layer table instead).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl HistSummary {
    fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum MetricVal {
    Counter(u64),
    Gauge(f64),
    Hist(HistSummary),
}

fn spans() -> &'static Mutex<BTreeMap<String, SpanStat>> {
    static SPANS: OnceLock<Mutex<BTreeMap<String, SpanStat>>> = OnceLock::new();
    SPANS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn metrics() -> &'static Mutex<BTreeMap<&'static str, MetricVal>> {
    static METRICS: OnceLock<Mutex<BTreeMap<&'static str, MetricVal>>> = OnceLock::new();
    METRICS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Total recorded events (spans closed + metric updates) in this
/// process — the disabled-mode no-op regression hook: with tracing off
/// this must not move across an entire pipeline run
/// (`rust/tests/obs_trace.rs`).
static EVENTS: AtomicU64 = AtomicU64::new(0);

/// Total events recorded so far (see [`EVENTS`]).
pub fn event_count() -> u64 {
    EVENTS.load(Ordering::Relaxed)
}

thread_local! {
    /// Stack of active span names on this thread; a closing span joins
    /// it into the aggregation path.
    static SPAN_STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

/// Clear all recorded spans/metrics and the event counter. Test and
/// CLI-start support (a `--trace` run reports only itself); live span
/// guards are unaffected and simply record into the fresh registry.
pub fn reset() {
    spans().lock().unwrap_or_else(|e| e.into_inner()).clear();
    metrics().lock().unwrap_or_else(|e| e.into_inner()).clear();
    EVENTS.store(0, Ordering::Relaxed);
}

// ----- spans ----------------------------------------------------------

/// RAII guard for one span; created by [`span`] / the `span!` macro.
/// Not `Send`: the guard must close on the thread that opened it (the
/// span stack is thread-local).
pub struct SpanGuard {
    start: Option<Instant>,
    _not_send: std::marker::PhantomData<*const ()>,
}

/// Open a span named `name` (must be in [`SPAN_NAMES`]); the returned
/// guard records `(path, count, secs)` on drop. No-op (no allocation,
/// no clock read) when tracing is disabled.
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { start: None, _not_send: std::marker::PhantomData };
    }
    debug_assert!(SPAN_NAMES.contains(&name), "span name {name:?} not in obs::SPAN_NAMES");
    SPAN_STACK.with(|s| s.borrow_mut().push(name));
    SpanGuard { start: Some(Instant::now()), _not_send: std::marker::PhantomData }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(t0) = self.start else { return };
        let secs = t0.elapsed().as_secs_f64();
        let path = SPAN_STACK.with(|s| {
            let mut st = s.borrow_mut();
            let path = st.join("/");
            st.pop();
            path
        });
        let mut map = spans().lock().unwrap_or_else(|e| e.into_inner());
        let stat = map.entry(path).or_default();
        stat.count += 1;
        stat.secs += secs;
        EVENTS.fetch_add(1, Ordering::Relaxed);
    }
}

/// Open a span around `name`, evaluating to the body's value:
/// `span!("solve", { decode() })`.
#[macro_export]
macro_rules! span {
    ($name:expr, $body:expr) => {{
        let _obs_span_guard = $crate::obs::span($name);
        $body
    }};
}

/// Measure wall-clock seconds of `f` under a span — the **single timing
/// source**: always measures (callers like `PipelineReport` need the
/// seconds whether or not tracing is on) and additionally records the
/// span when enabled. Replaces ad-hoc `Instant::now()` pairs in the
/// coordinator (`capture_secs` et al. are now derived views of these
/// measurements).
pub fn timed<T>(name: &'static str, f: impl FnOnce() -> T) -> (T, f64) {
    let guard = span(name);
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    drop(guard);
    (out, secs)
}

// ----- metrics --------------------------------------------------------

fn with_metric(name: &'static str, default: MetricVal, f: impl FnOnce(&mut MetricVal)) {
    debug_assert!(METRIC_NAMES.contains(&name), "metric {name:?} not in obs::METRIC_NAMES");
    let mut map = metrics().lock().unwrap_or_else(|e| e.into_inner());
    f(map.entry(name).or_insert(default));
    EVENTS.fetch_add(1, Ordering::Relaxed);
}

/// Add `v` to monotonic counter `name`. No-op when tracing is disabled.
pub fn counter_add(name: &'static str, v: u64) {
    if !enabled() {
        return;
    }
    with_metric(name, MetricVal::Counter(0), |m| {
        if let MetricVal::Counter(c) = m {
            *c += v;
        }
    });
}

/// Set gauge `name` to `v` (last write wins). No-op when disabled.
pub fn gauge_set(name: &'static str, v: f64) {
    if !enabled() {
        return;
    }
    with_metric(name, MetricVal::Gauge(v), |m| {
        if let MetricVal::Gauge(g) = m {
            *g = v;
        }
    });
}

/// Record sample `v` into histogram `name`. No-op when disabled.
pub fn hist_record(name: &'static str, v: f64) {
    if !enabled() {
        return;
    }
    with_metric(name, MetricVal::Hist(HistSummary::default()), |m| {
        if let MetricVal::Hist(h) = m {
            h.record(v);
        }
    });
}

// ----- snapshot -------------------------------------------------------

/// One aggregated span path.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRow {
    /// `/`-joined ancestry, e.g. `pipeline/attn_in/solve`.
    pub path: String,
    /// Times this path closed.
    pub count: u64,
    /// Total wall-clock seconds across those closes.
    pub secs: f64,
}

/// A point-in-time copy of the whole registry — the payload of
/// [`crate::report::RunTrace`].
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub spans: Vec<SpanRow>,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSummary)>,
}

impl Snapshot {
    /// Look up a counter by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Look up a span row by exact path.
    pub fn span(&self, path: &str) -> Option<&SpanRow> {
        self.spans.iter().find(|s| s.path == path)
    }
}

/// Copy out everything recorded so far (sorted by name/path — the
/// registries are BTree-backed).
pub fn snapshot() -> Snapshot {
    let spans: Vec<SpanRow> = spans()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|(path, st)| SpanRow { path: path.clone(), count: st.count, secs: st.secs })
        .collect();
    let mut counters = Vec::new();
    let mut gauges = Vec::new();
    let mut hists = Vec::new();
    for (name, val) in metrics().lock().unwrap_or_else(|e| e.into_inner()).iter() {
        match val {
            MetricVal::Counter(c) => counters.push((name.to_string(), *c)),
            MetricVal::Gauge(g) => gauges.push((name.to_string(), *g)),
            MetricVal::Hist(h) => hists.push((name.to_string(), *h)),
        }
    }
    Snapshot { spans, counters, gauges, hists }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_is_duplicate_free_and_wellformed() {
        for list in [SPAN_NAMES, METRIC_NAMES, LAYER_METRIC_NAMES] {
            let mut seen = std::collections::BTreeSet::new();
            for &n in list {
                assert!(seen.insert(n), "duplicate taxonomy name {n}");
                assert!(!n.is_empty() && !n.contains('/') && !n.contains(' '), "bad name {n:?}");
            }
        }
    }

    #[test]
    fn override_controls_enablement() {
        // Stateful registry assertions live in tests/obs_trace.rs (own
        // process); here only the inert on/off switch is exercised.
        set_trace_override(Some(false));
        assert!(!enabled());
        set_trace_override(Some(true));
        assert!(enabled());
        set_trace_override(None);
    }

    #[test]
    fn hist_summary_tracks_bounds() {
        let mut h = HistSummary::default();
        h.record(2.0);
        h.record(-1.0);
        h.record(5.0);
        assert_eq!(h.count, 3);
        assert_eq!(h.min, -1.0);
        assert_eq!(h.max, 5.0);
        assert!((h.mean() - 2.0).abs() < 1e-12);
        assert_eq!(HistSummary::default().mean(), 0.0);
    }
}
