//! Data-parallel execution substrate (no rayon offline).
//!
//! The coordinator parallelizes layer quantization across *weight columns*
//! (the paper's outer level of parallelism) and, inside the native solver,
//! across the K Klein paths (the inner level). Both reduce to the
//! [`parallel_for_chunks`] primitive below, built on `std::thread::scope`.
//!
//! Threads are spawned per call — on the target machine layer solves run
//! for milliseconds-to-seconds, so spawn cost (~10 µs) is noise, and the
//! scoped design means zero `unsafe` and no channel plumbing.

/// Number of worker threads to use: `OJBKQ_THREADS` env override, else
/// available parallelism, else 1.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("OJBKQ_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `[0, n)` into at most `parts` contiguous ranges of near-equal
/// size (difference ≤ 1). Empty ranges are omitted.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let mut out = Vec::with_capacity(parts.min(n));
    let base = n / parts;
    let rem = n % parts;
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `body(range)` over a partition of `[0, n)` on up to
/// [`num_threads`] threads. `body` must be `Sync` (shared immutably).
/// Results are returned in range order.
pub fn parallel_for_chunks<T, F>(n: usize, body: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let ranges = split_ranges(n, num_threads());
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&body).collect();
    }
    let mut out: Vec<Option<T>> = ranges.iter().map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len());
        for r in ranges.iter().cloned() {
            let body = &body;
            handles.push(scope.spawn(move || body(r)));
        }
        for (slot, h) in out.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("parallel worker panicked"));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel map over indices `0..n`, preserving order. Convenience
/// wrapper over [`parallel_for_chunks`].
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let chunks = parallel_for_chunks(n, |r| r.map(&f).collect::<Vec<T>>());
    chunks.into_iter().flatten().collect()
}

/// Parallel map over indices `0..n` with **dynamic scheduling**: workers
/// pull the next index from a shared atomic counter, so uneven item costs
/// (ragged calibration sequences in the batched attention core, ragged
/// row-block × column-tile cells in the packed GEMM grid) don't leave
/// threads idle the way [`parallel_map`]'s static contiguous ranges do.
/// Results are returned in index order; determinism is unaffected because
/// each item is computed independently.
pub fn parallel_map_dynamic<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let nt = num_threads().min(n);
    if nt <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut buckets: Vec<Vec<(usize, T)>> = Vec::with_capacity(nt);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nt);
        for _ in 0..nt {
            let f = &f;
            let next = &next;
            handles.push(scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                local
            }));
        }
        for h in handles {
            buckets.push(h.join().expect("parallel worker panicked"));
        }
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in buckets.into_iter().flatten() {
        out[i] = Some(v);
    }
    out.into_iter().map(|o| o.expect("dynamic worker skipped an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_ranges_covers_exactly() {
        for &(n, p) in &[(0usize, 4usize), (1, 4), (7, 3), (12, 4), (5, 8), (100, 7)] {
            let rs = split_ranges(n, p);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} p={p}");
            // Contiguous and ordered.
            let mut expect = 0;
            for r in &rs {
                assert_eq!(r.start, expect);
                expect = r.end;
            }
            // Balanced.
            if let (Some(min), Some(max)) =
                (rs.iter().map(|r| r.len()).min(), rs.iter().map(|r| r.len()).max())
            {
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(100, |i| i * i);
        let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_for_chunks_runs_everything_once() {
        let counter = AtomicUsize::new(0);
        let _ = parallel_for_chunks(1000, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_n_is_fine() {
        let out: Vec<usize> = parallel_map(0, |i| i);
        assert!(out.is_empty());
        let out: Vec<usize> = parallel_map_dynamic(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn dynamic_map_matches_serial_and_runs_everything_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map_dynamic(257, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i * 3
        });
        let expect: Vec<usize> = (0..257).map(|i| i * 3).collect();
        assert_eq!(out, expect);
        assert_eq!(counter.load(Ordering::Relaxed), 257);
    }
}
