//! Data-parallel execution substrate (no rayon offline).
//!
//! Everything fans out through the [`parallel_for_chunks`] /
//! [`parallel_map`] / [`parallel_map_dynamic`] primitives below, built on
//! `std::thread::scope`. The worker count comes from [`num_threads`]
//! (`OJBKQ_THREADS` override). Current consumers, outer to inner:
//!
//! * **Layer solve — column tiles** (`quant::ojbkq`): the Random-K
//!   Babai/Klein decode runs one `parallel_map` task per column tile.
//!   Tiles are independent by construction (each forks its own RNG
//!   sub-stream keyed by tile index), so codes are bit-identical at any
//!   thread count — pinned by `tests/solver_parallel.rs`.
//! * **Normal-equation substrate** (`linalg`): `syrk_upper` / `gemm_tn`
//!   split output-row ranges and the multi-RHS triangular solves
//!   (`solve_lower_t` / `solve_upper_mat`) split RHS-column blocks, each
//!   leaving per-element arithmetic order untouched (bit-identical).
//! * **Batched capture / eval** (`model`, `infer`, `eval`): tall-GEMM
//!   row blocks (`matmul_par`), the packed kernel's row-block × tile
//!   grid, and the ragged per-sequence attention cores
//!   ([`parallel_map_dynamic`]).
//!
//! Threads are spawned per call — on the target machine layer solves run
//! for milliseconds-to-seconds, so spawn cost (~10 µs) is noise, and the
//! scoped design means zero `unsafe` and no channel plumbing. Nested
//! fan-out is suppressed rather than compounded: [`num_threads`] reports
//! 1 on worker threads, so a tile worker's inner GEMM runs serially
//! instead of spawning `num_threads²` threads — outermost parallelism
//! wins, and since every primitive is bit-identical at any thread count
//! the suppression never changes results.

thread_local! {
    /// True on threads spawned by this module's primitives. [`num_threads`]
    /// reports 1 on such threads, so *nested* fan-out (a tile-decode
    /// worker calling the row-parallel GEMM, say) runs serially instead
    /// of spawning `num_threads²` CPU-bound threads — outermost
    /// parallelism wins, and every primitive is bit-identical at any
    /// thread count so the suppression never changes results.
    static IN_PARALLEL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Process-wide programmatic thread-count override; 0 = unset. Takes
/// precedence over the `OJBKQ_THREADS` environment variable. Exists so
/// tests and benches can flip thread counts mid-process without calling
/// `std::env::set_var`, whose glibc `setenv` races concurrent
/// `env::var` reads (e.g. [`num_threads`] on another test thread).
static THREAD_OVERRIDE: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Pin the worker thread count for this process (`0` clears the pin,
/// restoring the `OJBKQ_THREADS` / available-parallelism default).
/// Every parallel primitive here is bit-identical at any thread count,
/// so flipping this never changes results — only scheduling.
pub fn set_thread_override(n: usize) {
    THREAD_OVERRIDE.store(n, std::sync::atomic::Ordering::Relaxed);
}

/// Number of worker threads to use: [`set_thread_override`] pin, else
/// `OJBKQ_THREADS` env override, else available parallelism, else 1.
/// Always 1 on threads that are themselves parallel workers (see
/// [`IN_PARALLEL_WORKER`]).
pub fn num_threads() -> usize {
    if IN_PARALLEL_WORKER.with(|c| c.get()) {
        return 1;
    }
    let pinned = THREAD_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed);
    if pinned > 0 {
        return pinned;
    }
    if let Ok(s) = std::env::var("OJBKQ_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `[0, n)` into at most `parts` contiguous ranges of near-equal
/// size (difference ≤ 1). Empty ranges are omitted.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let mut out = Vec::with_capacity(parts.min(n));
    let base = n / parts;
    let rem = n % parts;
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `body(range)` over a partition of `[0, n)` on up to
/// [`num_threads`] threads. `body` must be `Sync` (shared immutably).
/// Results are returned in range order.
pub fn parallel_for_chunks<T, F>(n: usize, body: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    parallel_for_ranges(split_ranges(n, num_threads()), body)
}

/// Run `body` over an explicit, caller-chosen set of ranges — one task
/// per range, all spawned at once. Used when equal-size ranges would be
/// unbalanced (e.g. `syrk_upper`'s triangular row costs). Results are
/// returned in range order.
pub fn parallel_for_ranges<T, F>(ranges: Vec<std::ops::Range<usize>>, body: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&body).collect();
    }
    // Fan-out accounting (after the serial early-return, so the counters
    // measure actual thread spawns, not calls).
    crate::obs::counter_add("parallel.fanouts", 1);
    crate::obs::counter_add("parallel.tasks", ranges.len() as u64);
    let mut out: Vec<Option<T>> = ranges.iter().map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len());
        for r in ranges.iter().cloned() {
            let body = &body;
            handles.push(scope.spawn(move || {
                IN_PARALLEL_WORKER.with(|c| c.set(true));
                body(r)
            }));
        }
        for (slot, h) in out.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("parallel worker panicked"));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel map over indices `0..n`, preserving order. Convenience
/// wrapper over [`parallel_for_chunks`].
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let chunks = parallel_for_chunks(n, |r| r.map(&f).collect::<Vec<T>>());
    chunks.into_iter().flatten().collect()
}

/// Parallel map over indices `0..n` with **dynamic scheduling**: workers
/// pull the next index from a shared atomic counter, so uneven item costs
/// (ragged calibration sequences in the batched attention core, ragged
/// row-block × column-tile cells in the packed GEMM grid) don't leave
/// threads idle the way [`parallel_map`]'s static contiguous ranges do.
/// Results are returned in index order; determinism is unaffected because
/// each item is computed independently.
pub fn parallel_map_dynamic<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let nt = num_threads().min(n);
    if nt <= 1 {
        return (0..n).map(f).collect();
    }
    crate::obs::counter_add("parallel.fanouts", 1);
    crate::obs::counter_add("parallel.tasks", nt as u64);
    let next = AtomicUsize::new(0);
    let mut buckets: Vec<Vec<(usize, T)>> = Vec::with_capacity(nt);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nt);
        for _ in 0..nt {
            let f = &f;
            let next = &next;
            handles.push(scope.spawn(move || {
                IN_PARALLEL_WORKER.with(|c| c.set(true));
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                local
            }));
        }
        for h in handles {
            buckets.push(h.join().expect("parallel worker panicked"));
        }
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in buckets.into_iter().flatten() {
        out[i] = Some(v);
    }
    out.into_iter().map(|o| o.expect("dynamic worker skipped an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_ranges_covers_exactly() {
        for &(n, p) in &[(0usize, 4usize), (1, 4), (7, 3), (12, 4), (5, 8), (100, 7)] {
            let rs = split_ranges(n, p);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} p={p}");
            // Contiguous and ordered.
            let mut expect = 0;
            for r in &rs {
                assert_eq!(r.start, expect);
                expect = r.end;
            }
            // Balanced.
            if let (Some(min), Some(max)) =
                (rs.iter().map(|r| r.len()).min(), rs.iter().map(|r| r.len()).max())
            {
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(100, |i| i * i);
        let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_for_chunks_runs_everything_once() {
        let counter = AtomicUsize::new(0);
        let _ = parallel_for_chunks(1000, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn zero_n_is_fine() {
        let out: Vec<usize> = parallel_map(0, |i| i);
        assert!(out.is_empty());
        let out: Vec<usize> = parallel_map_dynamic(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn nested_fanout_is_suppressed() {
        // On a worker thread, num_threads() must report 1 so nested
        // primitives run serially instead of oversubscribing cores.
        let inner = parallel_for_ranges(vec![0..1, 1..2], |_| num_threads());
        assert_eq!(inner, vec![1, 1]);
        // The calling thread is unaffected afterwards.
        assert!(num_threads() >= 1);
    }

    #[test]
    fn dynamic_map_matches_serial_and_runs_everything_once() {
        let counter = AtomicUsize::new(0);
        let out = parallel_map_dynamic(257, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i * 3
        });
        let expect: Vec<usize> = (0..257).map(|i| i * 3).collect();
        assert_eq!(out, expect);
        assert_eq!(counter.load(Ordering::Relaxed), 257);
    }
}
