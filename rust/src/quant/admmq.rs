//! ADMM-Q: Hessian-based ADMM splitting for layer quantization
//! (Lucas et al., PAPERS.md) on the shared-factor engine — the second
//! iterative family mounted on [`FactoredSystem`], minimizing the same
//! shifted JTA quadratic as [`super::quantease`]:
//!
//! `f(Ŵ) = Σ_cols ŵᵀGŵ − 2ŵᵀb`,  `G = X̃ᵀX̃ + λ²I`,  `B = X̃ᵀY* + λ²W`
//!
//! The splitting introduces a continuous copy `W_c` constrained to the
//! quantization grid through the scaled dual `U`:
//!
//! 1. **LS subproblem** — `W_c ← (G + ρI)⁻¹ (B + ρ(Ŵ_q − U))`, solved
//!    by a Cholesky factor of `G_p + ρI` that is refactored ONLY when
//!    the penalty ρ changes (the shared `G_p` itself is built once per
//!    tap group — this is why ADMM-Q needs the Gram resident, not just
//!    `R`; a lean factor is rejected by `check_for`).
//! 2. **Projection** — `q ← clamp(round((W_c + U)/s + z))`,
//!    `Ŵ_q = s⊙(q − z)`: the exact Euclidean projection of `W_c + U`
//!    onto the box-constrained grid.
//! 3. **Dual ascent** — `U ← U + W_c − Ŵ_q`, with residual-balancing
//!    penalty adaptation (ρ doubles when the primal residual dominates
//!    the dual by 10×, halves in the mirror case — Boyd §3.4.1),
//!    bounded to ±6 doublings around ρ₀ = 0.1·mean diag(G).
//!
//! Nonconvex ADMM iterates are NOT monotone in `f`, so the solver
//! tracks an **incumbent**: the best integer assignment seen so far by
//! exact f64 objective, seeded with the per-column best of the
//! Babai/Klein warm start and RTN. The reported `obj_trace` is the
//! incumbent trajectory — non-increasing by construction — and the
//! returned codes are the incumbent, so the final objective can never
//! be worse than either initializer. Everything on the iteration path
//! (triangular solves, projections, f64 scoring) is bit-identical at
//! any `OJBKQ_THREADS`.

use super::factored::{FactorKind, FactoredSystem};
use super::quantease::{col_grid, col_obj_f64, IterStats};
use super::{jta, ojbkq, scales, QuantConfig, QuantizedLinear};
use crate::linalg::cholesky_upper_jittered;
use crate::rng::Rng;
use crate::runtime::SolverRuntime;
use crate::tensor::Matrix;

/// ADMM iteration cap — with the warm start the incumbent typically
/// stops moving after 5–10 iterations.
pub const MAX_ITERS: usize = 16;

/// `chol(G_p + ρI)` — the only per-ρ work in the loop.
fn chol_rho(gram: &Matrix, rho: f32) -> anyhow::Result<Matrix> {
    let m = gram.rows();
    let mut g = gram.clone();
    for i in 0..m {
        g.add_at(i, i, rho);
    }
    let (r, _jit) = cholesky_upper_jittered(&g, 1e-8)
        .map_err(|e| anyhow::anyhow!("admm-q chol(G+ρI): {e}"))?;
    Ok(r)
}

/// Total objective of an integer assignment (row-major m×n codes) on
/// the permuted system, in f64.
fn codes_obj(gram: &Matrix, rhs_p: &Matrix, sc: &scales::GroupScales, codes: &[u8]) -> f64 {
    let m = gram.rows();
    let n = rhs_p.cols();
    let mut total = 0.0f64;
    for j in 0..n {
        let (s, z) = col_grid(sc, j, m);
        let b: Vec<f64> = (0..m).map(|i| rhs_p.get(i, j) as f64).collect();
        let w_hat: Vec<f64> =
            (0..m).map(|i| s[i] * (codes[i * n + j] as f64 - z[i])).collect();
        total += col_obj_f64(gram, &b, &w_hat);
    }
    total
}

/// Quantize one layer with ADMM-Q. Signature and sharing contract match
/// [`ojbkq::quantize_with`]; additionally returns the [`IterStats`]
/// convergence record (incumbent trajectory). The shared factor (if
/// any) must have been built Gram-resident.
#[allow(clippy::too_many_arguments)]
pub fn quantize_with(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<(QuantizedLinear, IterStats)> {
    let (m, n) = w.shape();
    let owned_sys;
    let sys: &FactoredSystem = match shared {
        Some(s) => {
            s.check_for(FactorKind::Ojbkq, m, cfg, true)?;
            s
        }
        None => {
            owned_sys = FactoredSystem::for_ojbkq_with_gram(x_rt, cfg)?;
            &owned_sys
        }
    };
    let gram = sys.gram()?;
    let (warm_q, _) = ojbkq::quantize_with_diag(w, x_fp, x_rt, cfg, rng, rt, Some(sys))?;
    let rhs = jta::build_rhs(w, x_fp, x_rt, sys.lambda_sq, cfg);
    let permuted = sys.permuted;
    let perm = &sys.perm;
    let rhs_p_store;
    let rhs_p: &Matrix = if permuted {
        rhs_p_store = rhs.permute_rows(perm);
        &rhs_p_store
    } else {
        &rhs
    };
    let w_p_store;
    let w_p: &Matrix = if permuted {
        w_p_store = w.permute_rows(perm);
        &w_p_store
    } else {
        w
    };
    let sc = scales::compute(w_p, cfg);
    let w_real = jta::solve_real(&sys.r, rhs_p);
    let obj_real: f64 = -(0..m)
        .map(|i| {
            let wr = w_real.row(i);
            let br = rhs_p.row(i);
            wr.iter().zip(br).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>()
        })
        .sum::<f64>();
    // Grid expanded to full m×n once (group scales are per-group rows).
    let s_full = Matrix::from_fn(m, n, |i, j| sc.scale(i, j));
    let z_full = Matrix::from_fn(m, n, |i, j| sc.zero(i, j));
    let qmax = cfg.box_max() as f32;
    // Init: per-column best of the Babai/Klein warm start and RTN.
    let mut init = vec![0u8; m * n];
    let mut stats = IterStats { obj_real, ..Default::default() };
    for j in 0..n {
        let (s, z) = col_grid(&sc, j, m);
        let b: Vec<f64> = (0..m).map(|i| rhs_p.get(i, j) as f64).collect();
        let warm: Vec<f64> =
            (0..m).map(|i| s[i] * (warm_q.codes[i * n + j] as f64 - z[i])).collect();
        let rtn_codes: Vec<u8> = (0..m)
            .map(|i| {
                super::rtn::round_code(
                    w_p.get(i, j) / s_full.get(i, j) + z_full.get(i, j),
                    qmax,
                ) as u8
            })
            .collect();
        let rtn_hat: Vec<f64> =
            (0..m).map(|i| s[i] * (rtn_codes[i] as f64 - z[i])).collect();
        let ow = col_obj_f64(gram, &b, &warm);
        let or = col_obj_f64(gram, &b, &rtn_hat);
        stats.warm_obj += ow;
        stats.rtn_obj += or;
        if or < ow {
            stats.init_obj += or;
            for i in 0..m {
                init[i * n + j] = rtn_codes[i];
            }
        } else {
            stats.init_obj += ow;
            for i in 0..m {
                init[i * n + j] = warm_q.codes[i * n + j];
            }
        }
    }
    // Incumbent = init; ADMM can only improve on it.
    let mut best = init.clone();
    let mut best_obj = stats.init_obj;
    stats.obj_trace.push(best_obj);
    // ADMM state: W_c starts at the unconstrained optimum, U at zero.
    let dequant = |codes: &[u8]| -> Matrix {
        Matrix::from_fn(m, n, |i, j| {
            s_full.get(i, j) * (codes[i * n + j] as f32 - z_full.get(i, j))
        })
    };
    let rho0 = ((0.1 * (sys.diag_mean + sys.lambda_sq)) as f32).max(1e-6);
    let mut rho = rho0;
    let mut chol = chol_rho(gram, rho)?;
    let mut wq = dequant(&init);
    let mut u = Matrix::zeros(m, n);
    let mut codes = init.clone();
    for iter in 0..MAX_ITERS {
        // 1. Continuous LS subproblem under the current penalty.
        let mut rhs_a = wq.sub(&u).scale(rho);
        rhs_a.axpy(1.0, rhs_p);
        let w_c = jta::solve_real(&chol, &rhs_a);
        // 2. Box-constrained grid projection of W_c + U.
        let prev_wq = wq.clone();
        let prev_codes = codes.clone();
        for i in 0..m {
            for j in 0..n {
                let t = w_c.get(i, j) + u.get(i, j);
                let q = super::rtn::round_code(
                    t / s_full.get(i, j) + z_full.get(i, j),
                    qmax,
                );
                codes[i * n + j] = q as u8;
                wq.set(i, j, s_full.get(i, j) * (q - z_full.get(i, j)));
            }
        }
        // 3. Dual ascent.
        u.axpy(1.0, &w_c);
        u.axpy(-1.0, &wq);
        stats.iters = (iter + 1) as u64;
        // Incumbent update by exact objective.
        let obj = codes_obj(gram, rhs_p, &sc, &codes);
        if obj < best_obj {
            best_obj = obj;
            best.copy_from_slice(&codes);
        }
        stats.obj_trace.push(best_obj);
        if codes == prev_codes && iter > 0 {
            break; // projection fixed point — further iterates repeat
        }
        // Residual-balancing penalty adaptation (bounded around ρ₀).
        let primal = w_c.sub(&wq).frob();
        let dual = rho as f64 * wq.sub(&prev_wq).frob();
        let mut new_rho = rho;
        if primal > 10.0 * dual && rho < rho0 * 64.0 {
            new_rho = rho * 2.0;
        } else if dual > 10.0 * primal && rho > rho0 / 64.0 {
            new_rho = rho * 0.5;
        }
        if new_rho != rho {
            rho = new_rho;
            chol = chol_rho(gram, rho)?;
        }
    }
    stats.changed = best.iter().zip(&init).filter(|(a, b)| a != b).count() as u64;
    let mut q = QuantizedLinear::new(best, sc, cfg.wbit, m, n);
    if permuted {
        let inv = crate::tensor::invert_perm(perm);
        let w_hat = q.dequantize().permute_rows(&inv);
        q.effective = Some(w_hat);
        q.perm = Some(perm.iter().map(|&p| p as u32).collect());
    }
    Ok((q, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
        let noise = Matrix::randn(p, m, 0.05, &mut rng);
        let x_rt = x_fp.add(&noise);
        (w, x_fp, x_rt)
    }

    #[test]
    fn incumbent_trace_is_monotone_and_dominates_inits() {
        for seed in [1u64, 2, 3] {
            let (w, x_fp, x_rt) = layer(24, 16, 48, seed);
            let cfg =
                QuantConfig { wbit: 3, group_size: 8, ntile: 8, ..Default::default() };
            let mut rng = Rng::new(seed);
            let (_, it) =
                quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, None).unwrap();
            assert_eq!(it.obj_trace[0], it.init_obj);
            for win in it.obj_trace.windows(2) {
                assert!(win[1] <= win[0], "incumbent increased: {win:?}");
            }
            assert!(it.final_obj() <= it.warm_obj + 1e-9);
            assert!(it.final_obj() <= it.rtn_obj + 1e-9);
            assert!(it.iters >= 1 && it.iters <= MAX_ITERS as u64);
            assert!(it.resid() >= -1e-6);
            assert!(it.resid() <= it.init_resid() + 1e-9);
        }
    }

    #[test]
    fn beats_rtn_on_runtime_error() {
        let (w, x_fp, x_rt) = layer(48, 32, 96, 4);
        let cfg = QuantConfig { wbit: 3, group_size: 0, ntile: 16, ..Default::default() };
        let mut rng = Rng::new(4);
        let (q, it) = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, None).unwrap();
        let q_rtn = super::super::rtn::quantize(&w, &cfg);
        let err = |wh: &Matrix| matmul(&x_rt, wh).sub(&matmul(&x_rt, &w)).frob();
        assert!(it.final_obj() <= it.rtn_obj);
        assert!(err(&q.dequantize()) < err(&q_rtn.dequantize()));
    }

    #[test]
    fn deterministic_and_boxed() {
        let (w, x_fp, x_rt) = layer(20, 12, 40, 6);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        let (qa, _) = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut a, None, None).unwrap();
        let (qb, _) = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut b, None, None).unwrap();
        assert_eq!(qa.codes, qb.codes);
        assert!(qa.codes.iter().all(|&c| c <= 15));
    }

    #[test]
    fn lean_factor_is_rejected() {
        let (w, x_fp, x_rt) = layer(16, 8, 32, 9);
        let cfg = QuantConfig::default();
        let lean = FactoredSystem::for_ojbkq(&x_rt, &cfg).unwrap();
        let mut rng = Rng::new(9);
        let err = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, Some(&lean))
            .unwrap_err()
            .to_string();
        assert!(err.contains("Gram"), "unexpected error: {err}");
    }
}
