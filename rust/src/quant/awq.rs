//! AWQ baseline (Lin et al., 2024): activation-aware weight scaling.
//!
//! Salient input channels (large mean |activation|) get their weights
//! scaled *up* before RTN — shrinking their relative rounding error — and
//! the inverse scale is folded back at runtime. The exponent of the
//! per-channel scale `s_i = (mean|x_i|)^α` is grid-searched on the
//! calibration set to minimize output MSE (the paper's full-precision
//! mapping objective, Eq. 3).
//!
//! We keep AWQ's original objective (calibrating against `XW` with the
//! given activations) — differences in alignment target between methods
//! are exactly what the paper's JTA analysis studies.

use super::rtn;
use super::{QuantConfig, QuantizedLinear};
use crate::linalg::matmul;
use crate::tensor::Matrix;

/// Number of grid points for the α search (α = i / GRID, i = 0..GRID).
const GRID: usize = 20;

/// AWQ-quantize a layer against calibration activations `x` (`p×m`).
pub fn quantize(w: &Matrix, x: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    let (m, _n) = w.shape();
    assert_eq!(x.cols(), m);
    // Per-input-channel salience: mean |x_i| over the calibration set.
    let p = x.rows();
    let mut salience = vec![0.0f64; m];
    for r in 0..p {
        let row = x.row(r);
        for (s, &v) in salience.iter_mut().zip(row) {
            *s += v.abs() as f64;
        }
    }
    for s in salience.iter_mut() {
        *s = (*s / p.max(1) as f64).max(1e-8);
    }
    // Normalize so the geometric mean is 1 (keeps scales centered and the
    // α grid comparable across layers — matches the reference impl).
    let log_mean: f64 = salience.iter().map(|s| s.ln()).sum::<f64>() / m as f64;
    let norm = log_mean.exp();
    for s in salience.iter_mut() {
        *s /= norm;
    }

    let y_ref = matmul(x, w);
    let mut best: Option<(f64, QuantizedLinear, Vec<f32>)> = None;
    for gi in 0..=GRID {
        let alpha = gi as f64 / GRID as f64;
        let scale: Vec<f32> = salience.iter().map(|&s| (s.powf(alpha)) as f32).collect();
        // W' = diag(scale)·W; runtime folds diag(1/scale) into activations.
        let mut w_scaled = w.clone();
        for i in 0..m {
            let si = scale[i];
            for v in w_scaled.row_mut(i) {
                *v *= si;
            }
        }
        let q = rtn::quantize(&w_scaled, cfg);
        // Effective weight the runtime sees: diag(1/scale)·dq(W').
        let mut w_eff = q.dequantize();
        for i in 0..m {
            let inv = 1.0 / scale[i];
            for v in w_eff.row_mut(i) {
                *v *= inv;
            }
        }
        let err = matmul(x, &w_eff).sub(&y_ref).frob_sq();
        if best.as_ref().map(|(e, _, _)| err < *e).unwrap_or(true) {
            best = Some((err, q, scale));
        }
    }
    let (_, mut q, scale) = best.unwrap();
    // Store the effective dense weight (scales folded) for the eval path.
    let mut w_eff = q.dequantize();
    for i in 0..m {
        let inv = 1.0 / scale[i];
        for v in w_eff.row_mut(i) {
            *v *= inv;
        }
    }
    q.effective = Some(w_eff);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rt_err(w_hat: &Matrix, w: &Matrix, x: &Matrix) -> f64 {
        matmul(x, w_hat).sub(&matmul(x, w)).frob()
    }

    /// Activations with a few dominant (salient) channels — the regime
    /// AWQ is built for.
    fn salient_layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let mut x = Matrix::randn(p, m, 1.0, &mut rng);
        for r in 0..p {
            let row = x.row_mut(r);
            for i in 0..m / 8 {
                row[i * 8] *= 8.0; // every 8th channel is 8x hotter
            }
        }
        (w, x)
    }

    #[test]
    fn awq_beats_rtn_on_salient_activations() {
        let mut wins = 0;
        for seed in 0..5 {
            let (w, x) = salient_layer(64, 24, 128, seed);
            let cfg = QuantConfig { wbit: 3, group_size: 32, ..Default::default() };
            let q_awq = quantize(&w, &x, &cfg);
            let q_rtn = rtn::quantize(&w, &cfg);
            if rt_err(&q_awq.dequantize(), &w, &x) < rt_err(&q_rtn.dequantize(), &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "awq won only {wins}/5");
    }

    #[test]
    fn alpha_zero_included_so_never_much_worse_than_rtn() {
        // α=0 gives scale ≡ 1 (pure RTN), so grid search can only improve
        // the calibration objective.
        let (w, x) = salient_layer(32, 16, 64, 42);
        let cfg = QuantConfig { wbit: 4, group_size: 0, ..Default::default() };
        let q_awq = quantize(&w, &x, &cfg);
        let q_rtn = rtn::quantize(&w, &cfg);
        let e_awq = rt_err(&q_awq.dequantize(), &w, &x);
        let e_rtn = rt_err(&q_rtn.dequantize(), &w, &x);
        assert!(e_awq <= e_rtn * 1.0001, "awq {e_awq} vs rtn {e_rtn}");
    }

    #[test]
    fn effective_weight_finite_and_shaped() {
        let (w, x) = salient_layer(24, 8, 48, 7);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let q = quantize(&w, &x, &cfg);
        let eff = q.dequantize();
        assert_eq!(eff.shape(), (24, 8));
        assert!(eff.all_finite());
    }

    #[test]
    fn deterministic() {
        let (w, x) = salient_layer(16, 8, 32, 9);
        let cfg = QuantConfig { wbit: 4, ..Default::default() };
        let a = quantize(&w, &x, &cfg);
        let b = quantize(&w, &x, &cfg);
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.dequantize(), b.dequantize());
    }
}
