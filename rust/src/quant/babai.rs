//! Box-constrained Babai nearest-plane decoding (paper Algorithm 1) —
//! "Ours(N)" and the reserved greedy path inside every K-best decode.
//!
//! Per weight column `j` the BILS problem is
//! `min_{q ∈ B^m} ||R̄(q − q̄)||²` with `R̄ = R·diag(s_j)` (§3.3). We never
//! materialize `R̄`: with the *weight-space error* `e(l) = s(l)·(q̄(l) −
//! q(l))` the back-substitution center is
//!
//! `c(i) = q̄(i) + (Σ_{l>i} R(i,l)·e(l)) / (R(i,i)·s(i))`
//!
//! which shares the single Cholesky factor `R` across all columns of the
//! layer — the structure that makes the tiled PPI decoder (and its Pallas
//! twin) a batched GEMM problem.

use super::rtn::round_code;
use crate::tensor::Matrix;

/// Greedy Babai decode of one column.
///
/// * `r` — `m×m` upper-triangular Cholesky factor (shared per layer).
/// * `s` — per-row scales for this column (diagonal of `D_j`).
/// * `qbar` — real-valued unconstrained solution in code space.
/// * `qmax` — box upper bound `2^b − 1`.
///
/// Returns integer codes as f32 (exact small integers).
pub fn decode_greedy(r: &Matrix, s: &[f32], qbar: &[f32], qmax: f32) -> Vec<f32> {
    let m = r.rows();
    assert_eq!(s.len(), m);
    assert_eq!(qbar.len(), m);
    let mut q = vec![0.0f32; m];
    let mut e = vec![0.0f32; m]; // weight-space error of processed rows
    for i in (0..m).rev() {
        let c = center(r, s, qbar, &e, i, m);
        let qi = round_code(c, qmax);
        q[i] = qi;
        e[i] = s[i] * (qbar[i] - qi);
    }
    q
}

/// Back-substitution center for row `i` given errors of rows `> i`.
#[inline]
pub(crate) fn center(r: &Matrix, s: &[f32], qbar: &[f32], e: &[f32], i: usize, m: usize) -> f32 {
    let mut acc = 0.0f64;
    let ri = &r.row(i)[i + 1..m];
    for (off, &rij) in ri.iter().enumerate() {
        acc += rij as f64 * e[i + 1 + off] as f64;
    }
    qbar[i] + (acc / (r.get(i, i) as f64 * s[i] as f64)) as f32
}

/// Squared residual `||R · (s ⊙ (q − q̄))||²` — the BILS objective value
/// of a candidate (the quantity Algorithm 4 minimizes over candidates).
pub fn residual_sq(r: &Matrix, s: &[f32], qbar: &[f32], q: &[f32]) -> f64 {
    let m = r.rows();
    let e: Vec<f64> =
        (0..m).map(|l| s[l] as f64 * (q[l] as f64 - qbar[l] as f64)).collect();
    let mut total = 0.0f64;
    for i in 0..m {
        let mut acc = 0.0f64;
        let ri = &r.row(i)[i..m];
        for (off, &rij) in ri.iter().enumerate() {
            acc += rij as f64 * e[i + off];
        }
        total += acc * acc;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cholesky_upper, syrk_upper};
    use crate::rng::Rng;

    fn setup(m: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(2 * m, m, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.1);
        let r = cholesky_upper(&g).unwrap();
        let s: Vec<f32> = (0..m).map(|_| 0.05 + 0.2 * rng.uniform_f32()).collect();
        let qbar: Vec<f32> = (0..m).map(|_| 15.0 * rng.uniform_f32()).collect();
        (r, s, qbar)
    }

    #[test]
    fn identity_lattice_reduces_to_rtn() {
        // With R = I and s = 1, Babai is exactly per-coordinate rounding.
        let m = 24;
        let r = Matrix::eye(m);
        let s = vec![1.0f32; m];
        let mut rng = Rng::new(1);
        let qbar: Vec<f32> = (0..m).map(|_| 15.0 * rng.uniform_f32() - 2.0).collect();
        let q = decode_greedy(&r, &s, &qbar, 15.0);
        for i in 0..m {
            assert_eq!(q[i], round_code(qbar[i], 15.0), "i={i}");
        }
    }

    #[test]
    fn codes_respect_box() {
        let (r, s, qbar) = setup(48, 2);
        for qmax in [7.0f32, 15.0] {
            let q = decode_greedy(&r, &s, &qbar, qmax);
            for &v in &q {
                assert!(v >= 0.0 && v <= qmax && v.fract() == 0.0);
            }
        }
    }

    #[test]
    fn babai_beats_or_ties_rtn_in_lattice_metric() {
        // The Babai point minimizes each successive projection, so its
        // residual is <= the naive rounding residual in the same metric
        // on the vast majority of instances; we assert across many seeds
        // it never loses by more than float noise and wins on average.
        let mut babai_total = 0.0;
        let mut rtn_total = 0.0;
        for seed in 0..20 {
            let (r, s, qbar) = setup(32, 100 + seed);
            let qb = decode_greedy(&r, &s, &qbar, 15.0);
            let qr: Vec<f32> = qbar.iter().map(|&c| round_code(c, 15.0)).collect();
            babai_total += residual_sq(&r, &s, &qbar, &qb);
            rtn_total += residual_sq(&r, &s, &qbar, &qr);
        }
        assert!(
            babai_total < rtn_total,
            "babai {babai_total} should beat rtn {rtn_total} on average"
        );
    }

    #[test]
    fn exact_point_has_zero_residual() {
        let (r, s, _) = setup(16, 3);
        let mut rng = Rng::new(4);
        let q_true: Vec<f32> = (0..16).map(|_| rng.below(16) as f32).collect();
        // qbar = exactly representable integer point.
        let q = decode_greedy(&r, &s, &q_true, 15.0);
        assert_eq!(q, q_true);
        assert!(residual_sq(&r, &s, &q_true, &q) < 1e-9);
    }

    #[test]
    fn residual_positive_for_wrong_point() {
        let (r, s, qbar) = setup(16, 5);
        let mut q = decode_greedy(&r, &s, &qbar, 15.0);
        let r0 = residual_sq(&r, &s, &qbar, &q);
        q[7] = if q[7] > 0.0 { q[7] - 1.0 } else { q[7] + 1.0 };
        let r1 = residual_sq(&r, &s, &qbar, &q);
        assert!(r1 > r0, "perturbing the Babai point should not improve residual");
    }
}
