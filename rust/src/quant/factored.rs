//! Shared per-tap-point factorization — the **factor-sharing half of the
//! parallel layer-solve engine**.
//!
//! The expensive, weight-independent part of every normal-equation solve
//! is a function of the runtime activations `X̃` and the config alone:
//! the (ridged) Gram `G = X̃ᵀX̃ + λ²I` (or GPTQ's damped Hessian), the
//! act-order permutation derived from its diagonal, and the jittered
//! Cholesky factor `R`. Layers that consume the same tap share all of it
//! exactly — Q/K/V read the same `attn_in` taps and Gate/Up the same
//! `mlp_in` taps — so the coordinator builds ONE [`FactoredSystem`] per
//! tap group ([`FactoredSystem::for_method`] in
//! `coordinator::quantize_group`) and threads it through
//! [`crate::quant::quantize_layer_shared`] into the OJBKQ and GPTQ
//! solvers, eliminating 3× redundant syrk+Cholesky work for the QKV
//! group and 2× for Gate/Up. Only the per-layer RHS `B = X̃ᵀY* + λ²W`
//! ([`super::jta::build_rhs`]), scales and decode remain per layer.
//!
//! Sharing is **bit-exact** by construction: a solver handed a
//! `FactoredSystem` performs the same arithmetic it would have performed
//! rebuilding the factor itself (pinned by `tests/solver_parallel.rs`).

use super::jta;
use super::{Method, QuantConfig};
use crate::linalg::cholesky_upper_jittered;
use crate::tensor::Matrix;

/// Which solver family a [`FactoredSystem`] was built for. The two
/// families ridge and order the Gram differently (λ²_abs + ascending
/// diagonal for the Babai/Klein decode vs 1% dampening + descending
/// diagonal for the GPTQ sweep), so a factor is only valid for the
/// family that built it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactorKind {
    /// OJBKQ family (Ours / Ours(N) / Ours(R) / QEP): `G = X̃ᵀX̃ + λ²I`,
    /// act-order sorts by ASCENDING Gram diagonal (Babai decides the last
    /// row first).
    Ojbkq,
    /// GPTQ baseline: `H = X̃ᵀX̃ + 0.01·mean(diag)·I`, act-order sorts by
    /// DESCENDING Hessian diagonal, and the sweep consumes the Cholesky
    /// factor of `H⁻¹`.
    Gptq,
}

/// The weight-independent factorization of one tap point's normal
/// equations, shared across every layer of a tap group.
#[derive(Debug, Clone)]
pub struct FactoredSystem {
    /// Solver family this factor serves.
    pub kind: FactorKind,
    /// Decode/act order (identity when `act_order` is off).
    pub perm: Vec<usize>,
    /// Whether `perm` is a real permutation (i.e. `cfg.act_order`); when
    /// false the solvers skip every gather/scatter.
    pub permuted: bool,
    /// The upper-triangular factor the family's solver consumes. OJBKQ:
    /// Cholesky factor of the permuted ridged Gram. GPTQ: the Cholesky
    /// factor `U` of `H⁻¹ = UᵀU`, whose rows carry the sweep's
    /// error-compensation coefficients (the intermediate `chol(H)` is
    /// dropped after use).
    pub r: Matrix,
    /// The permuted ridged Gram `G_p` itself — retained ONLY when the
    /// factor was built for an iterative solver that consumes it
    /// (QuantEase coordinate descent reads Gram rows; ADMM-Q refactors
    /// `G_p + ρI` on penalty changes). `None` for the single-pass
    /// decode/sweep solvers, which keep only `r` resident. Guarded by
    /// [`FactoredSystem::check_for`]: a factor without the Gram handed
    /// to a Gram-requiring solver is a hard error, never wrong codes.
    pub gram: Option<Matrix>,
    /// The ridge actually added to the diagonal: `λ²_abs` (OJBKQ) or the
    /// 1% mean-diagonal dampening (GPTQ). OJBKQ's RHS needs it.
    pub lambda_sq: f64,
    /// Mean of the pre-ridge Gram diagonal (diagnostics / λ resolution).
    pub diag_mean: f64,
}

impl FactoredSystem {
    /// Build the shared factor for the OJBKQ solver family. `cfg` must be
    /// the *solver* config (variant mapping already applied — use
    /// [`FactoredSystem::for_method`] from generic callers).
    pub fn for_ojbkq(x_rt: &Matrix, cfg: &QuantConfig) -> anyhow::Result<FactoredSystem> {
        Self::build_ojbkq(x_rt, cfg, false)
    }

    /// Same factor as [`FactoredSystem::for_ojbkq`], but the permuted
    /// ridged Gram `G_p` stays resident for the iterative solvers
    /// (QuantEase / ADMM-Q) that consume it directly. `r` is bit-identical
    /// to the Gram-free build.
    pub fn for_ojbkq_with_gram(
        x_rt: &Matrix,
        cfg: &QuantConfig,
    ) -> anyhow::Result<FactoredSystem> {
        Self::build_ojbkq(x_rt, cfg, true)
    }

    fn build_ojbkq(
        x_rt: &Matrix,
        cfg: &QuantConfig,
        keep_gram: bool,
    ) -> anyhow::Result<FactoredSystem> {
        let m = x_rt.cols();
        let (gram, lambda_sq, diag_mean) = jta::build_gram(x_rt, cfg);
        // Decode ordering: Babai decides row m−1 first (uncompensated), so
        // sort rows by ASCENDING Gram diagonal — the highest-curvature
        // feature is decided first, exactly GPTQ's act_order under the
        // Babai/GPTQ order reversal (Chen et al. 2025).
        let perm: Vec<usize> = if cfg.act_order {
            let mut idx: Vec<usize> = (0..m).collect();
            idx.sort_by(|&a, &b| {
                gram.get(a, a)
                    .partial_cmp(&gram.get(b, b))
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            idx
        } else {
            (0..m).collect()
        };
        let gram_p = if cfg.act_order { permute_sym(&gram, &perm) } else { gram };
        let (r, _jitter) = cholesky_upper_jittered(&gram_p, 1e-6)
            .map_err(|e| anyhow::anyhow!("gram cholesky failed: {e}"))?;
        Ok(FactoredSystem {
            kind: FactorKind::Ojbkq,
            perm,
            permuted: cfg.act_order,
            r,
            gram: if keep_gram { Some(gram_p) } else { None },
            lambda_sq,
            diag_mean,
        })
    }

    /// Build the shared factor for the GPTQ baseline: damped Hessian,
    /// descending act-order, and the Cholesky factor of `H⁻¹` the
    /// compensation sweep reads its coefficients from.
    pub fn for_gptq(x_rt: &Matrix, cfg: &QuantConfig) -> anyhow::Result<FactoredSystem> {
        let m = x_rt.cols();
        // Hessian with the standard 1% mean-diagonal dampening.
        let gram = crate::linalg::syrk_upper(x_rt, 0.0);
        let diag_mean: f64 =
            (0..m).map(|i| gram.get(i, i) as f64).sum::<f64>() / m.max(1) as f64;
        let damp = (0.01 * diag_mean) as f32;
        let mut h = gram;
        for i in 0..m {
            h.add_at(i, i, damp);
        }
        // Activation ordering: quantize high-curvature features first.
        let perm: Vec<usize> = if cfg.act_order {
            let mut idx: Vec<usize> = (0..m).collect();
            idx.sort_by(|&a, &b| {
                h.get(b, b).partial_cmp(&h.get(a, a)).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx
        } else {
            (0..m).collect()
        };
        let h_p = if cfg.act_order { permute_sym(&h, &perm) } else { h };
        let (r_h, _jit) = cholesky_upper_jittered(&h_p, 1e-6)
            .map_err(|e| anyhow::anyhow!("gptq hessian cholesky: {e}"))?;
        // H⁻¹ = R⁻¹R⁻ᵀ via two multi-RHS triangular solves against the
        // identity (never a Gaussian-elimination inverse), then factored.
        // `r_h` itself is dead after this; only `U = chol(H⁻¹)` is kept.
        let hinv = {
            let z = crate::linalg::solve_lower_t(&r_h, &Matrix::eye(m)); // Rᵀ Z = I
            crate::linalg::solve_upper_mat(&r_h, &z) // R Hinv = Z
        };
        let (uinv, _jit2) = cholesky_upper_jittered(&hinv, 1e-8)
            .map_err(|e| anyhow::anyhow!("gptq H^-1 cholesky: {e}"))?;
        Ok(FactoredSystem {
            kind: FactorKind::Gptq,
            perm,
            permuted: cfg.act_order,
            r: uinv,
            gram: None,
            lambda_sq: damp as f64,
            diag_mean,
        })
    }

    /// Build the shared factor appropriate for `method` (with the same
    /// per-method variant mapping [`crate::quant::quantize_layer`]
    /// applies), or `None` for methods with no shareable factorization
    /// (RTN/AWQ have none; QuIP rotates its activations per layer).
    pub fn for_method(
        method: Method,
        x_rt: &Matrix,
        cfg: &QuantConfig,
    ) -> anyhow::Result<Option<FactoredSystem>> {
        let scfg = super::solver_cfg(method, cfg);
        Ok(match method {
            Method::Gptq => Some(Self::for_gptq(x_rt, &scfg)?),
            Method::BabaiNaive | Method::KleinRandomK | Method::Ojbkq | Method::Qep => {
                Some(Self::for_ojbkq(x_rt, &scfg)?)
            }
            // Iterative families share the OJBKQ factor (same objective,
            // same ordering, same ridge) but additionally keep the Gram.
            Method::QuantEase | Method::AdmmQ => {
                Some(Self::for_ojbkq_with_gram(x_rt, &scfg)?)
            }
            Method::Fp | Method::Rtn | Method::Awq | Method::Quip => None,
        })
    }

    /// Feature dimension `m` the factor was built for.
    pub fn dim(&self) -> usize {
        self.r.rows()
    }

    /// Guard: a solver must only consume a factor of its own family and
    /// dimension, built under the same ordering/ridge configuration it
    /// is decoding with (a mismatched factor would silently quantize
    /// under the factor's permutation and λ, not the cfg's).
    pub fn check(&self, kind: FactorKind, m: usize, cfg: &QuantConfig) -> anyhow::Result<()> {
        self.check_for(kind, m, cfg, false)
    }

    /// [`FactoredSystem::check`] plus per-solver *requirements*: solver
    /// families within the same `FactorKind` need different pieces of the
    /// factorization resident. The single-pass decoders only read `r`;
    /// QuantEase / ADMM-Q need the full Gram (`needs_gram`). A factor
    /// built for the wrong requirements is rejected here instead of
    /// silently producing wrong codes downstream.
    pub fn check_for(
        &self,
        kind: FactorKind,
        m: usize,
        cfg: &QuantConfig,
        needs_gram: bool,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.kind == kind,
            "FactoredSystem family mismatch: built for {:?}, used by {:?}",
            self.kind,
            kind
        );
        anyhow::ensure!(
            self.dim() == m,
            "FactoredSystem dim mismatch: built for m={}, layer has m={m}",
            self.dim()
        );
        anyhow::ensure!(
            self.permuted == cfg.act_order,
            "FactoredSystem act_order mismatch: built with {}, cfg wants {}",
            self.permuted,
            cfg.act_order
        );
        if kind == FactorKind::Ojbkq {
            // The ridge is a pure function of (λ, mode, diag_mean); a
            // factor built under another λ resolves to a different value.
            let expect = jta::lambda_sq_abs(cfg, self.diag_mean);
            anyhow::ensure!(
                expect == self.lambda_sq,
                "FactoredSystem λ mismatch: factor ridge {} vs cfg-resolved {expect}",
                self.lambda_sq
            );
        }
        if needs_gram {
            anyhow::ensure!(
                self.gram.is_some(),
                "FactoredSystem requirements mismatch: solver needs the full \
                 Gram resident, but this factor only retained R (built for a \
                 single-pass decode family — use for_ojbkq_with_gram / \
                 for_method with the iterative solver)"
            );
        }
        Ok(())
    }

    /// The resident permuted ridged Gram, or the requirements-mismatch
    /// error. Iterative solvers call this after [`Self::check_for`].
    pub fn gram(&self) -> anyhow::Result<&Matrix> {
        self.gram.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "FactoredSystem has no resident Gram (built for a single-pass family)"
            )
        })
    }
}

/// Symmetric permutation `H[perm, perm]` as two gather passes — a
/// contiguous row gather (one `memcpy` per row) followed by a row-wise
/// column gather (contiguous writes) — instead of the old per-element
/// `Matrix::from_fn` double-indexed walk.
pub fn permute_sym(h: &Matrix, perm: &[usize]) -> Matrix {
    let m = h.rows();
    assert_eq!(perm.len(), m);
    let rows = h.gather_rows(perm);
    let mut out = Matrix::zeros(m, m);
    for i in 0..m {
        let src = rows.row(i);
        let dst = out.row_mut(i);
        for (d, &p) in dst.iter_mut().zip(perm) {
            *d = src[p];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn permute_sym_matches_from_fn() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let h = crate::linalg::syrk_upper(&a, 0.1);
        let mut perm: Vec<usize> = (0..12).collect();
        rng.shuffle(&mut perm);
        let fast = permute_sym(&h, &perm);
        let reference = Matrix::from_fn(12, 12, |i, j| h.get(perm[i], perm[j]));
        assert_eq!(fast.as_slice(), reference.as_slice());
    }

    #[test]
    fn ojbkq_factor_matches_inline_build() {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(64, 24, 1.0, &mut rng);
        for act_order in [false, true] {
            let cfg = QuantConfig { act_order, lambda: 0.3, ..Default::default() };
            let sys = FactoredSystem::for_ojbkq(&x, &cfg).unwrap();
            assert_eq!(sys.kind, FactorKind::Ojbkq);
            assert_eq!(sys.dim(), 24);
            assert_eq!(sys.permuted, act_order);
            // Reference: the pre-refactor inline build.
            let (gram, lambda_sq, _) = crate::quant::jta::build_gram(&x, &cfg);
            assert_eq!(sys.lambda_sq, lambda_sq);
            let perm: Vec<usize> = if act_order {
                let mut idx: Vec<usize> = (0..24).collect();
                idx.sort_by(|&a, &b| {
                    gram.get(a, a).partial_cmp(&gram.get(b, b)).unwrap()
                });
                idx
            } else {
                (0..24).collect()
            };
            assert_eq!(sys.perm, perm);
            let gram_p = Matrix::from_fn(24, 24, |i, j| gram.get(perm[i], perm[j]));
            let (r, _) = crate::linalg::cholesky_upper_jittered(&gram_p, 1e-6).unwrap();
            assert_eq!(sys.r.as_slice(), r.as_slice());
        }
    }

    #[test]
    fn factor_guards_fire_on_every_mismatch_axis() {
        let mut rng = Rng::new(9);
        let x = Matrix::randn(40, 16, 1.0, &mut rng);
        let cfg = QuantConfig::default();
        let sys = FactoredSystem::for_ojbkq(&x, &cfg).unwrap();
        assert!(sys.check(FactorKind::Ojbkq, 16, &cfg).is_ok());
        assert!(sys.check(FactorKind::Gptq, 16, &cfg).is_err(), "family");
        assert!(sys.check(FactorKind::Ojbkq, 17, &cfg).is_err(), "dim");
        let flipped = QuantConfig { act_order: !cfg.act_order, ..cfg.clone() };
        assert!(sys.check(FactorKind::Ojbkq, 16, &flipped).is_err(), "act_order");
        let other_lambda = QuantConfig { lambda: cfg.lambda + 0.1, ..cfg.clone() };
        assert!(sys.check(FactorKind::Ojbkq, 16, &other_lambda).is_err(), "lambda");
        let sys = FactoredSystem::for_gptq(&x, &cfg).unwrap();
        assert_eq!(sys.kind, FactorKind::Gptq);
        assert_eq!(sys.dim(), 16);
        assert!(sys.check(FactorKind::Gptq, 16, &cfg).is_ok());
    }

    #[test]
    fn gram_retention_matches_and_requirements_guard_fires() {
        let mut rng = Rng::new(21);
        let x = Matrix::randn(48, 16, 1.0, &mut rng);
        for act_order in [false, true] {
            let cfg = QuantConfig { act_order, ..Default::default() };
            let lean = FactoredSystem::for_ojbkq(&x, &cfg).unwrap();
            let full = FactoredSystem::for_ojbkq_with_gram(&x, &cfg).unwrap();
            // Same factor either way — the Gram is extra, never different.
            assert_eq!(lean.r.as_slice(), full.r.as_slice());
            assert_eq!(lean.perm, full.perm);
            assert!(lean.gram.is_none());
            let gram_p = full.gram().unwrap();
            assert_eq!(gram_p.rows(), 16);
            // The retained Gram is exactly what was factored: R^T R ≈ G_p.
            let rt_r = crate::linalg::matmul(&full.r.transpose(), &full.r);
            for i in 0..16 {
                for j in 0..16 {
                    assert!(
                        (rt_r.get(i, j) - gram_p.get(i, j)).abs()
                            <= 1e-3 * (1.0 + gram_p.get(i, j).abs()),
                        "R^T R vs G_p at ({i},{j})"
                    );
                }
            }
            // Requirements guard: a Gram-less factor is rejected for a
            // Gram-requiring solver, accepted otherwise.
            assert!(lean.check_for(FactorKind::Ojbkq, 16, &cfg, false).is_ok());
            assert!(lean.check_for(FactorKind::Ojbkq, 16, &cfg, true).is_err());
            assert!(full.check_for(FactorKind::Ojbkq, 16, &cfg, true).is_ok());
            assert!(lean.gram().is_err());
        }
    }

    #[test]
    fn for_method_covers_the_factorizing_solvers() {
        let mut rng = Rng::new(11);
        let x = Matrix::randn(32, 12, 1.0, &mut rng);
        let cfg = QuantConfig::default();
        for (method, expect) in [
            (Method::Ojbkq, Some(FactorKind::Ojbkq)),
            (Method::BabaiNaive, Some(FactorKind::Ojbkq)),
            (Method::KleinRandomK, Some(FactorKind::Ojbkq)),
            (Method::Qep, Some(FactorKind::Ojbkq)),
            (Method::QuantEase, Some(FactorKind::Ojbkq)),
            (Method::AdmmQ, Some(FactorKind::Ojbkq)),
            (Method::Gptq, Some(FactorKind::Gptq)),
            (Method::Rtn, None),
            (Method::Awq, None),
            (Method::Quip, None),
            (Method::Fp, None),
        ] {
            let got = FactoredSystem::for_method(method, &x, &cfg).unwrap();
            // Iterative families must come back with the Gram resident.
            let needs_gram = matches!(method, Method::QuantEase | Method::AdmmQ);
            if let Some(s) = &got {
                assert_eq!(s.gram.is_some(), needs_gram, "{method:?} gram retention");
            }
            assert_eq!(got.map(|s| s.kind), expect, "{method:?}");
        }
    }
}
