//! GPTQ baseline (Frantar et al., 2023): sequential per-row quantization
//! with second-order error compensation, optional activation ordering.
//!
//! Classic formulation on the Hessian `H = X̃ᵀX̃ + damp·I`: walk input
//! features in order, RTN-quantize row `i` (all output channels at once),
//! then push the scaled residual error into the not-yet-quantized rows
//! using the Cholesky factor of `H⁻¹`. We obtain that factor *without*
//! explicitly inverting H — `H = RᵀR ⇒ H⁻¹ = R⁻¹R⁻ᵀ`, and the update
//! coefficients `Hinv[i, j]/Hinv[i, i]` are rows of `R⁻¹` obtained by a
//! triangular solve (the paper's jab at GPTQ concerns numerical style;
//! the baseline math is unchanged).
//!
//! `act_order` (enabled in the paper's baseline config) permutes features
//! by descending Hessian diagonal before quantization and un-permutes the
//! result. Group scales are computed on the *original* weights
//! (static-groups style) so grouping and ordering compose correctly.

use super::factored::{FactorKind, FactoredSystem};
use super::scales::{self};
use super::{QuantConfig, QuantizedLinear};
use crate::tensor::{invert_perm, Matrix};

/// GPTQ-quantize a layer against runtime activations `x_rt` (`p×m`).
pub fn quantize(w: &Matrix, x_rt: &Matrix, cfg: &QuantConfig) -> anyhow::Result<QuantizedLinear> {
    quantize_with(w, x_rt, cfg, None)
}

/// [`quantize`] with an optional shared per-tap-point factorization: the
/// damped Hessian, act-order permutation, and the Cholesky factor of
/// `H⁻¹` the sweep reads its compensation coefficients from are all
/// weight-independent, so the coordinator builds them once per Q/K/V /
/// Gate/Up group ([`FactoredSystem::for_gptq`]) and every layer of the
/// group reuses them — bit-identical to rebuilding per layer.
pub fn quantize_with(
    w: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<QuantizedLinear> {
    let (m, n) = w.shape();
    assert_eq!(x_rt.cols(), m);
    let owned_sys;
    let sys: &FactoredSystem = match shared {
        Some(s) => {
            s.check(FactorKind::Gptq, m, cfg)?;
            s
        }
        None => {
            owned_sys = FactoredSystem::for_gptq(x_rt, cfg)?;
            &owned_sys
        }
    };
    let perm = &sys.perm;
    // The classic GPTQ recursion (Frantar et al., reference impl):
    //   U = upper Cholesky factor of H⁻¹  (H⁻¹ = UᵀU),
    //   err_i = (w_i − q̂_i) / U[i,i],   w_j -= U[i,j]·err_i  (j > i).
    // Row i of U encodes the Schur-complement compensation coefficients
    // H_sub⁻¹[0,:]/H_sub⁻¹[0,0] for the remaining submatrix, so one factor
    // serves the whole sweep. For the Gptq kind, `sys.r` IS that U.
    let uinv = &sys.r;

    // Static group scales from the (permuted) original weights. Note: with
    // act_order, group boundaries follow the PERMUTED order, matching the
    // `static_groups=False` default of the reference implementation.
    let mut work = if sys.permuted { w.permute_rows(perm) } else { w.clone() };
    let sc = scales::compute(&work, cfg);
    let qmax = cfg.box_max() as f32;

    let mut codes_p = vec![0u8; m * n];
    for i in 0..m {
        let g = sc.group_of(i);
        let d = uinv.get(i, i); // √(Schur-complement pivot)⁻¹ > 0
        // Quantize row i and accumulate the compensated error.
        let mut err = vec![0.0f32; n];
        for j in 0..n {
            let s = sc.scales.get(g, j);
            let z = sc.zeros.get(g, j);
            let v = work.get(i, j);
            let q = (v / s + z).round().clamp(0.0, qmax);
            codes_p[i * n + j] = q as u8;
            let dq = s * (q - z);
            err[j] = (v - dq) / d;
        }
        // Propagate into remaining rows: w_l -= U[i, l] * err (l > i).
        for l in i + 1..m {
            let coef = uinv.get(i, l);
            if coef == 0.0 {
                continue;
            }
            let row = work.row_mut(l);
            for (wv, &ev) in row.iter_mut().zip(&err) {
                *wv -= coef * ev;
            }
        }
    }

    // Un-permute rows of the code matrix back to original feature order.
    // Scales were computed in permuted space with permuted group
    // boundaries, so under act_order we keep codes+scales in permuted
    // space and attach the inverse permutation through an effective dense
    // weight, plus the decode-order row permutation so the packed
    // execution engine can stay on integer codes. Without act_order the
    // permutation is the identity: codes+scales are already in feature
    // order and neither field is needed (and the packed kernel skips the
    // activation gather entirely).
    let mut q = QuantizedLinear::new(codes_p, sc, cfg.wbit, m, n);
    if sys.permuted {
        let inv = invert_perm(perm);
        let w_hat = q.dequantize().permute_rows(&inv);
        q.effective = Some(w_hat);
        q.perm = Some(perm.iter().map(|&p| p as u32).collect());
    }
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::rtn;
    use crate::rng::Rng;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        // Correlated activations => non-trivial Hessian off-diagonals.
        let base = Matrix::randn(p, m, 1.0, &mut rng);
        let mix = Matrix::randn(m, m, 0.2, &mut rng);
        let x = matmul(&base, &Matrix::eye(m).add(&mix));
        (w, x)
    }

    fn rt_err(w_hat: &Matrix, w: &Matrix, x: &Matrix) -> f64 {
        matmul(x, w_hat).sub(&matmul(x, w)).frob()
    }

    #[test]
    fn gptq_beats_rtn() {
        let mut wins = 0;
        for seed in 0..5 {
            let (w, x) = layer(48, 32, 96, seed);
            let cfg = QuantConfig { wbit: 3, group_size: 16, ..Default::default() };
            let q = quantize(&w, &x, &cfg).unwrap();
            let q_rtn = rtn::quantize(&w, &cfg);
            if rt_err(&q.dequantize(), &w, &x) < rt_err(&q_rtn.dequantize(), &w, &x) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "gptq won only {wins}/5 vs rtn");
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // With X̃ᵀX̃ ∝ I there is nothing to compensate: GPTQ == RTN.
        let mut rng = Rng::new(1);
        let m = 16;
        let w = Matrix::randn(m, 8, 0.5, &mut rng);
        // Orthogonal activations: X = sqrt(p) * I stacked.
        let x = Matrix::from_fn(m, m, |i, j| if i == j { 3.0 } else { 0.0 });
        let cfg =
            QuantConfig { wbit: 4, group_size: 0, act_order: false, ..Default::default() };
        let q = quantize(&w, &x, &cfg).unwrap();
        let q_rtn = rtn::quantize(&w, &cfg);
        assert_eq!(q.codes, q_rtn.codes);
    }

    #[test]
    fn act_order_at_least_not_catastrophic() {
        let (w, x) = layer(32, 16, 64, 7);
        let cfg_on = QuantConfig { wbit: 3, group_size: 0, act_order: true, ..Default::default() };
        let cfg_off =
            QuantConfig { wbit: 3, group_size: 0, act_order: false, ..Default::default() };
        let e_on = rt_err(&quantize(&w, &x, &cfg_on).unwrap().dequantize(), &w, &x);
        let e_off = rt_err(&quantize(&w, &x, &cfg_off).unwrap().dequantize(), &w, &x);
        // act-order usually helps; never allow it to be much worse.
        assert!(e_on < e_off * 1.5, "on={e_on} off={e_off}");
    }

    #[test]
    fn effective_weight_has_layer_shape() {
        let (w, x) = layer(24, 12, 48, 3);
        let cfg = QuantConfig { wbit: 4, ..Default::default() };
        let q = quantize(&w, &x, &cfg).unwrap();
        assert_eq!(q.dequantize().shape(), (24, 12));
        assert!(q.dequantize().all_finite());
    }

    #[test]
    fn matches_babai_under_same_objective() {
        // Chen et al. 2025: GPTQ is Babai's nearest-plane under the
        // runtime-consistent objective. With act_order off, no groups and
        // identical dampening the two solvers should produce nearly
        // identical output error (codes may differ on ties).
        let (w, x) = layer(32, 16, 64, 11);
        let cfg = QuantConfig {
            wbit: 4,
            group_size: 0,
            act_order: false,
            k: 0,
            mu: 1.0,
            lambda: 0.0,
            ..Default::default()
        };
        let q_gptq = quantize(&w, &x, &cfg).unwrap();
        let mut rng = Rng::new(5);
        let q_babai =
            crate::quant::ojbkq::quantize(&w, &x, &x, &cfg, &mut rng, None).unwrap();
        let e_gptq = rt_err(&q_gptq.dequantize(), &w, &x);
        let e_babai = rt_err(&q_babai.dequantize(), &w, &x);
        let ratio = e_gptq / e_babai.max(1e-12);
        assert!(
            (0.8..1.25).contains(&ratio),
            "gptq {e_gptq} vs babai {e_babai} (ratio {ratio})"
        );
    }
}
