//! Joint Target Alignment (JTA) — the paper's unified selection objective
//! (§3.1, Eq. 6–8):
//!
//! `Y*(μ) = (1−μ)·XW + μ·X̃W`
//! `S(Ŵ) = ||X̃Ŵ − Y*(μ)||²_F + λ²||Ŵ − W||²_F`
//!
//! Special cases: (μ=1, λ=0) = runtime-consistent (GPTQ/QuIP, Eq. 1);
//! (μ=0, λ=0) = mismatch target (QEP, Eq. 4); the full-precision mapping
//! objective (AWQ, Eq. 3) corresponds to calibrating with `X̃ := X`.
//!
//! This module builds the stacked least-squares system of Eq. 8 in its
//! normal-equation form: `G = X̃ᵀX̃ + λ²I` and RHS `B = X̃ᵀY* + λ²W`, from
//! which the real-valued solution `Ŵ_real = G⁻¹B` is obtained via the
//! Cholesky factor and two triangular solves — no inverse materialized.

use super::{LambdaMode, QuantConfig};
use crate::linalg::{gemm_tn, matmul, solve_lower_t, solve_upper_mat, syrk_upper};
use crate::tensor::Matrix;

/// `Y*(μ) = (1−μ)·Y_fp + μ·Y_rt` (Eq. 6), computed from precomputed
/// outputs.
pub fn interp_target(y_fp: &Matrix, y_rt: &Matrix, mu: f32) -> Matrix {
    assert_eq!(y_fp.shape(), y_rt.shape());
    let mut out = y_fp.scale(1.0 - mu);
    out.axpy(mu, y_rt);
    out
}

/// Resolve the absolute λ² used in the Gram matrix. `Relative` scales the
/// knob by the mean diagonal of `X̃ᵀX̃` so the paper's λ ∈ [0.1, 0.8] sweep
/// stays meaningful regardless of activation magnitude.
pub fn lambda_sq_abs(cfg: &QuantConfig, gram_diag_mean: f64) -> f64 {
    let l2 = cfg.lambda * cfg.lambda;
    match cfg.lambda_mode {
        LambdaMode::Absolute => l2,
        LambdaMode::Relative => l2 * gram_diag_mean,
    }
}

/// The assembled per-layer system.
pub struct JtaSystem {
    /// `G = X̃ᵀX̃ + λ²_abs·I` (m×m).
    pub gram: Matrix,
    /// `B = X̃ᵀ·Y*(μ) + λ²_abs·W` (m×n).
    pub rhs: Matrix,
    /// The λ² actually added.
    pub lambda_sq: f64,
}

/// Build the ridged Gram `G = X̃ᵀX̃ + λ²_abs·I` alone — the part of the
/// system that depends only on the runtime activations and `(λ, mode)`,
/// NOT on the weight. Layers sharing a tap point (Q/K/V on `attn_in`,
/// Gate/Up on `mlp_in`) therefore share this matrix, its act-order
/// permutation and its Cholesky factor — see
/// [`crate::quant::FactoredSystem`]. Returns `(gram, lambda_sq,
/// diag_mean)` where `diag_mean` is the pre-ridge mean Gram diagonal.
pub fn build_gram(x_rt: &Matrix, cfg: &QuantConfig) -> (Matrix, f64, f64) {
    let m = x_rt.cols();
    let gram0 = syrk_upper(x_rt, 0.0);
    let diag_mean: f64 =
        (0..m).map(|i| gram0.get(i, i) as f64).sum::<f64>() / m.max(1) as f64;
    let lambda_sq = lambda_sq_abs(cfg, diag_mean);
    let mut gram = gram0;
    for i in 0..m {
        gram.add_at(i, i, lambda_sq as f32);
    }
    (gram, lambda_sq, diag_mean)
}

/// Build the per-layer RHS `B = X̃ᵀ·Y*(μ) + λ²_abs·W` (Eq. 8). `lambda_sq`
/// must be the absolute λ² resolved by [`build_gram`] for the same
/// `x_rt`/`cfg` so the two halves of the normal equations agree.
pub fn build_rhs(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    lambda_sq: f64,
    cfg: &QuantConfig,
) -> Matrix {
    assert_eq!(x_rt.cols(), w.rows());
    // Y*(μ): avoid forming both outputs when μ is at a boundary.
    let mu = cfg.mu as f32;
    let y_star = if mu == 0.0 {
        matmul(x_fp, w)
    } else if mu == 1.0 {
        matmul(x_rt, w)
    } else {
        let y_fp = matmul(x_fp, w);
        let y_rt = matmul(x_rt, w);
        interp_target(&y_fp, &y_rt, mu)
    };
    let mut rhs = gemm_tn(x_rt, &y_star);
    rhs.axpy(lambda_sq as f32, w);
    rhs
}

/// Build `G` and `B` for a layer (Eq. 8's normal equations).
pub fn build_system(w: &Matrix, x_fp: &Matrix, x_rt: &Matrix, cfg: &QuantConfig) -> JtaSystem {
    assert_eq!(x_rt.cols(), w.rows());
    let (gram, lambda_sq, _) = build_gram(x_rt, cfg);
    let rhs = build_rhs(w, x_fp, x_rt, lambda_sq, cfg);
    JtaSystem { gram, rhs, lambda_sq }
}

/// Real-valued (unconstrained) solution `Ŵ_real` of the JTA system given
/// the Cholesky factor `R` of `G`: Algorithm 1 line 3, all RHS at once.
pub fn solve_real(r: &Matrix, rhs: &Matrix) -> Matrix {
    let u = solve_lower_t(r, rhs);
    solve_upper_mat(r, &u)
}

/// The full JTA score `S(Ŵ)` of a candidate dequantized weight (Eq. 7) —
/// used in tests/diagnostics; the solver itself compares candidates in the
/// equivalent q-space residual metric.
pub fn score(
    w_hat: &Matrix,
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
) -> f64 {
    let y_fp = matmul(x_fp, w);
    let y_rt = matmul(x_rt, w);
    let y_star = interp_target(&y_fp, &y_rt, cfg.mu as f32);
    let y_hat = matmul(x_rt, w_hat);
    let gram = syrk_upper(x_rt, 0.0);
    let m = w.rows();
    let diag_mean: f64 = (0..m).map(|i| gram.get(i, i) as f64).sum::<f64>() / m.max(1) as f64;
    let l2 = lambda_sq_abs(cfg, diag_mean);
    y_hat.sub(&y_star).frob_sq() + l2 * w_hat.sub(w).frob_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky_upper;
    use crate::rng::Rng;

    fn cfg(mu: f64, lambda: f64) -> QuantConfig {
        QuantConfig { mu, lambda, ..Default::default() }
    }

    #[test]
    fn interp_boundaries() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(5, 4, 1.0, &mut rng);
        let b = Matrix::randn(5, 4, 1.0, &mut rng);
        assert_eq!(interp_target(&a, &b, 0.0), a);
        assert_eq!(interp_target(&a, &b, 1.0), b);
        let mid = interp_target(&a, &b, 0.5);
        assert!((mid.get(2, 2) - 0.5 * (a.get(2, 2) + b.get(2, 2))).abs() < 1e-6);
    }

    #[test]
    fn lambda_zero_recovers_ls_solution() {
        // With λ=0 and μ=1 the real solution of the system is exactly W
        // (X̃Ŵ = X̃W is solved by Ŵ=W when X̃ has full column rank).
        let mut rng = Rng::new(2);
        let m = 16;
        let w = Matrix::randn(m, 6, 1.0, &mut rng);
        let x = Matrix::randn(64, m, 1.0, &mut rng);
        let sys = build_system(&w, &x, &x, &cfg(1.0, 0.0));
        let r = cholesky_upper(&sys.gram).unwrap();
        let w_real = solve_real(&r, &sys.rhs);
        assert!(w_real.rel_err(&w) < 1e-3, "rel={}", w_real.rel_err(&w));
    }

    #[test]
    fn large_lambda_pins_solution_to_w() {
        // As λ→∞ the drift penalty dominates and Ŵ_real → W even when the
        // activation targets disagree.
        let mut rng = Rng::new(3);
        let m = 12;
        let w = Matrix::randn(m, 4, 1.0, &mut rng);
        let x_fp = Matrix::randn(48, m, 1.0, &mut rng);
        let x_rt = x_fp.map(|v| v + 0.3); // drifted runtime activations
        let sys = build_system(
            &w,
            &x_fp,
            &x_rt,
            &QuantConfig { mu: 0.0, lambda: 100.0, ..Default::default() },
        );
        let r = cholesky_upper(&sys.gram).unwrap();
        let w_real = solve_real(&r, &sys.rhs);
        assert!(w_real.rel_err(&w) < 1e-2, "rel={}", w_real.rel_err(&w));
    }

    #[test]
    fn mu_interpolates_solutions() {
        // The μ=0 and μ=1 solutions differ when X̃ ≠ X; μ=0.5's solution
        // sits between them (linearity of the normal equations in Y*).
        let mut rng = Rng::new(4);
        let m = 10;
        let w = Matrix::randn(m, 3, 1.0, &mut rng);
        let x_fp = Matrix::randn(40, m, 1.0, &mut rng);
        let noise = Matrix::randn(40, m, 0.2, &mut rng);
        let x_rt = x_fp.add(&noise);
        let solve = |mu: f64| {
            let sys = build_system(&w, &x_fp, &x_rt, &cfg(mu, 0.0));
            let r = cholesky_upper(&sys.gram).unwrap();
            solve_real(&r, &sys.rhs)
        };
        let w0 = solve(0.0);
        let w1 = solve(1.0);
        let wm = solve(0.5);
        assert!(w0.rel_err(&w1) > 1e-4, "targets should differ under drift");
        let expect = interp_target(&w0, &w1, 0.5);
        assert!(wm.rel_err(&expect) < 1e-3, "rel={}", wm.rel_err(&expect));
    }

    #[test]
    fn score_special_cases() {
        let mut rng = Rng::new(5);
        let m = 8;
        let w = Matrix::randn(m, 4, 1.0, &mut rng);
        let w_hat = w.map(|v| v + 0.01);
        let x = Matrix::randn(32, m, 1.0, &mut rng);
        // μ=1, λ=0 on identical activations = plain runtime-consistent MSE.
        let s = score(&w_hat, &w, &x, &x, &cfg(1.0, 0.0));
        let direct = matmul(&x, &w_hat).sub(&matmul(&x, &w)).frob_sq();
        assert!((s - direct).abs() / direct.max(1e-12) < 1e-5);
        // Perfect candidate scores ~0.
        assert!(score(&w, &w, &x, &x, &cfg(0.5, 0.3)) < 1e-6);
    }
}
