//! Klein-randomized Babai decoding with K-best selection (paper §3.4,
//! Algorithms 3–4) — "Ours(R)" when paired with the runtime-consistent
//! objective.
//!
//! At each back-substitution step the code is *sampled* from the discrete
//! Gaussian restricted to the box (Eq. 13):
//!
//! `Pr(q_i = v) ∝ exp(−α · r̄ᵢᵢ² · (cᵢ − v)²)`, `v ∈ {0, …, 2^b−1}`
//!
//! with `r̄ᵢᵢ = R(i,i)·s(i)`. (Eq. 13 as printed omits the square on
//! `r̄ᵢᵢ`; we follow Liu–Ling–Stehlé (2011), which the paper cites for its
//! α schedule and where the exponent is `ln(ρ)·r²ᵢᵢ(c−v)²/min r²ᵢᵢ` —
//! dimensionally consistent and reducing to greedy as α → ∞.)
//!
//! The temperature is data-driven: `α = ln(ρ)/min_i r̄ᵢᵢ²` where ρ solves
//! `K = (eρ)^(2m/ρ)` — larger K ⇒ smaller ρ ⇒ more exploration.

use super::babai::{center, decode_greedy, residual_sq};
use super::rtn::round_code;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Solve `K = (eρ)^(2m/ρ)` for ρ on the branch ρ ≥ 1 (where the map is
/// monotone decreasing in ρ), by bisection on
/// `g(ρ) = (2m/ρ)(1 + ln ρ) − ln K`. For K ≤ 1 the root escapes to
/// infinity (pure greedy); we clamp to `RHO_MAX`.
pub fn solve_rho(k: usize, m: usize) -> f64 {
    const RHO_MAX: f64 = 1e9;
    if k <= 1 {
        return RHO_MAX;
    }
    let ln_k = (k as f64).ln();
    let g = |rho: f64| (2.0 * m as f64 / rho) * (1.0 + rho.ln()) - ln_k;
    // g(1) = 2m − ln K > 0 for any sane (K, m); g decreases towards −lnK.
    if g(1.0) <= 0.0 {
        return 1.0;
    }
    let (mut lo, mut hi) = (1.0f64, 2.0f64);
    while g(hi) > 0.0 && hi < RHO_MAX {
        hi *= 2.0;
    }
    if hi >= RHO_MAX {
        return RHO_MAX;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if g(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// The Liu–Ling–Stehlé temperature: `α = ln(ρ(K, m)) / min_i r̄ᵢᵢ²`.
/// `min_rbar_sq` is `min_i (R(i,i)·s(i))²` for the column.
pub fn alpha_for(k: usize, m: usize, min_rbar_sq: f64) -> f64 {
    let rho = solve_rho(k, m);
    let a = rho.ln() / min_rbar_sq.max(1e-30);
    a.max(0.0)
}

/// Sample one code from Eq. 13 given center `c`, squared diagonal
/// `rbar_sq = (R(i,i)·s(i))²`, temperature `alpha`, box `[0, qmax]` and a
/// uniform `u ∈ [0,1)`. Max-subtracted for stability; exactly reproduces
/// greedy rounding as `alpha·rbar_sq → ∞`. This scalar is THE contract
/// shared with the Pallas kernel — both backends implement this formula
/// with the same cumulative-sum tie-breaking so identical uniforms give
/// identical codes.
#[inline]
pub fn sample_code(c: f32, rbar_sq: f32, alpha: f32, qmax: f32, u: f32) -> f32 {
    let n = qmax as usize + 1;
    debug_assert!(n <= 256);
    // Max exponent is at the clamped nearest integer.
    let nearest = round_code(c, qmax);
    let scale = alpha * rbar_sq;
    // Significance window (§Perf): terms with RELATIVE exponent beyond
    // EXP_CUTOFF contribute < e^-30 ≈ 1e-13 of the max weight — far below
    // f32 cumsum resolution — and are treated as exact zeros. The same
    // constant cuts the tail in the Pallas kernel and the numpy oracle,
    // which keeps all three implementations decision-identical even where
    // XLA's flush-to-zero vs libm subnormal handling would diverge.
    // Window radius: relative exponent scale·(dv² − dn²) ≤ 30 ⇔
    // |v − c| ≤ sqrt(30/scale + dn²).
    const EXP_CUTOFF: f32 = 30.0;
    let dn0 = c - nearest;
    let (lo, hi) = if scale > 0.0 && scale.is_finite() {
        let w = (EXP_CUTOFF / scale + dn0 * dn0).sqrt();
        let lo = ((c - w).max(0.0) as usize).min(n - 1).min(nearest as usize);
        let hi = (((c + w).ceil().min(qmax).max(0.0)) as usize)
            .min(n - 1)
            .max(nearest as usize);
        (lo, hi)
    } else {
        (0, n - 1)
    };
    let mut weights = [0.0f32; 256];
    let mut total = 0.0f32;
    let dn = c - nearest;
    for (off, w) in weights[lo..=hi].iter_mut().enumerate() {
        let dv = c - (lo + off) as f32;
        // exponent relative to the max term (≥ 0 difference).
        let ex = -scale * (dv * dv - dn * dn);
        *w = ex.exp();
        total += *w;
    }
    if !(total > 0.0) || !total.is_finite() {
        return nearest;
    }
    let target = u * total;
    let mut acc = 0.0f32;
    for (off, &w) in weights[lo..=hi].iter().enumerate() {
        acc += w;
        if target < acc {
            return (lo + off) as f32;
        }
    }
    hi as f32
}

/// One Klein-randomized decode of a column (Algorithm 3). `uniforms`
/// supplies one `[0,1)` value per row, consumed at row `i` — the explicit
/// form shared with the PPI decoder and the PJRT artifact.
pub fn decode_sampled_with_uniforms(
    r: &Matrix,
    s: &[f32],
    qbar: &[f32],
    qmax: f32,
    alpha: f32,
    uniforms: &[f32],
) -> Vec<f32> {
    let m = r.rows();
    assert_eq!(uniforms.len(), m);
    let mut q = vec![0.0f32; m];
    let mut e = vec![0.0f32; m];
    for i in (0..m).rev() {
        let c = center(r, s, qbar, &e, i, m);
        let rbar = r.get(i, i) * s[i];
        let qi = sample_code(c, rbar * rbar, alpha, qmax, uniforms[i]);
        q[i] = qi;
        e[i] = s[i] * (qbar[i] - qi);
    }
    q
}

/// Convenience wrapper drawing uniforms from an [`Rng`].
pub fn decode_sampled(
    r: &Matrix,
    s: &[f32],
    qbar: &[f32],
    qmax: f32,
    alpha: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    let m = r.rows();
    let uniforms = rng.uniform_vec_f32(m);
    decode_sampled_with_uniforms(r, s, qbar, qmax, alpha, &uniforms)
}

/// K-best randomized decoding (Algorithm 4): the greedy Babai point plus
/// `k` independent Klein traces; returns the minimum-residual candidate
/// and its residual. Reference implementation — the production hot path
/// is the tiled [`super::ppi`] decoder.
pub fn decode_kbest(
    r: &Matrix,
    s: &[f32],
    qbar: &[f32],
    qmax: f32,
    k: usize,
    rng: &mut Rng,
) -> (Vec<f32>, f64) {
    let m = r.rows();
    let min_rbar_sq = (0..m)
        .map(|i| {
            let v = r.get(i, i) as f64 * s[i] as f64;
            v * v
        })
        .fold(f64::INFINITY, f64::min);
    let alpha = alpha_for(k.max(1), m, min_rbar_sq) as f32;
    // Reserved greedy path guarantees the Babai point is in the set.
    let mut best = decode_greedy(r, s, qbar, qmax);
    let mut best_res = residual_sq(r, s, qbar, &best);
    for _ in 0..k {
        let cand = decode_sampled(r, s, qbar, qmax, alpha, rng);
        let res = residual_sq(r, s, qbar, &cand);
        if res < best_res {
            best_res = res;
            best = cand;
        }
    }
    (best, best_res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cholesky_upper, syrk_upper};

    fn setup(m: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        // Mildly ill-conditioned Gram so Babai is beatable.
        let a = Matrix::randn(m + 2, m, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.05);
        let r = cholesky_upper(&g).unwrap();
        let s: Vec<f32> = (0..m).map(|_| 0.05 + 0.2 * rng.uniform_f32()).collect();
        let qbar: Vec<f32> = (0..m).map(|_| 15.0 * rng.uniform_f32()).collect();
        (r, s, qbar)
    }

    #[test]
    fn rho_monotone_decreasing_in_k() {
        let m = 128;
        let r5 = solve_rho(5, m);
        let r10 = solve_rho(10, m);
        let r50 = solve_rho(50, m);
        assert!(r5 > r10 && r10 > r50, "{r5} {r10} {r50}");
        assert!(r50 >= 1.0);
    }

    #[test]
    fn rho_satisfies_equation() {
        let (k, m) = (8usize, 64usize);
        let rho = solve_rho(k, m);
        let lhs = (k as f64).ln();
        let rhs = (2.0 * m as f64 / rho) * (1.0 + rho.ln());
        assert!((lhs - rhs).abs() < 1e-6, "lhs={lhs} rhs={rhs}");
    }

    #[test]
    fn k1_is_effectively_greedy() {
        // ρ(K=1) clamps to RHO_MAX, so sharpness is maximal and strictly
        // above any K>1 setting; sampling then matches greedy rounding
        // except within a vanishing band around half-integers.
        let a1 = alpha_for(1, 64, 0.01);
        let a5 = alpha_for(5, 64, 0.01);
        let a50 = alpha_for(50, 64, 0.01);
        assert!(a1 > a5 && a5 > a50, "{a1} {a5} {a50}");
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let c = 15.0 * rng.uniform_f32();
            if (c.fract() - 0.5).abs() < 0.05 {
                continue; // skip the half-integer rounding boundary band
            }
            let v = sample_code(c, 0.01, a1 as f32, 15.0, rng.uniform_f32());
            assert_eq!(v, round_code(c, 15.0), "c={c}");
        }
    }

    #[test]
    fn sample_code_greedy_limit() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let c = 15.0 * rng.uniform_f32();
            let u = rng.uniform_f32();
            let v = sample_code(c, 1.0, 1e9, 15.0, u);
            assert_eq!(v, round_code(c, 15.0), "c={c} u={u}");
        }
    }

    #[test]
    fn sample_code_distribution_matches_eq13() {
        // Empirical frequencies vs the analytic distribution at moderate
        // temperature.
        let (c, rbar_sq, alpha, qmax) = (6.3f32, 1.0f32, 0.8f32, 15.0f32);
        let n = qmax as usize + 1;
        let probs: Vec<f64> = {
            let w: Vec<f64> = (0..n)
                .map(|v| (-(alpha * rbar_sq) as f64 * ((c - v as f32) as f64).powi(2)).exp())
                .collect();
            let t: f64 = w.iter().sum();
            w.into_iter().map(|x| x / t).collect()
        };
        let mut rng = Rng::new(2);
        let trials = 60_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            counts[sample_code(c, rbar_sq, alpha, qmax, rng.uniform_f32()) as usize] += 1;
        }
        for v in 0..n {
            let emp = counts[v] as f64 / trials as f64;
            assert!(
                (emp - probs[v]).abs() < 0.01,
                "v={v} emp={emp:.4} analytic={:.4}",
                probs[v]
            );
        }
    }

    /// Full-range reference sampler (no significance window) — the
    /// pre-optimization semantics the windowed fast path must preserve.
    fn sample_code_full(c: f32, rbar_sq: f32, alpha: f32, qmax: f32, u: f32) -> f32 {
        let n = qmax as usize + 1;
        let nearest = round_code(c, qmax);
        let mut weights = [0.0f32; 256];
        let mut total = 0.0f32;
        let scale = alpha * rbar_sq;
        for (v, w) in weights.iter_mut().take(n).enumerate() {
            let dv = c - v as f32;
            let dn = c - nearest;
            *w = (-scale * (dv * dv - dn * dn)).exp();
            total += *w;
        }
        if !(total > 0.0) || !total.is_finite() {
            return nearest;
        }
        let target = u * total;
        let mut acc = 0.0f32;
        for (v, &w) in weights.iter().take(n).enumerate() {
            acc += w;
            if target < acc {
                return v as f32;
            }
        }
        qmax
    }

    /// §Perf regression guard: the significance-window fast path must be
    /// equivalent to the full enumeration across the whole (c, scale, u)
    /// envelope — including half-integer centers at high sharpness, the
    /// case that originally exposed a floor-vs-ceil window bug.
    #[test]
    fn windowed_sampler_equals_full_enumeration() {
        let mut rng = Rng::new(0x5EED5);
        for _ in 0..100_000 {
            let c = 18.0 * rng.uniform_f32() - 1.5;
            let scale = (10.0f32).powf(4.0 * rng.uniform_f32() - 1.0); // 0.1..1e3
            // Avoid the measure-zero u≈0 / u≈1 boundaries where the
            // deliberately-dropped e^-30 tail mass can flip the pick.
            let u = 1e-6 + (1.0 - 2e-6) * rng.uniform_f32();
            let a = sample_code(c, 1.0, scale, 15.0, u);
            let b = sample_code_full(c, 1.0, scale, 15.0, u);
            assert_eq!(a, b, "c={c} scale={scale} u={u}");
        }
    }

    #[test]
    fn sampled_codes_respect_box() {
        let (r, s, qbar) = setup(32, 3);
        let mut rng = Rng::new(4);
        let q = decode_sampled(&r, &s, &qbar, 7.0, 0.5, &mut rng);
        for &v in &q {
            assert!((0.0..=7.0).contains(&v) && v.fract() == 0.0);
        }
    }

    #[test]
    fn kbest_never_worse_than_greedy() {
        for seed in 0..10 {
            let (r, s, qbar) = setup(32, 50 + seed);
            let greedy = decode_greedy(&r, &s, &qbar, 15.0);
            let greedy_res = residual_sq(&r, &s, &qbar, &greedy);
            let mut rng = Rng::new(seed);
            let (_, best_res) = decode_kbest(&r, &s, &qbar, 15.0, 5, &mut rng);
            assert!(
                best_res <= greedy_res + 1e-9,
                "seed={seed} kbest {best_res} vs greedy {greedy_res}"
            );
        }
    }

    #[test]
    fn kbest_residual_monotone_in_k_on_average() {
        // Property from the paper's Fig. 2: more candidates => better
        // residual (on average; individual seeds share the greedy floor).
        let mut tot1 = 0.0;
        let mut tot5 = 0.0;
        let mut tot25 = 0.0;
        for seed in 0..12 {
            let (r, s, qbar) = setup(48, 200 + seed);
            let mut rng1 = Rng::new(seed);
            let mut rng5 = Rng::new(seed);
            let mut rng25 = Rng::new(seed);
            tot1 += decode_kbest(&r, &s, &qbar, 15.0, 1, &mut rng1).1;
            tot5 += decode_kbest(&r, &s, &qbar, 15.0, 5, &mut rng5).1;
            tot25 += decode_kbest(&r, &s, &qbar, 15.0, 25, &mut rng25).1;
        }
        assert!(tot5 <= tot1 + 1e-9, "K=5 {tot5} should beat K=1 {tot1}");
        assert!(tot25 <= tot5 + 1e-9, "K=25 {tot25} should beat K=5 {tot5}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (r, s, qbar) = setup(24, 9);
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let qa = decode_kbest(&r, &s, &qbar, 15.0, 5, &mut a);
        let qb = decode_kbest(&r, &s, &qbar, 15.0, 5, &mut b);
        assert_eq!(qa.0, qb.0);
        assert_eq!(qa.1, qb.1);
    }
}
