//! Quantization solver library — the paper's contribution plus every
//! baseline it compares against, implemented from scratch on the same
//! substrate so comparisons are apples-to-apples.
//!
//! Solvers (paper Table 1 rows):
//! * [`rtn`] — round-to-nearest (naive baseline).
//! * [`gptq`] — compensation-based sequential solver (Frantar et al.),
//!   with optional activation ordering.
//! * [`awq`] — activation-aware weight scaling + RTN (Lin et al.).
//! * [`quip`] — incoherence processing via random orthogonal rotations +
//!   LDLQ-style greedy decoding (Chee et al.).
//! * [`babai`] — box-constrained Babai nearest-plane decoding = "Ours(N)".
//! * [`klein`] — Klein-randomized Babai with K-best selection = "Ours(R)".
//! * [`ojbkq`] — Random-K Babai/Klein under the JTA objective = "Ours".
//!
//! Shared plumbing: [`scales`] (group-wise scale/zero calibration),
//! [`qtensor`] (packed integer weight storage), [`jta`] (the Joint Target
//! Alignment objective, Eq. 6–8), [`ppi`] (the Parallel Path-Isolated
//! K-best decoder of Appendix A — the performance-critical hot path,
//! mirrored by the Pallas kernel at `python/compile/kernels/`).

pub mod admmq;
pub mod awq;
pub mod babai;
pub mod factored;
pub mod gptq;
pub mod jta;
pub mod klein;
pub mod ojbkq;
pub mod quantease;
pub mod ppi;
pub mod qgemm;
pub mod qtensor;
pub mod quip;
pub mod rtn;
pub mod scales;
pub mod sphere;

pub use factored::{FactorKind, FactoredSystem};
pub use qtensor::QuantizedLinear;
pub use quantease::IterStats;
pub use scales::GroupScales;

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Which solver quantizes a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// FP reference (no quantization) — the BF16 row of the tables.
    Fp,
    /// Round-to-nearest.
    Rtn,
    /// GPTQ-style error compensation.
    Gptq,
    /// AWQ-style activation-aware scaling.
    Awq,
    /// QuIP-style incoherence rotation + greedy decode.
    Quip,
    /// Ours(N): box-constrained Babai nearest-plane.
    BabaiNaive,
    /// Ours(R): Random-K Babai/Klein, runtime-consistent objective.
    KleinRandomK,
    /// Ours: Random-K Babai/Klein + JTA objective.
    Ojbkq,
    /// QEP-style corrective patch (Arai & Ichikawa 2025): the paper's
    /// Eq. 4 corner of JTA — runtime activations, full-precision
    /// reference (μ=0, λ=0) — with Random-K decoding. Standalone
    /// [`quantize_layer`] calls use the true `X` reference; in-pipeline
    /// the FP tap cache is skipped and the runtime taps stand in for it
    /// (ROADMAP capture optimization — see [`skip_fp_reference`]), which
    /// realizes the self-referential target `X̃W` instead.
    Qep,
    /// QuantEase-style cyclic coordinate descent (Behdin et al.): exact
    /// rank-1 objective updates from the shared Gram, Babai/Klein
    /// solution as warm start, convergence-tracked sweeps
    /// ([`quantease`]).
    QuantEase,
    /// ADMM-Q (Lucas et al.): ADMM splitting between the continuous
    /// Hessian-weighted least-squares subproblem and the
    /// box-constrained integer projection, with residual-balancing
    /// penalty adaptation ([`admmq`]).
    AdmmQ,
}

impl Method {
    /// All methods in the paper's table order.
    pub fn all() -> &'static [Method] {
        &[
            Method::Rtn,
            Method::Gptq,
            Method::Awq,
            Method::Quip,
            Method::BabaiNaive,
            Method::KleinRandomK,
            Method::Ojbkq,
            Method::QuantEase,
            Method::AdmmQ,
        ]
    }

    /// Paper label.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Fp => "BF16",
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::Quip => "QUIP",
            Method::BabaiNaive => "Ours(N)",
            Method::KleinRandomK => "Ours(R)",
            Method::Ojbkq => "Ours",
            Method::Qep => "QEP",
            Method::QuantEase => "QuantEase",
            Method::AdmmQ => "ADMM-Q",
        }
    }

    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fp" | "bf16" | "fp32" => Method::Fp,
            "rtn" => Method::Rtn,
            "gptq" => Method::Gptq,
            "awq" => Method::Awq,
            "quip" => Method::Quip,
            "babai" | "ours-n" | "ours(n)" => Method::BabaiNaive,
            "klein" | "ours-r" | "ours(r)" => Method::KleinRandomK,
            "ojbkq" | "ours" => Method::Ojbkq,
            "qep" => Method::Qep,
            "quantease" | "qe" => Method::QuantEase,
            "admm-q" | "admmq" | "admm" => Method::AdmmQ,
            _ => return None,
        })
    }
}

/// Which backend executes the K-path decode hot loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Hand-optimized Rust ([`ppi`]).
    Native,
    /// AOT-compiled Pallas kernel through PJRT ([`crate::runtime`]).
    Pjrt,
}

/// Layer-wise μ scheduling — the paper's Limitations section names
/// per-layer adaptive (μ, λ) as future work; [`MuSchedule::DepthLinear`]
/// implements the natural first instance: interpolate μ with network
/// depth (early layers see little activation drift so the target choice
/// barely matters; deep layers accumulate drift and benefit from leaning
/// on the runtime-consistent reference). Ablated in
/// `rust/benches/ablation_design.rs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MuSchedule {
    /// Use `QuantConfig::mu` for every layer.
    Fixed,
    /// μ(depth) = start + (end − start) · block/(n_blocks−1).
    DepthLinear { start: f64, end: f64 },
}

/// How the JTA `λ` is interpreted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LambdaMode {
    /// `λ_abs² = λ² · mean(diag(X̃ᵀX̃))` — scale-free, default. The paper
    /// sweeps λ ∈ [0.1, 0.8] against LLM activations; relative mode keeps
    /// that range meaningful on our synthetic substrate.
    Relative,
    /// Use λ as given.
    Absolute,
}

/// Full quantization configuration (paper defaults: Table 1 setup).
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Weight bit-width (paper: 3 or 4).
    pub wbit: u8,
    /// Rows per scale group; 0 = one group per column (paper "g=0").
    pub group_size: usize,
    /// Number of Klein-randomized paths K (paper default 5); the greedy
    /// Babai path is always reserved in addition.
    pub k: usize,
    /// JTA interpolation knob μ ∈ [0,1] (Eq. 6).
    pub mu: f64,
    /// Optional per-layer μ schedule (overrides `mu` when not Fixed).
    pub mu_schedule: MuSchedule,
    /// JTA weight-drift regularizer λ (Eq. 7).
    pub lambda: f64,
    /// Interpretation of λ.
    pub lambda_mode: LambdaMode,
    /// GPTQ activation ordering (paper enables it for the baseline).
    pub act_order: bool,
    /// Decode backend for the OJBKQ family.
    pub backend: Backend,
    /// Column tile width fed to the PPI decoder / PJRT artifact.
    pub ntile: usize,
    /// PPI look-ahead block size B (Appendix A, Algorithm 2).
    pub block: usize,
    /// Base RNG seed (forked per layer/column for determinism under
    /// parallel execution).
    pub seed: u64,
    /// Execute the progressively-quantized runtime model through the
    /// packed integer kernels of [`crate::infer`] (default). When false,
    /// the pipeline splices dense dequantized f32 weights as before —
    /// the numerically bit-identical legacy mode, kept selectable for
    /// capture-equivalence tests and A/B CI runs (`OJBKQ_DENSE_EXEC=1`
    /// flips the default).
    pub packed_exec: bool,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            wbit: 4,
            group_size: 128,
            k: 5,
            // Paper: (μ=0.1, λ=0.2) for 4-bit, (0.6, 0.6) for 3-bit.
            mu: 0.1,
            mu_schedule: MuSchedule::Fixed,
            lambda: 0.2,
            lambda_mode: LambdaMode::Relative,
            act_order: true,
            backend: Backend::Native,
            ntile: 64,
            block: 16,
            seed: 0xBABA1,
            packed_exec: !matches!(
                std::env::var("OJBKQ_DENSE_EXEC").as_deref(),
                Ok("1") | Ok("true") | Ok("yes")
            ),
        }
    }
}

impl QuantConfig {
    /// Paper defaults per bit-width (§4 Ablations).
    pub fn paper_defaults(wbit: u8, group_size: usize) -> QuantConfig {
        let (mu, lambda) = if wbit <= 3 { (0.6, 0.6) } else { (0.1, 0.2) };
        QuantConfig { wbit, group_size, mu, lambda, ..QuantConfig::default() }
    }

    /// Max integer code value `2^wbit - 1`.
    pub fn box_max(&self) -> u8 {
        (1u16 << self.wbit).saturating_sub(1).min(255) as u8
    }

    /// Effective group size for an `m`-row weight (0 → whole column).
    pub fn effective_group(&self, m: usize) -> usize {
        if self.group_size == 0 || self.group_size > m {
            m
        } else {
            self.group_size
        }
    }
}

/// True when the pipeline may skip streaming the full-precision reference
/// tap cache for `(method, cfg)` — the ROADMAP's "reuse runtime captures
/// for the FP reference" item, which halves calibration capture cost
/// (one resident hidden-state cache instead of two).
///
/// This holds at the QEP corner `(μ=0, λ=0)` — [`Method::Qep`], or
/// [`Method::Ojbkq`] configured onto that corner — where the pipeline
/// substitutes the runtime taps for the reference (`X := X̃`), realizing
/// the self-referential corner `‖X̃Ŵ − X̃W‖²` in place of Eq. 4's
/// mismatch target. Standalone [`quantize_layer`] calls with an explicit
/// `x_fp` are unaffected. Schedules that vary μ across depth never
/// qualify.
///
/// Side effect on diagnostics: with the FP cache skipped, per-layer
/// [`LayerStats`] are computed against the runtime taps too, so
/// `out_norm` reports `‖X̃W‖_F` rather than `‖XW‖_F` at this corner
/// (the two drift apart with depth).
pub fn skip_fp_reference(method: Method, cfg: &QuantConfig) -> bool {
    if method == Method::Qep {
        return true;
    }
    matches!(cfg.mu_schedule, MuSchedule::Fixed) && cfg.mu == 0.0 && cfg.lambda == 0.0
}

/// Per-layer quantization diagnostics, used by Figure-1-style reporting
/// and the coordinator's metrics stream (`trace.json` layer records).
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// `||X̃·Ŵ − Y*(μ)||_F` — the JTA reconstruction error (Fig. 1).
    pub jta_err: f64,
    /// `||X̃·Ŵ − X̃·W||_F` — runtime-consistent proxy error (Eq. 1).
    pub rt_err: f64,
    /// `||X·W||_F` — the original output norm (Fig. 1 reference line).
    /// Under the QEP-corner capture skip ([`skip_fp_reference`]) the
    /// pipeline substitutes runtime taps, making this `||X̃·W||_F`.
    pub out_norm: f64,
    /// Wall-clock seconds spent in the solver.
    pub solve_secs: f64,
    /// Wall-clock seconds of calibration capture attributed to this layer
    /// (its group's activation refresh, split evenly across the group).
    /// Filled in by the pipeline coordinator; 0 for standalone solves.
    pub capture_secs: f64,
    /// Winning lattice residual Σ_cols `‖R(s⊙(q−q̄))‖²` from the decode
    /// (OJBKQ family, native backend; 0 otherwise).
    pub decode_resid: f64,
    /// Same sum under greedy Babai only — what K=0 would have scored.
    pub greedy_resid: f64,
    /// Weight columns decoded by the Babai/Klein solver (0 for other
    /// methods).
    pub cols: u64,
    /// Klein paths sampled (K·cols; greedy not counted).
    pub klein_samples: u64,
    /// Columns where a sampled path beat greedy Babai.
    pub klein_improved: u64,
    /// Fraction of emitted codes saturated at a box bound
    /// (0 or `2^wbit − 1`). 0 for FP passthrough layers (no codes).
    pub clip_rate: f64,
    /// Code-histogram occupancy: distinct code values used / `2^wbit`.
    pub occupancy: f64,
    /// True when this layer was produced by the degradation ladder's
    /// RTN fallback (shared-factor Cholesky failed after jitter
    /// escalation) rather than the requested method. Recorded in
    /// `trace.json` as the `layer.fallback` metric.
    pub fallback: bool,
}

impl LayerStats {
    /// Fraction of columns where Klein sampling improved on greedy
    /// Babai (0 when the layer wasn't solved by the OJBKQ family).
    pub fn klein_improvement_rate(&self) -> f64 {
        if self.cols == 0 {
            0.0
        } else {
            self.klein_improved as f64 / self.cols as f64
        }
    }
}

/// Code-distribution diagnostics: `(clip_rate, occupancy)` over the
/// packed code array. Empty codes (FP passthrough) report `(0, 0)`.
fn code_histogram_stats(codes: &[u8], wbit: u8) -> (f64, f64) {
    if codes.is_empty() || wbit == 0 {
        return (0.0, 0.0);
    }
    let qmax = ((1u16 << wbit) - 1).min(255) as u8;
    let mut seen = [false; 256];
    let mut clipped = 0u64;
    for &c in codes {
        seen[c as usize] = true;
        if c == 0 || c == qmax {
            clipped += 1;
        }
    }
    let distinct = seen.iter().filter(|&&s| s).count();
    (clipped as f64 / codes.len() as f64, distinct as f64 / (qmax as f64 + 1.0))
}

/// Uniform entry point: quantize one linear layer.
///
/// * `w` — full-precision weight, `m×n` (inputs × outputs, `y = xW`).
/// * `x_fp` — full-precision calibration activations, `p×m`.
/// * `x_rt` — runtime activations from the partially-quantized prefix.
///
/// Returns the quantized layer and diagnostics. Deterministic given
/// `cfg.seed` and a `layer_id` (used to fork RNG streams).
pub fn quantize_layer(
    method: Method,
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    layer_id: u64,
    rt: Option<&crate::runtime::SolverRuntime>,
) -> anyhow::Result<(QuantizedLinear, LayerStats)> {
    quantize_layer_shared(method, w, x_fp, x_rt, cfg, layer_id, rt, None)
}

/// Resolve the per-method config variant the solver actually sees
/// ([`ojbkq::variant_naive`] / [`ojbkq::variant_random_k`] /
/// [`ojbkq::variant_qep`] for the OJBKQ family, identity otherwise).
/// [`FactoredSystem::for_method`] applies the same mapping so shared
/// factors are built under exactly the config the solver decodes with.
pub fn solver_cfg(method: Method, cfg: &QuantConfig) -> QuantConfig {
    match method {
        Method::BabaiNaive => ojbkq::variant_naive(cfg),
        Method::KleinRandomK => ojbkq::variant_random_k(cfg),
        Method::Qep => ojbkq::variant_qep(cfg),
        _ => cfg.clone(),
    }
}

/// [`quantize_layer`] with an optional shared per-tap-point
/// factorization ([`FactoredSystem`]): layers of one tap group (Q/K/V,
/// Gate/Up) see identical runtime activations, so the coordinator builds
/// the Gram/act-order/Cholesky factor once and passes it to every layer
/// of the group. `shared = None` rebuilds the factor per layer —
/// bit-identical output either way (pinned by
/// `tests/solver_parallel.rs`). Methods without a shareable factor
/// ignore the argument.
#[allow(clippy::too_many_arguments)]
pub fn quantize_layer_shared(
    method: Method,
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    layer_id: u64,
    rt: Option<&crate::runtime::SolverRuntime>,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<(QuantizedLinear, LayerStats)> {
    assert_eq!(x_fp.cols(), w.rows(), "activation/weight shape mismatch");
    assert_eq!(x_rt.cols(), w.rows(), "runtime activation/weight shape mismatch");
    let mut rng = Rng::new(cfg.seed).fork(layer_id);
    let scfg = solver_cfg(method, cfg);
    // One timing source: `obs::timed` both feeds `solve_secs` (always)
    // and the `solve` span (when tracing is on).
    let (solved, solve_secs) = crate::obs::timed("solve", || {
        Ok::<_, anyhow::Error>(match method {
            Method::Fp => {
                (QuantizedLinear::identity(w), ojbkq::DecodeDiag::default(), None)
            }
            Method::Rtn => (rtn::quantize(w, &scfg), ojbkq::DecodeDiag::default(), None),
            Method::Gptq => (
                gptq::quantize_with(w, x_rt, &scfg, shared)?,
                ojbkq::DecodeDiag::default(),
                None,
            ),
            Method::Awq => (awq::quantize(w, x_rt, &scfg), ojbkq::DecodeDiag::default(), None),
            Method::Quip => {
                (quip::quantize(w, x_rt, &scfg, &mut rng)?, ojbkq::DecodeDiag::default(), None)
            }
            Method::BabaiNaive | Method::KleinRandomK | Method::Ojbkq | Method::Qep => {
                let (q, d) =
                    ojbkq::quantize_with_diag(w, x_fp, x_rt, &scfg, &mut rng, rt, shared)?;
                (q, d, None)
            }
            Method::QuantEase => {
                let (q, it) =
                    quantease::quantize_with(w, x_fp, x_rt, &scfg, &mut rng, rt, shared)?;
                (q, ojbkq::DecodeDiag::default(), Some(it))
            }
            Method::AdmmQ => {
                let (q, it) =
                    admmq::quantize_with(w, x_fp, x_rt, &scfg, &mut rng, rt, shared)?;
                (q, ojbkq::DecodeDiag::default(), Some(it))
            }
        })
    });
    let (q, diag, iter) = solved?;
    let mut stats = layer_stats(&q, w, x_fp, x_rt, cfg, solve_secs);
    stats.decode_resid = diag.decode_resid;
    stats.greedy_resid = diag.greedy_resid;
    stats.cols = diag.cols;
    stats.klein_samples = diag.sampled_paths;
    stats.klein_improved = diag.improved_cols;
    if let Some(it) = &iter {
        // The iterative families report through the same residual
        // columns: `f(q) − f(w_real)` IS the lattice residual
        // `‖R(s⊙(q−q̄))‖²` the decode family sums, and the init residual
        // plays greedy's "what the warm start alone scored" role.
        stats.decode_resid = it.resid();
        stats.greedy_resid = it.init_resid();
        stats.cols = w.cols() as u64;
        record_iter_metrics(it);
    }
    // Solve→pack boundary guard: a non-finite solve output (NaN-poisoned
    // weights or activations that slipped past the upstream guards)
    // becomes a structured per-layer error here instead of packing
    // garbage codes into the checkpoint.
    if !stats.rt_err.is_finite() || !stats.jta_err.is_finite() {
        return Err(crate::robust::RobustError::new(
            "coordinator.solve",
            "non-finite solve output (rt_err/jta_err)",
        )
        .with_context(format!("layer uid {layer_id}, method {}", method.label()))
        .into());
    }
    record_layer_metrics(&q, &stats);
    Ok((q, stats))
}

/// Drain one iterative solve's convergence record into the
/// [`crate::obs`] registry (no-op when tracing is disabled): sweep
/// counts and the total objective decrease the sweeps bought over the
/// warm start.
fn record_iter_metrics(it: &IterStats) {
    use crate::obs;
    if !obs::enabled() {
        return;
    }
    obs::counter_add("quant.sweeps", it.iters);
    obs::hist_record("layer.sweeps", it.iters as f64);
    obs::hist_record("layer.obj_delta", it.init_obj - it.final_obj());
}

/// Drain one layer's stats into the [`crate::obs`] registry (no-op when
/// tracing is disabled).
fn record_layer_metrics(q: &QuantizedLinear, stats: &LayerStats) {
    use crate::obs;
    if !obs::enabled() {
        return;
    }
    obs::counter_add("quant.layers", 1);
    obs::counter_add("quant.cols", stats.cols);
    obs::counter_add("quant.klein_samples", stats.klein_samples);
    obs::counter_add("quant.klein_improved", stats.klein_improved);
    obs::counter_add("quant.codes", q.codes.len() as u64);
    obs::counter_add(
        "quant.clipped_codes",
        (stats.clip_rate * q.codes.len() as f64).round() as u64,
    );
    obs::hist_record("layer.rt_err", stats.rt_err);
    obs::hist_record("layer.jta_err", stats.jta_err);
    obs::hist_record("layer.decode_resid", stats.decode_resid);
    obs::hist_record("layer.clip_rate", stats.clip_rate);
    obs::hist_record("layer.occupancy", stats.occupancy);
    obs::hist_record("layer.solve_secs", stats.solve_secs);
}

/// Compute diagnostics for a quantized layer.
pub fn layer_stats(
    q: &QuantizedLinear,
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    solve_secs: f64,
) -> LayerStats {
    use crate::linalg::matmul;
    let w_hat = q.dequantize();
    let y_fp = matmul(x_fp, w);
    let y_rt = matmul(x_rt, w);
    let y_hat = matmul(x_rt, &w_hat);
    let y_star = jta::interp_target(&y_fp, &y_rt, cfg.mu as f32);
    let (clip_rate, occupancy) = code_histogram_stats(&q.codes, q.wbit);
    LayerStats {
        jta_err: y_hat.sub(&y_star).frob(),
        rt_err: y_hat.sub(&y_rt).frob(),
        out_norm: y_fp.frob(),
        solve_secs,
        capture_secs: 0.0,
        decode_resid: 0.0,
        greedy_resid: 0.0,
        cols: 0,
        klein_samples: 0,
        klein_improved: 0,
        clip_rate,
        occupancy,
        fallback: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for &m in Method::all() {
            assert_eq!(Method::parse(&m.label().to_ascii_lowercase()), Some(m), "{m:?}");
        }
        assert_eq!(Method::parse("nope"), None);
        assert_eq!(Method::parse("ours"), Some(Method::Ojbkq));
    }

    #[test]
    fn config_box_max_and_groups() {
        let c3 = QuantConfig { wbit: 3, ..Default::default() };
        assert_eq!(c3.box_max(), 7);
        let c4 = QuantConfig { wbit: 4, ..Default::default() };
        assert_eq!(c4.box_max(), 15);
        let g0 = QuantConfig { group_size: 0, ..Default::default() };
        assert_eq!(g0.effective_group(300), 300);
        assert_eq!(c4.effective_group(300), 128);
        assert_eq!(c4.effective_group(64), 64);
    }

    #[test]
    fn fp_reference_skip_matches_qep_corner() {
        let corner = QuantConfig { mu: 0.0, lambda: 0.0, ..Default::default() };
        assert!(skip_fp_reference(Method::Ojbkq, &corner));
        assert!(skip_fp_reference(Method::Rtn, &corner));
        // Method::Qep pins (μ=0, λ=0) itself, whatever the config says.
        assert!(skip_fp_reference(Method::Qep, &QuantConfig::default()));
        // Any interpolation or drift penalty needs the true FP reference.
        assert!(!skip_fp_reference(Method::Ojbkq, &QuantConfig::default()));
        assert!(!skip_fp_reference(
            Method::Ojbkq,
            &QuantConfig { mu: 0.0, lambda: 0.1, ..Default::default() }
        ));
        assert!(!skip_fp_reference(
            Method::Ojbkq,
            &QuantConfig {
                mu: 0.0,
                lambda: 0.0,
                mu_schedule: MuSchedule::DepthLinear { start: 0.0, end: 1.0 },
                ..Default::default()
            }
        ));
    }

    #[test]
    fn code_histogram_stats_counts_clips_and_occupancy() {
        let (clip, occ) = code_histogram_stats(&[0, 7, 3, 3], 3);
        assert!((clip - 0.5).abs() < 1e-12); // 0 and 7 are the W3 bounds
        assert!((occ - 3.0 / 8.0).abs() < 1e-12); // {0,3,7} of 8 codes
        // FP passthrough: no codes, no stats.
        assert_eq!(code_histogram_stats(&[], 4), (0.0, 0.0));
        let (clip, occ) = code_histogram_stats(&[5; 10], 4);
        assert_eq!(clip, 0.0);
        assert!((occ - 1.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn paper_defaults_per_bitwidth() {
        let c3 = QuantConfig::paper_defaults(3, 128);
        assert_eq!((c3.mu, c3.lambda), (0.6, 0.6));
        let c4 = QuantConfig::paper_defaults(4, 128);
        assert_eq!((c4.mu, c4.lambda), (0.1, 0.2));
    }
}
