//! The OJBKQ layer solver — end-to-end per-layer quantization
//! (paper Algorithms 1, 3, 4 + Appendix A), orchestrating:
//!
//! 1. scale/zero calibration (§3.2),
//! 2. the JTA normal-equation system `G = X̃ᵀX̃+λ²I`, `B = X̃ᵀY*+λ²W`,
//! 3. Cholesky `G = RᵀR` (jittered if near-singular) — *no inverse*,
//! 4. the real-valued solution `Ŵ_real` via triangular solves and its
//!    code-space image `Q̄ = Ŵ_real ⊘ S + Z`,
//! 5. tiled Random-K Babai/Klein decoding (native PPI or the AOT Pallas
//!    artifact via PJRT), selecting the minimum-residual candidate.
//!
//! The paper's three reported variants are configuration points:
//! * **Ours(N)** — [`variant_naive`]: K=0 (greedy only), μ=1, λ=0.
//! * **Ours(R)** — [`variant_random_k`]: K>0, μ=1, λ=0.
//! * **Ours** — the given `(K, μ, λ)` (paper defaults per bit-width).

use super::factored::{FactorKind, FactoredSystem};
use super::klein::alpha_for;
use super::ppi::{decode_tile, PpiInput, PpiOutput};
use super::scales::{self, GroupScales};
use super::{jta, Backend, QuantConfig, QuantizedLinear};
use crate::parallel::parallel_map;
use crate::rng::Rng;
use crate::runtime::SolverRuntime;
use crate::tensor::Matrix;

/// Aggregated decode diagnostics for one layer — the measured
/// Babai/Klein sampling behavior the observability stack surfaces
/// (`layer.decode_resid`, `quant.klein_improved`, Fig. 2's sampling
/// columns). Zeroed for the PJRT backend, whose artifact returns codes
/// only.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DecodeDiag {
    /// Σ over columns of the winning `‖R(s⊙(q−q̄))‖²` — the lattice
    /// proxy for the layer's objective residual.
    pub decode_resid: f64,
    /// Same sum restricted to the greedy Babai path (path 0), i.e. what
    /// the residual would have been with K=0.
    pub greedy_resid: f64,
    /// Columns decoded.
    pub cols: u64,
    /// Columns where a Klein-sampled path beat greedy Babai
    /// (`winner != 0`).
    pub improved_cols: u64,
    /// Klein paths sampled (`K · cols`; the reserved greedy path is not
    /// counted as a sample).
    pub sampled_paths: u64,
}

impl DecodeDiag {
    fn absorb(&mut self, out: &PpiOutput, k: usize) {
        let width = out.resid.len();
        self.cols += width as u64;
        self.sampled_paths += (k * width) as u64;
        for j in 0..width {
            self.decode_resid += out.resid[j];
            self.greedy_resid += out.path_resids.get(0, j) as f64;
            if out.winner[j] != 0 {
                self.improved_cols += 1;
            }
        }
    }

    /// Fraction of columns where sampling improved on greedy Babai.
    pub fn improvement_rate(&self) -> f64 {
        if self.cols == 0 {
            0.0
        } else {
            self.improved_cols as f64 / self.cols as f64
        }
    }
}

/// Ours(N): deterministic box-constrained Babai under the
/// runtime-consistent objective (Eq. 1).
pub fn variant_naive(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { k: 0, mu: 1.0, lambda: 0.0, ..cfg.clone() }
}

/// Ours(R): Random-K Babai/Klein under the runtime-consistent objective.
pub fn variant_random_k(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { mu: 1.0, lambda: 0.0, ..cfg.clone() }
}

/// QEP corner (Eq. 4): runtime activations, full-precision reference.
/// Note the pipeline substitutes runtime taps for that reference when it
/// skips the FP cache at this corner
/// ([`crate::quant::skip_fp_reference`]); pass a true `x_fp` here to get
/// the literal Eq. 4 objective.
pub fn variant_qep(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { mu: 0.0, lambda: 0.0, ..cfg.clone() }
}

/// Quantize one layer with OJBKQ. `rng` must already be forked per layer;
/// column tiles fork sub-streams so results are independent of tile
/// iteration order AND of which thread decodes them. `rt` supplies the
/// PJRT backend when `cfg.backend == Backend::Pjrt`.
pub fn quantize(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
) -> anyhow::Result<QuantizedLinear> {
    quantize_with(w, x_fp, x_rt, cfg, rng, rt, None)
}

/// [`quantize`] with an optional shared per-tap-point factorization:
/// when the coordinator hands in a [`FactoredSystem`] (built once for the
/// whole Q/K/V or Gate/Up group), the Gram, act-order permutation and
/// Cholesky factor are reused and only the per-layer RHS, scales and
/// decode run here — bit-identical to rebuilding the factor in place.
pub fn quantize_with(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<QuantizedLinear> {
    quantize_with_diag(w, x_fp, x_rt, cfg, rng, rt, shared).map(|(q, _)| q)
}

/// [`quantize_with`], additionally returning the aggregated
/// [`DecodeDiag`] from the tile decodes. The diagnostics are pure
/// observation — codes, scales, and RNG consumption are bit-identical
/// to [`quantize_with`].
#[allow(clippy::too_many_arguments)]
pub fn quantize_with_diag(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<(QuantizedLinear, DecodeDiag)> {
    let (m, n) = w.shape();
    // 2–3. JTA system + Cholesky (Algorithm 1 line 2) — shared across the
    // tap group when the coordinator built the factor, rebuilt here for
    // standalone calls. The decode ordering (ASCENDING Gram diagonal —
    // Babai decides row m−1 first, so this is exactly GPTQ's act_order
    // under the Babai/GPTQ order reversal, Chen et al. 2025) lives in
    // the factor too: scales are computed on the permuted weight (group
    // boundaries follow decode order, like the GPTQ reference's default)
    // and the dequantized effective weight is un-permuted at the end.
    let owned_sys;
    let sys: &FactoredSystem = match shared {
        Some(s) => {
            s.check(FactorKind::Ojbkq, m, cfg)?;
            s
        }
        None => {
            owned_sys = FactoredSystem::for_ojbkq(x_rt, cfg)?;
            &owned_sys
        }
    };
    let rhs = jta::build_rhs(w, x_fp, x_rt, sys.lambda_sq, cfg);
    let permuted = sys.permuted;
    let perm = &sys.perm;
    let r = &sys.r;
    // Borrow the unpermuted operands directly in the identity case — no
    // whole-matrix clones on the non-act-order path.
    let rhs_p_store;
    let rhs_p: &Matrix = if permuted {
        rhs_p_store = rhs.permute_rows(perm);
        &rhs_p_store
    } else {
        &rhs
    };
    let w_p_store;
    let w_p: &Matrix = if permuted {
        w_p_store = w.permute_rows(perm);
        &w_p_store
    } else {
        w
    };
    // 1. Scales/zeros (Algorithm 1 line 1) — in decode order.
    let sc = scales::compute(w_p, cfg);
    // 4. Real-valued solution (line 3). Its code-space image Q̄ (line 4)
    // is formed per tile inside the decode workers from `w_real` slices —
    // the full m×n Q̄ is never materialized.
    let w_real = jta::solve_real(r, rhs_p);
    // The R diagonal drives the per-column Klein temperature α; extract
    // it once per layer instead of `r.get(i,i)` per (tile, column, row).
    let r_diag: Vec<f32> = (0..m).map(|i| r.get(i, i)).collect();
    // 5. Tiled Random-K decode — tiles are independent by construction
    // (each forks its own RNG sub-stream keyed by tile index), so the
    // native backend fans them out with `parallel_map` and the codes are
    // bit-identical at any `OJBKQ_THREADS`.
    let qmax = cfg.box_max() as f32;
    let ntile = cfg.ntile.max(1).min(n.max(1));
    let n_tiles = n.div_ceil(ntile);
    let rng_ref: &Rng = rng;
    let decode_inputs = |t: usize| {
        let c0 = t * ntile;
        let width = ntile.min(n - c0);
        let s_tile = sc.scale_tile(c0, width);
        let qbar_tile = qbar_tile(&w_real, &sc, c0, width);
        let alpha = tile_alpha(cfg.k, &r_diag, &s_tile);
        let mut trng = rng_ref.fork(t as u64);
        let uniforms = trng.uniform_vec_f32((cfg.k + 1) * m * width);
        (s_tile, qbar_tile, alpha, uniforms)
    };
    let mut diag = DecodeDiag::default();
    let tiles: Vec<Matrix> = match cfg.backend {
        Backend::Native => {
            // Keep the full PpiOutput per tile so the per-layer decode
            // diagnostics (winning/greedy residual, improvement events)
            // come for free — the decoder computes them anyway.
            let outs: Vec<PpiOutput> = parallel_map(n_tiles, |t| {
                let (s_tile, qbar_tile, alpha, uniforms) = decode_inputs(t);
                decode_tile(&PpiInput {
                    r,
                    s: &s_tile,
                    qbar: &qbar_tile,
                    qmax,
                    k: cfg.k,
                    block: cfg.block,
                    alpha: &alpha,
                    uniforms: &uniforms,
                })
            });
            for out in &outs {
                diag.absorb(out, cfg.k);
            }
            outs.into_iter().map(|o| o.q).collect()
        }
        Backend::Pjrt => {
            // The PJRT runtime owns a single device stream; keep the tile
            // loop serial and let the artifact parallelize internally.
            // The artifact returns codes only, so `diag` stays zeroed.
            let rt = rt.ok_or_else(|| {
                anyhow::anyhow!("PJRT backend requested but no SolverRuntime provided")
            })?;
            let mut out = Vec::with_capacity(n_tiles);
            for t in 0..n_tiles {
                let (s_tile, qbar_tile, alpha, uniforms) = decode_inputs(t);
                out.push(rt.decode_tile(r, &s_tile, &qbar_tile, qmax, cfg.k, &alpha, &uniforms)?);
            }
            out
        }
    };
    let mut codes = vec![0u8; m * n];
    for (t, q_tile) in tiles.iter().enumerate() {
        let c0 = t * ntile;
        let width = q_tile.cols();
        for i in 0..m {
            let row = q_tile.row(i);
            for (j, &v) in row.iter().enumerate() {
                codes[i * n + c0 + j] = v as u8;
            }
        }
        debug_assert_eq!(width, ntile.min(n - c0));
    }
    let mut q = QuantizedLinear::new(codes, sc, cfg.wbit, m, n);
    if permuted {
        // Codes/scales live in decode order; expose the runtime weight in
        // the original feature order via the effective matrix, and record
        // the row permutation so the packed execution engine can keep the
        // integer codes and gather activations instead.
        let inv = crate::tensor::invert_perm(perm);
        let w_hat = q.dequantize().permute_rows(&inv);
        q.effective = Some(w_hat);
        q.perm = Some(perm.iter().map(|&p| p as u32).collect());
    }
    Ok((q, diag))
}

/// The code-space center `Q̄ = Ŵ_real ⊘ S + Z` restricted to columns
/// `[c0, c0+width)` — built straight from `w_real` slices so the decode
/// never materializes the full `m×n` Q̄.
fn qbar_tile(w_real: &Matrix, sc: &GroupScales, c0: usize, width: usize) -> Matrix {
    let m = w_real.rows();
    let mut out = Matrix::zeros(m, width);
    for i in 0..m {
        let g = sc.group_of(i);
        let src = &w_real.row(i)[c0..c0 + width];
        let s_row = &sc.scales.row(g)[c0..c0 + width];
        let z_row = &sc.zeros.row(g)[c0..c0 + width];
        let dst = out.row_mut(i);
        for j in 0..width {
            dst[j] = src[j] / s_row[j] + z_row[j];
        }
    }
    out
}

/// Per-column Klein temperature α for one tile, from the hoisted `R`
/// diagonal (`min_j r̄² = min_i (R[i,i]·S[i,j])²` feeds Klein's ρ).
fn tile_alpha(k: usize, r_diag: &[f32], s_tile: &Matrix) -> Vec<f32> {
    let (m, width) = s_tile.shape();
    (0..width)
        .map(|j| {
            if k == 0 {
                return 1.0;
            }
            let min_rbar_sq = (0..m)
                .map(|i| {
                    let v = r_diag[i] as f64 * s_tile.get(i, j) as f64;
                    v * v
                })
                .fold(f64::INFINITY, f64::min);
            alpha_for(k, m, min_rbar_sq) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::rtn;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
        let noise = Matrix::randn(p, m, 0.05, &mut rng);
        let x_rt = x_fp.add(&noise);
        (w, x_fp, x_rt)
    }

    fn rt_err(w_hat: &Matrix, w: &Matrix, x_rt: &Matrix) -> f64 {
        matmul(x_rt, w_hat).sub(&matmul(x_rt, w)).frob()
    }

    #[test]
    fn ojbkq_beats_rtn_on_runtime_error() {
        let (w, x_fp, x_rt) = layer(48, 32, 96, 1);
        let cfg = QuantConfig { wbit: 3, group_size: 0, k: 5, ntile: 16, ..Default::default() };
        let mut rng = Rng::new(2);
        let q = quantize(&w, &x_fp, &x_rt, &variant_random_k(&cfg), &mut rng, None).unwrap();
        let q_rtn = rtn::quantize(&w, &cfg);
        let e_ours = rt_err(&q.dequantize(), &w, &x_rt);
        let e_rtn = rt_err(&q_rtn.dequantize(), &w, &x_rt);
        assert!(e_ours < e_rtn, "ours {e_ours} vs rtn {e_rtn}");
    }

    #[test]
    fn random_k_no_worse_than_naive() {
        let mut worse = 0;
        for seed in 0..5 {
            let (w, x_fp, x_rt) = layer(32, 24, 64, 10 + seed);
            let cfg =
                QuantConfig { wbit: 3, group_size: 16, k: 8, ntile: 8, ..Default::default() };
            let mut rng_a = Rng::new(seed);
            let mut rng_b = Rng::new(seed);
            let qn =
                quantize(&w, &x_fp, &x_rt, &variant_naive(&cfg), &mut rng_a, None).unwrap();
            let qr =
                quantize(&w, &x_fp, &x_rt, &variant_random_k(&cfg), &mut rng_b, None).unwrap();
            let en = rt_err(&qn.dequantize(), &w, &x_rt);
            let er = rt_err(&qr.dequantize(), &w, &x_rt);
            if er > en * 1.001 {
                worse += 1;
            }
        }
        // The greedy path is reserved inside Random-K, so in the *lattice
        // metric* it never loses; in output MSE it can only lose via the
        // (tiny) metric mismatch. Allow at most one seed of noise.
        assert!(worse <= 1, "random-K lost on {worse}/5 seeds");
    }

    #[test]
    fn deterministic_given_seed_and_tiling() {
        let (w, x_fp, x_rt) = layer(24, 20, 48, 3);
        let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 7, ..Default::default() };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let qa = quantize(&w, &x_fp, &x_rt, &cfg, &mut a, None).unwrap();
        let qb = quantize(&w, &x_fp, &x_rt, &cfg, &mut b, None).unwrap();
        assert_eq!(qa.codes, qb.codes);
    }

    #[test]
    fn identical_activations_make_mu_irrelevant() {
        // With X̃ == X, Y*(μ) is the same for every μ; codes must agree.
        let (w, x_fp, _) = layer(16, 12, 32, 4);
        let mk = |mu: f64| {
            let cfg = QuantConfig {
                wbit: 4,
                group_size: 0,
                k: 0,
                mu,
                lambda: 0.0,
                ntile: 12,
                ..Default::default()
            };
            let mut rng = Rng::new(5);
            quantize(&w, &x_fp, &x_fp, &cfg, &mut rng, None).unwrap().codes
        };
        assert_eq!(mk(0.0), mk(1.0));
    }

    #[test]
    fn codes_respect_box_for_3bit() {
        let (w, x_fp, x_rt) = layer(20, 10, 40, 6);
        let cfg = QuantConfig { wbit: 3, group_size: 0, k: 4, ..Default::default() };
        let mut rng = Rng::new(7);
        let q = quantize(&w, &x_fp, &x_rt, &cfg, &mut rng, None).unwrap();
        assert!(q.codes.iter().all(|&c| c <= 7));
    }

    #[test]
    fn diag_matches_decode_semantics() {
        let (w, x_fp, x_rt) = layer(32, 24, 64, 11);
        let cfg = QuantConfig { wbit: 3, group_size: 8, k: 6, ntile: 10, ..Default::default() };
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        let (qd, diag) = quantize_with_diag(&w, &x_fp, &x_rt, &cfg, &mut a, None, None).unwrap();
        let q = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut b, None, None).unwrap();
        // Pure observation: codes identical with and without diagnostics.
        assert_eq!(qd.codes, q.codes);
        assert_eq!(diag.cols, 24);
        assert_eq!(diag.sampled_paths, 6 * 24);
        // The winner is the min over paths including greedy, so the
        // winning residual never exceeds greedy's.
        assert!(diag.decode_resid <= diag.greedy_resid + 1e-9);
        assert!((0.0..=1.0).contains(&diag.improvement_rate()));
        // K=0 has only the greedy path: nothing sampled, nothing improved.
        let cfg0 = variant_naive(&cfg);
        let mut c = Rng::new(4);
        let (_, d0) = quantize_with_diag(&w, &x_fp, &x_rt, &cfg0, &mut c, None, None).unwrap();
        assert_eq!((d0.sampled_paths, d0.improved_cols), (0, 0));
        assert!((d0.decode_resid - d0.greedy_resid).abs() < 1e-9);
    }

    #[test]
    fn tile_width_does_not_change_greedy_result() {
        // Greedy decode consumes no randomness, so tiling is pure
        // bookkeeping and must not alter codes.
        let (w, x_fp, x_rt) = layer(24, 30, 48, 8);
        let mk = |ntile: usize| {
            let cfg = QuantConfig {
                wbit: 4,
                group_size: 8,
                ntile,
                ..variant_naive(&QuantConfig::default())
            };
            let mut rng = Rng::new(1);
            quantize(&w, &x_fp, &x_rt, &cfg, &mut rng, None).unwrap().codes
        };
        assert_eq!(mk(5), mk(30));
        assert_eq!(mk(64), mk(30));
    }
}
