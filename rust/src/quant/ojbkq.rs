//! The OJBKQ layer solver — end-to-end per-layer quantization
//! (paper Algorithms 1, 3, 4 + Appendix A), orchestrating:
//!
//! 1. scale/zero calibration (§3.2),
//! 2. the JTA normal-equation system `G = X̃ᵀX̃+λ²I`, `B = X̃ᵀY*+λ²W`,
//! 3. Cholesky `G = RᵀR` (jittered if near-singular) — *no inverse*,
//! 4. the real-valued solution `Ŵ_real` via triangular solves and its
//!    code-space image `Q̄ = Ŵ_real ⊘ S + Z`,
//! 5. tiled Random-K Babai/Klein decoding (native PPI or the AOT Pallas
//!    artifact via PJRT), selecting the minimum-residual candidate.
//!
//! The paper's three reported variants are configuration points:
//! * **Ours(N)** — [`variant_naive`]: K=0 (greedy only), μ=1, λ=0.
//! * **Ours(R)** — [`variant_random_k`]: K>0, μ=1, λ=0.
//! * **Ours** — the given `(K, μ, λ)` (paper defaults per bit-width).

use super::klein::alpha_for;
use super::ppi::{decode_tile, PpiInput};
use super::scales::{self};
use super::{jta, Backend, QuantConfig, QuantizedLinear};
use crate::linalg::cholesky_upper_jittered;
use crate::rng::Rng;
use crate::runtime::SolverRuntime;
use crate::tensor::Matrix;

/// Ours(N): deterministic box-constrained Babai under the
/// runtime-consistent objective (Eq. 1).
pub fn variant_naive(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { k: 0, mu: 1.0, lambda: 0.0, ..cfg.clone() }
}

/// Ours(R): Random-K Babai/Klein under the runtime-consistent objective.
pub fn variant_random_k(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { mu: 1.0, lambda: 0.0, ..cfg.clone() }
}

/// QEP corner (Eq. 4): runtime activations, full-precision reference.
/// Note the pipeline substitutes runtime taps for that reference when it
/// skips the FP cache at this corner
/// ([`crate::quant::skip_fp_reference`]); pass a true `x_fp` here to get
/// the literal Eq. 4 objective.
pub fn variant_qep(cfg: &QuantConfig) -> QuantConfig {
    QuantConfig { mu: 0.0, lambda: 0.0, ..cfg.clone() }
}

/// Quantize one layer with OJBKQ. `rng` must already be forked per layer;
/// column tiles fork sub-streams so results are independent of tile
/// iteration order. `rt` supplies the PJRT backend when
/// `cfg.backend == Backend::Pjrt`.
pub fn quantize(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
) -> anyhow::Result<QuantizedLinear> {
    let (m, n) = w.shape();
    // 2–3. JTA system + Cholesky (Algorithm 1 line 2).
    let sys = jta::build_system(w, x_fp, x_rt, cfg);
    // Decode ordering: Babai decides row m−1 first (uncompensated), so we
    // sort rows by ASCENDING Gram diagonal — the highest-curvature
    // feature is decided first, exactly GPTQ's act_order under the
    // Babai/GPTQ order reversal (Chen et al. 2025). The paper lists
    // weight permutation as future work; we enable it behind the same
    // `act_order` flag as the GPTQ baseline for a like-for-like
    // comparison (ablate with act_order=false). Scales are computed on
    // the permuted weight (group boundaries follow decode order, exactly
    // like the GPTQ reference's default) and the dequantized effective
    // weight is un-permuted at the end.
    let perm: Vec<usize> = if cfg.act_order {
        let mut idx: Vec<usize> = (0..m).collect();
        idx.sort_by(|&a, &b| {
            sys.gram
                .get(a, a)
                .partial_cmp(&sys.gram.get(b, b))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx
    } else {
        (0..m).collect()
    };
    let permuted = cfg.act_order;
    let gram_p = if permuted {
        Matrix::from_fn(m, m, |i, j| sys.gram.get(perm[i], perm[j]))
    } else {
        sys.gram.clone()
    };
    let rhs_p = if permuted { sys.rhs.permute_rows(&perm) } else { sys.rhs.clone() };
    let w_p = if permuted { w.permute_rows(&perm) } else { w.clone() };
    // 1. Scales/zeros (Algorithm 1 line 1) — in decode order.
    let sc = scales::compute(&w_p, cfg);
    let (r, _jitter) = cholesky_upper_jittered(&gram_p, 1e-6)
        .map_err(|e| anyhow::anyhow!("gram cholesky failed: {e}"))?;
    // 4. Real-valued solution and its code-space center (lines 3–4).
    let w_real = jta::solve_real(&r, &rhs_p);
    let mut qbar = Matrix::zeros(m, n);
    for i in 0..m {
        let g = sc.group_of(i);
        for j in 0..n {
            let s = sc.scales.get(g, j);
            let z = sc.zeros.get(g, j);
            qbar.set(i, j, w_real.get(i, j) / s + z);
        }
    }
    // 5. Tiled Random-K decode.
    let qmax = cfg.box_max() as f32;
    let ntile = cfg.ntile.max(1).min(n);
    let mut codes = vec![0u8; m * n];
    let mut tile_idx = 0u64;
    let mut c0 = 0usize;
    while c0 < n {
        let width = ntile.min(n - c0);
        let s_tile = sc.scale_tile(c0, width);
        let qbar_tile = qbar.block(0, c0, m, width);
        // Per-column Klein temperature from the lattice geometry.
        let alpha: Vec<f32> = (0..width)
            .map(|j| {
                if cfg.k == 0 {
                    return 1.0;
                }
                let min_rbar_sq = (0..m)
                    .map(|i| {
                        let v = r.get(i, i) as f64 * s_tile.get(i, j) as f64;
                        v * v
                    })
                    .fold(f64::INFINITY, f64::min);
                alpha_for(cfg.k, m, min_rbar_sq) as f32
            })
            .collect();
        let mut trng = rng.fork(tile_idx);
        let uniforms = trng.uniform_vec_f32((cfg.k + 1) * m * width);
        let q_tile = match cfg.backend {
            Backend::Native => {
                let out = decode_tile(&PpiInput {
                    r: &r,
                    s: &s_tile,
                    qbar: &qbar_tile,
                    qmax,
                    k: cfg.k,
                    block: cfg.block,
                    alpha: &alpha,
                    uniforms: &uniforms,
                });
                out.q
            }
            Backend::Pjrt => {
                let rt = rt.ok_or_else(|| {
                    anyhow::anyhow!("PJRT backend requested but no SolverRuntime provided")
                })?;
                rt.decode_tile(&r, &s_tile, &qbar_tile, qmax, cfg.k, &alpha, &uniforms)?
            }
        };
        for i in 0..m {
            for j in 0..width {
                codes[i * n + c0 + j] = q_tile.get(i, j) as u8;
            }
        }
        c0 += width;
        tile_idx += 1;
    }
    let mut q = QuantizedLinear::new(codes, sc, cfg.wbit, m, n);
    if permuted {
        // Codes/scales live in decode order; expose the runtime weight in
        // the original feature order via the effective matrix, and record
        // the row permutation so the packed execution engine can keep the
        // integer codes and gather activations instead.
        let inv = crate::tensor::invert_perm(&perm);
        let w_hat = q.dequantize().permute_rows(&inv);
        q.effective = Some(w_hat);
        q.perm = Some(perm.iter().map(|&p| p as u32).collect());
    }
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::rtn;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
        let noise = Matrix::randn(p, m, 0.05, &mut rng);
        let x_rt = x_fp.add(&noise);
        (w, x_fp, x_rt)
    }

    fn rt_err(w_hat: &Matrix, w: &Matrix, x_rt: &Matrix) -> f64 {
        matmul(x_rt, w_hat).sub(&matmul(x_rt, w)).frob()
    }

    #[test]
    fn ojbkq_beats_rtn_on_runtime_error() {
        let (w, x_fp, x_rt) = layer(48, 32, 96, 1);
        let cfg = QuantConfig { wbit: 3, group_size: 0, k: 5, ntile: 16, ..Default::default() };
        let mut rng = Rng::new(2);
        let q = quantize(&w, &x_fp, &x_rt, &variant_random_k(&cfg), &mut rng, None).unwrap();
        let q_rtn = rtn::quantize(&w, &cfg);
        let e_ours = rt_err(&q.dequantize(), &w, &x_rt);
        let e_rtn = rt_err(&q_rtn.dequantize(), &w, &x_rt);
        assert!(e_ours < e_rtn, "ours {e_ours} vs rtn {e_rtn}");
    }

    #[test]
    fn random_k_no_worse_than_naive() {
        let mut worse = 0;
        for seed in 0..5 {
            let (w, x_fp, x_rt) = layer(32, 24, 64, 10 + seed);
            let cfg =
                QuantConfig { wbit: 3, group_size: 16, k: 8, ntile: 8, ..Default::default() };
            let mut rng_a = Rng::new(seed);
            let mut rng_b = Rng::new(seed);
            let qn =
                quantize(&w, &x_fp, &x_rt, &variant_naive(&cfg), &mut rng_a, None).unwrap();
            let qr =
                quantize(&w, &x_fp, &x_rt, &variant_random_k(&cfg), &mut rng_b, None).unwrap();
            let en = rt_err(&qn.dequantize(), &w, &x_rt);
            let er = rt_err(&qr.dequantize(), &w, &x_rt);
            if er > en * 1.001 {
                worse += 1;
            }
        }
        // The greedy path is reserved inside Random-K, so in the *lattice
        // metric* it never loses; in output MSE it can only lose via the
        // (tiny) metric mismatch. Allow at most one seed of noise.
        assert!(worse <= 1, "random-K lost on {worse}/5 seeds");
    }

    #[test]
    fn deterministic_given_seed_and_tiling() {
        let (w, x_fp, x_rt) = layer(24, 20, 48, 3);
        let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 7, ..Default::default() };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let qa = quantize(&w, &x_fp, &x_rt, &cfg, &mut a, None).unwrap();
        let qb = quantize(&w, &x_fp, &x_rt, &cfg, &mut b, None).unwrap();
        assert_eq!(qa.codes, qb.codes);
    }

    #[test]
    fn identical_activations_make_mu_irrelevant() {
        // With X̃ == X, Y*(μ) is the same for every μ; codes must agree.
        let (w, x_fp, _) = layer(16, 12, 32, 4);
        let mk = |mu: f64| {
            let cfg = QuantConfig {
                wbit: 4,
                group_size: 0,
                k: 0,
                mu,
                lambda: 0.0,
                ntile: 12,
                ..Default::default()
            };
            let mut rng = Rng::new(5);
            quantize(&w, &x_fp, &x_fp, &cfg, &mut rng, None).unwrap().codes
        };
        assert_eq!(mk(0.0), mk(1.0));
    }

    #[test]
    fn codes_respect_box_for_3bit() {
        let (w, x_fp, x_rt) = layer(20, 10, 40, 6);
        let cfg = QuantConfig { wbit: 3, group_size: 0, k: 4, ..Default::default() };
        let mut rng = Rng::new(7);
        let q = quantize(&w, &x_fp, &x_rt, &cfg, &mut rng, None).unwrap();
        assert!(q.codes.iter().all(|&c| c <= 7));
    }

    #[test]
    fn tile_width_does_not_change_greedy_result() {
        // Greedy decode consumes no randomness, so tiling is pure
        // bookkeeping and must not alter codes.
        let (w, x_fp, x_rt) = layer(24, 30, 48, 8);
        let mk = |ntile: usize| {
            let cfg = QuantConfig {
                wbit: 4,
                group_size: 8,
                ntile,
                ..variant_naive(&QuantConfig::default())
            };
            let mut rng = Rng::new(1);
            quantize(&w, &x_fp, &x_rt, &cfg, &mut rng, None).unwrap().codes
        };
        assert_eq!(mk(5), mk(30));
        assert_eq!(mk(64), mk(30));
    }
}
