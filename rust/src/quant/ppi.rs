//! Parallel Path-Isolated K-best Babai decoding (**PPI-KBabai**, paper
//! Appendix A, Algorithm 2) — the performance-critical native hot path,
//! mirrored 1:1 by the Pallas kernel in
//! `python/compile/kernels/babai_klein.py`.
//!
//! Works on a *column tile* of the layer: all columns share the Cholesky
//! factor `R`; per-column scales enter element-wise. Each of the K+1
//! decoding paths (path 0 reserved greedy) owns an isolated error buffer
//! `E_p = S ⊙ (Q̄ − Q_p)` — the paper's fix for cross-path interference —
//! and rows are processed high→low in blocks of `B` with the accumulated
//! look-ahead update `ADJ = R[J, F] · E[F]` done as one GEMM per block
//! instead of m rank-1 updates.
//!
//! Consumes explicit `uniforms` (path-major `(K+1) × m × ntile`) so the
//! native and PJRT backends are fed identical randomness and can be
//! compared exactly.

use super::klein::sample_code;
use super::rtn::round_code;
use crate::linalg::{gemm, matmul_par};
use crate::tensor::Matrix;

/// Input to one tile decode.
pub struct PpiInput<'a> {
    /// Shared `m×m` upper-triangular Cholesky factor.
    pub r: &'a Matrix,
    /// Per-(row, column) scales, `m×ntile`.
    pub s: &'a Matrix,
    /// Real-valued LS solution in code space, `m×ntile`.
    pub qbar: &'a Matrix,
    /// Box upper bound `2^b − 1`.
    pub qmax: f32,
    /// Number of *sampled* paths (greedy path 0 is additional).
    pub k: usize,
    /// Look-ahead block size `B`.
    pub block: usize,
    /// Per-column Klein temperature α (length ntile).
    pub alpha: &'a [f32],
    /// Uniform randomness, length `(k+1)·m·ntile`, layout `[path][row][col]`.
    /// Path 0's values are ignored (greedy).
    pub uniforms: &'a [f32],
}

/// Result of one tile decode.
pub struct PpiOutput {
    /// Best codes per column, `m×ntile`.
    pub q: Matrix,
    /// Winning residual `||R·(s⊙(q−q̄))||²` per column.
    pub resid: Vec<f64>,
    /// Residuals of every path, `(k+1)×ntile` (Fig. 1 diagnostics).
    pub path_resids: Matrix,
    /// Index of the winning path per column (0 = greedy Babai).
    pub winner: Vec<usize>,
}

/// Decode one column tile. Paths run in parallel (they are isolated by
/// construction); each path's inner loop is the blocked Algorithm 2.
pub fn decode_tile(inp: &PpiInput) -> PpiOutput {
    let m = inp.r.rows();
    let ntile = inp.qbar.cols();
    assert_eq!(inp.r.cols(), m);
    assert_eq!(inp.s.shape(), (m, ntile));
    assert_eq!(inp.alpha.len(), ntile);
    let paths = inp.k + 1;
    assert_eq!(inp.uniforms.len(), paths * m * ntile, "uniform buffer size");

    // Decode all paths jointly: buffers are (m × paths·ntile) with path p
    // occupying columns [p·ntile, (p+1)·ntile) — still strictly
    // path-isolated (no cross-path reads), but the Algorithm-2 look-ahead
    // update becomes ONE wide GEMM per block ("propagate error to all K
    // paths simultaneously using matrix multiplication"), which is both
    // the paper's formulation and ~1.3× faster than per-path GEMMs.
    let (q_wide, e_wide) = decode_paths_fused(inp, paths);

    // Residuals for every path in one wide GEMM: RE = R · E_wide, then
    // column sums of squares. Routed through the row-parallel GEMM —
    // bit-identical to the serial kernel. When this decode already runs
    // on a tile-parallel worker, `parallel::num_threads()` reports 1
    // there and the call stays serial (no nested fan-out); standalone
    // decodes (single-tile layers, benches) get the row parallelism.
    let wide = paths * ntile;
    let re = matmul_par(inp.r, &e_wide);
    let mut path_resids = Matrix::zeros(paths, ntile);
    let mut acc = vec![0.0f64; wide];
    for i in 0..m {
        let row = re.row(i);
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += v as f64 * v as f64;
        }
    }
    for p in 0..paths {
        for j in 0..ntile {
            path_resids.set(p, j, acc[p * ntile + j] as f32);
        }
    }

    // Select the winner per column (Algorithm 4's argmin).
    let mut q = Matrix::zeros(m, ntile);
    let mut resid = vec![0.0f64; ntile];
    let mut winner = vec![0usize; ntile];
    for j in 0..ntile {
        let mut best_p = 0usize;
        for p in 1..paths {
            if path_resids.get(p, j) < path_resids.get(best_p, j) {
                best_p = p;
            }
        }
        winner[j] = best_p;
        resid[j] = path_resids.get(best_p, j) as f64;
        for i in 0..m {
            q.set(i, j, q_wide.get(i, best_p * ntile + j));
        }
    }
    PpiOutput { q, resid, path_resids, winner }
}

/// Fused blocked back-substitution over all paths at once. Buffers are
/// `(m × paths·ntile)`; returns the wide `(Q, E)` pair.
///
/// The `adj` look-ahead panel and the per-row `local` accumulator are
/// allocated once and reused across blocks/rows (they used to be
/// re-allocated per block and per row respectively) — this routine runs
/// once per column tile per layer, inside the tile-parallel decode, so
/// the allocator would otherwise sit on the hot path of every worker.
fn decode_paths_fused(inp: &PpiInput, paths: usize) -> (Matrix, Matrix) {
    let m = inp.r.rows();
    let ntile = inp.qbar.cols();
    let wide = paths * ntile;
    let b = inp.block.max(1);
    let mut q = Matrix::zeros(m, wide);
    let mut e = Matrix::zeros(m, wide);
    // Reused scratch: the loop walks rows high→low, so the first block
    // processed (rows [m−B, m)) has no look-ahead and reads the freshly
    // zeroed `adj`; every later block overwrites it fully via the beta=0
    // GEMM. Only the last-processed block (rows [0, m mod B) when B ∤ m)
    // can have a different height, costing at most one extra allocation.
    let mut adj = Matrix::zeros(b.min(m), wide);
    let mut local = vec![0.0f32; wide];
    let mut j_hi = m;
    while j_hi > 0 {
        let j_lo = j_hi.saturating_sub(b);
        let blk = j_hi - j_lo;
        // 1. Global vectorized look-ahead for ALL paths in one GEMM:
        //    ADJ = R[J, F] · E[F, :]  (B × paths·ntile).
        if blk != adj.rows() {
            adj = Matrix::zeros(blk, wide);
        }
        if j_hi < m {
            let r_panel = inp.r.block(j_lo, j_hi, blk, m - j_hi);
            let e_panel = e.block(j_hi, 0, m - j_hi, wide);
            gemm(1.0, &r_panel, &e_panel, 0.0, &mut adj);
        }
        // 2. Local sequential sweep inside the block.
        for i in (j_lo..j_hi).rev() {
            let rii = inp.r.get(i, i);
            local.fill(0.0);
            for l in i + 1..j_hi {
                let ril = inp.r.get(i, l);
                if ril == 0.0 {
                    continue;
                }
                let el = e.row(l);
                for (acc, &ev) in local.iter_mut().zip(el) {
                    *acc += ril * ev;
                }
            }
            let adj_row = adj.row(i - j_lo);
            let qbar_row = inp.qbar.row(i);
            let s_row = inp.s.row(i);
            let q_row = q.row_mut(i);
            for p in 0..paths {
                let greedy = p == 0;
                for j in 0..ntile {
                    let col = p * ntile + j;
                    let s_ij = s_row[j];
                    let c = qbar_row[j] + (adj_row[col] + local[col]) / (rii * s_ij);
                    let code = if greedy {
                        round_code(c, inp.qmax)
                    } else {
                        let rbar = rii * s_ij;
                        let u = inp.uniforms[(p * m + i) * ntile + j];
                        sample_code(c, rbar * rbar, inp.alpha[j], inp.qmax, u)
                    };
                    q_row[col] = code;
                }
            }
            // Error row: E = S ⊙ (Q̄ − Q), replicated across paths.
            let e_row = e.row_mut(i);
            for p in 0..paths {
                for j in 0..ntile {
                    let col = p * ntile + j;
                    e_row[col] = s_row[j] * (qbar_row[j] - q_row[col]);
                }
            }
        }
        j_hi = j_lo;
    }
    (q, e)
}

/// Blocked back-substitution for one path (reference form kept for the
/// path-parallel configuration and documentation; the fused variant above
/// is the default hot path).
#[allow(dead_code)]
fn decode_one_path(inp: &PpiInput, p: usize) -> (Matrix, Matrix) {
    let m = inp.r.rows();
    let ntile = inp.qbar.cols();
    let b = inp.block.max(1);
    let greedy = p == 0;
    let mut q = Matrix::zeros(m, ntile);
    let mut e = Matrix::zeros(m, ntile); // weight-space error, filled high→low
    // adj[i][j] accumulates Σ_{l ≥ block end} R(i,l)·E(l,j) for the rows of
    // the *current* block only — recomputed per block via GEMM.
    let mut j_hi = m;
    while j_hi > 0 {
        let j_lo = j_hi.saturating_sub(b);
        let blk = j_hi - j_lo;
        // 1. Global vectorized look-ahead: ADJ = R[J, F] · E[F, :] where
        //    F = [j_hi, m) are the already-processed rows.
        let mut adj = Matrix::zeros(blk, ntile);
        if j_hi < m {
            let r_panel = inp.r.block(j_lo, j_hi, blk, m - j_hi);
            let e_panel = e.block(j_hi, 0, m - j_hi, ntile);
            gemm(1.0, &r_panel, &e_panel, 0.0, &mut adj);
        }
        // 2. Local sequential sweep inside the block (rows couple through
        //    rows of the same block, so this part is inherently ordered).
        for i in (j_lo..j_hi).rev() {
            let rii = inp.r.get(i, i);
            // local contributions from rows (i, j_hi) within the block
            let mut local = vec![0.0f32; ntile];
            for l in i + 1..j_hi {
                let ril = inp.r.get(i, l);
                if ril == 0.0 {
                    continue;
                }
                let el = e.row(l);
                for (acc, &ev) in local.iter_mut().zip(el) {
                    *acc += ril * ev;
                }
            }
            let adj_row = adj.row(i - j_lo);
            for j in 0..ntile {
                let s_ij = inp.s.get(i, j);
                let c = inp.qbar.get(i, j) + (adj_row[j] + local[j]) / (rii * s_ij);
                let code = if greedy {
                    round_code(c, inp.qmax)
                } else {
                    let rbar = rii * s_ij;
                    let u = inp.uniforms[(p * m + i) * ntile + j];
                    sample_code(c, rbar * rbar, inp.alpha[j], inp.qmax, u)
                };
                q.set(i, j, code);
                e.set(i, j, s_ij * (inp.qbar.get(i, j) - code));
            }
        }
        j_hi = j_lo;
    }
    (q, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cholesky_upper, syrk_upper};
    use crate::quant::babai::{decode_greedy, residual_sq};
    use crate::quant::klein::{alpha_for, decode_sampled_with_uniforms};
    use crate::rng::Rng;

    struct Fixture {
        r: Matrix,
        s: Matrix,
        qbar: Matrix,
        alpha: Vec<f32>,
    }

    fn fixture(m: usize, ntile: usize, seed: u64) -> Fixture {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(m + 4, m, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.05);
        let r = cholesky_upper(&g).unwrap();
        let s = Matrix::from_fn(m, ntile, |_, _| 0.05 + 0.2 * rng.uniform_f32());
        let qbar = Matrix::from_fn(m, ntile, |_, _| 15.0 * rng.uniform_f32());
        let alpha: Vec<f32> = (0..ntile)
            .map(|j| {
                let min_rbar_sq = (0..m)
                    .map(|i| {
                        let v = r.get(i, i) as f64 * s.get(i, j) as f64;
                        v * v
                    })
                    .fold(f64::INFINITY, f64::min);
                alpha_for(5, m, min_rbar_sq) as f32
            })
            .collect();
        Fixture { r, s, qbar, alpha }
    }

    fn uniforms(k: usize, m: usize, ntile: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).uniform_vec_f32((k + 1) * m * ntile)
    }

    #[test]
    fn greedy_path_matches_reference_babai_exactly() {
        for &block in &[1usize, 4, 16, 64] {
            let f = fixture(48, 6, 11);
            let u = uniforms(0, 48, 6, 1);
            let out = decode_tile(&PpiInput {
                r: &f.r,
                s: &f.s,
                qbar: &f.qbar,
                qmax: 15.0,
                k: 0,
                block,
                alpha: &f.alpha,
                uniforms: &u,
            });
            for j in 0..6 {
                let sj = f.s.col(j);
                let qj = f.qbar.col(j);
                let expect = decode_greedy(&f.r, &sj, &qj, 15.0);
                assert_eq!(out.q.col(j), expect, "block={block} col={j}");
            }
        }
    }

    #[test]
    fn sampled_paths_match_reference_klein_exactly() {
        let (m, ntile, k) = (32usize, 4usize, 3usize);
        let f = fixture(m, ntile, 13);
        let u = uniforms(k, m, ntile, 2);
        let out = decode_tile(&PpiInput {
            r: &f.r,
            s: &f.s,
            qbar: &f.qbar,
            qmax: 15.0,
            k,
            block: 8,
            alpha: &f.alpha,
            uniforms: &u,
        });
        // Reconstruct each sampled path column via the per-column reference,
        // feeding it the same uniforms slice.
        for p in 1..=k {
            for j in 0..ntile {
                let sj = f.s.col(j);
                let qj = f.qbar.col(j);
                let col_u: Vec<f32> = (0..m).map(|i| u[(p * m + i) * ntile + j]).collect();
                let expect =
                    decode_sampled_with_uniforms(&f.r, &sj, &qj, 15.0, f.alpha[j], &col_u);
                // The tile output only exposes the winner, so compare path
                // residuals instead: recompute the reference residual and
                // check it equals the tile's recorded path residual.
                let expect_res = residual_sq(&f.r, &sj, &qj, &expect);
                let got = out.path_resids.get(p, j) as f64;
                assert!(
                    (got - expect_res).abs() <= 1e-3 * expect_res.max(1.0),
                    "p={p} j={j}: tile {got} vs ref {expect_res}"
                );
            }
        }
    }

    #[test]
    fn block_size_does_not_change_results() {
        let (m, ntile, k) = (40usize, 5usize, 2usize);
        let f = fixture(m, ntile, 17);
        let u = uniforms(k, m, ntile, 3);
        let base = decode_tile(&PpiInput {
            r: &f.r,
            s: &f.s,
            qbar: &f.qbar,
            qmax: 15.0,
            k,
            block: 1,
            alpha: &f.alpha,
            uniforms: &u,
        });
        for &block in &[2usize, 7, 16, 40, 100] {
            let out = decode_tile(&PpiInput {
                r: &f.r,
                s: &f.s,
                qbar: &f.qbar,
                qmax: 15.0,
                k,
                block,
                alpha: &f.alpha,
                uniforms: &u,
            });
            assert_eq!(out.q.as_slice(), base.q.as_slice(), "block={block}");
        }
    }

    #[test]
    fn winner_residual_is_min_over_paths() {
        let f = fixture(24, 8, 19);
        let u = uniforms(4, 24, 8, 5);
        let out = decode_tile(&PpiInput {
            r: &f.r,
            s: &f.s,
            qbar: &f.qbar,
            qmax: 15.0,
            k: 4,
            block: 8,
            alpha: &f.alpha,
            uniforms: &u,
        });
        for j in 0..8 {
            for p in 0..5 {
                assert!(
                    out.resid[j] <= out.path_resids.get(p, j) as f64 + 1e-6,
                    "col {j} path {p}"
                );
            }
        }
        // Winner never worse than the reserved greedy path.
        for j in 0..8 {
            assert!(out.resid[j] <= out.path_resids.get(0, j) as f64 + 1e-6);
        }
    }

    #[test]
    fn codes_in_box_and_integer() {
        let f = fixture(16, 3, 23);
        let u = uniforms(3, 16, 3, 7);
        let out = decode_tile(&PpiInput {
            r: &f.r,
            s: &f.s,
            qbar: &f.qbar,
            qmax: 7.0,
            k: 3,
            block: 4,
            alpha: &f.alpha,
            uniforms: &u,
        });
        for &v in out.q.as_slice() {
            assert!((0.0..=7.0).contains(&v) && v.fract() == 0.0);
        }
    }
}
