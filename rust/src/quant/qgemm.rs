//! Quantized GEMM — inference directly on integer codes.
//!
//! The deployment payoff of weight-only PTQ is running `y = x·Ŵ` without
//! ever materializing the dense f32 `Ŵ = S⊙(Q−Z)`. Per scale group the
//! product factorizes:
//!
//! `y_j = Σ_g s_{g,j} · ( Σ_{i∈g} x_i·q_{ij}  −  z_{g,j} · Σ_{i∈g} x_i )`
//!
//! so the inner loop is a plain integer-code dot product plus one
//! group-level correction using the precomputable per-group activation
//! sums — the standard W4A16 kernel structure (cf. AWQ/GPTQ runtimes),
//! here in portable Rust over the unpacked code buffer.

use super::QuantizedLinear;
use crate::tensor::Matrix;

/// Reusable per-call scratch for [`qgemv_into`]: the per-group activation
/// sums and the per-group code-dot accumulator. Allocated once and reused
/// across rows by [`qgemm`] (the original `qgemv` allocated both vectors
/// — plus the output — on every call, which dominated small-layer GEMMs).
#[derive(Debug, Default)]
pub struct QgemvScratch {
    gsum: Vec<f32>,
    acc: Vec<f32>,
}

/// `y = x · Ŵ` for a single activation row `x` (length m), straight from
/// codes, written into `y` (length n). Falls back to the dense effective
/// weight when the layer carries one (AWQ/QuIP transforms fold into
/// `effective`). `scratch` is resized on first use and reused verbatim
/// afterwards — contents need not be zeroed by the caller.
pub fn qgemv_into(q: &QuantizedLinear, x: &[f32], y: &mut [f32], scratch: &mut QgemvScratch) {
    assert_eq!(x.len(), q.m);
    assert_eq!(y.len(), q.n);
    if let Some(eff) = &q.effective {
        // `y = xᵀ·W` accumulated row by row — the old fallback
        // re-materialized `eff.transpose()` (a full m×n copy) on every
        // activation row just to call gemv on it.
        y.fill(0.0);
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            for (yv, &wv) in y.iter_mut().zip(eff.row(i)) {
                *yv += xv * wv;
            }
        }
        return;
    }
    let gs = q.scales.group_size;
    let n_groups = q.scales.n_groups();
    // Per-group activation sums (the z-correction term), accumulated
    // group-by-group over slices — no per-element `i / gs` division.
    scratch.gsum.clear();
    scratch.gsum.extend(x.chunks(gs).map(|c| c.iter().sum::<f32>()));
    debug_assert_eq!(scratch.gsum.len(), n_groups);
    scratch.acc.resize(q.n, 0.0);
    let acc = &mut scratch.acc; // per-group code-dot accumulator
    y.fill(0.0);
    for g in 0..n_groups {
        acc.fill(0.0);
        let r0 = g * gs;
        let r1 = (r0 + gs).min(q.m);
        for i in r0..r1 {
            let xv = x[i];
            if xv == 0.0 {
                continue;
            }
            let row = &q.codes[i * q.n..(i + 1) * q.n];
            for (a, &code) in acc.iter_mut().zip(row) {
                *a += xv * code as f32;
            }
        }
        for (j, yv) in y.iter_mut().enumerate() {
            let s = q.scales.scales.get(g, j);
            let z = q.scales.zeros.get(g, j);
            *yv += s * (acc[j] - z * scratch.gsum[g]);
        }
    }
}

/// `y = x · Ŵ` — allocating convenience wrapper over [`qgemv_into`].
pub fn qgemv(q: &QuantizedLinear, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; q.n];
    qgemv_into(q, x, &mut y, &mut QgemvScratch::default());
    y
}

/// `Y = X · Ŵ` for a batch of rows (one shared scratch across rows).
pub fn qgemm(q: &QuantizedLinear, x: &Matrix) -> Matrix {
    assert_eq!(x.cols(), q.m);
    let mut y = Matrix::zeros(x.rows(), q.n);
    let mut scratch = QgemvScratch::default();
    for r in 0..x.rows() {
        qgemv_into(q, x.row(r), y.row_mut(r), &mut scratch);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::quant::{rtn, QuantConfig};
    use crate::rng::Rng;

    #[test]
    fn qgemm_matches_dequantized_matmul() {
        let mut rng = Rng::new(1);
        for &(m, n, gs) in &[(32usize, 16usize, 8usize), (48, 24, 16), (33, 7, 16), (20, 5, 0)] {
            let w = Matrix::randn(m, n, 0.5, &mut rng);
            let cfg = QuantConfig { wbit: 4, group_size: gs, ..Default::default() };
            let q = rtn::quantize(&w, &cfg);
            let x = Matrix::randn(5, m, 1.0, &mut rng);
            let dense = matmul(&x, &q.dequantize());
            let packed = qgemm(&q, &x);
            assert!(
                packed.rel_err(&dense) < 1e-4,
                "(m={m},n={n},gs={gs}) rel={}",
                packed.rel_err(&dense)
            );
        }
    }

    #[test]
    fn qgemv_effective_fallback() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(16, 8, 0.5, &mut rng);
        let mut q = rtn::quantize(&w, &QuantConfig::default());
        q.effective = Some(w.clone()); // pretend a transform folded here
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let y = qgemv(&q, &x);
        let expect = crate::linalg::gemv(&w.transpose(), &x);
        for (a, b) in y.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn effective_fallback_matches_dense_matmul_batched() {
        // The transpose-free fallback must still equal `X · W` exactly
        // for a whole batch (and leave no scratch residue between rows).
        let mut rng = Rng::new(21);
        let w = Matrix::randn(24, 10, 0.7, &mut rng);
        let mut q = rtn::quantize(&w, &QuantConfig::default());
        q.effective = Some(w.clone());
        let x = Matrix::randn(6, 24, 1.0, &mut rng);
        let expect = matmul(&x, &w);
        let got = qgemm(&q, &x);
        assert!(got.rel_err(&expect) < 1e-6, "rel={}", got.rel_err(&expect));
    }

    #[test]
    fn dirty_scratch_does_not_leak_between_rows() {
        let mut rng = Rng::new(11);
        let w = Matrix::randn(40, 9, 0.5, &mut rng);
        let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let xa: Vec<f32> = (0..40).map(|i| (i as f32 * 0.11).cos()).collect();
        let xb: Vec<f32> = (0..40).map(|i| (i as f32 * 0.29).sin()).collect();
        let mut scratch = QgemvScratch::default();
        let mut ya = vec![f32::NAN; 9]; // outputs must be fully overwritten
        qgemv_into(&q, &xa, &mut ya, &mut scratch);
        let mut yb = vec![f32::NAN; 9];
        qgemv_into(&q, &xb, &mut yb, &mut scratch);
        assert_eq!(ya, qgemv(&q, &xa));
        assert_eq!(yb, qgemv(&q, &xb));
    }

    #[test]
    fn zero_activation_rows_short_circuit() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(24, 6, 0.5, &mut rng);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let y = qgemv(&q, &vec![0.0; 24]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn three_bit_codes_supported() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(32, 12, 0.5, &mut rng);
        let cfg = QuantConfig { wbit: 3, group_size: 16, ..Default::default() };
        let q = rtn::quantize(&w, &cfg);
        let x = Matrix::randn(3, 32, 1.0, &mut rng);
        let dense = matmul(&x, &q.dequantize());
        let packed = qgemm(&q, &x);
        assert!(packed.rel_err(&dense) < 1e-4);
    }
}
