//! Quantized linear layer storage: integer codes + group scales, with
//! sub-byte bit-packing for honest memory-footprint accounting and a
//! dequantization path used by the evaluation forward pass.

use super::scales::GroupScales;
use crate::tensor::Matrix;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of [`QuantizedLinear::dequantize`] calls — the
/// regression hook behind the packed-execution guarantee that the
/// capture/eval hot path never materializes dense f32 weights (see
/// `rust/tests/no_dequant_hot_path.rs`, which runs as its own process so
/// the count is not polluted by parallel tests).
static DEQUANT_CALLS: AtomicU64 = AtomicU64::new(0);

/// Total `dequantize()` calls so far in this process.
pub fn dequant_calls() -> u64 {
    DEQUANT_CALLS.load(Ordering::Relaxed)
}

/// A quantized `m×n` linear layer: `Ŵ = S ⊙ (Q − Z)` (paper §3.2), plus
/// an optional dense "effective" override for transform-based methods
/// (AWQ folds activation scaling, QuIP folds rotations) whose runtime
/// weight is not literally `S⊙(Q−Z)`.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// Integer codes, row-major `m×n`, one byte per code (unpacked form).
    pub codes: Vec<u8>,
    /// Group scale/zero tables.
    pub scales: GroupScales,
    /// Bit width.
    pub wbit: u8,
    /// Rows (input features).
    pub m: usize,
    /// Columns (output features).
    pub n: usize,
    /// Dense effective weight for transformed methods; when `Some`, it is
    /// what [`Self::dequantize`] returns.
    pub effective: Option<Matrix>,
    /// Input-feature (row) permutation when codes/scales live in decode
    /// order (act-order solvers: OJBKQ, GPTQ): code row `i` multiplies
    /// activation feature `perm[i]`. Lets the packed execution engine
    /// (`crate::infer`) run integer kernels on permuted codes via an
    /// activation gather instead of falling back to the dense
    /// `effective` weight.
    pub perm: Option<Vec<u32>>,
}

impl QuantizedLinear {
    /// Wrap raw codes.
    pub fn new(codes: Vec<u8>, scales: GroupScales, wbit: u8, m: usize, n: usize) -> Self {
        assert_eq!(codes.len(), m * n);
        debug_assert!(codes.iter().all(|&c| (c as u16) < (1 << wbit)));
        QuantizedLinear { codes, scales, wbit, m, n, effective: None, perm: None }
    }

    /// FP passthrough pseudo-layer (the BF16 table rows): codes are empty
    /// and `dequantize` returns the original weight.
    pub fn identity(w: &Matrix) -> Self {
        QuantizedLinear {
            codes: Vec::new(),
            scales: GroupScales {
                scales: Matrix::zeros(1, w.cols()),
                zeros: Matrix::zeros(1, w.cols()),
                group_size: w.rows().max(1),
                m: w.rows(),
            },
            wbit: 0,
            m: w.rows(),
            n: w.cols(),
            effective: Some(w.clone()),
            perm: None,
        }
    }

    /// Code at (i, j).
    #[inline]
    pub fn code(&self, i: usize, j: usize) -> u8 {
        self.codes[i * self.n + j]
    }

    /// Dequantize to a dense `m×n` f32 matrix.
    pub fn dequantize(&self) -> Matrix {
        DEQUANT_CALLS.fetch_add(1, Ordering::Relaxed);
        if let Some(eff) = &self.effective {
            return eff.clone();
        }
        let mut w = Matrix::zeros(self.m, self.n);
        for i in 0..self.m {
            let g = self.scales.group_of(i);
            let row = w.row_mut(i);
            for (j, slot) in row.iter_mut().enumerate() {
                let s = self.scales.scales.get(g, j);
                let z = self.scales.zeros.get(g, j);
                *slot = s * (self.codes[i * self.n + j] as f32 - z);
            }
        }
        w
    }

    /// Serialized (packed) size in bytes: codes at `wbit` bits each plus
    /// f16-equivalent scale/zero tables — the number a deployment would
    /// ship. Used for the compression-ratio reporting in EXPERIMENTS.md.
    pub fn packed_bytes(&self) -> usize {
        if self.wbit == 0 {
            return self.m * self.n * 4;
        }
        let code_bits = self.m * self.n * self.wbit as usize;
        let table_entries = self.scales.scales.len() + self.scales.zeros.len();
        code_bits.div_ceil(8) + table_entries * 2
    }

    /// Pack codes into a dense little-endian bitstream.
    pub fn pack_codes(&self) -> Vec<u8> {
        pack_bits(&self.codes, self.wbit)
    }
}

/// Byte length of a [`pack_bits`] stream holding `n_codes` codes at
/// `wbit` bits each — the shared size formula between the packer, the
/// packed execution engine, and the OJBQ1 checkpoint reader/writer
/// (`crate::infer::io`), whose allocation caps and record framing must
/// agree with the packer bit for bit.
pub fn packed_len(n_codes: usize, wbit: u8) -> usize {
    (n_codes * wbit as usize).div_ceil(8)
}

/// Pack `codes` (values < 2^wbit) into a little-endian bitstream.
pub fn pack_bits(codes: &[u8], wbit: u8) -> Vec<u8> {
    assert!(wbit >= 1 && wbit <= 8);
    let mut out = vec![0u8; packed_len(codes.len(), wbit)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as u16) < (1u16 << wbit));
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + wbit as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += wbit as usize;
    }
    out
}

/// Inverse of [`pack_bits`]; `n` is the code count.
pub fn unpack_bits(packed: &[u8], wbit: u8, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_bits_range(packed, wbit, 0, &mut out);
    out
}

/// Unpack `out.len()` codes starting at code index `start` of a
/// [`pack_bits`] stream — the tile-row accessor of the packed execution
/// engine (`crate::infer`), which unpacks one row of a column tile at a
/// time into a stack buffer without touching the rest of the stream.
///
/// The deployment widths take **u64 bit-sliced fast paths**: one 8-byte
/// little-endian word load yields 16 W4 codes, 32 W2 codes, or (from its
/// low 48 bits) 16 W3 codes, each extracted with an in-register
/// shift+mask — replacing the byte-at-a-time 256-entry LUT walk
/// ([`unpack_bits_range_lut`], kept public as a secondary equivalence
/// reference). Other widths, the unaligned head, and the stream tail
/// where a full word load would run past the buffer fall back to the
/// per-code shift loop ([`unpack_bits_range_shift`]). Streams produced
/// by the packed engine are word-aligned (padded to a multiple of 8
/// bytes, `crate::infer::packed::PackedTiles`), so on the hot path the
/// word loop covers effectively every code.
pub fn unpack_bits_range(packed: &[u8], wbit: u8, start: usize, out: &mut [u8]) {
    match wbit {
        2 => unpack_range_w2_u64(packed, start, out),
        3 => unpack_range_w3_u64(packed, start, out),
        4 => unpack_range_w4_u64(packed, start, out),
        _ => unpack_bits_range_shift(packed, wbit, start, out),
    }
}

/// Table-driven unpack (the PR-3 fast path): one byte load decodes two
/// W4 codes or four W2 codes through a 256-entry LUT, and W3 decodes
/// eight codes per aligned 3-byte group from a u32. Superseded by the
/// u64 bit-sliced paths of [`unpack_bits_range`]; kept public as a
/// second equivalence reference (the three-way u64/LUT/shift agreement
/// tests) and a bench baseline (`fig_qgemm`).
pub fn unpack_bits_range_lut(packed: &[u8], wbit: u8, start: usize, out: &mut [u8]) {
    match wbit {
        2 => unpack_range_w2(packed, start, out),
        3 => unpack_range_w3(packed, start, out),
        4 => unpack_range_w4(packed, start, out),
        _ => unpack_bits_range_shift(packed, wbit, start, out),
    }
}

/// Load 8 little-endian bytes at `byte` as a u64 word.
#[inline]
fn load_word(packed: &[u8], byte: usize) -> u64 {
    u64::from_le_bytes(packed[byte..byte + 8].try_into().unwrap())
}

fn unpack_range_w4_u64(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    // Byte-align: W4 codes come two per byte.
    let lead = ((2 - start % 2) % 2).min(n);
    unpack_bits_range_shift(packed, 4, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) / 2;
    while n - o >= 16 && byte + 8 <= packed.len() {
        let w = load_word(packed, byte);
        for (k, slot) in out[o..o + 16].iter_mut().enumerate() {
            *slot = ((w >> (4 * k)) & 0xF) as u8;
        }
        byte += 8;
        o += 16;
    }
    unpack_bits_range_shift(packed, 4, start + o, &mut out[o..]);
}

fn unpack_range_w2_u64(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    // Byte-align: W2 codes come four per byte.
    let lead = ((4 - start % 4) % 4).min(n);
    unpack_bits_range_shift(packed, 2, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) / 4;
    while n - o >= 32 && byte + 8 <= packed.len() {
        let w = load_word(packed, byte);
        for (k, slot) in out[o..o + 32].iter_mut().enumerate() {
            *slot = ((w >> (2 * k)) & 0x3) as u8;
        }
        byte += 8;
        o += 32;
    }
    unpack_bits_range_shift(packed, 2, start + o, &mut out[o..]);
}

fn unpack_range_w3_u64(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    // Align to the 8-code / 3-byte period, then pull 16 codes from the
    // low 48 bits of each word load, advancing 6 bytes per iteration.
    let lead = ((8 - start % 8) % 8).min(n);
    unpack_bits_range_shift(packed, 3, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) * 3 / 8;
    while n - o >= 16 && byte + 8 <= packed.len() {
        let w = load_word(packed, byte);
        for (k, slot) in out[o..o + 16].iter_mut().enumerate() {
            *slot = ((w >> (3 * k)) & 0x7) as u8;
        }
        byte += 6;
        o += 16;
    }
    unpack_bits_range_shift(packed, 3, start + o, &mut out[o..]);
}

/// Reference per-code shift unpack (the pre-LUT kernel). Handles every
/// width `1..=8` and any alignment; the fast paths above must match it
/// bit for bit (see the `lut_unpack_matches_shift_unpack` test and the
/// `fig_qgemm` unpack microbench).
pub fn unpack_bits_range_shift(packed: &[u8], wbit: u8, start: usize, out: &mut [u8]) {
    assert!(wbit >= 1 && wbit <= 8);
    let mask = ((1u16 << wbit) - 1) as u8;
    let mut bitpos = start * wbit as usize;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + wbit as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        *slot = v & mask;
        bitpos += wbit as usize;
    }
}

/// Byte → two W4 codes (low nibble first, matching the little-endian
/// stream order of [`pack_bits`]).
static LUT_W4: [[u8; 2]; 256] = build_lut_w4();
/// Byte → four W2 codes.
static LUT_W2: [[u8; 4]; 256] = build_lut_w2();

const fn build_lut_w4() -> [[u8; 2]; 256] {
    let mut t = [[0u8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [(b & 0x0F) as u8, (b >> 4) as u8];
        b += 1;
    }
    t
}

const fn build_lut_w2() -> [[u8; 4]; 256] {
    let mut t = [[0u8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [(b & 3) as u8, ((b >> 2) & 3) as u8, ((b >> 4) & 3) as u8, (b >> 6) as u8];
        b += 1;
    }
    t
}

fn unpack_range_w4(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    let lead = ((2 - start % 2) % 2).min(n);
    unpack_bits_range_shift(packed, 4, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) / 2;
    for chunk in out[lead..].chunks_exact_mut(2) {
        let t = &LUT_W4[packed[byte] as usize];
        chunk[0] = t[0];
        chunk[1] = t[1];
        byte += 1;
        o += 2;
    }
    unpack_bits_range_shift(packed, 4, start + o, &mut out[o..]);
}

fn unpack_range_w2(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    let lead = ((4 - start % 4) % 4).min(n);
    unpack_bits_range_shift(packed, 2, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) / 4;
    for chunk in out[lead..].chunks_exact_mut(4) {
        chunk.copy_from_slice(&LUT_W2[packed[byte] as usize]);
        byte += 1;
        o += 4;
    }
    unpack_bits_range_shift(packed, 2, start + o, &mut out[o..]);
}

fn unpack_range_w3(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    // Eight W3 codes occupy exactly three bytes; align to that period,
    // then decode whole groups from one u32-assembled register.
    let lead = ((8 - start % 8) % 8).min(n);
    unpack_bits_range_shift(packed, 3, start, &mut out[..lead]);
    let mut o = lead;
    let mut byte = (start + lead) * 3 / 8;
    for chunk in out[lead..].chunks_exact_mut(8) {
        let w = packed[byte] as u32
            | ((packed[byte + 1] as u32) << 8)
            | ((packed[byte + 2] as u32) << 16);
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = ((w >> (3 * k)) & 7) as u8;
        }
        byte += 3;
        o += 8;
    }
    unpack_bits_range_shift(packed, 3, start + o, &mut out[o..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{scales, QuantConfig};
    use crate::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        let mut rng = Rng::new(1);
        for wbit in 1..=8u8 {
            let n = 257; // odd length to exercise tail handling
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(1 << wbit)) as u8).collect();
            let packed = pack_bits(&codes, wbit);
            assert_eq!(packed.len(), (n * wbit as usize).div_ceil(8));
            let back = unpack_bits(&packed, wbit, n);
            assert_eq!(back, codes, "wbit={wbit}");
        }
    }

    #[test]
    fn unpack_range_matches_full_unpack() {
        let mut rng = Rng::new(9);
        for wbit in [2u8, 3, 4, 5, 7] {
            let n = 301;
            let codes: Vec<u8> = (0..n).map(|_| (rng.below(1 << wbit)) as u8).collect();
            let packed = pack_bits(&codes, wbit);
            for &(start, len) in &[(0usize, 7usize), (13, 32), (250, 51), (300, 1), (64, 0)] {
                let mut out = vec![0u8; len];
                unpack_bits_range(&packed, wbit, start, &mut out);
                assert_eq!(out, &codes[start..start + len], "wbit={wbit} start={start}");
            }
        }
    }

    #[test]
    fn u64_and_lut_unpack_match_shift_unpack() {
        // Deployment widths, exhaustively over byte patterns: a stream
        // containing every code value adjacency, decoded at every start
        // offset and several lengths, must agree across all three
        // kernels — the u64 bit-sliced dispatch, the 256-entry LUT walk,
        // and the per-code shift reference — exactly.
        for &wbit in &[2u8, 3, 4] {
            let per_code = 1usize << wbit;
            // All pairs (a, b) of code values, flattened — covers every
            // packed byte pattern each width can produce.
            let codes: Vec<u8> = (0..per_code)
                .flat_map(|a| (0..per_code).flat_map(move |b| [a as u8, b as u8]))
                .collect();
            let packed = pack_bits(&codes, wbit);
            for start in 0..codes.len().min(40) {
                for len in [0usize, 1, 2, 7, 8, 9, 15, 16, 17, 31, 32, 33, codes.len() - start]
                {
                    if start + len > codes.len() {
                        continue;
                    }
                    let mut fast = vec![0xAAu8; len];
                    let mut lut = vec![0xBBu8; len];
                    let mut slow = vec![0x55u8; len];
                    unpack_bits_range(&packed, wbit, start, &mut fast);
                    unpack_bits_range_lut(&packed, wbit, start, &mut lut);
                    unpack_bits_range_shift(&packed, wbit, start, &mut slow);
                    assert_eq!(fast, slow, "u64 wbit={wbit} start={start} len={len}");
                    assert_eq!(lut, slow, "lut wbit={wbit} start={start} len={len}");
                    assert_eq!(fast, &codes[start..start + len], "wbit={wbit} vs source");
                }
            }
        }
    }

    #[test]
    fn dequantize_matches_formula() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(32, 6, 1.0, &mut rng);
        let cfg = QuantConfig { wbit: 4, group_size: 16, ..Default::default() };
        let sc = scales::compute(&w, &cfg);
        let codes: Vec<u8> = (0..32 * 6).map(|_| rng.below(16) as u8).collect();
        let q = QuantizedLinear::new(codes.clone(), sc.clone(), 4, 32, 6);
        let d = q.dequantize();
        for i in 0..32 {
            for j in 0..6 {
                let expect = sc.scale(i, j) * (codes[i * 6 + j] as f32 - sc.zero(i, j));
                assert!((d.get(i, j) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn identity_passthrough() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let q = QuantizedLinear::identity(&w);
        assert_eq!(q.dequantize(), w);
        assert_eq!(q.packed_bytes(), 8 * 8 * 4);
    }

    #[test]
    fn packed_bytes_compression_ratio() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(256, 64, 1.0, &mut rng);
        let cfg = QuantConfig { wbit: 4, group_size: 128, ..Default::default() };
        let sc = scales::compute(&w, &cfg);
        let q = QuantizedLinear::new(vec![0u8; 256 * 64], sc, 4, 256, 64);
        let fp_bytes = 256 * 64 * 4;
        let ratio = fp_bytes as f64 / q.packed_bytes() as f64;
        // 4-bit + small tables ≈ 7-8x compression over f32.
        assert!(ratio > 6.0, "ratio={ratio}");
    }
}
