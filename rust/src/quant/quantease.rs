//! QuantEase-style cyclic coordinate descent on the shared-factor
//! engine (Behdin et al., PAPERS.md) — the first *iterative* solver
//! family mounted on [`FactoredSystem`].
//!
//! Both iterative families (this module and [`super::admmq`]) minimize
//! the same per-column quadratic the OJBKQ decode minimizes, written in
//! weight space:
//!
//! `f(ŵ) = ŵᵀGŵ − 2ŵᵀb`,  `G = X̃ᵀX̃ + λ²I`,  `b = X̃ᵀy* + λ²w`
//!
//! which equals the JTA objective `S(Ŵ)` (Eq. 7) up to the
//! ŵ-independent constant `‖y*‖² + λ²‖w‖²`. Since `G·w_real = b`, the
//! unconstrained optimum scores `f(w_real) = −w_realᵀb`, and
//! `f(q) − f(w_real) = ‖R(s⊙(q−q̄))‖²` — exactly the lattice residual
//! the Babai/Klein decoder reports — so [`IterStats::resid`] lands in
//! the same `decode_resid` diagnostic the single-pass family fills.
//!
//! Algorithm (QuantEase §3): hold every coordinate of a column fixed
//! but one; the restriction of `f` to coordinate `i` is an exact
//! 1-D quadratic minimized at `w*_i = ŵ_i + r_i/G_ii` where
//! `r = b − Gŵ` is the maintained residual. Snapping `w*_i` to the
//! nearest grid point `s(q−z)` can only decrease `f` (the grid is
//! uniform along the axis), giving per-update descent
//! `Δf = G_ii·δ² − 2δ·r_i ≤ 0` — each accepted update is additionally
//! guarded by that inequality in f64, so the per-sweep objective trace
//! is non-increasing **by construction**, not just in expectation.
//!
//! Warm start: the column's initial codes are the better (per column,
//! by `f`) of the Babai/Klein decode ([`ojbkq::quantize_with_diag`] on
//! the same shared factor) and plain RTN — hence the final objective
//! can never be worse than either initializer.
//!
//! The factor contract: QuantEase consumes Gram **rows** (`G[:,i]`
//! for the residual update), so it requires a [`FactoredSystem`] built
//! with the Gram resident ([`FactoredSystem::for_ojbkq_with_gram`]);
//! a lean decode-only factor is rejected by `check_for`, never
//! silently mis-decoded. Columns are independent, so the sweep fans
//! out over column tiles with [`parallel_map`] — all inner arithmetic
//! is serial f64 per column, making codes bit-identical at any
//! `OJBKQ_THREADS`.

use super::factored::{FactorKind, FactoredSystem};
use super::scales::GroupScales;
use super::{jta, ojbkq, scales, QuantConfig, QuantizedLinear};
use crate::parallel::parallel_map;
use crate::rng::Rng;
use crate::runtime::SolverRuntime;
use crate::tensor::Matrix;

/// Hard cap on coordinate-descent sweeps; in practice columns converge
/// (no code changes in a full sweep) in 2–5 sweeps.
pub const MAX_SWEEPS: usize = 12;

/// Convergence record of one iterative solve (QuantEase sweeps or ADMM
/// iterations) — the iterative-family analogue of
/// [`super::ojbkq::DecodeDiag`]. All objectives are the shifted JTA
/// quadratic `f(ŵ) = ŵᵀGŵ − 2ŵᵀb`, summed over columns, evaluated in
/// f64.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IterStats {
    /// Objective of the chosen initialization (per-column best of the
    /// Babai/Klein warm start and RTN).
    pub init_obj: f64,
    /// Objective of the Babai/Klein warm-start candidate alone.
    pub warm_obj: f64,
    /// Objective of the RTN candidate alone.
    pub rtn_obj: f64,
    /// Objective of the unconstrained solution `w_real` — the lower
    /// bound `f(w_real) = −w_realᵀb` every integer assignment sits
    /// above.
    pub obj_real: f64,
    /// Objective after each sweep / iteration; `obj_trace[0] ==
    /// init_obj`, and the sequence is non-increasing by construction.
    pub obj_trace: Vec<f64>,
    /// Sweeps (QuantEase) or ADMM iterations executed — the max over
    /// columns for the tile-parallel sweep.
    pub iters: u64,
    /// Codes in the final assignment that differ from the chosen
    /// initialization.
    pub changed: u64,
}

impl IterStats {
    /// Objective of the returned codes.
    pub fn final_obj(&self) -> f64 {
        *self.obj_trace.last().unwrap_or(&self.init_obj)
    }

    /// `f(q) − f(w_real)` — the lattice residual `‖R(s⊙(q−q̄))‖²` of
    /// the returned codes (maps onto `LayerStats::decode_resid`).
    pub fn resid(&self) -> f64 {
        self.final_obj() - self.obj_real
    }

    /// Same residual for the initialization (maps onto
    /// `LayerStats::greedy_resid`, the "what the warm start alone
    /// would have scored" column).
    pub fn init_resid(&self) -> f64 {
        self.init_obj - self.obj_real
    }
}

/// One column's workspace: codes plus the f64 dequantized weight and
/// maintained residual `r = b − Gŵ`.
struct ColState {
    q: Vec<u8>,
    /// `ŵ_i = s_i·(q_i − z_i)` in f64.
    w_hat: Vec<f64>,
    /// `r = b − Gŵ` in f64.
    resid: Vec<f64>,
    /// `f(ŵ) = −ŵᵀ(r + b)`.
    obj: f64,
}

/// Build a column state from codes: dequantize, form the residual by a
/// full f64 `Gŵ`, and score. `O(m²)`.
fn col_state(gram: &Matrix, b: &[f64], s: &[f64], z: &[f64], q: Vec<u8>) -> ColState {
    let m = b.len();
    let w_hat: Vec<f64> = (0..m).map(|i| s[i] * (q[i] as f64 - z[i])).collect();
    let mut resid = vec![0.0f64; m];
    for i in 0..m {
        let g_row = gram.row(i);
        let mut acc = 0.0f64;
        for k in 0..m {
            acc += g_row[k] as f64 * w_hat[k];
        }
        resid[i] = b[i] - acc;
    }
    let obj = -(0..m).map(|i| w_hat[i] * (resid[i] + b[i])).sum::<f64>();
    ColState { q, w_hat, resid, obj }
}

/// Per-column scale/zero/RHS vectors in f64, hoisted once per column.
pub(crate) fn col_grid(sc: &GroupScales, j: usize, m: usize) -> (Vec<f64>, Vec<f64>) {
    let s: Vec<f64> = (0..m).map(|i| sc.scale(i, j) as f64).collect();
    let z: Vec<f64> = (0..m).map(|i| sc.zero(i, j) as f64).collect();
    (s, z)
}

/// `f(ŵ) = ŵᵀGŵ − 2ŵᵀb` for one dequantized column, f64 throughout.
pub(crate) fn col_obj_f64(gram: &Matrix, b: &[f64], w_hat: &[f64]) -> f64 {
    let m = b.len();
    let mut obj = 0.0f64;
    for i in 0..m {
        let g_row = gram.row(i);
        let mut gw = 0.0f64;
        for k in 0..m {
            gw += g_row[k] as f64 * w_hat[k];
        }
        obj += w_hat[i] * (gw - 2.0 * b[i]);
    }
    obj
}

/// Result of refining one column.
struct ColOut {
    q: Vec<u8>,
    warm_obj: f64,
    rtn_obj: f64,
    init_obj: f64,
    /// Σ of accepted `Δf` per sweep (each entry ≤ 0).
    sweep_deltas: Vec<f64>,
    changed: u64,
}

/// Cyclic CD on one column: pick the better of the two candidate code
/// vectors, then sweep until a full pass changes nothing.
fn refine_col(
    gram: &Matrix,
    b: &[f64],
    s: &[f64],
    z: &[f64],
    qmax: u8,
    warm: Vec<u8>,
    rtn: Vec<u8>,
) -> ColOut {
    let m = b.len();
    let warm_st = col_state(gram, b, s, z, warm);
    let rtn_st = col_state(gram, b, s, z, rtn);
    let (warm_obj, rtn_obj) = (warm_st.obj, rtn_st.obj);
    // Ties go to the warm start (deterministic either way).
    let mut st = if rtn_st.obj < warm_st.obj { rtn_st } else { warm_st };
    let init_obj = st.obj;
    let init_q = st.q.clone();
    let mut sweep_deltas = Vec::new();
    for _sweep in 0..MAX_SWEEPS {
        let mut delta_sum = 0.0f64;
        let mut changes = 0u32;
        for i in 0..m {
            let g_row = gram.row(i);
            let g_ii = g_row[i] as f64;
            if g_ii <= 0.0 {
                continue;
            }
            // Exact 1-D minimizer along coordinate i, snapped to grid.
            let w_star = st.w_hat[i] + st.resid[i] / g_ii;
            let qf = (w_star / s[i] + z[i]).round().clamp(0.0, qmax as f64);
            let q_new = qf as u8;
            if q_new == st.q[i] {
                continue;
            }
            let delta = s[i] * (q_new as f64 - z[i]) - st.w_hat[i];
            let df = g_ii * delta * delta - 2.0 * delta * st.resid[i];
            // Descent guard: nearest-grid snapping implies df ≤ 0 in
            // exact arithmetic; reject the (rounding-noise) exceptions
            // so the trace is non-increasing by construction.
            if df >= 0.0 {
                continue;
            }
            st.q[i] = q_new;
            st.w_hat[i] += delta;
            for k in 0..m {
                st.resid[k] -= g_row[k] as f64 * delta;
            }
            delta_sum += df;
            changes += 1;
        }
        if changes == 0 {
            break;
        }
        sweep_deltas.push(delta_sum);
    }
    let changed = st.q.iter().zip(&init_q).filter(|(a, b)| a != b).count() as u64;
    ColOut { q: st.q, warm_obj, rtn_obj, init_obj, sweep_deltas, changed }
}

/// Quantize one layer with QuantEase coordinate descent. Signature and
/// sharing contract match [`ojbkq::quantize_with`]; additionally
/// returns the [`IterStats`] convergence record. The shared factor (if
/// any) must have been built Gram-resident
/// ([`FactoredSystem::for_method`] does this for `Method::QuantEase`).
#[allow(clippy::too_many_arguments)]
pub fn quantize_with(
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
    rt: Option<&SolverRuntime>,
    shared: Option<&FactoredSystem>,
) -> anyhow::Result<(QuantizedLinear, IterStats)> {
    let (m, n) = w.shape();
    let owned_sys;
    let sys: &FactoredSystem = match shared {
        Some(s) => {
            s.check_for(FactorKind::Ojbkq, m, cfg, true)?;
            s
        }
        None => {
            owned_sys = FactoredSystem::for_ojbkq_with_gram(x_rt, cfg)?;
            &owned_sys
        }
    };
    let gram = sys.gram()?;
    // Babai/Klein warm start on the *same* factor — same λ, ordering and
    // scales, so its codes live in the same permuted grid refined below.
    let (warm_q, _) = ojbkq::quantize_with_diag(w, x_fp, x_rt, cfg, rng, rt, Some(sys))?;
    let rhs = jta::build_rhs(w, x_fp, x_rt, sys.lambda_sq, cfg);
    let permuted = sys.permuted;
    let perm = &sys.perm;
    let rhs_p_store;
    let rhs_p: &Matrix = if permuted {
        rhs_p_store = rhs.permute_rows(perm);
        &rhs_p_store
    } else {
        &rhs
    };
    let w_p_store;
    let w_p: &Matrix = if permuted {
        w_p_store = w.permute_rows(perm);
        &w_p_store
    } else {
        w
    };
    let sc = scales::compute(w_p, cfg);
    debug_assert_eq!(warm_q.scales.scales.as_slice(), sc.scales.as_slice());
    let w_real = jta::solve_real(&sys.r, rhs_p);
    // f(w_real) = −w_realᵀb, the unconstrained lower bound.
    let obj_real: f64 = -(0..m)
        .map(|i| {
            let wr = w_real.row(i);
            let br = rhs_p.row(i);
            wr.iter().zip(br).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>()
        })
        .sum::<f64>();
    let qmax = cfg.box_max();
    let ntile = cfg.ntile.max(1).min(n.max(1));
    let n_tiles = n.div_ceil(ntile);
    struct TileOut {
        codes: Vec<u8>, // row-major m×width
        warm_obj: f64,
        rtn_obj: f64,
        init_obj: f64,
        sweep_deltas: Vec<f64>,
        max_sweeps: usize,
        changed: u64,
    }
    let outs: Vec<TileOut> = parallel_map(n_tiles, |t| {
        let c0 = t * ntile;
        let width = ntile.min(n - c0);
        let mut out = TileOut {
            codes: vec![0u8; m * width],
            warm_obj: 0.0,
            rtn_obj: 0.0,
            init_obj: 0.0,
            sweep_deltas: Vec::new(),
            max_sweeps: 0,
            changed: 0,
        };
        for jj in 0..width {
            let j = c0 + jj;
            let (s, z) = col_grid(&sc, j, m);
            let b: Vec<f64> = (0..m).map(|i| rhs_p.get(i, j) as f64).collect();
            let warm: Vec<u8> = (0..m).map(|i| warm_q.codes[i * n + j]).collect();
            // Bit-identical to `rtn::quantize_with_scales` on this grid.
            let rtn: Vec<u8> = (0..m)
                .map(|i| {
                    super::rtn::round_code(
                        w_p.get(i, j) / sc.scale(i, j) + sc.zero(i, j),
                        qmax as f32,
                    ) as u8
                })
                .collect();
            let col = refine_col(gram, &b, &s, &z, qmax, warm, rtn);
            for i in 0..m {
                out.codes[i * width + jj] = col.q[i];
            }
            out.warm_obj += col.warm_obj;
            out.rtn_obj += col.rtn_obj;
            out.init_obj += col.init_obj;
            out.changed += col.changed;
            out.max_sweeps = out.max_sweeps.max(col.sweep_deltas.len());
            if out.sweep_deltas.len() < col.sweep_deltas.len() {
                out.sweep_deltas.resize(col.sweep_deltas.len(), 0.0);
            }
            for (acc, d) in out.sweep_deltas.iter_mut().zip(&col.sweep_deltas) {
                *acc += d;
            }
        }
        out
    });
    let mut codes = vec![0u8; m * n];
    let mut stats = IterStats { obj_real, ..Default::default() };
    let mut sweep_deltas: Vec<f64> = Vec::new();
    for (t, out) in outs.iter().enumerate() {
        let c0 = t * ntile;
        let width = ntile.min(n - c0);
        for i in 0..m {
            codes[i * n + c0..i * n + c0 + width]
                .copy_from_slice(&out.codes[i * width..(i + 1) * width]);
        }
        stats.warm_obj += out.warm_obj;
        stats.rtn_obj += out.rtn_obj;
        stats.init_obj += out.init_obj;
        stats.changed += out.changed;
        if sweep_deltas.len() < out.sweep_deltas.len() {
            sweep_deltas.resize(out.sweep_deltas.len(), 0.0);
        }
        for (acc, d) in sweep_deltas.iter_mut().zip(&out.sweep_deltas) {
            *acc += d;
        }
    }
    stats.iters = sweep_deltas.len() as u64;
    stats.obj_trace = Vec::with_capacity(sweep_deltas.len() + 1);
    stats.obj_trace.push(stats.init_obj);
    let mut acc = stats.init_obj;
    for d in &sweep_deltas {
        acc += d;
        stats.obj_trace.push(acc);
    }
    let mut q = QuantizedLinear::new(codes, sc, cfg.wbit, m, n);
    if permuted {
        let inv = crate::tensor::invert_perm(perm);
        let w_hat = q.dequantize().permute_rows(&inv);
        q.effective = Some(w_hat);
        q.perm = Some(perm.iter().map(|&p| p as u32).collect());
    }
    Ok((q, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.5, &mut rng);
        let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
        let noise = Matrix::randn(p, m, 0.05, &mut rng);
        let x_rt = x_fp.add(&noise);
        (w, x_fp, x_rt)
    }

    #[test]
    fn trace_is_monotone_and_dominates_both_inits() {
        for seed in [1u64, 2, 3] {
            let (w, x_fp, x_rt) = layer(32, 24, 64, seed);
            let cfg =
                QuantConfig { wbit: 3, group_size: 16, ntile: 10, ..Default::default() };
            let mut rng = Rng::new(seed);
            let (_, it) =
                quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, None).unwrap();
            assert_eq!(it.obj_trace[0], it.init_obj);
            for win in it.obj_trace.windows(2) {
                assert!(win[1] <= win[0], "trace increased: {win:?}");
            }
            // Per-column best-of-two init + monotone descent ⇒ the final
            // objective can never be worse than either initializer.
            assert!(it.final_obj() <= it.warm_obj + 1e-9);
            assert!(it.final_obj() <= it.rtn_obj + 1e-9);
            assert!(it.init_obj <= it.warm_obj.min(it.rtn_obj) + 1e-9);
            // Residuals vs the unconstrained optimum are non-negative.
            assert!(it.resid() >= -1e-6, "resid {}", it.resid());
            assert!(it.resid() <= it.init_resid() + 1e-9);
        }
    }

    #[test]
    fn refinement_improves_runtime_error_over_rtn() {
        let (w, x_fp, x_rt) = layer(48, 32, 96, 7);
        let cfg = QuantConfig { wbit: 3, group_size: 0, ntile: 16, ..Default::default() };
        let mut rng = Rng::new(7);
        let (q, it) = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, None).unwrap();
        let q_rtn = super::super::rtn::quantize(&w, &cfg);
        let err = |wh: &Matrix| matmul(&x_rt, wh).sub(&matmul(&x_rt, &w)).frob();
        // Strict objective dominance is guaranteed; the runtime-error
        // proxy follows it on every seed we pin.
        assert!(it.final_obj() <= it.rtn_obj);
        assert!(err(&q.dequantize()) < err(&q_rtn.dequantize()));
    }

    #[test]
    fn codes_respect_box_and_shapes() {
        let (w, x_fp, x_rt) = layer(20, 10, 40, 5);
        let cfg = QuantConfig { wbit: 3, group_size: 8, ntile: 4, ..Default::default() };
        let mut rng = Rng::new(5);
        let (q, it) = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, None).unwrap();
        assert_eq!((q.m, q.n), (20, 10));
        assert!(q.codes.iter().all(|&c| c <= 7));
        assert!(it.iters <= MAX_SWEEPS as u64);
    }

    #[test]
    fn lean_factor_is_rejected() {
        let (w, x_fp, x_rt) = layer(16, 8, 32, 9);
        let cfg = QuantConfig::default();
        let lean = FactoredSystem::for_ojbkq(&x_rt, &cfg).unwrap();
        let mut rng = Rng::new(9);
        let err = quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, Some(&lean))
            .unwrap_err()
            .to_string();
        assert!(err.contains("Gram"), "unexpected error: {err}");
    }
}
