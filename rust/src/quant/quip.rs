//! QuIP-style baseline (Chee et al., 2024): incoherence processing with
//! random orthogonal rotations + LDLQ-style greedy decoding.
//!
//! The weight is conjugated by Haar-random orthogonal matrices,
//! `W̃ = Uᵀ W V`, which spreads outliers ("incoherence"), the Hessian is
//! rotated accordingly (`H̃ = Uᵀ H U`), the rotated weight is quantized
//! with the compensation-based greedy solver (LDLQ ≙ our GPTQ core), and
//! the effective runtime weight is `Ŵ = U·dq(W̃)·Vᵀ`.
//!
//! Matching the paper's observation, this baseline is strong at g=0 on
//! well-behaved models but brittle on small/sensitive ones — the rotation
//! spreads *every* column's range, so per-group scale adaptation is lost
//! (rotated weights don't align with group boundaries).

use super::{gptq, QuantConfig, QuantizedLinear};
use crate::linalg::{matmul, random_orthogonal};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// QuIP-quantize a layer against runtime activations `x` (`p×m`).
pub fn quantize(
    w: &Matrix,
    x: &Matrix,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> anyhow::Result<QuantizedLinear> {
    let (m, n) = w.shape();
    assert_eq!(x.cols(), m);
    let u = random_orthogonal(m, rng);
    let v = random_orthogonal(n, rng);
    // W̃ = Uᵀ W V.
    let w_rot = matmul(&matmul(&u.transpose(), w), &v);
    // Rotated activations: y = xW = (xU)(UᵀWV)Vᵀ, so the solver sees X̃U.
    let x_rot = matmul(x, &u);
    // LDLQ on the rotated problem. QuIP does not use activation ordering.
    let quip_cfg = QuantConfig { act_order: false, ..cfg.clone() };
    let mut q = gptq::quantize(&w_rot, &x_rot, &quip_cfg)?;
    // Effective runtime weight: undo the rotation.
    let w_hat_rot = q.dequantize();
    let w_hat = matmul(&matmul(&u, &w_hat_rot), &v.transpose());
    q.effective = Some(w_hat);
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn;

    fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        // Weight with strong outlier rows — the case incoherence helps.
        let mut w = Matrix::randn(m, n, 0.3, &mut rng);
        for j in 0..n {
            w.set(3 % m, j, w.get(3 % m, j) * 10.0);
        }
        let x = Matrix::randn(p, m, 1.0, &mut rng);
        (w, x)
    }

    fn rt_err(w_hat: &Matrix, w: &Matrix, x: &Matrix) -> f64 {
        matmul(x, w_hat).sub(&matmul(x, w)).frob()
    }

    #[test]
    fn quip_runs_and_is_reasonable_at_g0() {
        let (w, x) = layer(32, 16, 64, 1);
        let cfg = QuantConfig { wbit: 4, group_size: 0, ..Default::default() };
        let mut rng = Rng::new(2);
        let q = quantize(&w, &x, &cfg, &mut rng).unwrap();
        let e_quip = rt_err(&q.dequantize(), &w, &x);
        let e_rtn = rt_err(&rtn::quantize(&w, &cfg).dequantize(), &w, &x);
        // At g=0 with outliers, incoherence should not be catastrophically
        // worse than RTN (it is usually much better).
        assert!(e_quip < e_rtn * 1.2, "quip {e_quip} vs rtn {e_rtn}");
    }

    #[test]
    fn rotation_roundtrip_at_high_bits() {
        // At 8 bits the quantization error is tiny, so Ŵ ≈ W through the
        // rotate→quantize→unrotate pipeline — catches transform bugs.
        let (w, x) = layer(24, 12, 48, 3);
        let cfg = QuantConfig { wbit: 8, group_size: 0, ..Default::default() };
        let mut rng = Rng::new(4);
        let q = quantize(&w, &x, &cfg, &mut rng).unwrap();
        let rel = q.dequantize().rel_err(&w);
        assert!(rel < 0.02, "rel={rel}");
    }

    #[test]
    fn effective_shape_and_finite() {
        let (w, x) = layer(16, 20, 32, 5);
        let cfg = QuantConfig { wbit: 3, group_size: 0, ..Default::default() };
        let mut rng = Rng::new(6);
        let q = quantize(&w, &x, &cfg, &mut rng).unwrap();
        let eff = q.dequantize();
        assert_eq!(eff.shape(), (16, 20));
        assert!(eff.all_finite());
    }

    #[test]
    fn different_seeds_different_rotations_similar_quality() {
        let (w, x) = layer(24, 12, 48, 7);
        let cfg = QuantConfig { wbit: 4, group_size: 0, ..Default::default() };
        let mut r1 = Rng::new(10);
        let mut r2 = Rng::new(20);
        let e1 = rt_err(&quantize(&w, &x, &cfg, &mut r1).unwrap().dequantize(), &w, &x);
        let e2 = rt_err(&quantize(&w, &x, &cfg, &mut r2).unwrap().dequantize(), &w, &x);
        let ratio = e1 / e2.max(1e-12);
        assert!((0.5..2.0).contains(&ratio), "e1={e1} e2={e2}");
    }
}
