//! Round-to-nearest (RTN) — the naive baseline: `q = clamp(⌊w/s + z⌉)`.
//! Also the building block AWQ reuses after rescaling.

use super::scales::{self, GroupScales};
use super::{QuantConfig, QuantizedLinear};
use crate::tensor::Matrix;

/// RTN-quantize a weight matrix under `cfg`.
pub fn quantize(w: &Matrix, cfg: &QuantConfig) -> QuantizedLinear {
    let sc = scales::compute(w, cfg);
    quantize_with_scales(w, &sc, cfg)
}

/// RTN with externally-provided scales (AWQ path, GPTQ static groups).
pub fn quantize_with_scales(w: &Matrix, sc: &GroupScales, cfg: &QuantConfig) -> QuantizedLinear {
    let (m, n) = w.shape();
    let qmax = cfg.box_max() as f32;
    let mut codes = vec![0u8; m * n];
    for i in 0..m {
        let g = sc.group_of(i);
        let row = w.row(i);
        for j in 0..n {
            let s = sc.scales.get(g, j);
            let z = sc.zeros.get(g, j);
            let q = (row[j] / s + z).round().clamp(0.0, qmax);
            codes[i * n + j] = q as u8;
        }
    }
    QuantizedLinear::new(codes, sc.clone(), cfg.wbit, m, n)
}

/// Scalar RTN in code space: `clamp(round(c), 0, qmax)` — shared helper
/// for the greedy paths of every lattice solver.
#[inline]
pub fn round_code(c: f32, qmax: f32) -> f32 {
    c.round().clamp(0.0, qmax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn rtn_error_bounded_by_half_scale() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(64, 16, 1.0, &mut rng);
        let cfg = QuantConfig { wbit: 4, group_size: 32, ..Default::default() };
        let q = quantize(&w, &cfg);
        let d = q.dequantize();
        for i in 0..64 {
            for j in 0..16 {
                let s = q.scales.scale(i, j);
                let err = (d.get(i, j) - w.get(i, j)).abs();
                assert!(err <= 0.5 * s + 1e-5, "err={err} s={s}");
            }
        }
    }

    #[test]
    fn exactly_representable_weights_roundtrip() {
        // Build weights already on the quantization grid: w = s*(q-z).
        let mut rng = Rng::new(2);
        let m = 32;
        let n = 4;
        let cfg = QuantConfig { wbit: 4, group_size: 0, ..Default::default() };
        let mut grid = Matrix::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                grid.set(i, j, 0.1 * (rng.below(16) as f32 - 8.0));
            }
            // Pin the column's extremes so the calibrated scale matches
            // the construction grid exactly (s = 1.5/15 = 0.1, z = 8).
            grid.set(0, j, 0.1 * (0.0 - 8.0));
            grid.set(1, j, 0.1 * (15.0 - 8.0));
        }
        let q = quantize(&grid, &cfg);
        let d = q.dequantize();
        assert!(d.rel_err(&grid) < 1e-4, "rel={}", d.rel_err(&grid));
    }

    #[test]
    fn lower_bits_more_error() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(128, 8, 1.0, &mut rng);
        let e3 = {
            let cfg = QuantConfig { wbit: 3, group_size: 128, ..Default::default() };
            quantize(&w, &cfg).dequantize().sub(&w).frob()
        };
        let e4 = {
            let cfg = QuantConfig { wbit: 4, group_size: 128, ..Default::default() };
            quantize(&w, &cfg).dequantize().sub(&w).frob()
        };
        let e8 = {
            let cfg = QuantConfig { wbit: 8, group_size: 128, ..Default::default() };
            quantize(&w, &cfg).dequantize().sub(&w).frob()
        };
        assert!(e3 > e4 && e4 > e8, "e3={e3} e4={e4} e8={e8}");
    }

    #[test]
    fn smaller_groups_no_worse() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(256, 8, 1.0, &mut rng);
        let err = |gs: usize| {
            let cfg = QuantConfig { wbit: 3, group_size: gs, ..Default::default() };
            quantize(&w, &cfg).dequantize().sub(&w).frob()
        };
        // Finer groups adapt scales better -> monotone (weakly) lower error.
        assert!(err(32) <= err(128) * 1.02);
        assert!(err(128) <= err(0) * 1.02);
    }

    #[test]
    fn round_code_clamps() {
        assert_eq!(round_code(-3.2, 15.0), 0.0);
        assert_eq!(round_code(20.0, 15.0), 15.0);
        assert_eq!(round_code(7.4, 15.0), 7.0);
        assert_eq!(round_code(7.5, 15.0), 8.0);
    }
}
