//! Group-wise scale / zero-point calibration (the paper's `S`, `Z`
//! matrices, §3.2). Standard asymmetric min–max ("Absmax-style") statistics
//! computed per (row-group, output-column) cell:
//!
//! `s = (max − min) / (2^b − 1)`, `z = clamp(round(−min/s), 0, 2^b−1)`,
//! so `ŵ = s · (q − z)` covers the group's range.
//!
//! In the BILS formulation `D_j = diag(s_j)` is the per-column diagonal
//! scale (the group structure is just a piecewise-constant pattern in
//! `s_j`), so these vectors feed straight into `R̄ = R·D`.

use super::QuantConfig;
use crate::tensor::Matrix;

/// Per-layer scale/zero-point tables: `(n_groups × n)` matrices plus the
/// grouping metadata needed to expand them to full per-row vectors.
#[derive(Debug, Clone)]
pub struct GroupScales {
    /// Scales, `n_groups × n`.
    pub scales: Matrix,
    /// Zero-points (stored as f32 integers), `n_groups × n`.
    pub zeros: Matrix,
    /// Rows per group (last group may be short).
    pub group_size: usize,
    /// Number of weight rows `m`.
    pub m: usize,
}

impl GroupScales {
    /// Number of groups.
    pub fn n_groups(&self) -> usize {
        self.scales.rows()
    }

    /// Group index of row `i`.
    #[inline]
    pub fn group_of(&self, i: usize) -> usize {
        i / self.group_size
    }

    /// Scale for (row, col).
    #[inline]
    pub fn scale(&self, i: usize, j: usize) -> f32 {
        self.scales.get(self.group_of(i), j)
    }

    /// Zero-point for (row, col).
    #[inline]
    pub fn zero(&self, i: usize, j: usize) -> f32 {
        self.zeros.get(self.group_of(i), j)
    }

    /// Expand column `j`'s scales to a full length-`m` vector
    /// (`s_j` in the paper — the diagonal of `D_j`).
    pub fn col_scale_vec(&self, j: usize) -> Vec<f32> {
        (0..self.m).map(|i| self.scale(i, j)).collect()
    }

    /// Expand column `j`'s zero-points to a full length-`m` vector.
    pub fn col_zero_vec(&self, j: usize) -> Vec<f32> {
        (0..self.m).map(|i| self.zero(i, j)).collect()
    }

    /// Full `m × ntile` scale matrix for columns `[c0, c0+w)` — the `S`
    /// tile handed to the PPI decoder / PJRT artifact.
    pub fn scale_tile(&self, c0: usize, w: usize) -> Matrix {
        Matrix::from_fn(self.m, w, |i, j| self.scale(i, c0 + j))
    }

    /// Full `m × ntile` zero-point matrix for columns `[c0, c0+w)`.
    pub fn zero_tile(&self, c0: usize, w: usize) -> Matrix {
        Matrix::from_fn(self.m, w, |i, j| self.zero(i, c0 + j))
    }
}

/// Compute asymmetric min–max scales/zeros for `w` (`m×n`) under `cfg`.
/// Degenerate groups (constant weight) get `s = 1, z = clamp(round(-w))`
/// …actually `s=1, z` chosen so the constant is representable exactly.
pub fn compute(w: &Matrix, cfg: &QuantConfig) -> GroupScales {
    let (m, n) = w.shape();
    let gs = cfg.effective_group(m);
    let n_groups = m.div_ceil(gs);
    let qmax = cfg.box_max() as f32;
    let mut scales = Matrix::zeros(n_groups, n);
    let mut zeros = Matrix::zeros(n_groups, n);
    for g in 0..n_groups {
        let r0 = g * gs;
        let r1 = (r0 + gs).min(m);
        for j in 0..n {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in r0..r1 {
                let v = w.get(i, j);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            // Always include zero in the range so zero weights stay exact
            // (standard practice; keeps RTN sane on sparse rows).
            lo = lo.min(0.0);
            hi = hi.max(0.0);
            let range = hi - lo;
            if range <= 0.0 || !range.is_finite() {
                scales.set(g, j, 1.0);
                zeros.set(g, j, 0.0);
                continue;
            }
            let s = range / qmax;
            let z = (-lo / s).round().clamp(0.0, qmax);
            scales.set(g, j, s);
            zeros.set(g, j, z);
        }
    }
    GroupScales { scales, zeros, group_size: gs, m }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn cfg(wbit: u8, gs: usize) -> QuantConfig {
        QuantConfig { wbit, group_size: gs, ..Default::default() }
    }

    #[test]
    fn ranges_are_covered() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(64, 8, 1.0, &mut rng);
        let sc = compute(&w, &cfg(4, 16));
        assert_eq!(sc.n_groups(), 4);
        // Every weight must be inside [s*(0-z), s*(qmax-z)] of its cell up
        // to the half-step the integer zero-point rounding can shift the
        // representable window by.
        for j in 0..8 {
            for i in 0..64 {
                let s = sc.scale(i, j);
                let z = sc.zero(i, j);
                let lo = s * (0.0 - z);
                let hi = s * (15.0 - z);
                let v = w.get(i, j);
                let slack = 0.5 * s + 1e-4;
                assert!(
                    v >= lo - slack && v <= hi + slack,
                    "w[{i},{j}]={v} not in [{lo},{hi}]±{slack}"
                );
            }
        }
    }

    #[test]
    fn group_zero_means_whole_column() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(100, 3, 1.0, &mut rng);
        let sc = compute(&w, &cfg(4, 0));
        assert_eq!(sc.n_groups(), 1);
        assert_eq!(sc.group_size, 100);
    }

    #[test]
    fn degenerate_group_safe() {
        let w = Matrix::zeros(32, 2);
        let sc = compute(&w, &cfg(4, 16));
        for g in 0..sc.n_groups() {
            assert_eq!(sc.scales.get(g, 0), 1.0);
            assert_eq!(sc.zeros.get(g, 0), 0.0);
        }
    }

    #[test]
    fn tiles_match_point_lookups() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(48, 10, 1.0, &mut rng);
        let sc = compute(&w, &cfg(3, 16));
        let tile = sc.scale_tile(4, 3);
        for i in 0..48 {
            for j in 0..3 {
                assert_eq!(tile.get(i, j), sc.scale(i, 4 + j));
            }
        }
        let sv = sc.col_scale_vec(7);
        for i in 0..48 {
            assert_eq!(sv[i], sc.scale(i, 7));
        }
    }

    #[test]
    fn zero_is_exactly_representable() {
        let mut rng = Rng::new(4);
        let w = Matrix::rand_uniform(32, 4, 0.5, 1.5, &mut rng); // all-positive
        let sc = compute(&w, &cfg(4, 32));
        for j in 0..4 {
            let s = sc.scale(0, j);
            let z = sc.zero(0, j);
            // Dequantizing code z gives exactly 0.
            assert_eq!(s * (z - z), 0.0);
            // And 0 is inside the box image.
            assert!(z >= 0.0 && z <= 15.0);
        }
    }
}
