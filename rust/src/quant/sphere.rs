//! Box-constrained Schnorr–Euchner sphere decoding — the *optimal* BILS
//! solver (paper §2: "the Babai point is the first integer point found by
//! the Schnorr-Euchner sphere-decoding algorithm, which enumerates
//! integer points in an ellipsoid to find the optimal solution", extended
//! to the box-constrained case per Wen & Chang 2021).
//!
//! Exponential worst case — usable only for small/medium `m` — but
//! invaluable as an **optimality oracle**: property tests verify that
//! every suboptimal solver (Babai, Klein K-best, PPI) never beats it, and
//! `rust/benches/ablation_design.rs` quantifies the Babai→optimal gap
//! that Random-K is designed to close.
//!
//! Depth-first enumeration in the same weight-space-error coordinates as
//! [`super::babai`]: at level `i`, candidate code `v` contributes
//! `(R(i,i)·s(i)·(v − c_i))²`; the (≤ 2^wbit) box values are visited in
//! Schnorr–Euchner order (ascending distance from the center), so the
//! first radius violation prunes the remaining siblings too.

use super::babai::{decode_greedy, residual_sq};
use crate::tensor::Matrix;

/// Result of an exact (or node-capped) solve.
#[derive(Debug, Clone)]
pub struct SphereResult {
    /// Best codes found.
    pub q: Vec<f32>,
    /// Their residual `||R·(s⊙(q−q̄))||²`.
    pub resid: f64,
    /// Nodes expanded (search-effort diagnostic).
    pub nodes: u64,
    /// True iff the search ran to completion (result provably optimal).
    pub optimal: bool,
}

struct Search<'a> {
    r: &'a Matrix,
    s: &'a [f32],
    qbar: &'a [f32],
    qmax: f32,
    max_nodes: u64,
    nodes: u64,
    capped: bool,
    best_q: Vec<f32>,
    best_res: f64,
    cur: Vec<f32>,
    e: Vec<f32>,
}

impl Search<'_> {
    fn center(&self, i: usize) -> f32 {
        let m = self.r.rows();
        let mut acc = 0.0f64;
        let row = &self.r.row(i)[i + 1..m];
        for (off, &rij) in row.iter().enumerate() {
            acc += rij as f64 * self.e[i + 1 + off] as f64;
        }
        self.qbar[i] + (acc / (self.r.get(i, i) as f64 * self.s[i] as f64)) as f32
    }

    fn dive(&mut self, i: usize, part: f64) {
        if self.nodes >= self.max_nodes {
            self.capped = true;
            return;
        }
        self.nodes += 1;
        let c = self.center(i);
        let rbar = self.r.get(i, i) as f64 * self.s[i] as f64;
        // Schnorr–Euchner order: box values by ascending distance from c.
        let n = self.qmax as usize + 1;
        let mut order: Vec<u8> = (0..n as u8).collect();
        order.sort_by(|&a, &b| {
            let da = (a as f32 - c).abs();
            let db = (b as f32 - c).abs();
            da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
        });
        for &vu in &order {
            let v = vu as f32;
            let d = (v - c) as f64;
            let contrib = rbar * rbar * d * d;
            if part + contrib >= self.best_res {
                break; // ordered ⇒ all remaining siblings prune too
            }
            self.cur[i] = v;
            if i == 0 {
                self.best_res = part + contrib;
                self.best_q.copy_from_slice(&self.cur);
            } else {
                self.e[i] = self.s[i] * (self.qbar[i] - v);
                self.dive(i - 1, part + contrib);
                if self.capped {
                    return;
                }
            }
        }
    }
}

/// Exact box-constrained ILS solve of one column. `max_nodes` bounds the
/// search; the Babai point seeds the radius, so the result is never worse
/// than Babai even when capped (`optimal == false`).
pub fn decode_optimal(
    r: &Matrix,
    s: &[f32],
    qbar: &[f32],
    qmax: f32,
    max_nodes: u64,
) -> SphereResult {
    let m = r.rows();
    assert_eq!(r.cols(), m);
    assert_eq!(s.len(), m);
    assert_eq!(qbar.len(), m);
    let babai = decode_greedy(r, s, qbar, qmax);
    let best_res = residual_sq(r, s, qbar, &babai);
    let mut search = Search {
        r,
        s,
        qbar,
        qmax,
        max_nodes,
        nodes: 0,
        capped: false,
        best_q: babai,
        // Tiny slack so the (equal-residual) Babai leaf itself is not
        // pruned before a strictly better leaf can replace it.
        best_res: best_res + 1e-9 * best_res.max(1e-9),
        cur: vec![0.0; m],
        e: vec![0.0; m],
    };
    search.dive(m - 1, 0.0);
    let optimal = !search.capped;
    // Report the true residual of the returned point.
    let resid = residual_sq(r, s, qbar, &search.best_q);
    SphereResult { q: search.best_q, resid, nodes: search.nodes, optimal }
}

/// Brute-force solver for very small cases — validates the sphere
/// decoder itself in tests.
pub fn decode_exhaustive(r: &Matrix, s: &[f32], qbar: &[f32], qmax: f32) -> (Vec<f32>, f64) {
    let m = r.rows();
    let n = qmax as usize + 1;
    assert!((n as f64).powi(m as i32) <= 2e6, "exhaustive only for tiny cases");
    let total = n.pow(m as u32);
    let mut best_q = vec![0.0f32; m];
    let mut best_res = f64::INFINITY;
    let mut q = vec![0.0f32; m];
    for code in 0..total {
        let mut x = code;
        for qi in q.iter_mut() {
            *qi = (x % n) as f32;
            x /= n;
        }
        let res = residual_sq(r, s, qbar, &q);
        if res < best_res {
            best_res = res;
            best_q.copy_from_slice(&q);
        }
    }
    (best_q, best_res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::klein::decode_kbest;
    use crate::rng::Rng;
    use crate::testutil::{check_cases, gen_dim, gen_solver_case};

    #[test]
    fn sphere_matches_exhaustive_on_tiny_cases() {
        check_cases(0x5E, 15, |rng, _| {
            let m = gen_dim(rng, 2, 5);
            let qmax = 7.0; // 8^5 = 32k points max
            let case = gen_solver_case(rng, m, 3);
            let exact = decode_exhaustive(&case.r, &case.s, &case.qbar, qmax);
            let sphere = decode_optimal(&case.r, &case.s, &case.qbar, qmax, u64::MAX);
            assert!(sphere.optimal);
            assert!(
                (sphere.resid - exact.1).abs() <= 1e-6 * exact.1.max(1e-9),
                "sphere {} vs exhaustive {}",
                sphere.resid,
                exact.1
            );
        });
    }

    #[test]
    fn suboptimal_solvers_never_beat_the_oracle() {
        check_cases(0x5F, 12, |rng, case_idx| {
            let m = gen_dim(rng, 4, 12);
            let case = gen_solver_case(rng, m, 4);
            let opt = decode_optimal(&case.r, &case.s, &case.qbar, case.qmax, 5_000_000);
            let greedy = crate::quant::babai::decode_greedy(
                &case.r, &case.s, &case.qbar, case.qmax,
            );
            let greedy_res = residual_sq(&case.r, &case.s, &case.qbar, &greedy);
            let mut krng = Rng::new(900 + case_idx as u64);
            let (_, kres) = decode_kbest(&case.r, &case.s, &case.qbar, case.qmax, 8, &mut krng);
            assert!(opt.resid <= greedy_res + 1e-6, "oracle beaten by Babai");
            assert!(opt.resid <= kres + 1e-6, "oracle beaten by Klein K-best");
            // And K-best closes (part of) the Babai->optimal gap.
            assert!(kres <= greedy_res + 1e-9);
        });
    }

    #[test]
    fn node_cap_still_returns_at_least_babai() {
        let mut rng = Rng::new(3);
        let case = gen_solver_case(&mut rng, 24, 4);
        let capped = decode_optimal(&case.r, &case.s, &case.qbar, case.qmax, 50);
        assert!(!capped.optimal);
        let greedy =
            crate::quant::babai::decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let greedy_res = residual_sq(&case.r, &case.s, &case.qbar, &greedy);
        assert!(capped.resid <= greedy_res + 1e-6);
    }

    #[test]
    fn optimal_point_in_box() {
        let mut rng = Rng::new(4);
        let case = gen_solver_case(&mut rng, 8, 3);
        let opt = decode_optimal(&case.r, &case.s, &case.qbar, case.qmax, u64::MAX);
        for &v in &opt.q {
            assert!(v >= 0.0 && v <= case.qmax && v.fract() == 0.0);
        }
    }
}
