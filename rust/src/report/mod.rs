//! Table/report emission — regenerates the paper's tables in markdown and
//! CSV, with best/second-best annotation matching the paper's bold /
//! underline convention.

use std::fmt::Write as _;
use std::path::Path;

pub mod trace;
pub use trace::{validate_trace, LayerTraceRow, RunTrace, TRACE_VERSION};

/// A simple column-oriented table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Render as github-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows.iter().map(|r| r[i].len()).chain([h.len()]).max().unwrap_or(3)
            })
            .collect();
        let fmt_row = |cells: &[String]| -> String {
            let padded: Vec<String> =
                cells.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}")).collect();
            format!("| {} |", padded.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep));
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let headers = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        let _ = writeln!(out, "{headers}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Render as a JSON object `{title, headers, rows}` (hand-rolled — no
    /// serde offline). Used by benches that record machine-readable
    /// results (e.g. `BENCH_qgemm.json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        let _ = write!(out, "\"title\":{},", json_str(&self.title));
        let list = |cells: &[String]| {
            let inner: Vec<String> = cells.iter().map(|c| json_str(c)).collect();
            format!("[{}]", inner.join(","))
        };
        let _ = write!(out, "\"headers\":{},", list(&self.headers));
        let rows: Vec<String> = self.rows.iter().map(|r| list(r)).collect();
        let _ = write!(out, "\"rows\":[{}]", rows.join(","));
        out.push('}');
        out
    }

    /// Print markdown to stdout and also write `<dir>/<stem>.md` + `.csv`
    /// when `dir` is Some. Bench harnesses call this with
    /// `results/` so every paper table lands on disk.
    pub fn emit(&self, dir: Option<&Path>, stem: &str) {
        println!("{}", self.to_markdown());
        if let Some(d) = dir {
            let _ = std::fs::create_dir_all(d);
            let _ = std::fs::write(d.join(format!("{stem}.md")), self.to_markdown());
            let _ = std::fs::write(d.join(format!("{stem}.csv")), self.to_csv());
        }
    }
}

/// Human-readable byte size (decimal SI: B / KB / MB / GB).
pub fn fmt_bytes(n: u64) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.2}GB", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}MB", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}KB", f / 1e3)
    } else {
        format!("{n}B")
    }
}

/// One-line summary for a written checkpoint artifact: its size plus its
/// share of the dense f32 footprint it replaces — the number the
/// ≤40%-of-dense regression pins (`rust/tests/packed_checkpoint.rs`).
/// Used by the `ojbkq quantize --out` path and the pipeline example.
pub fn artifact_summary(label: &str, file_bytes: u64, dense_bytes: u64) -> String {
    if dense_bytes == 0 {
        return format!("{label}: {}", fmt_bytes(file_bytes));
    }
    format!(
        "{label}: {} ({:.1}% of the {} dense f32 footprint)",
        fmt_bytes(file_bytes),
        100.0 * file_bytes as f64 / dense_bytes as f64,
        fmt_bytes(dense_bytes)
    )
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Annotate the minimum (bold) and second-minimum (underline) of a series
/// of numeric cells, paper-style. Returns formatted strings.
pub fn mark_best_min(values: &[f64], decimals: usize) -> Vec<String> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap());
    values
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let s = format!("{v:.decimals$}");
            if !idx.is_empty() && i == idx[0] {
                format!("**{s}**")
            } else if idx.len() > 1 && i == idx[1] {
                format!("_{s}_")
            } else {
                s
            }
        })
        .collect()
}

/// Same but maximum is best (accuracy tables).
pub fn mark_best_max(values: &[f64], decimals: usize) -> Vec<String> {
    let neg: Vec<f64> = values.iter().map(|v| -v).collect();
    let marked = mark_best_min(&neg, decimals);
    // Re-render the numbers positively while keeping the markers.
    values
        .iter()
        .zip(marked)
        .map(|(v, m)| {
            let s = format!("{v:.decimals$}");
            if m.starts_with("**") {
                format!("**{s}**")
            } else if m.starts_with('_') {
                format!("_{s}_")
            } else {
                s
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.push_row(&["1", "2"]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.lines().count() >= 4);
        assert!(md.contains("| 1"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a"]);
        t.push_row(&["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn json_shape_and_escaping() {
        let mut t = Table::new("T\"1\"", &["a", "b"]);
        t.push_row(&["1", "x\ny"]);
        let j = t.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"title\":\"T\\\"1\\\"\""));
        assert!(j.contains("\"headers\":[\"a\",\"b\"]"));
        assert!(j.contains("\"rows\":[[\"1\",\"x\\ny\"]]"));
    }

    #[test]
    fn best_marking_min() {
        let m = mark_best_min(&[3.0, 1.0, 2.0], 1);
        assert_eq!(m, vec!["3.0", "**1.0**", "_2.0_"]);
    }

    #[test]
    fn best_marking_max() {
        let m = mark_best_max(&[3.0, 1.0, 2.0], 0);
        assert_eq!(m, vec!["**3**", "1", "_2_"]);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(1_500), "1.5KB");
        assert_eq!(fmt_bytes(2_500_000), "2.50MB");
        assert_eq!(fmt_bytes(3_000_000_000), "3.00GB");
    }

    #[test]
    fn artifact_summary_shapes() {
        let s = artifact_summary("ckpt.ojbq1", 1_000_000, 4_000_000);
        assert!(s.contains("ckpt.ojbq1: 1.00MB"));
        assert!(s.contains("25.0% of the 4.00MB dense"));
        // Zero denominator stays printable (FP passthrough runs).
        assert_eq!(artifact_summary("x", 512, 0), "x: 512B");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.push_row(&["only-one"]);
    }
}
