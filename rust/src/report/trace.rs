//! `RunTrace` — the machine-readable run manifest behind `trace.json`.
//!
//! A trace captures one process's [`crate::obs`] registry (span tree +
//! metrics) together with run configuration, thread count, and the
//! per-layer residual records from the pipeline. Emitted by
//! `ojbkq quantize --trace` and the traced benches; consumed by the CI
//! `check-trace` leg via [`validate_trace`], which parses the JSON with
//! a self-contained recursive-descent parser (no serde offline) and
//! rejects any span path segment or metric name outside the curated
//! [`crate::obs`] taxonomy.
//!
//! ## `trace.json` schema (version 1)
//!
//! ```json
//! {
//!   "version": 1,
//!   "threads": 8,
//!   "config": {"model": "tiny-0.2M", "method": "ojbkq", ...},
//!   "spans": [{"path": "pipeline/attn_in/solve", "count": 8, "secs": 0.12}, ...],
//!   "counters": {"qgemm.calls": 42, ...},
//!   "gauges": {"eval.windows_per_sec": 193.5, ...},
//!   "hists": {"layer.rt_err": {"count": 14, "sum": 1.2, "min": 0.01, "max": 0.4}, ...},
//!   "layers": [{"id": "b0.q", "metrics": {"rt_err": 0.08, ...}}, ...]
//! }
//! ```
//!
//! All numbers are finite: non-finite f64s are serialized as `0.0` so
//! the file stays strict JSON.

use crate::obs::{self, HistSummary, Snapshot};
use std::fmt::Write as _;
use std::path::Path;

/// Current `trace.json` schema version.
pub const TRACE_VERSION: u64 = 1;

/// Per-layer metric record in a trace (one per quantized linear).
#[derive(Debug, Clone, Default)]
pub struct LayerTraceRow {
    /// Layer identity, e.g. `b0.q` (`model::LinearId` display form).
    pub id: String,
    /// `(name, value)` pairs; names must be in
    /// [`obs::LAYER_METRIC_NAMES`].
    pub metrics: Vec<(String, f64)>,
}

/// One run's full observability manifest.
#[derive(Debug, Clone, Default)]
pub struct RunTrace {
    /// Schema version ([`TRACE_VERSION`]).
    pub version: u64,
    /// Worker threads the run used (`parallel::num_threads()` at capture).
    pub threads: usize,
    /// Free-form `(key, value)` run configuration (model, method, wbit…).
    pub config: Vec<(String, String)>,
    /// The span/metric registry snapshot.
    pub snapshot: Snapshot,
    /// Per-layer residual records from `PipelineReport`.
    pub layers: Vec<LayerTraceRow>,
}

impl RunTrace {
    /// Snapshot the global [`obs`] registry right now, with the given run
    /// configuration attached.
    pub fn capture(config: Vec<(String, String)>) -> RunTrace {
        RunTrace {
            version: TRACE_VERSION,
            threads: crate::parallel::num_threads(),
            config,
            snapshot: obs::snapshot(),
            layers: Vec::new(),
        }
    }

    /// Serialize to the `trace.json` schema (see module docs).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        let _ = write!(out, "\"version\":{},", self.version);
        let _ = write!(out, "\"threads\":{},", self.threads);
        let cfg: Vec<String> = self
            .config
            .iter()
            .map(|(k, v)| format!("{}:{}", super::json_str(k), super::json_str(v)))
            .collect();
        let _ = write!(out, "\"config\":{{{}}},", cfg.join(","));
        let spans: Vec<String> = self
            .snapshot
            .spans
            .iter()
            .map(|s| {
                format!(
                    "{{\"path\":{},\"count\":{},\"secs\":{}}}",
                    super::json_str(&s.path),
                    s.count,
                    json_f64(s.secs)
                )
            })
            .collect();
        let _ = write!(out, "\"spans\":[{}],", spans.join(","));
        let counters: Vec<String> = self
            .snapshot
            .counters
            .iter()
            .map(|(n, v)| format!("{}:{}", super::json_str(n), v))
            .collect();
        let _ = write!(out, "\"counters\":{{{}}},", counters.join(","));
        let gauges: Vec<String> = self
            .snapshot
            .gauges
            .iter()
            .map(|(n, v)| format!("{}:{}", super::json_str(n), json_f64(*v)))
            .collect();
        let _ = write!(out, "\"gauges\":{{{}}},", gauges.join(","));
        let hists: Vec<String> = self
            .snapshot
            .hists
            .iter()
            .map(|(n, h)| {
                format!(
                    "{}:{{\"count\":{},\"sum\":{},\"min\":{},\"max\":{}}}",
                    super::json_str(n),
                    h.count,
                    json_f64(h.sum),
                    json_f64(h.min),
                    json_f64(h.max)
                )
            })
            .collect();
        let _ = write!(out, "\"hists\":{{{}}},", hists.join(","));
        let layers: Vec<String> = self
            .layers
            .iter()
            .map(|l| {
                let ms: Vec<String> = l
                    .metrics
                    .iter()
                    .map(|(n, v)| format!("{}:{}", super::json_str(n), json_f64(*v)))
                    .collect();
                format!(
                    "{{\"id\":{},\"metrics\":{{{}}}}}",
                    super::json_str(&l.id),
                    ms.join(",")
                )
            })
            .collect();
        let _ = write!(out, "\"layers\":[{}]", layers.join(","));
        out.push('}');
        out
    }

    /// Human-readable span-tree table plus metric summary — what
    /// `--trace` prints after the run.
    pub fn to_markdown(&self) -> String {
        let mut t = super::Table::new(
            "Span tree (wall-clock, aggregated by path)",
            &["span", "calls", "total s", "mean ms"],
        );
        for s in &self.snapshot.spans {
            // Indent by depth so the aggregated paths read as a tree.
            let depth = s.path.matches('/').count();
            let leaf = s.path.rsplit('/').next().unwrap_or(&s.path);
            let label = format!("{}{}", "  ".repeat(depth), leaf);
            t.push_row(&[
                label,
                s.count.to_string(),
                format!("{:.3}", s.secs),
                format!("{:.3}", 1e3 * s.secs / s.count.max(1) as f64),
            ]);
        }
        let mut out = t.to_markdown();
        let mut m = super::Table::new("Metrics", &["name", "kind", "value"]);
        for (n, v) in &self.snapshot.counters {
            m.push_row(&[n.clone(), "counter".into(), v.to_string()]);
        }
        for (n, v) in &self.snapshot.gauges {
            m.push_row(&[n.clone(), "gauge".into(), format!("{v:.3}")]);
        }
        for (n, h) in &self.snapshot.hists {
            m.push_row(&[
                n.clone(),
                "hist".into(),
                format!("n={} mean={:.4} min={:.4} max={:.4}", h.count, h.mean(), h.min, h.max),
            ]);
        }
        if !m.rows.is_empty() {
            out.push('\n');
            out.push_str(&m.to_markdown());
        }
        out
    }

    /// Write `to_json()` to `path`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Finite JSON number rendering (non-finite → 0, strict JSON has no
/// NaN/Inf tokens).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

// ----- validation ----------------------------------------------------

/// Minimal JSON value for the schema checker.
#[derive(Debug, Clone, PartialEq)]
enum JsonV {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonV>),
    Obj(Vec<(String, JsonV)>),
}

impl JsonV {
    fn get<'a>(&'a self, key: &str) -> Option<&'a JsonV> {
        match self {
            JsonV::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Parser<'a> {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {}", self.pos, msg)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<JsonV, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonV::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonV::Bool(true)),
            Some(b'f') => self.literal("false", JsonV::Bool(false)),
            Some(b'n') => self.literal("null", JsonV::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: JsonV) -> Result<JsonV, String> {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<JsonV, String> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(JsonV::Num).map_err(|_| self.err(&format!("bad number {s:?}")))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&c) = self.bytes.get(self.pos) else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&e) = self.bytes.get(self.pos) else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("utf8 in \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogates don't occur in our own output;
                            // map unpaired ones to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xc0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("utf8 in string"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonV, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonV::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonV::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonV, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonV::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            fields.push((key, self.value()?));
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonV::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn parse(mut self) -> Result<JsonV, String> {
        let v = self.value()?;
        if self.peek().is_some() {
            return Err(self.err("trailing data"));
        }
        Ok(v)
    }
}

fn require<'a>(obj: &'a JsonV, key: &str) -> Result<&'a JsonV, String> {
    obj.get(key).ok_or_else(|| format!("trace missing required key {key:?}"))
}

fn as_num(v: &JsonV, what: &str) -> Result<f64, String> {
    match v {
        JsonV::Num(n) => Ok(*n),
        _ => Err(format!("{what} must be a number")),
    }
}

/// Validate `text` against the `trace.json` schema: structure, types,
/// and — critically — that every span path segment, metric name, and
/// per-layer metric key belongs to the curated [`obs`] taxonomy.
/// Returns a human-readable error on the first violation. This is the
/// CI `check-trace` entry point keeping the metric namespace curated.
pub fn validate_trace(text: &str) -> Result<(), String> {
    let root = Parser::new(text).parse()?;
    let version = as_num(require(&root, "version")?, "version")?;
    if version != TRACE_VERSION as f64 {
        return Err(format!("unsupported trace version {version} (want {TRACE_VERSION})"));
    }
    let threads = as_num(require(&root, "threads")?, "threads")?;
    if threads < 1.0 || threads.fract() != 0.0 {
        return Err(format!("threads must be a positive integer, got {threads}"));
    }
    match require(&root, "config")? {
        JsonV::Obj(fields) => {
            for (k, v) in fields {
                if !matches!(v, JsonV::Str(_)) {
                    return Err(format!("config[{k:?}] must be a string"));
                }
            }
        }
        _ => return Err("config must be an object".into()),
    }
    match require(&root, "spans")? {
        JsonV::Arr(items) => {
            for it in items {
                let path = match require(it, "path")? {
                    JsonV::Str(s) => s,
                    _ => return Err("span path must be a string".into()),
                };
                for seg in path.split('/') {
                    if !obs::SPAN_NAMES.contains(&seg) {
                        return Err(format!("unknown span name {seg:?} in path {path:?}"));
                    }
                }
                let count = as_num(require(it, "count")?, "span count")?;
                if count < 1.0 || count.fract() != 0.0 {
                    return Err(format!("span {path:?} count must be a positive integer"));
                }
                let secs = as_num(require(it, "secs")?, "span secs")?;
                if secs < 0.0 {
                    return Err(format!("span {path:?} secs must be non-negative"));
                }
            }
        }
        _ => return Err("spans must be an array".into()),
    }
    for (key, kind) in [("counters", "counter"), ("gauges", "gauge")] {
        match require(&root, key)? {
            JsonV::Obj(fields) => {
                for (n, v) in fields {
                    if !obs::METRIC_NAMES.contains(&n.as_str()) {
                        return Err(format!("unknown {kind} metric {n:?}"));
                    }
                    as_num(v, &format!("{kind} {n:?}"))?;
                }
            }
            _ => return Err(format!("{key} must be an object")),
        }
    }
    match require(&root, "hists")? {
        JsonV::Obj(fields) => {
            for (n, v) in fields {
                if !obs::METRIC_NAMES.contains(&n.as_str()) {
                    return Err(format!("unknown hist metric {n:?}"));
                }
                for f in ["count", "sum", "min", "max"] {
                    as_num(require(v, f)?, &format!("hist {n:?}.{f}"))?;
                }
            }
        }
        _ => return Err("hists must be an object".into()),
    }
    match require(&root, "layers")? {
        JsonV::Arr(items) => {
            for it in items {
                let id = match require(it, "id")? {
                    JsonV::Str(s) => s,
                    _ => return Err("layer id must be a string".into()),
                };
                match require(it, "metrics")? {
                    JsonV::Obj(fields) => {
                        for (n, v) in fields {
                            if !obs::LAYER_METRIC_NAMES.contains(&n.as_str()) {
                                return Err(format!("unknown layer metric {n:?} on layer {id:?}"));
                            }
                            as_num(v, &format!("layer {id:?} metric {n:?}"))?;
                        }
                    }
                    _ => return Err(format!("layer {id:?} metrics must be an object")),
                }
            }
        }
        _ => return Err("layers must be an array".into()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanRow;

    fn sample_trace() -> RunTrace {
        RunTrace {
            version: TRACE_VERSION,
            threads: 4,
            config: vec![
                ("model".into(), "tiny-0.2M".into()),
                ("method".into(), "ojbkq \"q\"".into()),
            ],
            snapshot: Snapshot {
                spans: vec![
                    SpanRow { path: "pipeline".into(), count: 1, secs: 1.5 },
                    SpanRow { path: "pipeline/attn_in/solve".into(), count: 8, secs: 0.25 },
                ],
                counters: vec![("qgemm.calls".into(), 42)],
                gauges: vec![("eval.windows_per_sec".into(), 19.5)],
                hists: vec![(
                    "layer.rt_err".into(),
                    HistSummary { count: 2, sum: 0.3, min: 0.1, max: 0.2 },
                )],
            },
            layers: vec![LayerTraceRow {
                id: "b0.q".into(),
                metrics: vec![("rt_err".into(), 0.1), ("clip_rate".into(), 0.02)],
            }],
        }
    }

    #[test]
    fn roundtrip_validates() {
        let json = sample_trace().to_json();
        validate_trace(&json).unwrap();
    }

    #[test]
    fn empty_capture_validates() {
        // A run with nothing recorded still emits a schema-valid file.
        let t = RunTrace::capture(vec![("model".into(), "m".into())]);
        validate_trace(&t.to_json()).unwrap();
    }

    #[test]
    fn unknown_metric_name_rejected() {
        let mut t = sample_trace();
        t.snapshot.counters.push(("qgemm.bogus_counter".into(), 1));
        let err = validate_trace(&t.to_json()).unwrap_err();
        assert!(err.contains("bogus_counter"), "{err}");
    }

    #[test]
    fn unknown_span_segment_rejected() {
        let mut t = sample_trace();
        t.snapshot.spans.push(SpanRow { path: "pipeline/warp_drive".into(), count: 1, secs: 0.0 });
        let err = validate_trace(&t.to_json()).unwrap_err();
        assert!(err.contains("warp_drive"), "{err}");
    }

    #[test]
    fn unknown_layer_metric_rejected() {
        let mut t = sample_trace();
        t.layers[0].metrics.push(("vibes".into(), 1.0));
        let err = validate_trace(&t.to_json()).unwrap_err();
        assert!(err.contains("vibes"), "{err}");
    }

    #[test]
    fn missing_key_and_bad_version_rejected() {
        assert!(validate_trace("{}").unwrap_err().contains("version"));
        let mut t = sample_trace();
        t.version = 99;
        assert!(validate_trace(&t.to_json()).unwrap_err().contains("version"));
    }

    #[test]
    fn malformed_json_rejected() {
        assert!(validate_trace("{\"version\":1,").is_err());
        assert!(validate_trace("").is_err());
        assert!(validate_trace("[1,2,]").is_err());
    }

    #[test]
    fn parser_handles_escapes_and_nesting() {
        let v = Parser::new(r#"{"a":[1,-2.5e1,"x\n\"yA"],"b":{"c":null,"d":true}}"#)
            .parse()
            .unwrap();
        assert_eq!(
            v.get("a"),
            Some(&JsonV::Arr(vec![
                JsonV::Num(1.0),
                JsonV::Num(-25.0),
                JsonV::Str("x\n\"yA".into())
            ]))
        );
        assert_eq!(v.get("b").and_then(|b| b.get("d")), Some(&JsonV::Bool(true)));
    }

    #[test]
    fn non_finite_serialized_as_zero() {
        let mut t = sample_trace();
        t.snapshot.gauges[0].1 = f64::NAN;
        let json = t.to_json();
        validate_trace(&json).unwrap();
        assert!(json.contains("\"eval.windows_per_sec\":0"));
    }

    #[test]
    fn markdown_renders_tree() {
        let md = sample_trace().to_markdown();
        assert!(md.contains("pipeline"));
        assert!(md.contains("    solve")); // depth-2 indent
        assert!(md.contains("qgemm.calls"));
    }
}
