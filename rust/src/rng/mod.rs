//! Deterministic pseudo-random number generation.
//!
//! The build is fully offline (no `rand` crate), so we carry our own
//! generator: **xoshiro256++** seeded through SplitMix64 — the standard
//! recommendation for seeding xoshiro family state. All randomness in the
//! repository (Klein sampling, synthetic data, weight init fallback,
//! property-test case generation) flows through [`Rng`] so every
//! experiment is replayable from a single `u64` seed.

/// SplitMix64 step — used to expand a single `u64` seed into generator
/// state and for cheap stateless hashing of (seed, stream) pairs.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush; plenty for
/// Monte-Carlo style sampling (we make no cryptographic claims).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the (vanishingly unlikely) all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x853C49E6748FEA9B;
        }
        Rng { s }
    }

    /// Derive an independent stream for a (seed, stream-id) pair without
    /// advancing `self`. Used to hand each weight-column / data-shard its
    /// own generator so parallel order never changes results.
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm =
            self.s[0] ^ self.s[2].rotate_left(17) ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of entropy.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift with
    /// rejection to stay unbiased.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (one value per call; we do not
    /// cache the second to keep the stream position deterministic and
    /// easy to reason about across refactors).
    pub fn normal(&mut self) -> f64 {
        // u in (0,1]: avoid ln(0).
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Normal with mean/std (f32 convenience for weight init + noise).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalized non-negative `weights` by
    /// inverse-CDF. Returns `weights.len()-1` on total-mass underflow so
    /// callers never index out of bounds.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return weights.len() - 1;
        }
        let target = self.uniform() * total;
        let mut acc = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            acc += w;
            if target < acc {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of uniform f32s — the explicit-uniforms input fed to both
    /// the native Klein solver and the AOT PJRT artifact so the two
    /// backends consume identical randomness.
    pub fn uniform_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent_and_stable() {
        let root = Rng::new(7);
        let mut f1 = root.fork(0);
        let mut f2 = root.fork(1);
        let mut f1b = root.fork(0);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_follows_weights() {
        let mut rng = Rng::new(13);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2={p2}");
    }

    #[test]
    fn categorical_degenerate_mass() {
        let mut rng = Rng::new(5);
        assert_eq!(rng.categorical(&[0.0, 0.0, 0.0]), 2);
        assert_eq!(rng.categorical(&[1.0]), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(19);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }
}
