//! Failure model: fault injection, structured errors, crash-safe IO.
//!
//! This module is the substrate for the pipeline's robustness story
//! (DESIGN.md §Failure model). It provides four things:
//!
//! 1. **Fault-injection harness.** Named fault sites are threaded
//!    through the coordinator, checkpoint IO, and the serve scheduler
//!    as calls to [`fault_point`]. A site is inert until armed via
//!    `OJBKQ_FAULTS=site:kind:nth` (or `--inject-fault`, or
//!    [`set_faults`] in tests); when armed, the `nth` crossing of the
//!    named site fires the configured [`FaultKind`]. Disarmed cost is
//!    one relaxed atomic load per crossing — the same zero-cost
//!    discipline as `obs/` — pinned by `obs_trace.rs` and
//!    `BENCH_robust.json`.
//!
//! 2. **Structured errors.** [`RobustError`] carries the site name
//!    plus block/tap/layer context so a per-layer failure (injected or
//!    genuine NaN poisoning) surfaces as a diagnosable `Err` instead
//!    of a panic or a silently corrupt layer.
//!
//! 3. **Run manifest.** [`RunManifest`] records the identity of a
//!    checkpointed quantization run (config hash, calibration digest,
//!    completed-block prefix) in a tiny text format (`OJBM1`), so
//!    `quantize --resume` can refuse mismatched resumes and replay
//!    exactly the completed prefix.
//!
//! 4. **Atomic writes.** [`atomic_write`] is the single choke point
//!    for checkpoint-file IO: full payload to `<path>.tmp`, then
//!    `rename` — a crash at any instant leaves either the old file or
//!    the new file, never a torn one. The `partial_write` fault kind
//!    simulates the torn-temp-file crash, which resume must ignore.
//!
//! ## Fault kinds
//!
//! | kind            | effect at the site                                      |
//! |-----------------|---------------------------------------------------------|
//! | `err`           | site returns a structured [`RobustError`]               |
//! | `panic`         | `fault_point` panics (simulated crash)                  |
//! | `nan`           | site poisons its value with NaN (guards must catch it)  |
//! | `partial_write` | IO site writes half the payload to `.tmp`, no rename    |
//! | `stall`         | `fault_point` sleeps ~25ms, then proceeds normally      |
//!
//! Sites that cannot express a kind (e.g. `nan` at a write site)
//! degrade it to `err` — every armed fault is always observable.

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, bail, Context};

/// Every registered fault site. [`fault_point`] debug-asserts its
/// argument is in this list and [`set_faults`] rejects specs naming
/// anything else, so the taxonomy cannot drift silently (mirroring
/// `obs::METRIC_NAMES`).
pub const FAULT_SITES: &[&str] = &[
    // Coordinator: per-block capture -> factor -> solve -> advance.
    "coordinator.capture",
    "coordinator.factor",
    "coordinator.solve",
    "coordinator.advance",
    // Checkpoint IO (segments + manifest go through `atomic_write`).
    "io.segment_write",
    "io.manifest_write",
    // Serve scheduler: per-step admission/decode + logits production.
    "serve.step",
    "serve.logits",
];

/// What an armed fault does when it fires. See the module-level table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Err,
    Panic,
    Nan,
    PartialWrite,
    Stall,
}

impl FaultKind {
    /// All kinds, in spec-string order — used by the fault-sweep test.
    pub fn all() -> &'static [FaultKind] {
        &[
            FaultKind::Err,
            FaultKind::Panic,
            FaultKind::Nan,
            FaultKind::PartialWrite,
            FaultKind::Stall,
        ]
    }

    /// The spec-string name (`err`, `panic`, `nan`, `partial_write`,
    /// `stall`).
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Err => "err",
            FaultKind::Panic => "panic",
            FaultKind::Nan => "nan",
            FaultKind::PartialWrite => "partial_write",
            FaultKind::Stall => "stall",
        }
    }

    fn parse(s: &str) -> anyhow::Result<FaultKind> {
        FaultKind::all()
            .iter()
            .copied()
            .find(|k| k.label() == s)
            .ok_or_else(|| {
                anyhow!(
                    "unknown fault kind `{s}` (known: err, panic, nan, partial_write, stall)"
                )
            })
    }
}

/// One armed fault: fire `kind` on the `nth` crossing of `site`
/// (1-based), then stay spent.
#[derive(Debug, Clone)]
struct FaultSpec {
    site: &'static str,
    kind: FaultKind,
    nth: u64,
    hits: u64,
    fired: bool,
}

/// 0 = unresolved (consult `OJBKQ_FAULTS` on first crossing),
/// 1 = armed, 2 = disarmed. Steady-state disarmed cost is the single
/// relaxed load of this flag.
static FAULT_STATE: AtomicU8 = AtomicU8::new(0);
/// Total faults fired since the last [`reset_faults`].
static FAULT_EVENTS: AtomicU64 = AtomicU64::new(0);
static ENV_RESOLVE: OnceLock<()> = OnceLock::new();

/// Serializes lib unit tests that arm the process-global fault
/// registry or cross sites another test may arm (lib tests run
/// multi-threaded in one process; integration-test binaries run
/// sequentially and keep their own file-level locks).
#[cfg(test)]
pub(crate) static TEST_FAULT_LOCK: Mutex<()> = Mutex::new(());

fn specs() -> &'static Mutex<Vec<FaultSpec>> {
    static SPECS: OnceLock<Mutex<Vec<FaultSpec>>> = OnceLock::new();
    SPECS.get_or_init(|| Mutex::new(Vec::new()))
}

fn parse_specs(s: &str) -> anyhow::Result<Vec<FaultSpec>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let mut it = part.split(':');
        let site = it.next().unwrap_or("");
        let Some(&canon) = FAULT_SITES.iter().find(|&&k| k == site) else {
            bail!(
                "unknown fault site `{site}` (known: {})",
                FAULT_SITES.join(", ")
            );
        };
        let kind = FaultKind::parse(
            it.next()
                .ok_or_else(|| anyhow!("fault spec `{part}` is missing a kind"))?,
        )?;
        let nth = match it.next() {
            None => 1,
            Some(n) => n
                .parse::<u64>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| anyhow!("fault spec `{part}`: nth must be an integer >= 1"))?,
        };
        if it.next().is_some() {
            bail!("fault spec `{part}` has trailing fields (want site:kind[:nth])");
        }
        out.push(FaultSpec {
            site: canon,
            kind,
            nth,
            hits: 0,
            fired: false,
        });
    }
    Ok(out)
}

/// Arm (or disarm, with `None`) the fault registry. The spec is a
/// comma-separated list of `site:kind[:nth]` entries; `nth` defaults
/// to 1 and counts crossings of that site (1-based). Each entry fires
/// exactly once. An invalid spec leaves the registry disarmed.
pub fn set_faults(spec: Option<&str>) -> anyhow::Result<()> {
    let mut guard = specs().lock().unwrap_or_else(|e| e.into_inner());
    match spec {
        Some(s) => {
            let parsed = parse_specs(s);
            match parsed {
                Ok(list) => {
                    let armed = !list.is_empty();
                    *guard = list;
                    FAULT_STATE.store(if armed { 1 } else { 2 }, Ordering::Relaxed);
                    Ok(())
                }
                Err(e) => {
                    guard.clear();
                    FAULT_STATE.store(2, Ordering::Relaxed);
                    Err(e)
                }
            }
        }
        None => {
            guard.clear();
            FAULT_STATE.store(2, Ordering::Relaxed);
            Ok(())
        }
    }
}

/// Disarm every fault and zero the fired-event counter. Tests call
/// this on entry and exit so a poisoned registry never leaks across
/// test cases.
pub fn reset_faults() {
    let _ = set_faults(None);
    FAULT_EVENTS.store(0, Ordering::Relaxed);
}

/// Number of faults fired since the last [`reset_faults`]. The
/// disarmed-overhead gate asserts this stays 0 across a full pipeline
/// run with the registry off.
pub fn fault_event_count() -> u64 {
    FAULT_EVENTS.load(Ordering::Relaxed)
}

fn resolve_env() {
    ENV_RESOLVE.get_or_init(|| {
        // Only consult the environment if nothing armed the registry
        // programmatically first.
        if FAULT_STATE.load(Ordering::Relaxed) == 0 {
            match std::env::var("OJBKQ_FAULTS") {
                Ok(s) => {
                    if let Err(e) = set_faults(Some(&s)) {
                        eprintln!("warning: ignoring OJBKQ_FAULTS: {e}");
                    }
                }
                Err(_) => {
                    FAULT_STATE.store(2, Ordering::Relaxed);
                }
            }
        }
    });
}

/// Cross a named fault site. Disarmed this is one relaxed atomic
/// load. Armed, the matching spec's `nth` crossing fires:
/// `panic` panics here, `stall` sleeps here and returns `None`, and
/// every other kind is returned for the call site to act on (callers
/// treat kinds they cannot express as `err`).
pub fn fault_point(site: &'static str) -> Option<FaultKind> {
    debug_assert!(
        FAULT_SITES.contains(&site),
        "unregistered fault site: {site}"
    );
    match FAULT_STATE.load(Ordering::Relaxed) {
        2 => return None,
        0 => resolve_env(),
        _ => {}
    }
    if FAULT_STATE.load(Ordering::Relaxed) != 1 {
        return None;
    }
    let kind = {
        let mut guard = specs().lock().unwrap_or_else(|e| e.into_inner());
        let mut fired = None;
        for s in guard.iter_mut() {
            if s.site == site && !s.fired {
                s.hits += 1;
                if s.hits >= s.nth {
                    s.fired = true;
                    fired = Some(s.kind);
                    break;
                }
            }
        }
        fired
    }?;
    FAULT_EVENTS.fetch_add(1, Ordering::Relaxed);
    match kind {
        FaultKind::Panic => panic!("injected panic at fault site {site}"),
        FaultKind::Stall => {
            std::thread::sleep(std::time::Duration::from_millis(25));
            None
        }
        k => Some(k),
    }
}

/// A structured robustness failure: which site tripped, where in the
/// run (block / tap / layer), and why. Everything the degradation
/// ladder cannot absorb surfaces as one of these instead of a panic.
#[derive(Debug, Clone)]
pub struct RobustError {
    /// The fault site or guard boundary that detected the failure.
    pub site: &'static str,
    /// Transformer block index, when the failure is block-scoped.
    pub block: Option<usize>,
    /// Free-form locator: tap point, layer id, sequence/position, path.
    pub context: String,
    /// What went wrong.
    pub msg: String,
}

impl RobustError {
    pub fn new(site: &'static str, msg: impl Into<String>) -> Self {
        RobustError {
            site,
            block: None,
            context: String::new(),
            msg: msg.into(),
        }
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = Some(block);
        self
    }

    pub fn with_context(mut self, context: impl Into<String>) -> Self {
        self.context = context.into();
        self
    }
}

impl fmt::Display for RobustError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.site, self.msg)?;
        if let Some(b) = self.block {
            write!(f, " (block {b})")?;
        }
        if !self.context.is_empty() {
            write!(f, " — {}", self.context)?;
        }
        Ok(())
    }
}

impl std::error::Error for RobustError {}

/// FNV-1a 64-bit over a byte stream — the checkpoint manifest's
/// fingerprint primitive (stable, dependency-free, not cryptographic).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(0xcbf2_9ce4_8422_2325, bytes)
}

/// Fold more bytes into a running FNV-1a state.
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest a calibration set (token sequences). Each sequence is
/// length-prefixed so `[1,2],[3]` and `[1],[2,3]` hash differently.
pub fn digest_tokens(seqs: &[Vec<u16>]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for s in seqs {
        h = fnv1a64_extend(h, &(s.len() as u64).to_le_bytes());
        for &t in s {
            h = fnv1a64_extend(h, &t.to_le_bytes());
        }
    }
    h
}

/// Magic line of the run manifest (`manifest.ojbm`).
pub const MANIFEST_MAGIC: &str = "OJBM1";

/// Identity + progress record of a checkpointed quantization run.
/// `completed` is a *prefix* count: blocks `0..completed` have
/// durable segments in the same directory. Serialized as five text
/// lines (see DESIGN.md §Failure model):
///
/// ```text
/// OJBM1
/// config_hash <16-hex>
/// calib_digest <16-hex>
/// n_blocks <N>
/// completed <K>
/// end
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunManifest {
    /// Fingerprint of everything that determines the run's output
    /// besides the calibration tokens (model shape, method, quant
    /// config).
    pub config_hash: u64,
    /// [`digest_tokens`] of the sampled calibration set.
    pub calib_digest: u64,
    /// Total transformer blocks in the run.
    pub n_blocks: usize,
    /// Durable completed-block prefix (`0..completed` resumable).
    pub completed: usize,
}

impl RunManifest {
    /// Manifest location inside a checkpoint parts directory.
    pub fn path(dir: &Path) -> PathBuf {
        dir.join("manifest.ojbm")
    }

    /// Atomically persist to `dir` (via the `io.manifest_write` site).
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        let text = format!(
            "{MANIFEST_MAGIC}\nconfig_hash {:016x}\ncalib_digest {:016x}\nn_blocks {}\ncompleted {}\nend\n",
            self.config_hash, self.calib_digest, self.n_blocks, self.completed
        );
        atomic_write("io.manifest_write", &Self::path(dir), text.as_bytes())
    }

    /// Load and validate the manifest in `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = Self::path(dir);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading run manifest {}", path.display()))?;
        let mut lines = text.lines();
        let magic = lines.next().unwrap_or("");
        if magic != MANIFEST_MAGIC {
            bail!("bad manifest magic `{magic}` (want {MANIFEST_MAGIC})");
        }
        let config_hash = u64::from_str_radix(field(lines.next(), "config_hash")?, 16)
            .context("manifest: config_hash is not hex")?;
        let calib_digest = u64::from_str_radix(field(lines.next(), "calib_digest")?, 16)
            .context("manifest: calib_digest is not hex")?;
        let n_blocks: usize = field(lines.next(), "n_blocks")?
            .parse()
            .context("manifest: n_blocks is not an integer")?;
        let completed: usize = field(lines.next(), "completed")?
            .parse()
            .context("manifest: completed is not an integer")?;
        if lines.next() != Some("end") {
            bail!("manifest truncated: missing `end`");
        }
        if completed > n_blocks {
            bail!("manifest corrupt: completed {completed} > n_blocks {n_blocks}");
        }
        Ok(RunManifest {
            config_hash,
            calib_digest,
            n_blocks,
            completed,
        })
    }

    /// Check that a resume matches the run that wrote this manifest.
    pub fn verify(&self, config_hash: u64, calib_digest: u64, n_blocks: usize) -> anyhow::Result<()> {
        if self.config_hash != config_hash {
            bail!(
                "resume mismatch: manifest config_hash {:016x} != current {:016x} \
                 (model/method/quant config changed)",
                self.config_hash,
                config_hash
            );
        }
        if self.calib_digest != calib_digest {
            bail!(
                "resume mismatch: manifest calib_digest {:016x} != current {:016x} \
                 (calibration set changed)",
                self.calib_digest,
                calib_digest
            );
        }
        if self.n_blocks != n_blocks {
            bail!(
                "resume mismatch: manifest n_blocks {} != current {}",
                self.n_blocks,
                n_blocks
            );
        }
        Ok(())
    }
}

fn field<'a>(line: Option<&'a str>, key: &str) -> anyhow::Result<&'a str> {
    let l = line.ok_or_else(|| anyhow!("manifest truncated: missing `{key}`"))?;
    l.strip_prefix(key)
        .and_then(|rest| rest.strip_prefix(' '))
        .map(str::trim)
        .ok_or_else(|| anyhow!("manifest: expected `{key} ...`, got `{l}`"))
}

/// Crash-safe file write: full payload to `<path>.tmp`, then rename
/// over `path`. A crash (or injected fault) at any point leaves the
/// destination either absent, old, or new — never torn; at worst an
/// orphan `.tmp` remains, which readers ignore and the next write
/// overwrites. `site` is the IO fault site consulted before touching
/// the filesystem (`err`/`nan` → fail without writing, `partial_write`
/// → half the payload lands in `.tmp` and the rename never happens).
pub fn atomic_write(site: &'static str, path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    match fault_point(site) {
        None => {}
        Some(FaultKind::PartialWrite) => {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent).ok();
            }
            let tmp = tmp_path(path);
            std::fs::write(&tmp, &bytes[..bytes.len() / 2])
                .with_context(|| format!("writing {}", tmp.display()))?;
            return Err(RobustError::new(site, "injected torn write (crash before rename)")
                .with_context(path.display().to_string())
                .into());
        }
        Some(_) => {
            return Err(RobustError::new(site, "injected write fault")
                .with_context(path.display().to_string())
                .into());
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let tmp = tmp_path(path);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The fault registry is process-global and lib tests run
    // multi-threaded; every test here that arms it (or asserts on the
    // disarmed state) serializes through the crate-wide test lock and
    // only arms the io.* sites.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn spec_parsing_accepts_valid_and_rejects_invalid() {
        let ok = parse_specs("coordinator.solve:err, io.segment_write:partial_write:3").unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok[0].site, "coordinator.solve");
        assert_eq!(ok[0].kind, FaultKind::Err);
        assert_eq!(ok[0].nth, 1);
        assert_eq!(ok[1].site, "io.segment_write");
        assert_eq!(ok[1].kind, FaultKind::PartialWrite);
        assert_eq!(ok[1].nth, 3);

        assert!(parse_specs("bogus.site:err").is_err());
        assert!(parse_specs("coordinator.solve:sparkle").is_err());
        assert!(parse_specs("coordinator.solve").is_err());
        assert!(parse_specs("coordinator.solve:err:0").is_err());
        assert!(parse_specs("coordinator.solve:err:1:extra").is_err());
    }

    #[test]
    fn fault_fires_on_nth_crossing_then_stays_spent() {
        let _g = lock();
        reset_faults();
        set_faults(Some("io.manifest_write:err:3")).unwrap();
        assert_eq!(fault_point("io.manifest_write"), None);
        assert_eq!(fault_point("io.manifest_write"), None);
        assert_eq!(fault_point("io.manifest_write"), Some(FaultKind::Err));
        // Spent: never fires again.
        assert_eq!(fault_point("io.manifest_write"), None);
        assert_eq!(fault_event_count(), 1);
        reset_faults();
        assert_eq!(fault_point("io.manifest_write"), None);
        assert_eq!(fault_event_count(), 0);
    }

    #[test]
    fn fnv_digest_is_stable_and_order_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // Known FNV-1a vector: "a" -> 0xaf63dc4c8601ec8c.
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        let a = digest_tokens(&[vec![1, 2], vec![3]]);
        let b = digest_tokens(&[vec![1], vec![2, 3]]);
        assert_ne!(a, b, "length prefix must separate sequence boundaries");
        assert_eq!(a, digest_tokens(&[vec![1, 2], vec![3]]));
    }

    #[test]
    fn manifest_roundtrip_and_verify() {
        let _g = lock();
        reset_faults();
        let dir = std::env::temp_dir().join("ojbkq_robust_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = RunManifest {
            config_hash: 0xdead_beef_0123_4567,
            calib_digest: 0x0bad_cafe_89ab_cdef,
            n_blocks: 4,
            completed: 2,
        };
        m.save(&dir).unwrap();
        let back = RunManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        back.verify(m.config_hash, m.calib_digest, 4).unwrap();
        assert!(back.verify(1, m.calib_digest, 4).is_err());
        assert!(back.verify(m.config_hash, 1, 4).is_err());
        assert!(back.verify(m.config_hash, m.calib_digest, 5).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_survives_injected_torn_write() {
        let _g = lock();
        reset_faults();
        let dir = std::env::temp_dir().join("ojbkq_robust_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("payload.bin");
        atomic_write("io.segment_write", &path, b"first-version").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first-version");

        // Injected torn write: destination untouched, orphan .tmp holds
        // only half the new payload.
        set_faults(Some("io.segment_write:partial_write")).unwrap();
        let err = atomic_write("io.segment_write", &path, b"second-version!!").unwrap_err();
        assert!(err.to_string().contains("io.segment_write"), "{err}");
        assert_eq!(
            std::fs::read(&path).unwrap(),
            b"first-version",
            "torn write must not disturb the committed file"
        );
        let tmp = tmp_path(&path);
        assert_eq!(std::fs::read(&tmp).unwrap().len(), b"second-version!!".len() / 2);
        reset_faults();

        // The next clean write overwrites the orphan and commits.
        atomic_write("io.segment_write", &path, b"second-version!!").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second-version!!");
        assert!(!tmp.exists(), "clean write renames the temp file away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn robust_error_formats_site_block_and_context() {
        let e = RobustError::new("coordinator.solve", "non-finite solve output")
            .with_block(3)
            .with_context("layer b3.attn_q (tap AttnIn)");
        let s = e.to_string();
        assert!(s.contains("coordinator.solve"), "{s}");
        assert!(s.contains("block 3"), "{s}");
        assert!(s.contains("b3.attn_q"), "{s}");
    }
}
