//! PJRT runtime — the L3↔L2 bridge.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/ojbkq_m{M}_t{T}_k{K}.hlo.txt` — HLO *text*, see
//! DESIGN.md §3), compiles each once on the PJRT CPU client, caches the
//! executables, and exposes [`SolverRuntime::decode_tile`] with the same
//! contract as the native [`crate::quant::ppi`] decoder.
//!
//! Shape-variant dispatch: AOT artifacts have static shapes, so a tile of
//! size `(m, ntile)` is padded up to the smallest registered variant with
//! `M ≥ m`, `T ≥ ntile` and exactly matching `K`. Padding is semantically
//! inert by construction: pad rows extend `R` with an identity block
//! (`R[i, pad] = 0` keeps real rows untouched), pad scales are 1, pad
//! centers 0 — pad positions decode to code 0 and contribute nothing to
//! any residual.
//!
//! `wbit` is NOT part of the variant key: the kernel takes `qmax` as a
//! runtime input and masks box values above it, so one artifact serves
//! every bit-width ≤ 4.
//!
//! The PJRT client itself (the `xla` crate + XLA C library) is only
//! linked when the crate is built with the **`pjrt` cargo feature**.
//! Without it, [`SolverRuntime::new`] returns an error and every caller
//! falls back to the native decoder — the default build has no external
//! dependencies beyond `anyhow`.

mod tiler;

pub use tiler::{pad_decode_inputs, PaddedTile};

/// Static-shape variant identifier, parsed from artifact file names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArtifactKey {
    /// Row dimension M of the compiled kernel.
    pub m: usize,
    /// Column-tile width T.
    pub ntile: usize,
    /// Number of sampled paths K (greedy path is additional).
    pub k: usize,
}

impl ArtifactKey {
    /// Canonical artifact file name.
    pub fn file_name(&self) -> String {
        format!("ojbkq_m{}_t{}_k{}.hlo.txt", self.m, self.ntile, self.k)
    }

    /// Parse `ojbkq_m{M}_t{T}_k{K}.hlo.txt`.
    pub fn parse(name: &str) -> Option<ArtifactKey> {
        let stem = name.strip_suffix(".hlo.txt")?.strip_prefix("ojbkq_m")?;
        let (m, rest) = stem.split_once("_t")?;
        let (t, k) = rest.split_once("_k")?;
        Some(ArtifactKey { m: m.parse().ok()?, ntile: t.parse().ok()?, k: k.parse().ok()? })
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! Built without the `pjrt` feature: an API-compatible stand-in whose
    //! constructor always fails, steering every call site onto the native
    //! decoder path.

    use super::ArtifactKey;
    use crate::tensor::Matrix;
    use std::path::Path;

    /// Unavailable PJRT runtime (crate built without the `pjrt` feature).
    pub struct SolverRuntime {
        registry: Vec<ArtifactKey>,
    }

    impl SolverRuntime {
        /// Always errors: enable the `pjrt` cargo feature (and provide the
        /// `xla` crate + XLA C library) for the real runtime.
        pub fn new(_dir: &Path) -> anyhow::Result<SolverRuntime> {
            anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` cargo feature")
        }

        /// Registered decoder variants (unreachable: `new` always errors).
        pub fn registry(&self) -> &[ArtifactKey] {
            &self.registry
        }

        /// No variant is ever available without the PJRT client.
        pub fn select_variant(&self, _m: usize, _ntile: usize, _k: usize) -> Option<ArtifactKey> {
            None
        }

        /// Always errors (unreachable: `new` always errors).
        #[allow(clippy::too_many_arguments)]
        pub fn decode_tile(
            &self,
            _r: &Matrix,
            _s: &Matrix,
            _qbar: &Matrix,
            _qmax: f32,
            _k: usize,
            _alpha: &[f32],
            _uniforms: &[f32],
        ) -> anyhow::Result<Matrix> {
            anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` cargo feature")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::SolverRuntime;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{pad_decode_inputs, ArtifactKey};
    use crate::tensor::Matrix;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// PJRT-backed solver runtime.
    pub struct SolverRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        registry: Vec<ArtifactKey>,
        cache: Mutex<HashMap<ArtifactKey, xla::PjRtLoadedExecutable>>,
    }

    impl SolverRuntime {
        /// Create from an artifact directory (typically `artifacts/`).
        /// Scans for decoder artifacts; errors if the directory is
        /// missing. An empty registry is allowed (the runtime can still
        /// run model artifacts).
        pub fn new(dir: &Path) -> anyhow::Result<SolverRuntime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client init failed: {e:?}"))?;
            let mut registry = Vec::new();
            let entries = std::fs::read_dir(dir).map_err(|e| {
                anyhow::anyhow!("artifact dir {dir:?} unreadable: {e} (run `make artifacts`)")
            })?;
            for entry in entries.flatten() {
                if let Some(name) = entry.file_name().to_str() {
                    if let Some(key) = ArtifactKey::parse(name) {
                        registry.push(key);
                    }
                }
            }
            registry.sort();
            Ok(SolverRuntime {
                client,
                dir: dir.to_path_buf(),
                registry,
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// Registered decoder variants.
        pub fn registry(&self) -> &[ArtifactKey] {
            &self.registry
        }

        /// Smallest registered variant covering `(m, ntile)` with exact `k`.
        pub fn select_variant(&self, m: usize, ntile: usize, k: usize) -> Option<ArtifactKey> {
            self.registry
                .iter()
                .filter(|a| a.k == k && a.m >= m && a.ntile >= ntile)
                .min_by_key(|a| (a.m, a.ntile))
                .copied()
        }

        fn ensure_compiled(&self, key: ArtifactKey) -> anyhow::Result<()> {
            let mut cache = self.cache.lock().unwrap();
            if cache.contains_key(&key) {
                return Ok(());
            }
            let path = self.dir.join(key.file_name());
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
            cache.insert(key, exe);
            Ok(())
        }

        /// Decode one column tile through the AOT Pallas kernel. Contract
        /// matches [`crate::quant::ppi::decode_tile`]: same inputs (with
        /// `uniforms` laid out `[path][row][col]`), returns the winning codes.
        pub fn decode_tile(
            &self,
            r: &Matrix,
            s: &Matrix,
            qbar: &Matrix,
            qmax: f32,
            k: usize,
            alpha: &[f32],
            uniforms: &[f32],
        ) -> anyhow::Result<Matrix> {
            let m = r.rows();
            let ntile = qbar.cols();
            let key = self.select_variant(m, ntile, k).ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact variant for m={m} ntile={ntile} k={k}; registry={:?}",
                    self.registry
                )
            })?;
            self.ensure_compiled(key)?;
            let padded = pad_decode_inputs(r, s, qbar, alpha, uniforms, k, key.m, key.ntile);

            let lit = |data: &[f32], dims: &[i64]| -> anyhow::Result<xla::Literal> {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow::anyhow!("literal reshape {dims:?}: {e:?}"))
            };
            let mm = key.m as i64;
            let tt = key.ntile as i64;
            let kk = (k + 1) as i64;
            let args = [
                lit(padded.r.as_slice(), &[mm, mm])?,
                lit(padded.s.as_slice(), &[mm, tt])?,
                lit(padded.qbar.as_slice(), &[mm, tt])?,
                lit(&padded.alpha, &[tt])?,
                lit(&padded.uniforms, &[kk, mm, tt])?,
                xla::Literal::scalar(qmax),
            ];
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(&key).unwrap();
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", key.file_name()))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
            // aot.py lowers with return_tuple=True; output 0 is Q (M×T).
            let q_lit =
                result.to_tuple1().map_err(|e| anyhow::anyhow!("unwrapping tuple: {e:?}"))?;
            let q_flat: Vec<f32> = q_lit.to_vec().map_err(|e| anyhow::anyhow!("reading Q: {e:?}"))?;
            let expected = key.m * key.ntile;
            anyhow::ensure!(q_flat.len() == expected, "unexpected Q size {}", q_flat.len());
            // Crop padding back off.
            let q_full = Matrix::from_vec(key.m, key.ntile, q_flat);
            Ok(q_full.block(0, 0, m, ntile))
        }

        /// Load, compile and run an arbitrary artifact by file stem — generic
        /// escape hatch used by integration tests and examples that exercise
        /// non-decoder artifacts.
        pub fn run_artifact(
            &self,
            stem: &str,
            inputs: &[xla::Literal],
        ) -> anyhow::Result<xla::Literal> {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
            let out = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("executing {stem}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
            Ok(out)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn variant_selection_prefers_smallest_cover() {
            let rtm = SolverRuntime {
                client: match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(_) => return, // no PJRT in this environment: skip
                },
                dir: PathBuf::from("/nonexistent"),
                registry: vec![
                    ArtifactKey { m: 64, ntile: 32, k: 5 },
                    ArtifactKey { m: 128, ntile: 64, k: 5 },
                    ArtifactKey { m: 256, ntile: 64, k: 5 },
                    ArtifactKey { m: 128, ntile: 64, k: 0 },
                ],
                cache: Mutex::new(HashMap::new()),
            };
            assert_eq!(
                rtm.select_variant(100, 40, 5),
                Some(ArtifactKey { m: 128, ntile: 64, k: 5 })
            );
            assert_eq!(
                rtm.select_variant(64, 32, 5),
                Some(ArtifactKey { m: 64, ntile: 32, k: 5 })
            );
            assert_eq!(rtm.select_variant(300, 32, 5), None);
            assert_eq!(
                rtm.select_variant(65, 1, 0),
                Some(ArtifactKey { m: 128, ntile: 64, k: 0 })
            );
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::SolverRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_key_roundtrip() {
        let k = ArtifactKey { m: 128, ntile: 64, k: 5 };
        assert_eq!(ArtifactKey::parse(&k.file_name()), Some(k));
        assert_eq!(ArtifactKey::parse("garbage.txt"), None);
        assert_eq!(
            ArtifactKey::parse("ojbkq_m64_t32_k0.hlo.txt"),
            Some(ArtifactKey { m: 64, ntile: 32, k: 0 })
        );
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = SolverRuntime::new(std::path::Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
