//! Padding of decode-tile inputs up to a static artifact shape.
//!
//! Padding must be *semantically inert*: the decode of real rows/columns
//! must be identical with and without padding. The construction:
//!
//! * `R` → block-diagonal `[R 0; 0 I]`: pad rows never influence real
//!   rows (the look-ahead term `R[i, pad]·E[pad]` is zero) and decode to
//!   `q = round(0) = 0` themselves.
//! * `S` pads with 1 (any positive value works; 1 keeps α finite).
//! * `Q̄` pads with 0 → pad codes are 0, pad errors are 0.
//! * `α` pads with 1.
//! * `uniforms` pad with 0.5 (value irrelevant — pad centers are exact
//!   integers so every path rounds/samples to the same code 0... almost:
//!   sampling at an exact integer center still has tail mass, so pad
//!   columns may decode nonzero on sampled paths. That is still inert:
//!   pad columns are cropped, and pad *rows* cannot affect real rows
//!   because `R[real, pad] = 0` and column residuals are per-column).

use crate::tensor::Matrix;

/// Inputs padded to the artifact's static shape.
pub struct PaddedTile {
    pub r: Matrix,
    pub s: Matrix,
    pub qbar: Matrix,
    pub alpha: Vec<f32>,
    pub uniforms: Vec<f32>,
}

/// Pad `(r, s, qbar, alpha, uniforms)` from `(m, ntile)` up to `(mm, tt)`.
pub fn pad_decode_inputs(
    r: &Matrix,
    s: &Matrix,
    qbar: &Matrix,
    alpha: &[f32],
    uniforms: &[f32],
    k: usize,
    mm: usize,
    tt: usize,
) -> PaddedTile {
    let m = r.rows();
    let ntile = qbar.cols();
    assert!(mm >= m && tt >= ntile);
    assert_eq!(uniforms.len(), (k + 1) * m * ntile);

    let mut r_pad = r.pad_to(mm, mm);
    for i in m..mm {
        r_pad.set(i, i, 1.0);
    }
    let mut s_pad = Matrix::full(mm, tt, 1.0);
    s_pad.set_block(0, 0, s);
    let qbar_pad = qbar.pad_to(mm, tt);
    let mut alpha_pad = vec![1.0f32; tt];
    alpha_pad[..ntile].copy_from_slice(alpha);
    let mut uni_pad = vec![0.5f32; (k + 1) * mm * tt];
    for p in 0..=k {
        for i in 0..m {
            for j in 0..ntile {
                uni_pad[(p * mm + i) * tt + j] = uniforms[(p * m + i) * ntile + j];
            }
        }
    }
    PaddedTile { r: r_pad, s: s_pad, qbar: qbar_pad, alpha: alpha_pad, uniforms: uni_pad }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cholesky_upper, syrk_upper};
    use crate::quant::klein::alpha_for;
    use crate::quant::ppi::{decode_tile, PpiInput};
    use crate::rng::Rng;

    /// The semantic-inertness property, checked against the native
    /// decoder: decoding the padded problem and cropping equals decoding
    /// the original problem.
    #[test]
    fn padding_is_semantically_inert() {
        let (m, ntile, k) = (24usize, 5usize, 3usize);
        let (mm, tt) = (40usize, 8usize);
        let mut rng = Rng::new(1);
        let a = Matrix::randn(m + 4, m, 1.0, &mut rng);
        let g = syrk_upper(&a, 0.05);
        let r = cholesky_upper(&g).unwrap();
        let s = Matrix::from_fn(m, ntile, |_, _| 0.05 + 0.2 * rng.uniform_f32());
        let qbar = Matrix::from_fn(m, ntile, |_, _| 15.0 * rng.uniform_f32());
        let alpha: Vec<f32> = (0..ntile)
            .map(|j| {
                let mn = (0..m)
                    .map(|i| {
                        let v = r.get(i, i) as f64 * s.get(i, j) as f64;
                        v * v
                    })
                    .fold(f64::INFINITY, f64::min);
                alpha_for(k, m, mn) as f32
            })
            .collect();
        let uniforms = Rng::new(2).uniform_vec_f32((k + 1) * m * ntile);

        let base = decode_tile(&PpiInput {
            r: &r,
            s: &s,
            qbar: &qbar,
            qmax: 15.0,
            k,
            block: 8,
            alpha: &alpha,
            uniforms: &uniforms,
        });
        let p = pad_decode_inputs(&r, &s, &qbar, &alpha, &uniforms, k, mm, tt);
        let padded = decode_tile(&PpiInput {
            r: &p.r,
            s: &p.s,
            qbar: &p.qbar,
            qmax: 15.0,
            k,
            block: 8,
            alpha: &p.alpha,
            uniforms: &p.uniforms,
        });
        let cropped = padded.q.block(0, 0, m, ntile);
        assert_eq!(cropped.as_slice(), base.q.as_slice());
    }

    #[test]
    fn pad_shapes() {
        let r = Matrix::eye(4);
        let s = Matrix::full(4, 2, 0.1);
        let qbar = Matrix::zeros(4, 2);
        let alpha = vec![1.0; 2];
        let uniforms = vec![0.3; 2 * 4 * 2]; // k=1
        let p = pad_decode_inputs(&r, &s, &qbar, &alpha, &uniforms, 1, 6, 3);
        assert_eq!(p.r.shape(), (6, 6));
        assert_eq!(p.r.get(5, 5), 1.0);
        assert_eq!(p.r.get(4, 5), 0.0);
        assert_eq!(p.s.get(5, 2), 1.0);
        assert_eq!(p.uniforms.len(), 2 * 6 * 3);
        // Original uniform mapped to right position.
        assert_eq!(p.uniforms[(1 * 6 + 3) * 3 + 1], 0.3);
    }
}
