//! Token-serving engine: KV-cached autoregressive decode + continuous
//! batching on the packed integer core.
//!
//! Teacher-forced eval ([`crate::eval`]) and [`LanguageModel::
//! greedy_continue`] re-forward the whole prefix for every generated
//! token — O(T²) work per sequence. This module is the deployment
//! serving loop OJBKQ's memory savings are aimed at (the memory-bound
//! `m = 1` decode regime): forward the prompt **once**, cache every
//! block's K/V rows, then advance one token per step through
//! allocation-free single-row kernels. Three layers:
//!
//! * [`KvCache`] — per-(sequence, block) key/value rows at fixed
//!   capacity, appended one row per decode step. Capacity is
//!   `prompt_len + max_new` (clamped to `max_seq`), so resident cache
//!   bytes are known at admission ([`KvCache::bytes`]).
//! * [`ServeEngine`] — [`ServeEngine::prefill`] runs the model's own
//!   batch stages over the prompt while capturing K/V;
//!   [`ServeEngine::decode_step`] advances one token through
//!   [`crate::model::embed_token_into`] → per-block
//!   [`crate::model::rmsnorm_row`] / [`PackedLinear::gemv_into`] /
//!   [`crate::model::attention_step`] → [`crate::linalg::
//!   row_matmul_into`] LM head, every buffer living in a caller-held
//!   [`DecodeScratch`] so the hot loop performs **zero heap
//!   allocations** after warm-up. [`ServeEngine::decode_step_batch`]
//!   stacks the live sequences' rows and drives each linear through one
//!   [`crate::infer::qgemm_packed`] call, with the ragged per-sequence
//!   attention fanned out via [`parallel_map_dynamic`].
//! * [`Scheduler`] — continuous batching: requests are admitted
//!   (prefilled) whenever a slot is free, decoded in lockstep, and
//!   retired the moment they hit their token budget — sequences join
//!   and leave the batch between steps, no padding, no drain barrier.
//!
//! **Bit-identity.** Decode logits equal the teacher-forced
//! [`LanguageModel::forward_batch`] logits at every position, on both
//! packed cores and the dense-exec leg (pinned by
//! `tests/serve_decode.rs`). The chain: every per-row helper is the
//! extracted body of its batch twin (`embed_token_into`, `rmsnorm_row`,
//! `attention_step`, `row_matmul_into`, `gemv_into` — see each one's
//! docs), every stage of the transformer is row-independent given the
//! cached K/V, and cached K/V rows are themselves outputs of the same
//! projections the batch path runs. Batched decode equals single-stream
//! decode for the same reason, so the scheduler's batching decisions
//! never change any sequence's tokens.

use crate::config::ModelConfig;
use crate::infer::{GemvScratch, PackedLinear, QuantizedModel};
use crate::linalg::{matmul_par, row_matmul_into};
use crate::model::{
    attention_step, causal_attention, embed_token_into, rmsnorm, rmsnorm_row, silu, LinearKind,
};
use crate::parallel::parallel_map_dynamic;
use crate::rng::Rng;
use crate::tensor::Matrix;
use std::collections::VecDeque;
use std::fmt;
use std::time::{Duration, Instant};

/// Cached key/value rows for one (sequence, block) pair: two
/// `capacity × d_model` panels filled top-down, one row per position.
#[derive(Debug, Clone)]
pub struct KvCache {
    k: Matrix,
    v: Matrix,
    len: usize,
}

impl KvCache {
    /// Fixed-capacity cache (capacity = the sequence's final length,
    /// known at admission).
    pub fn new(capacity: usize, d_model: usize) -> KvCache {
        KvCache { k: Matrix::zeros(capacity, d_model), v: Matrix::zeros(capacity, d_model), len: 0 }
    }

    /// Append the K/V projection rows of the next position.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert!(self.len < self.k.rows(), "KV cache capacity exceeded");
        self.k.row_mut(self.len).copy_from_slice(k_row);
        self.v.row_mut(self.len).copy_from_slice(v_row);
        self.len += 1;
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the first append.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Row capacity (the admission-time sequence budget).
    pub fn capacity(&self) -> usize {
        self.k.rows()
    }

    /// Key panel (rows `0..len()` are valid).
    pub fn keys(&self) -> &Matrix {
        &self.k
    }

    /// Value panel (rows `0..len()` are valid).
    pub fn values(&self) -> &Matrix {
        &self.v
    }

    /// Resident bytes of this cache (full capacity — the allocation is
    /// made at admission, not grown per step).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// Total resident bytes of one sequence's per-block caches.
pub fn kv_bytes(caches: &[KvCache]) -> usize {
    caches.iter().map(|c| c.bytes()).sum()
}

/// Caller-held buffers for the single-token decode hot loop: hidden
/// rows, projection rows, the packed-GEMV scratch arena, and the logits
/// row. Sized once from the model config; [`ServeEngine::decode_step`]
/// allocates nothing.
#[derive(Debug)]
pub struct DecodeScratch {
    /// Resident hidden row (`d_model`).
    x: Vec<f32>,
    /// Normed row feeding the linears (`d_model`).
    h: Vec<f32>,
    /// Q projection row (`d_model`).
    q: Vec<f32>,
    /// K projection row (`d_model`).
    k: Vec<f32>,
    /// V projection row (`d_model`).
    v: Vec<f32>,
    /// Attention context row (`d_model`).
    ctx: Vec<f32>,
    /// O/Down projection output row (`d_model`).
    o: Vec<f32>,
    /// Post-attention residual row (`d_model`).
    x_mid: Vec<f32>,
    /// Gate projection row (`d_ff`).
    g: Vec<f32>,
    /// Up projection row (`d_ff`).
    u: Vec<f32>,
    /// SwiGLU activation row (`d_ff`).
    act: Vec<f32>,
    /// LM-head logits row (`vocab_size`).
    logits: Vec<f32>,
    /// Packed single-row GEMV arena ([`GemvScratch`]).
    gemv: GemvScratch,
}

impl DecodeScratch {
    /// Buffers sized for `cfg`.
    pub fn new(cfg: &ModelConfig) -> DecodeScratch {
        DecodeScratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.d_model],
            k: vec![0.0; cfg.d_model],
            v: vec![0.0; cfg.d_model],
            ctx: vec![0.0; cfg.d_model],
            o: vec![0.0; cfg.d_model],
            x_mid: vec![0.0; cfg.d_model],
            g: vec![0.0; cfg.d_ff],
            u: vec![0.0; cfg.d_ff],
            act: vec![0.0; cfg.d_ff],
            logits: vec![0.0; cfg.vocab_size],
            gemv: GemvScratch::new(),
        }
    }
}

/// The KV-cached serving engine over a [`QuantizedModel`].
pub struct ServeEngine<'m> {
    model: &'m QuantizedModel,
    /// `d × vocab` transposed tied head, materialized once — the same
    /// matrix [`QuantizedModel::lm_head`] transposes per call.
    head_t: Matrix,
}

impl<'m> ServeEngine<'m> {
    /// Wrap a packed model for serving.
    pub fn new(model: &'m QuantizedModel) -> ServeEngine<'m> {
        ServeEngine { model, head_t: model.embedding.transpose() }
    }

    /// The wrapped model.
    pub fn model(&self) -> &QuantizedModel {
        self.model
    }

    /// Fresh per-block caches for a sequence with final length
    /// `capacity`.
    pub fn new_caches(&self, capacity: usize) -> Vec<KvCache> {
        (0..self.model.blocks.len())
            .map(|_| KvCache::new(capacity, self.model.cfg.d_model))
            .collect()
    }

    fn lin(&self, block_idx: usize, kind: LinearKind) -> &PackedLinear {
        &self.model.blocks[block_idx].linears()[kind.index()]
    }

    /// Forward the whole prompt once, filling `caches` with every
    /// block's K/V rows, and return the logits at **all** prompt
    /// positions (the last row seeds sampling; the rest are the parity
    /// surface). Runs the model's own stage calls — bit-identical to
    /// [`crate::model::LanguageModel::forward`] — capturing the K/V
    /// GEMM outputs the attention core already computes.
    pub fn prefill(&self, tokens: &[u16], caches: &mut [KvCache]) -> Matrix {
        let _sp = crate::obs::span("prefill");
        assert_eq!(caches.len(), self.model.blocks.len(), "one cache per block");
        let m = self.model;
        let mut x = m.embed_sequence(tokens);
        for (bi, cache) in caches.iter_mut().enumerate() {
            assert_eq!(cache.len, 0, "prefill needs empty caches");
            let h = m.attn_in(&x, bi);
            let q = self.lin(bi, LinearKind::Q).matmul(&h);
            let k = self.lin(bi, LinearKind::K).matmul(&h);
            let v = self.lin(bi, LinearKind::V).matmul(&h);
            for r in 0..k.rows() {
                cache.append(k.row(r), v.row(r));
            }
            let ctx = causal_attention(&q, &k, &v, m.cfg.n_heads);
            let x_mid = m.post_attn(&x, &ctx, bi);
            let h2 = m.mlp_in(&x_mid, bi);
            let act = m.mlp_act(&h2, bi);
            x = m.post_mlp(&x_mid, &act, bi);
        }
        m.lm_head(&x)
    }

    /// Advance one sequence one token: embed `tok` at absolute position
    /// `pos` (which must equal the cache length), append its K/V rows,
    /// attend over the cache, and return the logits row for the next
    /// position. Every buffer lives in `scratch`; every linear runs
    /// through [`PackedLinear::gemv_into`] — the loop is allocation-free
    /// after scratch warm-up. Bit-identical to the corresponding
    /// teacher-forced [`crate::model::LanguageModel::forward_batch`]
    /// logits row.
    pub fn decode_step<'a>(
        &self,
        tok: u16,
        pos: usize,
        caches: &mut [KvCache],
        scratch: &'a mut DecodeScratch,
    ) -> &'a [f32] {
        let _sp = crate::obs::span("decode_step");
        let cfg = &self.model.cfg;
        let d = cfg.d_model;
        let s = scratch;
        embed_token_into(&self.model.embedding, cfg, tok, pos, &mut s.x);
        for (bi, cache) in caches.iter_mut().enumerate() {
            debug_assert_eq!(cache.len, pos, "cache length must equal the decode position");
            let block = &self.model.blocks[bi];
            rmsnorm_row(&s.x, &block.attn_norm, &mut s.h);
            self.lin(bi, LinearKind::Q).gemv_into(&s.h, &mut s.gemv, &mut s.q);
            self.lin(bi, LinearKind::K).gemv_into(&s.h, &mut s.gemv, &mut s.k);
            self.lin(bi, LinearKind::V).gemv_into(&s.h, &mut s.gemv, &mut s.v);
            cache.append(&s.k, &s.v);
            attention_step(&s.q, &cache.k, &cache.v, cache.len, cfg.n_heads, &mut s.ctx);
            self.lin(bi, LinearKind::O).gemv_into(&s.ctx, &mut s.gemv, &mut s.o);
            for j in 0..d {
                s.x_mid[j] = s.x[j] + s.o[j];
            }
            rmsnorm_row(&s.x_mid, &block.mlp_norm, &mut s.h);
            self.lin(bi, LinearKind::Gate).gemv_into(&s.h, &mut s.gemv, &mut s.g);
            self.lin(bi, LinearKind::Up).gemv_into(&s.h, &mut s.gemv, &mut s.u);
            for j in 0..cfg.d_ff {
                s.act[j] = silu(s.g[j]) * s.u[j];
            }
            self.lin(bi, LinearKind::Down).gemv_into(&s.act, &mut s.gemv, &mut s.o);
            for j in 0..d {
                s.x[j] = s.x_mid[j] + s.o[j];
            }
        }
        rmsnorm_row(&s.x, &self.model.final_norm, &mut s.h);
        row_matmul_into(&s.h, &self.head_t, &mut s.logits);
        &s.logits
    }

    /// Advance several sequences one token each in lockstep: their rows
    /// are stacked so every linear runs as **one** multi-row
    /// [`crate::infer::qgemm_packed`] call (the continuous-batching
    /// payoff — codes unpack once per step, not once per sequence), and
    /// the ragged per-sequence attention fans out via
    /// [`parallel_map_dynamic`]. Returns one logits row per input.
    /// Bit-identical to running [`ServeEngine::decode_step`] per
    /// sequence: every stage is row-independent, and the packed grid is
    /// bit-exact under batching.
    pub fn decode_step_batch(
        &self,
        inputs: &[(u16, usize)],
        caches: &mut [&mut [KvCache]],
    ) -> Matrix {
        let _sp = crate::obs::span("decode_step");
        let b = inputs.len();
        assert_eq!(caches.len(), b, "one cache set per sequence");
        let cfg = &self.model.cfg;
        let d = cfg.d_model;
        let mut x = Matrix::zeros(b, d);
        for (r, &(tok, pos)) in inputs.iter().enumerate() {
            embed_token_into(&self.model.embedding, cfg, tok, pos, x.row_mut(r));
        }
        for bi in 0..self.model.blocks.len() {
            let block = &self.model.blocks[bi];
            let h = rmsnorm(&x, &block.attn_norm);
            let q = self.lin(bi, LinearKind::Q).matmul(&h);
            let k = self.lin(bi, LinearKind::K).matmul(&h);
            let v = self.lin(bi, LinearKind::V).matmul(&h);
            for (r, c) in caches.iter_mut().enumerate() {
                c[bi].append(k.row(r), v.row(r));
            }
            let cs: Vec<&KvCache> = caches.iter().map(|c| &c[bi]).collect();
            let ctx_rows = parallel_map_dynamic(b, |r| {
                let cache = cs[r];
                let mut out = vec![0.0f32; d];
                attention_step(q.row(r), &cache.k, &cache.v, cache.len, cfg.n_heads, &mut out);
                out
            });
            let mut ctx = Matrix::zeros(b, d);
            for (r, row) in ctx_rows.iter().enumerate() {
                ctx.row_mut(r).copy_from_slice(row);
            }
            let x_mid = x.add(&self.lin(bi, LinearKind::O).matmul(&ctx));
            let h2 = rmsnorm(&x_mid, &block.mlp_norm);
            let g = self.lin(bi, LinearKind::Gate).matmul(&h2);
            let u = self.lin(bi, LinearKind::Up).matmul(&h2);
            let act = Matrix::from_fn(b, cfg.d_ff, |i, j| silu(g.get(i, j)) * u.get(i, j));
            x = x_mid.add(&self.lin(bi, LinearKind::Down).matmul(&act));
        }
        let xf = rmsnorm(&x, &self.model.final_norm);
        matmul_par(&xf, &self.head_t)
    }
}

/// Reusable buffers for temperature sampling — owned by the caller
/// ([`Scheduler`] keeps one next to its [`DecodeScratch`]) so the decode
/// hot path samples without any per-token heap allocation after the
/// first warm-up call.
#[derive(Debug, Default)]
pub struct SampleScratch {
    /// Temperature-scaled log-softmax row (`vocab_size`).
    ls: Vec<f32>,
    /// Unnormalized probabilities for [`Rng::categorical`].
    probs: Vec<f64>,
}

impl SampleScratch {
    /// Scratch pre-sized for a `vocab_size`-wide logits row.
    pub fn new(vocab_size: usize) -> SampleScratch {
        SampleScratch {
            ls: Vec::with_capacity(vocab_size),
            probs: Vec::with_capacity(vocab_size),
        }
    }
}

/// Sample a token from a logits row: greedy argmax at `temperature ≤ 0`,
/// otherwise softmax at the given temperature through
/// [`Rng::categorical`]. Allocating convenience wrapper around
/// [`sample_token_scratch`] — bit-identical draws.
pub fn sample_token(logits: &[f32], temperature: f32, rng: &mut Rng) -> u16 {
    let mut scratch = SampleScratch::new(logits.len());
    sample_token_scratch(logits, temperature, rng, &mut scratch)
}

/// [`sample_token`] from caller-owned scratch: the temperature scale is
/// folded into [`crate::util::log_softmax_scaled_into`] and both the
/// log-softmax row and the probability vector live in `scratch`, so the
/// per-token decode path performs zero heap allocations once the
/// buffers have grown to `vocab_size`.
pub fn sample_token_scratch(
    logits: &[f32],
    temperature: f32,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) -> u16 {
    if temperature <= 0.0 {
        return crate::util::argmax(logits) as u16;
    }
    crate::util::log_softmax_scaled_into(logits, temperature, &mut scratch.ls);
    scratch.probs.clear();
    scratch.probs.extend(scratch.ls.iter().map(|&l| (l as f64).exp()));
    rng.categorical(&scratch.probs) as u16
}

/// A generation request submitted to the [`Scheduler`].
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`FinishedRequest`].
    pub id: u64,
    /// Prompt tokens (non-empty, at most `max_seq`).
    pub prompt: Vec<u16>,
    /// Token budget; clamped so `prompt + generated ≤ max_seq`.
    pub max_new: usize,
    /// `≤ 0` = greedy; otherwise softmax temperature.
    pub temperature: f32,
    /// Per-request sampling stream ([`Rng::new`]) — batching order
    /// never changes a request's random draws.
    pub seed: u64,
}

/// Why [`Scheduler::submit`] refused a request. Admission control turns
/// malformed or over-capacity submissions into a structured rejection
/// instead of a panic deep in the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The prompt has no tokens — nothing to prefill.
    EmptyPrompt,
    /// The prompt alone exceeds the model's context window.
    PromptTooLong {
        /// Submitted prompt length.
        len: usize,
        /// The model's context window.
        max_seq: usize,
    },
    /// The pending queue is at [`Scheduler::set_max_queue`] capacity.
    QueueFull {
        /// Queue depth at rejection time.
        depth: usize,
        /// The configured capacity.
        max_queue: usize,
    },
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::EmptyPrompt => write!(f, "empty prompt"),
            RejectReason::PromptTooLong { len, max_seq } => {
                write!(f, "prompt length {len} exceeds max_seq {max_seq}")
            }
            RejectReason::QueueFull { depth, max_queue } => {
                write!(f, "queue depth {depth} at capacity {max_queue}")
            }
        }
    }
}

impl std::error::Error for RejectReason {}

/// How a request left the scheduler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishStatus {
    /// Generated its full (clamped) token budget.
    Complete,
    /// Retired by the per-request deadline ([`Scheduler::set_deadline`])
    /// before its budget filled; `generated` holds the partial output.
    DeadlineExceeded,
    /// Retired by a serve-side failure (poisoned logits, torn token
    /// stream, injected fault); the message names the cause. The batch
    /// keeps running — one poisoned request never takes down its peers.
    Error(String),
}

impl fmt::Display for FinishStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FinishStatus::Complete => write!(f, "complete"),
            FinishStatus::DeadlineExceeded => write!(f, "deadline exceeded"),
            FinishStatus::Error(msg) => write!(f, "error: {msg}"),
        }
    }
}

/// A completed request, in retirement order.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    /// The submitted id.
    pub id: u64,
    /// Prompt length (positions served from the prefill).
    pub prompt_len: usize,
    /// Generated tokens, in order (length ≤ the requested `max_new`).
    pub generated: Vec<u16>,
    /// Resident KV-cache bytes this sequence held while live.
    pub kv_bytes: usize,
    /// How the request left the scheduler ([`FinishStatus::Complete`]
    /// unless a deadline or serve-side failure retired it early).
    pub status: FinishStatus,
}

/// One live sequence between decode steps.
struct ActiveSeq {
    id: u64,
    prompt_len: usize,
    /// Prompt + generated so far; the last entry is the next token to
    /// embed, at position `tokens.len() − 1 == cache.len()`.
    tokens: Vec<u16>,
    generated: Vec<u16>,
    max_new: usize,
    temperature: f32,
    rng: Rng,
    caches: Vec<KvCache>,
    /// Submission time, for the per-request deadline.
    submitted: Instant,
}

/// Continuous-batching scheduler: admits pending requests into free
/// slots (prefill + first sample), advances every live sequence one
/// token per [`Scheduler::step`] — through the batched engine path when
/// ≥ 2 are live, the scratch-arena single-stream path otherwise — and
/// retires sequences the moment they hit their budget. A retired
/// request never re-enters a batch, so it contributes no further
/// tokens.
pub struct Scheduler<'m> {
    engine: ServeEngine<'m>,
    max_concurrent: usize,
    /// Pending-queue capacity; submissions beyond it are rejected.
    max_queue: usize,
    /// Per-request wall-clock deadline, measured from submission.
    deadline: Option<Duration>,
    pending: VecDeque<(Request, Instant)>,
    active: Vec<ActiveSeq>,
    finished: Vec<FinishedRequest>,
    scratch: DecodeScratch,
    sample: SampleScratch,
    /// Wall-clock split, for the serving-rate report.
    prefill_secs: f64,
    decode_secs: f64,
    tokens_generated: u64,
    peak_kv_bytes: usize,
}

impl<'m> Scheduler<'m> {
    /// A scheduler serving `model` with at most `max_concurrent` live
    /// sequences (≥ 1).
    pub fn new(model: &'m QuantizedModel, max_concurrent: usize) -> Scheduler<'m> {
        assert!(max_concurrent >= 1, "need at least one slot");
        let scratch = DecodeScratch::new(&model.cfg);
        let sample = SampleScratch::new(model.cfg.vocab_size);
        Scheduler {
            engine: ServeEngine::new(model),
            max_concurrent,
            max_queue: usize::MAX,
            deadline: None,
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            scratch,
            sample,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            tokens_generated: 0,
            peak_kv_bytes: 0,
        }
    }

    /// The wrapped engine (parity tests drive it directly).
    pub fn engine(&self) -> &ServeEngine<'m> {
        &self.engine
    }

    /// Cap the pending queue at `max_queue` submissions (≥ 1); further
    /// [`Scheduler::submit`] calls are rejected with
    /// [`RejectReason::QueueFull`] until admissions drain the queue.
    pub fn set_max_queue(&mut self, max_queue: usize) {
        assert!(max_queue >= 1, "need at least one queue slot");
        self.max_queue = max_queue;
    }

    /// Retire requests still live `deadline` after submission with
    /// [`FinishStatus::DeadlineExceeded`] (partial output kept).
    pub fn set_deadline(&mut self, deadline: Duration) {
        self.deadline = Some(deadline);
    }

    /// Queue a request (admitted FIFO as slots free up). Admission
    /// control rejects malformed or over-capacity submissions instead of
    /// panicking: empty prompts, prompts beyond `max_seq`, and
    /// submissions past the [`Scheduler::set_max_queue`] depth all come
    /// back as a structured [`RejectReason`].
    pub fn submit(&mut self, req: Request) -> Result<(), RejectReason> {
        let max_seq = self.engine.model.cfg.max_seq;
        let reject = if req.prompt.is_empty() {
            Some(RejectReason::EmptyPrompt)
        } else if req.prompt.len() > max_seq {
            Some(RejectReason::PromptTooLong { len: req.prompt.len(), max_seq })
        } else if self.pending.len() >= self.max_queue {
            Some(RejectReason::QueueFull { depth: self.pending.len(), max_queue: self.max_queue })
        } else {
            None
        };
        if let Some(reason) = reject {
            crate::obs::counter_add("serve.requests_rejected", 1);
            return Err(reason);
        }
        self.pending.push_back((req, Instant::now()));
        Ok(())
    }

    /// Live sequences.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Queued, not-yet-admitted requests.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Completed requests, in retirement order.
    pub fn finished(&self) -> &[FinishedRequest] {
        &self.finished
    }

    /// Resident KV-cache bytes across the live sequences right now.
    pub fn kv_bytes(&self) -> usize {
        self.active.iter().map(|s| kv_bytes(&s.caches)).sum()
    }

    /// Largest concurrent KV residency seen so far.
    pub fn peak_kv_bytes(&self) -> usize {
        self.peak_kv_bytes
    }

    /// Total tokens sampled so far (prefill-seeded first tokens
    /// included).
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated
    }

    /// Wall-clock seconds spent in prefill so far.
    pub fn prefill_secs(&self) -> f64 {
        self.prefill_secs
    }

    /// Wall-clock seconds spent in decode steps so far.
    pub fn decode_secs(&self) -> f64 {
        self.decode_secs
    }

    fn sample_and_account(
        seq: &mut ActiveSeq,
        logits: &[f32],
        total: &mut u64,
        scratch: &mut SampleScratch,
    ) {
        let tok = sample_token_scratch(logits, seq.temperature, &mut seq.rng, scratch);
        seq.generated.push(tok);
        seq.tokens.push(tok);
        *total += 1;
        crate::obs::counter_add("serve.tokens_generated", 1);
    }

    /// Move a sequence to the finished list with `status`.
    fn finish_with(&mut self, seq: ActiveSeq, status: FinishStatus) {
        crate::obs::counter_add("serve.requests_retired", 1);
        self.finished.push(FinishedRequest {
            id: seq.id,
            prompt_len: seq.prompt_len,
            generated: seq.generated,
            kv_bytes: kv_bytes(&seq.caches),
            status,
        });
    }

    /// Admit pending requests into free slots: allocate caches, prefill
    /// the prompt, sample the first token.
    fn admit(&mut self) {
        let max_seq = self.engine.model.cfg.max_seq;
        while self.active.len() < self.max_concurrent {
            let Some((req, submitted)) = self.pending.pop_front() else { break };
            crate::obs::counter_add("serve.requests_admitted", 1);
            let prompt_len = req.prompt.len();
            let max_new = req.max_new.min(max_seq - prompt_len);
            if max_new == 0 {
                // Nothing to generate (budget 0 or prompt at max_seq):
                // retire without touching the engine.
                crate::obs::counter_add("serve.requests_retired", 1);
                self.finished.push(FinishedRequest {
                    id: req.id,
                    prompt_len,
                    generated: Vec::new(),
                    kv_bytes: 0,
                    status: FinishStatus::Complete,
                });
                continue;
            }
            let mut caches = self.engine.new_caches(prompt_len + max_new);
            let t0 = Instant::now();
            let logits = self.engine.prefill(&req.prompt, &mut caches);
            self.prefill_secs += t0.elapsed().as_secs_f64();
            let mut seq = ActiveSeq {
                id: req.id,
                prompt_len,
                tokens: req.prompt,
                generated: Vec::new(),
                max_new,
                temperature: req.temperature,
                rng: Rng::new(req.seed),
                caches,
                submitted,
            };
            let last = logits.rows() - 1;
            Self::sample_and_account(
                &mut seq,
                logits.row(last),
                &mut self.tokens_generated,
                &mut self.sample,
            );
            self.active.push(seq);
        }
        let kv = self.kv_bytes();
        self.peak_kv_bytes = self.peak_kv_bytes.max(kv);
        if crate::obs::enabled() {
            crate::obs::gauge_set("serve.kv_bytes", kv as f64);
        }
    }

    /// Retire sequences that hit their budget. The retired sequence's
    /// caches drop here; it never re-enters a batch.
    fn retire(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated.len() >= self.active[i].max_new {
                let seq = self.active.remove(i);
                self.finish_with(seq, FinishStatus::Complete);
            } else {
                i += 1;
            }
        }
    }

    /// Retire live sequences whose wall-clock deadline has passed. The
    /// `>=` comparison makes a `Duration::ZERO` deadline expire every
    /// admitted request deterministically at its first step.
    fn expire(&mut self) {
        let Some(dl) = self.deadline else { return };
        let now = Instant::now();
        let mut i = 0;
        while i < self.active.len() {
            if now.duration_since(self.active[i].submitted) >= dl {
                let seq = self.active.remove(i);
                crate::obs::counter_add("serve.requests_expired", 1);
                self.finish_with(seq, FinishStatus::DeadlineExceeded);
            } else {
                i += 1;
            }
        }
    }

    /// One scheduler tick: admit into free slots, retire filled budgets,
    /// then advance every live sequence one token (one batched engine
    /// call when ≥ 2 are live). Returns `false` once no pending or live
    /// work remains.
    pub fn step(&mut self) -> bool {
        let _sp = crate::obs::span("serve");
        self.retire();
        self.admit();
        self.retire();
        self.expire();
        if let Some(kind) = crate::robust::fault_point("serve.step") {
            // Injected serve failure: retire one live sequence with an
            // error status instead of taking down the whole batch.
            if !self.active.is_empty() {
                let seq = self.active.remove(0);
                self.finish_with(seq, FinishStatus::Error(format!("injected fault ({kind:?})")));
            }
        }
        if self.active.is_empty() {
            // Expiry/faults can empty the batch while requests still
            // queue behind a full slot table — keep ticking for those.
            return !self.pending.is_empty();
        }
        let t0 = Instant::now();
        if self.active.len() >= 2 {
            // Defensive: a sequence with a torn token stream cannot be
            // embedded — retire it with an error instead of panicking,
            // and re-enter with a cleaned batch next step.
            let bad: Vec<usize> = (0..self.active.len())
                .filter(|&i| self.active[i].tokens.last().is_none())
                .collect();
            if !bad.is_empty() {
                for i in bad.into_iter().rev() {
                    let seq = self.active.remove(i);
                    self.finish_with(seq, FinishStatus::Error("empty token stream".into()));
                }
                return true;
            }
            let inputs: Vec<(u16, usize)> = self
                .active
                .iter()
                .map(|s| (*s.tokens.last().expect("batch cleaned above"), s.tokens.len() - 1))
                .collect();
            let mut cs: Vec<&mut [KvCache]> =
                self.active.iter_mut().map(|s| s.caches.as_mut_slice()).collect();
            let mut logits = self.engine.decode_step_batch(&inputs, &mut cs);
            if crate::robust::fault_point("serve.logits").is_some() {
                // Poison one row so the genuine detection path below
                // exercises end to end.
                logits.row_mut(0)[0] = f32::NAN;
            }
            // Poisoned rows (non-finite logits) retire their sequence
            // with an error; healthy rows sample as usual.
            let poisoned: Vec<usize> = (0..self.active.len())
                .filter(|&r| logits.row(r).iter().any(|v| !v.is_finite()))
                .collect();
            for (r, seq) in self.active.iter_mut().enumerate() {
                if poisoned.contains(&r) {
                    continue;
                }
                Self::sample_and_account(
                    seq,
                    logits.row(r),
                    &mut self.tokens_generated,
                    &mut self.sample,
                );
            }
            for r in poisoned.into_iter().rev() {
                let seq = self.active.remove(r);
                self.finish_with(seq, FinishStatus::Error("non-finite logits row".into()));
            }
        } else {
            let Some(tok) = self.active[0].tokens.last().copied() else {
                let seq = self.active.remove(0);
                self.finish_with(seq, FinishStatus::Error("empty token stream".into()));
                return true;
            };
            let seq = &mut self.active[0];
            let pos = seq.tokens.len() - 1;
            let logits = self.engine.decode_step(tok, pos, &mut seq.caches, &mut self.scratch);
            // `decode_step` hands back a borrow of the scratch arena, so
            // a fired fault counts as poison directly rather than
            // mutating the row in place.
            let injected = crate::robust::fault_point("serve.logits").is_some();
            if injected || logits.iter().any(|v| !v.is_finite()) {
                let seq = self.active.remove(0);
                self.finish_with(seq, FinishStatus::Error("non-finite logits row".into()));
            } else {
                let t =
                    sample_token_scratch(logits, seq.temperature, &mut seq.rng, &mut self.sample);
                seq.generated.push(t);
                seq.tokens.push(t);
                self.tokens_generated += 1;
                crate::obs::counter_add("serve.tokens_generated", 1);
            }
        }
        self.decode_secs += t0.elapsed().as_secs_f64();
        true
    }

    /// Drive the scheduler until every submitted request has retired,
    /// then record the serving rate. Returns the finished requests in
    /// retirement order.
    pub fn run(&mut self) -> &[FinishedRequest] {
        while self.step() {}
        self.retire();
        if crate::obs::enabled() {
            let secs = self.prefill_secs + self.decode_secs;
            if secs > 0.0 {
                crate::obs::gauge_set("serve.tokens_per_sec", self.tokens_generated as f64 / secs);
            }
        }
        &self.finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LanguageModel, Model};
    use crate::quant::{rtn, QuantConfig};

    fn tiny_packed() -> QuantizedModel {
        let cfg = ModelConfig {
            name: "serve-test".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 24,
        };
        let mut rng = Rng::new(0x5E21);
        let m = Model::random(cfg, &mut rng);
        let mut qm = QuantizedModel::from_model(&m);
        let qc = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        for id in qm.linear_ids() {
            let q = rtn::quantize(m.linear(id), &qc);
            qm.set_layer(id, PackedLinear::from_quantized(&q, true));
        }
        qm
    }

    #[test]
    fn prefill_then_decode_matches_teacher_forced_forward() {
        let qm = tiny_packed();
        let engine = ServeEngine::new(&qm);
        let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
        let n_new = 6;
        // Serve path: prefill + greedy decode.
        let mut caches = engine.new_caches(prompt.len() + n_new);
        let mut scratch = DecodeScratch::new(&qm.cfg);
        let prefill_logits = engine.prefill(&prompt, &mut caches);
        let mut tokens = prompt.clone();
        let mut served_logits: Vec<Vec<f32>> = Vec::new();
        let mut next = crate::util::argmax(prefill_logits.row(prefill_logits.rows() - 1)) as u16;
        for _ in 0..n_new {
            tokens.push(next);
            let row =
                engine.decode_step(next, tokens.len() - 1, &mut caches, &mut scratch).to_vec();
            next = crate::util::argmax(&row) as u16;
            served_logits.push(row);
        }
        // Teacher-forced reference over the final token stream.
        let full = qm.forward(&tokens);
        for (i, row) in served_logits.iter().enumerate() {
            let pos = prompt.len() + i;
            assert_eq!(&row[..], full.row(pos), "decode position {pos}");
        }
        for pos in 0..prompt.len() {
            assert_eq!(prefill_logits.row(pos), full.row(pos), "prefill position {pos}");
        }
    }

    #[test]
    fn scheduler_single_matches_greedy_continue() {
        let qm = tiny_packed();
        let prompt: Vec<u16> = vec![7, 2, 9];
        let n = 5;
        let want = qm.greedy_continue(&prompt, n);
        let mut sched = Scheduler::new(&qm, 1);
        sched.submit(Request { id: 1, prompt, max_new: n, temperature: 0.0, seed: 0 }).unwrap();
        let fins = sched.run();
        assert_eq!(fins.len(), 1);
        assert_eq!(fins[0].generated, want);
        assert!(fins[0].kv_bytes > 0);
        assert_eq!(fins[0].status, FinishStatus::Complete);
        assert_eq!(sched.tokens_generated(), n as u64);
    }

    #[test]
    fn temperature_sampling_is_stream_deterministic() {
        let qm = tiny_packed();
        let run = |max_concurrent| {
            let mut sched = Scheduler::new(&qm, max_concurrent);
            for id in 0..3u64 {
                sched
                    .submit(Request {
                        id,
                        prompt: vec![1 + id as u16, 2, 3],
                        max_new: 4,
                        temperature: 0.8,
                        seed: 100 + id,
                    })
                    .unwrap();
            }
            let mut fins = sched.run().to_vec();
            fins.sort_by_key(|f| f.id);
            fins.iter().map(|f| f.generated.clone()).collect::<Vec<_>>()
        };
        // Same seeds → same tokens, regardless of batching width.
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn admission_rejects_bad_prompts_cleanly() {
        let qm = tiny_packed();
        let mut sched = Scheduler::new(&qm, 1);
        let req =
            |prompt: Vec<u16>| Request { id: 0, prompt, max_new: 2, temperature: 0.0, seed: 1 };
        assert_eq!(sched.submit(req(vec![])), Err(RejectReason::EmptyPrompt));
        let max_seq = qm.cfg.max_seq;
        assert_eq!(
            sched.submit(req(vec![1u16; max_seq + 1])),
            Err(RejectReason::PromptTooLong { len: max_seq + 1, max_seq })
        );
        assert_eq!(sched.pending_len(), 0);
        assert!(sched.run().is_empty());
    }

    #[test]
    fn queue_backpressure_rejects_when_full() {
        let qm = tiny_packed();
        let mut sched = Scheduler::new(&qm, 1);
        sched.set_max_queue(2);
        let req = |id| Request { id, prompt: vec![1, 2], max_new: 2, temperature: 0.0, seed: id };
        sched.submit(req(0)).unwrap();
        sched.submit(req(1)).unwrap();
        assert_eq!(sched.submit(req(2)), Err(RejectReason::QueueFull { depth: 2, max_queue: 2 }));
        let fins = sched.run();
        assert_eq!(fins.len(), 2);
        assert!(fins.iter().all(|f| f.status == FinishStatus::Complete));
    }

    #[test]
    fn zero_deadline_expires_requests_without_panic() {
        let qm = tiny_packed();
        let mut sched = Scheduler::new(&qm, 2);
        sched.set_deadline(Duration::ZERO);
        for id in 0..2u64 {
            sched
                .submit(Request { id, prompt: vec![3, 4], max_new: 5, temperature: 0.0, seed: id })
                .unwrap();
        }
        let fins = sched.run().to_vec();
        assert_eq!(fins.len(), 2);
        for f in &fins {
            assert_eq!(f.status, FinishStatus::DeadlineExceeded);
            // Admission samples one token from the prefill before the
            // zero deadline expires the request at its first step.
            assert!(f.generated.len() <= 1, "expired request kept generating");
        }
    }

    #[test]
    fn scratch_sampling_matches_allocating_path_and_reuses_buffers() {
        let mut rng = Rng::new(77);
        let mut scratch = SampleScratch::new(0); // deliberately cold
        let mut buf_ptrs = None;
        for temperature in [0.0f32, 0.3, 0.8, 1.0, 2.5] {
            for trial in 0..20u64 {
                let logits: Vec<f32> = (0..32)
                    .map(|i| ((i as f32 * 0.37 + trial as f32).sin()) * 4.0)
                    .collect();
                // Identical RNG streams for the two paths.
                let mut ra = Rng::new(1000 + trial).fork(temperature.to_bits() as u64);
                let mut rb = Rng::new(1000 + trial).fork(temperature.to_bits() as u64);
                let a = sample_token(&logits, temperature, &mut ra);
                let b = sample_token_scratch(&logits, temperature, &mut rb, &mut scratch);
                assert_eq!(a, b, "temp={temperature} trial={trial}");
                // Allocation-free proxy: once warm, the scratch buffers
                // keep their allocations (stable pointers, no regrowth).
                if temperature > 0.0 {
                    let ptrs = (scratch.ls.as_ptr(), scratch.probs.as_ptr());
                    match buf_ptrs {
                        None => buf_ptrs = Some(ptrs),
                        Some(p) => assert_eq!(p, ptrs, "scratch reallocated"),
                    }
                }
            }
        }
    }
}
