//! Dense row-major `f32` matrix type — the storage substrate for model
//! weights, activations, Hessians and triangular factors.
//!
//! Deliberately minimal: a contiguous `Vec<f32>` with shape metadata and
//! the handful of structural operations the rest of the crate needs.
//! Numerics (GEMM, Cholesky, triangular solves) live in [`crate::linalg`].

use crate::rng::Rng;

/// Row-major dense matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    /// Zero-filled `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity (or rectangular eye).
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch {rows}x{cols} vs {}", data.len());
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// I.i.d. `N(0, std²)` entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32(0.0, std)).collect();
        Matrix { rows, cols, data }
    }

    /// I.i.d. uniform `[lo, hi)` entries.
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.range(lo as f64, hi as f64) as f32).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j` (strided gather).
    pub fn col(&self, j: usize) -> Vec<f32> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Overwrite column `j`.
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.data[i * self.cols + j] = v[i];
        }
    }

    /// Raw storage.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Raw mutable storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for j in 0..self.cols {
                t.data[j * self.rows + i] = row[j];
            }
        }
        t
    }

    /// Copy a block `[r0..r0+h) × [c0..c0+w)`.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for i in 0..h {
            out.row_mut(i).copy_from_slice(&self.row(r0 + i)[c0..c0 + w]);
        }
        out
    }

    /// Paste `src` at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Matrix) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols, "block out of range");
        for i in 0..src.rows {
            let cols = self.cols;
            self.data[(r0 + i) * cols + c0..(r0 + i) * cols + c0 + src.cols]
                .copy_from_slice(src.row(i));
        }
    }

    /// Columns `[c0, c0+w)` as a new matrix (used by the column tiler).
    pub fn col_range(&self, c0: usize, w: usize) -> Matrix {
        self.block(0, c0, self.rows, w)
    }

    /// Element-wise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Element-wise out-of-place map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scale by a constant, out of place.
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|v| alpha * v)
    }

    /// Hadamard (element-wise) product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Mean of entries.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64
    }

    /// True iff every entry is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// `||a - b||_F / max(||b||_F, eps)` — relative error helper used all
    /// over the tests and benches.
    pub fn rel_err(&self, reference: &Matrix) -> f64 {
        let denom = reference.frob().max(1e-12);
        self.sub(reference).frob() / denom
    }

    /// Pad to `(new_rows, new_cols)` with zeros (tiler support).
    pub fn pad_to(&self, new_rows: usize, new_cols: usize) -> Matrix {
        assert!(new_rows >= self.rows && new_cols >= self.cols);
        let mut out = Matrix::zeros(new_rows, new_cols);
        out.set_block(0, 0, self);
        out
    }

    /// Concatenate vertically: `[self; other]`.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Concatenate many matrices vertically in a single allocation (the
    /// capture paths stack one part per calibration sequence; a pairwise
    /// fold would re-copy the accumulator quadratically).
    pub fn vstack_all(parts: &[Matrix]) -> Matrix {
        assert!(!parts.is_empty(), "vstack_all of empty set");
        let cols = parts[0].cols;
        let total: usize = parts
            .iter()
            .map(|m| {
                assert_eq!(m.cols, cols, "vstack_all column mismatch");
                m.rows
            })
            .sum();
        let mut data = Vec::with_capacity(total * cols);
        for m in parts {
            data.extend_from_slice(&m.data);
        }
        Matrix { rows: total, cols, data }
    }

    /// Inverse of [`Matrix::vstack_all`]: split into consecutive row
    /// groups of the given sizes (`counts` must sum to `rows`). The
    /// batched capture paths use this to hand a tall GEMM result back to
    /// per-sequence consumers (the attention core, the eval harnesses).
    pub fn split_rows(&self, counts: &[usize]) -> Vec<Matrix> {
        let total: usize = counts.iter().sum();
        assert_eq!(total, self.rows, "split_rows counts must cover all rows");
        let mut out = Vec::with_capacity(counts.len());
        let mut r0 = 0usize;
        for &h in counts {
            out.push(self.block(r0, 0, h, self.cols));
            r0 += h;
        }
        out
    }

    /// Gather rows by index (activation subsampling, act-order permutes).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Permute columns: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Permute rows: `out[i, :] = self[perm[i], :]`.
    pub fn permute_rows(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.rows);
        self.gather_rows(perm)
    }
}

/// An owning vertical stack of per-sequence row groups: one contiguous
/// `Σ rows_i × cols` matrix plus the row offsets of each group.
///
/// This is the hidden-state cache layout of the **batched capture path**:
/// the pipeline coordinator keeps one `RowBatch` per cache (FP and
/// runtime) instead of a `Vec<Matrix>`, so every non-attention linear
/// stage runs as a single tall GEMM over [`RowBatch::data`] while the
/// causal-attention core still sees per-sequence row ranges through
/// [`RowBatch::offsets`]. It is also the handoff unit the pipeline-
/// sharding roadmap item will ship between block workers.
#[derive(Debug, Clone, PartialEq)]
pub struct RowBatch {
    data: Matrix,
    /// `n_seqs + 1` cumulative row offsets; sequence `i` owns rows
    /// `offsets[i]..offsets[i+1]` of `data`.
    offsets: Vec<usize>,
}

impl RowBatch {
    /// Stack per-sequence matrices (in order) into one batch.
    pub fn stack(parts: &[Matrix]) -> RowBatch {
        let mut offsets = Vec::with_capacity(parts.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for p in parts {
            total += p.rows();
            offsets.push(total);
        }
        RowBatch { data: Matrix::vstack_all(parts), offsets }
    }

    /// The stacked `Σ rows_i × cols` matrix.
    #[inline]
    pub fn data(&self) -> &Matrix {
        &self.data
    }

    /// Cumulative row offsets (`n_seqs + 1` entries, starting at 0).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// Number of sequences in the batch.
    #[inline]
    pub fn n_seqs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Row count of sequence `i`.
    #[inline]
    pub fn seq_rows(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Replace the stacked data with a same-height matrix (a stage
    /// advance: row offsets are invariant across every block stage).
    pub fn set_data(&mut self, data: Matrix) {
        assert_eq!(data.rows(), self.data.rows(), "RowBatch stage must preserve row count");
        self.data = data;
    }
}

/// Inverse of a permutation vector.
pub fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32)
    }

    #[test]
    fn construction_and_access() {
        let m = sample();
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(2), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(m.col(1), vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.shape(), (4, 3));
        assert_eq!(t.get(2, 1), m.get(1, 2));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn block_and_set_block() {
        let m = sample();
        let b = m.block(1, 1, 2, 2);
        assert_eq!(b.as_slice(), &[5.0, 6.0, 9.0, 10.0]);
        let mut z = Matrix::zeros(3, 4);
        z.set_block(1, 1, &b);
        assert_eq!(z.get(2, 2), 10.0);
        assert_eq!(z.get(0, 0), 0.0);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 3, vec![3.0, 4.0, 0.0]);
        assert!((m.frob() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn axpy_sub_add() {
        let a = sample();
        let mut b = a.clone();
        b.axpy(2.0, &a);
        assert_eq!(b.get(1, 1), 15.0);
        assert_eq!(b.sub(&a).get(1, 1), 10.0);
        assert_eq!(a.add(&a).get(2, 3), 22.0);
    }

    #[test]
    fn permutations_invert() {
        let m = sample();
        let perm = vec![2, 0, 3, 1];
        let inv = invert_perm(&perm);
        let back = m.permute_cols(&perm).permute_cols(&inv);
        assert_eq!(back, m);
        let rperm = vec![1, 2, 0];
        let rback = m.permute_rows(&rperm).permute_rows(&invert_perm(&rperm));
        assert_eq!(rback, m);
    }

    #[test]
    fn pad_and_col_range() {
        let m = sample();
        let p = m.pad_to(5, 6);
        assert_eq!(p.shape(), (5, 6));
        assert_eq!(p.get(1, 2), 6.0);
        assert_eq!(p.get(4, 5), 0.0);
        let c = m.col_range(1, 2);
        assert_eq!(c.shape(), (3, 2));
        assert_eq!(c.get(0, 0), 1.0);
    }

    #[test]
    fn vstack_shapes() {
        let m = sample();
        let v = m.vstack(&m);
        assert_eq!(v.shape(), (6, 4));
        assert_eq!(v.get(4, 1), m.get(1, 1));
    }

    #[test]
    fn vstack_all_matches_pairwise_fold() {
        let m = sample();
        let parts = vec![m.clone(), m.clone(), m.clone()];
        let folded = m.vstack(&m).vstack(&m);
        assert_eq!(Matrix::vstack_all(&parts), folded);
        assert_eq!(Matrix::vstack_all(&[m.clone()]), m);
    }

    #[test]
    fn split_rows_inverts_vstack_all() {
        let parts = vec![
            Matrix::from_fn(2, 3, |i, j| (i + j) as f32),
            Matrix::from_fn(1, 3, |_, j| j as f32 * 7.0),
            Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 - 5.0),
        ];
        let stacked = Matrix::vstack_all(&parts);
        let back = stacked.split_rows(&[2, 1, 4]);
        assert_eq!(back, parts);
    }

    #[test]
    #[should_panic]
    fn split_rows_bad_counts_panics() {
        let m = sample();
        let _ = m.split_rows(&[1, 1]);
    }

    #[test]
    fn row_batch_roundtrip_and_offsets() {
        let parts = vec![
            Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f32),
            Matrix::from_fn(1, 2, |_, j| 10.0 + j as f32),
            Matrix::from_fn(2, 2, |i, j| -((i + j) as f32)),
        ];
        let batch = RowBatch::stack(&parts);
        assert_eq!(batch.n_seqs(), 3);
        assert_eq!(batch.offsets(), &[0, 3, 4, 6]);
        assert_eq!(batch.seq_rows(1), 1);
        assert_eq!(*batch.data(), Matrix::vstack_all(&parts));
        assert_eq!(batch.data().split_rows(&[3, 1, 2]), parts);
    }

    #[test]
    #[should_panic]
    fn row_batch_set_data_height_mismatch_panics() {
        let mut batch = RowBatch::stack(&[sample()]);
        batch.set_data(Matrix::zeros(1, 4));
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let m = sample();
        assert!(m.rel_err(&m) < 1e-12);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 5]);
    }
}
