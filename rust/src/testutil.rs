//! Property-testing support (no `proptest` offline): a tiny random-case
//! runner that shrinks nothing but reports the failing seed, plus shared
//! generators for solver-shaped inputs.

use crate::linalg::{cholesky_upper_jittered, syrk_upper};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Run `body(case_rng, case_index)` for `cases` independent cases derived
/// from `seed`. Panics with the failing case seed in the message so a
/// failure can be replayed as a unit test.
pub fn check_cases(seed: u64, cases: usize, body: impl Fn(&mut Rng, usize)) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed={seed}): {msg}");
        }
    }
}

/// Random dimension in `[lo, hi]`.
pub fn gen_dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// A random solver instance: upper Cholesky factor of a random Gram
/// matrix (condition controlled by the `rows` oversampling), positive
/// scales, and centers spread across the box.
pub struct SolverCase {
    pub r: Matrix,
    pub s: Vec<f32>,
    pub qbar: Vec<f32>,
    pub qmax: f32,
}

/// Generate a random per-column BILS case of dimension `m`.
pub fn gen_solver_case(rng: &mut Rng, m: usize, wbit: u8) -> SolverCase {
    // Oversampling factor near 1 => ill-conditioned Gram (hard case).
    let oversample = 1 + rng.below(3) as usize;
    let a = Matrix::randn(m * oversample + 2, m, 1.0, rng);
    let g = syrk_upper(&a, 0.01);
    let (r, _) = cholesky_upper_jittered(&g, 1e-6).expect("gen gram must factor");
    let qmax = ((1u32 << wbit) - 1) as f32;
    let s: Vec<f32> = (0..m).map(|_| 0.01 + 0.3 * rng.uniform_f32()).collect();
    let qbar: Vec<f32> = (0..m).map(|_| (qmax + 2.0) * rng.uniform_f32() - 1.0).collect();
    SolverCase { r, s, qbar, qmax }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_cases_runs_all() {
        let mut seen = std::sync::atomic::AtomicUsize::new(0);
        check_cases(1, 17, |_, _| {
            seen.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(*seen.get_mut(), 17);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn check_cases_reports_failure() {
        check_cases(2, 5, |_, case| {
            assert!(case < 3, "boom");
        });
    }

    #[test]
    fn solver_case_well_formed() {
        check_cases(3, 10, |rng, _| {
            let m = gen_dim(rng, 4, 40);
            let case = gen_solver_case(rng, m, 4);
            assert_eq!(case.r.shape(), (m, m));
            for i in 0..m {
                assert!(case.r.get(i, i) > 0.0);
                assert!(case.s[i] > 0.0);
            }
        });
    }
}
