//! Small shared utilities: wall-clock timing, byte (de)serialization of
//! f32 tensors, and human-readable formatting helpers.

use std::time::Instant;

/// Measure wall-clock seconds of a closure, returning `(result, secs)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Format a duration in seconds adaptively (µs / ms / s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Format a count with SI suffix (1.2k, 3.4M, …).
pub fn fmt_count(n: f64) -> String {
    if n >= 1e9 {
        format!("{:.2}G", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}k", n / 1e3)
    } else {
        format!("{:.0}", n)
    }
}

/// Little-endian encode a `f32` slice.
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Little-endian decode a `f32` slice; errors on ragged length.
pub fn bytes_to_f32s(bytes: &[u8]) -> anyhow::Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        anyhow::bail!("byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Natural-log-domain softmax over a small slice — shared by eval scoring.
pub fn log_softmax(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(xs.len());
    log_softmax_scaled_into(xs, 1.0, &mut out);
    out
}

/// `log_softmax(xs / temperature)` into a caller-owned buffer — the
/// temperature scale folded in so the decode hot path neither allocates
/// nor materializes a scaled copy. Bit-identical to scaling first and
/// calling [`log_softmax`] (each element is divided in f32 exactly once,
/// then the identical f64 log-sum-exp runs over the scaled values;
/// `temperature = 1.0` divides by 1.0, which is IEEE-exact).
pub fn log_softmax_scaled_into(xs: &[f32], temperature: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| x / temperature));
    let max = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = out.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln();
    for x in out.iter_mut() {
        *x = ((*x - max) as f64 - lse) as f32;
    }
}

/// Argmax of a slice (first maximal index); panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of an f64 slice (0.0 when empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (linear interpolation) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        let b = f32s_to_bytes(&xs);
        assert_eq!(bytes_to_f32s(&b).unwrap(), xs);
        assert!(bytes_to_f32s(&b[..5]).is_err());
    }

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = ls.iter().map(|&x| (x as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn scaled_into_matches_scale_then_log_softmax_bitwise() {
        let xs: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.73).sin() * 5.0).collect();
        let mut out = Vec::new();
        for temperature in [0.25f32, 0.8, 1.0, 3.0] {
            let scaled: Vec<f32> = xs.iter().map(|&x| x / temperature).collect();
            let reference = log_softmax(&scaled);
            log_softmax_scaled_into(&xs, temperature, &mut out);
            assert_eq!(out, reference, "temperature {temperature}");
        }
        // The allocating entry point is the scaled variant at T=1.
        assert_eq!(log_softmax(&xs), {
            let mut o = Vec::new();
            log_softmax_scaled_into(&xs, 1.0, &mut o);
            o
        });
    }

    #[test]
    fn percentile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(argmax(&[0.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_count(1500.0), "1.5k");
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
