//! Parity of the batch-fused capture path with per-sequence stepping.
//!
//! PR 3 rewrote the capture loops to vstack all calibration sequences
//! into one `RowBatch` and run every non-attention linear stage as a
//! single tall GEMM. These tests pin the contract that made that safe:
//! the batched stages are **bit-identical** to stepping each sequence
//! independently — across ragged sequence lengths, act-order (decode-
//! order permuted) layers, dense effective-fallback layers, and both the
//! packed and dense execution legs — and the end-to-end pipeline still
//! matches the legacy prefix re-forward capture.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{CaptureMode, Pipeline};
use ojbkq::data::SyntheticGrammar;
use ojbkq::infer::{PackedLinear, QuantizedModel};
use ojbkq::model::{LanguageModel, LinearId, LinearKind, Model, TapPoint, TapSet};
use ojbkq::quant::{gptq, rtn, Method, QuantConfig};
use ojbkq::rng::Rng;
use ojbkq::tensor::{Matrix, RowBatch};

fn setup() -> (Model, Vec<Vec<u16>>) {
    let cfg = ModelConfig {
        name: "batch".into(),
        vocab_size: 48,
        d_model: 24,
        n_layers: 3,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
    };
    let mut rng = Rng::new(0xBA7C);
    let model = Model::random(cfg, &mut rng);
    // Deliberately ragged sequence lengths (the batched path must not
    // assume equal-length calibration windows).
    let corpus = SyntheticGrammar::new(48, 0.2, 7).corpus(8_000, &mut rng);
    let calib: Vec<Vec<u16>> = [20usize, 7, 1, 13]
        .iter()
        .map(|&len| corpus.calibration(1, len, &mut rng).remove(0))
        .collect();
    (model, calib)
}

/// A runtime engine exercising every layer flavor the kernel supports:
/// packed RTN at several widths, a packed act-order (perm) layer, a dense
/// effective fallback, and untouched FP passthrough layers.
fn mixed_engine(model: &Model, packed_exec: bool) -> QuantizedModel {
    let mut qm = QuantizedModel::from_model(model);
    let mut rng = Rng::new(0x317);
    for (i, &kind) in LinearKind::all().iter().enumerate() {
        let id = LinearId { block: 0, kind };
        let w = model.linear(id);
        let lin = match i % 3 {
            0 => {
                let cfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
                PackedLinear::from_quantized(&rtn::quantize(w, &cfg), packed_exec)
            }
            1 => {
                let cfg = QuantConfig {
                    wbit: 3,
                    group_size: 12,
                    act_order: true,
                    ..Default::default()
                };
                let x = Matrix::randn(16, w.rows(), 1.0, &mut rng);
                PackedLinear::from_quantized(&gptq::quantize(w, &x, &cfg).unwrap(), packed_exec)
            }
            _ => {
                // AWQ/QuIP-style: a transform folded into a dense
                // effective weight (no perm) — must take the dense leg.
                let mut q = rtn::quantize(
                    w,
                    &QuantConfig { wbit: 2, group_size: 8, ..Default::default() },
                );
                q.effective = Some(w.map(|v| (v * 16.0).round() / 16.0));
                PackedLinear::from_quantized(&q, packed_exec)
            }
        };
        qm.set_layer(id, lin);
    }
    qm
}

#[test]
fn batched_stages_match_per_sequence_stepping_both_legs() {
    let (model, calib) = setup();
    for packed_exec in [true, false] {
        let qm = mixed_engine(&model, packed_exec);
        let parts: Vec<Matrix> = calib.iter().map(|s| qm.embed_sequence(s)).collect();
        let mut batch = RowBatch::stack(&parts);
        let mut per_seq: Vec<Matrix> = parts.clone();
        for bi in 0..model.blocks.len() {
            // Batched: one tall call per stage, mirroring the pipeline's
            // capture sites.
            let attn_in = qm.attn_in_batch(batch.data(), bi);
            let ctx = qm.attn_ctx_batch(&attn_in, batch.offsets(), bi);
            let x_mid = qm.post_attn_batch(batch.data(), &ctx, bi);
            let mlp_in = qm.mlp_in_batch(&x_mid, bi);
            let act = qm.mlp_act_batch(&mlp_in, bi);
            // Per-sequence reference for each captured stage output.
            let mut s_attn_in = Vec::new();
            let mut s_ctx = Vec::new();
            let mut s_mlp_in = Vec::new();
            let mut s_act = Vec::new();
            for h in &per_seq {
                let a = qm.attn_in(h, bi);
                let c = qm.attn_ctx(&a, bi);
                let m = qm.post_attn(h, &c, bi);
                let mi = qm.mlp_in(&m, bi);
                s_act.push(qm.mlp_act(&mi, bi));
                s_attn_in.push(a);
                s_ctx.push(c);
                s_mlp_in.push(mi);
            }
            let leg = if packed_exec { "packed" } else { "dense" };
            assert_eq!(attn_in, Matrix::vstack_all(&s_attn_in), "{leg} b{bi} AttnIn");
            assert_eq!(ctx, Matrix::vstack_all(&s_ctx), "{leg} b{bi} OIn");
            assert_eq!(mlp_in, Matrix::vstack_all(&s_mlp_in), "{leg} b{bi} MlpIn");
            assert_eq!(act, Matrix::vstack_all(&s_act), "{leg} b{bi} DownIn");
            // Advance both representations.
            batch.set_data(qm.post_mlp_batch(&x_mid, &act, bi));
            for h in per_seq.iter_mut() {
                qm.block_step(h, bi);
            }
            assert_eq!(*batch.data(), Matrix::vstack_all(&per_seq), "{leg} b{bi} hidden");
        }
    }
}

#[test]
fn fp_block_step_batch_matches_per_sequence_taps() {
    let (model, calib) = setup();
    let parts: Vec<Matrix> = calib.iter().map(|s| model.embed_sequence(s)).collect();
    let mut batch = RowBatch::stack(&parts);
    let mut per_seq = parts.clone();
    for bi in 0..model.blocks.len() {
        let mut batch_taps = TapSet::request(bi, &TapPoint::all());
        model.block_step_batch(&mut batch, bi, &mut batch_taps);
        let mut seq_taps = TapSet::request(bi, &TapPoint::all());
        for h in per_seq.iter_mut() {
            model.block_step(h, bi, &mut seq_taps);
        }
        for p in TapPoint::all() {
            let a = batch_taps.take(bi, p).unwrap();
            let b = seq_taps.take(bi, p).unwrap();
            assert_eq!(a, b, "block {bi} {p:?}");
        }
        assert_eq!(*batch.data(), Matrix::vstack_all(&per_seq), "block {bi} hidden");
    }
}

#[test]
fn forward_batch_matches_forward_ragged_mixed_layers() {
    let (model, calib) = setup();
    let refs: Vec<&[u16]> = calib.iter().map(|s| s.as_slice()).collect();
    for packed_exec in [true, false] {
        let qm = mixed_engine(&model, packed_exec);
        let batched = qm.forward_batch(&refs);
        for (s, got) in calib.iter().zip(&batched) {
            assert_eq!(*got, LanguageModel::forward(&qm, s), "len {}", s.len());
        }
    }
    // Dense FP model too (the fp-cache leg of the pipeline).
    let batched = model.forward_batch(&refs);
    for (s, got) in calib.iter().zip(&batched) {
        assert_eq!(*got, Model::forward(&model, s), "fp len {}", s.len());
    }
}

/// End-to-end: the batch-fused streaming pipeline must still produce the
/// same model as the legacy per-sequence prefix re-forward capture (dense
/// execution on both legs isolates the capture strategy, as in
/// `streaming_capture.rs`), on a ragged calibration set.
#[test]
fn batched_pipeline_matches_reforward_on_ragged_calib() {
    let (model, calib) = setup();
    let cfg = QuantConfig {
        wbit: 4,
        group_size: 8,
        k: 2,
        ntile: 16,
        mu: 0.3,
        lambda: 0.2,
        packed_exec: false,
        ..Default::default()
    };
    let (qm_batched, rep_batched) =
        Pipeline::new(&model, calib.clone(), Method::Ojbkq, cfg.clone(), None).run().unwrap();
    let (qm_legacy, rep_legacy) = Pipeline::new(&model, calib, Method::Ojbkq, cfg, None)
        .with_capture_mode(CaptureMode::Reforward)
        .run()
        .unwrap();
    let toks: Vec<u16> = vec![1, 7, 13, 2, 40];
    assert!(
        qm_batched.forward(&toks).rel_err(&qm_legacy.forward(&toks)) < 1e-9,
        "batch-fused and re-forward pipelines must produce equivalent models"
    );
    for (a, b) in rep_batched.layers.iter().zip(rep_legacy.layers.iter()) {
        assert_eq!(a.id, b.id);
        let denom = b.stats.rt_err.abs().max(1e-12);
        assert!(
            (a.stats.rt_err - b.stats.rt_err).abs() / denom < 1e-6,
            "{}: rt_err {} vs {}",
            a.id,
            a.stats.rt_err,
            b.stats.rt_err
        );
    }
    assert!(rep_batched.capture_block_steps < rep_legacy.capture_block_steps);
}

/// The packed-execution leg of the batch-fused pipeline stays
/// deterministic and finite on ragged calibration sets.
#[test]
fn batched_packed_pipeline_deterministic_on_ragged_calib() {
    let (model, calib) = setup();
    let cfg = QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, ..Default::default() };
    let (qa, ra) =
        Pipeline::new(&model, calib.clone(), Method::Ojbkq, cfg.clone(), None).run().unwrap();
    let (qb, rb) = Pipeline::new(&model, calib, Method::Ojbkq, cfg, None).run().unwrap();
    let toks: Vec<u16> = vec![2, 4, 6, 8, 10];
    assert!(qa.forward(&toks).rel_err(&qb.forward(&toks)) < 1e-12);
    assert!(qa.forward(&toks).all_finite());
    for (a, b) in ra.layers.iter().zip(rb.layers.iter()) {
        assert_eq!(a.stats.rt_err, b.stats.rt_err, "{}", a.id);
    }
}
