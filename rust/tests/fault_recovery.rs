//! Fault-injection sweep and crash-safe resume invariants (PR 10).
//!
//! The contract under test, site by site: every registered fault site ×
//! fault kind either **recovers** (the degradation ladder absorbs it and
//! records the event) or surfaces as a **structured error naming the
//! site** — never a panic, never a torn checkpoint file. And a
//! `--resume` after an interruption at *any* block boundary produces an
//! OJBQ1 checkpoint byte-identical to an uninterrupted run: the calib
//! sample and every solver RNG are keyed, not sequential, so replaying
//! the durable prefix perturbs nothing downstream.
//!
//! Fault arming is process-global, so every test here serializes on one
//! lock and disarms on entry and exit.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{quantize_model, quantize_model_checkpointed, Pipeline};
use ojbkq::data::{Corpus, SyntheticGrammar};
use ojbkq::infer::{save_quantized, PackedLinear, QuantizedModel};
use ojbkq::model::Model;
use ojbkq::quant::{rtn, Method, QuantConfig};
use ojbkq::rng::Rng;
use ojbkq::robust::{self, RunManifest};
use ojbkq::serve::{FinishStatus, Request, Scheduler};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Serializes every test in this binary: fault specs are process-global.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panic under an armed fault (deliberate in the interrupt sweep)
    // poisons the mutex; the guard itself is still valid.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> (Model, Corpus) {
    let cfg = ModelConfig {
        name: "fault-test".into(),
        vocab_size: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
    };
    let mut rng = Rng::new(0xFA17);
    (Model::random(cfg, &mut rng), SyntheticGrammar::new(32, 0.2, 3).corpus(6_000, &mut rng))
}

fn qcfg() -> QuantConfig {
    QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, packed_exec: true, ..Default::default() }
}

/// Serialize a packed model to OJBQ1 at `path` and return the bytes —
/// the byte-identity currency of every resume assertion below.
fn ojbq1_bytes(qm: &QuantizedModel, path: &Path) -> Vec<u8> {
    save_quantized(qm, path).expect("writing OJBQ1");
    std::fs::read(path).expect("reading OJBQ1 back")
}

fn fresh_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("creating temp dir");
    d
}

/// The tentpole acceptance gate: checkpointing is inert (a fresh
/// checkpointed run is byte-identical to the plain pipeline), and after
/// an injected crash at **every** block boundary — both a torn segment
/// write and a mid-capture panic — `--resume` completes the run to the
/// same bytes.
#[test]
fn checkpointed_run_is_inert_and_resumable_at_every_block() {
    let _g = lock();
    robust::reset_faults();
    let (model, corpus) = setup();
    let cfg = qcfg();
    let tmp = fresh_dir("ojbkq_fault_recovery_resume");

    // Golden: the plain, non-checkpointed pipeline.
    let (gold_qm, _) = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None).unwrap();
    let gold = ojbq1_bytes(&gold_qm, &tmp.join("gold.ojbq1"));

    // Checkpointing is inert: fresh checkpointed run, byte-identical,
    // manifest complete.
    let parts = tmp.join("fresh.parts");
    let (ck_qm, _) = quantize_model_checkpointed(
        &model, &corpus, Method::Ojbkq, &cfg, 3, 16, None, &parts, false,
    )
    .unwrap();
    assert_eq!(ojbq1_bytes(&ck_qm, &tmp.join("fresh.ojbq1")), gold, "checkpointing moved bytes");
    let man = RunManifest::load(&parts).unwrap();
    assert_eq!(man.completed, man.n_blocks, "fresh run must record every block");
    let n_blocks = man.n_blocks;
    assert!(n_blocks >= 2, "sweep needs at least two blocks");

    // Interrupt at every block, two ways: a torn segment write (clean
    // Err) and an injected panic at the cache-advance boundary.
    for block in 0..n_blocks {
        for (spec, label) in [
            (format!("io.segment_write:partial_write:{}", block + 1), "torn write"),
            (format!("coordinator.advance:panic:{}", block + 1), "panic"),
        ] {
            let parts = tmp.join(format!("kill_b{block}_{}.parts", label.replace(' ', "_")));
            robust::set_faults(Some(&spec)).unwrap();
            let killed = catch_unwind(AssertUnwindSafe(|| {
                quantize_model_checkpointed(
                    &model, &corpus, Method::Ojbkq, &cfg, 3, 16, None, &parts, false,
                )
            }));
            let events = robust::fault_event_count();
            robust::reset_faults();
            assert!(events >= 1, "block {block} {label}: fault never fired");
            match killed {
                Ok(run) => assert!(run.is_err(), "block {block} {label}: run must not complete"),
                Err(_) => assert_eq!(label, "panic", "block {block}: unexpected panic"),
            }
            // The crash left a valid resumable prefix: manifest intact,
            // exactly `block` durable segments, no torn destination file.
            let man = RunManifest::load(&parts).unwrap();
            assert_eq!(man.completed, block, "block {block} {label}: wrong durable prefix");
            assert!(
                !parts.join(format!("block_{block}.seg")).exists(),
                "block {block} {label}: interrupted segment must not be committed"
            );
            // Resume completes to byte-identical output.
            let (r_qm, _) = quantize_model_checkpointed(
                &model, &corpus, Method::Ojbkq, &cfg, 3, 16, None, &parts, true,
            )
            .unwrap_or_else(|e| panic!("block {block} {label}: resume failed: {e:#}"));
            let out = tmp.join(format!("resumed_b{block}_{}.ojbq1", label.replace(' ', "_")));
            assert_eq!(ojbq1_bytes(&r_qm, &out), gold, "block {block} {label}: resume diverged");
            let man = RunManifest::load(&parts).unwrap();
            assert_eq!(man.completed, n_blocks, "block {block} {label}: resume left gaps");
        }
    }
}

/// A stale parts directory can never be silently resumed under a
/// different configuration: the manifest identity check refuses it.
#[test]
fn resume_rejects_mismatched_config() {
    let _g = lock();
    robust::reset_faults();
    let (model, corpus) = setup();
    let cfg = qcfg();
    let tmp = fresh_dir("ojbkq_fault_recovery_mismatch");
    let parts = tmp.join("run.parts");
    quantize_model_checkpointed(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None, &parts, false)
        .unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.wbit = 3;
    let err = quantize_model_checkpointed(
        &model, &corpus, Method::Ojbkq, &cfg2, 3, 16, None, &parts, true,
    )
    .expect_err("resume under a changed config must be refused");
    let msg = format!("{err:#}");
    assert!(msg.contains("resume mismatch"), "unexpected refusal message: {msg}");
}

/// Site × kind sweep over the pipeline: every injected fault either
/// recovers through the degradation ladder (factor → per-layer RTN
/// fallback, recorded on the layer stats) or returns a structured error
/// naming its site. No panics, no torn files.
#[test]
fn fault_sweep_no_panics_no_torn_files() {
    let _g = lock();
    robust::reset_faults();
    let (model, corpus) = setup();
    let cfg = qcfg();
    let run = |spec: &str| {
        robust::set_faults(Some(spec)).unwrap();
        let r = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 2, 16, None);
        let events = robust::fault_event_count();
        robust::reset_faults();
        (r, events)
    };

    // Capture/solve/advance boundaries: structured errors naming the
    // site (the solve `nan` kind poisons a weight and must be caught by
    // the genuine solve→pack finiteness guard).
    for spec in [
        "coordinator.capture:err:1",
        "coordinator.capture:nan:1",
        "coordinator.solve:err:1",
        "coordinator.solve:nan:1",
        "coordinator.advance:err:1",
        "coordinator.advance:nan:1",
    ] {
        let site = spec.split(':').next().unwrap();
        let (r, events) = run(spec);
        assert!(events >= 1, "{spec}: fault never fired");
        let msg = format!("{:#}", r.expect_err(spec));
        assert!(msg.contains(site), "{spec}: error does not name the site: {msg}");
    }

    // Factor failures are absorbed: the group degrades per-layer to RTN
    // and the event is recorded on every affected layer's stats.
    for spec in ["coordinator.factor:err:1", "coordinator.factor:nan:1"] {
        let (r, events) = run(spec);
        assert!(events >= 1, "{spec}: fault never fired");
        let (_qm, report) = r.unwrap_or_else(|e| panic!("{spec}: must degrade, not abort: {e:#}"));
        assert!(report.layers.iter().any(|s| s.fallback), "{spec}: no fallback recorded");
    }

    // Stall is a pure delay — the run completes untouched.
    let (r, events) = run("coordinator.advance:stall:1");
    assert!(events >= 1 && r.is_ok(), "stall must not change the outcome");

    // IO sites, via a checkpointed run: clean error, manifest still
    // loadable, the destination file never torn.
    let tmp = fresh_dir("ojbkq_fault_sweep_io");
    for (i, spec) in ["io.segment_write:err:1", "io.manifest_write:err:1"].into_iter().enumerate() {
        robust::set_faults(Some(spec)).unwrap();
        let parts = tmp.join(format!("sweep_{i}.parts"));
        let r = quantize_model_checkpointed(
            &model, &corpus, Method::Ojbkq, &cfg, 2, 16, None, &parts, false,
        );
        let events = robust::fault_event_count();
        robust::reset_faults();
        assert!(events >= 1, "{spec}: fault never fired");
        let site = spec.split(':').next().unwrap();
        let msg = format!("{:#}", r.expect_err(spec));
        assert!(msg.contains(site), "{spec}: error does not name the site: {msg}");
        if RunManifest::path(&parts).exists() {
            RunManifest::load(&parts).unwrap_or_else(|e| panic!("{spec}: torn manifest: {e:#}"));
        }
    }
}

/// NaN-seeded calibration activations are detected at ingest — before
/// the Gram build can spread the poison — with sequence/position/dim
/// context in the error.
#[test]
fn calib_nan_is_reported_with_context() {
    let _g = lock();
    robust::reset_faults();
    let (mut model, _corpus) = setup();
    // Token 5 appears in the explicit calibration set below; poisoning
    // its embedding row poisons the ingest activations for exactly that
    // sequence/position.
    model.embedding.row_mut(5)[0] = f32::NAN;
    let calib = vec![vec![1u16, 2, 3], vec![4, 5, 6]];
    let err = Pipeline::new(&model, calib, Method::Ojbkq, qcfg(), None)
        .run()
        .expect_err("NaN calibration activations must fail loudly at ingest");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("sequence") && msg.contains("position"),
        "ingest error lacks sequence/position context: {msg}"
    );
}

fn serve_model() -> QuantizedModel {
    let cfg = ModelConfig {
        name: "fault-serve".into(),
        vocab_size: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 24,
    };
    let mut rng = Rng::new(0x5EFA);
    let m = Model::random(cfg, &mut rng);
    let mut qm = QuantizedModel::from_model(&m);
    let qc = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
    for id in qm.linear_ids() {
        let q = rtn::quantize(m.linear(id), &qc);
        qm.set_layer(id, PackedLinear::from_quantized(&q, true));
    }
    qm
}

/// Serve-side fault sites: an injected step fault and a poisoned logits
/// row each retire exactly one request with an error status while its
/// batch peer completes its full budget — one poisoned request never
/// takes down the batch.
#[test]
fn serve_faults_retire_poisoned_requests_without_killing_the_batch() {
    let _g = lock();
    robust::reset_faults();
    let qm = serve_model();
    for spec in ["serve.step:err:1", "serve.logits:nan:1"] {
        robust::set_faults(Some(spec)).unwrap();
        let mut sched = Scheduler::new(&qm, 2);
        for id in 0..2u64 {
            sched
                .submit(Request {
                    id,
                    prompt: vec![2 + id as u16, 5, 9],
                    max_new: 4,
                    temperature: 0.0,
                    seed: id,
                })
                .unwrap();
        }
        let fins = sched.run().to_vec();
        let events = robust::fault_event_count();
        robust::reset_faults();
        assert!(events >= 1, "{spec}: fault never fired");
        assert_eq!(fins.len(), 2, "{spec}: every request must retire");
        let errored: Vec<_> =
            fins.iter().filter(|f| matches!(f.status, FinishStatus::Error(_))).collect();
        assert_eq!(errored.len(), 1, "{spec}: exactly one request absorbs the fault: {fins:?}");
        assert!(
            fins.iter()
                .any(|f| f.status == FinishStatus::Complete && f.generated.len() == 4),
            "{spec}: the surviving request must complete its budget: {fins:?}"
        );
    }
}
