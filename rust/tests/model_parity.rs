//! Cross-implementation numerics: the Rust forward pass must reproduce
//! the JAX training forward (python/compile/pretrain.py) on the exported
//! fixture — this is what makes "quantize the JAX-trained weights in
//! Rust" sound.
//!
//! Skips when artifacts are missing (`make artifacts`).

use ojbkq::coordinator::quantize_model;
use ojbkq::infer::{load_quantized, save_quantized};
use ojbkq::model::{load_model, save_model};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::util::bytes_to_f32s;
use std::io::{BufRead, Read};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    std::env::var("OJBKQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Parse an OJBF1 fixture: (tokens, logits seq×vocab).
fn load_fixture(path: &PathBuf) -> anyhow::Result<(Vec<u16>, usize, Vec<f32>)> {
    let f = std::fs::File::open(path)?;
    let mut r = std::io::BufReader::new(f);
    let mut line = String::new();
    r.read_line(&mut line)?;
    anyhow::ensure!(line.trim() == "OJBF1", "bad fixture magic");
    line.clear();
    r.read_line(&mut line)?;
    let dims: Vec<usize> = line.split_whitespace().map(|t| t.parse().unwrap()).collect();
    let (seq, vocab) = (dims[0], dims[1]);
    let mut tok_bytes = vec![0u8; seq * 2];
    r.read_exact(&mut tok_bytes)?;
    let tokens: Vec<u16> =
        tok_bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    let mut logit_bytes = vec![0u8; seq * vocab * 4];
    r.read_exact(&mut logit_bytes)?;
    Ok((tokens, vocab, bytes_to_f32s(&logit_bytes)?))
}

#[test]
fn rust_forward_matches_jax_fixture() {
    let dir = artifacts_dir();
    let mut checked = 0;
    for name in ["tiny-0.2M", "small-0.8M", "base-2M", "med-5M"] {
        let model_path = dir.join(format!("model_{name}.bin"));
        let fixture_path = dir.join(format!("fixture_{name}.bin"));
        if !model_path.exists() || !fixture_path.exists() {
            continue;
        }
        let model = load_model(&model_path, name).expect("load model");
        let (tokens, vocab, jax_logits) = load_fixture(&fixture_path).expect("load fixture");
        assert_eq!(vocab, model.cfg.vocab_size);
        let rust_logits = model.forward(&tokens);
        assert_eq!(rust_logits.shape(), (tokens.len(), vocab));
        // Relative Frobenius error between the two implementations.
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in rust_logits.as_slice().iter().zip(&jax_logits) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 1e-3, "{name}: rust vs jax logits rel err {rel}");
        // Also: argmax agreement (what generation actually consumes).
        let mut agree = 0;
        for t in 0..tokens.len() {
            let r_arg = ojbkq::util::argmax(rust_logits.row(t));
            let j_arg = ojbkq::util::argmax(&jax_logits[t * vocab..(t + 1) * vocab]);
            if r_arg == j_arg {
                agree += 1;
            }
        }
        assert!(
            agree as f64 / tokens.len() as f64 > 0.95,
            "{name}: argmax agreement only {agree}/{}",
            tokens.len()
        );
        checked += 1;
        eprintln!("parity ok: {name} (rel={rel:.2e})");
    }
    if checked == 0 {
        eprintln!("SKIP: no model/fixture artifacts found in {dir:?}; run `make artifacts`");
    }
}

/// The two on-disk forms of one quantized run must agree: evaluating the
/// packed OJBQ1 checkpoint is bit-identical to the in-memory engine that
/// wrote it, and the dense OJBW1 cross-check export (`--dense-out`)
/// scores the same model up to integer-kernel vs dense-GEMM accumulation
/// order. Also pins the artifact-size win on real trained weights.
///
/// Skips when artifacts are missing (`make artifacts`).
#[test]
fn dense_ojbw1_vs_packed_ojbq1_eval_parity() {
    let dir = artifacts_dir();
    let name = "tiny-0.2M";
    let model_path = dir.join(format!("model_{name}.bin"));
    let corpus_path = dir.join(format!("corpus_{name}.bin"));
    if !model_path.exists() || !corpus_path.exists() {
        eprintln!("SKIP: no trained artifacts for {name} in {dir:?}; run `make artifacts`");
        return;
    }
    let model = load_model(&model_path, name).expect("load model");
    let corpus = ojbkq::data::load_corpus(&corpus_path).expect("load corpus");
    let mut cfg = QuantConfig::paper_defaults(3, 128);
    cfg.packed_exec = true;
    let (qm, _) = quantize_model(&model, &corpus, Method::Rtn, &cfg, 2, 32, None).unwrap();
    let tmp = std::env::temp_dir().join("ojbkq_model_parity");
    std::fs::create_dir_all(&tmp).unwrap();
    let q_path = tmp.join(format!("parity_{name}.ojbq1"));
    let d_path = tmp.join(format!("parity_{name}.ojbw1"));
    let info = save_quantized(&qm, &q_path).unwrap();
    save_model(&qm.to_dense(), &d_path).unwrap();
    let dense_len = std::fs::metadata(&d_path).unwrap().len();
    assert!(
        info.file_bytes * 100 <= dense_len * 40,
        "trained-artifact OJBQ1 {} vs dense {} bytes",
        info.file_bytes,
        dense_len
    );
    let packed = load_quantized(&q_path, name).expect("load OJBQ1");
    let dense = load_model(&d_path, name).expect("load OJBW1");
    let seq_len = model.cfg.max_seq.min(64);
    let ppl_mem = ojbkq::eval::perplexity(&qm, &corpus, seq_len, 1_024);
    let ppl_packed = ojbkq::eval::perplexity(&packed, &corpus, seq_len, 1_024);
    assert_eq!(
        ppl_mem.to_bits(),
        ppl_packed.to_bits(),
        "OJBQ1 reload must score bit-identically ({ppl_mem} vs {ppl_packed})"
    );
    let ppl_dense = ojbkq::eval::perplexity(&dense, &corpus, seq_len, 1_024);
    let rel = (ppl_packed - ppl_dense).abs() / ppl_dense;
    assert!(
        rel < 5e-3,
        "packed OJBQ1 ppl {ppl_packed} vs dense OJBW1 ppl {ppl_dense} (rel {rel})"
    );
    eprintln!("parity ok: {name} OJBQ1 {}B vs OJBW1 {dense_len}B", info.file_bytes);
}
