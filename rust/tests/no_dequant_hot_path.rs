//! The packed-execution guarantee: with `packed_exec` on, pipeline
//! calibration capture and model evaluation never call
//! `QuantizedLinear::dequantize()` — the hot path runs entirely on
//! bit-packed integer codes.
//!
//! This file intentionally holds a single test: integration-test files
//! run as separate processes, so the process-global dequantize counter
//! ([`ojbkq::quant::qtensor::dequant_calls`]) is not polluted by other
//! tests running in parallel threads.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::quantize_model;
use ojbkq::data::SyntheticGrammar;
use ojbkq::eval::perplexity;
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::qtensor::dequant_calls;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::rng::Rng;

#[test]
fn packed_pipeline_and_eval_never_dequantize_on_hot_path() {
    let cfg = ModelConfig {
        name: "nodq".into(),
        vocab_size: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
    };
    let mut rng = Rng::new(0xD0);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(32, 0.2, 3).corpus(6_000, &mut rng);
    // RTN: codes only — no solver-side effective weight, so the one
    // legitimate dequantize per layer is the layer_stats diagnostic
    // computed at solve time (off the hot path).
    let qcfg =
        QuantConfig { wbit: 4, group_size: 8, packed_exec: true, ..Default::default() };
    let before = dequant_calls();
    let (qm, report) =
        quantize_model(&model, &corpus, Method::Rtn, &qcfg, 3, 24, None).unwrap();
    let after_pipeline = dequant_calls();
    assert_eq!(
        after_pipeline - before,
        report.layers.len() as u64,
        "capture/splice must not dequantize (only per-layer solve stats may)"
    );
    // Evaluation + raw forwards run straight off the packed codes.
    let ppl = perplexity(&qm, &corpus, 24, 480);
    assert!(ppl.is_finite() && ppl > 1.0);
    let toks: Vec<u16> = vec![1, 2, 3, 4, 5];
    let _ = qm.forward(&toks);
    assert_eq!(
        dequant_calls(),
        after_pipeline,
        "eval/forward on the packed engine must never dequantize"
    );
}
