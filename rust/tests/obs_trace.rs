//! Observability-stack integration suite (`ojbkq::obs` +
//! `report::RunTrace`):
//!
//! * **span nesting/aggregation** — guards aggregate `(count, secs)` by
//!   `/`-joined path, and a full pipeline run produces the documented
//!   span tree with call counts invariant across `OJBKQ_THREADS ∈ {1,4}`;
//! * **metrics registry concurrency** — counters/hists accumulate
//!   exactly under contention from many threads;
//! * **disabled-mode no-op** — with tracing off, an entire pipeline +
//!   eval + forward records *zero* events (the [`ojbkq::obs::event_count`]
//!   hook, mirroring `no_dequant_hot_path.rs`'s counter pattern);
//! * **inertness** — pipeline output is bit-identical with tracing on
//!   and off;
//! * **trace manifest** — a captured `RunTrace` serializes to JSON that
//!   passes [`ojbkq::report::validate_trace`], and tampering is caught.
//!
//! The obs registry and the trace override are process-global, so every
//! test here serializes through a file-wide mutex and resets the
//! registry on entry/exit (same discipline as `solver_parallel.rs`'s
//! thread pin).

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{quantize_model, PipelineReport};
use ojbkq::data::{Corpus, SyntheticGrammar};
use ojbkq::eval::perplexity;
use ojbkq::infer::QuantizedModel;
use ojbkq::model::{LanguageModel, Model};
use ojbkq::obs;
use ojbkq::parallel::set_thread_override;
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::report::{validate_trace, RunTrace};
use ojbkq::rng::Rng;
use std::sync::Mutex;

static OBS_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with tracing forced to `on`, the registry cleared, and the
/// worker thread count pinned to `threads` — restoring the environment
/// defaults afterwards. Serialized across tests in this binary (the
/// registry and both overrides are process-global).
fn with_obs<T>(on: bool, threads: usize, f: impl FnOnce() -> T) -> T {
    let _guard = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_trace_override(Some(on));
    set_thread_override(threads);
    obs::reset();
    let out = f();
    obs::set_trace_override(None);
    set_thread_override(0);
    obs::reset();
    out
}

fn tiny_setup() -> (Model, Corpus) {
    let cfg = ModelConfig {
        name: "obs".into(),
        vocab_size: 64,
        d_model: 24,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 48,
    };
    let mut rng = Rng::new(0x0B5);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(64, 0.2, 5).corpus(12_000, &mut rng);
    (model, corpus)
}

fn run_pipeline(model: &Model, corpus: &Corpus) -> (QuantizedModel, PipelineReport) {
    let cfg = QuantConfig { ntile: 16, ..QuantConfig::paper_defaults(4, 8) };
    quantize_model(model, corpus, Method::Ojbkq, &cfg, 3, 32, None).expect("pipeline")
}

#[test]
fn spans_aggregate_by_nested_path() {
    with_obs(true, 1, || {
        {
            let _outer = obs::span("pipeline");
            for _ in 0..3 {
                let _inner = obs::span("solve");
            }
        }
        let _toplevel = obs::span("eval");
        drop(_toplevel);
        let snap = obs::snapshot();
        let outer = snap.span("pipeline").expect("outer span recorded");
        assert_eq!(outer.count, 1);
        assert!(outer.secs >= 0.0);
        let inner = snap.span("pipeline/solve").expect("nested span aggregates under parent");
        assert_eq!(inner.count, 3);
        let eval = snap.span("eval").expect("sibling top-level span");
        assert_eq!(eval.count, 1);
        assert!(snap.span("solve").is_none(), "nested span must not leak to top level");
    });
}

#[test]
fn pipeline_span_tree_covers_phases_and_is_thread_invariant() {
    let (model, corpus) = tiny_setup();
    let mut per_thread: Vec<Vec<(String, u64)>> = Vec::new();
    for &threads in &[1usize, 4] {
        let snap = with_obs(true, threads, || {
            let _ = run_pipeline(&model, &corpus);
            obs::snapshot()
        });
        let n_layers = model.cfg.n_layers as u64;
        assert_eq!(snap.span("pipeline").expect("pipeline root span").count, 1);
        assert!(snap.span("pipeline/embed").is_some(), "embed span under pipeline");
        // Every tap group opens capture/factor/solve/pack under its own
        // span; solve closes once per linear in the group.
        for (group, lins) in [("attn_in", 3u64), ("o_in", 1), ("mlp_in", 2), ("down_in", 1)] {
            for phase in ["capture", "factor", "solve", "pack"] {
                let path = format!("pipeline/{group}/{phase}");
                let row = snap.span(&path).unwrap_or_else(|| panic!("missing span {path}"));
                assert!(row.count >= 1, "{path} count");
                if phase == "solve" || phase == "pack" {
                    assert_eq!(row.count, lins * n_layers, "{path} per-linear count");
                }
                if phase == "factor" {
                    assert_eq!(row.count, n_layers, "{path} once per block");
                }
            }
        }
        // Span paths never escape the curated taxonomy.
        for row in &snap.spans {
            for seg in row.path.split('/') {
                assert!(obs::SPAN_NAMES.contains(&seg), "unknown span segment {seg}");
            }
        }
        // Per-layer quality metrics covered every quantized linear.
        assert_eq!(snap.counter("quant.layers"), 7 * n_layers);
        assert!(snap.counter("quant.cols") > 0);
        assert!(snap.counter("quant.klein_samples") > 0, "K>0 decode samples Klein paths");
        assert!(snap.counter("capture.block_steps") > 0);
        per_thread.push(snap.spans.iter().map(|s| (s.path.clone(), s.count)).collect());
    }
    // Span paths and call counts are scheduling-invariant (wall-clock
    // obviously differs); only the parallel.* metrics may vary.
    assert_eq!(per_thread[0], per_thread[1], "span tree must not depend on thread count");
}

#[test]
fn metrics_registry_is_concurrency_safe() {
    with_obs(true, 1, || {
        std::thread::scope(|scope| {
            for t in 0..8 {
                scope.spawn(move || {
                    for i in 0..500u64 {
                        obs::counter_add("qgemm.calls", 1);
                        obs::hist_record("layer.rt_err", (t * 500 + i) as f64);
                    }
                });
            }
        });
        let snap = obs::snapshot();
        assert_eq!(snap.counter("qgemm.calls"), 8 * 500);
        let (_, h) = snap
            .hists
            .iter()
            .find(|(n, _)| n == "layer.rt_err")
            .expect("hist recorded under contention");
        assert_eq!(h.count, 8 * 500);
        assert_eq!(h.min, 0.0);
        assert_eq!(h.max, (8 * 500 - 1) as f64);
    });
}

#[test]
fn disabled_mode_records_nothing_across_full_pipeline() {
    let (model, corpus) = tiny_setup();
    with_obs(false, 0, || {
        let (qm, _report) = run_pipeline(&model, &corpus);
        let ppl = perplexity(&qm, &corpus, 32, 640);
        assert!(ppl.is_finite());
        let _ = qm.forward(&[1u16, 2, 3, 4, 5]);
        assert_eq!(
            obs::event_count(),
            0,
            "tracing off must record zero span/metric events on the hot path"
        );
        assert!(obs::snapshot().spans.is_empty());
    });
}

#[test]
fn tracing_is_inert_pipeline_output_bit_identical() {
    let (model, corpus) = tiny_setup();
    let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let (logits_off, ppl_off) = with_obs(false, 2, || {
        let (qm, _) = run_pipeline(&model, &corpus);
        (qm.forward(&toks), perplexity(&qm, &corpus, 32, 640))
    });
    let (logits_on, ppl_on) = with_obs(true, 2, || {
        let (qm, _) = run_pipeline(&model, &corpus);
        (qm.forward(&toks), perplexity(&qm, &corpus, 32, 640))
    });
    assert!(logits_off == logits_on, "forward logits must be bit-identical with tracing on/off");
    assert_eq!(ppl_off, ppl_on, "perplexity must be bit-identical with tracing on/off");
}

#[test]
fn fault_harness_is_inert_when_disarmed() {
    // The fault harness (`ojbkq::robust`) mirrors obs's zero-cost
    // discipline: disarmed, a full pipeline crosses every fault site
    // without recording a single event; armed-but-never-firing leaves
    // the output bit-identical too.
    let (model, corpus) = tiny_setup();
    let toks: Vec<u16> = vec![3, 1, 4, 1, 5, 9];
    let logits_disarmed = with_obs(false, 1, || {
        ojbkq::robust::reset_faults();
        let (qm, _) = run_pipeline(&model, &corpus);
        assert_eq!(ojbkq::robust::fault_event_count(), 0, "disarmed run recorded fault events");
        qm.forward(&toks)
    });
    let logits_armed = with_obs(false, 1, || {
        ojbkq::robust::set_faults(Some("coordinator.solve:err:1000000")).unwrap();
        let (qm, _) = run_pipeline(&model, &corpus);
        assert_eq!(ojbkq::robust::fault_event_count(), 0, "unfired fault recorded events");
        ojbkq::robust::reset_faults();
        qm.forward(&toks)
    });
    assert!(logits_disarmed == logits_armed, "armed-but-unfired fault harness moved bits");
}

#[test]
fn captured_trace_roundtrips_schema_validation() {
    let (model, corpus) = tiny_setup();
    with_obs(true, 2, || {
        let (qm, report) = run_pipeline(&model, &corpus);
        let _ = perplexity(&qm, &corpus, 32, 640);
        let mut trace = RunTrace::capture(vec![
            ("model".to_string(), "obs".to_string()),
            ("method".to_string(), "ours".to_string()),
        ]);
        trace.layers = report.trace_layers();
        assert_eq!(trace.layers.len(), report.layers.len());
        let json = trace.to_json();
        validate_trace(&json).expect("captured trace must satisfy its own schema");
        // The checker rejects taxonomy drift and version skew.
        let renamed = json.replacen("\"quant.layers\"", "\"quant.bogus\"", 1);
        assert!(renamed != json, "pipeline trace should carry quant.layers");
        assert!(validate_trace(&renamed).is_err(), "unknown metric name must be rejected");
        let skewed = json.replacen("\"version\":1", "\"version\":99", 1);
        assert!(validate_trace(&skewed).is_err(), "version skew must be rejected");
        // Human rendering exists and mentions at least the root span.
        let md = trace.to_markdown();
        assert!(md.contains("pipeline"));
    });
}
