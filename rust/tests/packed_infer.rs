//! Packed-execution parity and memory accounting: the quantized
//! inference engine (`ojbkq::infer`) must reproduce the dense spliced
//! model's logits from bit-packed integer codes — across bit-widths,
//! ragged scale groups, act-order permuted layers, and the dense
//! `effective` fallback — while resident weight memory shrinks by the
//! advertised factor and the report's accounting matches the engine's.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::quantize_model;
use ojbkq::data::{Corpus, SyntheticGrammar};
use ojbkq::eval::perplexity;
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::rng::Rng;

fn setup(d_model: usize, d_ff: usize) -> (Model, Corpus) {
    let cfg = ModelConfig {
        name: "pk".into(),
        vocab_size: 48,
        d_model,
        n_layers: 2,
        n_heads: 2,
        d_ff,
        max_seq: 32,
    };
    let mut rng = Rng::new(0xBEEF);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(48, 0.2, 5).corpus(10_000, &mut rng);
    (model, corpus)
}

/// Packed forward vs the dense dequantized twin of the *same* codes:
/// every supported bit-width, with ragged groups (m % gs ≠ 0) and ragged
/// column tiles (n % COL_TILE ≠ 0), through the act-order (perm) path.
#[test]
fn packed_forward_matches_dense_spliced_model() {
    // d=24, ff=40: 24×24, 24×40 and 40×24 layers — group size 9 leaves
    // ragged tails on both row counts, and both 24 and 40 are ragged
    // against the 32-column tiles.
    let (model, corpus) = setup(24, 40);
    let toks: Vec<u16> = vec![1, 7, 13, 2, 40, 9, 27, 5];
    for &wbit in &[2u8, 3, 4] {
        for &gs in &[8usize, 9, 0] {
            let cfg = QuantConfig {
                wbit,
                group_size: gs,
                k: 2,
                ntile: 16,
                packed_exec: true,
                ..QuantConfig::paper_defaults(wbit, gs)
            };
            // Ojbkq (act_order on by default) exercises the permuted
            // integer path on every layer.
            let (qm, _) =
                quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 24, None).unwrap();
            for id in qm.linear_ids() {
                assert!(qm.layer(id).is_packed(), "wbit={wbit} gs={gs} {id} fell back dense");
            }
            let dense = qm.to_dense();
            let rel = qm.forward(&toks).rel_err(&dense.forward(&toks));
            assert!(rel < 1e-3, "wbit={wbit} gs={gs}: packed vs dense logits rel={rel}");
        }
    }
}

/// RTN (no permutation, pure codes) also matches, and its packed layers
/// carry no activation gather.
#[test]
fn rtn_packed_forward_matches_dense() {
    let (model, corpus) = setup(24, 40);
    let toks: Vec<u16> = vec![3, 11, 0, 45, 22, 8];
    let cfg = QuantConfig { wbit: 3, group_size: 8, packed_exec: true, ..Default::default() };
    let (qm, _) = quantize_model(&model, &corpus, Method::Rtn, &cfg, 3, 24, None).unwrap();
    for id in qm.linear_ids() {
        assert!(qm.layer(id).is_packed());
    }
    let rel = qm.forward(&toks).rel_err(&qm.to_dense().forward(&toks));
    assert!(rel < 1e-3, "rel={rel}");
}

/// Transform methods (AWQ's folded scaling, QuIP's rotations) must keep
/// the dense `effective` fallback — and then packed and dense execution
/// are the same arithmetic, bit for bit.
#[test]
fn effective_fallback_layers_stay_dense_and_exact() {
    let (model, corpus) = setup(16, 24);
    let toks: Vec<u16> = vec![5, 9, 13, 2, 30];
    for method in [Method::Awq, Method::Quip] {
        let cfg = QuantConfig {
            wbit: 4,
            group_size: 8,
            ntile: 16,
            packed_exec: true,
            ..Default::default()
        };
        let (qm, _) = quantize_model(&model, &corpus, method, &cfg, 3, 24, None).unwrap();
        for id in qm.linear_ids() {
            assert!(
                !qm.layer(id).is_packed(),
                "{} {id} must use the dense effective fallback",
                method.label()
            );
        }
        let rel = qm.forward(&toks).rel_err(&qm.to_dense().forward(&toks));
        assert!(rel < 1e-12, "{}: rel={rel}", method.label());
    }
}

/// The report's engine-memory numbers must equal the engine's own
/// accounting layer by layer, and a realistic 4-bit config must hold
/// resident weight bytes at ≤ 1/4 of the f32 model.
#[test]
fn packed_bytes_accounting_matches_engine() {
    let (model, corpus) = setup(64, 96);
    let cfg =
        QuantConfig { wbit: 4, group_size: 32, packed_exec: true, ..Default::default() };
    let (qm, report) = quantize_model(&model, &corpus, Method::Rtn, &cfg, 3, 24, None).unwrap();
    assert_eq!(report.packed_weight_bytes(), qm.packed_weight_bytes());
    assert_eq!(report.fp_weight_bytes(), qm.fp_weight_bytes());
    for rec in &report.layers {
        assert_eq!(rec.resident_bytes, qm.layer(rec.id).bytes(), "{}", rec.id);
    }
    // W4 + one f32 scale/correction pair per 32-row group: ≥ 4× below
    // dense f32 resident memory.
    assert!(
        qm.packed_weight_bytes() * 4 <= qm.fp_weight_bytes(),
        "resident {} vs fp {} (ratio {:.2})",
        qm.packed_weight_bytes(),
        qm.fp_weight_bytes(),
        report.resident_compression()
    );
}

/// Dense-exec mode (the legacy f32 splice) produces the same scores the
/// packed engine does, and the eval harness runs on either — perplexity
/// is the paper's headline metric, so packed execution must not move it.
#[test]
fn eval_scores_match_between_packed_and_dense_exec() {
    let (model, corpus) = setup(24, 40);
    let base = QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, ..Default::default() };
    let packed_cfg = QuantConfig { packed_exec: true, ..base.clone() };
    let dense_cfg = QuantConfig { packed_exec: false, ..base };
    let (qm_p, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &packed_cfg, 3, 24, None).unwrap();
    let (qm_d, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &dense_cfg, 3, 24, None).unwrap();
    let ppl_p = perplexity(&qm_p, &corpus, 24, 480);
    let ppl_d = perplexity(&qm_d, &corpus, 24, 480);
    let rel = (ppl_p - ppl_d).abs() / ppl_d;
    assert!(rel < 0.02, "packed ppl {ppl_p} vs dense ppl {ppl_d}");
    assert!(ppl_p.is_finite() && ppl_p > 1.0);
}
