//! Packed-execution parity and memory accounting: the quantized
//! inference engine (`ojbkq::infer`) must reproduce the dense spliced
//! model's logits from bit-packed integer codes — across bit-widths,
//! ragged scale groups, act-order permuted layers, and the dense
//! `effective` fallback — while resident weight memory shrinks by the
//! advertised factor and the report's accounting matches the engine's.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::quantize_model;
use ojbkq::data::{Corpus, SyntheticGrammar};
use ojbkq::eval::perplexity;
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::rng::Rng;

fn setup(d_model: usize, d_ff: usize) -> (Model, Corpus) {
    let cfg = ModelConfig {
        name: "pk".into(),
        vocab_size: 48,
        d_model,
        n_layers: 2,
        n_heads: 2,
        d_ff,
        max_seq: 32,
    };
    let mut rng = Rng::new(0xBEEF);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(48, 0.2, 5).corpus(10_000, &mut rng);
    (model, corpus)
}

/// Packed forward vs the dense dequantized twin of the *same* codes:
/// every supported bit-width, with ragged groups (m % gs ≠ 0) and ragged
/// column tiles (n % COL_TILE ≠ 0), through the act-order (perm) path.
#[test]
fn packed_forward_matches_dense_spliced_model() {
    // d=24, ff=40: 24×24, 24×40 and 40×24 layers — group size 9 leaves
    // ragged tails on both row counts, and both 24 and 40 are ragged
    // against the 32-column tiles.
    let (model, corpus) = setup(24, 40);
    let toks: Vec<u16> = vec![1, 7, 13, 2, 40, 9, 27, 5];
    for &wbit in &[2u8, 3, 4] {
        for &gs in &[8usize, 9, 0] {
            let cfg = QuantConfig {
                wbit,
                group_size: gs,
                k: 2,
                ntile: 16,
                packed_exec: true,
                ..QuantConfig::paper_defaults(wbit, gs)
            };
            // Ojbkq (act_order on by default) exercises the permuted
            // integer path on every layer.
            let (qm, _) =
                quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 24, None).unwrap();
            for id in qm.linear_ids() {
                assert!(qm.layer(id).is_packed(), "wbit={wbit} gs={gs} {id} fell back dense");
            }
            let dense = qm.to_dense();
            let rel = qm.forward(&toks).rel_err(&dense.forward(&toks));
            assert!(rel < 1e-3, "wbit={wbit} gs={gs}: packed vs dense logits rel={rel}");
        }
    }
}

/// RTN (no permutation, pure codes) also matches, and its packed layers
/// carry no activation gather.
#[test]
fn rtn_packed_forward_matches_dense() {
    let (model, corpus) = setup(24, 40);
    let toks: Vec<u16> = vec![3, 11, 0, 45, 22, 8];
    let cfg = QuantConfig { wbit: 3, group_size: 8, packed_exec: true, ..Default::default() };
    let (qm, _) = quantize_model(&model, &corpus, Method::Rtn, &cfg, 3, 24, None).unwrap();
    for id in qm.linear_ids() {
        assert!(qm.layer(id).is_packed());
    }
    let rel = qm.forward(&toks).rel_err(&qm.to_dense().forward(&toks));
    assert!(rel < 1e-3, "rel={rel}");
}

/// Transform methods (AWQ's folded scaling, QuIP's rotations) must keep
/// the dense `effective` fallback — and then packed and dense execution
/// are the same arithmetic, bit for bit.
#[test]
fn effective_fallback_layers_stay_dense_and_exact() {
    let (model, corpus) = setup(16, 24);
    let toks: Vec<u16> = vec![5, 9, 13, 2, 30];
    for method in [Method::Awq, Method::Quip] {
        let cfg = QuantConfig {
            wbit: 4,
            group_size: 8,
            ntile: 16,
            packed_exec: true,
            ..Default::default()
        };
        let (qm, _) = quantize_model(&model, &corpus, method, &cfg, 3, 24, None).unwrap();
        for id in qm.linear_ids() {
            assert!(
                !qm.layer(id).is_packed(),
                "{} {id} must use the dense effective fallback",
                method.label()
            );
        }
        let rel = qm.forward(&toks).rel_err(&qm.to_dense().forward(&toks));
        assert!(rel < 1e-12, "{}: rel={rel}", method.label());
    }
}

/// The report's engine-memory numbers must equal the engine's own
/// accounting layer by layer, and a realistic 4-bit config must hold
/// resident weight bytes at ≤ 1/4 of the f32 model.
#[test]
fn packed_bytes_accounting_matches_engine() {
    let (model, corpus) = setup(64, 96);
    let cfg =
        QuantConfig { wbit: 4, group_size: 32, packed_exec: true, ..Default::default() };
    let (qm, report) = quantize_model(&model, &corpus, Method::Rtn, &cfg, 3, 24, None).unwrap();
    assert_eq!(report.packed_weight_bytes(), qm.packed_weight_bytes());
    assert_eq!(report.fp_weight_bytes(), qm.fp_weight_bytes());
    for rec in &report.layers {
        assert_eq!(rec.resident_bytes, qm.layer(rec.id).bytes(), "{}", rec.id);
    }
    // W4 + one f32 scale/correction pair per 32-row group: ≥ 4× below
    // dense f32 resident memory.
    assert!(
        qm.packed_weight_bytes() * 4 <= qm.fp_weight_bytes(),
        "resident {} vs fp {} (ratio {:.2})",
        qm.packed_weight_bytes(),
        qm.fp_weight_bytes(),
        report.resident_compression()
    );
}

/// Dense-exec mode (the legacy f32 splice) produces the same scores the
/// packed engine does, and the eval harness runs on either — perplexity
/// is the paper's headline metric, so packed execution must not move it.
#[test]
fn eval_scores_match_between_packed_and_dense_exec() {
    let (model, corpus) = setup(24, 40);
    let base = QuantConfig { wbit: 4, group_size: 8, k: 2, ntile: 16, ..Default::default() };
    let packed_cfg = QuantConfig { packed_exec: true, ..base.clone() };
    let dense_cfg = QuantConfig { packed_exec: false, ..base };
    let (qm_p, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &packed_cfg, 3, 24, None).unwrap();
    let (qm_d, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &dense_cfg, 3, 24, None).unwrap();
    let ppl_p = perplexity(&qm_p, &corpus, 24, 480);
    let ppl_d = perplexity(&qm_d, &corpus, 24, 480);
    let rel = (ppl_p - ppl_d).abs() / ppl_d;
    assert!(rel < 0.02, "packed ppl {ppl_p} vs dense ppl {ppl_d}");
    assert!(ppl_p.is_finite() && ppl_p > 1.0);
}

// ----- integer-core vs f32-reference parity (PR 6) ---------------------

use ojbkq::infer::{qgemm_packed_with, qgemv_packed_with, PackedCore, PackedLinear};
use ojbkq::quant::qtensor::{
    pack_bits, unpack_bits_range, unpack_bits_range_lut, unpack_bits_range_shift,
};
use ojbkq::quant::{gptq, rtn};
use ojbkq::tensor::Matrix;

/// Relative parity bound between the integer core and the f32
/// reference: the integer core quantizes activations onto a per-group
/// fixed-point grid of amplitude ≤ 32767, so its results differ from
/// the f32 kernel by O(group_max/2·amp) per activation — measured
/// ≈ 2-4·10⁻⁵ Frobenius-relative on gaussian layers, bounded here with
/// headroom (see DESIGN.md §Integer-core packed GEMM).
const CORE_PARITY_REL: f64 = 1e-4;

/// Kernel-level parity across every deployment width, ragged group and
/// tile shapes, the act-order (perm) path, the m=1 gemv entry, and a
/// tall batch that takes the parallel grid.
#[test]
fn int_core_matches_f32_core_across_widths_and_shapes() {
    let mut rng = Rng::new(0xC0DE);
    for &wbit in &[2u8, 3, 4] {
        for &(m, n, gs) in &[(48usize, 40usize, 16usize), (33, 37, 12), (64, 96, 0)] {
            let w = Matrix::randn(m, n, 0.5, &mut rng);
            let cfg = QuantConfig { wbit, group_size: gs, ..Default::default() };
            let q = rtn::quantize(&w, &cfg);
            let p = PackedLinear::from_quantized(&q, true);
            let t = p.as_packed().unwrap();
            for &b in &[1usize, 8, 600] {
                let x = Matrix::randn(b, m, 1.0, &mut rng);
                let yi = qgemm_packed_with(t, &x, PackedCore::Int);
                let yf = qgemm_packed_with(t, &x, PackedCore::F32);
                let rel = yi.rel_err(&yf);
                assert!(
                    rel < CORE_PARITY_REL,
                    "wbit={wbit} m={m} n={n} gs={gs} b={b}: int vs f32 rel={rel}"
                );
                if b == 1 {
                    assert_eq!(
                        qgemv_packed_with(t, &x, PackedCore::Int),
                        yi,
                        "gemv entry must be bit-identical to the gemm path"
                    );
                }
            }
        }
    }
}

/// The act-order decode-permutation path holds the same parity: the
/// integer prologue resolves the gather once, the f32 core gathers
/// inside the tile loop — same math, same bound.
#[test]
fn int_core_matches_f32_core_act_order() {
    let mut rng = Rng::new(0xAC7);
    let w = Matrix::randn(40, 24, 0.5, &mut rng);
    let xcal = Matrix::randn(16, 40, 1.0, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 8, act_order: true, ..Default::default() };
    let q = gptq::quantize(&w, &xcal, &cfg).unwrap();
    assert!(q.perm.is_some());
    let p = PackedLinear::from_quantized(&q, true);
    let t = p.as_packed().unwrap();
    for &b in &[1usize, 7, 130] {
        let x = Matrix::randn(b, 40, 1.0, &mut rng);
        let rel =
            qgemm_packed_with(t, &x, PackedCore::Int).rel_err(&qgemm_packed_with(t, &x, PackedCore::F32));
        assert!(rel < CORE_PARITY_REL, "b={b}: rel={rel}");
    }
}

/// Both cores are bit-stable across thread counts: the integer core by
/// exact i32 accumulation, the f32 core by fixed per-accumulator
/// addition order. A tall batch (above the parallel threshold) must
/// reproduce the single-thread result exactly at any pin.
#[test]
fn cores_are_bit_stable_across_thread_counts() {
    let mut rng = Rng::new(0x7C0);
    let w = Matrix::randn(48, 40, 0.5, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 16, ..Default::default() };
    let p = PackedLinear::from_quantized(&rtn::quantize(&w, &cfg), true);
    let t = p.as_packed().unwrap();
    let x = Matrix::randn(600, 48, 1.0, &mut rng); // 600·48·40 ≥ 2^20
    for core in [PackedCore::Int, PackedCore::F32] {
        ojbkq::parallel::set_thread_override(1);
        let base = qgemm_packed_with(t, &x, core);
        for threads in [2usize, 3, 5, 8] {
            ojbkq::parallel::set_thread_override(threads);
            assert_eq!(
                qgemm_packed_with(t, &x, core),
                base,
                "{core:?} not bit-stable at {threads} threads"
            );
        }
        ojbkq::parallel::set_thread_override(0);
    }
}

/// Model-level parity: the same packed model forwards the same tokens
/// under both cores (flipped via the process-global override, as the
/// CLI's `--f32-core` does) to logits within the spliced-model
/// tolerance the rest of this suite uses.
#[test]
fn model_forward_parity_between_cores() {
    let (model, corpus) = setup(24, 40);
    let toks: Vec<u16> = vec![4, 19, 7, 33, 2, 41, 11];
    let cfg = QuantConfig {
        wbit: 3,
        group_size: 9,
        k: 2,
        ntile: 16,
        packed_exec: true,
        ..QuantConfig::paper_defaults(3, 9)
    };
    let (qm, _) = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 24, None).unwrap();
    ojbkq::infer::set_packed_core_override(Some(PackedCore::Int));
    let li = qm.forward(&toks);
    ojbkq::infer::set_packed_core_override(Some(PackedCore::F32));
    let lf = qm.forward(&toks);
    ojbkq::infer::set_packed_core_override(None);
    let rel = li.rel_err(&lf);
    assert!(rel < 1e-3, "int vs f32 logits rel={rel}");
}

/// Exhaustive three-way unpack equivalence at the deployment widths:
/// the u64 bit-sliced fast path, the PR-3 LUT path, and the per-code
/// shift reference must agree code-for-code — over streams laid out
/// from every byte pattern, at every alignment class, on logical and
/// word-padded stream lengths alike.
#[test]
fn u64_lut_and_shift_unpack_agree() {
    let mut scratch_a = [0u8; 97];
    let mut scratch_b = [0u8; 97];
    let mut scratch_c = [0u8; 97];
    for &wbit in &[2u8, 3, 4] {
        let maxc = 1u16 << wbit;
        // Codes cycling through every value and every adjacent pair, long
        // enough for several u64 words plus ragged head and tail.
        let codes: Vec<u8> =
            (0..97u16).map(|i| ((i * 7 + i * i) % maxc) as u8).collect();
        let logical = pack_bits(&codes, wbit);
        let mut padded = logical.clone();
        padded.resize(logical.len().div_ceil(8) * 8, 0);
        for stream in [&logical, &padded] {
            for start in 0..codes.len() {
                for &len in &[0usize, 1, 7, 15, 16, 17, 31, 32, 33, codes.len() - start] {
                    if len > codes.len() - start {
                        continue;
                    }
                    let (a, b, c) = (
                        &mut scratch_a[..len],
                        &mut scratch_b[..len],
                        &mut scratch_c[..len],
                    );
                    unpack_bits_range(stream, wbit, start, a);
                    unpack_bits_range_lut(stream, wbit, start, b);
                    unpack_bits_range_shift(stream, wbit, start, c);
                    assert_eq!(a, c, "u64 vs shift: wbit={wbit} start={start} len={len}");
                    assert_eq!(b, c, "lut vs shift: wbit={wbit} start={start} len={len}");
                    assert_eq!(&codes[start..start + len], c, "shift vs packer");
                }
            }
        }
    }
}
